from repro.fl.keys import KeyAuthority, ThresholdKeyAuthority
from repro.fl.client import FLClient, ClientConfig
from repro.fl.server import FLServer
from repro.fl.orchestrator import FLTask, FLRunConfig, run_federated_training
