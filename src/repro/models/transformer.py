"""Decoder/encoder transformer backbones: dense, MoE, encoder-only (HuBERT),
and VLM (phi-3-vision with stubbed patch frontend).

Layer loop is a static python loop over stacked per-layer weights — layers
are *unrolled* in the lowered HLO so cost_analysis/collective parsing is
exact (see DESIGN.md §6).  cfg.remat wraps each layer in jax.checkpoint.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import sharding
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init(cfg: ModelConfig, key):
    ks = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.param_dtype)
    p = {}
    if cfg.vocab:
        p.update(L.init_embed(ks[0], cfg))
    blk = {
        "ln1": jnp.ones((cfg.n_layers, cfg.d_model), dt),
        "ln2": jnp.ones((cfg.n_layers, cfg.d_model), dt),
        **L.init_attn(ks[1], cfg, cfg.n_layers),
    }
    if cfg.family == "moe":
        blk.update(moe_mod.init(ks[2], cfg, cfg.n_layers))
    else:
        blk.update(L.init_mlp(ks[2], cfg, cfg.n_layers))
    p["layers"] = blk
    p["ln_f"] = jnp.ones((cfg.d_model,), dt)
    if cfg.family == "vlm":
        p["patch_proj"] = L.trunc_normal(ks[3], (cfg.patch_dim, cfg.d_model),
                                         0.02, dt)
    if cfg.family == "encoder":
        p["frame_proj"] = L.trunc_normal(ks[3], (cfg.frame_dim, cfg.d_model),
                                         0.02, dt)
    return p


def init_abstract(cfg: ModelConfig, key=None):
    return jax.eval_shape(lambda: init(cfg, jax.random.PRNGKey(0)))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _layer(p, i, x, cfg: ModelConfig, ax, positions, causal: bool):
    h = L.rms_norm(x, p["ln1"][i])
    q, k, v = L.attn_qkv(p, i, h, cfg, ax, positions)
    o = L.blocked_attention(q, k, v, cfg, ax, causal=causal)
    x = x + L.attn_out(p, i, o, x.dtype)
    h = L.rms_norm(x, p["ln2"][i])
    if cfg.family == "moe":
        y, aux = moe_mod.moe_ffn(p, i, h, cfg, ax)
    else:
        y, aux = L.mlp(p, i, h), 0.0
    return x + y, aux


def backbone(params, x, cfg: ModelConfig, ax, positions, causal=None):
    """x: [B, S, d] -> (hidden [B, S, d], aux_loss)."""
    causal = cfg.is_causal if causal is None else causal
    p = params["layers"]
    aux_total = 0.0
    step = _layer
    if cfg.remat:
        step = jax.checkpoint(_layer, static_argnums=(1, 3, 4, 6),
                              policy=None)
    for i in range(cfg.n_layers):
        x = sharding.constrain(x, ax.dp, ax.mp(x.shape[1]), None)
        x, aux = step(p, i, x, cfg, ax, positions, causal)
        aux_total = aux_total + aux
    return L.rms_norm(x, params["ln_f"]), aux_total


def _inputs_to_hidden(params, batch, cfg: ModelConfig, dtype):
    """Family-specific input embedding. Returns (x [B,S,d], positions [S])."""
    if cfg.family == "encoder":
        x = jnp.einsum("bsf,fd->bsd", batch["frames"].astype(dtype),
                       params["frame_proj"].astype(dtype))
        s = x.shape[1]
        return x, jnp.arange(s)
    if cfg.family == "vlm":
        tok = L.embed_tokens(params, batch["tokens"], cfg, dtype)
        img = jnp.einsum("bpf,fd->bpd", batch["patches"].astype(dtype),
                         params["patch_proj"].astype(dtype))
        x = jnp.concatenate([img, tok], axis=1)
        return x, jnp.arange(x.shape[1])
    x = L.embed_tokens(params, batch["tokens"], cfg, dtype)
    return x, jnp.arange(x.shape[1])


def forward_logits(params, batch, cfg: ModelConfig, ax):
    """Full-sequence logits [B, S(, V)] (+ MoE aux loss)."""
    dtype = jnp.dtype(cfg.dtype)
    x, positions = _inputs_to_hidden(params, batch, cfg, dtype)
    h, aux = backbone(params, x, cfg, ax, positions)
    if cfg.family == "vlm":
        h = h[:, cfg.n_patches:]          # loss on text positions only
    return L.logits_fn(params, h, cfg), aux


def loss_fn(params, batch, cfg: ModelConfig, ax):
    dtype = jnp.dtype(cfg.dtype)
    x, positions = _inputs_to_hidden(params, batch, cfg, dtype)
    h, aux = backbone(params, x, cfg, ax, positions)
    if cfg.family == "vlm":
        h = h[:, cfg.n_patches:]
    labels = batch.get("labels", batch.get("targets"))
    w = L.unembed_weight(params, cfg).astype(h.dtype)
    return L.chunked_softmax_xent(h, w, labels, cfg.vocab) + 0.01 * aux


# ---------------------------------------------------------------------------
# serving: prefill + KV-cache decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    """Per-layer list of buffers (NOT stacked): a stacked [L, ...] cache
    makes every layer's in-place update copy the whole cache (O(L^2) HBM
    traffic per decode step)."""
    shape = (batch, cache_len, cfg.n_kv_heads, cfg.hd)
    return {"k": [jnp.zeros(shape, dtype) for _ in range(cfg.n_layers)],
            "v": [jnp.zeros(shape, dtype) for _ in range(cfg.n_layers)],
            "pos": jnp.zeros((), jnp.int32)}


def abstract_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    return jax.eval_shape(lambda: init_cache(cfg, batch, cache_len, dtype))


def prefill(params, batch, cfg: ModelConfig, ax, cache_len: int | None = None):
    """Full forward over the prompt; returns (last-token logits, cache)."""
    dtype = jnp.dtype(cfg.dtype)
    x, positions = _inputs_to_hidden(params, batch, cfg, dtype)
    b, s, _ = x.shape
    cache_len = cache_len or s
    cache = init_cache(cfg, b, cache_len, dtype)
    p = params["layers"]
    for i in range(cfg.n_layers):
        x = sharding.constrain(x, ax.dp, ax.mp(x.shape[1]), None)
        h = L.rms_norm(x, p["ln1"][i])
        q, k, v = L.attn_qkv(p, i, h, cfg, ax, positions)
        o = L.blocked_attention(q, k, v, cfg, ax, causal=cfg.is_causal)
        x = x + L.attn_out(p, i, o, x.dtype)
        cache["k"][i] = cache["k"][i].at[:, :s].set(k)
        cache["v"][i] = cache["v"][i].at[:, :s].set(v)
        h = L.rms_norm(x, p["ln2"][i])
        if cfg.family == "moe":
            y, _ = moe_mod.moe_ffn(p, i, h, cfg, ax)
        else:
            y = L.mlp(p, i, h)
        x = x + y
    cache["pos"] = jnp.asarray(s, jnp.int32)
    h = L.rms_norm(x, params["ln_f"])
    logits = L.logits_fn(params, h[:, -1:], cfg)[:, 0]
    return logits, cache


def decode_step(params, cache, batch, cfg: ModelConfig, ax):
    """One token for every sequence in the batch.

    batch: {"tokens": i32[B]}; cache["pos"] scalar = write position.
    Returns (logits [B, V], updated cache).
    """
    dtype = jnp.dtype(cfg.dtype)
    cache = {"k": list(cache["k"]), "v": list(cache["v"]),
             "pos": cache["pos"]}
    pos = cache["pos"]
    tok = batch["tokens"]
    x = L.embed_tokens(params, tok[:, None], cfg, dtype)      # [B, 1, d]
    p = params["layers"]
    positions = pos[None]
    for i in range(cfg.n_layers):
        h = L.rms_norm(x, p["ln1"][i])
        q, k, v = L.attn_qkv(p, i, h, cfg, ax, positions)
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"][i], k, pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"][i], v, pos, axis=1)
        cache["k"][i] = kc
        cache["v"][i] = vc
        o = L.decode_attention(q[:, 0], kc, vc, pos)
        x = x + L.attn_out(p, i, o[:, None], x.dtype)
        h = L.rms_norm(x, p["ln2"][i])
        if cfg.family == "moe":
            y, _ = moe_mod.moe_ffn(p, i, h, cfg, ax)
        else:
            y = L.mlp(p, i, h)
        x = x + y
    cache["pos"] = pos + 1
    h = L.rms_norm(x, params["ln_f"])
    logits = L.logits_fn(params, h, cfg)[:, 0]
    return logits, cache
