"""Transcipher (hybrid-HE) uplink: the server's homomorphic unmask must be
BIT-IDENTICAL to the seeded-CKKS encrypt path for the same noise key, per
derive id and per backend — plus the thin-client bound validation, the
escrow seed ciphertext, the mod_lift kernel contract, and the StreamIngest
materials registry (DESIGN.md §15)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.ckks import cipher, encoding
from repro.core.ckks import params as ckks_params
from repro.core.ckks import transcipher as tc
from repro.kernels import ops
from repro.wire import compress as wc
from repro.wire import format as wf
from repro.wire import stream as ws

CTX = ckks_params.make_test_context(n_poly=256, n_limbs=2, delta_bits=20)
SK, PK = cipher.keygen(CTX, jax.random.PRNGKey(0))
DERIVES = (cipher.DERIVE_FOLD_CHUNK, cipher.DERIVE_CTR)


@pytest.fixture(params=["ref", "pallas", "pallas4"])
def backend(request):
    old = {op: ops.get_backend(op) for op in ops.OPS}
    ops.set_backend(request.param)
    yield request.param
    for op, name in old.items():
        ops.set_backend(name, op=op)


def _values(b=3, seed=0, scale=0.1):
    rng = np.random.RandomState(seed)
    return (rng.randn(b, CTX.slots) * scale).astype(np.float32)


# ---------------------------------------------------------------------------
# the exactness anchor: encode_np == encode_centered % qs
# ---------------------------------------------------------------------------


def test_encode_centered_is_pre_rns_encode_np():
    v = _values(b=4, seed=3, scale=2.0)
    c_int = encoding.encode_centered(v, CTX)
    qs = np.asarray(CTX.primes, dtype=np.int64)[None, :, None]
    np.testing.assert_array_equal(
        (c_int[:, None, :] % qs).astype(np.uint32),
        encoding.encode_np(v, CTX))


def test_mod_lift_matches_numpy_per_limb(backend):
    rng = np.random.RandomState(1)
    x = rng.randint(0, 1 << 32, size=(5, CTX.n_poly)).astype(np.uint32)
    out = np.asarray(ops.mod_lift(jnp.asarray(x), CTX.n_limbs, CTX))
    qs = np.asarray(CTX.primes, dtype=np.uint64)
    for li, q in enumerate(qs):
        np.testing.assert_array_equal(
            out[:, li, :], (x.astype(np.uint64) % q).astype(np.uint32))


# ---------------------------------------------------------------------------
# bit-identity with the seeded path, per derive x backend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("derive", DERIVES)
def test_server_unmask_bit_identical_to_seeded(derive, backend):
    v = _values()
    key, a_seed = jax.random.PRNGKey(42), 777
    coeffs = jnp.asarray(encoding.encode_np(v, CTX))
    ct_ref = cipher.encrypt_coeffs_seeded(CTX, SK, coeffs, key, a_seed,
                                          derive=derive)

    cm, sm = tc.provision(CTX, SK, key, a_seed, v.shape[0], derive=derive)
    masked = tc.mask_values(CTX, cm, v)
    ct = tc.server_unmask(CTX, sm, masked, 0)
    np.testing.assert_array_equal(np.asarray(ct.data),
                                  np.asarray(ct_ref.data))
    assert ct.scale == ct_ref.scale
    # and the round decrypts: client values survive mask -> unmask -> dec
    out = cipher.decrypt_values_np(CTX, SK, ct)
    assert float(np.abs(out - v).max()) < 3e-3


@pytest.mark.parametrize("derive", DERIVES)
def test_server_unmask_spanned_rows_bit_identical(derive):
    """Streaming receivers unmask arbitrary contiguous row slices: rows
    [1, B) unmasked at chunk_idx=1 must equal the same rows of the full
    unmask (per-chunk derivation is slice-invariant, DESIGN.md §9.2)."""
    v = _values(b=4, seed=5)
    key, a_seed = jax.random.PRNGKey(9), 31337
    cm, sm = tc.provision(CTX, SK, key, a_seed, 4, derive=derive)
    masked = tc.mask_values(CTX, cm, v)
    whole = tc.server_unmask(CTX, sm, masked, 0)
    part = tc.server_unmask(CTX, sm, masked[1:], 1)
    np.testing.assert_array_equal(np.asarray(whole.data[1:]),
                                  np.asarray(part.data))


def test_escrow_ct_decrypts_to_keystream_seed():
    key, a_seed = jax.random.PRNGKey(3), 12345
    cm, _ = tc.provision(CTX, SK, key, a_seed, 2)
    dig = np.asarray(cipher.decrypt_values_np(CTX, SK,
                                              cm.seed_ct)).ravel()[:4]
    rec = sum(int(round(float(d))) << (16 * i) for i, d in enumerate(dig))
    assert rec == cm.keystream_seed
    assert cm.escrow_a_seed == a_seed + tc.ESCROW_SEED_OFFSET


def test_keystream_seed_is_secret_not_wire_derivable():
    """Regression (review): the pad seed must depend on the provisioner's
    SECRET noise key — a seed derived from the wire-public a_seed would
    let any passive observer recompute the pad and recover the plaintext
    update as masked - K."""
    a_seed = 12345
    cm1, sm1 = tc.provision(CTX, SK, jax.random.PRNGKey(1), a_seed, 2)
    cm2, _ = tc.provision(CTX, SK, jax.random.PRNGKey(2), a_seed, 2)
    # same public inputs, different secret keys -> different pad seeds
    assert cm1.keystream_seed != cm2.keystream_seed
    assert 0 <= cm1.keystream_seed < 1 << 64
    # and specifically NOT the old public derivation a_seed + 2^41
    assert cm1.keystream_seed != a_seed + (1 << 41)
    # the server's materials never contain the seed
    assert not hasattr(sm1, "keystream_seed")
    # out-of-band provisioning is honored verbatim (and range-checked)
    cm3, _ = tc.provision(CTX, SK, jax.random.PRNGKey(1), a_seed, 2,
                          keystream_seed=0xDEADBEEF)
    assert cm3.keystream_seed == 0xDEADBEEF
    with pytest.raises(ValueError, match="64 bits"):
        tc.provision(CTX, SK, jax.random.PRNGKey(1), a_seed, 2,
                     keystream_seed=1 << 64)


def test_ctr_derive_streams_disjoint_for_sequential_seeds():
    """Regression (review): uplink_a_seed issues SEQUENTIAL seeds, so
    DERIVE_CTR must not give seed s's chunk b+1 the same key as seed
    s+1's chunk b — counter mode over the raw PRNGKey words did exactly
    that; the registry now hashes the base key once before counting."""
    for s in (0, 777, 1_000_003):
        k0 = np.asarray(cipher.derive_chunk_keys(
            jax.random.PRNGKey(s), 0, 8, cipher.DERIVE_CTR))
        k1 = np.asarray(cipher.derive_chunk_keys(
            jax.random.PRNGKey(s + 1), 0, 8, cipher.DERIVE_CTR))
        assert not (k0[:, None, :] == k1[None, :, :]).all(-1).any(), \
            f"CTR chunk keys overlap between base seeds {s} and {s + 1}"
    # ...and the expanded pad rows are likewise disjoint
    p0 = np.asarray(tc.expand_pad_rows(CTX.n_poly, 500, 0, 4))
    p1 = np.asarray(tc.expand_pad_rows(CTX.n_poly, 501, 0, 4))
    assert not (p0[:, None, :] == p1[None, :, :]).all(-1).any()


# ---------------------------------------------------------------------------
# validation: bound, shape, provisioned range
# ---------------------------------------------------------------------------


def test_mask_rejects_out_of_bound_coefficients():
    cm, _ = tc.provision(CTX, SK, jax.random.PRNGKey(0), 1, 1)
    big = np.zeros((1, CTX.n_poly), dtype=np.int64)
    big[0, 0] = 1 << tc.BOUND_BITS
    with pytest.raises(ValueError, match="delta"):
        tc.mask_coeffs_centered(CTX, cm, big)
    # the max encodable magnitude is fine
    big[0, 0] = (1 << tc.BOUND_BITS) - 1
    out = tc.mask_coeffs_centered(CTX, cm, big)
    assert out.dtype == np.uint32


def test_mask_rejects_chunk_count_mismatch():
    cm, _ = tc.provision(CTX, SK, jax.random.PRNGKey(0), 1, 2)
    with pytest.raises(ValueError, match="chunks"):
        tc.mask_coeffs_centered(CTX, cm,
                                np.zeros((3, CTX.n_poly), dtype=np.int64))


def test_unmask_rejects_rows_outside_provisioned_range():
    _, sm = tc.provision(CTX, SK, jax.random.PRNGKey(0), 1, 2)
    rows = np.ones((2, CTX.n_poly), dtype=np.uint32)
    with pytest.raises(ValueError, match="provisioned range"):
        tc.server_unmask(CTX, sm, rows, 1)       # rows [1, 3) vs [0, 2)


def test_pad_window_never_wraps():
    pad = np.asarray(tc.expand_pad_rows(CTX.n_poly, 999, 0, 8))
    assert pad.min() >= (1 << tc.BOUND_BITS)
    assert pad.max() < (1 << 32) - (1 << tc.BOUND_BITS)


# ---------------------------------------------------------------------------
# stream ingest: materials registry, bit parity, atomic rejection
# ---------------------------------------------------------------------------


def _masked_blob(v, cm, plain, cid=1, rnd=0):
    mc = wc.MaskedChunk(masked=tc.mask_values(CTX, cm, v), a_seed=cm.a_seed,
                        scale=cm.scale, derive=cm.derive)
    sct = wc.seed_compress(cm.seed_ct, cm.escrow_a_seed, cm.derive)
    return ws.pack_masked_update_frames(mc, sct, plain, cid=cid,
                                        n_samples=2, rnd=rnd)


@pytest.mark.parametrize("derive", DERIVES)
def test_stream_ingest_transcipher_bit_identical_to_seeded(derive, backend):
    v, plain = _values(seed=8), np.arange(9, dtype=np.float32)
    key, a_seed, cid, rnd = jax.random.PRNGKey(21), 1_000_003 * 0 + 1, 1, 0
    coeffs = jnp.asarray(encoding.encode_np(v, CTX))
    ct_ref = cipher.encrypt_coeffs_seeded(CTX, SK, coeffs, key, a_seed,
                                          derive=derive)
    from repro.core.secure_agg import ProtectedUpdate
    blob_seeded = ws.pack_update_frames(
        ProtectedUpdate(ct=ct_ref, plain=jnp.asarray(plain)), cid=cid,
        n_samples=2, rnd=rnd, seeded=wc.seed_compress(ct_ref, a_seed,
                                                      derive))
    ing_a = ws.StreamIngest(CTX)
    ing_a.ingest(blob_seeded, 0.5)
    agg_a = ing_a.finalize()

    cm, sm = tc.provision(CTX, SK, key, a_seed, v.shape[0], derive=derive)
    blob = _masked_blob(v, cm, plain, cid=cid, rnd=rnd)
    meta = ws.peek_update_meta(blob)
    assert meta.transcipher and not meta.seeded
    ing_b = ws.StreamIngest(CTX, transcipher_materials={(cid, rnd): sm})
    ing_b.ingest(blob, 0.5)
    agg_b = ing_b.finalize()
    np.testing.assert_array_equal(np.asarray(agg_a.ct.data),
                                  np.asarray(agg_b.ct.data))
    np.testing.assert_array_equal(np.asarray(agg_a.plain),
                                  np.asarray(agg_b.plain))
    # the escrow seed ciphertext was stored for the key authority
    esc = ing_b.escrow_seeds[(cid, rnd)].expand(CTX)
    dig = np.asarray(cipher.decrypt_values_np(CTX, SK, esc)).ravel()[:4]
    rec = sum(int(round(float(d))) << (16 * i) for i, d in enumerate(dig))
    assert rec == cm.keystream_seed


def test_stream_ingest_rejects_unprovisioned_transcipher_atomically():
    v, plain = _values(seed=2), np.zeros(4, dtype=np.float32)
    cm, sm = tc.provision(CTX, SK, jax.random.PRNGKey(5), 77, v.shape[0])
    blob = _masked_blob(v, cm, plain, cid=3, rnd=1)
    ing = ws.StreamIngest(CTX)            # no materials registered
    with pytest.raises(wf.WireError, match="no transcipher materials"):
        ing.ingest(blob, 1.0)
    assert ing.rejected_updates == 1 and ing._acc_ct is None
    assert not ing._pending and not ing.escrow_seeds
    # late provisioning heals it
    ing.add_transcipher_materials(3, 1, sm)
    ing.ingest(blob, 1.0)
    assert ing.finalize() is not None


def test_stream_ingest_rejects_mismatched_materials():
    import dataclasses
    v, plain = _values(seed=4), np.zeros(4, dtype=np.float32)
    cm, sm = tc.provision(CTX, SK, jax.random.PRNGKey(6), 88, v.shape[0])
    blob = _masked_blob(v, cm, plain, cid=2, rnd=0)
    bad = dataclasses.replace(sm, a_seed=sm.a_seed + 1)
    ing = ws.StreamIngest(CTX, transcipher_materials={(2, 0): bad})
    with pytest.raises(wf.WireError, match="do not match the provisioned"):
        ing.ingest(blob, 1.0)
    assert ing.rejected_updates == 1 and not ing.escrow_seeds


def test_rejected_update_restores_prior_escrow_seed():
    """Regression (review): a rejected re-submission for a (cid, round)
    that already has an escrow seed must restore the PRIOR ciphertext —
    not leave the rejected update's seed shadowing it in the audit
    trail."""
    import dataclasses
    v, plain = _values(seed=21), np.zeros(4, dtype=np.float32)
    cid, rnd = 6, 2
    cm, sm = tc.provision(CTX, SK, jax.random.PRNGKey(15), 55, v.shape[0])
    ing = ws.StreamIngest(CTX, transcipher_materials={(cid, rnd): sm})
    ing.ingest(_masked_blob(v, cm, plain, cid=cid, rnd=rnd), 1.0)
    before = ing.escrow_seeds[(cid, rnd)]
    # a second update for the same key: different escrow seed ct, and a
    # chunk a_seed that mismatches the materials -> rejected AFTER its
    # TRANSCIPHER_SEED frame overwrote the escrow entry
    bad_cm = dataclasses.replace(cm, a_seed=cm.a_seed + 1,
                                 escrow_a_seed=cm.escrow_a_seed + 7)
    with pytest.raises(wf.WireError, match="do not match the provisioned"):
        ing.ingest(_masked_blob(v, bad_cm, plain, cid=cid, rnd=rnd), 1.0)
    assert ing.escrow_seeds[(cid, rnd)].seed == before.seed
    assert ing.finalize() is not None


def test_chunk_kind_must_match_declared_ct_kind():
    """Regression (review): a MaskedChunk nested in a CT_FULL/CT_SEEDED
    update (or a seeded chunk in a CT_TRANSCIPHER one) is a
    wire-consistency violation — rejected atomically, never silently
    accepted under the wrong UpdateMeta classification."""
    import struct
    v = _values(b=1, seed=22)
    cm, sm = tc.provision(CTX, SK, jax.random.PRNGKey(16), 66, 1)
    mc = wc.MaskedChunk(masked=tc.mask_values(CTX, cm, v),
                        a_seed=cm.a_seed, scale=cm.scale, derive=cm.derive)
    arr, qscale = wc.quantize_plain(np.zeros(3, np.float32), "f32")

    def blob(kind, inner):
        return b"".join([
            wf.frame(wf.T_UPDATE_BEGIN, ws._BEGIN.pack(1, 1, 0, 1, kind)),
            wf.frame(wf.T_CT_CHUNK, struct.pack("<I", 0) + inner),
            wf.serialize_plain_segment(arr, "f32", qscale),
            wf.frame(wf.T_UPDATE_END, b"")])

    masked_inner = wf.serialize_masked_chunk(mc)
    key, a_seed = jax.random.PRNGKey(17), 66
    ct = cipher.encrypt_values_seeded(CTX, SK, jnp.asarray(v), key, a_seed)
    seeded_inner = wf.serialize_seeded_ciphertext(
        wc.seed_compress(ct, a_seed, cipher.DERIVE_FOLD_CHUNK))
    ing = ws.StreamIngest(CTX, transcipher_materials={(1, 0): sm})
    for kind, inner in ((ws.CT_FULL, masked_inner),
                        (ws.CT_SEEDED, masked_inner),
                        (ws.CT_TRANSCIPHER, seeded_inner)):
        with pytest.raises(wf.WireError, match="declared ct_kind"):
            ing.ingest(blob(kind, inner), 1.0)
    # unknown kind bytes and stray TRANSCIPHER_SEED frames reject too
    with pytest.raises(wf.WireError, match="unknown ct_kind"):
        ing.ingest(blob(7, seeded_inner), 1.0)
    sct = wc.seed_compress(cm.seed_ct, cm.escrow_a_seed, cm.derive)
    stray = b"".join([
        wf.frame(wf.T_UPDATE_BEGIN, ws._BEGIN.pack(1, 1, 0, 1,
                                                   ws.CT_SEEDED)),
        wf.serialize_transcipher_seed(sct),
        wf.frame(wf.T_CT_CHUNK, struct.pack("<I", 0) + seeded_inner),
        wf.serialize_plain_segment(arr, "f32", qscale),
        wf.frame(wf.T_UPDATE_END, b"")])
    with pytest.raises(wf.WireError, match="non-transcipher"):
        ing.ingest(stray, 1.0)
    assert ing.rejected_updates == 5 and ing._acc_ct is None
    assert not ing._pending and not ing.escrow_seeds


def test_transcipher_frames_are_v2_only():
    v = _values(b=1)
    cm, _ = tc.provision(CTX, SK, jax.random.PRNGKey(7), 5, 1)
    mc = wc.MaskedChunk(masked=tc.mask_values(CTX, cm, v),
                        a_seed=cm.a_seed, scale=cm.scale, derive=cm.derive)
    with pytest.raises(wf.WireError, match="v1"):
        wf.serialize_masked_chunk(mc, version=1)
    sct = wc.seed_compress(cm.seed_ct, cm.escrow_a_seed, cm.derive)
    with pytest.raises(wf.WireError, match="v1"):
        wf.serialize_transcipher_seed(sct, version=1)


def test_masked_chunk_roundtrip_and_unknown_derive_rejected():
    import dataclasses
    v = _values(b=2)
    cm, _ = tc.provision(CTX, SK, jax.random.PRNGKey(8), 6, 2)
    mc = wc.MaskedChunk(masked=tc.mask_values(CTX, cm, v),
                        a_seed=cm.a_seed, scale=cm.scale, chunk_offset=0,
                        derive=cm.derive)
    out, end = wf.deserialize(wf.serialize_masked_chunk(mc))
    assert isinstance(out, wc.MaskedChunk)
    np.testing.assert_array_equal(out.masked, np.asarray(mc.masked))
    assert (out.a_seed, out.scale, out.chunk_offset, out.derive) == \
        (mc.a_seed, mc.scale, mc.chunk_offset, mc.derive)
    blob = wf.serialize_masked_chunk(dataclasses.replace(mc, derive=9))
    with pytest.raises(wf.WireError, match="DESIGN.md"):
        wf.deserialize(blob)


# ---------------------------------------------------------------------------
# fl client + aggregation service plumbing
# ---------------------------------------------------------------------------


class _NoModel:
    """protect_and_pack never touches the model; FLClient.__init__ only
    reads .loss_fn to build the (unused here) jitted local-train step."""
    loss_fn = staticmethod(lambda params, batch: 0.0)


class _NoStream:
    def next_batch(self):
        raise AssertionError("unused")


def test_fl_client_transcipher_mode_matches_seeded_aggregate():
    from repro.core.secure_agg import (AggregatorConfig,
                                       SelectiveHEAggregator)
    from repro.fl.client import FLClient, uplink_a_seed
    from repro.wire.compress import LOSSLESS

    rng = np.random.RandomState(0)
    m = {"w": jnp.asarray(rng.randn(60, 10), jnp.float32)}
    sens = np.abs(rng.randn(600))
    agg = SelectiveHEAggregator.build(CTX, m, sens,
                                      AggregatorConfig(p_ratio=0.4))

    cid, rnd = 4, 1
    cli = FLClient(cid, _NoModel(), _NoStream())
    key = jax.random.PRNGKey(rnd * 100_003 + cid)
    a_seed = uplink_a_seed(rnd, cid)
    cm, sm = tc.provision(CTX, SK, jax.random.split(key)[0], a_seed,
                          agg.part.n_chunks, derive=cipher.DERIVE_CTR)
    blob_tc = cli.protect_and_pack(agg, m, rnd=rnd, policy=LOSSLESS, sk=SK,
                                   mode="transcipher",
                                   transcipher_materials=cm)
    ing = ws.StreamIngest(CTX, transcipher_materials={(cid, rnd): sm})
    ing.ingest(blob_tc, 1.0)
    rec = agg.client_recover_params(ing.finalize(), SK)
    err = float(jnp.abs(rec["w"] - m["w"]).max())
    assert err < 1e-2

    # missing/mismatched materials are caller errors, caught before the wire
    with pytest.raises(ValueError, match="transcipher_materials"):
        cli.protect_and_pack(agg, m, rnd=rnd, policy=LOSSLESS,
                             mode="transcipher")
    import dataclasses
    wrong = dataclasses.replace(cm, a_seed=cm.a_seed + 1)
    with pytest.raises(ValueError, match="uplink_a_seed"):
        cli.protect_and_pack(agg, m, rnd=rnd, policy=LOSSLESS,
                             mode="transcipher", transcipher_materials=wrong)


def test_fl_client_uplink_mode_env_default(monkeypatch):
    from repro.fl.client import FLClient

    cli = FLClient(0, _NoModel(), _NoStream())
    monkeypatch.setenv("REPRO_UPLINK_MODE", "bogus")
    from repro.core.secure_agg import (AggregatorConfig,
                                       SelectiveHEAggregator)
    from repro.wire.compress import LOSSLESS
    rng = np.random.RandomState(0)
    m = {"w": jnp.asarray(rng.randn(10, 10), jnp.float32)}
    agg = SelectiveHEAggregator.build(CTX, m, np.abs(rng.randn(100)),
                                      AggregatorConfig(p_ratio=0.4))
    with pytest.raises(ValueError, match="REPRO_UPLINK_MODE"):
        cli.protect_and_pack(agg, m, rnd=0, policy=LOSSLESS, sk=SK)


def test_aggregation_service_folds_transcipher_updates():
    """A transcipher blob folds through the async service exactly like a
    seeded one once materials are registered — and an unprovisioned one is
    dropped atomically with the round renormalizing over the survivors."""
    from repro.serve import quorum as qr
    from repro.serve.service import AggregationService

    v1, v2 = _values(seed=11), _values(seed=12)
    plain = np.zeros(5, dtype=np.float32)
    key = jax.random.PRNGKey(13)
    cm1, sm1 = tc.provision(CTX, SK, key, 1_000_003 * 0 + 0, v1.shape[0])
    cm2, sm2 = tc.provision(CTX, SK, jax.random.PRNGKey(14),
                            1_000_003 * 0 + 1, v2.shape[0])
    b1 = _masked_blob(v1, cm1, plain, cid=0, rnd=0)
    b2 = _masked_blob(v2, cm2, plain, cid=1, rnd=0)

    svc = AggregationService(
        CTX, qr.QuorumPolicy(min_clients=1, target_clients=2),
        transcipher_materials={(0, 0): sm1})   # cid 1 NOT provisioned
    svc.add_transcipher_materials(1, 0, sm2)   # ...until here
    rnd_id = svc.open_round()
    assert svc.submit(b1).accepted and svc.submit(b2).accepted
    svc.drain()
    out = svc.result(rnd_id)
    assert out is not None

    # reference: plain StreamIngest over the same blobs and weights
    ing = ws.StreamIngest(CTX, transcipher_materials={(0, 0): sm1,
                                                      (1, 0): sm2})
    ing.ingest(b1, 0.5)
    ing.ingest(b2, 0.5)
    np.testing.assert_array_equal(np.asarray(out.ct.data),
                                  np.asarray(ing.finalize().ct.data))
