"""Tier-1 test environment: force 4 simulated host devices.

jax locks the device count at first initialization, so this must run
before ANY test module imports jax — conftest import time is the only
hook early enough.  With 4 host devices the multi-device families in
tests/test_sharded.py and tests/test_ntt4.py run under a plain
`pytest -x -q` instead of skipping (CI asserts their skip count is 0);
on real hardware, or to test against the machine's actual devices, opt
out with REPRO_TEST_REAL_DEVICES=1.

An explicit --xla_force_host_platform_device_count in XLA_FLAGS (how the
CI matrix legs pin their own device counts) always wins over the default
here.
"""
import os
import sys

_FLAG = "--xla_force_host_platform_device_count"

_opt_out = os.environ.get("REPRO_TEST_REAL_DEVICES", "") not in ("", "0")

if not _opt_out and _FLAG not in os.environ.get("XLA_FLAGS", ""):
    if "jax" in sys.modules:                  # pragma: no cover - dev error
        raise RuntimeError(
            "jax was imported before tests/conftest.py could set XLA_FLAGS; "
            "the forced-host-device tier-1 contract needs conftest to run "
            "first (invoke tests via `python -m pytest` from the repo "
            "root), or opt out with REPRO_TEST_REAL_DEVICES=1")
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + f" {_FLAG}=4").strip()
