"""Substrate layers: optimizer, schedule, DoubleSqueeze compression,
checkpointing, data pipeline, sharding rules."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pragma: no cover
    from _hyp import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.ckpt import CheckpointManager, latest_step
from repro.data import SyntheticLM, dirichlet_partition, make_client_streams
from repro.models import sharding as shd
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         cosine_lr, double_squeeze_compress,
                         double_squeeze_init, topk_sparsify, global_norm)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, cfg)
    assert float(loss(params)) < 1e-2


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    g = {"w": jnp.full(4, 100.0)}
    _, _, norm = adamw_update(g, opt, params, cfg)
    assert float(norm) == pytest.approx(200.0)


def test_cosine_schedule_shape():
    assert float(cosine_lr(0, 1.0, warmup=10, total=100)) == 0.0
    assert float(cosine_lr(10, 1.0, warmup=10, total=100)) == pytest.approx(1.0)
    assert float(cosine_lr(100, 1.0, warmup=10, total=100)) == \
        pytest.approx(0.1, abs=1e-5)
    # monotone decay after warmup
    xs = [float(cosine_lr(s, 1.0, 10, 100)) for s in range(10, 101, 10)]
    assert all(a >= b for a, b in zip(xs, xs[1:]))


# ---------------------------------------------------------------------------
# DoubleSqueeze
# ---------------------------------------------------------------------------


def test_topk_sparsify():
    v = jnp.asarray([0.1, -5.0, 3.0, 0.0, -0.2])
    vals, idx, dense = topk_sparsify(v, 2)
    assert set(np.asarray(idx).tolist()) == {1, 2}
    np.testing.assert_allclose(np.asarray(dense),
                               [0.0, -5.0, 3.0, 0.0, 0.0])


@given(seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_double_squeeze_error_feedback_conserves(seed):
    """compressed + error == corrected (no signal lost, only delayed)."""
    rng = np.random.RandomState(seed)
    v = jnp.asarray(rng.randn(64), jnp.float32)
    state = double_squeeze_init(64)
    dense, _, new_state = double_squeeze_compress(v, state, k=8)
    np.testing.assert_allclose(np.asarray(dense + new_state.error),
                               np.asarray(v + state.error), atol=1e-6)


def test_double_squeeze_transmits_everything_with_bounded_error():
    """Error feedback: every coordinate is eventually transmitted and the
    residual stays bounded (top-k without feedback would starve small
    coordinates forever and its residual would grow without bound)."""
    rng = np.random.RandomState(1)
    v = jnp.asarray(rng.randn(128), jnp.float32)
    state = double_squeeze_init(128)
    touched = np.zeros(128, bool)
    rounds = 48
    for _ in range(rounds):
        dense, (vals, idx), state = double_squeeze_compress(v, state, k=8)
        touched[np.asarray(idx)] = True
        # residual per coordinate is bounded by its own accumulation rate
        assert float(jnp.abs(state.error).max()) <= rounds * float(
            jnp.abs(v).max()) + 1e-4
    # every non-tiny coordinate is selected once its error accumulates;
    # a tiny |v_i| needs ~max|v|/|v_i| rounds, so only assert the big ones
    big = np.abs(np.asarray(v)) >= 0.5
    assert touched[big].all(), f"{(~touched[big]).sum()} big coords unsent"


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_and_rotation(tmp_path):
    d = str(tmp_path)
    mgr = CheckpointManager(d, keep=2)
    tree = {"a": jnp.arange(5.0), "b": {"c": jnp.ones((2, 3))}}
    for step in range(5):
        t = jax.tree_util.tree_map(lambda x: x + step, tree)
        mgr.save(step, t, extra={"loss": float(step)})
    assert latest_step(d) == 4
    restored, step, extra = mgr.restore(tree)
    assert step == 4 and extra["loss"] == 4.0
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.arange(5.0) + 4)
    # rotation kept only 2
    kept = [f for f in os.listdir(d) if f.startswith("step_")]
    assert len(kept) == 2


def test_checkpoint_restore_empty_dir(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "nope"))
    tree, step, extra = mgr.restore({"a": jnp.zeros(1)})
    assert tree is None and step is None


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_stream_deterministic():
    prior = dirichlet_partition(1, 50, seed=1)[0]
    s1 = SyntheticLM(vocab=50, seq_len=8, batch_size=2, client_prior=prior,
                     seed=7)
    s2 = SyntheticLM(vocab=50, seq_len=8, batch_size=2, client_prior=prior,
                     seed=7)
    np.testing.assert_array_equal(s1.next_batch()["tokens"],
                                  s2.next_batch()["tokens"])


def test_dirichlet_partition_heterogeneous():
    priors = dirichlet_partition(4, 100, alpha=0.1, seed=2)
    assert len(priors) == 4
    for p in priors:
        assert p.shape == (100,) and abs(p.sum() - 1) < 1e-9
    # low alpha -> clients concentrate on different tokens
    tops = [int(np.argmax(p)) for p in priors]
    assert len(set(tops)) > 1


def test_labels_are_shifted_tokens():
    prior = dirichlet_partition(1, 50)[0]
    b = SyntheticLM(vocab=50, seq_len=8, batch_size=2,
                    client_prior=prior).next_batch()
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------


def _ax(data=16, model=16):
    return shd.AxisEnv(data=("data",), model="model", data_size=data,
                       model_size=model)


def test_param_spec_rules():
    sds = jax.ShapeDtypeStruct
    tree = {
        "embed": sds((1600, 64), jnp.float32),
        "unembed": sds((64, 1600), jnp.float32),
        "layers": {"wq": sds((4, 64, 128), jnp.float32),
                   "ln1": sds((4, 64), jnp.float32),
                   "expert_gate": sds((4, 8, 64, 128), jnp.float32),
                   "router": sds((4, 64, 8), jnp.float32)},
    }
    specs = shd.param_specs(tree, _ax())
    assert specs["embed"] == P("model", None)
    assert specs["unembed"] == P("data", "model")
    assert specs["layers"]["wq"] == P(None, "data", "model")
    # stacked norms [L, d] shard their d over 'model' (harmless + free)
    assert specs["layers"]["ln1"] == P(None, "model")
    assert specs["layers"]["expert_gate"] == P(None, None, None, "model")
    assert specs["layers"]["router"] == P(None, None, None)


def test_param_spec_divisibility_fallback():
    sds = jax.ShapeDtypeStruct
    specs = shd.param_specs({"w": sds((30, 50), jnp.float32)}, _ax())
    assert specs["w"] == P(None, None)      # 30, 50 not divisible by 16


def test_kv_cache_spec_batch1_uses_seq_sharding():
    ax = _ax()
    s = shd.kv_cache_spec(ax, batch_size=1)
    assert s == P(None, ("data", "model"), None, None)
    s = shd.kv_cache_spec(ax, batch_size=128)
    assert s == P(("data",), "model", None, None)


def test_cpu_env_everything_replicated():
    sds = jax.ShapeDtypeStruct
    specs = shd.param_specs({"w": sds((64, 64), jnp.float32)}, shd.CPU_ENV)
    assert specs["w"] == P(None, None)
