"""Pallas kernels vs pure-jnp ref: EXACT integer equality across shape
sweeps, plus the u32 construction vs a uint64 gold model."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.ckks import params as ckks_params
from repro.kernels import he_agg, ntt, ops, pointwise, ref

import gold


def ctxs():
    return [ckks_params.make_test_context(n_poly=n, n_limbs=2)
            for n in (64, 256)]


@pytest.mark.parametrize("n_poly", [64, 256, 1024])
def test_mont_mul_matches_gold(n_poly):
    ctx = ckks_params.make_test_context(n_poly=n_poly, n_limbs=2)
    lc = ctx.limbs[0]
    rng = np.random.RandomState(0)
    a = rng.randint(0, lc.q, size=(3, n_poly)).astype(np.uint32)
    b = rng.randint(0, lc.q, size=(3, n_poly)).astype(np.uint32)
    ours = np.asarray(ref.mont_mul(jnp.asarray(a), jnp.asarray(b),
                                   np.uint32(lc.q), np.uint32(lc.qinv_neg)))
    gold_out = gold.gold_mont_mul(a, b, lc.q)
    np.testing.assert_array_equal(ours, gold_out)


def test_mod_ops_match_gold():
    ctx = ckks_params.make_test_context(n_poly=64, n_limbs=2)
    q = ctx.primes[0]
    rng = np.random.RandomState(1)
    a = rng.randint(0, q, size=(100,)).astype(np.uint32)
    b = rng.randint(0, q, size=(100,)).astype(np.uint32)
    np.testing.assert_array_equal(
        np.asarray(ref.mod_add(a, b, np.uint32(q))),
        ((a.astype(np.uint64) + b) % q).astype(np.uint32))
    np.testing.assert_array_equal(
        np.asarray(ref.mod_sub(a, b, np.uint32(q))),
        ((a.astype(np.int64) - b) % q).astype(np.uint32))
    np.testing.assert_array_equal(
        np.asarray(ref.mod_neg(a, np.uint32(q))),
        ((-a.astype(np.int64)) % q).astype(np.uint32))


def test_wide_arithmetic():
    rng = np.random.RandomState(2)
    a = rng.randint(0, 1 << 32, size=(64,), dtype=np.uint64).astype(np.uint32)
    b = rng.randint(0, 1 << 32, size=(64,), dtype=np.uint64).astype(np.uint32)
    hi, lo = ref.mul_wide(jnp.asarray(a), jnp.asarray(b))
    wide = a.astype(np.uint64) * b.astype(np.uint64)
    np.testing.assert_array_equal(np.asarray(hi), (wide >> 32).astype(np.uint32))
    np.testing.assert_array_equal(np.asarray(lo),
                                  (wide & 0xFFFFFFFF).astype(np.uint32))


@pytest.mark.parametrize("n_poly", [64, 128])
def test_ntt_matches_quadratic_gold(n_poly):
    ctx = ckks_params.make_test_context(n_poly=n_poly, n_limbs=2)
    lc = ctx.limbs[0]
    psi = ckks_params.root_of_unity(lc.q, 2 * n_poly)
    rng = np.random.RandomState(3)
    x = rng.randint(0, lc.q, size=(2, n_poly)).astype(np.uint32)
    ours = np.asarray(ref.ntt_fwd(jnp.asarray(x),
                                  jnp.asarray(lc.psi_rev_mont),
                                  np.uint32(lc.q), np.uint32(lc.qinv_neg)))
    g = np.stack([gold.gold_ntt(x[i], lc.q, psi) for i in range(2)])
    np.testing.assert_array_equal(ours, g)


@pytest.mark.parametrize("n_poly", [64, 256, 2048])
@pytest.mark.parametrize("batch", [1, 3, 8, 13])
def test_ntt_roundtrip_exact(n_poly, batch):
    ctx = ckks_params.make_test_context(n_poly=n_poly, n_limbs=2)
    for lc in ctx.limbs:
        rng = np.random.RandomState(4)
        x = rng.randint(0, lc.q, size=(batch, n_poly)).astype(np.uint32)
        fwd = ref.ntt_fwd(jnp.asarray(x), jnp.asarray(lc.psi_rev_mont),
                          np.uint32(lc.q), np.uint32(lc.qinv_neg))
        inv = ref.ntt_inv(fwd, jnp.asarray(lc.psi_inv_rev_mont),
                          np.asarray(lc.n_inv_mont),
                          np.uint32(lc.q), np.uint32(lc.qinv_neg))
        np.testing.assert_array_equal(np.asarray(inv), x)


# ---------------------------------------------------------------------------
# Pallas limb-grid kernels (interpret mode) vs fused ref: exact equality
# ---------------------------------------------------------------------------


def _rand_limbed(rng, ctx, shape):
    return jnp.asarray(ref.rand_limbed_np(rng, ctx, shape))


@pytest.mark.parametrize("n_poly", [64, 256, 1024])
@pytest.mark.parametrize("batch", [1, 5, 8, 11])
def test_pallas_ntt_exact(n_poly, batch):
    ctx = ckks_params.make_test_context(n_poly=n_poly, n_limbs=2)
    t = ctx.tables
    rng = np.random.RandomState(5)
    x = _rand_limbed(rng, ctx, (batch,))
    a = ntt.ntt_fwd_fused(x, t.psi_rev_mont, t.qs, t.qinv_negs,
                          interpret=True)
    b = ref.ntt_fwd_fused(x, t.psi_rev_mont, t.qs, t.qinv_negs)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    ai = ntt.ntt_inv_fused(a, t.psi_inv_rev_mont, t.n_inv_monts, t.qs,
                           t.qinv_negs, interpret=True)
    bi = ref.ntt_inv_fused(b, t.psi_inv_rev_mont, t.n_inv_monts, t.qs,
                           t.qinv_negs)
    np.testing.assert_array_equal(np.asarray(ai), np.asarray(bi))
    np.testing.assert_array_equal(np.asarray(ai), np.asarray(x))


@pytest.mark.parametrize("batch,n", [(1, 64), (7, 256), (16, 512)])
def test_pallas_mul_add_exact(batch, n):
    ctx = ckks_params.make_test_context(n_poly=max(n, 64), n_limbs=2)
    t = ctx.tables
    rng = np.random.RandomState(6)
    x, y, z = (_rand_limbed(rng, ctx, (batch,)) for _ in range(3))
    a = pointwise.mul_add_fused(x, y, z, t.qs, t.qinv_negs, interpret=True)
    b = ref.mul_add_fused(x, y, z, t.qs, t.qinv_negs)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("clients", [1, 2, 5, 16])
def test_pallas_he_agg_exact(clients):
    ctx = ckks_params.make_test_context(n_poly=256, n_limbs=2)
    t = ctx.tables
    rng = np.random.RandomState(7)
    cts = _rand_limbed(rng, ctx, (clients, 6))
    w = jnp.asarray(np.stack([rng.randint(0, int(q), size=(clients,))
                              for q in ctx.primes], axis=1).astype(np.uint32))
    a = he_agg.he_weighted_sum_fused(cts, w, t.qs, t.qinv_negs,
                                     interpret=True)
    b = ref.he_weighted_sum_fused(cts, w, t.qs, t.qinv_negs)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("batch", [1, 6])
def test_pallas_he_accum_exact(batch):
    ctx = ckks_params.make_test_context(n_poly=128, n_limbs=2)
    t = ctx.tables
    rng = np.random.RandomState(9)
    acc = _rand_limbed(rng, ctx, (batch,))
    ct = _rand_limbed(rng, ctx, (batch,))
    w = jnp.asarray(np.asarray([rng.randint(0, int(q)) for q in ctx.primes],
                               dtype=np.uint32))
    a = he_agg.he_weighted_accum_fused(acc, ct, w, t.qs, t.qinv_negs,
                                       interpret=True)
    b = ref.he_weighted_accum_fused(acc, ct, w, t.qs, t.qinv_negs)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ops_backend_dispatch_consistent():
    """ops.* with pallas backend == ops.* with ref backend, exactly."""
    ctx = ckks_params.make_test_context(n_poly=128, n_limbs=2)
    rng = np.random.RandomState(8)
    x = jnp.asarray(np.stack([rng.randint(0, q, size=(4, 128))
                              for q in ctx.primes], axis=1).astype(np.uint32))
    old = ops.get_backend()
    try:
        ops.set_backend("ref")
        a = ops.ntt_fwd(x, ctx)
        ops.set_backend("pallas")
        b = ops.ntt_fwd(x, ctx)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    finally:
        ops.set_backend(old)
