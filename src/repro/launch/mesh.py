"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state; callers (dryrun.py) set XLA_FLAGS *before* the first jax import.
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} exist; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(dryrun.py sets this automatically)")
    return jax.make_mesh(
        shape, axes, devices=devices[:n],
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Trivial 1x1 mesh for CPU smoke runs."""
    import jax

    return jax.make_mesh(
        (1, 1), ("data", "model"),
        devices=jax.devices()[:1],
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
