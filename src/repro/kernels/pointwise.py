"""Pallas TPU kernel: fused modular pointwise ops, limb-fused over all limbs.

`mul_add`:  out = x (*) y_mont + z  — the encrypt/decrypt workhorse:
    encrypt: c0 = pk0 (*) u + (e0 + m),  c1 = pk1 (*) u + e1
    decrypt: m~ = c1 (*) s + c0
Fusing the Montgomery multiply with the modular add keeps each operand to a
single HBM read (arithmetic intensity of HE pointwise ops is ~0.5 int-op/B,
firmly memory-bound — see EXPERIMENTS.md §Roofline-HE).

The grid is (L, ceil(B / block_b)): the RNS limb is a grid coordinate and the
per-limb Montgomery constants (q, -q^{-1}) are u32[L] VMEM tables indexed by
it, so one `pallas_call` covers the whole u32[B, L, N] tensor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref as _ref
from repro.kernels import tune as _tune


def _mul_add_body(x_ref, y_ref, z_ref, q_ref, qinv_ref, o_ref):
    q = q_ref[0]
    qinv_neg = qinv_ref[0]
    prod = _ref.mont_mul(x_ref[:, 0, :], y_ref[:, 0, :], q, qinv_neg)
    o_ref[:, 0, :] = _ref.mod_add(prod, z_ref[:, 0, :], q)


@functools.lru_cache(maxsize=128)
def _build(l: int, n: int, block_b: int, interpret: bool):
    tile = pl.BlockSpec((block_b, 1, n), lambda li, bi: (bi, li, 0))
    scalar = pl.BlockSpec((1,), lambda li, bi: (li,))

    def call(x, y, z, qs, qinv_negs):
        b = x.shape[0]
        return pl.pallas_call(
            _mul_add_body,
            grid=(l, pl.cdiv(b, block_b)),
            in_specs=[tile, tile, tile, scalar, scalar],
            out_specs=tile,
            out_shape=jax.ShapeDtypeStruct((b, l, n), jnp.uint32),
            interpret=interpret,
        )(x, y, z, qs, qinv_negs)

    return call


def mul_add_fused(x, y_mont, z, qs, qinv_negs, *, block_b: int | None = None,
                  interpret: bool = True):
    """out = x (*) y_mont + z mod q_l, all limbs in one pallas_call.

    x, y_mont, z: u32[..., L, N]; qs, qinv_negs: u32[L].  block_b=None
    takes the shared default from tune.DEFAULT_BLOCK."""
    if block_b is None:
        block_b = _tune.default_block("mul_add")
    l, n = x.shape[-2], x.shape[-1]
    batch = x.shape[:-2]
    x2 = x.reshape((-1, l, n))
    y2 = jnp.broadcast_to(y_mont, x.shape).reshape((-1, l, n))
    z2 = jnp.broadcast_to(z, x.shape).reshape((-1, l, n))
    b = x2.shape[0]
    call = _build(l, n, min(block_b, b), interpret)
    return call(x2, y2, z2, qs, qinv_negs).reshape(batch + (l, n))
