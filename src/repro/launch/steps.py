"""Jitted step builders + input/cache sharding trees (shared by dryrun,
train.py and serve.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import Model
from repro.models import sharding as shd
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import cosine_lr


# ---------------------------------------------------------------------------
# sharding trees
# ---------------------------------------------------------------------------


def batch_specs(batch_tree, ax: shd.AxisEnv):
    """Input batch leaves: leading dim over dp, rest replicated."""
    def spec(leaf):
        if not leaf.shape:
            return P()
        b = leaf.shape[0]
        dp = ax.dp if (ax.dp and b % ax.data_size == 0 and b > 1) else None
        return P(dp, *([None] * (len(leaf.shape) - 1)))
    return jax.tree_util.tree_map(spec, batch_tree)


def cache_specs(cfg, cache_tree, ax: shd.AxisEnv, batch: int):
    """Per-layer cache buffers: conv [B, w-1, ch], ssm [B, nh, hd, st],
    k/v [B, S, KH, hd]."""
    def visit(path, leaf):
        name = jax.tree_util.keystr(path)
        if "conv" in name:
            return shd.conv_state_spec(ax, batch, leaf.shape[-1])
        if "ssm" in name:
            return shd.ssm_state_spec(ax, batch, cfg.ssm_heads)
        if leaf.ndim == 4:   # k/v and attn_k/attn_v [B, S, KH, hd]
            return shd.kv_cache_spec(ax, batch)
        return P()
    return jax.tree_util.tree_map_with_path(visit, cache_tree)


def opt_specs(param_spec_tree):
    return {"m": param_spec_tree, "v": param_spec_tree, "step": P()}


def named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    warmup: int = 100, total_steps: int = 10_000):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
        lr = cosine_lr(opt_state["step"], opt_cfg.lr, warmup, total_steps)
        params, opt_state, gnorm = adamw_update(grads, opt_state, params,
                                                opt_cfg, lr=lr)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}
    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, batch):
        return model.decode_step(params, cache, batch)
    return decode_step


def jit_train_step(model: Model, mesh, opt_cfg: AdamWConfig, batch_tree):
    """pjit'd production train step: donated params/opt, explicit shardings."""
    ax = model.ax
    pspecs = model.param_specs()
    ospecs = opt_specs(pspecs)
    bspecs = batch_specs(batch_tree, ax)
    step = make_train_step(model, opt_cfg)
    return jax.jit(
        step,
        in_shardings=(named(mesh, pspecs), named(mesh, ospecs),
                      named(mesh, bspecs)),
        out_shardings=(named(mesh, pspecs), named(mesh, ospecs), None),
        donate_argnums=(0, 1),
    )


def jit_prefill_step(model: Model, mesh, batch_tree):
    ax = model.ax
    pspecs = model.param_specs()
    bspecs = batch_specs(batch_tree, ax)
    return jax.jit(
        make_prefill_step(model),
        in_shardings=(named(mesh, pspecs), named(mesh, bspecs)),
    )


def jit_decode_step(model: Model, mesh, cache_tree, batch_tree, batch: int,
                    param_mode: str = "train"):
    ax = model.ax
    pspecs = model.param_specs(mode=param_mode)
    cspecs = cache_specs(model.cfg, cache_tree, ax, batch)
    bspecs = batch_specs(batch_tree, ax)
    return jax.jit(
        make_decode_step(model),
        in_shardings=(named(mesh, pspecs), named(mesh, cspecs),
                      named(mesh, bspecs)),
        out_shardings=(None, named(mesh, cspecs)),
        donate_argnums=(1,),
    )
