"""Nestable trace spans emitting Chrome-trace-event JSONL.

`span("round", round=3)` is a context manager that records one Chrome
trace "complete" event (`ph: "X"`) with microsecond `ts`/`dur` on exit.
Spans nest by wall-time containment on the emitting thread — exactly the
model Perfetto / chrome://tracing render — so the per-round span tree in
`fl/orchestrator.py` (round > client > local_train/encrypt, round >
aggregate > wire.ingest > wire.flush, ...; taxonomy table in DESIGN.md
§11) needs no explicit parent ids.

File format: one JSON event per line.  The first line is ``[`` and every
event line ends with ``,`` — the Chrome trace-event array format with the
optional closing bracket omitted, which both Perfetto and chrome://tracing
load directly, while staying trivially parseable line-by-line
(tools/round_report.py).  Events are appended as they close, so a crash
mid-run loses at most the open spans.

Gating: `enabled()` is False unless REPRO_OBS=1 (or `configure()` flips
it), and a disabled `span()` returns a shared no-op — the round loop pays
one truthiness check per span and nothing else (overhead policy, DESIGN.md
§11.3).  The default sink is $REPRO_OBS_TRACE (default ./obs_trace.jsonl),
opened lazily on the first event.
"""
from __future__ import annotations

import atexit
import json
import os
import threading
import time

#: schema version stamped into trace metadata and BENCH provenance
OBS_VERSION = 1

_ENV_ENABLED = os.environ.get("REPRO_OBS", "") not in ("", "0")
_ENV_TRACE_PATH = os.environ.get("REPRO_OBS_TRACE", "obs_trace.jsonl")

_enabled = _ENV_ENABLED
_tracer: "Tracer | None" = None
_lock = threading.Lock()


def enabled() -> bool:
    """True when span/trace recording is on (REPRO_OBS=1 or configure())."""
    return _enabled


def configure(enabled: bool | None = None, trace_path: str | None = "KEEP",
              reset: bool = False) -> None:
    """Programmatic override of the env-var gate (tests, notebooks).

    Args:
        enabled: flip span/kernel-hook recording on or off (None = keep).
        trace_path: file sink for a fresh tracer; None = in-memory only,
            "KEEP" (default) = leave the current sink setting alone.
        reset: drop the current tracer (and its buffered events) so the
            next event starts a fresh trace.
    """
    global _enabled, _tracer
    with _lock:
        if reset and _tracer is not None:
            _tracer.close()
            _tracer = None
        if enabled is not None:
            _enabled = bool(enabled)
        if trace_path != "KEEP":
            if _tracer is not None:
                _tracer.close()
            _tracer = Tracer(path=trace_path)


def get_tracer() -> "Tracer":
    """The process tracer (created on first use; sink from REPRO_OBS_TRACE
    when REPRO_OBS=1, else in-memory)."""
    global _tracer
    with _lock:
        if _tracer is None:
            _tracer = Tracer(path=_ENV_TRACE_PATH if _ENV_ENABLED else None)
        return _tracer


class Tracer:
    """Event buffer + optional JSONL file sink, one per process."""

    def __init__(self, path: str | None = None):
        self.path = path
        self.events: list[dict] = []
        self._fh = None
        self._flock = threading.Lock()
        self._t0_ns = time.perf_counter_ns()
        self._local = threading.local()

    # -- time / stack --------------------------------------------------------

    def now_us(self) -> float:
        """Microseconds since tracer start (perf_counter clock — durations,
        never wall-clock timestamps)."""
        return (time.perf_counter_ns() - self._t0_ns) / 1e3

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def depth(self) -> int:
        """Current span nesting depth on this thread."""
        return len(self._stack())

    def current_span(self) -> "Span | None":
        st = self._stack()
        return st[-1] if st else None

    # -- emission ------------------------------------------------------------

    def emit(self, ev: dict) -> None:
        with self._flock:
            self.events.append(ev)
            if self.path:
                if self._fh is None:
                    self._fh = open(self.path, "w")
                    self._fh.write("[\n")
                    self._fh.write(json.dumps(self._meta_event(),
                                              separators=(",", ":")) + ",\n")
                self._fh.write(json.dumps(ev, separators=(",", ":")) + ",\n")

    def _meta_event(self) -> dict:
        return {"name": "process_name", "ph": "M", "pid": os.getpid(),
                "tid": threading.get_native_id(),
                "args": {"name": "repro", "obs_version": OBS_VERSION,
                         "wall_time": time.time()}}

    def emit_complete(self, name: str, ts_us: float, dur_us: float,
                      cat: str = "phase", args: dict | None = None) -> None:
        """One Chrome 'X' complete event (ts/dur in microseconds)."""
        self.emit({"name": name, "cat": cat, "ph": "X",
                   "ts": round(ts_us, 3), "dur": round(dur_us, 3),
                   "pid": os.getpid(), "tid": threading.get_native_id(),
                   "args": args or {}})

    def emit_instant(self, name: str, cat: str = "event",
                     args: dict | None = None) -> None:
        """One Chrome 'i' instant event at the current time."""
        self.emit({"name": name, "cat": cat, "ph": "i",
                   "ts": round(self.now_us(), 3), "s": "t",
                   "pid": os.getpid(), "tid": threading.get_native_id(),
                   "args": args or {}})

    def flush(self) -> None:
        with self._flock:
            if self._fh is not None:
                self._fh.flush()

    def close(self) -> None:
        with self._flock:
            if self._fh is not None:
                self._fh.flush()
                self._fh.close()
                self._fh = None


class Span:
    """One nestable trace span; records a complete event on __exit__."""

    __slots__ = ("tracer", "name", "cat", "args", "_ts0")

    def __init__(self, tracer: Tracer, name: str, cat: str, args: dict):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self._ts0 = 0.0

    def set(self, **kw) -> None:
        """Attach/overwrite args after the span opened (e.g. byte counts
        known only at the end of the phase)."""
        self.args.update(kw)

    def __enter__(self) -> "Span":
        self.tracer._stack().append(self)
        self._ts0 = self.tracer.now_us()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        dur = self.tracer.now_us() - self._ts0
        st = self.tracer._stack()
        if st and st[-1] is self:
            st.pop()
        if exc_type is not None:
            self.args["error"] = exc_type.__name__
        self.tracer.emit_complete(self.name, self._ts0, dur, cat=self.cat,
                                  args=self.args)


class _NullSpan:
    """Shared no-op span: the entire disabled-path cost of obs.span()."""

    __slots__ = ()

    def set(self, **kw) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        pass


NULL_SPAN = _NullSpan()


def span(name: str, cat: str = "phase", **args):
    """Open a nestable trace span (no-op unless obs is enabled).

    Usage::

        with obs.span("round", round=rnd) as sp:
            ...
            sp.set(bytes_up=ledger.total(UPLINK, rnd))
    """
    if not _enabled:
        return NULL_SPAN
    return Span(get_tracer(), name, cat, dict(args))


def event(name: str, cat: str = "event", **args) -> None:
    """Record an instant event (no-op unless obs is enabled)."""
    if _enabled:
        get_tracer().emit_instant(name, cat=cat, args=dict(args))


def flush() -> None:
    """Flush the trace sink (atexit does this too; call before reading the
    file in-process)."""
    if _tracer is not None:
        _tracer.flush()


def trace_path() -> str | None:
    """The active trace file path, or None (disabled / in-memory)."""
    if not _enabled:
        return None
    return get_tracer().path


@atexit.register
def _atexit_flush() -> None:  # pragma: no cover - exit path
    if _tracer is not None:
        _tracer.close()
