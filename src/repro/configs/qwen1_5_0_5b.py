"""qwen1.5-0.5b [dense] — QKV bias, tied embeddings.
Source: hf:Qwen/Qwen1.5-0.5B (hf tier).
24L d_model=1024 16H (kv=16) d_ff=2816 vocab=151936."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=2816,
    vocab=151936, qkv_bias=True, tie_embeddings=True,
    dtype="bfloat16", param_dtype="float32", remat=True,
)

SMOKE = ModelConfig(
    name="qwen-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=257, qkv_bias=True, tie_embeddings=True, attn_chunk=16,
)
