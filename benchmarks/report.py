"""Render EXPERIMENTS.md tables from dry-run artifacts.

  PYTHONPATH=src python -m benchmarks.report          # rewrite EXPERIMENTS.md
"""
from __future__ import annotations

import json
import os

ART = os.path.join(os.path.dirname(__file__), "artifacts")
EXP = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
               "long_500k": 3, "he_agg": 4}


def load():
    arts = []
    for fn in sorted(os.listdir(ART)):
        if fn.endswith(".json"):
            arts.append(json.load(open(os.path.join(ART, fn))))
    arts.sort(key=lambda a: (a["arch"], SHAPE_ORDER.get(a["shape"], 9),
                             a["mesh"], a.get("tag", "")))
    return arts


def fmt_b(x):
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if abs(x) >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def dryrun_table(arts):
    rows = ["| arch | shape | mesh | compile_s | HLO flops/dev | "
            "bytes/dev (op-level) | wire/dev | args+out/dev | collectives |",
            "|---|---|---|---|---|---|---|---|---|"]
    for a in arts:
        if a.get("tag"):
            continue
        r = a["roofline"]
        m = a["memory"]
        cc = a["collectives"]["counts"]
        csum = " ".join(f"{k.split('-')[-1][:4]}:{v}" for k, v in
                        sorted(cc.items())) or "none"
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} | "
            f"{a['compile_s']} | {r['flops']:.2e} | "
            f"{fmt_b(r['bytes_accessed'])} | {fmt_b(r['wire_bytes'])} | "
            f"{fmt_b(m['argument_bytes'] + m['output_bytes'])} | {csum} |")
    return "\n".join(rows)


def roofline_table(arts):
    rows = ["| arch | shape | comp ms | mem ms (fused) | coll ms | "
            "mem_upper ms | dominant | flops_ratio | roofline_frac | "
            "what moves the dominant term |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for a in arts:
        if a["mesh"] != "single" or a.get("tag"):
            continue
        r = a["roofline"]
        hint = _hint(a)
        rows.append(
            f"| {a['arch']} | {a['shape']} | {r['compute_s']*1e3:.1f} | "
            f"{r.get('memory_s', 0)*1e3:.1f} | {r['collective_s']*1e3:.1f} | "
            f"{r.get('memory_upper_s', r['memory_s'])*1e3:.1f} | "
            f"{r['dominant']} | {r['flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {hint} |")
    return "\n".join(rows)


def _hint(a):
    r = a["roofline"]
    dom = r["dominant"]
    kind = a["kind"]
    if kind == "he_agg":
        return "fuse weight-mul+accumulate (Pallas he_agg kernel)"
    if dom == "collective":
        if kind == "decode":
            return "weight-stationary serve_tp sharding (no FSDP gathers)"
        return "overlap AG/RS with compute; bigger per-device batch"
    if dom == "memory":
        if kind in ("train", "prefill"):
            return "fused (flash) attention kernel; bf16 score buffers"
        return "cache layout/quantization; fuse dus+attention"
    return "near compute roofline: raise flops_ratio (less remat)"


def perf_cells(arts):
    tagged = [a for a in arts if a.get("tag")]
    if not tagged:
        return "(hillclimb artifacts pending)"
    rows = ["| cell | tag | comp ms | mem ms | coll ms | dominant |",
            "|---|---|---|---|---|---|"]
    for a in sorted(tagged, key=lambda x: (x["arch"], x["shape"], x["tag"])):
        r = a["roofline"]
        rows.append(f"| {a['arch']} {a['shape']} {a['mesh']} | {a['tag']} | "
                    f"{r['compute_s']*1e3:.1f} | {r.get('memory_s',0)*1e3:.1f} | "
                    f"{r['collective_s']*1e3:.1f} | {r['dominant']} |")
    return "\n".join(rows)


def main():
    arts = load()
    with open(EXP) as f:
        text = f.read()
    text = _replace(text, "DRYRUN_TABLE", dryrun_table(arts))
    text = _replace(text, "ROOFLINE_TABLE", roofline_table(arts))
    text = _replace(text, "PERF_CELLS", perf_cells(arts))
    with open(EXP, "w") as f:
        f.write(text)
    singles = sum(1 for a in arts if a["mesh"] == "single" and not a.get("tag"))
    multis = sum(1 for a in arts if a["mesh"] == "multi" and not a.get("tag"))
    print(f"EXPERIMENTS.md updated: {singles} single-pod cells, "
          f"{multis} multi-pod cells, {len(arts)} artifacts total")


def _replace(text, marker, table):
    tag = f"<!-- {marker} -->"
    start = text.index(tag)
    # replace from marker to the next blank-line-followed-by-## or end marker
    end = text.find("\n## ", start)
    if end == -1:
        end = len(text)
    return text[:start] + tag + "\n\n" + table + "\n" + text[end:]


if __name__ == "__main__":
    main()
