"""One benchmark per paper table/figure, run on this host's CPU backend.

The paper benchmarks wall-clock of CPU HE libraries (PALISADE/TenSEAL); we
benchmark our own TPU-native u32 CKKS running on the CPU backend, so the
*ratios* (HE vs plaintext, selective vs full) are comparable even though
absolute times differ.  Communication numbers use the serialized-size model
(exact byte accounting, hardware independent).

Tables covered:
  table4   Vanilla fully-encrypted aggregation vs plaintext across model
           sizes (comp ratio + comm ratio)           [paper Table 4]
  table6   Crypto-parameter sweep: packing batch size x scaling bits
                                                     [paper Table 6]
  table7   Selective-encryption ratio sweep on a ViT-sized model
                                                     [paper Table 7]
  fig7     Overhead vs selection ratio across model sizes  [Figure 7]
  fig14a   Server aggregation cost vs number of clients    [Figure 14a]
  fig8     Training-cycle time distribution with/without optimization
           at AWS-region bandwidth                   [Figure 8]
  dp_adv   Privacy-budget advantage (1-p) vs (1-p)^2 law   [Remarks 3.12-14]
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import dp, selection
from repro.core.ckks import cipher, encoding
from repro.core.ckks import params as ckks_params

# paper Table 4 model inventory (name, n_params)
PAPER_MODELS = [
    ("Linear", 101),
    ("TimeSeriesTransformer", 5_609),
    ("MLP-2FC", 79_510),
    ("LeNet", 88_648),
    ("RNN-2LSTM", 822_570),
    ("CNN-2conv2fc", 1_663_370),
    ("MobileNet", 3_315_428),
    ("ResNet-18", 12_556_426),
    ("ResNet-50", 25_557_032),
    ("ViT", 86_389_248),
    ("BERT", 109_482_240),
]

BW_CASES = {"IB": 5e9, "SAR": 592e6, "MAR": 15.6e6}    # paper §D.5


def _time(f, *args, reps=3):
    f(*args)                       # compile/warm
    jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps


def _bench_agg(ctx, n_values: int, n_clients: int = 3):
    """Wall-clock one encrypted aggregation of n_values params (CPU) and
    the plaintext equivalent.  Returns dict of times + sizes."""
    sk, pk = cipher.keygen(ctx, jax.random.PRNGKey(0))
    n_ct = ctx.num_ciphertexts(n_values)
    rng = np.random.RandomState(0)
    vals = rng.randn(n_ct, ctx.slots).astype(np.float32)
    coeffs = jnp.asarray(encoding.encode_np(vals, ctx))

    enc = jax.jit(lambda c, k: cipher.encrypt_coeffs(ctx, pk, c, k).data)
    t_enc = _time(enc, coeffs, jax.random.PRNGKey(1))

    ct = cipher.encrypt_coeffs(ctx, pk, coeffs, jax.random.PRNGKey(1))
    cts = cipher.Ciphertext(
        data=jnp.broadcast_to(ct.data, (n_clients,) + ct.data.shape),
        scale=ct.scale)
    w = [1.0 / n_clients] * n_clients
    agg = jax.jit(lambda d: cipher.weighted_sum(
        ctx, cipher.Ciphertext(data=d, scale=ct.scale), w).data)
    t_agg = _time(agg, cts.data)

    dec = jax.jit(lambda d: cipher.decrypt_values(
        ctx, sk, cipher.Ciphertext(data=d, scale=ct.scale * ctx.delta)))
    t_dec = _time(dec, agg(cts.data))

    plain = jnp.asarray(rng.randn(n_clients, n_values).astype(np.float32))
    pl = jax.jit(lambda x: jnp.einsum(
        "c,cp->p", jnp.asarray(w, jnp.float32), x))
    t_plain = _time(pl, plain)

    return {
        "t_he": t_enc + t_agg + t_dec,
        "t_enc": t_enc, "t_agg": t_agg, "t_dec": t_dec,
        "t_plain": t_plain,
        "ct_bytes": ctx.encrypted_bytes(n_values),
        "pt_bytes": ctx.plaintext_bytes(n_values),
    }


def table4(ctx=None, max_params=2_000_000):
    """HE vs plaintext aggregation across model sizes (sub-sampled: models
    above max_params use the measured per-ciphertext rate — exact, since
    cost is linear in ciphertext count; Figure 2 observation)."""
    ctx = ctx or ckks_params.make_context(n_poly=8192, n_limbs=2,
                                          delta_bits=26)
    # measure the per-ciphertext rate once at a calibration size
    calib_n = 512 * ctx.slots
    base = _bench_agg(ctx, calib_n)
    per_ct_he = base["t_he"] / ctx.num_ciphertexts(calib_n)
    per_val_plain = base["t_plain"] / calib_n
    rows = []
    for name, n in PAPER_MODELS:
        if n <= max_params:
            r = _bench_agg(ctx, n)
            t_he, t_plain = r["t_he"], r["t_plain"]
            measured = True
        else:
            t_he = per_ct_he * ctx.num_ciphertexts(n)
            t_plain = per_val_plain * n
            measured = False
        rows.append({
            "model": name, "params": n,
            "t_he_s": t_he, "t_plain_s": t_plain,
            "comp_ratio": t_he / max(t_plain, 1e-9),
            "ct_bytes": ctx.encrypted_bytes(n),
            "pt_bytes": ctx.plaintext_bytes(n),
            "comm_ratio": ctx.encrypted_bytes(n)
                          / max(1, ctx.plaintext_bytes(n)),
            "measured": measured,
        })
    return rows


def table6():
    """Packing batch size x scaling bits: comp/comm/accuracy proxy."""
    rows = []
    n_values = 200_000
    rng = np.random.RandomState(0)
    for n_poly in (2048, 4096, 8192):
        for delta_bits in (14, 20, 26):
            ctx = ckks_params.make_context(n_poly=n_poly, n_limbs=2,
                                           delta_bits=delta_bits)
            sk, pk = cipher.keygen(ctx, jax.random.PRNGKey(0))
            v = rng.randn(1, ctx.slots).astype(np.float32)
            ct = cipher.encrypt_coeffs(
                ctx, pk, jnp.asarray(encoding.encode_np(v, ctx)),
                jax.random.PRNGKey(1))
            w = cipher.mul_plain_scalar(ctx, ct, 0.5)
            err = float(np.abs(cipher.decrypt_values_np(ctx, sk, w)
                               - 0.5 * v).max())
            r = _bench_agg(ctx, 64 * ctx.slots)
            scale_t = ctx.num_ciphertexts(n_values) / 64
            rows.append({
                "batch_size": ctx.slots, "scaling_bits": delta_bits,
                "comp_s": r["t_he"] * scale_t,
                "comm_bytes": ctx.encrypted_bytes(n_values),
                "decrypt_abs_err": err,
            })
    return rows


def table7(n_params=86_389_248):
    """Selection-ratio sweep (ViT-sized): overhead vs Enc w/ 0%."""
    ctx = ckks_params.make_context(n_poly=8192, n_limbs=2, delta_bits=26)
    base = _bench_agg(ctx, 64 * ctx.slots)
    per_ct = base["t_he"] / 64
    per_val_plain = base["t_plain"] / (64 * ctx.slots)
    rows = []
    t0 = per_val_plain * n_params
    b0 = ctx.plaintext_bytes(n_params)
    for ratio in (0.0, 0.1, 0.3, 0.5, 0.7, 1.0):
        n_enc = int(n_params * ratio)
        t = per_ct * ctx.num_ciphertexts(n_enc) \
            + per_val_plain * (n_params - n_enc)
        comm = ctx.encrypted_bytes(n_enc) \
            + ctx.plaintext_bytes(n_params - n_enc)
        rows.append({"ratio": ratio, "comp_s": t, "comm_bytes": comm,
                     "comp_ratio": t / t0, "comm_ratio": comm / b0})
    return rows


def fig7(ratios=(0.1, 0.5, 1.0)):
    """Overhead vs selection ratio across paper model sizes (size model)."""
    ctx = ckks_params.make_context(n_poly=8192, n_limbs=2, delta_bits=26)
    rows = []
    for name, n in PAPER_MODELS[3::2]:
        for p in ratios:
            n_enc = int(n * p)
            rows.append({
                "model": name, "ratio": p,
                "comm_bytes": ctx.encrypted_bytes(n_enc)
                              + ctx.plaintext_bytes(n - n_enc)})
    return rows


def fig14a(client_counts=(2, 4, 8, 16, 32)):
    """Server aggregation cost vs number of clients."""
    ctx = ckks_params.make_context(n_poly=4096, n_limbs=2, delta_bits=26)
    sk, pk = cipher.keygen(ctx, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    v = rng.randn(32, ctx.slots).astype(np.float32)
    ct = cipher.encrypt_coeffs(ctx, pk,
                               jnp.asarray(encoding.encode_np(v, ctx)),
                               jax.random.PRNGKey(1))
    rows = []
    for c in client_counts:
        data = jnp.broadcast_to(ct.data, (c,) + ct.data.shape)
        w = [1.0 / c] * c
        agg = jax.jit(lambda d: cipher.weighted_sum(
            ctx, cipher.Ciphertext(data=d, scale=ct.scale), w).data)
        rows.append({"clients": c, "t_agg_s": _time(agg, data)})
    return rows


def fig8(model_params=25_557_032, ratio=0.3, train_s=30.0):
    """ResNet-50-scale training-cycle decomposition at SAR bandwidth:
    plaintext vs HE-unoptimized vs HE w/ selective encryption."""
    ctx = ckks_params.make_context(n_poly=8192, n_limbs=2, delta_bits=26)
    base = _bench_agg(ctx, 64 * ctx.slots)
    per_ct = base["t_he"] / 64
    bw = BW_CASES["SAR"]
    rows = []
    for mode, p in (("plaintext", 0.0), ("he_full", 1.0),
                    ("he_selective", ratio)):
        n_enc = int(model_params * p)
        he_t = per_ct * ctx.num_ciphertexts(n_enc)
        comm_b = ctx.encrypted_bytes(n_enc) \
            + ctx.plaintext_bytes(model_params - n_enc)
        rows.append({
            "mode": mode, "train_s": train_s,
            "he_s": he_t, "comm_s": 2 * comm_b / bw,
            "total_s": train_s + he_t + 2 * comm_b / bw,
        })
    return rows


def dp_advantage(p_grid=(0.1, 0.3, 0.5, 0.7, 0.9)):
    """Empirical (1-p) vs (1-p)^2 privacy-budget law on synthetic
    sensitivities (Remarks 3.12-3.14)."""
    s = np.random.RandomState(0).rand(500_000)
    j = dp.epsilon_all_plaintext(s, b=1.0)
    rows = []
    for p in p_grid:
        out = dp.selection_advantage(s, p, b=1.0)
        rows.append({
            "p": p,
            "eps_random/J": out["eps_random"] / j,
            "eps_selective/J": out["eps_selective"] / j,
            "law_random": 1 - p,
            "law_selective": (1 - p) ** 2,
        })
    return rows
