"""Unified model API over all architecture families.

``build_model(cfg)`` returns a ``Model`` namespace of pure functions:
  init(key) -> params                    (real weights)
  init_abstract() -> params              (ShapeDtypeStructs; no allocation)
  loss_fn(params, batch) -> scalar
  prefill(params, batch, cache_len) -> (logits, cache)     (causal families)
  decode_step(params, cache, batch) -> (logits, cache)
  abstract_cache(batch, cache_len) -> cache ShapeDtypeStructs
All functions take an AxisEnv (mesh-aware sharding hints) at build time.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from repro.models import mamba2, sharding, transformer, zamba2
from repro.models.config import ModelConfig
from repro.models.sharding import AxisEnv, CPU_ENV, axis_env_from_mesh, param_specs


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    ax: AxisEnv
    init: Callable
    loss_fn: Callable
    prefill: Callable | None
    decode_step: Callable | None
    abstract_cache: Callable | None

    def init_abstract(self):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    def param_specs(self, mode: str = "train"):
        return param_specs(self.init_abstract(), self.ax, mode=mode)


def build_model(cfg: ModelConfig, ax: AxisEnv = CPU_ENV) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "encoder"):
        mod = transformer
        init = lambda key: transformer.init(cfg, key)
        loss = lambda p, b: transformer.loss_fn(p, b, cfg, ax)
        if fam == "encoder":
            # encoder inference = one bidirectional forward, no cache
            enc_fwd = lambda p, b, cache_len=None: (
                transformer.forward_logits(p, b, cfg, ax)[0], None)
            return Model(cfg, ax, init, loss, prefill=enc_fwd,
                         decode_step=None, abstract_cache=None)
        return Model(
            cfg, ax, init, loss,
            prefill=lambda p, b, cache_len=None: transformer.prefill(
                p, b, cfg, ax, cache_len),
            decode_step=lambda p, c, b: transformer.decode_step(p, c, b, cfg, ax),
            abstract_cache=lambda batch, cache_len, dtype=None: (
                transformer.abstract_cache(
                    cfg, batch, cache_len, dtype or cfg.dtype)),
        )
    if fam == "ssm":
        return Model(
            cfg, ax,
            init=lambda key: mamba2.init_model(cfg, key),
            loss_fn=lambda p, b: mamba2.loss_fn(p, b, cfg, ax),
            prefill=lambda p, b, cache_len=None: mamba2.prefill(
                p, b, cfg, ax, cache_len),
            decode_step=lambda p, c, b: mamba2.decode_step(p, c, b, cfg, ax),
            abstract_cache=lambda batch, cache_len=None, dtype=None: (
                mamba2.abstract_cache(cfg, batch, dtype or cfg.dtype)),
        )
    if fam == "hybrid":
        return Model(
            cfg, ax,
            init=lambda key: zamba2.init_model(cfg, key),
            loss_fn=lambda p, b: zamba2.loss_fn(p, b, cfg, ax),
            prefill=lambda p, b, cache_len=None: zamba2.prefill(
                p, b, cfg, ax, cache_len),
            decode_step=lambda p, c, b: zamba2.decode_step(p, c, b, cfg, ax),
            abstract_cache=lambda batch, cache_len, dtype=None: (
                zamba2.abstract_cache(cfg, batch, cache_len,
                                      dtype or cfg.dtype)),
        )
    raise ValueError(f"unknown family {fam}")
