"""Public wrappers over the limb-fused HE kernels, with a backend registry.

Execution model
---------------
RNS limbs are a batch/grid dimension, never a Python loop: every op consumes
the full u32[..., L, N] tensor in ONE call — a single fused jnp graph on the
`ref` backend, a single `pallas_call` with the limb index in the grid on the
`pallas` backend.  Per-limb constants (q, -q^{-1}, R^2, N^{-1}R, twiddle
tables) come pre-stacked as u32[L] / u32[L, N] arrays from
`CkksContext.tables` (params.LimbTables) and are sliced to the input's limb
count, so limb-dropped ciphertexts work transparently.

Backend registry
----------------
Each op is an entry in an op-table mapping backend name -> implementation:

  * "ref"    — pure-jnp oracle (repro/kernels/ref.py). Default on CPU: fast,
               exact, and what the FL examples/benchmarks run.
  * "pallas" — pl.pallas_call kernels. On CPU they run in interpret mode
               (kernel body executed in Python) for validation; on TPU they
               compile natively.
  * "pallas4" — like "pallas", but ntt_fwd/ntt_inv dispatch to the 4-step
               transpose NTT kernels (kernels/ntt.py, DESIGN.md §10): the
               lane-efficient layout for real-TPU butterflies below 128
               lanes.  Every non-NTT op shares the "pallas" kernels.  All
               concrete backends are bit-identical (tests/test_gold.py).
  * "auto"   — per-op, per-SHAPE resolution through the kernels/tune.py
               tuning cache (DESIGN.md §12): a cache hit runs the measured
               winner (concrete backend + launch config — block_b, ntt4
               split, butterfly radix), a miss runs the platform fallback
               with the shared defaults.  Resolution happens at trace
               time (shapes are static under jit) and the tuner's cache
               generation is folded into `backend_token()`, so cached
               graphs retrace when the cache (re)loads.

Selection is per-op: `set_backend("pallas")` flips every op,
`set_backend("pallas", op="weighted_sum")` flips one.  The interpret/compile
decision is made once (first use) from the JAX platform.  `backend_token()`
returns a hashable snapshot of the whole assignment for use as a static jit
key — the jitted encrypt/decrypt/aggregate graphs in core/ckks/cipher.py
retrace when the registry changes.
"""
from __future__ import annotations

import functools
import math
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as _obs
from repro.kernels import he_agg as _he_agg
from repro.kernels import lift as _lift
from repro.kernels import ntt as _ntt
from repro.kernels import pointwise as _pointwise
from repro.kernels import ref as _ref
from repro.kernels import tune as _tune

OPS = ("ntt_fwd", "ntt_inv", "mul_add", "mod_lift", "weighted_sum",
       "weighted_accum", "weighted_accum_chunks")
BACKENDS = ("ref", "pallas", "pallas4", "auto")


def _env_backend() -> str:
    """Read + validate REPRO_HE_BACKEND at import time.  An unknown value
    used to land in the assignment unchecked and surface much later as a
    bare KeyError at first dispatch; fail at import with the fix instead."""
    name = os.environ.get("REPRO_HE_BACKEND", "ref")
    if name not in BACKENDS:
        raise ValueError(
            f"REPRO_HE_BACKEND={name!r} is not a known backend; expected one "
            f"of {'/'.join(BACKENDS)} — see the 'Environment variables & "
            "flags' table in README.md")
    return name


_ASSIGN: dict[str, str] = {op: _env_backend() for op in OPS}
_INTERPRET: bool | None = None


def _interpret() -> bool:
    """Interpret vs native-compile, decided once per process at first build."""
    global _INTERPRET
    if _INTERPRET is None:
        _INTERPRET = jax.default_backend() == "cpu"
    return _INTERPRET


def set_backend(name: str, op: str | None = None) -> None:
    """Select the backend for every op (op=None) or one op."""
    assert name in BACKENDS, name
    if op is None:
        for o in OPS:
            _ASSIGN[o] = name
    else:
        assert op in OPS, op
        _ASSIGN[op] = name


def get_backend(op: str | None = None) -> str:
    """Backend for `op`; with op=None, the common backend ("mixed" if the
    per-op assignments diverge)."""
    if op is not None:
        return _ASSIGN[op]
    names = set(_ASSIGN.values())
    return names.pop() if len(names) == 1 else "mixed"


def backend_token() -> tuple:
    """Hashable snapshot of (per-op assignment, interpret flag) — the static
    jit key that makes cached graphs retrace on registry changes.  With any
    op on `auto` the tuner's cache generation is part of the token: a cache
    (re)load may change what a dispatch resolves to, so graphs that embedded
    the old resolution must retrace (tests/test_tune.py pins this)."""
    tok = tuple(sorted(_ASSIGN.items())) + (("interpret", _interpret()),)
    if "auto" in _ASSIGN.values():
        tok += (("tune", _tune.generation()),)
    return tok


@functools.lru_cache(maxsize=256)
def _tables(ctx, l: int):
    """ctx's stacked constant tables sliced to the first l limbs."""
    return ctx.tables.take(l)


def _qcol(t):
    return t.qs[:, None]


# ---------------------------------------------------------------------------
# op-table: one fused implementation per (op, backend)
# ---------------------------------------------------------------------------


# Every implementation takes a trailing `cfg` kwarg (tune.KernelConfig or
# None).  cfg=None means "kernel defaults" — byte-identical to the
# pre-autotuner call, which is what explicit ref/pallas/pallas4 backend
# selections always pass.  The ref oracle has no launch geometry, so it
# ignores cfg entirely.


def _blk(cfg):
    return cfg.block_b if cfg is not None else None


def _ntt_fwd_ref(t, x, cfg=None):
    return _ref.ntt_fwd_fused(x, t.psi_rev_mont, t.qs, t.qinv_negs)


def _ntt_fwd_pallas(t, x, cfg=None):
    return _ntt.ntt_fwd_fused(x, t.psi_rev_mont, t.qs, t.qinv_negs,
                              block_b=_blk(cfg), interpret=_interpret())


def _ntt_inv_ref(t, x, cfg=None):
    return _ref.ntt_inv_fused(x, t.psi_inv_rev_mont, t.n_inv_monts, t.qs,
                              t.qinv_negs)


def _ntt_inv_pallas(t, x, cfg=None):
    return _ntt.ntt_inv_fused(x, t.psi_inv_rev_mont, t.n_inv_monts, t.qs,
                              t.qinv_negs, block_b=_blk(cfg),
                              interpret=_interpret())


def _ntt_fwd_pallas4(t, x, cfg=None):
    return _ntt.ntt4_fwd_fused(x, t.ntt4_psi1_mont, t.ntt4_psi2_mont,
                               t.ntt4_corr_mont, t.qs, t.qinv_negs,
                               block_b=_blk(cfg),
                               radix=cfg.radix if cfg is not None else 2,
                               interpret=_interpret())


def _ntt_inv_pallas4(t, x, cfg=None):
    return _ntt.ntt4_inv_fused(x, t.ntt4_psi1_inv_mont,
                               t.ntt4_psi2_inv_mont, t.ntt4_corr_inv_mont,
                               t.n_inv_monts, t.qs, t.qinv_negs,
                               block_b=_blk(cfg),
                               radix=cfg.radix if cfg is not None else 2,
                               interpret=_interpret())


def _mul_add_ref(t, x, y_mont, z, cfg=None):
    return _ref.mul_add_fused(x, jnp.broadcast_to(y_mont, x.shape),
                              jnp.broadcast_to(z, x.shape), t.qs, t.qinv_negs)


def _mul_add_pallas(t, x, y_mont, z, cfg=None):
    return _pointwise.mul_add_fused(x, y_mont, z, t.qs, t.qinv_negs,
                                    block_b=_blk(cfg),
                                    interpret=_interpret())


def _mod_lift_ref(t, x, cfg=None):
    return _ref.mod_lift_fused(x, t.qs)


def _mod_lift_pallas(t, x, cfg=None):
    return _lift.mod_lift_fused(x, t.qs, block_b=_blk(cfg),
                                interpret=_interpret())


def _weighted_sum_ref(t, cts, w_mont, cfg=None):
    return _ref.he_weighted_sum_fused(cts, w_mont, t.qs, t.qinv_negs)


def _weighted_sum_pallas(t, cts, w_mont, cfg=None):
    return _he_agg.he_weighted_sum_fused(cts, w_mont, t.qs, t.qinv_negs,
                                         block_b=_blk(cfg),
                                         interpret=_interpret())


def _weighted_accum_ref(t, acc, ct, w_mont, cfg=None):
    return _ref.he_weighted_accum_fused(acc, ct, w_mont, t.qs, t.qinv_negs)


def _weighted_accum_pallas(t, acc, ct, w_mont, cfg=None):
    return _he_agg.he_weighted_accum_fused(acc, ct, w_mont, t.qs,
                                           t.qinv_negs, block_b=_blk(cfg),
                                           interpret=_interpret())


def _weighted_accum_chunks_ref(t, acc, cts, w_mont, cfg=None):
    return _ref.he_weighted_accum_chunks_fused(acc, cts, w_mont, t.qs,
                                               t.qinv_negs)


def _weighted_accum_chunks_pallas(t, acc, cts, w_mont, cfg=None):
    return _he_agg.he_weighted_accum_chunks_fused(acc, cts, w_mont, t.qs,
                                                  t.qinv_negs,
                                                  block_k=_blk(cfg),
                                                  interpret=_interpret())


_IMPL = {
    "ntt_fwd": {"ref": _ntt_fwd_ref, "pallas": _ntt_fwd_pallas,
                "pallas4": _ntt_fwd_pallas4},
    "ntt_inv": {"ref": _ntt_inv_ref, "pallas": _ntt_inv_pallas,
                "pallas4": _ntt_inv_pallas4},
    # pallas4 differs only in the NTT family; every other op shares the
    # limb-grid pallas kernel so REPRO_HE_BACKEND=pallas4 stays a full
    # backend assignment (same env canon as ref/pallas).
    "mul_add": {"ref": _mul_add_ref, "pallas": _mul_add_pallas,
                "pallas4": _mul_add_pallas},
    "mod_lift": {"ref": _mod_lift_ref, "pallas": _mod_lift_pallas,
                 "pallas4": _mod_lift_pallas},
    "weighted_sum": {"ref": _weighted_sum_ref,
                     "pallas": _weighted_sum_pallas,
                     "pallas4": _weighted_sum_pallas},
    "weighted_accum": {"ref": _weighted_accum_ref,
                       "pallas": _weighted_accum_pallas,
                       "pallas4": _weighted_accum_pallas},
    "weighted_accum_chunks": {"ref": _weighted_accum_chunks_ref,
                              "pallas": _weighted_accum_chunks_pallas,
                              "pallas4": _weighted_accum_chunks_pallas},
}


# shape-key extraction: which positional arg carries the [..., L, N] tensor
# whose batch size keys the tuning cache, and how its batch is counted.
# Shapes are static under jit, so `auto` resolution is a trace-time
# decision — the resolved (backend, config) is baked into the graph and
# `backend_token()` carries the tuner generation to force retraces.
_SHAPE_ARG = {"ntt_fwd": 0, "ntt_inv": 0, "mul_add": 0, "mod_lift": 0,
              "weighted_sum": 0, "weighted_accum": 1,
              "weighted_accum_chunks": 1}


def _shape_dims(op, tables, args):
    """(N, L, B) of one dispatch — B is the flattened batch the kernel
    wrappers grid over (leading-axis rows for the chunk kernel)."""
    x = args[_SHAPE_ARG[op]]
    if op == "mod_lift":
        # the lift input is u32[..., N] with NO limb axis (the client never
        # touched RNS); L is the table depth the dispatch was sliced to.
        return x.shape[-1], int(tables.qs.shape[0]), \
            int(math.prod(x.shape[:-1]))
    n, l = x.shape[-1], x.shape[-2]
    if op == "weighted_sum":
        b = math.prod(x.shape[1:-2])      # leading axis is the client count
    elif op == "weighted_accum_chunks":
        b = x.shape[0]                    # grid rows = chunk rows K
    else:
        b = math.prod(x.shape[:-2])
    return n, l, int(b)


def _variant_tables(tables, split):
    """tables with the ntt4_* fields rebuilt for a non-default split.

    Only the host-numpy constant-embedding path can be retabled; traced or
    sharded tables (core/ckks/sharded.py passes per-shard slices inside
    shard_map) keep their default split — the tuner's split choice simply
    doesn't apply there."""
    if not isinstance(tables.qs, np.ndarray):
        return tables
    from repro.core.ckks import params as _params

    return _params.retable_ntt4(tables, split[0], split[1])


def _resolve(op, tables, args):
    """(concrete backend, config|None) for one dispatch.  Explicit
    assignments keep cfg=None — byte-identical to the pre-autotuner call."""
    backend = _ASSIGN[op]
    if backend != "auto":
        return backend, None
    n, l, b = _shape_dims(op, tables, args)
    return _tune.resolve(op, n, l, b, _interpret())


def _dispatch(op, tables, *args):
    """Registry dispatch point for every op invocation.

    With REPRO_OBS unset this is exactly the raw implementation call —
    same jitted graph keys, same dispatch count (tests/test_obs.py pins
    it).  With REPRO_OBS=1 the call routes through obs.timed_kernel:
    eager invocations get blocked per-op wall timing under a
    jax.profiler.TraceAnnotation; invocations inside a jit/shard_map
    trace get a jax.named_scope so device profiles carry op names, plus a
    retrace counter — all recorded per backend so flat/pallas/pallas4
    runs are distinguishable (DESIGN.md §11).  `auto` resolves through
    the tuning cache first and stamps the resolved config into the span.
    """
    backend, cfg = _resolve(op, tables, args)
    if (cfg is not None and cfg.ntt4_split is not None
            and backend == "pallas4" and op in _tune.NTT_OPS):
        tables = _variant_tables(tables, cfg.ntt4_split)
    impl = _IMPL[op][backend]
    if not _obs.kernel_hooks_enabled():
        return impl(tables, *args, cfg=cfg)
    return _obs.timed_kernel(op, backend, backend_token(),
                             functools.partial(impl, cfg=cfg), tables,
                             *args, config=cfg)


def run_config(op, backend, cfg, tables, *args):
    """Run one op under an explicit (concrete backend, KernelConfig),
    bypassing the registry assignment — the tuner's measurement entry
    (tune._candidate_fn) and a debugging hook.  Applies the config's
    ntt4_split variant tables exactly like `_dispatch`."""
    assert backend in ("ref", "pallas", "pallas4"), backend
    if (cfg is not None and cfg.ntt4_split is not None
            and backend == "pallas4" and op in _tune.NTT_OPS):
        tables = _variant_tables(tables, cfg.ntt4_split)
    return _IMPL[op][backend](tables, *args, cfg=cfg)


def apply(op, tables, *args):
    """Dispatch `op` through the registry with explicit constant tables.

    Args:
        op: one of OPS.
        tables: a `params.LimbTables` — may hold host numpy arrays (the
            normal constant-embedding path) OR traced/sharded jnp arrays.
            The sharded engine (core/ckks/sharded.py) builds per-shard
            tables inside `shard_map` and routes every kernel through here,
            so per-op backend selection applies unchanged across chips.
        *args: the op's positional tensor arguments (see the public
            wrappers below for each op's layout contract).

    Returns:
        The op's result with the same layout as the public wrapper.
    """
    return _dispatch(op, tables, *args)


# ---------------------------------------------------------------------------
# public fused ops (ciphertext-limb layout: u32[..., L, N])
# ---------------------------------------------------------------------------


def ntt_fwd(x, ctx):
    """Forward negacyclic NTT over every limb in one launch.

    Args:
        x: u32[..., L, N] coefficient-domain residues, natural order.
        ctx: CkksContext (tables sliced to x's limb count).

    Returns:
        u32[..., L, N] in bit-reversed NTT domain.
    """
    return _dispatch("ntt_fwd", _tables(ctx, x.shape[-2]), x)


def ntt_inv(x, ctx):
    """Inverse negacyclic NTT over every limb in one launch.

    Args:
        x: u32[..., L, N] bit-reversed NTT-domain residues.
        ctx: CkksContext.

    Returns:
        u32[..., L, N] coefficient-domain residues, natural order.
    """
    return _dispatch("ntt_inv", _tables(ctx, x.shape[-2]), x)


def mul_add(x, y_mont, z, ctx):
    """Fused x (*) y_mont + z — the encrypt/decrypt workhorse.

    Args:
        x: u32[..., L, N] normal-form residues.
        y_mont: u32[..., L, N] Montgomery-form operand (broadcastable to x).
        z: u32[..., L, N] normal-form addend (broadcastable to x).
        ctx: CkksContext.

    Returns:
        u32[..., L, N] normal-form result, one fused call over all limbs.
    """
    return _dispatch("mul_add", _tables(ctx, x.shape[-2]), x, y_mont, z)


def mod_lift(x, n_limbs, ctx):
    """Per-limb modular lift: residues of raw u32 rows across the limb grid.

    Args:
        x: u32[..., N] full-range 32-bit words with NO limb axis —
            transcipher-masked coefficients or keystream pads
            (DESIGN.md §15).
        n_limbs: limb count L of the target ciphertext level.
        ctx: CkksContext.

    Returns:
        u32[..., L, N] with out[..., l, :] = x mod q_l — the transcipher
        server unmask's first step, feeding ntt_fwd.
    """
    return _dispatch("mod_lift", _tables(ctx, n_limbs), x)


def weighted_sum(cts, w_mont, ctx):
    """Batch FedAvg aggregation: sum_i w_i (*) ct_i over the leading axis.

    Args:
        cts: u32[C, ..., L, N] client ciphertext residues (NTT domain).
        w_mont: u32[C, L] Montgomery-form scalar weights per (client, limb).
        ctx: CkksContext.

    Returns:
        u32[..., L, N] aggregate; each element read once, accumulator in
        VMEM on the pallas backend.
    """
    l = cts.shape[-2]
    return _dispatch("weighted_sum", _tables(ctx, l), cts, w_mont[:, :l])


def weighted_accum(acc, ct, w_mont, ctx):
    """Streaming aggregation step: acc + w (*) ct.

    Args:
        acc: u32[..., L, N] running modular accumulator.
        ct: u32[..., L, N] one arriving ciphertext.
        w_mont: u32[L] Montgomery scalar weight.
        ctx: CkksContext.

    Returns:
        u32[..., L, N] updated accumulator.  One client folded into the
        running sum — the O(1)-memory server path (repro.wire.stream);
        bit-identical to weighted_sum applied in arrival order.
    """
    l = ct.shape[-2]
    return _dispatch("weighted_accum", _tables(ctx, l), acc, ct, w_mont[:l])


def weighted_accum_chunks(acc, cts, w_mont, ctx):
    """Batched streaming flush: acc[k] + w[k] (*) ct[k] for every ready
    chunk row k in ONE launch.

    Args:
        acc: u32[K, ..., L, N] per-row accumulators (zeros for fresh rows).
        cts: u32[K, ..., L, N] ready ciphertext chunks; rows may belong to
            different clients and different chunk indices.
        w_mont: u32[K, L] per-row Montgomery scalar weights.
        ctx: CkksContext.

    Returns:
        u32[K, ..., L, N] updated accumulators.  Bit-identical to calling
        weighted_accum row by row — the wire/stream flush invariant.
    """
    l = cts.shape[-2]
    return _dispatch("weighted_accum_chunks", _tables(ctx, l), acc, cts,
                     w_mont[:, :l])


# limb-wise helpers with no dedicated kernel (cheap, always ref) ------------


def mod_add(a, b, ctx):
    t = _tables(ctx, a.shape[-2])
    return _ref.mod_add(a, jnp.broadcast_to(b, a.shape), _qcol(t))


def mod_sub(a, b, ctx):
    t = _tables(ctx, a.shape[-2])
    return _ref.mod_sub(a, jnp.broadcast_to(b, a.shape), _qcol(t))


def mod_neg(a, ctx):
    return _ref.mod_neg(a, _qcol(_tables(ctx, a.shape[-2])))


def to_mont(a, ctx):
    t = _tables(ctx, a.shape[-2])
    return _ref.mont_mul(a, jnp.broadcast_to(t.r2s[:, None], a.shape),
                         _qcol(t), t.qinv_negs[:, None])


def from_mont(a, ctx):
    t = _tables(ctx, a.shape[-2])
    return _ref.mont_mul(a, jnp.ones_like(a), _qcol(t), t.qinv_negs[:, None])


def mont_mul(a, b_mont, ctx):
    t = _tables(ctx, a.shape[-2])
    return _ref.mont_mul(a, jnp.broadcast_to(b_mont, a.shape), _qcol(t),
                         t.qinv_negs[:, None])
