from repro.data.synthetic import (SyntheticLM, dirichlet_partition,
                                  make_client_streams)
