"""Property tests for the mask selectors (core/selection.py) and fuzz for
the mask partition (core/packing.py) — the static halves of the selective
pipeline.

Invariants pinned here:
  * masks NEST across p for top_p / random / per_layer (fixed sensitivity,
    fixed seed): mask(p1) subset mask(p2) whenever p1 <= p2
  * recipe_mask always fully covers the first and last leaves
  * ties on |sensitivity| break deterministically by index (lowest wins)
  * make_partition / split_by_mask / merge_by_mask round-trip any mask —
    empty, full, non-slot-aligned, ragged last chunk
"""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hyp import given, settings, st

from repro.core import packing, selection

NESTING_STRATEGIES = ["top_p", "random", "per_layer"]


def _layout(n, n_leaves=3):
    """An arbitrary leaf layout covering [0, n) for layer-aware selectors."""
    cuts = np.linspace(0, n, n_leaves + 1).astype(int)
    sizes = tuple(int(b - a) for a, b in zip(cuts[:-1], cuts[1:])
                  if b - a > 0)
    offsets = tuple(int(x) for x in np.concatenate(
        [[0], np.cumsum(sizes)[:-1]])) if sizes else ()
    return offsets, sizes


# ---------------------------------------------------------------------------
# nesting across p (hypothesis + a deterministic pinned case)
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(st.lists(st.floats(-1e6, 1e6, allow_nan=False), min_size=1,
                max_size=200),
       st.floats(0.0, 1.0), st.floats(0.0, 1.0),
       st.sampled_from(NESTING_STRATEGIES), st.integers(0, 2 ** 31 - 1))
def test_masks_nest_across_p(sens, p1, p2, strategy, seed):
    sens = np.asarray(sens)
    lo, hi = sorted((p1, p2))
    offsets, sizes = _layout(sens.size)
    m_lo = selection.build_mask(sens, strategy, lo, offsets=offsets,
                                sizes=sizes, seed=seed)
    m_hi = selection.build_mask(sens, strategy, hi, offsets=offsets,
                                sizes=sizes, seed=seed)
    assert not np.any(m_lo & ~m_hi), "smaller-p mask escaped the larger one"


@pytest.mark.parametrize("strategy", NESTING_STRATEGIES)
def test_masks_nest_across_sweep(strategy):
    rng = np.random.RandomState(0)
    sens = rng.randn(997)
    offsets, sizes = _layout(sens.size, n_leaves=5)
    prev = None
    for p in (0.0, 0.05, 0.1, 0.3, 0.5, 1.0):
        m = selection.build_mask(sens, strategy, p, offsets=offsets,
                                 sizes=sizes, seed=3)
        if prev is not None:
            assert not np.any(prev & ~m)
        prev = m
    assert prev.all()                              # p=1.0 covers everything


# ---------------------------------------------------------------------------
# recipe covers first + last leaves
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(st.lists(st.floats(-1e6, 1e6, allow_nan=False), min_size=2,
                max_size=200),
       st.floats(0.0, 1.0), st.integers(1, 6))
def test_recipe_covers_first_and_last_leaves(sens, p, n_leaves):
    sens = np.asarray(sens)
    offsets, sizes = _layout(sens.size, n_leaves=n_leaves)
    m = selection.build_mask(sens, "recipe", p, offsets=offsets, sizes=sizes)
    assert m[offsets[0]: offsets[0] + sizes[0]].all()
    assert m[offsets[-1]: offsets[-1] + sizes[-1]].all()
    # and it is a superset of plain top_p at the same p
    assert not np.any(selection.top_p_mask(sens, p) & ~m)


# ---------------------------------------------------------------------------
# deterministic tie-break
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 300), st.floats(0.0, 1.0))
def test_top_p_tie_break_is_by_index(n, p):
    sens = np.full(n, 2.5)                         # all-equal sensitivities
    m = selection.top_p_mask(sens, p)
    k = int(m.sum())
    # lowest indices win — the mask is exactly a prefix
    assert m[:k].all() and not m[k:].any()


def test_tie_break_stable_under_sign_and_dtype():
    sens = np.asarray([1.0, -1.0, 1.0, -1.0, 0.5], dtype=np.float32)
    m = selection.top_p_mask(sens, 0.4)            # k=2: |1.0| ties, idx wins
    np.testing.assert_array_equal(m, [True, True, False, False, False])
    m64 = selection.top_p_mask(sens.astype(np.float64), 0.4)
    np.testing.assert_array_equal(m, m64)


def test_build_mask_dispatch_errors():
    with pytest.raises(ValueError, match="unknown selection strategy"):
        selection.build_mask(np.ones(4), "bogus", 0.5)
    with pytest.raises(ValueError, match="leaf layout"):
        selection.build_mask(np.ones(4), "recipe", 0.5)
    assert selection.build_mask(np.ones(4), "all", 0.0).all()
    assert not selection.build_mask(np.ones(4), "none", 1.0).any()


# ---------------------------------------------------------------------------
# partition fuzz: adversarial masks round-trip split/merge
# ---------------------------------------------------------------------------

SLOTS = 8


def _roundtrip(mask, slots=SLOTS):
    mask = np.asarray(mask, dtype=bool)
    part = packing.make_partition(mask, slots)
    # invariants: enc/plain indices disjointly cover [0, n)
    assert part.n_enc + part.n_plain == part.n_total == mask.size
    both = np.concatenate([part.enc_idx, part.plain_idx])
    assert np.array_equal(np.sort(both), np.arange(mask.size))
    assert part.n_chunks == max(1, -(-part.n_enc // slots))
    vec = jnp.asarray(
        np.random.RandomState(mask.size).randn(mask.size).astype(np.float32))
    enc, plain = packing.split_by_mask(vec, part)
    assert enc.shape == (part.n_chunks, slots)     # zero-padded ragged tail
    assert int(plain.shape[0]) == part.n_plain
    back = packing.merge_by_mask(enc, plain, part)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(vec))
    return part


@pytest.mark.parametrize("mask", [
    np.zeros(37, dtype=bool),                      # empty -> 1 all-pad chunk
    np.ones(37, dtype=bool),                       # full, non-slot-aligned
    np.ones(SLOTS * 3, dtype=bool),                # full, slot-aligned
    np.arange(61) % 2 == 0,                        # interleaved, ragged
    np.arange(9) < 8,                              # exactly one full chunk
    np.zeros(1, dtype=bool),                       # single param, plain
    np.ones(1, dtype=bool),                        # single param, encrypted
])
def test_partition_roundtrip_adversarial(mask):
    part = _roundtrip(mask)
    if not mask.any():
        assert part.n_chunks == 1                  # never a 0-chunk ct


@settings(max_examples=60, deadline=None)
@given(st.lists(st.booleans(), min_size=1, max_size=120),
       st.integers(1, 16))
def test_partition_roundtrip_fuzz(bits, slots):
    _roundtrip(np.asarray(bits, dtype=bool), slots=slots)
