"""CKKS correctness: roundtrips, homomorphism, rescale, threshold,
crypto-parameter sweeps (paper Table 6 behaviour) + hypothesis properties."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pragma: no cover
    from _hyp import given, settings, st

from repro.core.ckks import cipher, encoding, threshold
from repro.core.ckks import params as ckks_params


def make(n_poly=256, delta_bits=20):
    return ckks_params.make_test_context(n_poly=n_poly, n_limbs=2,
                                         delta_bits=delta_bits)


CTX = make()
SK, PK = cipher.keygen(CTX, jax.random.PRNGKey(0))


def _vals(b, slots, seed=0, scale=1.0):
    return (np.random.RandomState(seed).randn(b, slots) * scale) \
        .astype(np.float32)


def test_encode_decode_np_roundtrip():
    v = _vals(3, CTX.slots)
    out = encoding.decode_np(encoding.encode_np(v, CTX), CTX, CTX.delta)
    # rounding error ~ O(N)/delta: ~1e-3 at delta 2^20, N=256
    np.testing.assert_allclose(out, v, atol=3e-3)
    # and it shrinks ~linearly in delta (structural correctness)
    big = float(2 ** 40)
    out40 = encoding.decode_np(encoding.encode_np(v, CTX, delta=big), CTX, big)
    assert np.abs(out40 - v).max() < 1e-8


def test_encode_jnp_matches_np():
    v = _vals(2, CTX.slots)
    a = np.asarray(encoding.encode_jnp(jnp.asarray(v), CTX))
    b = encoding.encode_np(v, CTX)
    # complex64 FFT vs f64 FFT: residues may differ by +-1 ulp of delta
    diff = (a.astype(np.int64) - b.astype(np.int64)) % CTX.primes[0]
    diff = np.minimum(diff, CTX.primes[0] - diff)
    assert diff.max() <= 2


def test_encrypt_decrypt_roundtrip():
    v = _vals(3, CTX.slots, seed=1)
    ct = cipher.encrypt_coeffs(CTX, PK, jnp.asarray(encoding.encode_np(v, CTX)),
                               jax.random.PRNGKey(1))
    out = cipher.decrypt_values_np(CTX, SK, ct)
    np.testing.assert_allclose(out, v, atol=5e-3)
    out_jnp = np.asarray(cipher.decrypt_values(CTX, SK, ct))
    np.testing.assert_allclose(out_jnp, v, atol=5e-3)


def test_homomorphic_add():
    v1, v2 = _vals(2, CTX.slots, 2), _vals(2, CTX.slots, 3)
    k = jax.random.PRNGKey(2)
    ct1 = cipher.encrypt_coeffs(CTX, PK, jnp.asarray(encoding.encode_np(v1, CTX)), k)
    ct2 = cipher.encrypt_coeffs(CTX, PK, jnp.asarray(encoding.encode_np(v2, CTX)),
                                jax.random.fold_in(k, 1))
    out = cipher.decrypt_values_np(CTX, SK, cipher.add(CTX, ct1, ct2))
    np.testing.assert_allclose(out, v1 + v2, atol=1e-2)


@pytest.mark.parametrize("w", [0.25, 1.0, -0.7, 0.001])
def test_mul_plain_scalar(w):
    v = _vals(2, CTX.slots, 4)
    ct = cipher.encrypt_coeffs(CTX, PK, jnp.asarray(encoding.encode_np(v, CTX)),
                               jax.random.PRNGKey(3))
    out = cipher.decrypt_values_np(CTX, SK, cipher.mul_plain_scalar(CTX, ct, w))
    np.testing.assert_allclose(out, w * v, atol=2e-2)


@given(ws=st.lists(st.floats(0.01, 1.0), min_size=2, max_size=6))
@settings(max_examples=10, deadline=None)
def test_weighted_sum_homomorphism(ws):
    """Dec(sum w_i Enc(x_i)) ~= sum w_i x_i (the FedAvg core)."""
    ws = [w / sum(ws) for w in ws]
    vs = [_vals(1, CTX.slots, 10 + i) for i in range(len(ws))]
    cts = [cipher.encrypt_coeffs(
        CTX, PK, jnp.asarray(encoding.encode_np(v, CTX)),
        jax.random.PRNGKey(20 + i)) for i, v in enumerate(vs)]
    stacked = cipher.Ciphertext(data=jnp.stack([c.data for c in cts]),
                                scale=cts[0].scale)
    agg = cipher.weighted_sum(CTX, stacked, ws)
    out = cipher.decrypt_values_np(CTX, SK, agg)
    expect = sum(w * v for w, v in zip(ws, vs))
    np.testing.assert_allclose(out, expect, atol=2e-2)


def test_rescale_preserves_value():
    # delta 2^26: post-rescale scale is delta^2/q_last ~ 2^22, keeping the
    # O(||s||_1) rescale rounding noise ~1e-4 (paper-realistic params).
    ctx3 = ckks_params.make_context(n_poly=256, n_limbs=3, delta_bits=26)
    sk3, pk3 = cipher.keygen(ctx3, jax.random.PRNGKey(5))
    v = _vals(2, ctx3.slots, 6)
    ct = cipher.encrypt_coeffs(ctx3, pk3,
                               jnp.asarray(encoding.encode_np(v, ctx3)),
                               jax.random.PRNGKey(6))
    ct2 = cipher.mul_plain_scalar(ctx3, ct, 0.5)
    ct3 = cipher.rescale(ctx3, ct2)
    assert ct3.n_limbs == 2
    out = cipher.decrypt_values_np(ctx3, sk3, ct3)
    np.testing.assert_allclose(out, 0.5 * v, atol=5e-3)


def test_delta_accuracy_tradeoff():
    """Paper Table 6: larger scaling factor -> closer-to-exact decrypt."""
    errs = []
    for db in (12, 16, 20, 24):
        ctx = make(delta_bits=db)
        sk, pk = cipher.keygen(ctx, jax.random.PRNGKey(7))
        v = _vals(1, ctx.slots, 8)
        ct = cipher.encrypt_coeffs(ctx, pk,
                                   jnp.asarray(encoding.encode_np(v, ctx)),
                                   jax.random.PRNGKey(8))
        errs.append(np.abs(cipher.decrypt_values_np(ctx, sk, ct) - v).max())
    assert errs[0] > errs[-1], errs
    assert errs[-1] < 1e-3


def test_packing_batch_size_vs_ciphertext_count():
    """Paper Table 6: bigger packing batch -> fewer, larger ciphertexts;
    total encrypted bytes shrink with slot utilization."""
    n_values = 100_000
    sizes = {}
    for n_poly in (2048, 4096, 8192):
        ctx = ckks_params.make_context(n_poly=n_poly, n_limbs=2,
                                       delta_bits=26)
        sizes[n_poly] = (ctx.num_ciphertexts(n_values),
                         ctx.encrypted_bytes(n_values))
    assert sizes[2048][0] > sizes[8192][0]


# ---------------------------------------------------------------------------
# threshold HE
# ---------------------------------------------------------------------------


def test_threshold_additive_roundtrip():
    parties, tpk = threshold.threshold_keygen(CTX, jax.random.PRNGKey(9), 3)
    v = _vals(2, CTX.slots, 9)
    ct = cipher.encrypt_coeffs(CTX, tpk,
                               jnp.asarray(encoding.encode_np(v, CTX)),
                               jax.random.PRNGKey(10))
    partials = [threshold.partial_decrypt(CTX, p, ct, jax.random.PRNGKey(30 + i))
                for i, p in enumerate(parties)]
    out = encoding.decode_np(np.asarray(
        threshold.combine_partials(CTX, ct, partials)), CTX, ct.scale)
    np.testing.assert_allclose(out, v, atol=0.5)   # smudging noise


def test_threshold_missing_party_fails():
    parties, tpk = threshold.threshold_keygen(CTX, jax.random.PRNGKey(11), 3)
    v = _vals(1, CTX.slots, 11)
    ct = cipher.encrypt_coeffs(CTX, tpk,
                               jnp.asarray(encoding.encode_np(v, CTX)),
                               jax.random.PRNGKey(12))
    partials = [threshold.partial_decrypt(CTX, p, ct, jax.random.PRNGKey(40 + i))
                for i, p in enumerate(parties[:2])]    # one missing
    out = encoding.decode_np(np.asarray(
        threshold.combine_partials(CTX, ct, partials)), CTX, ct.scale)
    assert np.abs(out - v).max() > 1.0     # decryption garbage


def test_shamir_threshold_roundtrip():
    parties = threshold.shamir_share_secret(CTX, SK, jax.random.PRNGKey(13),
                                            n_parties=5, threshold=3)
    v = _vals(1, CTX.slots, 13)
    ct = cipher.encrypt_coeffs(CTX, PK,
                               jnp.asarray(encoding.encode_np(v, CTX)),
                               jax.random.PRNGKey(14))
    active = [0, 2, 4]
    partials = [threshold.shamir_partial_decrypt(
        CTX, parties[i], active, ct, jax.random.PRNGKey(50 + i))
        for i in active]
    acc = ct.c0
    from repro.kernels import ops
    for d in partials:
        acc = ops.mod_add(acc, d, CTX)
    out = encoding.decode_np(np.asarray(ops.ntt_inv(acc, CTX)), CTX, ct.scale)
    np.testing.assert_allclose(out, v, atol=0.5)


# ---------------------------------------------------------------------------
# semantic security smoke: ciphertexts of equal plaintexts differ
# ---------------------------------------------------------------------------


def test_probabilistic_encryption():
    v = _vals(1, CTX.slots, 15)
    c1 = cipher.encrypt_coeffs(CTX, PK, jnp.asarray(encoding.encode_np(v, CTX)),
                               jax.random.PRNGKey(15))
    c2 = cipher.encrypt_coeffs(CTX, PK, jnp.asarray(encoding.encode_np(v, CTX)),
                               jax.random.PRNGKey(16))
    assert not np.array_equal(np.asarray(c1.data), np.asarray(c2.data))
