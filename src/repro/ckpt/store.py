"""Fault-tolerant pytree checkpointing (npz payload + json manifest).

Atomicity: payload is written to a temp dir then os.replace'd into place —
a crash mid-write never corrupts the latest checkpoint.  Rotation keeps the
last ``keep`` steps.  FL round boundaries are natural checkpoint points
(repro/fl/orchestrator.py) so a restarted job resumes at the last round.

Sharded arrays: leaves are gathered to host (np.asarray) before writing;
restore hands back numpy arrays to be re-sharded by the caller's pjit
in_shardings (device_put against the target sharding).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile

import jax
import numpy as np

# only exactly step_<digits> counts as a checkpoint: a crash-orphaned
# .tmp_ckpt_* dir, a stray "step_final" note, or any other junk in the
# checkpoint root must never break latest_step / rotation
_STEP_DIR = re.compile(r"^step_(\d+)$")


def _step_numbers(path: str) -> list[int]:
    """Sorted step numbers of the well-formed step_<N> dirs under path."""
    if not os.path.isdir(path):
        return []
    steps = []
    for d in os.listdir(path):
        m = _STEP_DIR.match(d)
        if m and os.path.isdir(os.path.join(path, d)):
            steps.append(int(m.group(1)))
    return sorted(steps)


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save_checkpoint(path: str, step: int, tree, extra: dict | None = None):
    """Atomic write of one checkpoint at `path/step_<N>/`."""
    names, leaves, _ = _flatten_with_names(tree)
    final = os.path.join(path, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=path, prefix=".tmp_ckpt_")
    try:
        arrays = {f"a{i}": np.asarray(l) for i, l in enumerate(leaves)}
        np.savez(os.path.join(tmp, "payload.npz"), **arrays)
        manifest = {"step": step, "names": names,
                    "extra": extra or {}}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    finally:
        if os.path.exists(tmp):
            shutil.rmtree(tmp, ignore_errors=True)
    return final


def latest_step(path: str) -> int | None:
    """Highest step with a well-formed step_<N> dir, or None.  Ignores
    orphaned temp dirs and non-numeric step_* strays (a crashed writer
    must never wedge the next restore)."""
    steps = _step_numbers(path)
    return steps[-1] if steps else None


def read_manifest(path: str, step: int | None = None) -> dict | None:
    """Manifest dict of one checkpoint ({"step", "names", "extra"}), or
    None when absent.  Lets a resuming service read its json round state
    BEFORE it can construct the tree_like that restore_checkpoint needs
    (the extra records which accumulator trees the npz payload holds)."""
    step = latest_step(path) if step is None else step
    if step is None:
        return None
    manifest = os.path.join(path, f"step_{step:08d}", "manifest.json")
    if not os.path.exists(manifest):
        return None
    with open(manifest) as f:
        return json.load(f)


def restore_checkpoint(path: str, tree_like, step: int | None = None):
    """Returns (tree, step, extra) or (None, None, None) when absent."""
    step = latest_step(path) if step is None else step
    if step is None:
        return None, None, None
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    payload = np.load(os.path.join(d, "payload.npz"))
    leaves = [payload[f"a{i}"] for i in range(len(manifest["names"]))]
    _, treedef = jax.tree_util.tree_flatten(tree_like)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, manifest["step"], manifest["extra"]


class CheckpointManager:
    """Rotation + resume policy around save/restore."""

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep

    def save(self, step: int, tree, extra: dict | None = None):
        out = save_checkpoint(self.path, step, tree, extra)
        self._rotate()
        return out

    def restore(self, tree_like, step: int | None = None):
        return restore_checkpoint(self.path, tree_like, step)

    def _rotate(self):
        for s in _step_numbers(self.path)[: -self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"),
                          ignore_errors=True)
