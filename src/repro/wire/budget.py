"""Per-round bandwidth ledger: measured bytes on the wire, not estimates.

Every serialized artifact that crosses the (simulated) network records an
entry here — direction, client, artifact class, byte count — so the paper's
communication-overhead tables (Table 4/7, Figure 7) can be computed from
real serialized payload sizes.  Bytes are accounted at the receiving end:
FLServer ledgers uplink blobs as it ingests them, FLClient ledgers the
downlink broadcast it receives, and the orchestrator reads the shared
ledger into round logs; benchmarks/run.py and examples/quickstart.py
print it.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

from repro import obs

UPLINK = "up"
DOWNLINK = "down"

# artifact classes
K_CIPHERTEXT = "ciphertext"
K_SEEDED_CT = "seeded_ciphertext"
K_PLAIN = "plain"
K_KEY = "key"
K_META = "meta"


@dataclasses.dataclass(frozen=True)
class WireRecord:
    round: int
    cid: int
    direction: str       # "up" | "down"
    kind: str            # artifact class (K_* above)
    nbytes: int


class BandwidthLedger:
    """Append-only log of measured wire traffic.

    One WireRecord per serialized artifact that crossed the (simulated)
    network; query helpers aggregate by round / client / direction /
    artifact class.  Shared by FLServer (uplink), FLClient (downlink),
    and the orchestrator's round logs.
    """

    def __init__(self):
        self.records: list[WireRecord] = []

    def record(self, *, rnd: int, cid: int, direction: str, kind: str,
               nbytes: int) -> None:
        """Append one entry.

        Args:
            rnd: FL round number.
            cid: client id the bytes were sent by / to.
            direction: UPLINK ("up") or DOWNLINK ("down").
            kind: artifact class (one of the K_* constants).
            nbytes: measured serialized size in bytes.
        """
        self.records.append(WireRecord(int(rnd), int(cid), direction, kind,
                                       int(nbytes)))
        obs.counter("wire_bytes_total", direction=direction,
                    kind=kind).inc(int(nbytes))

    # -- queries ------------------------------------------------------------

    def total(self, direction: str | None = None, rnd: int | None = None,
              kind: str | None = None, cid: int | None = None) -> int:
        """Sum of measured bytes over records matching every given filter
        (None = match all).  Returns an int byte count."""
        return sum(r.nbytes for r in self.records
                   if (direction is None or r.direction == direction)
                   and (rnd is None or r.round == rnd)
                   and (kind is None or r.kind == kind)
                   and (cid is None or r.cid == cid))

    def round_summary(self, rnd: int) -> dict:
        """Measured bytes for one round, split by direction and artifact."""
        by_kind: dict[str, int] = defaultdict(int)
        clients = set()
        for r in self.records:
            if r.round != rnd:
                continue
            by_kind[f"{r.direction}/{r.kind}"] += r.nbytes
            clients.add(r.cid)
        up = self.total(UPLINK, rnd)
        down = self.total(DOWNLINK, rnd)
        return {
            "round": rnd,
            "n_clients": len(clients),
            "uplink_bytes": up,
            "downlink_bytes": down,
            "total_bytes": up + down,
            "by_kind": dict(by_kind),
        }

    def rounds(self) -> list[int]:
        """Sorted round numbers that have at least one record."""
        return sorted({r.round for r in self.records})

    def per_client_uplink(self, rnd: int) -> dict[int, int]:
        """Measured uplink bytes per client id for one round."""
        out: dict[int, int] = defaultdict(int)
        for r in self.records:
            if r.round == rnd and r.direction == UPLINK:
                out[r.cid] += r.nbytes
        return dict(out)

    def record_blob(self, blob: bytes, *, rnd: int, cid: int,
                    direction: str) -> int:
        """Split a serialized frame stream into per-artifact-class entries.

        Args:
            blob: concatenated wire frames (repro.wire.format layout).
            rnd, cid, direction: as for record().

        Returns:
            Total bytes recorded (== len(blob) when every frame parses).
            Header bytes count toward the class they envelope; nested
            PROTECTED_UPDATE frames are split into their inner ct/plain
            classes with the envelope accounted as K_META.
        """
        from repro.wire import format as wf
        off = 0
        total = 0
        while off < len(blob):
            ftype, _, payload, end = wf.parse_frame(blob, off)
            nbytes = end - off
            if ftype == wf.T_CT_CHUNK:
                inner_t, _, _, _ = wf.parse_frame(payload, 4)
                kind = (K_SEEDED_CT if inner_t == wf.T_SEEDED_CIPHERTEXT
                        else K_CIPHERTEXT)
            elif ftype == wf.T_CIPHERTEXT:
                kind = K_CIPHERTEXT
            elif ftype == wf.T_SEEDED_CIPHERTEXT:
                kind = K_SEEDED_CT
            elif ftype == wf.T_PLAIN_SEGMENT:
                kind = K_PLAIN
            elif ftype == wf.T_KEYSET:
                kind = K_KEY
            elif ftype == wf.T_PROTECTED_UPDATE:
                # nested: split ct + plain inner frames, count envelope as meta
                inner_off = 0
                while inner_off < len(payload):
                    it, _, ip, inner_end = wf.parse_frame(payload, inner_off)
                    ik = (K_PLAIN if it == wf.T_PLAIN_SEGMENT else
                          K_SEEDED_CT if it == wf.T_SEEDED_CIPHERTEXT else
                          K_CIPHERTEXT)
                    self.record(rnd=rnd, cid=cid, direction=direction,
                                kind=ik, nbytes=inner_end - inner_off)
                    inner_off = inner_end
                self.record(rnd=rnd, cid=cid, direction=direction,
                            kind=K_META, nbytes=nbytes - len(payload))
                total += nbytes
                off = end
                continue
            else:
                kind = K_META
            self.record(rnd=rnd, cid=cid, direction=direction, kind=kind,
                        nbytes=nbytes)
            total += nbytes
            off = end
        return total

    # -- paper-table helpers -------------------------------------------------

    def compression_summary(self, ctx, part, rnd: int) -> dict:
        """Measured uplink vs the naive all-encrypted raw-u32 baseline.

        `part` is the aggregator's MaskPartition; the baseline is what every
        client would ship with no selective encryption and no wire
        compression (full-model ciphertexts in raw u32).
        """
        ups = self.per_client_uplink(rnd)
        n_clients = max(1, len(ups))
        measured = sum(ups.values())
        naive = n_clients * ctx.encrypted_bytes(part.n_total, packed=False)
        return {
            "round": rnd,
            "n_clients": n_clients,
            "measured_uplink_bytes": measured,
            "uplink_bytes_per_client": measured // n_clients,
            "naive_all_encrypted_bytes": naive,
            "compression_ratio": naive / max(1, measured),
        }

    def report_rows(self) -> list[dict]:
        """One row per round — benchmarks/run.py table format."""
        return [self.round_summary(r) for r in self.rounds()]
