"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block
(arXiv:2411.15242).

The shared block's weights exist once; it is invoked after every
``shared_attn_every``-th mamba layer on concat(hidden, original embedding)
(the Zamba "global shared attention" pattern).  Each invocation sees
different activations, so serving keeps one KV cache *per invocation*
([n_shared, B, S, KH, hd]).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mamba2
from repro.models import sharding
from repro.models.config import ModelConfig


def n_shared_invocations(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.shared_attn_every


def init_model(cfg: ModelConfig, key):
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    p = L.init_embed(ks[0], cfg)
    p["layers"] = mamba2.init(ks[1], cfg, cfg.n_layers)
    p["shared"] = {
        "ln1": jnp.ones((2 * d,), dt),
        **{k: v[0] for k, v in
           L.init_attn(ks[2], cfg, 1, d_in=2 * d).items()},
        "ln2": jnp.ones((d,), dt),
        **{k: v[0] for k, v in L.init_mlp(ks[3], cfg, 1).items()},
    }
    p["ln_f"] = jnp.ones((d,), dt)
    return p


def _shared_block(ps, h, x0, cfg: ModelConfig, ax, positions,
                  kv_cache=None, pos=None):
    """h: [B, S, d] hidden; x0: [B, S, d] original embeddings.

    Returns (new h, (k, v)) — k/v returned for cache capture at prefill.
    kv_cache: optional (k_cache, v_cache) [B, Smax, KH, hd] for decode.
    """
    xcat = jnp.concatenate([h, x0], axis=-1)
    a = L.rms_norm(xcat, ps["ln1"])
    # qkv on 2d input: stack a fake layer axis for the shared weights
    pstack = {k: v[None] for k, v in ps.items() if k.startswith(("wq", "wk",
                                                                 "wv", "wo"))}
    q, k, v = L.attn_qkv(pstack, 0, a, cfg, ax, positions)
    if kv_cache is None:
        o = L.blocked_attention(q, k, v, cfg, ax, causal=True)
    else:
        kc = jax.lax.dynamic_update_slice_in_dim(kv_cache[0], k, pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(kv_cache[1], v, pos, axis=1)
        o = L.decode_attention(q[:, 0], kc, vc, pos)[:, None]
        k, v = kc, vc
    h = h + L.attn_out(pstack, 0, o, h.dtype)
    m = L.rms_norm(h, ps["ln2"])
    mstack = {k2: v2[None] for k2, v2 in ps.items()
              if k2.startswith("w_")}
    h = h + L.mlp(mstack, 0, m)
    return h, (k, v)


def _is_shared_layer(i: int, cfg: ModelConfig) -> bool:
    return (i + 1) % cfg.shared_attn_every == 0 \
        and (i + 1) // cfg.shared_attn_every <= n_shared_invocations(cfg)


def forward_logits(params, batch, cfg: ModelConfig, ax):
    h = _hidden(params, batch, cfg, ax)
    return L.logits_fn(params, h, cfg), 0.0


def _hidden(params, batch, cfg: ModelConfig, ax):
    dtype = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    x0 = L.embed_tokens(params, tokens, cfg, dtype)
    positions = jnp.arange(tokens.shape[1])
    h = x0
    p = params["layers"]
    mblock = mamba2.block
    sblock = _shared_block
    if cfg.remat:
        mblock = jax.checkpoint(mamba2.block, static_argnums=(1, 3, 4))
        sblock = jax.checkpoint(_shared_block, static_argnums=(3, 4))
    for i in range(cfg.n_layers):
        h = sharding.constrain(h, ax.dp, ax.mp(h.shape[1]), None)
        y, _ = mblock(p, i, h, cfg, ax)
        h = h + y
        if _is_shared_layer(i, cfg):
            h, _ = sblock(params["shared"], h, x0, cfg, ax, positions)
    return L.rms_norm(h, params["ln_f"])


def loss_fn(params, batch, cfg: ModelConfig, ax):
    h = _hidden(params, batch, cfg, ax)
    w = L.unembed_weight(params, cfg).astype(h.dtype)
    return L.chunked_softmax_xent(h, w, batch["labels"], cfg.vocab)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    m = mamba2.init_cache(cfg, batch, dtype)
    ns = n_shared_invocations(cfg)
    shape = (batch, cache_len, cfg.n_kv_heads, cfg.hd)
    m["attn_k"] = [jnp.zeros(shape, dtype) for _ in range(ns)]
    m["attn_v"] = [jnp.zeros(shape, dtype) for _ in range(ns)]
    return m


def abstract_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    return jax.eval_shape(lambda: init_cache(cfg, batch, cache_len, dtype))


def prefill(params, batch, cfg: ModelConfig, ax, cache_len: int | None = None):
    dtype = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    bsz, s = tokens.shape
    cache_len = cache_len or s
    cache = init_cache(cfg, bsz, cache_len, dtype)
    x0 = L.embed_tokens(params, tokens, cfg, dtype)
    positions = jnp.arange(s)
    h = x0
    p = params["layers"]
    si = 0
    for i in range(cfg.n_layers):
        h = sharding.constrain(h, ax.dp, ax.mp(h.shape[1]), None)
        y, h_final = mamba2.block(p, i, h, cfg, ax)
        hn = L.rms_norm(h, p["ln"][i])
        x_in = jnp.einsum("bsd,di->bsi", hn, p["in_x"][i].astype(dtype))
        b_in = jnp.einsum("bsd,dt->bst", hn, p["in_B"][i].astype(dtype))
        c_in = jnp.einsum("bsd,dt->bst", hn, p["in_C"][i].astype(dtype))
        xbc = jnp.concatenate([x_in, b_in, c_in], axis=-1)
        cache["conv"][i] = mamba2._conv_tail(xbc, s, cfg.conv_width)
        cache["ssm"][i] = h_final
        h = h + y
        if _is_shared_layer(i, cfg):
            h, (k, v) = _shared_block(params["shared"], h, x0, cfg, ax,
                                      positions)
            cache["attn_k"][si] = cache["attn_k"][si].at[:, :s].set(k)
            cache["attn_v"][si] = cache["attn_v"][si].at[:, :s].set(v)
            si += 1
    cache["pos"] = jnp.asarray(s, jnp.int32)
    h = L.rms_norm(h, params["ln_f"])
    logits = L.logits_fn(params, h[:, -1:], cfg)[:, 0]
    return logits, cache


def decode_step(params, cache, batch, cfg: ModelConfig, ax):
    dtype = jnp.dtype(cfg.dtype)
    cache = {"conv": list(cache["conv"]), "ssm": list(cache["ssm"]),
             "attn_k": list(cache["attn_k"]),
             "attn_v": list(cache["attn_v"]), "pos": cache["pos"]}
    pos = cache["pos"]
    tok = batch["tokens"]
    x0 = L.embed_tokens(params, tok[:, None], cfg, dtype)     # [B, 1, d]
    h = x0[:, 0]
    p = params["layers"]
    si = 0
    for i in range(cfg.n_layers):
        y, conv_s, ssm_s = mamba2.block_decode(
            p, i, h, cache["conv"][i], cache["ssm"][i], cfg, ax)
        cache["conv"][i] = conv_s
        cache["ssm"][i] = ssm_s
        h = h + y
        if _is_shared_layer(i, cfg):
            h2, (kc, vc) = _shared_block(
                params["shared"], h[:, None], x0, cfg, ax, pos[None],
                kv_cache=(cache["attn_k"][si], cache["attn_v"][si]), pos=pos)
            cache["attn_k"][si] = kc
            cache["attn_v"][si] = vc
            h = h2[:, 0]
            si += 1
    cache["pos"] = pos + 1
    h = L.rms_norm(h, params["ln_f"])
    logits = L.logits_fn(params, h[:, None], cfg)[:, 0]
    return logits, cache
