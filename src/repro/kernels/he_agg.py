"""Pallas TPU kernel: fused encrypted FedAvg aggregation, limb-fused.

The server hot loop of the paper is  sum_i alpha_i * [[W_i]]  over client
ciphertexts.  Library implementations (PALISADE/TenSEAL wrappers) materialize
each weighted ciphertext in memory before the add; at HE's low arithmetic
intensity that doubles HBM traffic.  This kernel fuses weight-multiply +
modular accumulate: each ciphertext element is read exactly once, the
accumulator lives in VMEM.

Layout: cts u32[n_clients, B, L, N] (normal form, NTT domain), w_mont
u32[n_clients, L] Montgomery-form scalar weights (round(alpha_i * delta) * R
mod q_l).  The grid is (L, ceil(B / block_b)): the RNS limb is a grid
coordinate, its constants come from u32[L] VMEM tables, and one `pallas_call`
covers every limb — kernel count is independent of limb depth.  The client
loop is unrolled inside the kernel.

VMEM: n_clients * block_b * N * 4B; for 16 clients, block_b=4, N=8192 ->
2 MiB in + 128 KiB out.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref as _ref
from repro.kernels import tune as _tune


def _agg_body(cts_ref, w_ref, q_ref, qinv_ref, o_ref, *, n_clients: int):
    q = q_ref[0]
    qinv_neg = qinv_ref[0]
    w = w_ref[:, 0]
    c0 = cts_ref[0, :, 0, :]
    acc = _ref.mont_mul(c0, jnp.broadcast_to(w[0], c0.shape), q, qinv_neg)
    for i in range(1, n_clients):
        ci = cts_ref[i, :, 0, :]
        term = _ref.mont_mul(ci, jnp.broadcast_to(w[i], ci.shape), q,
                             qinv_neg)
        acc = _ref.mod_add(acc, term, q)
    o_ref[:, 0, :] = acc


@functools.lru_cache(maxsize=128)
def _build(n_clients: int, l: int, n: int, block_b: int, interpret: bool):
    body = functools.partial(_agg_body, n_clients=n_clients)
    tile = pl.BlockSpec((block_b, 1, n), lambda li, bi: (bi, li, 0))
    scalar = pl.BlockSpec((1,), lambda li, bi: (li,))

    def call(cts, w_mont, qs, qinv_negs):
        b = cts.shape[1]
        return pl.pallas_call(
            body,
            grid=(l, pl.cdiv(b, block_b)),
            in_specs=[
                pl.BlockSpec((n_clients, block_b, 1, n),
                             lambda li, bi: (0, bi, li, 0)),
                pl.BlockSpec((n_clients, 1), lambda li, bi: (0, li)),
                scalar, scalar,
            ],
            out_specs=tile,
            out_shape=jax.ShapeDtypeStruct((b, l, n), jnp.uint32),
            interpret=interpret,
        )(cts, w_mont, qs, qinv_negs)

    return call


def he_weighted_sum_fused(cts, w_mont, qs, qinv_negs, *,
                          block_b: int | None = None, interpret: bool = True):
    """sum_i w_i (*) ct_i mod q_l, all limbs in one pallas_call.

    cts: u32[C, ..., L, N]; w_mont: u32[C, L]; qs, qinv_negs: u32[L].
    block_b=None takes the shared default from tune.DEFAULT_BLOCK (4 here:
    the unrolled client loop holds n_clients tiles in VMEM at once, so the
    batch tile stays smaller than the single-input kernels')."""
    if block_b is None:
        block_b = _tune.default_block("weighted_sum")
    c = cts.shape[0]
    l, n = cts.shape[-2], cts.shape[-1]
    batch = cts.shape[1:-2]
    cts2 = cts.reshape((c, -1, l, n))
    b = cts2.shape[1]
    call = _build(c, l, n, min(block_b, b), interpret)
    return call(cts2, w_mont, qs, qinv_negs).reshape(batch + (l, n))


# ---------------------------------------------------------------------------
# streaming variant: one client at a time into a running accumulator
# ---------------------------------------------------------------------------
#
# The batch kernel above needs all n_clients ciphertexts resident to fuse the
# client loop; at production scale ("millions of users") the server cannot
# materialize them.  The streaming kernel processes each arriving ciphertext
# as  acc' = acc + w (*) ct  — same fused multiply-accumulate, identical
# modular arithmetic (so the result is bit-for-bit equal to the batch path
# applied in arrival order), but server memory stays at one accumulator plus
# one in-flight ciphertext regardless of client count.


def _accum_body(ct_ref, acc_ref, w_ref, q_ref, qinv_ref, o_ref):
    q = q_ref[0]
    qinv_neg = qinv_ref[0]
    ct = ct_ref[:, 0, :]
    term = _ref.mont_mul(ct, jnp.broadcast_to(w_ref[0], ct.shape), q,
                         qinv_neg)
    o_ref[:, 0, :] = _ref.mod_add(acc_ref[:, 0, :], term, q)


@functools.lru_cache(maxsize=128)
def _build_accum(l: int, n: int, block_b: int, interpret: bool):
    tile = pl.BlockSpec((block_b, 1, n), lambda li, bi: (bi, li, 0))
    scalar = pl.BlockSpec((1,), lambda li, bi: (li,))

    def call(ct, acc, w_mont, qs, qinv_negs):
        b = ct.shape[0]
        return pl.pallas_call(
            _accum_body,
            grid=(l, pl.cdiv(b, block_b)),
            in_specs=[tile, tile, scalar, scalar, scalar],
            out_specs=tile,
            out_shape=jax.ShapeDtypeStruct((b, l, n), jnp.uint32),
            interpret=interpret,
        )(ct, acc, w_mont, qs, qinv_negs)

    return call


def he_weighted_accum_fused(acc, ct, w_mont, qs, qinv_negs, *,
                            block_b: int | None = None,
                            interpret: bool = True):
    """acc + w (*) ct mod q_l, all limbs in one pallas_call.

    acc, ct: u32[..., L, N]; w_mont: u32[L] per-limb Montgomery weight."""
    if block_b is None:
        block_b = _tune.default_block("weighted_accum")
    l, n = ct.shape[-2], ct.shape[-1]
    batch = ct.shape[:-2]
    ct2 = ct.reshape((-1, l, n))
    acc2 = jnp.broadcast_to(acc, ct.shape).reshape((-1, l, n))
    b = ct2.shape[0]
    call = _build_accum(l, n, min(block_b, b), interpret)
    return call(ct2, acc2, w_mont, qs, qinv_negs).reshape(batch + (l, n))


# ---------------------------------------------------------------------------
# chunk-batched variant: the whole ready-chunk buffer in ONE launch
# ---------------------------------------------------------------------------
#
# The per-chunk accumulate above still costs one kernel launch per arriving
# ciphertext chunk — at n_chunks per update that makes the server's flush
# latency launch-bound, not bandwidth-bound.  This kernel folds a whole
# batch of ready chunks at once:  acc[k] += w[k] (*) ct[k]  with a PER-ROW
# weight table u32[K, L] (rows of one flush may belong to different
# clients), grid (L, ceil(K / block_k)).  The modular arithmetic per
# (row, limb, coefficient) is identical to the per-chunk kernel, so a
# flush stays bit-for-bit equal to folding its rows one at a time.


def _accum_chunks_body(ct_ref, acc_ref, w_ref, q_ref, qinv_ref, o_ref):
    q = q_ref[0]
    qinv_neg = qinv_ref[0]
    ct = ct_ref[:, 0, :]                       # [block_k, M]
    w = w_ref[:, 0][:, None]                   # [block_k, 1] per-row weight
    term = _ref.mont_mul(ct, jnp.broadcast_to(w, ct.shape), q, qinv_neg)
    o_ref[:, 0, :] = _ref.mod_add(acc_ref[:, 0, :], term, q)


@functools.lru_cache(maxsize=128)
def _build_accum_chunks(l: int, m: int, block_k: int, interpret: bool):
    tile = pl.BlockSpec((block_k, 1, m), lambda li, ki: (ki, li, 0))
    wspec = pl.BlockSpec((block_k, 1), lambda li, ki: (ki, li))
    scalar = pl.BlockSpec((1,), lambda li, ki: (li,))

    def call(ct, acc, w_mont, qs, qinv_negs):
        k = ct.shape[0]
        return pl.pallas_call(
            _accum_chunks_body,
            grid=(l, pl.cdiv(k, block_k)),
            in_specs=[tile, tile, wspec, scalar, scalar],
            out_specs=tile,
            out_shape=jax.ShapeDtypeStruct((k, l, m), jnp.uint32),
            interpret=interpret,
        )(ct, acc, w_mont, qs, qinv_negs)

    return call


def he_weighted_accum_chunks_fused(acc, cts, w_mont, qs, qinv_negs, *,
                                   block_k: int | None = None,
                                   interpret: bool = True):
    """acc[k] + w[k] (*) ct[k] mod q_l for every row k, one pallas_call.

    acc, cts: u32[K, ..., L, N]; w_mont: u32[K, L] per-row Montgomery
    weights broadcast over the middle (...) dims; qs, qinv_negs: u32[L].
    """
    if block_k is None:
        block_k = _tune.default_block("weighted_accum_chunks")
    k, l, n = cts.shape[0], cts.shape[-2], cts.shape[-1]
    mid = cts.shape[1:-2]
    # [K, ..., L, N] -> [K, L, ..., N] -> [K, L, M]: every row owns a
    # contiguous M-wide stripe per limb, so the per-row weight is constant
    # within a tile row.
    ct2 = jnp.moveaxis(cts, -2, 1).reshape((k, l, -1))
    acc2 = jnp.moveaxis(jnp.broadcast_to(acc, cts.shape), -2, 1) \
        .reshape((k, l, -1))
    m = ct2.shape[-1]
    call = _build_accum_chunks(l, m, min(block_k, k), interpret)
    out = call(ct2, acc2, w_mont, qs, qinv_negs)
    return jnp.moveaxis(out.reshape((k, l) + mid + (n,)), 1, -2)
