"""End-to-end training driver.

On CPU this runs reduced (--smoke) configs for real; the full configs are
exercised via dryrun.py.  Includes checkpoint/restart fault tolerance: kill
the process mid-run and re-launch — it resumes from the last checkpoint.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
      --steps 50 --batch 8 --seq 32 --ckpt-dir /tmp/run1
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.ckpt import CheckpointManager
from repro.data import SyntheticLM, dirichlet_partition
from repro.launch import steps
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.models.sharding import axis_env_from_mesh
from repro.optim import AdamWConfig, adamw_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh()
    with jax.sharding.set_mesh(mesh):
        ax = axis_env_from_mesh(mesh)
        model = build_model(cfg, ax)
        params = model.init(jax.random.PRNGKey(0))
        opt_state = adamw_init(params)
        step_fn = jax.jit(steps.make_train_step(
            model, AdamWConfig(lr=args.lr), warmup=10,
            total_steps=args.steps), donate_argnums=(0, 1))

        start = 0
        mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
        if mgr:
            tree, s, _ = mgr.restore({"p": params, "o": opt_state})
            if tree is not None:
                params = jax.tree_util.tree_map(jnp.asarray, tree["p"])
                opt_state = jax.tree_util.tree_map(jnp.asarray, tree["o"])
                start = s + 1
                print(f"resumed from step {s}")

        prior = dirichlet_partition(1, cfg.vocab, alpha=100.0)[0]
        stream = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                             batch_size=args.batch, client_prior=prior)
        t0 = time.time()
        for step in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in
                     stream.next_batch().items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                tok_s = args.batch * args.seq * (step - start + 1) \
                    / max(1e-9, time.time() - t0)
                print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e} tok/s={tok_s:.0f}")
            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.save(step, {"p": params, "o": opt_state})
        if mgr:
            mgr.save(args.steps - 1, {"p": params, "o": opt_state})
    print("done")


if __name__ == "__main__":
    main()
