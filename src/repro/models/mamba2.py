"""Mamba-2 (SSD, state-space duality — arXiv:2405.21060) in pure JAX.

Training/prefill use the chunked SSD algorithm: intra-chunk quadratic term
(einsums) + inter-chunk linear recurrence run as jax.lax.associative_scan
over the chunk axis (log-depth, fully materialized ops — exact
cost_analysis accounting, unlike a sequential lax.scan whose body XLA
counts once).  Decode is the O(1) recurrent update.

TPU adaptation: projections are *separate* weights (z/x/B/C/dt) so each
output dim is independently TP-shardable without cross-shard slicing; SSD
head dim (nh) is the 'model'-sharded axis.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import sharding
from repro.models.config import ModelConfig


def init(key, cfg: ModelConfig, n_layers: int):
    dt = jnp.dtype(cfg.param_dtype)
    d, din, st, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    g = cfg.ssm_groups
    w = cfg.conv_width
    ks = jax.random.split(key, 9)
    # dt bias so softplus(dt) spans ~[1e-3, 1e-1] at init (mamba2 default)
    dt_init = jnp.log(jnp.expm1(jnp.exp(
        jax.random.uniform(ks[7], (n_layers, nh),
                           minval=math.log(1e-3), maxval=math.log(1e-1)))))
    return {
        "in_z": L.trunc_normal(ks[0], (n_layers, d, din), 0.02, dt),
        "in_x": L.trunc_normal(ks[1], (n_layers, d, din), 0.02, dt),
        "in_B": L.trunc_normal(ks[2], (n_layers, d, g * st), 0.02, dt),
        "in_C": L.trunc_normal(ks[3], (n_layers, d, g * st), 0.02, dt),
        "in_dt": L.trunc_normal(ks[4], (n_layers, d, nh), 0.02, dt),
        "conv_x": L.trunc_normal(ks[5], (n_layers, w, din), 0.2, dt),
        "conv_B": L.trunc_normal(ks[6], (n_layers, w, g * st), 0.2, dt),
        "conv_C": L.trunc_normal(ks[8], (n_layers, w, g * st), 0.2, dt),
        "A_log": jnp.broadcast_to(
            jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32))[None],
            (n_layers, nh)).astype(dt),
        "D": jnp.ones((n_layers, nh), dt),
        "dt_bias": dt_init.astype(dt),
        "norm": jnp.ones((n_layers, din), dt),
        "ln": jnp.ones((n_layers, d), dt),     # pre-norm
        "out_proj": L.trunc_normal(
            ks[7], (n_layers, din, d), 0.02 / math.sqrt(2 * n_layers), dt),
    }


def causal_conv(x, kernel):
    """Depthwise causal conv. x: [B, S, ch], kernel: [w, ch]."""
    w = kernel.shape[0]
    pad = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    s = x.shape[1]
    out = sum(pad[:, j:j + s] * kernel[j].astype(x.dtype) for j in range(w))
    return jax.nn.silu(out)


def _gated_norm(y, scale, z):
    return L.rms_norm(y * jax.nn.silu(z), scale)


# ---------------------------------------------------------------------------
# chunked SSD
# ---------------------------------------------------------------------------


def ssd_chunked(x, dtv, a, b, c, chunk: int, h0=None):
    """SSD over a full sequence.

    x:   [B, S, nh, hd]   (conv'd, activated)
    dtv: [B, S, nh]       (softplus'd timestep)
    a:   [nh]             (negative decay rates)
    b,c: [B, S, st]       (single group, broadcast over heads)
    h0:  optional initial state [B, nh, hd, st]
    Returns (y [B, S, nh, hd], h_final [B, nh, hd, st]).
    """
    bsz, s, nh, hd = x.shape
    st = b.shape[-1]
    q = min(chunk, s)
    n = s // q
    assert n * q == s, (s, q)
    f32 = jnp.float32
    xc = x.reshape(bsz, n, q, nh, hd)
    dtc = dtv.reshape(bsz, n, q, nh).astype(f32)
    bc = b.reshape(bsz, n, q, st).astype(f32)
    cc = c.reshape(bsz, n, q, st).astype(f32)
    da = dtc * a.astype(f32)                         # [B, n, q, nh]
    cum = jnp.cumsum(da, axis=2)                     # within-chunk cumulative
    # intra-chunk: Y[q'] = sum_{s'<=q'} C_q'.B_s' exp(cum_q'-cum_s') dt_s' x_s'
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [B,n,q,q,nh]
    causal = jnp.tril(jnp.ones((q, q), bool))
    # mask BEFORE exp: masked entries are positive and would overflow to inf,
    # poisoning gradients through the where.
    seg = jnp.where(causal[None, None, :, :, None], seg, -jnp.inf)
    decay = jnp.exp(seg)
    cb = jnp.einsum("bnqt,bnst->bnqs", cc, bc)       # [B,n,q,q]
    m = cb[..., None] * decay                        # [B,n,q,q,nh]
    xdt = xc.astype(f32) * dtc[..., None]            # [B,n,q,nh,hd]
    y_intra = jnp.einsum("bnqsh,bnshd->bnqhd", m, xdt)
    # chunk states: S_n = sum_q exp(cum_end - cum_q) dt_q B_q (x) x_q
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)     # [B,n,q,nh]
    states = jnp.einsum("bnqh,bnqt,bnqhd->bnhdt", decay_out, bc, xdt)
    # inter-chunk recurrence H_n = a_n H_{n-1} + S_n via associative scan
    a_chunk = jnp.exp(cum[:, :, -1, :])              # [B,n,nh]
    if h0 is not None:
        states = states.at[:, 0].add(
            a_chunk[:, 0][..., None, None] * h0.astype(f32))

    def op(lhs, rhs):
        al, sl = lhs
        ar, sr = rhs
        return al * ar, ar[..., None, None] * sl + sr

    a_scan, h_incl = jax.lax.associative_scan(
        op, (a_chunk, states), axis=1)
    h_before = jnp.concatenate(
        [jnp.zeros_like(h_incl[:, :1]), h_incl[:, :-1]], axis=1)
    # inter-chunk contribution: Y[q] = C_q exp(cum_q) . H_before
    y_inter = jnp.einsum("bnqt,bnhdt,bnqh->bnqhd",
                         cc, h_before, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(bsz, s, nh, hd).astype(x.dtype)
    return y, h_incl[:, -1].astype(x.dtype)


# ---------------------------------------------------------------------------
# block forward / decode
# ---------------------------------------------------------------------------


def block(p, i, u, cfg: ModelConfig, ax):
    """Full-sequence mamba2 block. u: [B, S, d] -> (y [B, S, d], state).

    Sharding discipline (prevents SPMD ping-pong between batch/chunk and
    head layouts — each reshard is an 'involuntary full remat' copy):
    one seq all-gather at entry; z/x/dt inherit the 'model' shard from
    their projection out-dims (din/nh); B/C are head-shared and stay
    replicated over 'model'; everything in ssd_chunked is then local.
    """
    dtp = u.dtype
    u = sharding.constrain(u, ax.dp, None, None)    # single AG from SP shard
    u = L.rms_norm(u, p["ln"][i])
    z = jnp.einsum("bsd,di->bsi", u, p["in_z"][i].astype(dtp))
    x = jnp.einsum("bsd,di->bsi", u, p["in_x"][i].astype(dtp))
    b_ = jnp.einsum("bsd,dt->bst", u, p["in_B"][i].astype(dtp))
    c_ = jnp.einsum("bsd,dt->bst", u, p["in_C"][i].astype(dtp))
    dt_raw = jnp.einsum("bsd,dh->bsh", u, p["in_dt"][i].astype(dtp))
    b_ = sharding.constrain(b_, ax.dp, None, None)
    c_ = sharding.constrain(c_, ax.dp, None, None)
    dt_raw = sharding.constrain(dt_raw, ax.dp, None,
                                ax.mp(cfg.ssm_heads))
    x = causal_conv(x, p["conv_x"][i])
    b_ = causal_conv(b_, p["conv_B"][i])
    c_ = causal_conv(c_, p["conv_C"][i])
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32)
                          + p["dt_bias"][i].astype(jnp.float32))
    a = -jnp.exp(p["A_log"][i].astype(jnp.float32))
    bsz, s, din = x.shape
    nh, hd = cfg.ssm_heads, cfg.ssm_head_dim
    # pad S to a chunk multiple; padded steps use dt=0 (decay 1, zero input)
    # so they neither contribute nor disturb the final state.
    pad = (-s) % min(cfg.ssm_chunk, max(s, 1))
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dtv = jnp.pad(dtv, ((0, 0), (0, pad), (0, 0)))
        b_ = jnp.pad(b_, ((0, 0), (0, pad), (0, 0)))
        c_ = jnp.pad(c_, ((0, 0), (0, pad), (0, 0)))
    xh = x.reshape(bsz, s + pad, nh, hd)
    xh = sharding.constrain(xh, ax.dp, None, ax.mp(nh), None)
    y, h_final = ssd_chunked(xh, dtv, a, b_, c_, cfg.ssm_chunk)
    if pad:
        y = y[:, :s]
        xh = xh[:, :s]
    y = y + p["D"][i].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(bsz, s, din)
    y = _gated_norm(y, p["norm"][i], z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"][i].astype(dtp))
    return out, h_final


def block_decode(p, i, u, conv_state, ssm_state, cfg: ModelConfig, ax):
    """Single-token recurrent update.

    u: [B, d]; conv_state: [B, w-1, din + 2*g*st]; ssm_state: [B, nh, hd, st].
    Returns (y [B, d], conv_state, ssm_state).
    """
    dtp = u.dtype
    din, st, g = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups
    nh, hd = cfg.ssm_heads, cfg.ssm_head_dim
    w = cfg.conv_width
    u = L.rms_norm(u, p["ln"][i])
    z = u @ p["in_z"][i].astype(dtp)
    x = u @ p["in_x"][i].astype(dtp)
    b_ = u @ p["in_B"][i].astype(dtp)
    c_ = u @ p["in_C"][i].astype(dtp)
    dt_raw = u @ p["in_dt"][i].astype(dtp)
    xbc = jnp.concatenate([x, b_, c_], axis=-1)           # [B, din+2gst]
    window = jnp.concatenate([conv_state, xbc[:, None]], axis=1)  # [B, w, ch]
    kernel = jnp.concatenate(
        [p["conv_x"][i], p["conv_B"][i], p["conv_C"][i]], axis=-1)
    conv_out = jax.nn.silu(
        jnp.sum(window * kernel.astype(dtp)[None], axis=1))
    x = conv_out[:, :din]
    b_ = conv_out[:, din:din + g * st]
    c_ = conv_out[:, din + g * st:]
    new_conv_state = window[:, 1:]
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32)
                          + p["dt_bias"][i].astype(jnp.float32))  # [B, nh]
    a = -jnp.exp(p["A_log"][i].astype(jnp.float32))
    da = jnp.exp(dtv * a)                                 # [B, nh]
    xh = x.reshape(-1, nh, hd).astype(jnp.float32)
    ssm_state = ssm_state.astype(jnp.float32) * da[..., None, None] \
        + jnp.einsum("bh,bt,bhd->bhdt", dtv, b_.astype(jnp.float32), xh)
    y = jnp.einsum("bhdt,bt->bhd", ssm_state, c_.astype(jnp.float32))
    y = y + p["D"][i].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(-1, din).astype(dtp)
    y = _gated_norm(y, p["norm"][i], z)
    out = y @ p["out_proj"][i].astype(dtp)
    return out, new_conv_state, ssm_state.astype(dtp)


# ---------------------------------------------------------------------------
# full model (ssm family)
# ---------------------------------------------------------------------------


def init_model(cfg: ModelConfig, key):
    k1, k2 = jax.random.split(key)
    p = L.init_embed(k1, cfg)
    p["layers"] = init(k2, cfg, cfg.n_layers)
    p["ln_f"] = jnp.ones((cfg.d_model,), jnp.dtype(cfg.param_dtype))
    return p


def _backbone(params, x, cfg: ModelConfig, ax):
    p = params["layers"]
    step = block
    if cfg.remat:
        step = jax.checkpoint(block, static_argnums=(1, 3, 4))
    for i in range(cfg.n_layers):
        x = sharding.constrain(x, ax.dp, ax.mp(x.shape[1]), None)
        y, _ = step(p, i, x, cfg, ax)
        x = x + y
    return L.rms_norm(x, params["ln_f"])


def forward_logits(params, batch, cfg: ModelConfig, ax):
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed_tokens(params, batch["tokens"], cfg, dtype)
    h = _backbone(params, x, cfg, ax)
    return L.logits_fn(params, h, cfg), 0.0


def loss_fn(params, batch, cfg: ModelConfig, ax):
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed_tokens(params, batch["tokens"], cfg, dtype)
    h = _backbone(params, x, cfg, ax)
    w = L.unembed_weight(params, cfg).astype(h.dtype)
    return L.chunked_softmax_xent(h, w, batch["labels"], cfg.vocab)


def init_cache(cfg: ModelConfig, batch: int, dtype):
    """Per-layer buffer lists (see transformer.init_cache)."""
    ch = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "conv": [jnp.zeros((batch, cfg.conv_width - 1, ch), dtype)
                 for _ in range(cfg.n_layers)],
        "ssm": [jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                           cfg.ssm_state), dtype)
                for _ in range(cfg.n_layers)],
        "pos": jnp.zeros((), jnp.int32),
    }


def abstract_cache(cfg: ModelConfig, batch: int, dtype):
    return jax.eval_shape(lambda: init_cache(cfg, batch, dtype))


def _conv_tail(xbc, s: int, w: int):
    """Last (w-1) conv inputs, zero-padded on the left for short prompts
    (matches the causal conv's zero padding)."""
    tail = xbc[:, max(0, s - w + 1):]
    short = (w - 1) - tail.shape[1]
    if short > 0:
        tail = jnp.pad(tail, ((0, 0), (short, 0), (0, 0)))
    return tail


def prefill(params, batch, cfg: ModelConfig, ax, cache_len=None):
    """Prompt pass; returns (last-token logits, recurrent cache)."""
    dtype = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    bsz, s = tokens.shape
    x = L.embed_tokens(params, tokens, cfg, dtype)
    cache = init_cache(cfg, bsz, dtype)
    p = params["layers"]
    for i in range(cfg.n_layers):
        x = sharding.constrain(x, ax.dp, ax.mp(x.shape[1]), None)
        y, h_final = block(p, i, x, cfg, ax)
        # conv state = last (w-1) pre-conv channel inputs (post-pre-norm)
        xn = L.rms_norm(x, p["ln"][i])
        x_in = jnp.einsum("bsd,di->bsi", xn, p["in_x"][i].astype(dtype))
        b_in = jnp.einsum("bsd,dt->bst", xn, p["in_B"][i].astype(dtype))
        c_in = jnp.einsum("bsd,dt->bst", xn, p["in_C"][i].astype(dtype))
        xbc = jnp.concatenate([x_in, b_in, c_in], axis=-1)
        cache["conv"][i] = _conv_tail(xbc, s, cfg.conv_width)
        cache["ssm"][i] = h_final
        x = x + y
    cache["pos"] = jnp.asarray(s, jnp.int32)
    h = L.rms_norm(x, params["ln_f"])
    logits = L.logits_fn(params, h[:, -1:], cfg)[:, 0]
    return logits, cache


def decode_step(params, cache, batch, cfg: ModelConfig, ax):
    dtype = jnp.dtype(cfg.dtype)
    cache = {"conv": list(cache["conv"]), "ssm": list(cache["ssm"]),
             "pos": cache["pos"]}
    tok = batch["tokens"]
    x = L.embed_tokens(params, tok[:, None], cfg, dtype)[:, 0]   # [B, d]
    p = params["layers"]
    for i in range(cfg.n_layers):
        y, conv_s, ssm_s = block_decode(
            p, i, x, cache["conv"][i], cache["ssm"][i], cfg, ax)
        cache["conv"][i] = conv_s
        cache["ssm"][i] = ssm_s
        x = x + y
    cache["pos"] = cache["pos"] + 1
    h = L.rms_norm(x, params["ln_f"])
    logits = L.logits_fn(params, h[:, None], cfg)[:, 0]
    return logits, cache
