"""Synthetic data pipeline: deterministic LM token streams + federated
non-IID (Dirichlet) partitioning.

Each client gets a seeded generator over its own token distribution so FL
runs are reproducible and clients are genuinely heterogeneous (the paper's
sensitivity-map aggregation exists precisely because client data differ).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    """Markov-ish synthetic LM stream: each client mixes a shared bigram
    table with a client-specific unigram prior."""

    vocab: int
    seq_len: int
    batch_size: int
    client_prior: np.ndarray        # [vocab] probability
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.RandomState(self.seed)
        base = np.random.RandomState(1234)
        self._shift = base.randint(1, self.vocab)

    def next_batch(self) -> dict:
        b, s, v = self.batch_size, self.seq_len, self.vocab
        first = self._rng.choice(v, size=(b, 1), p=self.client_prior)
        noise = self._rng.randint(0, v, size=(b, s))
        toks = np.empty((b, s), dtype=np.int64)
        toks[:, :1] = first
        for t in range(1, s):
            # deterministic bigram + 10% client-prior noise
            nxt = (toks[:, t - 1] * 31 + self._shift) % v
            use_noise = self._rng.rand(b) < 0.1
            toks[:, t] = np.where(use_noise, noise[:, t], nxt)
        labels = np.concatenate([toks[:, 1:], toks[:, :1]], axis=1)
        return {"tokens": toks.astype(np.int32),
                "labels": labels.astype(np.int32)}


def dirichlet_partition(n_clients: int, vocab: int, alpha: float = 0.5,
                        seed: int = 0) -> list[np.ndarray]:
    """Client-specific unigram priors ~ Dirichlet(alpha) (non-IID)."""
    rng = np.random.RandomState(seed)
    priors = rng.dirichlet([alpha] * vocab, size=n_clients)
    return [p / p.sum() for p in priors]


def make_client_streams(n_clients: int, vocab: int, seq_len: int,
                        batch_size: int, alpha: float = 0.5,
                        seed: int = 0) -> list[SyntheticLM]:
    priors = dirichlet_partition(n_clients, vocab, alpha, seed)
    return [SyntheticLM(vocab=vocab, seq_len=seq_len, batch_size=batch_size,
                        client_prior=priors[i], seed=seed * 1000 + i)
            for i in range(n_clients)]
