"""Recompute derived roofline quantities from saved .hlo.gz files without
recompiling (estimator iteration tool).

  PYTHONPATH=src python -m benchmarks.refresh
"""
from __future__ import annotations

import gzip
import json
import os

from benchmarks import roofline as rf

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def main():
    n = 0
    for fn in sorted(os.listdir(ART)):
        if not fn.endswith(".json"):
            continue
        path = os.path.join(ART, fn)
        hlo = path.replace(".json", ".hlo.gz")
        if not os.path.exists(hlo):
            continue
        art = json.load(open(path))
        with gzip.open(hlo, "rt") as f:
            txt = f.read()
        colls = rf.parse_collectives(txt)
        fused = rf.parse_memory_traffic(txt)
        r = art["roofline"]
        fused = min(fused, r["bytes_accessed"]) if r["bytes_accessed"] else fused
        r["fused_bytes"] = fused
        r["memory_s"] = fused / rf.HBM_BW
        r["memory_upper_s"] = r["bytes_accessed"] / rf.HBM_BW
        r["wire_bytes"] = colls.wire_bytes
        r["collective_s"] = colls.wire_bytes / rf.ICI_BW
        terms = {"compute": r["compute_s"], "memory": r["memory_s"],
                 "collective": r["collective_s"]}
        r["dominant"] = max(terms, key=terms.get)
        r["step_s"] = max(terms.values())
        ideal = r["model_flops"] / rf.PEAK_FLOPS
        r["roofline_fraction"] = ideal / r["step_s"] if r["step_s"] else 0.0
        art["collectives"] = {"counts": colls.counts,
                              "by_op_bytes": colls.by_op}
        json.dump(art, open(path, "w"), indent=1)
        n += 1
    print(f"refreshed {n} artifacts from saved HLO")


if __name__ == "__main__":
    main()
