"""Pallas TPU kernel: per-limb modular lift of raw u32 rows.

`mod_lift`: out[b, l, n] = x[b, n] mod q_l — the keystream-expansion step
of the transcipher uplink (DESIGN.md §15): the server receives stream-
cipher-masked coefficients as full-range u32 words (no limb axis — the
client never touched RNS) and lifts each row into per-limb residues before
the forward NTT.  One launch covers the whole u32[B, N] -> u32[B, L, N]
expansion; the input tile is re-read once per limb grid step, which is the
point — the lift is the only op whose OUTPUT traffic (L x the input)
dominates, so the tile shape mirrors pointwise.py's and the limb index
only picks the modulus.

The grid is (L, ceil(B / block_b)); per-limb moduli come from the same
u32[L] LimbTables plumbing as every other kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import tune as _tune


def _mod_lift_body(x_ref, q_ref, o_ref):
    # full-range u32 % u32 — unlike the Montgomery ops there is no < 2**30
    # precondition here: masked words span [1, 2**32 - 2] by construction
    # (core/ckks/transcipher.py's pad window), and lax.rem on uint32 is
    # exact for the whole range.
    o_ref[:, 0, :] = x_ref[...] % q_ref[0]


@functools.lru_cache(maxsize=128)
def _build(l: int, n: int, block_b: int, interpret: bool):
    x_tile = pl.BlockSpec((block_b, n), lambda li, bi: (bi, 0))
    o_tile = pl.BlockSpec((block_b, 1, n), lambda li, bi: (bi, li, 0))
    scalar = pl.BlockSpec((1,), lambda li, bi: (li,))

    def call(x, qs):
        b = x.shape[0]
        return pl.pallas_call(
            _mod_lift_body,
            grid=(l, pl.cdiv(b, block_b)),
            in_specs=[x_tile, scalar],
            out_specs=o_tile,
            out_shape=jax.ShapeDtypeStruct((b, l, n), jnp.uint32),
            interpret=interpret,
        )(x, qs)

    return call


def mod_lift_fused(x, qs, *, block_b: int | None = None,
                   interpret: bool = True):
    """out[..., l, :] = x[..., :] mod q_l, all limbs in one pallas_call.

    x: u32[..., N] full-range words; qs: u32[L].  block_b=None takes the
    shared default from tune.DEFAULT_BLOCK."""
    if block_b is None:
        block_b = _tune.default_block("mod_lift")
    n = x.shape[-1]
    batch = x.shape[:-1]
    x2 = jnp.asarray(x, dtype=jnp.uint32).reshape((-1, n))
    b = x2.shape[0]
    l = qs.shape[0]
    call = _build(l, n, min(block_b, b), interpret)
    return call(x2, qs).reshape(batch + (l, n))
