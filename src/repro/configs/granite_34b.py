"""granite-34b [dense] — llama architecture (MQA kv=1), code model.
Source: arXiv:2405.04324 (hf tier).
88L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, d_ff=24576,
    vocab=49152,
    mlp_gated=False,
    dtype="bfloat16", param_dtype="float32", remat=True,
)

SMOKE = ModelConfig(
    name="granite-34b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=1, d_ff=192,
    vocab=257, attn_chunk=16,
)
