"""Async encrypted aggregation service: the round state machine.

One `AggregationService` owns a sequence of FL rounds, each a small state
machine (DESIGN.md §14.1):

    OPEN ──seal──▶ SEALED ──▶ FOLDING ──▶ DONE
      │                          │
      └──deadline below quorum───┴──rejects below quorum──▶ FAILED

* **OPEN** — `submit()` accepts client update blobs: late (past the
  quorum deadline), duplicate-cid, and headerless submissions are
  rejected at the door; everything else is spooled (to disk when
  checkpointing is on) and acknowledged.  At most one round is OPEN at a
  time, but an OPEN round r+1 coexists with a FOLDING round r — that is
  the async overlap: accepting the next round's traffic never waits for
  the previous round's HE folds.
* **SEALED** — the quorum policy froze the accepted set (target reached
  or deadline passed with quorum met) and the FedAvg weights were
  normalized over it.
* **FOLDING** — `step()` drives the accepted blobs through ONE
  `wire.stream.StreamIngest` in arrival order, `fold_batch` updates per
  call.  A blob that fails wire validation here is dropped ATOMICALLY
  (StreamIngest's per-update rollback — nothing of it reaches the
  accumulator) and marked bad; when the pass ends with new bad blobs the
  round REFOLDS once from scratch with the weights renormalized over the
  survivors, so the final aggregate is bit-identical to a clean
  synchronous run over exactly the surviving clients.
* **DONE / FAILED** — `result()` returns the aggregated ProtectedUpdate;
  a round whose survivors dropped below `min_clients` fails instead of
  finalizing a below-quorum aggregate.

Crash consistency (DESIGN.md §14.3): every transition checkpoints the
FULL service state — accumulators (exact u32 residues + literal f32
plain partial sums), budget ledger, and round bookkeeping — through
`ckpt/store.py`'s atomic rename, and only THEN crosses the fault
injector's crash point.  `AggregationService.resume()` rebuilds the
service from the latest checkpoint and continues bit-exactly; a client
whose ack was lost in the crash simply resubmits and is deduplicated.
"""
from __future__ import annotations

import dataclasses
import itertools
import os
import threading
import time
from collections import Counter as _Counter

import numpy as np

from repro import obs
from repro.ckpt import store as ckpt_store
from repro.core.ckks.params import CkksContext
from repro.core.secure_agg import ProtectedUpdate
from repro.serve import quorum as qr
from repro.serve.faults import FaultInjector
from repro.wire import budget as wire_budget
from repro.wire import format as wf
from repro.wire import stream as wire_stream

ST_OPEN = "open"
ST_SEALED = "sealed"
ST_FOLDING = "folding"
ST_DONE = "done"
ST_FAILED = "failed"

# submit() rejection reasons (SubmitResult.reason; "accepted" on success)
REJ_NO_ROUND = "no_open_round"
REJ_LATE = "late"
REJ_DUP = "duplicate_cid"
REJ_BAD_HEADER = "bad_header"


@dataclasses.dataclass(frozen=True)
class SubmitResult:
    """Ack for one submit(): accepted flag, reason, and the round it was
    judged against (None when no round was open)."""
    accepted: bool
    reason: str
    round: int | None = None


class RoundState:
    """Bookkeeping for one round of the state machine (service-internal;
    exposed read-only through AggregationService.round_info)."""

    def __init__(self, rnd: int, opened_at: float):
        self.rnd = rnd
        self.status = ST_OPEN
        self.opened_at = opened_at
        self.sealed_reason: str | None = None
        # accepted updates, in arrival order; each is a dict with keys
        # cid / n_samples / nbytes / blob (bytes) / path (spool file|None)
        self.accepted: list[dict] = []
        self.seen_cids: set[int] = set()
        self.rejected: _Counter = _Counter()
        # fold progress: indices into `accepted` that failed wire
        # validation, FedAvg weights over the current survivor set, and
        # the cursor into the survivor order
        self.bad: set[int] = set()
        self.weights: list[float] | None = None
        self.cursor = 0
        self.pass_dirty = False        # new bad blobs found this pass
        self.refolds = 0
        self.result: ProtectedUpdate | None = None

    def good_order(self) -> list[int]:
        """Arrival-order indices of the accepted blobs still considered
        good — the fold order, and the set weights normalize over."""
        return [i for i in range(len(self.accepted)) if i not in self.bad]

    def elapsed(self, now: float) -> float:
        return now - self.opened_at


class AggregationService:
    """The encrypted aggregation service (module docstring for the state
    machine; DESIGN.md §14 for the full design).

    Args:
        ctx: CkksContext of the arriving ciphertext updates.
        quorum: the QuorumPolicy every round seals under.
        sharded: optional core.ckks.sharded.ShardedHe; folds then run
            sharded over its mesh, bit-identical (wire/stream contract).
        ckpt_dir: enable crash-safe checkpointing + blob spooling under
            this directory (None = in-memory only, no resume).
        ckpt_keep: checkpoints retained by rotation.
        ckpt_every_accepts: additionally checkpoint every N accepted
            updates while a round is OPEN (0 = only at transitions).
        fold_batch: updates folded per step() call — the granularity of
            both checkpointing and submit-latency while folding.
        clock: monotonic-seconds callable (injectable for deterministic
            deadline tests); default time.monotonic.
        faults: optional FaultInjector whose crash points this service
            honors (wire faults are applied by the network/driver, not
            here).
        ledger: optional wire.budget.BandwidthLedger; accepted uplink
            blobs are recorded per artifact class, and the records ride
            every checkpoint (a resume loses no accounted bytes).
        transcipher_materials: optional {(cid, round):
            transcipher.ServerMaterials} registry handed to every round's
            StreamIngest — required before any thin-client (transcipher)
            update can fold; unprovisioned masked updates are rejected at
            fold time like any bad blob (DESIGN.md §15).  Mutable: the
            provisioning path may add_transcipher_materials() while the
            service runs.
    """

    _ids = itertools.count()

    def __init__(self, ctx: CkksContext, quorum: qr.QuorumPolicy, *,
                 sharded=None, ckpt_dir: str | None = None,
                 ckpt_keep: int = 3, ckpt_every_accepts: int = 0,
                 fold_batch: int = 32, clock=None,
                 faults: FaultInjector | None = None,
                 ledger: wire_budget.BandwidthLedger | None = None,
                 transcipher_materials: dict | None = None):
        self.ctx = ctx
        self.quorum = quorum
        self.sharded = sharded
        self.transcipher_materials = dict(transcipher_materials or {})
        self.fold_batch = int(fold_batch)
        if self.fold_batch < 1:
            raise ValueError("fold_batch must be >= 1")
        self.ckpt_every_accepts = int(ckpt_every_accepts)
        self._clock = clock if clock is not None else time.monotonic
        self.faults = faults
        self.ledger = ledger
        self.ckpt_dir = ckpt_dir
        self._ckpt = (ckpt_store.CheckpointManager(ckpt_dir, keep=ckpt_keep)
                      if ckpt_dir else None)
        self._ckpt_step = 0
        self._accepts_since_ckpt = 0
        self._rounds: dict[int, RoundState] = {}
        self._ingests: dict[int, wire_stream.StreamIngest] = {}
        self._open_rnd: int | None = None
        self._next_round = 0
        self._lock = threading.RLock()
        self._worker: threading.Thread | None = None
        self._stop = threading.Event()
        self.worker_error: BaseException | None = None
        sid = str(next(self._ids))
        self.service_id = sid
        lab = {"service": sid}
        self._m_accepted = obs.counter("serve_submits", result="accepted",
                                       **lab)
        self._m_rejected = {
            r: obs.counter("serve_submits", result=r, **lab)
            for r in (REJ_NO_ROUND, REJ_LATE, REJ_DUP, REJ_BAD_HEADER)}
        self._m_folded = obs.counter("serve_updates_folded", **lab)
        self._m_fold_rejects = obs.counter("serve_fold_rejects", **lab)
        self._m_refolds = obs.counter("serve_refolds", **lab)
        self._m_done = obs.counter("serve_rounds", status=ST_DONE, **lab)
        self._m_failed = obs.counter("serve_rounds", status=ST_FAILED, **lab)
        self._m_ckpts = obs.counter("serve_checkpoints", **lab)

    def add_transcipher_materials(self, cid: int, rnd: int,
                                  materials) -> None:
        """Register one (cid, round)'s transcipher.ServerMaterials before
        that client's masked update folds.  Also propagated into every
        round ingest already in flight (each StreamIngest keeps its own
        copy of the registry)."""
        with self._lock:
            self.transcipher_materials[(int(cid), int(rnd))] = materials
            for ingest in self._ingests.values():
                ingest.add_transcipher_materials(cid, rnd, materials)

    # -- introspection -------------------------------------------------------

    def status(self, rnd: int) -> str:
        """State-machine status of round `rnd` (KeyError if unknown)."""
        with self._lock:
            return self._rounds[rnd].status

    def round_info(self, rnd: int) -> dict:
        """Read-only snapshot of one round's bookkeeping."""
        with self._lock:
            rs = self._rounds[rnd]
            return {
                "round": rs.rnd, "status": rs.status,
                "sealed_reason": rs.sealed_reason,
                "accepted": len(rs.accepted),
                "folded": len(rs.good_order()) if rs.status in
                          (ST_DONE,) else rs.cursor,
                "rejected": dict(rs.rejected),
                "bad_after_accept": len(rs.bad),
                "refolds": rs.refolds,
            }

    @property
    def open_round_id(self) -> int | None:
        return self._open_rnd

    def unfinished(self) -> list[int]:
        """Rounds still owing work (SEALED or FOLDING), oldest first."""
        with self._lock:
            return sorted(r for r, rs in self._rounds.items()
                          if rs.status in (ST_SEALED, ST_FOLDING))

    # -- transitions ---------------------------------------------------------

    def open_round(self) -> int:
        """OPEN the next round.  Allowed while earlier rounds are still
        SEALED/FOLDING (the ingest-vs-finalization overlap); refused while
        another round is OPEN — one accepting round at a time keeps
        submit() routing unambiguous."""
        with self._lock:
            if self._open_rnd is not None:
                raise RuntimeError(
                    f"round {self._open_rnd} is still open; seal it before "
                    "opening the next")
            rnd = self._next_round
            self._next_round += 1
            self._rounds[rnd] = RoundState(rnd, self._clock())
            self._open_rnd = rnd
            with obs.span("serve.open", round=rnd):
                self._checkpoint("open")
            self._crash("after_open")
            return rnd

    def submit(self, blob: bytes) -> SubmitResult:
        """Offer one client's serialized update to the OPEN round.

        Rejection here is cheap and final: past-deadline (``late``),
        duplicate client id, unparseable header, or no round open.
        Acceptance only promises the blob made the accepted set — deep
        wire validation happens at fold time, where a corrupt blob is
        dropped atomically and the round renormalizes without it.
        """
        with self._lock:
            rnd = self._open_rnd
            if rnd is None:
                self._m_rejected[REJ_NO_ROUND].inc()
                return SubmitResult(False, REJ_NO_ROUND, None)
            rs = self._rounds[rnd]
            now = self._clock()
            if self.quorum.late(rs.elapsed(now)):
                rs.rejected[REJ_LATE] += 1
                self._m_rejected[REJ_LATE].inc()
                self.maybe_seal()      # the deadline has passed: seal/fail
                return SubmitResult(False, REJ_LATE, rnd)
            try:
                meta = wire_stream.peek_update_meta(blob)
            except wf.WireError:
                rs.rejected[REJ_BAD_HEADER] += 1
                self._m_rejected[REJ_BAD_HEADER].inc()
                return SubmitResult(False, REJ_BAD_HEADER, rnd)
            if meta.cid in rs.seen_cids:
                rs.rejected[REJ_DUP] += 1
                self._m_rejected[REJ_DUP].inc()
                return SubmitResult(False, REJ_DUP, rnd)
            rec = {"cid": int(meta.cid), "n_samples": int(meta.n_samples),
                   "nbytes": len(blob), "blob": bytes(blob), "path": None}
            if self._ckpt is not None:
                rec["path"] = self._spool(rnd, rec)
            rs.accepted.append(rec)
            rs.seen_cids.add(int(meta.cid))
            self._m_accepted.inc()
            if self.ledger is not None:
                n_before = len(self.ledger.records)
                try:
                    self.ledger.record_blob(blob, rnd=rnd, cid=meta.cid,
                                            direction=wire_budget.UPLINK)
                except wf.WireError:
                    # the stream is corrupt past its header (it will be
                    # rejected at fold time) but its bytes DID cross the
                    # wire: drop the partial per-class split and account
                    # the raw blob in one record
                    del self.ledger.records[n_before:]
                    self.ledger.record(rnd=rnd, cid=meta.cid,
                                       direction=wire_budget.UPLINK,
                                       kind=wire_budget.K_META,
                                       nbytes=len(blob))
            self._accepts_since_ckpt += 1
            if self.ckpt_every_accepts \
                    and self._accepts_since_ckpt >= self.ckpt_every_accepts:
                self._checkpoint("accept")
            self._crash("after_accept")
            self.maybe_seal()          # target may be reached
            return SubmitResult(True, "accepted", rnd)

    def maybe_seal(self) -> str | None:
        """Poll the quorum policy for the OPEN round; seal or fail it when
        the policy says so.  Returns the seal/fail reason or None."""
        with self._lock:
            rnd = self._open_rnd
            if rnd is None:
                return None
            rs = self._rounds[rnd]
            reason = self.quorum.should_seal(len(rs.accepted),
                                             rs.elapsed(self._clock()))
            if reason is None:
                return None
            if reason == qr.FAIL_DEADLINE:
                self._fail(rs, reason)
            else:
                self._seal(rs, reason)
            return reason

    def seal(self) -> int:
        """Explicitly seal the OPEN round (drivers without a deadline).
        Raises if the quorum floor is not met — below `min_clients` a
        round may never seal, only fail."""
        with self._lock:
            rnd = self._open_rnd
            if rnd is None:
                raise RuntimeError("no round is open")
            rs = self._rounds[rnd]
            if not self.quorum.met(len(rs.accepted)):
                raise RuntimeError(
                    f"round {rnd} has {len(rs.accepted)} accepted updates, "
                    f"below the quorum floor {self.quorum.min_clients}")
            self._seal(rs, "explicit")
            return rnd

    def _seal(self, rs: RoundState, reason: str) -> None:
        rs.status = ST_SEALED
        rs.sealed_reason = reason
        self._open_rnd = None
        with obs.span("serve.seal", round=rs.rnd, reason=reason,
                      accepted=len(rs.accepted)):
            self._checkpoint("seal")
        self._crash("after_seal")

    def _fail(self, rs: RoundState, reason: str) -> None:
        rs.status = ST_FAILED
        rs.sealed_reason = reason
        if self._open_rnd == rs.rnd:
            self._open_rnd = None
        self._m_failed.inc()
        with obs.span("serve.fail", round=rs.rnd, reason=reason):
            self._checkpoint("fail")

    # -- folding -------------------------------------------------------------

    def step(self) -> bool:
        """Advance the oldest SEALED/FOLDING round by up to `fold_batch`
        updates.  Returns True iff any progress was made.  Never blocks on
        the network: this is the half of the service a worker thread (or
        the driver loop) pumps while submit() keeps accepting the next
        round's traffic."""
        with self._lock:
            pending = self.unfinished()
            if not pending:
                return False
            rs = self._rounds[pending[0]]
            if rs.status == ST_SEALED:
                self._begin_fold(rs)
            self._fold_some(rs)
            return True

    def drain(self) -> None:
        """step() until no round owes work (submissions stay possible to
        whatever round is OPEN throughout)."""
        while self.step():
            pass

    def _begin_fold(self, rs: RoundState) -> None:
        rs.status = ST_FOLDING
        rs.cursor = 0
        rs.pass_dirty = False
        good = rs.good_order()
        rs.weights = qr.normalized_weights(
            [rs.accepted[i]["n_samples"] for i in good])
        self._ingests[rs.rnd] = wire_stream.StreamIngest(
            self.ctx, sharded=self.sharded,
            transcipher_materials=self.transcipher_materials)

    def _fold_some(self, rs: RoundState) -> None:
        ingest = self._ingests[rs.rnd]
        good = rs.good_order()
        with obs.span("serve.fold", round=rs.rnd, cursor=rs.cursor,
                      of=len(good)):
            for _ in range(self.fold_batch):
                if rs.cursor >= len(good):
                    break
                i = good[rs.cursor]
                rec = rs.accepted[i]
                try:
                    ingest.ingest(self._blob(rs.rnd, rec),
                                  rs.weights[rs.cursor])
                    self._m_folded.inc()
                except wf.WireError as e:
                    # atomically rolled back by StreamIngest: nothing of
                    # this blob reached the accumulator.  Mark it bad; the
                    # pass completes (to discover every bad blob in one
                    # sweep) and then refolds the survivors with weights
                    # renormalized over them.
                    rs.bad.add(i)
                    rs.pass_dirty = True
                    rs.rejected[f"wire:{type(e).__name__}"] += 1
                    self._m_fold_rejects.inc()
                rs.cursor += 1
        if rs.cursor >= len(good):
            self._end_pass(rs)
            return
        self._checkpoint("fold")
        self._crash("after_fold_step")

    def _end_pass(self, rs: RoundState) -> None:
        if rs.pass_dirty:
            # rejects changed the survivor set: refold from scratch so the
            # weights (and therefore the bits) match a clean run over
            # exactly the surviving clients
            rs.refolds += 1
            self._m_refolds.inc()
            good = rs.good_order()
            if not self.quorum.met(len(good)):
                del self._ingests[rs.rnd]
                self._fail(rs, "below_quorum_after_rejects")
                return
            self._begin_fold(rs)
            self._checkpoint("refold")
            self._crash("after_fold_step")
            return
        ingest = self._ingests.pop(rs.rnd)
        good = rs.good_order()
        if not self.quorum.met(len(good)):
            self._fail(rs, "below_quorum_after_rejects")
            return
        with obs.span("serve.finalize", round=rs.rnd, folded=len(good),
                      launches=ingest.accum_launches):
            rs.result = ingest.finalize()
        rs.status = ST_DONE
        self._m_done.inc()
        self._checkpoint("finalize")
        self._crash("after_finalize")

    def result(self, rnd: int) -> ProtectedUpdate:
        """Aggregated ProtectedUpdate of a DONE round (raises otherwise)."""
        with self._lock:
            rs = self._rounds[rnd]
            if rs.status != ST_DONE:
                raise RuntimeError(
                    f"round {rnd} is {rs.status}, not {ST_DONE}"
                    + (f" ({rs.sealed_reason})"
                       if rs.status == ST_FAILED else ""))
            return rs.result

    def forget_round(self, rnd: int) -> None:
        """Drop a DONE/FAILED round's state (and its spool files) once the
        driver has consumed the result — the long-running service's GC."""
        with self._lock:
            rs = self._rounds[rnd]
            if rs.status not in (ST_DONE, ST_FAILED):
                raise RuntimeError(f"round {rnd} is still {rs.status}")
            for rec in rs.accepted:
                if rec["path"]:
                    try:
                        os.unlink(rec["path"])
                    except OSError:
                        pass
            del self._rounds[rnd]

    # -- background worker ---------------------------------------------------

    def start(self, poll_s: float = 0.001) -> None:
        """Run seal/fold in a background thread: submit() then overlaps
        with folding in wall-clock time too (the state machine already
        allows it logically).  A SimulatedCrash in the worker parks in
        `worker_error` — drivers re-raise after join."""
        if self._worker is not None:
            raise RuntimeError("worker already running")
        self._stop.clear()
        self.worker_error = None

        def _loop():
            while not self._stop.is_set():
                try:
                    self.maybe_seal()
                    progressed = self.step()
                except BaseException as e:     # SimulatedCrash included
                    self.worker_error = e
                    return
                if not progressed:
                    self._stop.wait(poll_s)

        self._worker = threading.Thread(target=_loop, name="serve-fold",
                                        daemon=True)
        self._worker.start()

    def stop(self) -> None:
        """Stop and join the background worker (idempotent)."""
        if self._worker is None:
            return
        self._stop.set()
        self._worker.join()
        self._worker = None

    # -- crash + checkpoint plumbing ----------------------------------------

    def _crash(self, point: str) -> None:
        if self.faults is not None:
            self.faults.crash_point(point)

    def _spool(self, rnd: int, rec: dict) -> str:
        """Persist one accepted blob under the checkpoint dir (atomic
        rename, like the checkpoints themselves)."""
        d = os.path.join(self.ckpt_dir, "spool", f"r{rnd:06d}")
        os.makedirs(d, exist_ok=True)
        path = os.path.join(d, f"u{rec['cid']:08d}.bin")
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(rec["blob"])
        os.replace(tmp, path)
        return path

    def _blob(self, rnd: int, rec: dict) -> bytes:
        if rec["blob"] is None:
            with open(rec["path"], "rb") as f:
                rec["blob"] = f.read()
        return rec["blob"]

    def _checkpoint(self, label: str) -> None:
        if self._ckpt is None:
            return
        now = self._clock()
        tree: dict = {}
        rounds_extra: dict = {}
        for rnd, rs in self._rounds.items():
            rx = {
                "status": rs.status,
                "sealed_reason": rs.sealed_reason,
                "accepted": [{k: rec[k] for k in
                              ("cid", "n_samples", "nbytes", "path")}
                             for rec in rs.accepted],
                "rejected": dict(rs.rejected),
                "bad": sorted(rs.bad),
                "weights": rs.weights,
                "cursor": rs.cursor,
                "pass_dirty": rs.pass_dirty,
                "refolds": rs.refolds,
                "deadline_remaining": (
                    self.quorum.deadline_s - rs.elapsed(now)
                    if rs.status == ST_OPEN
                    and self.quorum.deadline_s is not None else None),
                "has_result": rs.result is not None,
            }
            if rnd in self._ingests:
                arrays, meta = self._ingests[rnd].export_state()
                tree[f"ingest_{rnd}"] = arrays
                rx["ingest_meta"] = meta
            if rs.result is not None:
                tree[f"result_{rnd}"] = {
                    "ct_data": np.asarray(rs.result.ct.data,
                                          dtype=np.uint32),
                    "plain": np.asarray(rs.result.plain,
                                        dtype=np.float32),
                }
                rx["result_scale"] = float(rs.result.ct.scale)
            rounds_extra[str(rnd)] = rx
        extra = {
            "serve": {
                "label": label,
                "next_round": self._next_round,
                "open_rnd": self._open_rnd,
                "rounds": rounds_extra,
                "ledger": ([list(dataclasses.astuple(r))
                            for r in self.ledger.records]
                           if self.ledger is not None else None),
            },
        }
        self._ckpt_step += 1
        with obs.span("serve.checkpoint", step=self._ckpt_step,
                      label=label):
            self._ckpt.save(self._ckpt_step, tree, extra)
        self._m_ckpts.inc()
        self._accepts_since_ckpt = 0

    @classmethod
    def resume(cls, ckpt_dir: str, ctx: CkksContext,
               quorum: qr.QuorumPolicy, **kwargs) -> "AggregationService":
        """Rebuild a service from the latest checkpoint under `ckpt_dir`.

        Accumulators restore as the exact u32 residues / f32 partial sums
        they were checkpointed as, spooled blobs reload from disk, the
        budget ledger replays its records, and deadlines re-anchor to the
        remaining time at checkpoint — continuing the run reproduces the
        uninterrupted run's bits (tests/test_serve.py proves it at every
        crash point).  Raises FileNotFoundError when no checkpoint exists.
        """
        manifest = ckpt_store.read_manifest(ckpt_dir)
        if manifest is None:
            raise FileNotFoundError(
                f"no checkpoint to resume under {ckpt_dir!r}")
        sx = manifest["extra"]["serve"]
        tree_like = {}
        for rnd_s, rx in sx["rounds"].items():
            if "ingest_meta" in rx:
                tree_like[f"ingest_{rnd_s}"] = {
                    "chunk_idx": 0, "acc_ct": 0, "acc_plain": 0}
            if rx.get("has_result"):
                tree_like[f"result_{rnd_s}"] = {"ct_data": 0, "plain": 0}
        tree, step, _ = ckpt_store.restore_checkpoint(ckpt_dir, tree_like)
        svc = cls(ctx, quorum, ckpt_dir=ckpt_dir, **kwargs)
        svc._ckpt_step = step
        svc._next_round = int(sx["next_round"])
        svc._open_rnd = (int(sx["open_rnd"])
                         if sx["open_rnd"] is not None else None)
        now = svc._clock()
        for rnd_s, rx in sx["rounds"].items():
            rnd = int(rnd_s)
            rs = RoundState(rnd, now)
            rs.status = rx["status"]
            rs.sealed_reason = rx["sealed_reason"]
            if rx["deadline_remaining"] is not None:
                # re-anchor: the round keeps the deadline budget it had
                # left when the checkpoint was written
                rs.opened_at = now - (quorum.deadline_s
                                      - rx["deadline_remaining"])
            for rec in rx["accepted"]:
                path = rec["path"]
                blob = None
                if path is not None and os.path.exists(path):
                    with open(path, "rb") as f:
                        blob = f.read()
                rs.accepted.append({"cid": rec["cid"],
                                    "n_samples": rec["n_samples"],
                                    "nbytes": rec["nbytes"],
                                    "blob": blob, "path": path})
                rs.seen_cids.add(int(rec["cid"]))
            rs.rejected = _Counter(rx["rejected"])
            rs.bad = set(rx["bad"])
            rs.weights = rx["weights"]
            rs.cursor = int(rx["cursor"])
            rs.pass_dirty = bool(rx["pass_dirty"])
            rs.refolds = int(rx["refolds"])
            if "ingest_meta" in rx:
                ingest = wire_stream.StreamIngest(
                    ctx, sharded=kwargs.get("sharded"),
                    transcipher_materials=svc.transcipher_materials)
                ingest.restore_state(tree[f"ingest_{rnd_s}"],
                                     rx["ingest_meta"])
                svc._ingests[rnd] = ingest
            if rx.get("has_result"):
                rt = tree[f"result_{rnd_s}"]
                from repro.core.ckks.cipher import Ciphertext
                rs.result = ProtectedUpdate(
                    ct=Ciphertext(data=rt["ct_data"],
                                  scale=rx["result_scale"]),
                    plain=rt["plain"])
            svc._rounds[rnd] = rs
        if sx["ledger"] is not None and svc.ledger is not None:
            # replay records directly (no obs re-mirroring: this process's
            # registry starts fresh, the LEDGER must not lose a byte)
            for rec in sx["ledger"]:
                svc.ledger.records.append(wire_budget.WireRecord(*rec))
        return svc
