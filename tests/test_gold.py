"""Gold known-answer tests: the checked-in vectors in
tests/golden/ckks_kats.json must be reproduced BIT-EXACTLY by every
backend in the registry ("ref", "pallas", "pallas4").

This is the cross-version / cross-backend drift tripwire: a jax PRNG
change, a twiddle-table regression, or a new backend that is "only
approximately" compatible all fail here with the first differing vector
named.  Regeneration (after an intentional stream change) is
`python tools/gen_gold.py`; the CI docs job runs `tools/gen_gold.py
--check` so the file cannot silently drift from the code either.
"""
import numpy as np
import pytest

from repro.kernels import ops

import gold


@pytest.fixture(scope="module")
def golden():
    return gold.load_kats()


def test_golden_file_covers_every_case(golden):
    ops_per_ctx = {"ntt_fwd", "ntt_inv", "keygen_sk", "encrypt_seeded",
                   "encrypt_pk", "weighted_sum", "selective_wire",
                   "selective_agg", "selective_merged"}
    want = {f"{c}/{op}" for c in gold.KAT_CONTEXTS for op in ops_per_ctx}
    assert set(golden) == want


@pytest.mark.parametrize("backend", ops.BACKENDS)
def test_backend_reproduces_golden_kats(backend, golden):
    old = {op: ops.get_backend(op) for op in ops.OPS}
    try:
        ops.set_backend(backend)
        got = gold.compute_kats()
    finally:
        for op, name in old.items():
            ops.set_backend(name, op=op)
    assert set(got) == set(golden)
    for name in sorted(golden):
        np.testing.assert_array_equal(
            got[name], golden[name],
            err_msg=f"backend {backend!r} drifted from golden KAT {name!r}"
                    " (tests/golden/ckks_kats.json; see tools/gen_gold.py)")


def test_corrupt_golden_file_detected(tmp_path):
    """load_kats verifies the recorded sha256 — a hand-edited or truncated
    golden file is rejected, not silently trusted."""
    import json

    with open(gold.KAT_PATH) as f:
        doc = json.load(f)
    name = sorted(doc["kats"])[0]
    doc["kats"][name]["data_b64"] = doc["kats"][name]["data_b64"][:-8] \
        + "AAAAAAA="
    bad = tmp_path / "kats.json"
    bad.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="corrupt"):
        gold.load_kats(str(bad))
