"""Sharding policy: parameter/batch/cache PartitionSpecs for the production
mesh (see DESIGN.md §4).

Policy summary (axes: optional 'pod', 'data', 'model'):
  * 2-D weights [in, out]          -> P('data', 'model')    (ZeRO-FSDP x TP)
  * embed [V, d]                   -> P('model', None)      (vocab-sharded)
  * unembed [d, V]                 -> P('data', 'model')
  * MoE expert weights [E, in, out]-> P(None, None, 'model') (EP-free baseline;
                                      dispatch runs under shard_map over dp)
  * 1-D params (norms, biases, A_log, dt_bias, D) -> replicated
  * conv kernels [w, ch]           -> replicated
  * batch dims                     -> ('pod', 'data') when divisible
  * decode KV caches               -> batch over dp, seq over 'model'
                                      (B==1: seq over ('data','model'))

Stacked layer dims (leading L) are never sharded.  All rules check
divisibility and fall back to replication, so reduced smoke configs on one
CPU device lower with fully-replicated specs.
"""
from __future__ import annotations

import dataclasses
import re

import jax
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AxisEnv:
    """Names + sizes of the mesh axes in play ((1,)-sized axes => no mesh)."""

    data: tuple[str, ...] = ("data",)   # FSDP / batch axes ('pod','data')
    model: str = "model"
    data_size: int = 1
    model_size: int = 1
    mesh: object = dataclasses.field(default=None, compare=False, hash=False)

    @property
    def dp(self):
        return self.data if self.data_size > 1 else None

    def mp(self, dim: int):
        return self.model if self.model_size > 1 and dim % self.model_size == 0 \
            else None

    def fsdp(self, dim: int):
        if self.data_size > 1 and dim % self.data_size == 0:
            return self.data if len(self.data) > 1 else self.data[0]
        return None

    def flat(self, dim: int):
        """All mesh axes as one flattened TP axis (weight-stationary
        serving); falls back to 'model' then replication."""
        total = self.data_size * self.model_size
        if total > 1 and dim % total == 0:
            return (*self.data, self.model)
        return self.mp(dim)


def axis_env_from_mesh(mesh) -> AxisEnv:
    names = mesh.axis_names
    data = tuple(n for n in names if n in ("pod", "data"))
    data_size = int(np.prod([mesh.shape[n] for n in data])) if data else 1
    model_size = int(mesh.shape["model"]) if "model" in names else 1
    return AxisEnv(data=data or ("data",), model="model",
                   data_size=data_size, model_size=model_size, mesh=mesh)


CPU_ENV = AxisEnv()  # sizes 1 -> every spec collapses to replicated


# ---------------------------------------------------------------------------
# parameter specs by path
# ---------------------------------------------------------------------------

_REPLICATED_2D = re.compile(r"conv_|router")


def _leaf_spec(path: str, shape, ax: AxisEnv):
    nd = len(shape)
    if nd <= 1:
        return P()
    if "unembed" in path:                       # must precede the embed rule
        return P(ax.fsdp(shape[0]), ax.mp(shape[1]))
    if "embed" in path and "patch" not in path and "frame" not in path:
        # [V, d] vocab-sharded
        return P(ax.mp(shape[0]), None)
    if _REPLICATED_2D.search(path):
        return P(*([None] * nd))
    if nd == 2:
        return P(ax.fsdp(shape[0]), ax.mp(shape[1]))
    if nd == 3:
        # stacked per-layer [L, in, out] or expert [E, in, out]
        if "expert" in path:
            return P(None, None, ax.mp(shape[2]))
        return P(None, ax.fsdp(shape[1]), ax.mp(shape[2]))
    if nd == 4:
        # stacked experts [L, E, in, out]
        return P(None, None, None, ax.mp(shape[3]))
    return P(*([None] * nd))


def _leaf_spec_serve_tp(path: str, shape, ax: AxisEnv):
    """Weight-stationary serving: shard every weight's OUT dim over the
    flattened mesh (pure TP) so decode never all-gathers weights; the
    per-matmul psum moves only [B, d]-sized partials."""
    nd = len(shape)
    if nd == 1:
        return P(ax.flat(shape[0]))
    if nd == 0:
        return P()
    if "embed" in path and "patch" not in path and "frame" not in path:
        return P(ax.flat(shape[0]), None)
    lead = [None] * (nd - 2)
    return P(*lead, None, ax.flat(shape[-1]))


def param_specs(params_abstract, ax: AxisEnv, mode: str = "train"):
    """pytree of ShapeDtypeStruct -> pytree of PartitionSpec.

    mode='train': 2-D ZeRO-FSDP x TP (the baseline everywhere).
    mode='serve_tp': flattened-mesh weight-stationary TP (decode
    hillclimb — see EXPERIMENTS.md §Perf).
    """
    fn = _leaf_spec if mode == "train" else _leaf_spec_serve_tp

    def visit(path, leaf):
        name = jax.tree_util.keystr(path)
        return fn(name, leaf.shape, ax)
    return jax.tree_util.tree_map_with_path(visit, params_abstract)


# ---------------------------------------------------------------------------
# activation constraints (no-ops outside a mesh context)
# ---------------------------------------------------------------------------


def _have_mesh() -> bool:
    try:
        m = jax.sharding.get_abstract_mesh()
    except AttributeError:
        # jax < 0.5 has no abstract-mesh API; the context mesh lives on the
        # thread-local resource env instead.
        from jax._src import mesh as _mesh
        m = _mesh.thread_resources.env.physical_mesh
        return m is not None and not m.empty
    return m is not None and not m.empty and m.shape_tuple


def constrain(x, *spec):
    """with_sharding_constraint that degrades to identity on 1-device runs."""
    if not _have_mesh():
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------


def batch_spec(ax: AxisEnv, batch_size: int, extra_dims: int = 1):
    """P over the leading batch dim; replicate when indivisible."""
    dp = ax.dp if (ax.dp and batch_size % ax.data_size == 0) else None
    return P(dp, *([None] * extra_dims))


def kv_cache_spec(ax: AxisEnv, batch_size: int):
    """[B, S, KH, hd]: batch over dp, seq over model; B==1 -> seq over
    (data..., model)."""
    if batch_size == 1:
        seq = (*ax.data, ax.model) if ax.data_size > 1 else ax.model
        return P(None, seq if ax.model_size > 1 else None, None, None)
    dp = ax.dp if batch_size % ax.data_size == 0 else None
    mp = ax.model if ax.model_size > 1 else None
    return P(dp, mp, None, None)


def ssm_state_spec(ax: AxisEnv, batch_size: int, n_heads: int):
    """[B, nh, hd, state]: batch over dp, heads over model."""
    dp = ax.dp if (batch_size % ax.data_size == 0 and batch_size > 1) else None
    return P(dp, ax.mp(n_heads), None, None)


def conv_state_spec(ax: AxisEnv, batch_size: int, ch: int):
    """[B, w-1, ch]."""
    dp = ax.dp if (batch_size % ax.data_size == 0 and batch_size > 1) else None
    return P(dp, None, ax.mp(ch))
