"""Threshold-HE federated learning (paper Appendix B): no single client
holds the full secret key; decryption requires every party's partial
decryption (additive n-of-n) or any t of n (Shamir).

    PYTHONPATH=src python examples/threshold_fl.py
"""
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.core.ckks import cipher, encoding, threshold
from repro.core.ckks import params as ckks_params
from repro.core.secure_agg import AggregatorConfig
from repro.data import make_client_streams
from repro.fl import ClientConfig, FLClient, FLRunConfig, FLTask


def microbenchmark(ctx):
    """Appendix-B style microbenchmark: single-key vs threshold FedAvg."""
    rng = np.random.RandomState(0)
    vals = rng.randn(8, ctx.slots).astype(np.float32)
    coeffs = jnp.asarray(encoding.encode_np(vals, ctx))

    # single key
    sk, pk = cipher.keygen(ctx, jax.random.PRNGKey(0))
    t0 = time.time()
    ct = cipher.encrypt_coeffs(ctx, pk, coeffs, jax.random.PRNGKey(1))
    out = cipher.decrypt_values_np(ctx, sk, ct)
    t_single = time.time() - t0
    err_single = np.abs(out - vals).max()

    # two-party threshold
    parties, tpk = threshold.threshold_keygen(ctx, jax.random.PRNGKey(2), 2)
    t0 = time.time()
    ct = cipher.encrypt_coeffs(ctx, tpk, coeffs, jax.random.PRNGKey(3))
    partials = [threshold.partial_decrypt(ctx, p, ct,
                                          jax.random.PRNGKey(10 + i))
                for i, p in enumerate(parties)]
    out = encoding.decode_np(
        np.asarray(threshold.combine_partials(ctx, ct, partials)),
        ctx, ct.scale)
    t_thresh = time.time() - t0
    err_thresh = np.abs(out - vals).max()
    print(f"single-key: {t_single:.3f}s err={err_single:.2e} | "
          f"2-party threshold: {t_thresh:.3f}s err={err_thresh:.2e} "
          f"(smudging noise dominates)")


def main():
    ctx = ckks_params.make_context(n_poly=2048, n_limbs=2, delta_bits=24)
    print("== threshold-HE microbenchmark (Appendix B / Figure 12) ==")
    microbenchmark(ctx)

    print("\n== threshold-HE federated training ==")
    cfg = dataclasses.replace(configs.get_config("qwen1.5-0.5b", smoke=True),
                              n_layers=2, d_model=64, d_ff=128, vocab=512)
    from repro.models import build_model
    model = build_model(cfg)
    streams = make_client_streams(3, cfg.vocab, seq_len=32, batch_size=4)
    clients = [FLClient(i, model, streams[i], ClientConfig(local_steps=4))
               for i in range(3)]
    task = FLTask(model, clients,
                  AggregatorConfig(p_ratio=0.2, strategy="top_p"),
                  FLRunConfig(n_rounds=4, threshold_mode=True, seed=0),
                  ctx=ctx)
    for l in task.run():
        print(f"round {l.round} loss={l.loss:.4f} "
              f"clients={l.n_participating}")
    print("threshold FL OK — no party ever held the full secret key")


if __name__ == "__main__":
    main()
