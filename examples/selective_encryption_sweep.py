"""Reproduce the paper's key trade-off curves on a real model:
overhead vs selection ratio (Table 7 / Figure 7) and the privacy-budget
advantage of sensitivity-ordered selection (Remarks 3.12-3.14), using an
actual fine-tune + sensitivity map from trained LM clients.

The heavy lifting lives in benchmarks/selective.py (the `benchmarks.run
selective` mode): this example reuses its client half
(`fine_tune_and_sense`) for the sensitivity map and adds the DP-advantage
table on top.  For the full measured pipeline sweep (wire bytes, sharded
aggregation wall time, BENCH_selective.json) run

    PYTHONPATH=src python -m benchmarks.run selective [--smoke]

    PYTHONPATH=src python examples/selective_encryption_sweep.py
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..")))   # repo root: benchmarks/

from benchmarks.selective import fine_tune_and_sense, model_cfgs
from repro.core import dp
from repro.core.ckks import params as ckks_params
from repro.core.secure_agg import AggregatorConfig, SelectiveHEAggregator


def main():
    (_, cfg), = model_cfgs(smoke=True)
    print("fine-tuning 2 clients + computing per-parameter sensitivity "
          f"maps ({cfg.param_count()/1e3:.0f}k params)...")
    task = fine_tune_and_sense(cfg)
    sens = np.average(np.stack(task["sens_maps"]), axis=0,
                      weights=task["weights"])
    print(f"mean local loss {task['loss']:.3f}; sensitivity: "
          f"min={sens.min():.2e} max={sens.max():.2e} "
          f"p99/p50={np.percentile(sens,99)/max(np.percentile(sens,50),1e-12):.1f} "
          "(heavily imbalanced, Figure 5)")

    params = task["global_params"]
    ctx = ckks_params.make_context(n_poly=2048, n_limbs=2, delta_bits=24)
    print(f"\n{'p':>5} {'cts':>6} {'comm_MB':>8} {'ratio':>6} "
          f"{'eps_sel/J':>10} {'eps_rnd/J':>10}")
    j = dp.epsilon_all_plaintext(sens, b=1.0)
    for p in (0.0, 0.05, 0.1, 0.3, 0.5, 1.0):
        agg = SelectiveHEAggregator.build(
            ctx, params, sens, AggregatorConfig(p_ratio=p))
        rep = agg.overhead_report()
        adv = dp.selection_advantage(sens, p, b=1.0) if 0 < p < 1 else None
        es = adv["eps_selective"] / j if adv else (1.0 if p == 0 else 0.0)
        er = adv["eps_random"] / j if adv else (1.0 if p == 0 else 0.0)
        print(f"{p:5.2f} {rep['n_ciphertexts']:6d} "
              f"{rep['bytes_total']/1e6:8.2f} {rep['comm_ratio']:6.2f} "
              f"{es:10.3f} {er:10.3f}")
    print("\nsensitivity-ordered selection spends quadratically less "
          "privacy budget than random selection at equal overhead "
          "(Remark 3.14).")


if __name__ == "__main__":
    main()
