"""End-to-end driver: federated training of a ~100M-param LM with
HE-protected aggregation for a few hundred local steps total.

Runs the full paper pipeline (Figure 3): threshold-free key agreement ->
sensitivity maps -> HE mask agreement -> encrypted FedAvg rounds, with
dropout + checkpointing enabled.

    PYTHONPATH=src python examples/encrypted_finetune.py [--rounds 20]
    (defaults sized to finish on a laptop CPU; --big uses the ~100M model)
"""
import argparse
import dataclasses
import time

import numpy as np
import jax

from repro import configs
from repro.core.ckks import params as ckks_params
from repro.core.secure_agg import AggregatorConfig
from repro.data import make_client_streams
from repro.fl import ClientConfig, FLClient, FLRunConfig, FLTask
from repro.models import build_model
from repro.models.config import ModelConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--p-ratio", type=float, default=0.1)
    ap.add_argument("--big", action="store_true",
                    help="~100M-param model (slow on CPU)")
    ap.add_argument("--ckpt-dir", default="/tmp/fedml_he_finetune")
    args = ap.parse_args()

    if args.big:
        cfg = ModelConfig(
            name="lm-100m", family="dense", n_layers=8, d_model=768,
            n_heads=12, n_kv_heads=12, d_ff=2048, vocab=32000,
            tie_embeddings=True, attn_chunk=256)
    else:
        cfg = dataclasses.replace(
            configs.get_config("qwen1.5-0.5b", smoke=True),
            n_layers=2, d_model=128, d_ff=256, vocab=2048)
    model = build_model(cfg)
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    streams = make_client_streams(args.clients, cfg.vocab,
                                  seq_len=args.seq, batch_size=args.batch,
                                  alpha=0.5, seed=0)
    clients = [FLClient(i, model, streams[i],
                        ClientConfig(local_steps=args.local_steps, lr=1e-3,
                                     sensitivity_probes=2))
               for i in range(args.clients)]

    ctx = ckks_params.make_context(n_poly=2048, n_limbs=2, delta_bits=24)
    task = FLTask(
        model, clients,
        AggregatorConfig(p_ratio=args.p_ratio, strategy="top_p"),
        FLRunConfig(n_rounds=args.rounds, dropout_prob=0.05,
                    ckpt_dir=args.ckpt_dir, ckpt_every=2, seed=0),
        ctx=ctx)

    t0 = time.time()
    task.agree_encryption_mask()
    rep = task.aggregator.overhead_report()
    print(f"mask agreed in {time.time()-t0:.1f}s: "
          f"{rep['n_enc']}/{rep['n_total']} params encrypted "
          f"({rep['ratio']:.0%}), {rep['n_ciphertexts']} cts/client, "
          f"comm {rep['bytes_total']/1e6:.1f}MB vs "
          f"{rep['bytes_all_plain']/1e6:.1f}MB plaintext "
          f"({rep['comm_ratio']:.2f}x)")

    logs = task.run()
    for l in logs:
        print(f"round {l.round:3d} loss={l.loss:.4f} "
              f"clients={l.n_participating} dropped={l.n_dropped} "
              f"comm={l.comm_bytes/1e6:.1f}MB wall={l.wall_s:.1f}s")
    total_steps = args.rounds * args.clients * args.local_steps
    print(f"total local steps {total_steps}; "
          f"loss {logs[0].loss:.3f} -> {logs[-1].loss:.3f}")


if __name__ == "__main__":
    main()
