"""repro.wire: ciphertext serialization + bandwidth optimization.

format    versioned length-prefixed binary frames for every FL artifact
compress  seed-expanded ciphertexts, RNS limb dropping, plain quantization
stream    chunked uplink protocol + O(1)-in-clients server ingest
budget    per-round measured-bytes ledger feeding the paper tables

See DESIGN.md §6.
"""
from repro.wire.budget import (BandwidthLedger, DOWNLINK, K_CIPHERTEXT,
                               K_META, K_PLAIN, K_SEEDED_CT, UPLINK)
from repro.wire.compress import (COMPACT, DERIVE_FOLD_CHUNK, LOSSLESS,
                                 SeededCiphertext, WirePolicy,
                                 dequantize_plain, limb_drop,
                                 quantize_plain, seed_compress)
from repro.wire.format import (SUPPORTED_VERSIONS, VERSION, FrameReader,
                               WireError, deserialize, iter_frames,
                               serialize_ciphertext, serialize_keyset,
                               serialize_partition,
                               serialize_seeded_ciphertext, serialize_update)
from repro.wire.stream import (StreamIngest, UpdateMeta, pack_update_frames,
                               peek_update_meta)

__all__ = [
    "BandwidthLedger", "UPLINK", "DOWNLINK", "K_CIPHERTEXT", "K_SEEDED_CT",
    "K_PLAIN", "K_META", "WirePolicy", "LOSSLESS", "COMPACT",
    "VERSION", "SUPPORTED_VERSIONS", "DERIVE_FOLD_CHUNK",
    "SeededCiphertext", "seed_compress", "limb_drop", "quantize_plain",
    "dequantize_plain", "FrameReader", "WireError", "deserialize",
    "iter_frames", "serialize_ciphertext", "serialize_seeded_ciphertext",
    "serialize_update", "serialize_keyset", "serialize_partition",
    "StreamIngest", "UpdateMeta", "pack_update_frames", "peek_update_meta",
]
