"""Worker for `benchmarks/run.py agg-sharded`: one host-device count per
process.

jax locks the device count at first initialization, so each measurement
point runs in its own subprocess with

    XLA_FLAGS=--xla_force_host_platform_device_count=<n>

set by the parent (see README.md "Environment variables & flags").  The
worker times

  * sharded vs single-device fused `weighted_sum` (the server hot loop);
  * streaming ingest of serialized-shape chunk batches through the
    chunk-batched `weighted_accum_chunks` flush, recording the launch
    count per flush;

and prints one JSON object on the last stdout line for the parent to
collect into BENCH_agg_sharded.json.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, required=True,
                    help="host device count this worker was launched with")
    ap.add_argument("--n-poly", type=int, default=2048)
    ap.add_argument("--n-limbs", type=int, default=2)
    ap.add_argument("--n-clients", type=int, default=8)
    ap.add_argument("--n-chunks", type=int, default=8)
    ap.add_argument("--reps", type=int, default=10)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.ckks import cipher, params as ckks_params
    from repro.core.ckks.sharded import ShardedHe
    from repro.kernels import ops, ref
    from repro.launch.mesh import make_he_mesh
    from repro.wire import stream as ws

    assert jax.device_count() >= args.devices, (
        f"worker expected {args.devices} devices, found "
        f"{jax.device_count()}; the parent must set "
        "XLA_FLAGS=--xla_force_host_platform_device_count")

    ctx = ckks_params.make_context(n_poly=args.n_poly, n_limbs=args.n_limbs,
                                   delta_bits=26)
    mesh = make_he_mesh(args.n_limbs, args.devices)
    eng = ShardedHe(ctx, mesh)
    rng = np.random.RandomState(0)

    def timeit(fn, *a, reps=args.reps):
        out = fn(*a)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready()
            if hasattr(x, "block_until_ready") else x, out)
        t0 = time.time()
        for _ in range(reps):
            out = fn(*a)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready()
            if hasattr(x, "block_until_ready") else x, out)
        return (time.time() - t0) / reps

    # -- weighted_sum: sharded vs single-device fused -----------------------
    raw = ref.rand_limbed_np(rng, ctx, (args.n_clients, args.n_chunks, 2))
    data = jnp.asarray(np.moveaxis(raw, -2, -3))   # [C, chunks, L, 2, N]
    cts = cipher.Ciphertext(data=data, scale=float(ctx.delta))
    weights = [1.0 / args.n_clients] * args.n_clients

    single_s = timeit(lambda: cipher.weighted_sum(ctx, cts, weights).data)
    sharded_s = timeit(lambda: eng.weighted_sum(cts, weights).data)
    parity = bool(np.array_equal(
        np.asarray(cipher.weighted_sum(ctx, cts, weights).data),
        np.asarray(eng.weighted_sum(cts, weights).data)))

    # -- streaming ingest: chunk-batched flush ------------------------------
    upd_data = [jnp.asarray(np.moveaxis(
        ref.rand_limbed_np(rng, ctx, (args.n_chunks, 2)), -2, -3))
        for _ in range(args.n_clients)]

    from repro.core.secure_agg import ProtectedUpdate

    def run_ingest(sharded):
        ing = ws.StreamIngest(ctx, sharded=sharded)
        for d in upd_data:
            ing.ingest_update(
                ProtectedUpdate(ct=cipher.Ciphertext(
                    data=d, scale=float(ctx.delta)),
                    plain=jnp.zeros((0,), jnp.float32)),
                1.0 / args.n_clients)
        out = ing.finalize()
        out.ct.data.block_until_ready()
        return ing

    ing = run_ingest(None)                      # warm the jitted flush
    t0 = time.time()
    ing = run_ingest(None)
    ingest_single_s = time.time() - t0
    launches_per_update = ing.accum_launches / max(1, ing.clients_ingested)
    run_ingest(eng)                             # warm the sharded flush
    t0 = time.time()
    run_ingest(eng)
    ingest_sharded_s = time.time() - t0

    result = {
        "devices": args.devices,
        "mesh": dict(mesh.shape),
        "n_poly": args.n_poly,
        "n_limbs": args.n_limbs,
        "n_clients": args.n_clients,
        "n_chunks": args.n_chunks,
        "backend": ops.get_backend(),
        "weighted_sum_single_ms": single_s * 1e3,
        "weighted_sum_sharded_ms": sharded_s * 1e3,
        "sharded_parity": parity,
        "stream_ingest_single_ms": ingest_single_s * 1e3,
        "stream_ingest_sharded_ms": ingest_sharded_s * 1e3,
        "accum_launches": ing.accum_launches,
        "clients_ingested": ing.clients_ingested,
        "launches_per_update": launches_per_update,
    }
    print(json.dumps(result))


if __name__ == "__main__":
    sys.exit(main())
