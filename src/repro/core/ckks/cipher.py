"""RNS-CKKS cipher: keygen / encrypt / decrypt / homomorphic ops.

Everything here is jittable (jax.random + the u32 kernel ops); ciphertexts are
u32[..., L, 2, N] tensors in bit-reversed NTT domain, wrapped with their scale.

Scale discipline (depth-1, the paper's setting):
  fresh ct: scale = delta
  ct (*) plain-scalar weight: scale = delta**2   (no rescale — lazy; decode
  divides by the ct scale, saving one iNTT+NTT per limb per round. `rescale`
  is still provided and tested.)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ckks import encoding
from repro.core.ckks.params import CkksContext
from repro.kernels import ops, ref as _ref


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Ciphertext:
    """data: u32[..., L, 2, N] NTT domain; scale: encoding scale."""

    data: Any
    scale: float = dataclasses.field(metadata=dict(static=True), default=1.0)

    @property
    def n_limbs(self):
        return self.data.shape[-3]

    @property
    def c0(self):
        return self.data[..., 0, :]

    @property
    def c1(self):
        return self.data[..., 1, :]


# ---------------------------------------------------------------------------
# sampling helpers (all jittable)
# ---------------------------------------------------------------------------

def _ternary_residues(key, shape, ctx: CkksContext):
    """Uniform ternary {-1,0,1} -> per-limb residues u32[..., L, N]."""
    t = jax.random.randint(key, shape, 0, 3)  # 0,1,2 ~ {-1,0,1}
    out = []
    for q in ctx.primes:
        r = jnp.where(t == 0, np.uint32(q - 1),
                      jnp.where(t == 1, np.uint32(0), np.uint32(1)))
        out.append(r.astype(jnp.uint32))
    return jnp.stack(out, axis=-2)  # [..., L, N]


def _gaussian_residues(key, shape, ctx: CkksContext, sigma: float | None = None):
    sigma = float(sigma if sigma is not None else ctx.error_sigma)
    e = jnp.rint(sigma * jax.random.normal(key, shape)).astype(jnp.int32)
    out = [_ref.mod_reduce_centered(e, np.uint32(q)) for q in ctx.primes]
    return jnp.stack(out, axis=-2)


def _uniform_residues(key, shape, ctx: CkksContext):
    outs = []
    for i, q in enumerate(ctx.primes):
        k = jax.random.fold_in(key, i)
        outs.append(jax.random.randint(k, shape, 0, q, dtype=jnp.uint32))
    return jnp.stack(outs, axis=-2)


# ---------------------------------------------------------------------------
# key generation
# ---------------------------------------------------------------------------

def keygen(ctx: CkksContext, key) -> tuple[dict, dict]:
    """Returns (sk, pk).

    sk = {"s_mont": u32[L, N]}           NTT-domain Montgomery secret
    pk = {"pk0_mont", "pk1_mont": u32[L, N]}  b = -(a s) + e, a
    """
    k_s, k_a, k_e = jax.random.split(key, 3)
    n = ctx.n_poly
    s = ops.ntt_fwd(_ternary_residues(k_s, (n,), ctx), ctx)       # [L, N]
    s_mont = ops.to_mont(s, ctx)
    a = _uniform_residues(k_a, (n,), ctx)                         # NTT domain
    e = ops.ntt_fwd(_gaussian_residues(k_e, (n,), ctx), ctx)
    a_s = ops.mont_mul(a, s_mont, ctx)
    pk0 = ops.mod_add(ops.mod_neg(a_s, ctx), e, ctx)
    return (
        {"s_mont": s_mont},
        {"pk0_mont": ops.to_mont(pk0, ctx), "pk1_mont": ops.to_mont(a, ctx)},
    )


# ---------------------------------------------------------------------------
# encrypt / decrypt
# ---------------------------------------------------------------------------

def encrypt_coeffs(ctx: CkksContext, pk: dict, m_coeff, key,
                   scale: float | None = None) -> Ciphertext:
    """m_coeff: u32[B, L, N] coefficient-domain residues (from encode)."""
    scale = float(scale if scale is not None else ctx.delta)
    b = m_coeff.shape[0]
    n = ctx.n_poly
    k_u, k_e0, k_e1 = jax.random.split(key, 3)
    m = ops.ntt_fwd(m_coeff, ctx)
    u = ops.ntt_fwd(_ternary_residues(k_u, (b, n), ctx), ctx)
    e0 = ops.ntt_fwd(_gaussian_residues(k_e0, (b, n), ctx), ctx)
    e1 = ops.ntt_fwd(_gaussian_residues(k_e1, (b, n), ctx), ctx)
    c0 = ops.mul_add(u, pk["pk0_mont"][None], ops.mod_add(e0, m, ctx), ctx)
    c1 = ops.mul_add(u, pk["pk1_mont"][None], e1, ctx)
    return Ciphertext(data=jnp.stack([c0, c1], axis=-2), scale=scale)


def encrypt_values(ctx: CkksContext, pk: dict, values, key) -> Ciphertext:
    """values: f32[B, slots] -> fresh ciphertext (jnp encode path)."""
    return encrypt_coeffs(ctx, pk, encoding.encode_jnp(values, ctx), key)


def expand_a_rows(ctx: CkksContext, a_seed: int, start: int, count: int):
    """Deterministic uniform `a` rows [start, start+count) from a public seed.

    Row i is expanded from fold_in(PRNGKey(a_seed), i) so a receiver can
    regenerate any single chunk independently (streaming ingest never needs
    the whole batch).  Returns u32[count, L, N] in NTT domain (uniform
    residues are uniform in either domain; both sides just agree on this
    convention, matching keygen's treatment of `a`).
    """
    base = jax.random.PRNGKey(int(a_seed))
    rows = [_uniform_residues(jax.random.fold_in(base, i), (ctx.n_poly,), ctx)
            for i in range(start, start + count)]
    return jnp.stack(rows, axis=0)  # [count, L, N]


def expand_a(ctx: CkksContext, a_seed: int, batch: int):
    """Full-batch `a` expansion (rows 0..batch-1)."""
    return expand_a_rows(ctx, a_seed, 0, batch)


def encrypt_coeffs_seeded(ctx: CkksContext, sk: dict, m_coeff, key,
                          a_seed: int, scale: float | None = None) -> Ciphertext:
    """Secret-key encryption with seed-expandable c1 (uplink compression).

    ct = (c0, c1) with c1 = a = PRG(a_seed) and c0 = -(a s) + e + m, so the
    wire only needs (a_seed, c0) — half the fresh-ciphertext bytes.  The
    decryption identity c0 + c1 s = m + e matches the public-key path, so
    seeded and pk ciphertexts mix freely under the homomorphic ops.
    `a_seed` must be unique per (client, round); reuse leaks m1 - m2.
    """
    scale = float(scale if scale is not None else ctx.delta)
    b = m_coeff.shape[0]
    n = ctx.n_poly
    m = ops.ntt_fwd(m_coeff, ctx)
    a = expand_a(ctx, a_seed, b)                                  # [B, L, N]
    e = ops.ntt_fwd(_gaussian_residues(key, (b, n), ctx), ctx)
    a_s = ops.mont_mul(a, sk["s_mont"][None], ctx)
    c0 = ops.mod_add(ops.mod_neg(a_s, ctx), ops.mod_add(e, m, ctx), ctx)
    return Ciphertext(data=jnp.stack([c0, a], axis=-2), scale=scale)


def drop_limbs(ctx: CkksContext, ct: Ciphertext, keep: int) -> Ciphertext:
    """Rescale away trailing RNS limbs until only `keep` remain.

    Lossy downlink compression: each dropped limb divides the scale by that
    limb's prime, trading ~log2(q) bits of plaintext precision for a
    (L-keep)/L cut in ciphertext bytes.  decode must go through the
    any-limb-count np path when keep < 2.
    """
    assert 1 <= keep <= ct.n_limbs
    while ct.n_limbs > keep:
        ct = rescale(ctx, ct)
    return ct


def decrypt_to_coeffs(ctx: CkksContext, sk: dict, ct: Ciphertext):
    """-> u32[B, L, N] coefficient-domain residues of m + noise.
    Handles rescaled ciphertexts (fewer limbs than the context)."""
    s = sk["s_mont"][: ct.n_limbs]
    phase = ops.mul_add(ct.c1, s[None], ct.c0, ctx)
    return ops.ntt_inv(phase, ctx)


def decrypt_values(ctx: CkksContext, sk: dict, ct: Ciphertext):
    """-> f32[B, slots] (jnp decode path, 2-limb)."""
    return encoding.decode_jnp(decrypt_to_coeffs(ctx, sk, ct), ctx, ct.scale)


def decrypt_values_np(ctx: CkksContext, sk: dict, ct: Ciphertext) -> np.ndarray:
    """High-precision host decode (any limb count)."""
    coeffs = np.asarray(decrypt_to_coeffs(ctx, sk, ct))
    return encoding.decode_np(coeffs, ctx, ct.scale)


# ---------------------------------------------------------------------------
# homomorphic ops
# ---------------------------------------------------------------------------

def _limbs_to_minus2(data):
    """[..., L, 2, N] -> [..., 2, L, N]: ops.* helpers broadcast per-limb
    constants over axis -2, so the limb axis must sit there."""
    return jnp.moveaxis(data, -3, -2)


def _limbs_to_minus3(data):
    return jnp.moveaxis(data, -2, -3)


def add(ctx: CkksContext, a: Ciphertext, b: Ciphertext) -> Ciphertext:
    assert abs(a.scale - b.scale) < 1e-6 * a.scale
    out = ops.mod_add(_limbs_to_minus2(a.data), _limbs_to_minus2(b.data), ctx)
    return Ciphertext(data=_limbs_to_minus3(out), scale=a.scale)


def mul_plain_scalar(ctx: CkksContext, ct: Ciphertext, w: float) -> Ciphertext:
    """ct x plaintext scalar (encoded at delta): one multiplicative depth."""
    w_mont = encoding.encode_scalar_residues(w, ctx)   # u32[L]
    wb = jnp.asarray(w_mont)[:, None]                  # [L, N->bcast]
    out = ops.mont_mul(_limbs_to_minus2(ct.data), wb, ctx)
    return Ciphertext(data=_limbs_to_minus3(out), scale=ct.scale * ctx.delta)


def mul_plain_vec(ctx: CkksContext, ct: Ciphertext, pt_mont) -> Ciphertext:
    """ct x plaintext vector; pt_mont: u32[L, N] NTT-domain Montgomery."""
    out = ops.mont_mul(_limbs_to_minus2(ct.data), pt_mont, ctx)
    return Ciphertext(data=_limbs_to_minus3(out), scale=ct.scale * ctx.delta)


def weighted_sum(ctx: CkksContext, cts: Ciphertext, weights) -> Ciphertext:
    """Fused FedAvg aggregation: sum_i w_i * ct_i over the leading axis.

    cts.data: u32[C, ..., L, 2, N]; weights: python floats len C.
    Uses the fused kernel (single pass over client ciphertexts).
    """
    w_mont = np.stack([encoding.encode_scalar_residues(float(w), ctx)
                       for w in weights], axis=0)     # [C, L]
    # fold the (c0,c1) component axis into batch: [C, ..., L, 2, N] ->
    # [C, ..., 2, L, N] so the kernel sees limbs at axis -2.
    x = jnp.moveaxis(cts.data, -3, -2)
    out = ops.weighted_sum(x, jnp.asarray(w_mont), ctx)
    return Ciphertext(data=jnp.moveaxis(out, -2, -3),
                      scale=cts.scale * ctx.delta)


def rescale(ctx: CkksContext, ct: Ciphertext) -> Ciphertext:
    """Drop the last RNS limb: c'_j = (c_j - lift(c_last)) * q_last^{-1} mod q_j.

    Needs a domain switch for the last limb (iNTT under q_last, re-NTT under
    each remaining q_j) because NTT evaluation points differ per prime.
    """
    l = ct.n_limbs
    assert l >= 2
    q_last = ctx.primes[l - 1]
    lc_last = ctx.limbs[l - 1]
    # last limb to coefficient domain (exact)
    c_last_ntt = ct.data[..., l - 1, :, :]
    flat = c_last_ntt.reshape((-1, ctx.n_poly))
    c_last = _ref.ntt_inv(flat, jnp.asarray(lc_last.psi_inv_rev_mont),
                          np.asarray(lc_last.n_inv_mont),
                          np.uint32(q_last), np.uint32(lc_last.qinv_neg))
    new_limbs = []
    for j in range(l - 1):
        qj = ctx.primes[j]
        lcj = ctx.limbs[j]
        # centered lift of v in [0, q_last) into Z_qj: primes are within 2x of
        # each other, so v mod qj needs at most one conditional subtract.
        half = np.uint32(q_last // 2)
        if q_last > qj:
            v_mod = jnp.where(c_last >= np.uint32(qj), c_last - np.uint32(qj),
                              c_last)
        else:
            v_mod = c_last
        lifted = jnp.where(
            c_last > half,
            _ref.mod_sub(v_mod, np.uint32(q_last % qj), np.uint32(qj)),
            v_mod,
        )
        lifted_ntt = _ref.ntt_fwd(lifted, jnp.asarray(lcj.psi_rev_mont),
                                  np.uint32(qj), np.uint32(lcj.qinv_neg))
        cj = ct.data[..., j, :, :].reshape((-1, ctx.n_poly))
        diff = _ref.mod_sub(cj, lifted_ntt, np.uint32(qj))
        inv_mont = np.uint32(pow(q_last, -1, qj) * (1 << 32) % qj)
        outj = _ref.mont_mul(diff, jnp.broadcast_to(inv_mont, diff.shape),
                             np.uint32(qj), np.uint32(lcj.qinv_neg))
        new_limbs.append(outj.reshape(ct.data[..., j, :, :].shape))
    data = jnp.stack(new_limbs, axis=-3)
    return Ciphertext(data=data, scale=ct.scale / q_last)
