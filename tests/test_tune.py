"""Autotuner tests (kernels/tune.py + the `auto` backend, DESIGN.md §12).

Four contracts:

  1. **Bit-exactness across the FULL swept grid** — every candidate the
     tuner can ever pick (backend x block_b x ntt4_split x radix) must
     reproduce the checked-in gold KATs exactly.  Tuning may only change
     launch geometry, never bits.
  2. **Cache round-trip** — save -> load resolves to the same
     (backend, config); stale entries (wrong platform tag, unknown op,
     bogus backend, malformed config) are ignored one by one.
  3. **`auto` registry behaviour** — dispatch resolves through the cache,
     `backend_token()` carries the tuner generation (so cached jitted
     graphs retrace on cache changes) exactly when `auto` is assigned.
  4. **Ragged batches** — B not divisible by block_b on every op.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.ckks import params as ckks_params
from repro.kernels import ops, ref, tune

import gold


@pytest.fixture(autouse=True)
def _restore_registry_and_cache():
    """Every test runs against a clean tuner cache and leaves the backend
    registry exactly as it found it."""
    old = {op: ops.get_backend(op) for op in ops.OPS}
    tune.clear_cache()
    try:
        yield
    finally:
        for op, name in old.items():
            ops.set_backend(name, op=op)
        tune.clear_cache()


def _ctx(name="n64_l2"):
    return ckks_params.make_context(**gold.KAT_CONTEXTS[name])


def _inputs(op, ctx, b, seed=7):
    rng = np.random.RandomState(seed)
    l = ctx.n_limbs

    def rand(shape):
        return jnp.asarray(ref.rand_limbed_np(rng, ctx, shape))

    w = jnp.asarray(rng.randint(
        1, int(np.asarray(ctx.tables.qs).min()),
        size=(max(b, 4), l)).astype(np.uint32))
    if op in ("ntt_fwd", "ntt_inv"):
        return (rand((b,)),)
    if op == "mul_add":
        return (rand((b,)), rand((b,)), rand((b,)))
    if op == "mod_lift":
        # full-range u32 words with no limb axis: the pre-RNS masked rows
        # of the transcipher uplink (DESIGN.md §15)
        return (jnp.asarray(rng.randint(
            0, 1 << 32, size=(b, ctx.n_poly),
            dtype=np.uint64).astype(np.uint32)),)
    if op == "weighted_sum":
        return (rand((3, b)), w[:3])
    if op == "weighted_accum":
        return (rand((b,)), rand((b,)), w[0])
    if op == "weighted_accum_chunks":
        return (rand((b,)), rand((b,)), w[:b])
    raise ValueError(op)


# ---------------------------------------------------------------------------
# 1. full swept grid is bit-exact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("ctx_name", sorted(gold.KAT_CONTEXTS))
@pytest.mark.parametrize("op", tune.NTT_OPS)
def test_full_ntt_grid_reproduces_golden_kats(ctx_name, op):
    """Every (backend, block_b, ntt4_split, radix) candidate reproduces
    the golden NTT vectors bit-exactly — the tuner cannot pick a config
    that changes ciphertext bits."""
    golden = gold.load_kats()[f"{ctx_name}/{op}"]
    ctx = _ctx(ctx_name)
    rng = np.random.RandomState(12345)
    x = jnp.asarray(ref.rand_limbed_np(rng, ctx, (2,)))  # the KAT input
    t = ctx.tables.take(ctx.n_limbs)
    cands = tune.candidates(op, ctx.n_poly, ctx.n_limbs, 2, interpret=True)
    # the grid really is the full cross product, not a truncation
    splits = ckks_params.ntt4_split_candidates(ctx.n_poly)
    blocks = [blk for blk in tune.BLOCK_CANDIDATES if blk <= 2]
    assert len(cands) >= 1 + len(blocks) * (
        1 + len(splits) * len(tune.RADIX_CANDIDATES))
    for cand in cands:
        got = np.asarray(ops.run_config(op, cand.backend, cand.config, t, x))
        np.testing.assert_array_equal(
            got, golden,
            err_msg=f"swept config drifted from golden KAT: {cand}")


@pytest.mark.parametrize("op", [o for o in ops.OPS if o not in tune.NTT_OPS])
def test_full_block_grid_matches_ref(op):
    """Non-NTT ops: every block_b candidate is bit-identical to the jnp
    oracle (block size only re-tiles the grid)."""
    ctx = _ctx()
    b = 6
    args = _inputs(op, ctx, b)
    t = ctx.tables.take(ctx.n_limbs)
    want = np.asarray(ops.run_config(op, "ref", None, t, *args))
    for cand in tune.candidates(op, ctx.n_poly, ctx.n_limbs, b,
                                interpret=True):
        got = np.asarray(ops.run_config(op, cand.backend, cand.config, t,
                                        *args))
        np.testing.assert_array_equal(got, want, err_msg=str(cand))


# ---------------------------------------------------------------------------
# 2. cache round-trip + staleness
# ---------------------------------------------------------------------------


def test_cache_round_trip(tmp_path):
    import jax

    platform = jax.default_backend()
    cfg = tune.KernelConfig(block_b=2, ntt4_split=(16, 4), radix=4)
    tune.put("ntt_fwd", 64, 2, 5, platform, "pallas4", cfg,
             tuned_ms=1.0, default_ms=2.0)
    tune.put("mul_add", 64, 2, 5, platform, "pallas",
             tune.KernelConfig(block_b=16))
    path = tmp_path / "cache.json"
    tune.save_cache(str(path))
    tune.clear_cache()
    assert tune.resolve("ntt_fwd", 64, 2, 5, True) == \
        ("ref", tune.default_config("ntt_fwd"))
    assert tune.load_cache(str(path)) == 2
    backend, got = tune.resolve("ntt_fwd", 64, 2, 5, True)
    assert (backend, got) == ("pallas4", cfg)
    backend, got = tune.resolve("mul_add", 64, 2, 5, True)
    assert (backend, got) == ("pallas", tune.KernelConfig(block_b=16))
    # the meta block records where the numbers came from
    doc = json.loads(path.read_text())
    assert doc["meta"]["platform"] == platform
    assert doc["version"] == tune.CACHE_VERSION


def test_stale_entries_ignored(tmp_path):
    """Entries for another platform, unknown ops, bogus backends, or
    malformed configs load as 'no entry', never as garbage."""
    import jax

    platform = jax.default_backend()
    good_key = tune.shape_key("ntt_fwd", 64, 2, 5, platform)
    doc = {
        "version": tune.CACHE_VERSION,
        "entries": {
            good_key: {"backend": "pallas",
                       "config": {"block_b": 4}},
            tune.shape_key("ntt_fwd", 64, 2, 5, "not_a_platform"):
                {"backend": "pallas", "config": {"block_b": 2}},
            tune.shape_key("no_such_op", 64, 2, 5, platform):
                {"backend": "pallas", "config": {"block_b": 2}},
            tune.shape_key("ntt_inv", 64, 2, 5, platform):
                {"backend": "auto", "config": {"block_b": 2}},
            tune.shape_key("mul_add", 64, 2, 5, platform):
                {"backend": "pallas", "config": {"block_b": "huge"}},
        },
    }
    path = tmp_path / "stale.json"
    path.write_text(json.dumps(doc))
    assert tune.load_cache(str(path)) == 1
    assert tune.resolve("ntt_fwd", 64, 2, 5, True) == \
        ("pallas", tune.KernelConfig(block_b=4))
    for op in ("ntt_inv", "mul_add"):
        assert tune.resolve(op, 64, 2, 5, True) == \
            ("ref", tune.default_config(op))


def test_missing_cache_file_loads_empty(tmp_path):
    assert tune.load_cache(str(tmp_path / "absent.json")) == 0
    (tmp_path / "garbage.json").write_text("{not json")
    assert tune.load_cache(str(tmp_path / "garbage.json")) == 0


# ---------------------------------------------------------------------------
# 3. `auto` registry behaviour
# ---------------------------------------------------------------------------


def test_auto_dispatch_resolves_from_cache():
    import jax

    ctx = _ctx()
    rng = np.random.RandomState(3)
    x = jnp.asarray(ref.rand_limbed_np(rng, ctx, (5,)))
    ops.set_backend("ref")
    want = np.asarray(ops.ntt_fwd(x, ctx))
    ops.set_backend("auto")
    # miss -> fallback, still bit-exact
    np.testing.assert_array_equal(np.asarray(ops.ntt_fwd(x, ctx)), want)
    # hit -> the cached pallas4 variant config, still bit-exact
    tune.put("ntt_fwd", ctx.n_poly, ctx.n_limbs, 5, jax.default_backend(),
             "pallas4", tune.KernelConfig(block_b=2, ntt4_split=(16, 4),
                                          radix=4))
    np.testing.assert_array_equal(np.asarray(ops.ntt_fwd(x, ctx)), want)


def test_backend_token_carries_tune_generation():
    ops.set_backend("pallas")
    tok = ops.backend_token()
    assert not any(k == "tune" for k, _ in tok), tok
    ops.set_backend("auto")
    tok1 = ops.backend_token()
    assert any(k == "tune" for k, _ in tok1), tok1
    # a cache edit bumps the generation -> new static jit key -> retrace
    tune.put("ntt_fwd", 64, 2, 5, "cpu", "pallas",
             tune.KernelConfig(block_b=2))
    tok2 = ops.backend_token()
    assert tok2 != tok1
    tune.clear_cache()
    assert ops.backend_token() != tok2


def test_auto_in_env_canon_and_set_backend():
    assert "auto" in ops.BACKENDS
    ops.set_backend("ref")  # pin a uniform base: the env leg may start auto
    ops.set_backend("auto", op="mul_add")
    assert ops.get_backend("mul_add") == "auto"
    assert ops.get_backend() == "mixed"


def test_unknown_env_backend_fails_at_import_with_pointer():
    """REPRO_HE_BACKEND=bogus must fail AT IMPORT with an actionable
    message naming the README env table, not as a later bare KeyError."""
    env = dict(os.environ, REPRO_HE_BACKEND="bogus")
    env.setdefault("PYTHONPATH", "src")
    proc = subprocess.run(
        [sys.executable, "-c", "import repro.kernels.ops"],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert proc.returncode != 0
    assert "REPRO_HE_BACKEND" in proc.stderr
    assert "bogus" in proc.stderr
    assert "README" in proc.stderr


def test_provenance_stamps_tuner_state():
    from repro import obs

    ops.set_backend("auto")
    prov = obs.provenance()
    assert prov["tune"]["entries"] == 0
    tune.put("ntt_fwd", 64, 2, 5, "cpu", "pallas",
             tune.KernelConfig(block_b=2))
    assert obs.provenance()["tune"]["entries"] == 1
    ops.set_backend("ref")
    assert "tune" not in obs.provenance()


# ---------------------------------------------------------------------------
# 4. ragged batches
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op", ops.OPS)
def test_ragged_batch_every_op(op):
    """B=5 with block 2/4: the cdiv grid's last partial tile is handled on
    every op, bit-exactly."""
    ctx = _ctx()
    b = 5
    args = _inputs(op, ctx, b)
    t = ctx.tables.take(ctx.n_limbs)
    want = np.asarray(ops.run_config(op, "ref", None, t, *args))
    for blk in (2, 4, 16):
        got = np.asarray(ops.run_config(
            op, "pallas", tune.KernelConfig(block_b=blk), t, *args))
        np.testing.assert_array_equal(got, want,
                                      err_msg=f"{op} block_b={blk}")
    if op in tune.NTT_OPS:
        got = np.asarray(ops.run_config(
            op, "pallas4",
            tune.KernelConfig(block_b=2, ntt4_split=(16, 4), radix=4),
            t, *args))
        np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# sweep machinery
# ---------------------------------------------------------------------------


def test_sweep_winner_never_loses_to_default():
    ctx = _ctx()
    res = tune.sweep_op("mul_add", ctx, b=4, reps=1)
    assert res.tuned_ms <= res.default_ms
    assert res.n_candidates >= 1 + len(
        [blk for blk in tune.BLOCK_CANDIDATES if blk <= 4])
    # the winner was recorded: auto now resolves to it
    backend, cfg = tune.resolve("mul_add", ctx.n_poly, ctx.n_limbs, 4,
                                ops._interpret())
    assert (backend, cfg) == (res.winner.backend, res.winner.config)


def test_roofline_pruning_skips_hopeless_candidates():
    """With the model on, clearly launch-bound configs (block_b=1 at a
    tiny shape) are skipped unmeasured; the default is never pruned."""
    ctx = _ctx()
    res = tune.sweep_op("ntt_fwd", ctx, b=5, reps=1, use_roofline=True)
    assert res.n_pruned > 0
    full = tune.sweep_op("ntt_fwd", ctx, b=5, reps=1, use_roofline=False)
    assert full.n_pruned == 0
