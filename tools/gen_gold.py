#!/usr/bin/env python
"""Regenerate the checked-in gold known-answer vectors.

    PYTHONPATH=src python tools/gen_gold.py            # write + verify
    PYTHONPATH=src python tools/gen_gold.py --check    # verify only (CI)

The vectors (tests/golden/ckks_kats.json) pin NTT fwd/inv, pk + seeded
encrypt, keygen, weighted_sum, and the selective partitioned-update path
(fixed-mask uplink wire bytes, streamed aggregation, merged recovery)
for fixed keys/params on the
`ref` backend; tests/test_gold.py asserts every backend ("ref", "pallas",
"pallas4") reproduces them bit-exactly.  Only regenerate after an
INTENTIONAL stream/format change (e.g. a new sampling order) — the whole
point of the file is that accidental drift fails CI.

--check recomputes on the current environment and diffs against the
checked-in file without writing, so the docs job catches a code change
that silently moved the answers.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, os.path.join(ROOT, "tests"))

import gold  # noqa: E402  (tests/gold.py — the shared KAT layer)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--check", action="store_true",
                    help="verify the checked-in file instead of writing")
    args = ap.parse_args()

    from repro.kernels import ops
    ops.set_backend("ref")          # golden answers are defined by the oracle
    doc = gold.encode_kats(gold.compute_kats())

    if args.check:
        try:
            with open(gold.KAT_PATH) as f:
                have = json.load(f)
        except FileNotFoundError:
            print(f"GOLD ERROR: {gold.KAT_PATH} missing "
                  "(run tools/gen_gold.py)", file=sys.stderr)
            return 1
        errors = []
        for name, e in doc["kats"].items():
            got = have.get("kats", {}).get(name)
            if got is None:
                errors.append(f"missing KAT {name!r}")
            elif got["sha256"] != e["sha256"]:
                errors.append(f"KAT {name!r} drifted: checked-in sha256 "
                              f"{got['sha256'][:12]}.. != recomputed "
                              f"{e['sha256'][:12]}..")
        for extra in set(have.get("kats", {})) - set(doc["kats"]):
            errors.append(f"stale KAT {extra!r} in golden file")
        for err in errors:
            print(f"GOLD ERROR: {err}", file=sys.stderr)
        if errors:
            print("golden KATs drifted — if the change is intentional, "
                  "regenerate with `python tools/gen_gold.py`",
                  file=sys.stderr)
            return 1
        print(f"golden KATs verified ({len(doc['kats'])} vectors)")
        return 0

    os.makedirs(os.path.dirname(gold.KAT_PATH), exist_ok=True)
    with open(gold.KAT_PATH, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print(f"wrote {gold.KAT_PATH} ({len(doc['kats'])} vectors)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
