"""Pallas TPU kernel: fused encrypted FedAvg aggregation (one RNS limb).

The server hot loop of the paper is  sum_i alpha_i * [[W_i]]  over client
ciphertexts.  Library implementations (PALISADE/TenSEAL wrappers) materialize
each weighted ciphertext in memory before the add; at HE's low arithmetic
intensity that doubles HBM traffic.  This kernel fuses weight-multiply +
modular accumulate: each ciphertext element is read exactly once, the
accumulator lives in VMEM.

Layout: cts u32[n_clients, B, N] (normal form, NTT domain), w_mont
u32[n_clients] Montgomery-form scalar weights (round(alpha_i * delta) * R).
Grid tiles B; the client loop is unrolled inside the kernel.

VMEM: n_clients * block_b * N * 4B; for 16 clients, block_b=4, N=8192 ->
2 MiB in + 128 KiB out.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref as _ref


def _agg_body(cts_ref, w_ref, o_ref, *, q: int, qinv_neg: int, n_clients: int):
    w = w_ref[...]
    acc = _ref.mont_mul(
        cts_ref[0], jnp.broadcast_to(w[0], cts_ref[0].shape), q, qinv_neg
    )
    for i in range(1, n_clients):
        term = _ref.mont_mul(
            cts_ref[i], jnp.broadcast_to(w[i], cts_ref[i].shape), q, qinv_neg
        )
        acc = _ref.mod_add(acc, term, q)
    o_ref[...] = acc


@functools.lru_cache(maxsize=128)
def _build(n_clients: int, b: int, n: int, q: int, qinv_neg: int,
           block_b: int, interpret: bool):
    body = functools.partial(_agg_body, q=q, qinv_neg=qinv_neg, n_clients=n_clients)

    def call(cts, w_mont):
        grid = (pl.cdiv(b, block_b),)
        return pl.pallas_call(
            body,
            grid=grid,
            in_specs=[
                pl.BlockSpec((n_clients, block_b, n), lambda i: (0, i, 0)),
                pl.BlockSpec((n_clients,), lambda i: (0,)),
            ],
            out_specs=pl.BlockSpec((block_b, n), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((b, n), jnp.uint32),
            interpret=interpret,
        )(cts, w_mont)

    return call


def he_weighted_sum(cts, w_mont, q: int, qinv_neg: int, *, block_b: int = 4,
                    interpret: bool = True):
    """sum_i w_i (*) ct_i mod q.  cts: u32[C, B, N], w_mont: u32[C]."""
    c, b, n = cts.shape
    call = _build(c, b, n, int(q), int(qinv_neg), min(block_b, b), interpret)
    return call(cts, w_mont)


# ---------------------------------------------------------------------------
# streaming variant: one client at a time into a running accumulator
# ---------------------------------------------------------------------------
#
# The batch kernel above needs all n_clients ciphertexts resident to fuse the
# client loop; at production scale ("millions of users") the server cannot
# materialize them.  The streaming kernel processes each arriving ciphertext
# as  acc' = acc + w (*) ct  — same fused multiply-accumulate, identical
# modular arithmetic (so the result is bit-for-bit equal to the batch path
# applied in arrival order), but server memory stays at one accumulator plus
# one in-flight ciphertext regardless of client count.


def _accum_body(ct_ref, acc_ref, w_ref, o_ref, *, q: int, qinv_neg: int):
    term = _ref.mont_mul(
        ct_ref[...], jnp.broadcast_to(w_ref[0], ct_ref[...].shape), q, qinv_neg
    )
    o_ref[...] = _ref.mod_add(acc_ref[...], term, q)


@functools.lru_cache(maxsize=128)
def _build_accum(b: int, n: int, q: int, qinv_neg: int, block_b: int,
                 interpret: bool):
    body = functools.partial(_accum_body, q=q, qinv_neg=qinv_neg)

    def call(ct, acc, w_mont):
        grid = (pl.cdiv(b, block_b),)
        spec = pl.BlockSpec((block_b, n), lambda i: (i, 0))
        return pl.pallas_call(
            body,
            grid=grid,
            in_specs=[spec, spec, pl.BlockSpec((1,), lambda i: (0,))],
            out_specs=spec,
            out_shape=jax.ShapeDtypeStruct((b, n), jnp.uint32),
            interpret=interpret,
        )(ct, acc, w_mont)

    return call


def he_weighted_accum(acc, ct, w_mont, q: int, qinv_neg: int, *,
                      block_b: int = 8, interpret: bool = True):
    """acc + w (*) ct mod q.  acc, ct: u32[B, N]; w_mont: u32[1]."""
    b, n = ct.shape
    call = _build_accum(b, n, int(q), int(qinv_neg), min(block_b, b), interpret)
    return call(ct, acc, w_mont)
