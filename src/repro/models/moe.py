"""Mixture-of-Experts FFN with sort-based capacity dispatch.

TPU adaptation: instead of the one-hot einsum dispatch (which inflates HLO
FLOPs by O(E/k)) tokens are argsorted by expert id, packed into [E, C]
capacity slots (C = ceil(T*k/E * capacity_factor)), run through three
batched matmuls (active-expert FLOPs only), and scatter-added back.

Distribution: routing/dispatch runs *locally per data shard* under
jax.shard_map (tokens never cross the data axis — the baseline global-view
alternative would distribute the argsort itself).  Expert weights are
TP-sharded on the d_ff axis; the w_down contraction finishes with an
explicit psum over 'model'.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import sharding
from repro.models.config import ModelConfig


def init(key, cfg: ModelConfig, n_layers: int):
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": L.trunc_normal(ks[0], (n_layers, d, e), 0.02, dt),
        "expert_gate": L.trunc_normal(ks[1], (n_layers, e, d, ff), 0.02, dt),
        "expert_up": L.trunc_normal(ks[2], (n_layers, e, d, ff), 0.02, dt),
        "expert_down": L.trunc_normal(
            ks[3], (n_layers, e, ff, d), 0.02 / math.sqrt(2 * n_layers), dt),
    }


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(n_tokens * cfg.top_k / cfg.n_experts
                      * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)


def _moe_local(x, router_w, w_gate, w_up, w_down, cfg: ModelConfig,
               model_axis: str | None):
    """x: [T, d] (local tokens). Returns (out [T, d], aux scalar)."""
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(t, cfg)
    logits = jnp.einsum("td,de->te", x, router_w.astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                    # [T, k]
    # load-balance aux (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)
    fe = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(fe * me)

    flat_e = top_e.reshape(-1)                                # [T*k]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_tok = order // k
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1]])
    ranks = jnp.arange(t * k, dtype=jnp.int32) - offsets[sorted_e]
    keep = ranks < c
    slot = jnp.where(keep, sorted_e * c + ranks, e * c)       # drop -> last row

    xg = x[sorted_tok] * keep[:, None].astype(x.dtype)
    disp = jnp.zeros((e * c + 1, d), x.dtype).at[slot].add(xg)[:-1]
    h = disp.reshape(e, c, d)
    g = jnp.einsum("ecd,edf->ecf", h, w_gate.astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", h, w_up.astype(x.dtype))
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                   w_down.astype(x.dtype))
    # combine FIRST (it is linear in y), THEN psum the [T, d] result over
    # the ff-sharded axis — psum'ing the [E, C, d] dispatch buffer would
    # move capacity_factor*top_k/1 times more bytes per layer.
    yf = y.reshape(e * c, d)
    back = yf[jnp.minimum(slot, e * c - 1)] * keep[:, None].astype(x.dtype)
    w = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    w_sorted = w.reshape(-1)[order].astype(x.dtype)
    out = jnp.zeros((t, d), x.dtype).at[sorted_tok].add(back * w_sorted[:, None])
    if model_axis is not None:
        out = jax.lax.psum(out, model_axis)
        aux = jax.lax.pmean(aux, model_axis)
    return out, aux


def moe_ffn(p, i, x, cfg: ModelConfig, ax: sharding.AxisEnv):
    """x: [B, S, d] -> ([B, S, d], aux). shard_map'd when a mesh is active."""
    b, s, d = x.shape
    router = p["router"][i]
    wg, wu, wd = p["expert_gate"][i], p["expert_up"][i], p["expert_down"][i]
    mesh = getattr(ax, "mesh", None)
    if mesh is None or (ax.data_size == 1 and ax.model_size == 1):
        out, aux = _moe_local(x.reshape(-1, d), router, wg, wu, wd, cfg, None)
        return out.reshape(b, s, d), aux

    from jax.sharding import PartitionSpec as P
    dp = ax.dp
    mp = ax.model if ax.model_size > 1 else None
    fn = functools.partial(_body, cfg=cfg, model_axis=mp,
                           dp_axes=dp if ax.data_size > 1 else None)
    out, aux = jax.shard_map(
        fn, mesh=mesh,
        in_specs=(P(dp, None, None), P(None, None),
                  P(None, None, mp), P(None, None, mp), P(None, mp, None)),
        out_specs=(P(dp, None, None), P()),
        check_vma=False,
    )(x, router, wg, wu, wd)
    return out, aux


def _body(x, router, wg, wu, wd, *, cfg, model_axis, dp_axes):
    b, s, d = x.shape
    out, aux = _moe_local(x.reshape(-1, d), router, wg, wu, wd, cfg,
                          model_axis)
    if dp_axes is not None:
        aux = jax.lax.pmean(aux, dp_axes)     # replicate across data shards
    return out.reshape(b, s, d), aux
