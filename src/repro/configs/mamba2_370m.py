"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.
Source: arXiv:2405.21060 (unverified tier).
48L d_model=1024 (attn-free) vocab=50280, ssm_state=128."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, vocab=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    tie_embeddings=True,
    dtype="bfloat16", param_dtype="float32", remat=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=2, d_model=64, vocab=257, ssm_state=16, ssm_head_dim=16,
    ssm_chunk=8, tie_embeddings=True,
)
