"""Streaming uplink ingest: fold arriving ciphertext chunks into the
running modular accumulator, never materializing all n_clients updates.

Client side — pack_update_frames() emits, per update:

    UPDATE_BEGIN   (cid, n_samples, round, n_chunks, ct_kind)
    CT_CHUNK * n   (chunk_idx + one-chunk ciphertext/seeded-ciphertext frame)
    PLAIN_SEGMENT  (quantized plaintext partition)
    UPDATE_END

Server side — StreamIngest parses frames incrementally (any byte slicing),
BUFFERS each decoded chunk in a ready queue, and folds the whole queue in
ONE chunk-batched accumulate launch per flush:

    acc[k] = acc[k] + w[k] (*) ct[k]    for every ready row k

via `ops.weighted_accum_chunks` (kernels/he_agg.he_weighted_accum_chunks —
the RNS-limb axis and the ready-row axis are both grid dimensions of a
single `pallas_call`).  `ingest()` flushes once per client update, so the
launch count is O(clients), not O(clients * n_chunks); `accum_launches`
instruments this and tests assert it.  Attaching a `ShardedHe` engine
(core/ckks/sharded.py) shards the flush over the device mesh — ready rows
along ``data``, limbs along ``model`` — with no change in results.

Server-side update buffers stay O(1) in the number of clients: one
accumulator plus at most ONE update's worth of ready chunks
(`peak_chunk_buffers` instruments this; tests assert it).

The modular arithmetic is identical to the batch weighted_sum applied in
arrival order, so the streamed aggregate is bit-for-bit equal to the
in-memory path — flush batching does not change a single bit.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import struct

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.ckks import encoding
from repro.core.ckks import transcipher as _tc
from repro.core.ckks.cipher import Ciphertext
from repro.core.ckks.params import CkksContext
from repro.core.secure_agg import ProtectedUpdate
from repro.kernels import ops
from repro.wire import compress as _c
from repro.wire import format as wf

_BEGIN = struct.Struct("<IIIIB")

CT_FULL = 0
CT_SEEDED = 1
CT_TRANSCIPHER = 2
_CT_KINDS = (CT_FULL, CT_SEEDED, CT_TRANSCIPHER)

# escrow-rollback sentinel: "this (cid, round) had no escrow seed before
# the update under ingest touched it"
_ESCROW_MISSING = object()


@dataclasses.dataclass(frozen=True)
class UpdateMeta:
    cid: int
    n_samples: int
    round: int
    n_chunks: int
    seeded: bool
    transcipher: bool = False


# ---------------------------------------------------------------------------
# client side: update -> frames
# ---------------------------------------------------------------------------


def pack_update_frames(upd: ProtectedUpdate, *, cid: int, n_samples: int,
                       rnd: int = 0,
                       seeded: _c.SeededCiphertext | None = None,
                       plain_codec: str = "f32",
                       version: int | None = None) -> bytes:
    """One client's ProtectedUpdate -> concatenated wire frames.

    Args:
        upd: the update (ct data u32[n_chunks, L, 2, N] + plain f32).
        cid: client id for the UPDATE_BEGIN header.
        n_samples: local sample count (the server's FedAvg weight input).
        rnd: round number for the header.
        seeded: optional compress.seed_compress result; each CT_CHUNK then
            carries (seed, c0-chunk) instead of the full chunk, and its
            `derive` id rides in every per-chunk seeded frame (wire v2).
        plain_codec: "f32" | "f16" | "i8" quantizer for the plain segment.
        version: wire version for every emitted frame (default: the
            REPRO_WIRE_VERSION / wf.VERSION emit default).  version=1
            requires seeded.derive == DERIVE_FOLD_CHUNK.

    Returns:
        bytes: UPDATE_BEGIN + CT_CHUNK * n_chunks + PLAIN_SEGMENT +
        UPDATE_END, each a length-prefixed wire frame (DESIGN.md §6.1,
        §9.2 for the v2 layout diff).
    """
    n_chunks = int(upd.ct.data.shape[0])
    kind = CT_SEEDED if seeded is not None else CT_FULL
    out = [wf.frame(wf.T_UPDATE_BEGIN,
                    _BEGIN.pack(cid, n_samples, rnd, n_chunks, kind),
                    version=version)]
    ct_host = np.asarray(seeded.c0 if seeded is not None else upd.ct.data)
    for b in range(n_chunks):
        if seeded is not None:
            chunk = _c.SeededCiphertext(c0=ct_host[b:b + 1],
                                        seed=seeded.seed, scale=seeded.scale,
                                        chunk_offset=b,
                                        derive=seeded.derive)
            inner = wf.serialize_seeded_ciphertext(chunk, version=version)
        else:
            inner = wf.serialize_ciphertext(Ciphertext(
                data=ct_host[b:b + 1], scale=upd.ct.scale), version=version)
        out.append(wf.frame(wf.T_CT_CHUNK, struct.pack("<I", b) + inner,
                            version=version))
    arr, qscale = _c.quantize_plain(np.asarray(upd.plain), plain_codec)
    out.append(wf.serialize_plain_segment(arr, plain_codec, qscale,
                                          version=version))
    out.append(wf.frame(wf.T_UPDATE_END, b"", version=version))
    return b"".join(out)


def pack_masked_update_frames(masked: _c.MaskedChunk,
                              seed_ct: _c.SeededCiphertext, plain, *,
                              cid: int, n_samples: int, rnd: int = 0,
                              plain_codec: str = "f32",
                              version: int | None = None) -> bytes:
    """One transcipher client's masked update -> concatenated wire frames.

    The thin-client analogue of pack_update_frames: UPDATE_BEGIN (kind =
    CT_TRANSCIPHER) + the escrow TRANSCIPHER_SEED frame + one MASKED_CHUNK
    per row nested in CT_CHUNK + PLAIN_SEGMENT + UPDATE_END.  Transcipher
    frames are v2+ only — version=1 raises the serializer's WireError
    (DESIGN.md §15).

    Args:
        masked: the full masked update (masked u32[n_chunks, N] plus the
            a_seed/derive/scale/chunk_offset the server unmask needs).
        seed_ct: the escrow seeded-ciphertext wire form of the keystream
            seed (compress.seed_compress of ClientMaterials.seed_ct).
        plain: the plaintext partition (selective encryption remainder).
    """
    n_chunks = masked.n_chunks
    host = np.asarray(masked.masked, dtype=np.uint32)
    out = [wf.frame(wf.T_UPDATE_BEGIN,
                    _BEGIN.pack(cid, n_samples, rnd, n_chunks,
                                CT_TRANSCIPHER),
                    version=version),
           wf.serialize_transcipher_seed(seed_ct, version=version)]
    for b in range(n_chunks):
        chunk = _c.MaskedChunk(masked=host[b:b + 1], a_seed=masked.a_seed,
                               scale=masked.scale,
                               chunk_offset=masked.chunk_offset + b,
                               derive=masked.derive)
        inner = wf.serialize_masked_chunk(chunk, version=version)
        out.append(wf.frame(wf.T_CT_CHUNK, struct.pack("<I", b) + inner,
                            version=version))
    arr, qscale = _c.quantize_plain(np.asarray(plain), plain_codec)
    out.append(wf.serialize_plain_segment(arr, plain_codec, qscale,
                                          version=version))
    out.append(wf.frame(wf.T_UPDATE_END, b"", version=version))
    return b"".join(out)


def peek_update_meta(blob: bytes) -> UpdateMeta:
    """Read only the UPDATE_BEGIN header (e.g. to compute FedAvg weights
    before a second ingest pass)."""
    ftype, _, payload, _ = wf.parse_frame(blob, 0)
    if ftype != wf.T_UPDATE_BEGIN:
        raise wf.WireError(f"expected UPDATE_BEGIN, got {ftype:#x}")
    try:
        cid, n_samples, rnd, n_chunks, kind = _BEGIN.unpack_from(payload, 0)
    except struct.error as e:
        raise wf.WireError(f"short UPDATE_BEGIN payload: {e}") from e
    return UpdateMeta(cid=cid, n_samples=n_samples, round=rnd,
                      n_chunks=n_chunks, seeded=kind == CT_SEEDED,
                      transcipher=kind == CT_TRANSCIPHER)


# ---------------------------------------------------------------------------
# server side: streaming modular accumulator
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("ctx", "token"))
def _accum_chunks_graph(ctx: CkksContext, token, accs, cts, w_mont):
    """One chunk-batched fold: acc[k] + w[k] (*) ct[k] for every ready row,
    all limbs and rows in a single launch."""
    return ops.weighted_accum_chunks(accs, cts, w_mont, ctx)


class StreamIngest:
    """Accumulates arriving client updates chunk-by-chunk.

    Decoded chunks are buffered in a ready queue and folded by `flush()` —
    one chunk-batched accumulate launch per flush, not one per chunk.
    `ingest()`/`ingest_update()` flush automatically at the end of each
    update, so at most one update's worth of chunks is ever resident
    (O(1) in the client count; `peak_chunk_buffers` proves it) and the
    launch count is one per client (`accum_launches` proves it).

    Usage:
        ingest = StreamIngest(ctx)
        for blob, w in arriving:   # any interleaving of byte slices works
            ingest.ingest(blob, weight=w)
        agg = ingest.finalize()    # ProtectedUpdate, scale = in_scale*delta

    Attributes:
        accum_launches: accumulate launches issued so far (== flushes that
            had ready chunks; the one-launch-per-flush invariant).
        peak_chunk_buffers: max decoded-but-unfolded chunks ever resident.
        clients_ingested / bytes_ingested: ingest counters.

    All four are views over `repro.obs` registry instruments labeled with
    this instance's ingest id (``wire_ingest_*``), so process-wide
    telemetry and the legacy per-instance attributes read the same value
    by construction (tests/test_obs.py asserts bit-equality).
    """

    _ids = itertools.count()

    def __init__(self, ctx: CkksContext, sharded=None,
                 transcipher_materials: dict | None = None):
        """Args:
            ctx: CkksContext of the arriving ciphertexts.
            sharded: optional core.ckks.sharded.ShardedHe; when given,
                flushes run as sharded graphs over its mesh (ready rows ->
                data axis, limbs -> model axis), bit-identical results.
            transcipher_materials: optional {(cid, round):
                transcipher.ServerMaterials} registry; masked transcipher
                updates from unprovisioned (cid, round) pairs are rejected
                with an actionable WireError (DESIGN.md §15).
        """
        self.ctx = ctx
        self.sharded = sharded
        self._acc_ct = None            # dict chunk_idx -> u32[2, L, N]
        self._acc_plain = None         # f32[n_plain]
        self._in_scale = None
        self._pending = []             # ready queue: (chunk_idx, data, w)
        self._transcipher = dict(transcipher_materials or {})
        # escrow keystream-seed ciphertexts received so far, keyed like the
        # materials registry — the audit trail a key authority can decrypt
        self.escrow_seeds: dict = {}
        # registry-backed instrumentation, one label set per ingest
        # instance (obs.REGISTRY.total("wire_ingest_...") aggregates
        # across instances for process-level telemetry)
        self.ingest_id = str(next(self._ids))
        lab = {"ingest": self.ingest_id}
        self._m_launches = obs.counter("wire_ingest_accum_launches", **lab)
        self._m_clients = obs.counter("wire_ingest_clients", **lab)
        self._m_bytes = obs.counter("wire_ingest_bytes", **lab)
        # O(1)-memory instrumentation: decoded ciphertext chunk buffers
        # resident beyond the accumulator.  Incremented where a chunk is
        # decoded, decremented once it has been folded — a regression that
        # buffers several updates before folding shows up as peak >
        # n_chunks of one update.
        self._m_resident = obs.gauge("wire_ingest_resident_chunks", **lab)
        self._m_peak = obs.gauge("wire_ingest_peak_chunk_buffers", **lab)
        # updates rejected (and atomically rolled back) by ingest(): the
        # aggregation service's fault accounting reads this series
        self._m_rejected = obs.counter("wire_ingest_rejected_updates", **lab)

    # -- legacy counter views (registry-backed) ------------------------------

    @property
    def accum_launches(self) -> int:
        return int(self._m_launches.value)

    @property
    def clients_ingested(self) -> int:
        return int(self._m_clients.value)

    @property
    def bytes_ingested(self) -> int:
        return int(self._m_bytes.value)

    @property
    def peak_chunk_buffers(self) -> int:
        return int(self._m_peak.value)

    @property
    def rejected_updates(self) -> int:
        return int(self._m_rejected.value)

    def add_transcipher_materials(self, cid: int, rnd: int,
                                  materials) -> None:
        """Register one (cid, round)'s transcipher.ServerMaterials before
        its masked update arrives (serve/service.py provisioning path)."""
        self._transcipher[(int(cid), int(rnd))] = materials

    # -- internals ----------------------------------------------------------

    def _w_mont(self, weight: float):
        return jnp.asarray(encoding.encode_scalar_residues(float(weight),
                                                           self.ctx))

    def _note_decoded(self, n: int) -> None:
        self._m_resident.add(n)
        self._m_peak.set_max(self._m_resident.value)

    def _buffer_chunk(self, chunk_idx: int, data, scale: float,
                      w_mont) -> None:
        """Queue one decoded chunk (data u32[1, L, 2, N]) for the next
        flush; validates the scale, dtype, and shape against the running
        aggregation — a wire-mutated chunk must fail HERE, inside ingest's
        rollback scope, not later in a flush the rollback cannot reach."""
        if self._in_scale is None:
            self._in_scale = float(scale)
        elif abs(self._in_scale - scale) > 1e-6 * self._in_scale:
            raise wf.WireError("mixed ciphertext scales in one aggregation")
        data = np.asarray(data)
        if data.dtype != np.uint32:
            raise wf.WireError(
                f"ciphertext chunk dtype {data.dtype} is not uint32")
        if self._acc_ct is None:
            self._n_limbs, self._n = data.shape[-3], data.shape[-1]
            self._acc_ct = {}
        if tuple(data.shape) != (1, self._n_limbs, 2, self._n):
            raise wf.WireError(
                f"ciphertext chunk shape {tuple(data.shape)} does not match "
                f"this aggregation's (1, {self._n_limbs}, 2, {self._n})")
        self._note_decoded(+1)
        # limbs to axis -2 (ops layout): [1, L, 2, N] -> [2, L, N]
        x = jnp.moveaxis(jnp.asarray(data), -3, -2)[0]
        self._pending.append((int(chunk_idx), x, w_mont))

    def flush(self) -> None:
        """Fold every ready chunk into the accumulator — ONE chunk-batched
        accumulate launch per pass (a second pass only happens if the same
        chunk index was buffered twice, to preserve arrival order)."""
        while self._pending:
            batch, rest, seen = [], [], set()
            for item in self._pending:
                if item[0] in seen:
                    rest.append(item)
                else:
                    seen.add(item[0])
                    batch.append(item)
            self._pending = rest
            idxs = [i for i, _, _ in batch]
            cts = jnp.stack([x for _, x, _ in batch])          # [K, 2, L, N]
            ws = jnp.stack([w for _, _, w in batch])           # [K, L]
            zero = jnp.zeros((2, self._n_limbs, self._n), dtype=jnp.uint32)
            accs = jnp.stack([self._acc_ct.get(i, zero) for i in idxs])
            token = ops.backend_token()
            with obs.kernel_launch("weighted_accum_chunks", token,
                                   rows=len(batch),
                                   sharded=self.sharded is not None) as kl:
                if self.sharded is not None:
                    out = kl.done(
                        self.sharded.weighted_accum_chunks(accs, cts, ws))
                else:
                    out = kl.done(_accum_chunks_graph(self.ctx, token,
                                                      accs, cts, ws))
            self._m_launches.inc()
            for j, i in enumerate(idxs):
                self._acc_ct[i] = out[j]
            self._note_decoded(-len(batch))

    def _unmask_chunk(self, meta: UpdateMeta, mc: _c.MaskedChunk):
        """Transcipher one arriving masked chunk into its seeded-equivalent
        ciphertext (core/ckks/transcipher.server_unmask).  Runs inside the
        ingest rollback scope: unprovisioned or mismatched materials reject
        the whole update atomically."""
        sm = self._transcipher.get((meta.cid, meta.round))
        if sm is None:
            raise wf.WireError(
                f"no transcipher materials provisioned for client "
                f"{meta.cid} round {meta.round}; register ServerMaterials "
                f"(transcipher.provision) before ingest (DESIGN.md §15)")
        if int(mc.a_seed) != int(sm.a_seed) \
                or int(mc.derive) != int(sm.derive):
            raise wf.WireError(
                f"masked chunk parameters (a_seed={mc.a_seed}, "
                f"derive={mc.derive}) do not match the provisioned "
                f"materials (a_seed={sm.a_seed}, derive={sm.derive}) for "
                f"client {meta.cid} round {meta.round}")
        try:
            return _tc.server_unmask(self.ctx, sm, mc.masked,
                                     int(mc.chunk_offset))
        except ValueError as e:
            raise wf.WireError(f"transcipher unmask failed: {e}") from e

    def _fold_plain_decoded(self, plain: np.ndarray, weight: float) -> None:
        if self._acc_plain is None:
            self._acc_plain = np.zeros(plain.shape, dtype=np.float32)
        elif plain.shape != self._acc_plain.shape:
            raise wf.WireError(
                f"plain segment shape {plain.shape} does not match this "
                f"aggregation's {self._acc_plain.shape}")
        self._acc_plain += np.float32(weight) * plain

    def _fold_plain(self, arr, codec: str, qscale: float,
                    weight: float) -> None:
        self._fold_plain_decoded(_c.dequantize_plain(arr, codec, qscale),
                                 weight)

    # -- public API ---------------------------------------------------------

    def ingest(self, blob: bytes, weight: float) -> UpdateMeta:
        """Parse one client's frames, buffer its chunks, and flush them in
        one accumulate launch.

        Validates the stream against its own UPDATE_BEGIN header: the set
        of received chunk indices must be exactly {0..n_chunks-1} — a
        dropped or duplicated CT_CHUNK frame is an error, never a silent
        partial contribution to the aggregate.

        Args:
            blob: one client's serialized frame stream.
            weight: FedAvg weight for this client.

        Returns:
            The update's UpdateMeta header.
        """
        with obs.span("wire.ingest", nbytes=len(blob)) as sp:
            meta = self._ingest_spanned(blob, weight, sp)
        return meta

    def _ingest_spanned(self, blob: bytes, weight: float, sp) -> UpdateMeta:
        meta = None
        w_mont = self._w_mont(weight)
        saw_end = False
        chunks_seen: set[int] = set()
        plain_segments = []            # folded only after validation
        n_buffered = 0
        escrow_prev: dict = {}         # escrow keys this update touched
                                       # -> prior value (or _ESCROW_MISSING)
        prev_in_scale = self._in_scale
        acc_was_uninit = self._acc_ct is None
        try:
            for ftype, _, payload in wf.iter_frames(blob):
                if ftype == wf.T_UPDATE_BEGIN:
                    cid, n_samples, rnd, n_chunks, kind = _BEGIN.unpack_from(
                        payload, 0)
                    if kind not in _CT_KINDS:
                        raise wf.WireError(
                            f"unknown ct_kind {kind} in UPDATE_BEGIN; this "
                            f"build implements {_CT_KINDS}")
                    meta = UpdateMeta(cid, n_samples, rnd, n_chunks,
                                      kind == CT_SEEDED,
                                      kind == CT_TRANSCIPHER)
                elif ftype == wf.T_CT_CHUNK:
                    if meta is None:
                        raise wf.WireError("CT_CHUNK before UPDATE_BEGIN")
                    (chunk_idx,) = struct.unpack_from("<I", payload, 0)
                    if chunk_idx >= meta.n_chunks:
                        raise wf.WireError(
                            f"chunk index {chunk_idx} >= declared "
                            f"n_chunks {meta.n_chunks}")
                    if chunk_idx in chunks_seen:
                        raise wf.WireError(f"duplicate chunk {chunk_idx}")
                    chunks_seen.add(chunk_idx)
                    inner, _ = wf.deserialize(payload, self.ctx, off=4)
                    # the nested payload kind must MATCH the declared
                    # ct_kind: dispatching on isinstance alone would let a
                    # masked chunk slip into a seeded/full update (or vice
                    # versa), misclassifying UpdateMeta and the ledger —
                    # a wire-consistency violation, rejected atomically
                    got = ("masked" if isinstance(inner, _c.MaskedChunk)
                           else "seeded"
                           if isinstance(inner, _c.SeededCiphertext)
                           else "full")
                    want = ("masked" if meta.transcipher
                            else "seeded" if meta.seeded else "full")
                    if got != want:
                        raise wf.WireError(
                            f"CT_CHUNK {chunk_idx} carries a {got} payload "
                            f"but the update's declared ct_kind expects "
                            f"{want}")
                    if isinstance(inner, _c.MaskedChunk):
                        inner = self._unmask_chunk(meta, inner)
                    elif isinstance(inner, _c.SeededCiphertext):
                        inner = inner.expand(self.ctx)
                    self._buffer_chunk(chunk_idx, inner.data, inner.scale,
                                       w_mont)
                    n_buffered += 1
                elif ftype == wf.T_TRANSCIPHER_SEED:
                    if meta is None:
                        raise wf.WireError(
                            "TRANSCIPHER_SEED before UPDATE_BEGIN")
                    if not meta.transcipher:
                        raise wf.WireError(
                            "TRANSCIPHER_SEED frame in a non-transcipher "
                            "update (declared ct_kind is not "
                            "CT_TRANSCIPHER)")
                    sct, _ = wf.deserialize(payload, self.ctx, off=0)
                    if not isinstance(sct, _c.SeededCiphertext):
                        raise wf.WireError(
                            "TRANSCIPHER_SEED must nest a seeded-"
                            f"ciphertext frame, got {type(sct).__name__}")
                    escrow_key = (meta.cid, meta.round)
                    if escrow_key not in escrow_prev:
                        escrow_prev[escrow_key] = self.escrow_seeds.get(
                            escrow_key, _ESCROW_MISSING)
                    self.escrow_seeds[escrow_key] = sct
                elif ftype == wf.T_PLAIN_SEGMENT:
                    # decode AND shape-validate inside the rollback scope —
                    # a wire-mutated dim must reject the whole update here;
                    # the fold after validation then cannot fail, so the
                    # success path needs no accumulator snapshot
                    plain = _c.dequantize_plain(
                        *wf._parse_plain_segment(payload))
                    ref_shape = (self._acc_plain.shape
                                 if self._acc_plain is not None
                                 else plain_segments[0].shape
                                 if plain_segments else None)
                    if ref_shape is not None and plain.shape != ref_shape:
                        raise wf.WireError(
                            f"plain segment shape {plain.shape} does not "
                            f"match this aggregation's {ref_shape}")
                    plain_segments.append(plain)
                elif ftype == wf.T_UPDATE_END:
                    saw_end = True
                else:
                    raise wf.WireError(f"unexpected frame type {ftype:#x} "
                                       "in update stream")
            if meta is None or not saw_end:
                raise wf.WireError("truncated update stream")
            if len(chunks_seen) != meta.n_chunks:
                raise wf.WireError(
                    f"update declared {meta.n_chunks} chunks, "
                    f"received {len(chunks_seen)}")
        except Exception as e:
            # rejected update: NOTHING of it may reach the accumulator —
            # drop its queued chunks and roll back any state its chunks
            # initialized (struct.error etc. count as rejections too)
            if n_buffered:
                del self._pending[len(self._pending) - n_buffered:]
                self._note_decoded(-n_buffered)
            # restore every escrow entry this update touched to its PRIOR
            # value — a rejected re-submission must not leave its seed
            # ciphertext shadowing the accepted one in the audit trail
            for k, prev in escrow_prev.items():
                if prev is _ESCROW_MISSING:
                    self.escrow_seeds.pop(k, None)
                else:
                    self.escrow_seeds[k] = prev
            self._in_scale = prev_in_scale
            if acc_was_uninit:
                # the rejected chunks must not pin the limb/poly dims either
                self._acc_ct = None
            self._m_rejected.inc()
            if isinstance(e, wf.WireError):
                raise
            # uniform rejection contract (fuzzed in tests/test_wire.py):
            # corrupt payloads that slip past the frame envelope surface as
            # WireError here, never as a raw struct/numpy error
            raise wf.WireError(f"malformed update stream: {e!r}") from e
        # validated above: these folds cannot fail, so no rollback is needed
        # past this point (and no per-ingest accumulator snapshot either)
        for plain in plain_segments:
            self._fold_plain_decoded(plain, weight)
        self.flush()
        self._m_clients.inc()
        self._m_bytes.inc(len(blob))
        sp.set(cid=meta.cid, round=meta.round, n_chunks=meta.n_chunks)
        return meta

    def ingest_update(self, upd: ProtectedUpdate, weight: float) -> None:
        """In-memory streaming (no serialization): the caller already holds
        the whole decoded update; its chunks are buffered and folded in one
        flush — still O(1) in the client count."""
        with obs.span("wire.ingest", in_memory=True):
            w_mont = self._w_mont(weight)
            data = np.asarray(upd.ct.data)
            for b in range(data.shape[0]):
                self._buffer_chunk(b, data[b:b + 1], upd.ct.scale, w_mont)
            self.flush()
            self._fold_plain(np.asarray(upd.plain), "f32", 1.0, weight)
            self._m_clients.inc()

    # -- checkpointing (repro.serve crash-safe resume) -----------------------

    def export_state(self) -> tuple[dict, dict]:
        """-> (arrays, meta): the full accumulator state as a
        checkpointable pytree of numpy arrays plus a json-safe meta dict.

        The split matches `ckpt.store.save_checkpoint(tree, extra)`:
        arrays ride the npz payload, scalars the manifest.  Restoring via
        `restore_state` and continuing is bit-exact — the modular
        accumulator is exact integers and `acc_plain` is the literal f32
        partial sum, so the resumed fold reproduces the uninterrupted
        run's bits (tests/test_serve.py asserts it at every crash point).

        Raises RuntimeError with unflushed chunks pending: flush() (or
        ingest(), which flushes) before checkpointing.
        """
        if self._pending:
            raise RuntimeError("cannot export StreamIngest state with "
                               "unflushed chunks pending; call flush()")
        idxs = sorted(self._acc_ct) if self._acc_ct else []
        arrays = {
            "chunk_idx": np.asarray(idxs, dtype=np.int32),
            "acc_ct": (np.stack([np.asarray(self._acc_ct[i]) for i in idxs])
                       if idxs else np.zeros((0, 2, 0, 0), dtype=np.uint32)),
            "acc_plain": (np.asarray(self._acc_plain)
                          if self._acc_plain is not None
                          else np.zeros((0,), dtype=np.float32)),
        }
        meta = {
            "in_scale": self._in_scale,
            "has_plain": self._acc_plain is not None,
            "clients": self.clients_ingested,
            "bytes": self.bytes_ingested,
            "launches": self.accum_launches,
            "rejected": self.rejected_updates,
        }
        return arrays, meta

    def restore_state(self, arrays: dict, meta: dict) -> None:
        """Load a checkpointed accumulator (the export_state inverse) into
        this EMPTY ingest; counters resume at their checkpointed values so
        launch/byte accounting survives a restart."""
        if self._acc_ct is not None or self._pending \
                or self.clients_ingested:
            raise RuntimeError("restore_state needs a fresh StreamIngest")
        idxs = np.asarray(arrays["chunk_idx"]).tolist()
        acc = np.asarray(arrays["acc_ct"])
        if idxs:
            self._n_limbs = int(acc.shape[-2])
            self._n = int(acc.shape[-1])
            self._acc_ct = {int(i): jnp.asarray(acc[j])
                            for j, i in enumerate(idxs)}
        if meta.get("has_plain"):
            self._acc_plain = np.asarray(arrays["acc_plain"],
                                         dtype=np.float32).copy()
        if meta.get("in_scale") is not None:
            self._in_scale = float(meta["in_scale"])
        self._m_clients.inc(int(meta.get("clients", 0)))
        self._m_bytes.inc(int(meta.get("bytes", 0)))
        self._m_launches.inc(int(meta.get("launches", 0)))
        self._m_rejected.inc(int(meta.get("rejected", 0)))

    def finalize(self) -> ProtectedUpdate:
        """-> aggregated ProtectedUpdate (ct scale = in_scale * delta).
        Raises WireError if nothing arrived or chunk indices have holes."""
        self.flush()
        if self.clients_ingested == 0 or self._acc_ct is None:
            raise wf.WireError("no updates ingested")
        n_chunks = max(self._acc_ct) + 1
        if sorted(self._acc_ct) != list(range(n_chunks)):
            raise wf.WireError("missing ciphertext chunks at finalize")
        data = jnp.stack([jnp.moveaxis(self._acc_ct[b], -3, -2)
                          for b in range(n_chunks)], axis=0)
        ct = Ciphertext(data=data, scale=self._in_scale * self.ctx.delta)
        plain = jnp.asarray(self._acc_plain if self._acc_plain is not None
                            else np.zeros((0,), np.float32))
        return ProtectedUpdate(ct=ct, plain=plain)
