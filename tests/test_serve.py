"""repro.serve: the async aggregation service under fire.

Three families (DESIGN.md §14):
  * fault injection — every faults.py wire mode against a live round; the
    final aggregate must be BIT-identical to a clean synchronous ingest
    over exactly the surviving clients, and the reject metrics must count.
  * crash-restart — kill (SimulatedCrash) at every checkpoint boundary,
    resume from ckpt/store.py, and the finished round must reproduce the
    uninterrupted run's bits, with the bandwidth ledger losing no bytes.
  * quorum properties — any accepted set >= min_clients can finalize,
    below never, and weights renormalize over the survivors (hypothesis
    widens the search where installed; deterministic sweeps always run).

Runs under whatever REPRO_HE_BACKEND is set (the CI matrix covers ref and
pallas) — bit-identity is asserted against a reference computed under the
same backend, which the wire/stream contract ties to the batch path.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hyp import given, settings, st

from repro import obs, serve
from repro.core.ckks import cipher
from repro.core.ckks import params as ckks_params
from repro.core.secure_agg import ProtectedUpdate
from repro.fl.server import FLServer, ReceivedUpdate
from repro.serve import quorum as qr
from repro.serve import sim as ssim
from repro.wire import budget as wb
from repro.wire import stream as ws

CTX = ckks_params.make_test_context(n_poly=256, n_limbs=2, delta_bits=20)
SK, PK = cipher.keygen(CTX, jax.random.PRNGKey(0))
N_CLIENTS = 6


def _template(seed, n_chunks=2):
    rng = np.random.RandomState(seed)
    v = rng.randn(n_chunks, CTX.slots).astype(np.float32)
    ct = cipher.encrypt_values(CTX, PK, jnp.asarray(v),
                               jax.random.PRNGKey(seed + 1))
    return ws.pack_update_frames(
        ProtectedUpdate(ct=ct, plain=jnp.asarray(
            rng.randn(9).astype(np.float32))),
        cid=0, n_samples=1, rnd=0)


FLEET = ssim.Fleet([_template(s) for s in range(3)], N_CLIENTS, seed=42)


def reference(rnd=0, exclude=()):
    return ssim.reference_aggregate(
        CTX, [FLEET.blob(c, rnd) for c in range(N_CLIENTS)
              if c not in exclude])


def assert_bitexact(a, b):
    np.testing.assert_array_equal(np.asarray(a.ct.data, dtype=np.uint32),
                                  np.asarray(b.ct.data, dtype=np.uint32))
    np.testing.assert_array_equal(np.asarray(a.plain), np.asarray(b.plain))
    assert a.ct.scale == b.ct.scale


def make_service(min_clients=2, target=N_CLIENTS, **kw):
    pol = qr.QuorumPolicy(min_clients=min_clients, target_clients=target,
                          deadline_s=kw.pop("deadline_s", None))
    return serve.AggregationService(CTX, pol, **kw)


def _rejected_ingest_total():
    rows = obs.REGISTRY.snapshot().get("wire_ingest_rejected_updates", [])
    return sum(r["value"] for r in rows)


# ---------------------------------------------------------------------------
# clean path + state machine edges
# ---------------------------------------------------------------------------


def test_clean_round_bit_identical_to_sync_reference():
    svc = make_service()
    rnd = svc.open_round()
    assert svc.status(rnd) == serve.ST_OPEN
    for cid, blob in FLEET.blobs(rnd):
        assert svc.submit(blob).accepted
    assert svc.status(rnd) == serve.ST_SEALED      # sealed at target
    # the sealed round no longer accepts: no round is open
    late = svc.submit(FLEET.blob(0, rnd))
    assert not late.accepted and late.reason == "no_open_round"
    svc.drain()
    assert svc.status(rnd) == serve.ST_DONE
    assert_bitexact(svc.result(rnd), reference(rnd))
    info = svc.round_info(rnd)
    assert info["folded"] == N_CLIENTS and info["refolds"] == 0


def test_open_while_open_raises():
    svc = make_service(target=None)
    svc.open_round()
    with pytest.raises(RuntimeError, match="still open"):
        svc.open_round()


def test_result_before_done_raises():
    svc = make_service(target=None)
    rnd = svc.open_round()
    with pytest.raises(RuntimeError, match="not done"):
        svc.result(rnd)


def test_explicit_seal_below_quorum_raises():
    svc = make_service(min_clients=3, target=None)
    rnd = svc.open_round()
    svc.submit(FLEET.blob(0, rnd))
    with pytest.raises(RuntimeError, match="below the quorum floor"):
        svc.seal()


def test_duplicate_cid_rejected():
    svc = make_service(target=None)
    rnd = svc.open_round()
    assert svc.submit(FLEET.blob(1, rnd)).accepted
    dup = svc.submit(FLEET.blob(1, rnd))
    assert not dup.accepted and dup.reason == "duplicate_cid"
    assert svc.round_info(rnd)["rejected"] == {"duplicate_cid": 1}


def test_bad_header_rejected_at_door():
    svc = make_service(target=None)
    rnd = svc.open_round()
    res = svc.submit(b"this is not a wire frame stream")
    assert not res.accepted and res.reason == "bad_header"
    assert svc.round_info(rnd)["accepted"] == 0


# ---------------------------------------------------------------------------
# fault injection: every faults.py mode against a live round
# ---------------------------------------------------------------------------

REJECT_MODES = ("drop", "duplicate", "truncate", "garbage")


@pytest.mark.parametrize("mode", REJECT_MODES)
def test_fault_rejected_and_aggregate_bit_identical(mode):
    bad_cid = 3
    inj = serve.FaultInjector(seed=11, blob_faults={bad_cid: mode})
    svc = make_service()
    before = _rejected_ingest_total()
    rnd = svc.open_round()
    door_rejects = 0
    for cid, blob in FLEET.blobs(rnd):
        res = svc.submit(inj.corrupt(cid, blob))
        door_rejects += not res.accepted
    if door_rejects:
        # the fault truncated inside the header: rejected at submit()
        assert mode == "truncate" and door_rejects == 1
        svc.seal()
    svc.drain()
    assert svc.status(rnd) == serve.ST_DONE
    assert_bitexact(svc.result(rnd), reference(rnd, exclude={bad_cid}))
    info = svc.round_info(rnd)
    if door_rejects:
        assert info["bad_after_accept"] == 0
    else:
        # rejected at fold time, atomically, then one refold renormalized
        # the survivors' weights
        assert info["bad_after_accept"] == 1 and info["refolds"] == 1
        assert _rejected_ingest_total() == before + 1
        assert obs.counter("serve_fold_rejects",
                           service=svc.service_id).value == 1


def test_reorder_accepted_bit_identically():
    """Chunk-frame order is NOT part of the wire contract: a reordered
    stream folds to the same bits as the canonical one."""
    inj = serve.FaultInjector(seed=5, blob_faults={2: "reorder"})
    svc = make_service()
    rnd = svc.open_round()
    for cid, blob in FLEET.blobs(rnd):
        assert svc.submit(inj.corrupt(cid, blob)).accepted
    svc.drain()
    assert_bitexact(svc.result(rnd), reference(rnd))
    assert svc.round_info(rnd)["refolds"] == 0


def test_delay_rejected_late_and_round_seals_at_deadline():
    now = [0.0]
    svc = make_service(min_clients=2, target=None, deadline_s=10.0,
                       clock=lambda: now[0])
    inj = serve.FaultInjector(seed=0, blob_faults={5: "delay"})
    rnd = svc.open_round()
    for cid, blob in FLEET.blobs(rnd, cids=range(5)):
        assert svc.submit(inj.corrupt(cid, blob)).accepted
    now[0] = 10.5                               # past the deadline
    late = svc.submit(inj.corrupt(5, FLEET.blob(5, rnd)))
    assert not late.accepted and late.reason == "late"
    assert svc.status(rnd) == serve.ST_SEALED   # late submit sealed it
    assert svc.round_info(rnd)["sealed_reason"] == "deadline"
    svc.drain()
    assert_bitexact(svc.result(rnd), reference(rnd, exclude={5}))
    assert svc.round_info(rnd)["rejected"] == {"late": 1}


def test_below_quorum_at_deadline_fails():
    now = [0.0]
    svc = make_service(min_clients=4, target=None, deadline_s=5.0,
                       clock=lambda: now[0])
    rnd = svc.open_round()
    for cid, blob in FLEET.blobs(rnd, cids=range(2)):
        svc.submit(blob)
    now[0] = 6.0
    assert svc.maybe_seal() == qr.FAIL_DEADLINE
    assert svc.status(rnd) == serve.ST_FAILED
    with pytest.raises(RuntimeError, match="deadline_below_quorum"):
        svc.result(rnd)


def test_below_quorum_after_fold_rejects_fails():
    """Quorum is re-checked AFTER fold-time rejects: a round that sealed
    at quorum but lost a corrupt update below it must fail, never publish
    a below-quorum aggregate."""
    inj = serve.FaultInjector(seed=3, blob_faults={0: "drop"})
    svc = make_service(min_clients=N_CLIENTS)
    rnd = svc.open_round()
    for cid, blob in FLEET.blobs(rnd):
        assert svc.submit(inj.corrupt(cid, blob)).accepted
    svc.drain()
    assert svc.status(rnd) == serve.ST_FAILED
    assert svc.round_info(rnd)["sealed_reason"] == \
        "below_quorum_after_rejects"


def test_multiple_faulty_clients_one_round():
    inj = serve.FaultInjector(
        seed=13, blob_faults={1: "drop", 4: "garbage", 2: "reorder"})
    svc = make_service()
    rnd = svc.open_round()
    for cid, blob in FLEET.blobs(rnd):
        svc.submit(inj.corrupt(cid, blob))
    svc.drain()
    assert_bitexact(svc.result(rnd), reference(rnd, exclude={1, 4}))
    assert svc.round_info(rnd)["bad_after_accept"] == 2


# ---------------------------------------------------------------------------
# async overlap: round r+1 accepts while round r still owes folds
# ---------------------------------------------------------------------------


def test_overlap_next_round_accepts_while_previous_folds():
    svc = make_service(fold_batch=2)
    r0 = svc.open_round()
    for cid, blob in FLEET.blobs(r0):
        svc.submit(blob)
    assert svc.status(r0) == serve.ST_SEALED
    svc.step()                                   # partially folded
    assert svc.status(r0) == serve.ST_FOLDING
    r1 = svc.open_round()                        # overlap: r0 not done
    for cid, blob in FLEET.blobs(r1):
        assert svc.submit(blob).accepted
    assert svc.status(r0) in (serve.ST_FOLDING, serve.ST_SEALED)
    svc.drain()
    assert_bitexact(svc.result(r0), reference(r0))
    assert_bitexact(svc.result(r1), reference(r1))


def test_worker_thread_round_matches_reference():
    svc = make_service()
    svc.start(poll_s=0.0005)
    try:
        rnd = svc.open_round()
        for cid, blob in FLEET.blobs(rnd):
            svc.submit(blob)
        import time
        for _ in range(2000):
            if not svc.unfinished():
                break
            time.sleep(0.002)
    finally:
        svc.stop()
    assert svc.worker_error is None
    assert_bitexact(svc.result(rnd), reference(rnd))


# ---------------------------------------------------------------------------
# crash-restart: bit-exact resume from every checkpoint boundary
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("point", serve.CRASH_POINTS)
def test_crash_restart_bit_exact(tmp_path, point):
    pol = qr.QuorumPolicy(min_clients=2, target_clients=N_CLIENTS)
    inj = serve.FaultInjector(crash_at=[point])
    led = wb.BandwidthLedger()
    svc = serve.AggregationService(
        CTX, pol, ckpt_dir=str(tmp_path), faults=inj, ledger=led,
        fold_batch=2, ckpt_every_accepts=1)
    with pytest.raises(serve.SimulatedCrash):
        svc.open_round()
        for cid, blob in FLEET.blobs(0):
            svc.submit(blob)
        svc.drain()
    assert inj.fired == [point]

    # restart: fresh process state, resume from the durable checkpoint
    led2 = wb.BandwidthLedger()
    svc2 = serve.AggregationService.resume(str(tmp_path), CTX, pol,
                                           ledger=led2, fold_batch=2)
    # at-least-once delivery: clients whose ack was lost resubmit; the
    # service dedups anything the checkpoint already accepted
    if svc2.open_round_id is not None:
        for cid, blob in FLEET.blobs(0):
            svc2.submit(blob)
    svc2.drain()
    assert svc2.status(0) == serve.ST_DONE
    assert_bitexact(svc2.result(0), reference(0))
    # the budget ledger lost no bytes: every accepted blob is accounted
    # exactly once across the crash
    total = sum(len(FLEET.blob(c, 0)) for c in range(N_CLIENTS))
    assert led2.total(wb.UPLINK) == total


def test_crash_restart_mid_fold_with_faults(tmp_path):
    """Crash during folding of a round that ALSO has a corrupt update:
    resume must replay the refold logic to the same survivor bits."""
    pol = qr.QuorumPolicy(min_clients=2, target_clients=N_CLIENTS)
    inj = serve.FaultInjector(seed=9, crash_at=["after_fold_step"],
                              blob_faults={4: "garbage"})
    svc = serve.AggregationService(CTX, pol, ckpt_dir=str(tmp_path),
                                   faults=inj, fold_batch=2)
    with pytest.raises(serve.SimulatedCrash):
        svc.open_round()
        for cid, blob in FLEET.blobs(0):
            svc.submit(inj.corrupt(cid, blob))
        svc.drain()
    svc2 = serve.AggregationService.resume(str(tmp_path), CTX, pol,
                                           fold_batch=2)
    svc2.drain()
    assert svc2.status(0) == serve.ST_DONE
    assert_bitexact(svc2.result(0), reference(0, exclude={4}))


def test_resume_without_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        serve.AggregationService.resume(str(tmp_path / "empty"), CTX,
                                        qr.QuorumPolicy())


# ---------------------------------------------------------------------------
# quorum properties (deterministic sweeps always run; hypothesis widens)
# ---------------------------------------------------------------------------


def test_any_subset_at_or_above_quorum_finalizes_below_never():
    MIN = 3
    for size in range(1, N_CLIENTS + 1):
        svc = make_service(min_clients=MIN, target=None)
        rnd = svc.open_round()
        for cid, blob in FLEET.blobs(rnd, cids=range(size)):
            assert svc.submit(blob).accepted
        if size < MIN:
            with pytest.raises(RuntimeError, match="quorum"):
                svc.seal()
            assert svc.status(rnd) == serve.ST_OPEN
        else:
            svc.seal()
            svc.drain()
            assert svc.status(rnd) == serve.ST_DONE
            # weights renormalized over exactly this subset
            assert_bitexact(
                svc.result(rnd),
                ssim.reference_aggregate(
                    CTX, [FLEET.blob(c, rnd) for c in range(size)]))


@settings(max_examples=200, deadline=None)
@given(st.integers(min_value=1, max_value=50),
       st.integers(min_value=0, max_value=100),
       st.floats(min_value=0.0, max_value=1e4,
                 allow_nan=False, allow_infinity=False))
def test_quorum_policy_floor_property(min_clients, n_accepted, elapsed):
    pol = qr.QuorumPolicy(min_clients=min_clients, deadline_s=10.0)
    verdict = pol.should_seal(n_accepted, elapsed)
    if n_accepted < min_clients:
        # below the floor a round can NEVER seal, only fail
        assert verdict in (None, qr.FAIL_DEADLINE)
    if verdict in (qr.SEAL_TARGET, qr.SEAL_DEADLINE):
        assert pol.met(n_accepted)


@settings(max_examples=200, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=10_000),
                min_size=1, max_size=64))
def test_weights_renormalize_property(n_samples):
    w = qr.normalized_weights(n_samples)
    assert len(w) == len(n_samples)
    assert abs(sum(w) - 1.0) < 1e-9
    # proportionality: w_i / w_j == n_i / n_j (float64 math)
    tot = float(np.asarray(n_samples, dtype=np.float64).sum())
    for wi, ni in zip(w, n_samples):
        assert wi == pytest.approx(ni / tot, rel=1e-12)


@settings(max_examples=100, deadline=None)
@given(st.lists(st.tuples(st.integers(min_value=1, max_value=1000),
                          st.integers(min_value=0, max_value=20)),
                min_size=1, max_size=16),
       st.integers(min_value=0, max_value=20),
       st.floats(min_value=0.5, max_value=16.0, allow_nan=False))
def test_staleness_weights_property(buf, current_round, half_life):
    ns = [n for n, _ in buf]
    sent = [s for _, s in buf]
    w = qr.staleness_weights(ns, sent, current_round, half_life)
    assert abs(sum(w) - 1.0) < 1e-9
    # staler updates never outweigh fresher ones with equal n_samples
    for i in range(len(buf)):
        for j in range(len(buf)):
            if ns[i] == ns[j] and sent[i] <= sent[j]:
                assert w[i] <= w[j] + 1e-12


# ---------------------------------------------------------------------------
# FLServer.submit_async now folds through the shared weight law
# ---------------------------------------------------------------------------


def test_flserver_submit_async_uses_shared_staleness_law():
    from repro.core.secure_agg import (AggregatorConfig,
                                       SelectiveHEAggregator)

    rng = np.random.RandomState(0)
    model = {"w": jnp.asarray(rng.randn(40, 10), jnp.float32)}
    sens = np.abs(rng.randn(400))
    agg = SelectiveHEAggregator.build(CTX, model, sens,
                                      AggregatorConfig(p_ratio=0.3))
    ups = []
    for i in range(3):
        local = {"w": model["w"] + 0.01 * (i + 1)}
        ups.append(ReceivedUpdate(
            cid=i, n_samples=4 * (i + 1), round_sent=i,
            update=agg.client_protect(local, PK, jax.random.PRNGKey(i))))

    server = FLServer(agg, buffer_size=3, staleness_half_life=2.0)
    assert server.submit_async(ups[0], current_round=4) is None
    assert server.submit_async(ups[1], current_round=4) is None
    out = server.submit_async(ups[2], current_round=4)
    assert out is not None

    expect_w = qr.staleness_weights([4, 8, 12], [0, 1, 2],
                                    current_round=4, half_life=2.0)
    expect = agg.server_aggregate([u.update for u in ups], expect_w)
    assert_bitexact(out, expect)
    assert server._buffer == []                 # buffer flushed
