from repro.fl.keys import KeyAuthority, ThresholdKeyAuthority
from repro.fl.client import FLClient, ClientConfig
from repro.fl.server import FLServer
from repro.fl.orchestrator import (FLTask, FLRunConfig, RoundLog,
                                   run_federated_training)
from repro.wire import BandwidthLedger, WirePolicy
