#!/usr/bin/env python
"""Docs checker: the CI docs job and the README bench-table generator.

Checks (default mode — exit nonzero on any failure):
  1. every intra-repo markdown link in README.md / DESIGN.md / ROADMAP.md
     resolves to an existing file or directory;
  2. the benchmark tables in README.md match what the checked-in
     BENCH_he.json / BENCH_agg_sharded.json / BENCH_uplink_sharded.json /
     BENCH_tune.json render to;
  3. the DESIGN.md §9.2 wire-spec appendix matches wire/format.py's
     version and derivation constants (the WIRE_SPEC marker);
  4. the README "Environment variables & flags" table's REPRO_HE_BACKEND
     row names every backend in kernels/ops.py BACKENDS (ref, pallas,
     pallas4, ...);
  5. the README quickstart snippets (first ```bash block after the
     "quickstart" heading AND after the "sharded uplink" heading) execute
     successfully, and the checked-in gold KATs match a fresh recompute
     (tools/gen_gold.py --check) — both skipped with --no-exec for fast
     local runs;
  6. the telemetry layer stays documented: README env-table rows for
     REPRO_OBS / REPRO_OBS_TRACE plus a tools/round_report.py pointer,
     and the DESIGN.md §11 obs section;
  7. the autotuner stays documented: README `REPRO_HE_TUNE_CACHE` row +
     `benchmarks.run tune` pointer, and the DESIGN.md §12 section;
  8. the selective pipeline stays documented: README `benchmarks.run
     selective` pointer + rendered BENCH_selective table + the
     REPRO_WIRE_VERSION env row, and the DESIGN.md §13 section (mask
     agreement -> partition -> wire -> merge, overhead accounting);
  9. the transcipher uplink stays documented: README `REPRO_UPLINK_MODE`
     env row + thin-client quickstart + `benchmarks.run uplink-hybrid` /
     tests/test_transcipher.py pointers, and the DESIGN.md §15 section
     (encode_centered / mod_lift contract, frame + escrow semantics).

`--write` regenerates the README tables in place between the
BENCH_TABLES_START/END markers instead of failing on drift.

Usage:
    python tools/check_docs.py            # full check (CI docs job)
    python tools/check_docs.py --no-exec  # links + tables + spec only
    python tools/check_docs.py --write    # refresh README bench tables
"""
from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
import tempfile

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(ROOT, "src"))   # for the wire-spec check
DOC_FILES = ("README.md", "DESIGN.md", "ROADMAP.md")
MARK_START = "<!-- BENCH_TABLES_START -->"
MARK_END = "<!-- BENCH_TABLES_END -->"

_LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)]*)?\)")


def check_links() -> list[str]:
    """Every relative markdown link must resolve inside the repo."""
    errors = []
    for doc in DOC_FILES:
        path = os.path.join(ROOT, doc)
        if not os.path.exists(path):
            errors.append(f"{doc}: file missing")
            continue
        text = open(path).read()
        for m in _LINK.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            resolved = os.path.normpath(os.path.join(ROOT, target))
            if not resolved.startswith(ROOT):
                errors.append(f"{doc}: link escapes repo: {target}")
            elif not os.path.exists(resolved):
                errors.append(f"{doc}: broken link: {target}")
    return errors


def render_bench_tables() -> str:
    """Markdown tables from the checked-in BENCH json artifacts."""
    out = []

    he_path = os.path.join(ROOT, "BENCH_he.json")
    he = json.load(open(he_path))
    out.append(
        f"**Limb-fused engine vs per-limb dispatch baseline** "
        f"(`benchmarks/run.py he`; N={he['n_poly']}, L={he['n_limbs']}, "
        f"{he['n_clients']} clients, backend `{he['backend']}`):\n")
    out.append("| op | per-limb ms | fused ms | speedup |")
    out.append("|----|------------:|---------:|--------:|")
    for op, r in he["ops"].items():
        per = r.get("per_limb_ms")
        per_s = f"{per:.2f}" if per is not None else "—"
        spd = r.get("speedup")
        spd_s = f"{spd:.0f}x" if spd is not None else "—"
        out.append(f"| {op} | {per_s} | {r['fused_ms']:.2f} | {spd_s} |")
    out.append("")

    n4 = he["ntt4"]
    out.append(
        f"**Flat limb-grid NTT vs 4-step transpose NTT** "
        f"(`benchmarks/run.py ntt`; batch={n4['batch']}, "
        f"interpret={'yes' if n4['interpret'] else 'no'} — structure/"
        "dispatch tracking, not TPU lane behaviour; DESIGN.md §10):\n")
    out.append("| N | L | split n1 x n2 | fwd fused ms | fwd 4-step ms | "
               "inv fused ms | inv 4-step ms | bit-parity |")
    out.append("|---:|--:|---------------|-------------:|--------------:|"
               "-------------:|--------------:|:----------:|")
    for r in n4["rows"]:
        out.append(
            f"| {r['n_poly']} | {r['n_limbs']} | {r['split']} | "
            f"{r['fwd_fused_ms']:.2f} | {r['fwd_4step_ms']:.2f} | "
            f"{r['inv_fused_ms']:.2f} | {r['inv_4step_ms']:.2f} | "
            f"{'yes' if r['bit_parity'] else 'NO'} |")
    out.append("")

    ag_path = os.path.join(ROOT, "BENCH_agg_sharded.json")
    ag = json.load(open(ag_path))
    rows = [ag["per_devices"][k] for k in sorted(ag["per_devices"],
                                                key=lambda s: int(s))]
    r0 = rows[0]
    out.append(
        f"**Sharded vs single-device aggregation** "
        f"(`benchmarks/run.py agg-sharded`; N={r0['n_poly']}, "
        f"L={r0['n_limbs']}, {r0['n_clients']} clients x "
        f"{r0['n_chunks']} chunks, simulated host devices):\n")
    out.append("| devices | mesh (data x model) | weighted_sum single ms | "
               "weighted_sum sharded ms | stream ingest ms | "
               "launches/update | bit-parity |")
    out.append("|--------:|---------------------|----------------------:|"
               "------------------------:|-----------------:|"
               "----------------:|:----------:|")
    for r in rows:
        mesh = f"{r['mesh']['data']} x {r['mesh']['model']}"
        out.append(
            f"| {r['devices']} | {mesh} | "
            f"{r['weighted_sum_single_ms']:.2f} | "
            f"{r['weighted_sum_sharded_ms']:.2f} | "
            f"{r['stream_ingest_sharded_ms']:.0f} | "
            f"{r['launches_per_update']:.0f} | "
            f"{'yes' if r['sharded_parity'] else 'NO'} |")
    out.append("")

    up_path = os.path.join(ROOT, "BENCH_uplink_sharded.json")
    up = json.load(open(up_path))
    rows = [up["per_devices"][k] for k in sorted(up["per_devices"],
                                                 key=lambda s: int(s))]
    r0 = rows[0]
    out.append(
        f"**Sharded client uplink (seeded encrypt)** "
        f"(`benchmarks/run.py uplink-sharded`; N={r0['n_poly']}, "
        f"L={r0['n_limbs']}, {r0['n_chunks']} chunks, simulated host "
        "devices):\n")
    out.append("| devices | mesh (data x model) | seeded single ms | "
               "seeded sharded ms | pk single ms | pk sharded ms | "
               "seeded/full bytes | bit-parity |")
    out.append("|--------:|---------------------|-----------------:|"
               "------------------:|-------------:|--------------:|"
               "------------------:|:----------:|")
    for r in rows:
        mesh = f"{r['mesh']['data']} x {r['mesh']['model']}"
        out.append(
            f"| {r['devices']} | {mesh} | "
            f"{r['encrypt_seeded_single_ms']:.2f} | "
            f"{r['encrypt_seeded_sharded_ms']:.2f} | "
            f"{r['encrypt_pk_single_ms']:.2f} | "
            f"{r['encrypt_pk_sharded_ms']:.2f} | "
            f"{r['uplink_ratio']:.2f}x | "
            f"{'yes' if r['sharded_parity'] else 'NO'} |")
    out.append("")

    tn_path = os.path.join(ROOT, "BENCH_tune.json")
    tn = json.load(open(tn_path))
    plat = tn["provenance"]["platform"]
    out.append(
        f"**Autotuner: default vs swept launch configs** "
        f"(`benchmarks/run.py tune`; platform `{plat}`, "
        f"interpret={'yes' if tn['interpret'] else 'no'}; winners cached "
        "for `REPRO_HE_BACKEND=auto`, DESIGN.md §12):\n")
    out.append("| op | N | L | B | winner | config | default ms | "
               "tuned ms | speedup | candidates (pruned) |")
    out.append("|----|--:|--:|--:|--------|--------|-----------:|"
               "---------:|--------:|--------------------:|")
    for r in tn["rows"]:
        cfg = r["config"]
        bits = [f"block {cfg['block_b']}"]
        if cfg.get("ntt4_split"):
            bits.append(f"{cfg['ntt4_split'][0]}x{cfg['ntt4_split'][1]}")
        if cfg.get("radix", 2) != 2:
            bits.append(f"radix {cfg['radix']}")
        out.append(
            f"| {r['op']} | {r['n']} | {r['l']} | {r['b']} | "
            f"{r['backend']} | {', '.join(bits)} | "
            f"{r['default_ms']:.2f} | {r['tuned_ms']:.2f} | "
            f"{r['speedup']:.2f}x | {r['candidates']} ({r['pruned']}) |")
    out.append("")

    sel_path = os.path.join(ROOT, "BENCH_selective.json")
    sel = json.load(open(sel_path))
    big = sel["models"][-1]
    out.append(
        f"**Selective encryption end to end** (`benchmarks/run.py "
        f"selective`; {big['label']}, {big['n_params']/1e6:.1f}M params, "
        f"{big['n_clients']} clients, N={sel['ctx']['n_poly']}, "
        f"L={sel['ctx']['n_limbs']}, seeded uplink, plain codec "
        f"`{sel['plain_codec']}`, mesh {sel['mesh']['data']} x "
        f"{sel['mesh']['model']}; DESIGN.md §13):\n")
    out.append("| strategy | p | cts | uplink B/client | encrypt s | "
               "aggregate s | decrypt s | bytes vs p=1 | "
               "enc+agg time vs p=1 |")
    out.append("|----------|--:|----:|----------------:|----------:|"
               "-----------:|----------:|-------------:|"
               "--------------------:|")
    for r in big["rows"]:
        out.append(
            f"| {r['strategy']} | {r['p']:.2f} | {r['n_cts']} | "
            f"{r['uplink_B_per_client']:,} | {r['encrypt_s']:.3f} | "
            f"{r['aggregate_s']:.3f} | {r['decrypt_s']:.3f} | "
            f"{r['bytes_ratio_vs_p1']:.1f}x | "
            f"{r['time_ratio_vs_p1']:.1f}x |")
    out.append("")
    out.append(
        "**Extrapolated selective uplink at the paper's scales** (closed "
        "form from the measured per-chunk / per-plain-param wire costs "
        "above):\n")
    out.append("| model | params | p | est uplink MB/client | vs p=1 |")
    out.append("|-------|-------:|--:|---------------------:|-------:|")
    for r in sel["extrapolation"]:
        out.append(
            f"| {r['scale']} | {r['n_params']/1e6:.0f}M | {r['p']:.2f} | "
            f"{r['est_uplink_MB_per_client']:.1f} | "
            f"{r['bytes_ratio_vs_p1']:.1f}x |")
    out.append("")

    sv_path = os.path.join(ROOT, "BENCH_serve.json")
    sv = json.load(open(sv_path))
    c = sv["config"]
    out.append(
        f"**Async aggregation service** (`benchmarks/run.py serve`; "
        f"{c['n_clients']:,} simulated clients/round, quorum target "
        f"{c['target_clients']:,}, N={c['n_poly']}, L={c['n_limbs']}, "
        f"{c['n_chunks']} chunks, {c['blob_bytes']:,} B/update, backend "
        f"`{sv['backend']}`, worker-thread overlap on; DESIGN.md §14):\n")
    out.append("| round | accepted | stragglers dropped | folded | "
               "submit rate/s |")
    out.append("|------:|---------:|-------------------:|-------:|"
               "--------------:|")
    for r in sv["rows"]:
        out.append(
            f"| {r['round']} | {r['accepted']:,} | "
            f"{r['stragglers_dropped']:,} | {r['folded']:,} | "
            f"{r['submit_rate']:,.0f} |")
    out.append("")
    out.append(f"Sustained end to end (submit + fold + finalize, "
               f"{c['rounds']} rounds): "
               f"**{sv['sustained_updates_per_s']:,.0f} updates/s** "
               f"({sv['wall_s']:.1f}s wall).")
    out.append("")

    hy_path = os.path.join(ROOT, "BENCH_uplink_hybrid.json")
    hy = json.load(open(hy_path))
    out.append(
        f"**Thin-client transcipher uplink vs seeded CKKS** "
        f"(`benchmarks/run.py uplink-hybrid`; N={hy['n_poly']}, "
        f"L={hy['n_limbs']}, {hy['n_chunks']} chunks, delta 2^"
        f"{hy['delta_bits']}, backend `{hy['provenance']['backend']}`; "
        "client sends masked i64 coefficients + one escrowed keystream "
        "seed, server unmasks homomorphically — DESIGN.md §15):\n")
    out.append("| derive | seeded encrypt ms | masked pack ms | "
               "client speedup | seeded B | masked B | uplink ratio | "
               "bit-parity |")
    out.append("|--------|------------------:|---------------:|"
               "---------------:|---------:|---------:|-------------:|"
               ":----------:|")
    for name in ("fold_chunk", "ctr"):
        r = hy["per_derive"][name]
        out.append(
            f"| {name} | {r['seeded_encrypt_ms']:.2f} | "
            f"{r['masked_encrypt_ms']:.2f} | "
            f"{r['encrypt_speedup']:.2f}x | {r['seeded_B']:,} | "
            f"{r['masked_B']:,} | {r['uplink_ratio']:.2f}x | "
            f"{'yes' if r['bit_parity'] else 'NO'} |")
    return "\n".join(out) + "\n"


_WIRE_SPEC = re.compile(
    r"<!--\s*WIRE_SPEC\s+version=(\d+)\s+supported=([\d,]+)\s+"
    r"derives=([\d,]+)\s*-->")


def check_wire_spec() -> list[str]:
    """DESIGN.md §9.2 must agree with wire/format.py's constants.

    The appendix carries a machine-readable WIRE_SPEC marker; a version or
    derivation-id bump in code without the matching normative-spec edit
    fails the docs job (and vice versa)."""
    try:
        from repro.wire import format as wf
    except Exception as e:          # pragma: no cover - import environment
        return [f"DESIGN.md: cannot import repro.wire.format to verify "
                f"the wire spec: {e}"]
    full = open(os.path.join(ROOT, "DESIGN.md")).read()
    # scope every check to the §9.2 appendix itself, so gutting the
    # normative text cannot pass on phrases that also appear elsewhere
    sec = re.search(r"### §9\.2 .*?(?=\n## |\Z)", full, re.DOTALL)
    if not sec:
        return ["DESIGN.md: missing '### §9.2' wire-spec appendix section"]
    text = sec.group(0)
    m = _WIRE_SPEC.search(text)
    if not m:
        return ["DESIGN.md: missing WIRE_SPEC marker in the §9.2 appendix "
                "(<!-- WIRE_SPEC version=.. supported=.. derives=.. -->)"]
    errors = []
    if int(m.group(1)) != wf.VERSION:
        errors.append(f"DESIGN.md §9.2: spec version {m.group(1)} != "
                      f"wire/format.py VERSION {wf.VERSION}")
    spec_supported = tuple(int(x) for x in m.group(2).split(","))
    if spec_supported != tuple(wf.SUPPORTED_VERSIONS):
        errors.append(f"DESIGN.md §9.2: supported versions {spec_supported} "
                      f"!= wire/format.py {tuple(wf.SUPPORTED_VERSIONS)}")
    spec_derives = tuple(int(x) for x in m.group(3).split(","))
    if spec_derives != tuple(wf.DERIVES):
        errors.append(f"DESIGN.md §9.2: derive ids {spec_derives} != "
                      f"wire/format.py {tuple(wf.DERIVES)}")
    for needed in ("u8 derive", "fold_in", "chunk_offset + b",
                   "DERIVE_CTR"):
        if needed not in text:
            errors.append(f"DESIGN.md §9.2: normative appendix no longer "
                          f"spells out '{needed}'")
    return errors


def check_env_table() -> list[str]:
    """The README env-var table must keep pace with the backend registry:
    every name in kernels.ops.BACKENDS has to appear in the
    REPRO_HE_BACKEND row, so a new backend (e.g. pallas4) cannot land
    without its knob being documented."""
    try:
        from repro.kernels import ops
    except Exception as e:          # pragma: no cover - import environment
        return [f"README.md: cannot import repro.kernels.ops to verify the "
                f"REPRO_HE_BACKEND row: {e}"]
    text = open(os.path.join(ROOT, "README.md")).read()
    row = next((ln for ln in text.splitlines()
                if ln.startswith("| `REPRO_HE_BACKEND")), None)
    if row is None:
        return ["README.md: missing the `REPRO_HE_BACKEND` row in the "
                "'Environment variables & flags' table"]
    # whole-word match: "pallas4" in the row must not satisfy "pallas"
    words = set(re.findall(r"\w+", row))
    missing = [b for b in ops.BACKENDS if b not in words]
    if missing:
        return [f"README.md: REPRO_HE_BACKEND row does not mention "
                f"backend(s) {missing} (kernels/ops.py BACKENDS = "
                f"{list(ops.BACKENDS)})"]
    return []


def check_obs_docs() -> list[str]:
    """The telemetry layer must stay documented: README needs env-table
    rows for REPRO_OBS / REPRO_OBS_TRACE and a pointer at
    tools/round_report.py; DESIGN.md needs the §11 obs section."""
    errors = []
    readme = open(os.path.join(ROOT, "README.md")).read()
    for knob in ("REPRO_OBS", "REPRO_OBS_TRACE"):
        if not any(ln.startswith(f"| `{knob}") for ln in
                   readme.splitlines()):
            errors.append(f"README.md: missing the `{knob}` row in the "
                          "'Environment variables & flags' table")
    if "tools/round_report.py" not in readme:
        errors.append("README.md: telemetry docs no longer point at "
                      "tools/round_report.py")
    design = open(os.path.join(ROOT, "DESIGN.md")).read()
    if not re.search(r"^## §11 ", design, re.MULTILINE):
        errors.append("DESIGN.md: missing the '## §11' telemetry section "
                      "(repro/obs architecture + span taxonomy + overhead "
                      "policy)")
    return errors


def check_tune_docs() -> list[str]:
    """The autotuner must stay documented: README needs the
    `REPRO_HE_TUNE_CACHE` env row and a `benchmarks.run tune` pointer;
    DESIGN.md needs the §12 autotuner section (search space, cache key
    schema, pruning rule, bit-exactness argument)."""
    errors = []
    readme = open(os.path.join(ROOT, "README.md")).read()
    if not any(ln.startswith("| `REPRO_HE_TUNE_CACHE")
               for ln in readme.splitlines()):
        errors.append("README.md: missing the `REPRO_HE_TUNE_CACHE` row in "
                      "the 'Environment variables & flags' table")
    if "benchmarks.run tune" not in readme:
        errors.append("README.md: autotuner docs no longer point at "
                      "`benchmarks.run tune`")
    design = open(os.path.join(ROOT, "DESIGN.md")).read()
    sec = re.search(r"^## §12 .*?(?=\n## |\Z)", design,
                    re.MULTILINE | re.DOTALL)
    if not sec:
        errors.append("DESIGN.md: missing the '## §12' autotuner section")
        return errors
    for needed in ("block_b", "ntt4_split", "radix", "shape key",
                   "PRUNE_RATIO", "launch geometry"):
        if needed not in sec.group(0):
            errors.append(f"DESIGN.md §12: autotuner section no longer "
                          f"covers '{needed}'")
    return errors


def check_selective_docs() -> list[str]:
    """The selective pipeline must stay documented: README needs a
    `benchmarks.run selective` pointer and the `REPRO_WIRE_VERSION` env
    row (the wire knob the partitioned uplink rides on); DESIGN.md needs
    the §13 section covering mask agreement -> partition -> wire -> merge
    and the overhead accounting."""
    errors = []
    readme = open(os.path.join(ROOT, "README.md")).read()
    if "benchmarks.run selective" not in readme:
        errors.append("README.md: selective docs no longer point at "
                      "`benchmarks.run selective`")
    if not any(ln.startswith("| `REPRO_WIRE_VERSION")
               for ln in readme.splitlines()):
        errors.append("README.md: missing the `REPRO_WIRE_VERSION` row in "
                      "the 'Environment variables & flags' table")
    design = open(os.path.join(ROOT, "DESIGN.md")).read()
    sec = re.search(r"^## §13 .*?(?=\n## |\Z)", design,
                    re.MULTILINE | re.DOTALL)
    if not sec:
        errors.append("DESIGN.md: missing the '## §13' selective-pipeline "
                      "section")
        return errors
    for needed in ("agree_sensitivity", "build_mask", "MaskPartition",
                   "plain_codec", "merge_by_mask", "overhead"):
        if needed not in sec.group(0):
            errors.append(f"DESIGN.md §13: selective section no longer "
                          f"covers '{needed}'")
    return errors


def check_serve_docs() -> list[str]:
    """The aggregation service must stay documented: README needs the
    'Aggregation service quickstart' section with a runnable snippet and
    `benchmarks.run serve` / tests/test_serve.py pointers; DESIGN.md
    needs the §14 section covering the state machine, quorum semantics,
    crash consistency, and the fault taxonomy."""
    errors = []
    readme = open(os.path.join(ROOT, "README.md")).read()
    if not re.search(r"^## Aggregation service quickstart", readme,
                     re.MULTILINE):
        errors.append("README.md: missing the 'Aggregation service "
                      "quickstart' section")
    if "benchmarks.run serve" not in readme:
        errors.append("README.md: service docs no longer point at "
                      "`benchmarks.run serve`")
    if "tests/test_serve.py" not in readme:
        errors.append("README.md: service docs no longer point at "
                      "tests/test_serve.py")
    design = open(os.path.join(ROOT, "DESIGN.md")).read()
    sec = re.search(r"^## §14 .*?(?=\n## |\Z)", design,
                    re.MULTILINE | re.DOTALL)
    if not sec:
        errors.append("DESIGN.md: missing the '## §14' aggregation-service "
                      "section")
        return errors
    for needed in ("OPEN", "SEALED", "FOLDING", "FAILED", "min_clients",
                   "REFOLD", "at-least-once", "SimulatedCrash",
                   "export_state", "garbage"):
        if needed not in sec.group(0):
            errors.append(f"DESIGN.md §14: service section no longer "
                          f"covers '{needed}'")
    return errors


def check_transcipher_docs() -> list[str]:
    """The transcipher uplink must stay documented: README needs the
    `REPRO_UPLINK_MODE` env row, the thin-client quickstart section, and
    `benchmarks.run uplink-hybrid` / tests/test_transcipher.py pointers;
    DESIGN.md needs the §15 section covering the encode_centered /
    mod_lift exactness contract, provisioning, and the frame + escrow
    ingest semantics."""
    errors = []
    readme = open(os.path.join(ROOT, "README.md")).read()
    if not any(ln.startswith("| `REPRO_UPLINK_MODE")
               for ln in readme.splitlines()):
        errors.append("README.md: missing the `REPRO_UPLINK_MODE` row in "
                      "the 'Environment variables & flags' table")
    if not re.search(r"^## Thin-client transcipher uplink quickstart",
                     readme, re.MULTILINE):
        errors.append("README.md: missing the 'Thin-client transcipher "
                      "uplink quickstart' section")
    if "benchmarks.run uplink-hybrid" not in readme:
        errors.append("README.md: transcipher docs no longer point at "
                      "`benchmarks.run uplink-hybrid`")
    if "tests/test_transcipher.py" not in readme:
        errors.append("README.md: transcipher docs no longer point at "
                      "tests/test_transcipher.py")
    design = open(os.path.join(ROOT, "DESIGN.md")).read()
    sec = re.search(r"^## §15 .*?(?=\n## |\Z)", design,
                    re.MULTILINE | re.DOTALL)
    if not sec:
        errors.append("DESIGN.md: missing the '## §15' transcipher-uplink "
                      "section")
        return errors
    for needed in ("encode_centered", "mod_lift", "MASKED_CHUNK",
                   "TRANSCIPHER_SEED", "ClientMaterials", "ServerMaterials",
                   "provision", "escrow", "uplink_a_seed",
                   "add_transcipher_materials"):
        if needed not in sec.group(0):
            errors.append(f"DESIGN.md §15: transcipher section no longer "
                          f"covers '{needed}'")
    return errors


def check_or_write_tables(write: bool) -> list[str]:
    path = os.path.join(ROOT, "README.md")
    text = open(path).read()
    if MARK_START not in text or MARK_END not in text:
        return [f"README.md: missing {MARK_START}/{MARK_END} markers"]
    head, rest = text.split(MARK_START, 1)
    _, tail = rest.split(MARK_END, 1)
    try:
        rendered = MARK_START + "\n" + render_bench_tables() + MARK_END
    except (OSError, KeyError, ValueError) as e:
        # a missing BENCH json / section is a docs error, not a traceback
        # (e.g. BENCH_he.json regenerated by `run he` alone lacks 'ntt4' —
        # run `python -m benchmarks.run ntt` too)
        return [f"README.md: cannot render bench tables from the checked-in "
                f"BENCH json artifacts: {e!r}"]
    new = head + rendered + tail
    if new == text:
        return []
    if write:
        open(path, "w").write(new)
        print("README.md bench tables refreshed")
        return []
    return ["README.md: bench tables out of date with BENCH json "
            "(run `python tools/check_docs.py --write`)"]


def _run_snippet(heading: str) -> list[str]:
    """Extract and execute the first ```bash block after `heading`."""
    text = open(os.path.join(ROOT, "README.md")).read()
    m = re.search(heading + r".*?```bash\n(.*?)```", text,
                  re.IGNORECASE | re.DOTALL)
    if not m:
        return [f"README.md: no ```bash block found after '{heading}'"]
    script = m.group(1)
    with tempfile.NamedTemporaryFile("w", suffix=".sh", delete=False) as f:
        f.write("set -euo pipefail\n" + script)
        name = f.name
    try:
        proc = subprocess.run(["bash", name], cwd=ROOT, capture_output=True,
                              text=True, timeout=900)
    finally:
        os.unlink(name)
    if proc.returncode != 0:
        return [f"README '{heading}' snippet failed "
                f"(exit {proc.returncode}):\n{proc.stdout}\n{proc.stderr}"]
    print(f"README '{heading}' snippet OK: "
          f"{proc.stdout.strip().splitlines()[-1]}")
    return []


def run_quickstart() -> list[str]:
    """Execute the README snippets: the encrypted-averaging quickstart,
    the sharded-uplink quickstart, the aggregation-service quickstart,
    and the thin-client transcipher quickstart (each is the first
    ```bash block after its heading)."""
    return (_run_snippet(r"quickstart") + _run_snippet(r"sharded uplink")
            + _run_snippet(r"aggregation service")
            + _run_snippet(r"thin-client transcipher"))


def check_gold_kats() -> list[str]:
    """The checked-in gold KATs (tests/golden/ckks_kats.json) must match a
    fresh recompute — a code change that silently moves the known answers
    fails the docs job, not just the test suite."""
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "gen_gold.py"),
         "--check"], cwd=ROOT, capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        return [f"gold KATs drifted (tools/gen_gold.py --check):\n"
                f"{proc.stdout}\n{proc.stderr}"]
    print(proc.stdout.strip().splitlines()[-1])
    return []


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--write", action="store_true",
                    help="refresh README bench tables instead of checking")
    ap.add_argument("--no-exec", action="store_true",
                    help="skip executing the README quickstart snippet")
    args = ap.parse_args()

    errors = check_links()
    errors += check_or_write_tables(write=args.write)
    errors += check_wire_spec()
    errors += check_env_table()
    errors += check_obs_docs()
    errors += check_tune_docs()
    errors += check_selective_docs()
    errors += check_serve_docs()
    errors += check_transcipher_docs()
    if not args.no_exec and not args.write:
        errors += run_quickstart()
        errors += check_gold_kats()
    for e in errors:
        print(f"DOCS ERROR: {e}", file=sys.stderr)
    if not errors:
        print("docs check passed")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
