"""Batched serving driver: prefill a batch of prompts, decode greedily.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-370m --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.models.sharding import axis_env_from_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    if not cfg.has_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step")
    mesh = make_host_mesh()
    with jax.sharding.set_mesh(mesh):
        ax = axis_env_from_mesh(mesh)
        model = build_model(cfg, ax)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.RandomState(0)
        prompts = jnp.asarray(
            rng.randint(0, cfg.vocab, (args.batch, args.prompt_len)))

        cache_len = args.prompt_len + args.gen
        prefill = jax.jit(lambda p, b: model.prefill(p, b,
                                                     cache_len=cache_len))
        decode = jax.jit(model.decode_step, donate_argnums=(1,))

        # perf_counter: these are durations; wall-clock would jump on
        # clock steps
        t0 = time.perf_counter()
        logits, cache = prefill(params, {"tokens": prompts})
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0

        out_tokens = []
        tok = jnp.argmax(logits, axis=-1)
        t0 = time.perf_counter()
        for _ in range(args.gen):
            out_tokens.append(np.asarray(tok))
            logits, cache = decode(params, cache, {"tokens": tok})
            tok = jnp.argmax(logits, axis=-1)
        jax.block_until_ready(logits)
        t_decode = time.perf_counter() - t0
        gen = np.stack(out_tokens, axis=1)
        print(f"prefill {args.batch}x{args.prompt_len} in {t_prefill:.3f}s; "
              f"decode {args.gen} steps in {t_decode:.3f}s "
              f"({args.batch*args.gen/max(t_decode,1e-9):.1f} tok/s)")
        print("generated ids:\n", gen)
    print("done")


if __name__ == "__main__":
    main()
