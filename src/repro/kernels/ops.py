"""Public jit'd wrappers over the HE kernels, with backend dispatch.

Backends:
  * "ref"    — pure-jnp oracle (repro/kernels/ref.py). Default on CPU: fast,
               exact, and what the FL examples/benchmarks run.
  * "pallas" — pl.pallas_call kernels. On CPU they run in interpret mode
               (kernel body executed in Python) for validation; on TPU they
               compile natively. Select via REPRO_HE_BACKEND=pallas or
               set_backend("pallas").

All functions operate on multi-limb tensors: x u32[..., L, N] with one
Montgomery context per limb (params.CkksContext.limbs).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import he_agg as _he_agg
from repro.kernels import ntt as _ntt
from repro.kernels import pointwise as _pointwise
from repro.kernels import ref as _ref

_BACKEND = os.environ.get("REPRO_HE_BACKEND", "ref")


def set_backend(name: str) -> None:
    global _BACKEND
    assert name in ("ref", "pallas"), name
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _per_limb(x, fn):
    """Apply fn(limb_2d_array, limb_index) over x[..., L, N]."""
    batch = x.shape[:-2]
    l, n = x.shape[-2], x.shape[-1]
    x2 = x.reshape((-1, l, n))
    outs = [fn(x2[:, i, :], i) for i in range(l)]
    return jnp.stack(outs, axis=1).reshape(batch + (l, n))


# ---------------------------------------------------------------------------


def ntt_fwd(x, ctx):
    """u32[..., L, N] natural -> bit-reversed NTT domain (per limb)."""
    def fn(x2, i):
        lc = ctx.limbs[i]
        tw = jnp.asarray(lc.psi_rev_mont)
        if _BACKEND == "pallas":
            return _ntt.ntt_fwd(x2, tw, lc.q, lc.qinv_neg, interpret=_interpret())
        return _ref.ntt_fwd(x2, tw, jnp.uint32(lc.q), jnp.uint32(lc.qinv_neg))
    return _per_limb(x, fn)


def ntt_inv(x, ctx):
    def fn(x2, i):
        lc = ctx.limbs[i]
        tw = jnp.asarray(lc.psi_inv_rev_mont)
        if _BACKEND == "pallas":
            return _ntt.ntt_inv(x2, tw, int(lc.n_inv_mont), lc.q, lc.qinv_neg,
                                interpret=_interpret())
        return _ref.ntt_inv(x2, tw, jnp.asarray(lc.n_inv_mont),
                            jnp.uint32(lc.q), jnp.uint32(lc.qinv_neg))
    return _per_limb(x, fn)


def mul_add(x, y_mont, z, ctx):
    """x (*) y_mont + z, all u32[..., L, N]."""
    batch = x.shape[:-2]
    l, n = x.shape[-2:]
    x2 = x.reshape((-1, l, n))
    y2 = jnp.broadcast_to(y_mont, x.shape).reshape((-1, l, n))
    z2 = jnp.broadcast_to(z, x.shape).reshape((-1, l, n))
    outs = []
    for i in range(l):
        lc = ctx.limbs[i]
        if _BACKEND == "pallas":
            outs.append(_pointwise.mul_add(x2[:, i], y2[:, i], z2[:, i],
                                           lc.q, lc.qinv_neg, interpret=_interpret()))
        else:
            outs.append(_ref.mul_add(x2[:, i], y2[:, i], z2[:, i],
                                     jnp.uint32(lc.q), jnp.uint32(lc.qinv_neg)))
    return jnp.stack(outs, axis=1).reshape(batch + (l, n))


def weighted_sum(cts, w_mont, ctx):
    """sum_i w_i (*) ct_i.  cts: u32[C, ..., L, N], w_mont: u32[C, L]."""
    c = cts.shape[0]
    batch = cts.shape[1:-2]
    l, n = cts.shape[-2:]
    cts2 = cts.reshape((c, -1, l, n))
    outs = []
    for i in range(l):
        lc = ctx.limbs[i]
        if _BACKEND == "pallas":
            outs.append(_he_agg.he_weighted_sum(cts2[:, :, i, :], w_mont[:, i],
                                                lc.q, lc.qinv_neg,
                                                interpret=_interpret()))
        else:
            outs.append(_ref.he_weighted_sum(
                cts2[:, :, i, :], w_mont[:, i].reshape((c,) + (1,) * 2),
                jnp.uint32(lc.q), jnp.uint32(lc.qinv_neg)))
    return jnp.stack(outs, axis=1).reshape(batch + (l, n))


def weighted_accum(acc, ct, w_mont, ctx):
    """Streaming aggregation step: acc + w (*) ct.

    acc, ct: u32[..., L, N]; w_mont: u32[L] Montgomery scalar weight.
    One client folded into the running sum — the O(1)-memory server path
    (repro.wire.stream); bit-identical to weighted_sum applied in order.
    """
    batch = ct.shape[:-2]
    l, n = ct.shape[-2:]
    ct2 = ct.reshape((-1, l, n))
    acc2 = jnp.broadcast_to(acc, ct.shape).reshape((-1, l, n))
    outs = []
    for i in range(l):
        lc = ctx.limbs[i]
        if _BACKEND == "pallas":
            outs.append(_he_agg.he_weighted_accum(
                acc2[:, i], ct2[:, i], w_mont[i].reshape((1,)),
                lc.q, lc.qinv_neg, interpret=_interpret()))
        else:
            outs.append(_ref.mul_add(ct2[:, i],
                                     jnp.broadcast_to(w_mont[i], ct2[:, i].shape),
                                     acc2[:, i],
                                     jnp.uint32(lc.q), jnp.uint32(lc.qinv_neg)))
    return jnp.stack(outs, axis=1).reshape(batch + (l, n))


# limb-wise helpers that have no kernel (cheap, always ref) -----------------


def mod_add(a, b, ctx):
    qs = _limb_q(ctx, a.shape)
    return _ref.mod_add(a, jnp.broadcast_to(b, a.shape), qs)


def mod_sub(a, b, ctx):
    qs = _limb_q(ctx, a.shape)
    return _ref.mod_sub(a, jnp.broadcast_to(b, a.shape), qs)


def mod_neg(a, ctx):
    return _ref.mod_neg(a, _limb_q(ctx, a.shape))


def to_mont(a, ctx):
    qs = _limb_q(ctx, a.shape)
    qinvs = _limb_const(ctx, a.shape, "qinv_neg")
    r2s = _limb_const(ctx, a.shape, "r2")
    return _ref.mont_mul(a, r2s, qs, qinvs)


def from_mont(a, ctx):
    qs = _limb_q(ctx, a.shape)
    qinvs = _limb_const(ctx, a.shape, "qinv_neg")
    return _ref.mont_mul(a, jnp.ones_like(a), qs, qinvs)


def mont_mul(a, b_mont, ctx):
    qs = _limb_q(ctx, a.shape)
    qinvs = _limb_const(ctx, a.shape, "qinv_neg")
    return _ref.mont_mul(a, jnp.broadcast_to(b_mont, a.shape), qs, qinvs)


def _limb_q(ctx, shape):
    return _limb_const(ctx, shape, "q")


def _limb_const(ctx, shape, field):
    """Broadcast per-limb constant over [..., L, N]."""
    vals = jnp.asarray([getattr(lc, field) for lc in ctx.limbs], dtype=jnp.uint32)
    return jnp.broadcast_to(vals[:, None], shape)
