"""Pure-jnp oracle for every Pallas kernel in this package.

The math is u32-only Montgomery arithmetic (R = 2**32), identical to what the
kernels run on the TPU VPU, so kernel-vs-ref checks are *exact integer
equality*.  A separate numpy-uint64 gold model lives in tests/gold.py to
validate this u32 construction itself.

Conventions (see DESIGN.md §3):
  * "data" polynomials (ciphertext limbs, messages) are in NORMAL residue form;
  * "operator" polynomials (keys, plaintexts, weights, twiddles) are stored in
    MONTGOMERY form, so mont_mul(data, op_mont) yields normal-form data;
  * NTT domain is bit-reversed (forward DIF / inverse DIT pairing): pointwise
    server ops never need a permutation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import numpy as np

_U16 = np.uint32(0xFFFF)
_SIXTEEN = np.uint32(16)


def _u32(x):
    # numpy scalars stay jaxpr literals (Pallas kernels must not capture
    # device-array constants); arrays pass through as u32.
    if isinstance(x, (int, np.integer)):
        return np.uint32(x)
    return jnp.asarray(x, dtype=jnp.uint32) if not (
        hasattr(x, "dtype") and x.dtype == jnp.uint32
    ) else x


# ---------------------------------------------------------------------------
# Montgomery core (u32 lanes only; TPU-VPU compatible)
# ---------------------------------------------------------------------------

def mont_mul(a, b, q, qinv_neg):
    """REDC(a*b) = a*b*R^{-1} mod q, element-wise. a,b < q < 2**30.

    16-bit limb decomposition: every partial product < 2**32; the two 64-bit
    intermediates (a*b and m*q) are carried as (hi, lo) u32 pairs with
    compare-based carry recovery.
    """
    a = _u32(a)
    b = _u32(b)
    q = _u32(q)
    qinv_neg = _u32(qinv_neg)
    a0 = a & _U16
    a1 = a >> _SIXTEEN
    b0 = b & _U16
    b1 = b >> _SIXTEEN
    p00 = a0 * b0
    mid = a0 * b1 + a1 * b0            # < 2**31 for a,b < 2**30
    p11 = a1 * b1
    t_lo = p00 + ((mid & _U16) << _SIXTEEN)
    carry = (t_lo < p00).astype(jnp.uint32)
    t_hi = p11 + (mid >> _SIXTEEN) + carry
    m = t_lo * qinv_neg                # low 32 bits of m = T_lo * (-q^{-1})
    # m*q as (hi, lo): m is full-range u32, so the cross-term sum m0*q1 + m1*q0
    # can itself wrap u32 — track its carry explicitly (weights 2**48).
    m0 = m & _U16
    m1 = m >> _SIXTEEN
    q0 = q & _U16
    q1 = q >> _SIXTEEN
    mq00 = m0 * q0
    p_b = m0 * q1                      # < 2**30 (q1 < 2**14)
    mqmid = p_b + m1 * q0              # may wrap
    mqmid_carry = (mqmid < p_b).astype(jnp.uint32)
    mq_lo = mq00 + ((mqmid & _U16) << _SIXTEEN)
    mq_carry = (mq_lo < mq00).astype(jnp.uint32)
    mq_hi = m1 * q1 + (mqmid >> _SIXTEEN) + (mqmid_carry << _SIXTEEN) + mq_carry
    # T_lo + mq_lo == 0 (mod 2**32) by construction of m; carry unless both 0.
    carry2 = (t_lo != np.uint32(0)).astype(jnp.uint32)
    t = t_hi + mq_hi + carry2
    return jnp.where(t >= q, t - q, t)


def mod_add(a, b, q):
    s = _u32(a) + _u32(b)   # < 2**31, no wrap
    q = _u32(q)
    return jnp.where(s >= q, s - q, s)


def mod_sub(a, b, q):
    a = _u32(a)
    b = _u32(b)
    q = _u32(q)
    return jnp.where(a >= b, a - b, a + q - b)


def mod_neg(a, q):
    a = _u32(a)
    q = _u32(q)
    return jnp.where(a == np.uint32(0), a, q - a)


def to_mont(a, q, qinv_neg, r2):
    """a -> a*R mod q."""
    return mont_mul(a, jnp.broadcast_to(_u32(r2), jnp.shape(a)), q, qinv_neg)


def from_mont(a, q, qinv_neg):
    """a*R -> a mod q (multiply by 1)."""
    return mont_mul(a, jnp.broadcast_to(np.uint32(1), jnp.shape(a)), q, qinv_neg)


# ---------------------------------------------------------------------------
# negacyclic NTT (Longa-Naehrig), vectorized over leading batch dims
# ---------------------------------------------------------------------------

def ntt_fwd(x, psi_rev_mont, q, qinv_neg):
    """Forward negacyclic NTT. x: u32[..., N] natural order -> bit-reversed.

    CT butterflies; twiddles psi^bitrev(m+i) in Montgomery form.
    """
    x = _u32(x)
    n = x.shape[-1]
    batch = x.shape[:-1]
    x = x.reshape((-1, n))
    m = 1
    t = n
    while m < n:
        t //= 2
        # group layout: [B, m, 2, t]; twiddle for group i is psi_rev[m+i]
        xs = x.reshape((-1, m, 2, t))
        u = xs[:, :, 0, :]
        s = jax.lax.dynamic_slice_in_dim(psi_rev_mont, m, m)[None, :, None]
        v = mont_mul(xs[:, :, 1, :], jnp.broadcast_to(s, xs[:, :, 1, :].shape), q, qinv_neg)
        x = jnp.stack([mod_add(u, v, q), mod_sub(u, v, q)], axis=2).reshape((-1, n))
        m *= 2
    return x.reshape(batch + (n,))


def ntt_inv(x, psi_inv_rev_mont, n_inv_mont, q, qinv_neg):
    """Inverse negacyclic NTT. x: u32[..., N] bit-reversed -> natural order."""
    x = _u32(x)
    n = x.shape[-1]
    batch = x.shape[:-1]
    x = x.reshape((-1, n))
    t = 1
    m = n
    while m > 1:
        h = m // 2
        xs = x.reshape((-1, h, 2, t))
        u = xs[:, :, 0, :]
        v = xs[:, :, 1, :]
        s = jax.lax.dynamic_slice_in_dim(psi_inv_rev_mont, h, h)[None, :, None]
        lo = mod_add(u, v, q)
        hi = mont_mul(mod_sub(u, v, q), jnp.broadcast_to(s, u.shape), q, qinv_neg)
        x = jnp.stack([lo, hi], axis=2).reshape((-1, n))
        t *= 2
        m = h
    x = mont_mul(x, jnp.broadcast_to(_u32(n_inv_mont), x.shape), q, qinv_neg)
    return x.reshape(batch + (n,))


# ---------------------------------------------------------------------------
# fused server/client pointwise ops (one ref per Pallas kernel)
# ---------------------------------------------------------------------------

def mul_add(x, y_mont, z, q, qinv_neg):
    """x (*) y_mont + z  (normal-form result). Encrypt/decrypt workhorse."""
    return mod_add(mont_mul(x, y_mont, q, qinv_neg), z, q)


def he_weighted_sum(cts, w_mont, q, qinv_neg):
    """Fused FedAvg aggregation over one limb: sum_i w_i (*) ct_i mod q.

    cts:    u32[n_clients, ..., N]  (normal form, NTT domain)
    w_mont: u32[n_clients]          (Montgomery-form scalar weights)
    """
    cts = _u32(cts)
    w = _u32(w_mont)
    n_clients = cts.shape[0]
    acc = mont_mul(cts[0], jnp.broadcast_to(w[0], cts[0].shape), q, qinv_neg)
    for i in range(1, n_clients):
        term = mont_mul(cts[i], jnp.broadcast_to(w[i], cts[i].shape), q, qinv_neg)
        acc = mod_add(acc, term, q)
    return acc


# ---------------------------------------------------------------------------
# limb-fused variants: the whole u32[..., L, N] tensor in one jnp graph
# ---------------------------------------------------------------------------
#
# Per-limb constants arrive as stacked u32[L] / u32[L, N] tables
# (params.LimbTables); the limb axis is broadcast, never looped in Python.
# These are the `ref` backend of the fused execution engine and the oracle
# the limb-grid Pallas kernels are checked against.


def _col(v):
    """u32[L] -> u32[L, 1] so it broadcasts over [..., L, N]."""
    return _u32(v)[:, None]


def rand_limbed_np(rng, ctx, shape):
    """Uniform per-limb residues u32[*shape, L, N] from a numpy RandomState —
    the fused-layout input generator shared by tests and benchmarks."""
    return np.stack(
        [rng.randint(0, int(q), size=tuple(shape) + (ctx.n_poly,))
         for q in ctx.primes], axis=-2).astype(np.uint32)


def ntt_fwd_fused(x, psi_rev_mont, qs, qinv_negs):
    """Forward negacyclic NTT over all limbs at once.

    x: u32[..., L, N] natural order -> bit-reversed; psi_rev_mont: u32[L, N];
    qs, qinv_negs: u32[L].
    """
    x = _u32(x)
    l, n = x.shape[-2], x.shape[-1]
    batch = x.shape[:-2]
    x = x.reshape((-1, l, n))
    q = _u32(qs)[None, :, None, None]
    qi = _u32(qinv_negs)[None, :, None, None]
    psi = _u32(psi_rev_mont)
    m, t = 1, n
    while m < n:
        t //= 2
        xs = x.reshape((-1, l, m, 2, t))
        u = xs[:, :, :, 0, :]
        s = psi[:, m:2 * m][None, :, :, None]
        v = mont_mul(xs[:, :, :, 1, :], jnp.broadcast_to(s, u.shape), q, qi)
        x = jnp.stack([mod_add(u, v, q), mod_sub(u, v, q)],
                      axis=3).reshape((-1, l, n))
        m *= 2
    return x.reshape(batch + (l, n))


def ntt_inv_fused(x, psi_inv_rev_mont, n_inv_monts, qs, qinv_negs):
    """Inverse negacyclic NTT over all limbs: bit-reversed -> natural."""
    x = _u32(x)
    l, n = x.shape[-2], x.shape[-1]
    batch = x.shape[:-2]
    x = x.reshape((-1, l, n))
    q = _u32(qs)[None, :, None, None]
    qi = _u32(qinv_negs)[None, :, None, None]
    psi_inv = _u32(psi_inv_rev_mont)
    t, m = 1, n
    while m > 1:
        h = m // 2
        xs = x.reshape((-1, l, h, 2, t))
        u = xs[:, :, :, 0, :]
        v = xs[:, :, :, 1, :]
        s = psi_inv[:, h:2 * h][None, :, :, None]
        lo = mod_add(u, v, q)
        hi = mont_mul(mod_sub(u, v, q), jnp.broadcast_to(s, u.shape), q, qi)
        x = jnp.stack([lo, hi], axis=3).reshape((-1, l, n))
        t *= 2
        m = h
    x = mont_mul(x, jnp.broadcast_to(_col(n_inv_monts), x.shape),
                 _col(qs), _col(qinv_negs))
    return x.reshape(batch + (l, n))


def mul_add_fused(x, y_mont, z, qs, qinv_negs):
    """x (*) y_mont + z over u32[..., L, N] with per-limb moduli."""
    return mod_add(mont_mul(x, y_mont, _col(qs), _col(qinv_negs)), z,
                   _col(qs))


def he_weighted_sum_fused(cts, w_mont, qs, qinv_negs):
    """sum_i w_i (*) ct_i over all limbs.

    cts: u32[C, ..., L, N]; w_mont: u32[C, L] Montgomery scalar weights.
    The client loop is unrolled (it is the fused-kernel accumulation order);
    the limb axis broadcasts.
    """
    cts = _u32(cts)
    w = _u32(w_mont)
    n_clients = cts.shape[0]
    wb = w.reshape((n_clients,) + (1,) * (cts.ndim - 3) + (w.shape[1], 1))
    q = _col(qs)
    qi = _col(qinv_negs)
    acc = mont_mul(cts[0], jnp.broadcast_to(wb[0], cts[0].shape), q, qi)
    for i in range(1, n_clients):
        term = mont_mul(cts[i], jnp.broadcast_to(wb[i], cts[i].shape), q, qi)
        acc = mod_add(acc, term, q)
    return acc


def he_weighted_accum_fused(acc, ct, w_mont, qs, qinv_negs):
    """acc + w (*) ct over u32[..., L, N]; w_mont: u32[L]."""
    return mul_add_fused(ct, jnp.broadcast_to(_col(w_mont), ct.shape), acc,
                         qs, qinv_negs)


def he_weighted_accum_chunks_fused(acc, cts, w_mont, qs, qinv_negs):
    """Batched streaming flush: acc[k] + w[k] (*) ct[k] for every ready
    chunk row k, all limbs and rows in one graph.

    acc, cts: u32[K, ..., L, N]; w_mont: u32[K, L] per-row Montgomery scalar
    weights (rows may belong to different clients); qs, qinv_negs: u32[L].
    """
    cts = _u32(cts)
    w = _u32(w_mont)
    k = cts.shape[0]
    wb = w.reshape((k,) + (1,) * (cts.ndim - 3) + (w.shape[1], 1))
    return mul_add_fused(cts, jnp.broadcast_to(wb, cts.shape), acc,
                         qs, qinv_negs)


def mod_lift_fused(x, qs):
    """Per-limb lift of raw u32 rows: out[..., l, :] = x[..., :] mod q_l.

    x: u32[..., N] FULL-RANGE 32-bit words (no limb axis — transcipher-
    masked coefficients or keystream pads); qs: u32[L].  Unlike the
    Montgomery ops there is no < 2**30 operand precondition: uint32
    remainder is exact over the whole range."""
    return _u32(x)[..., None, :] % _col(qs)


def mul_wide(a, b):
    """Full 32x32 -> 64-bit product as a (hi, lo) u32 pair."""
    a = _u32(a)
    b = jnp.broadcast_to(_u32(b), jnp.shape(a))
    a0 = a & _U16
    a1 = a >> _SIXTEEN
    b0 = b & _U16
    b1 = b >> _SIXTEEN
    p00 = a0 * b0
    p01 = a0 * b1
    p10 = a1 * b0
    p11 = a1 * b1
    mid = p01 + p10
    mid_carry = (mid < p01).astype(jnp.uint32)
    lo = p00 + ((mid & _U16) << _SIXTEEN)
    lo_carry = (lo < p00).astype(jnp.uint32)
    hi = p11 + (mid >> _SIXTEEN) + (mid_carry << _SIXTEEN) + lo_carry
    return hi, lo


def add_wide(h1, l1, h2, l2):
    """(h1,l1) + (h2,l2) mod 2**64, as u32 pairs."""
    lo = _u32(l1) + _u32(l2)
    carry = (lo < _u32(l1)).astype(jnp.uint32)
    return _u32(h1) + _u32(h2) + carry, lo


def sub_wide(h1, l1, h2, l2):
    """(h1,l1) - (h2,l2) mod 2**64 (caller guarantees no underflow)."""
    shape = jnp.broadcast_shapes(jnp.shape(l1), jnp.shape(l2))
    h1 = jnp.broadcast_to(_u32(h1), shape)
    l1 = jnp.broadcast_to(_u32(l1), shape)
    h2 = jnp.broadcast_to(_u32(h2), shape)
    l2 = jnp.broadcast_to(_u32(l2), shape)
    lo = l1 - l2
    borrow = (l1 < l2).astype(jnp.uint32)
    return h1 - h2 - borrow, lo


def gt_wide(h1, l1, h2, l2):
    """(h1,l1) > (h2,l2), elementwise bool."""
    shape = jnp.broadcast_shapes(jnp.shape(l1), jnp.shape(l2))
    h1 = jnp.broadcast_to(_u32(h1), shape)
    l1 = jnp.broadcast_to(_u32(l1), shape)
    h2 = jnp.broadcast_to(_u32(h2), shape)
    l2 = jnp.broadcast_to(_u32(l2), shape)
    return (h1 > h2) | ((h1 == h2) & (l1 > l2))


def wide_to_f32(hi, lo):
    """Exact-ish float of hi*2**32 + lo; caller guarantees hi is small
    (post-centering magnitudes), so the 2**32 scaling is exact in f32."""
    return hi.astype(jnp.float32) * jnp.float32(4294967296.0) + lo.astype(jnp.float32)


def mod_reduce_centered(v_signed_i64_like, q):
    """Map float/int 'centered' values into [0, q) residues (encode helper).

    Implemented over int32 magnitude + sign split so it works without x64.
    """
    v = jnp.asarray(v_signed_i64_like)
    neg = v < 0
    mag = jnp.abs(v).astype(jnp.uint32)
    r = mag % _u32(q)
    return jnp.where(neg, mod_neg(r, q), r)
