"""Aggregation-service driver: run FL rounds through repro.serve.

Simulates a client fleet against a live `AggregationService` — partial
quorum, async overlap (round r+1 accepts while round r folds in the
worker thread), optional crash-safe checkpointing and fault injection —
and prints per-round state-machine outcomes plus the bandwidth ledger.

  PYTHONPATH=src python -m repro.launch.serve --clients 64 --rounds 2 \
      --target 48 --min-clients 16
  PYTHONPATH=src python -m repro.launch.serve --clients 32 --rounds 1 \
      --fault 3:truncate --fault 5:garbage      # inject wire faults
  PYTHONPATH=src python -m repro.launch.serve --ckpt-dir /tmp/serve-ckpt \
      --crash-at after_seal                     # then rerun with --resume

The same flow at benchmark scale lives in `benchmarks.run serve`;
DESIGN.md §14 documents the state machine this drives.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import serve
from repro.core.ckks import cipher
from repro.core.ckks import params as ckks_params
from repro.core.secure_agg import ProtectedUpdate
from repro.serve import sim as ssim
from repro.wire import budget as wire_budget
from repro.wire import stream as wire_stream


def _parse_fault(s: str) -> tuple[int, str]:
    cid, _, mode = s.partition(":")
    if mode not in serve.FAULT_MODES:
        raise argparse.ArgumentTypeError(
            f"fault mode {mode!r} not in {serve.FAULT_MODES}")
    return int(cid), mode


def main():
    ap = argparse.ArgumentParser(
        description="Drive repro.serve.AggregationService with a simulated "
                    "client fleet (DESIGN.md §14).")
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--min-clients", type=int, default=4)
    ap.add_argument("--target", type=int, default=None,
                    help="seal as soon as this many updates accepted "
                         "(default: the full fleet)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="round deadline; late submissions are rejected")
    ap.add_argument("--n-poly", type=int, default=256)
    ap.add_argument("--n-chunks", type=int, default=2)
    ap.add_argument("--fold-batch", type=int, default=32)
    ap.add_argument("--fault", action="append", type=_parse_fault,
                    default=[], metavar="CID:MODE",
                    help="inject a wire fault into one client's blob "
                         f"(modes: {', '.join(serve.FAULT_MODES)})")
    ap.add_argument("--crash-at", choices=serve.CRASH_POINTS, default=None,
                    help="simulate kill -9 after this transition "
                         "(needs --ckpt-dir; rerun with --resume)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint every transition under this dir")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint in --ckpt-dir")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.crash_at and not args.ckpt_dir:
        ap.error("--crash-at needs --ckpt-dir (the crash leaves only the "
                 "checkpoint behind)")
    if args.resume and not args.ckpt_dir:
        ap.error("--resume needs --ckpt-dir")

    ctx = ckks_params.make_test_context(n_poly=args.n_poly, n_limbs=2,
                                        delta_bits=20)
    sk, pk = cipher.keygen(ctx, jax.random.PRNGKey(0))
    rng = np.random.RandomState(args.seed)

    def template(seed: int) -> bytes:
        v = rng.randn(args.n_chunks, ctx.slots).astype(np.float32)
        ct = cipher.encrypt_values(ctx, pk, jnp.asarray(v),
                                   jax.random.PRNGKey(seed))
        upd = ProtectedUpdate(ct=ct, plain=jnp.asarray(
            rng.randn(16).astype(np.float32)))
        return wire_stream.pack_update_frames(upd, cid=0, n_samples=1,
                                              rnd=0)

    fleet = ssim.Fleet([template(s) for s in range(4)], args.clients,
                       seed=args.seed)
    pol = serve.QuorumPolicy(min_clients=args.min_clients,
                             target_clients=args.target,
                             deadline_s=args.deadline_s)
    faults = serve.FaultInjector(seed=args.seed,
                                 crash_at=[args.crash_at]
                                 if args.crash_at else (),
                                 blob_faults=dict(args.fault))
    ledger = wire_budget.BandwidthLedger()

    if args.resume:
        svc = serve.AggregationService.resume(
            args.ckpt_dir, ctx, pol, fold_batch=args.fold_batch,
            faults=faults, ledger=ledger)
        print(f"resumed from {args.ckpt_dir}: rounds "
              f"{sorted(svc._rounds)}, open={svc.open_round_id}, "
              f"unfinished={svc.unfinished()}")
    else:
        svc = serve.AggregationService(
            ctx, pol, ckpt_dir=args.ckpt_dir, fold_batch=args.fold_batch,
            faults=faults, ledger=ledger)

    t0 = time.perf_counter()
    try:
        svc.start()
        for _ in range(args.rounds):
            if svc.open_round_id is not None:
                rnd = svc.open_round_id       # resumed mid-round
            else:
                rnd = svc.open_round()
            accepted = rejected = 0
            for cid, blob in fleet.blobs(rnd):
                res = svc.submit(faults.corrupt(cid, blob))
                accepted += res.accepted
                rejected += not res.accepted
            if svc.open_round_id == rnd:      # no target/deadline seal yet
                svc.seal()
            print(f"round {rnd}: submitted {args.clients}, accepted "
                  f"{accepted}, rejected-at-door {rejected}")
        while svc.unfinished() and svc.worker_error is None:
            time.sleep(0.005)
    finally:
        svc.stop()
    if isinstance(svc.worker_error, serve.SimulatedCrash):
        print(f"simulated crash: {svc.worker_error} — checkpoint is in "
              f"{args.ckpt_dir}; rerun with --resume")
        raise SystemExit(1)
    if svc.worker_error is not None:
        raise svc.worker_error

    wall = time.perf_counter() - t0
    for rnd in sorted(svc._rounds):
        info = svc.round_info(rnd)
        line = (f"round {rnd}: {info['status']} "
                f"(seal={info['sealed_reason']}, accepted="
                f"{info['accepted']}, folded={info['folded']}, "
                f"fold-rejects={info['bad_after_accept']}, "
                f"refolds={info['refolds']})")
        if info["status"] == serve.ST_DONE:
            agg = svc.result(rnd)
            vals = cipher.decrypt_values(ctx, sk, agg.ct)
            line += (f"  |decrypt|max={float(jnp.abs(vals).max()):.4f} "
                     f"scale={agg.ct.scale:.3g}")
        print(line)
    up = ledger.total(wire_budget.UPLINK)
    print(f"ledger: {up} uplink bytes over {len(ledger.rounds())} rounds; "
          f"{wall:.2f}s wall")


if __name__ == "__main__":
    main()
