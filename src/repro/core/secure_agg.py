"""Algorithm 1: HE-based federated aggregation with Selective Parameter
Encryption.

Data flow per round (single-key setup; threshold variant in fl/orchestrator):

  client:  vec = flatten(W_i)
           enc, plain = split_by_mask(vec, partition)         # static indices
           ct_i = Enc(pk, encode(enc))                        # [n_chunks] cts
           (optional) plain += Laplace(b)
  server:  ct_glob   = sum_i alpha_i (*) ct_i   # limb-fused kernel, one
                                                # launch across all RNS limbs
           plain_glob = sum_i alpha_i * plain_i               # plaintext
  client:  enc_glob = decode(Dec(sk, ct_glob))
           W_glob = unflatten(merge(enc_glob, plain_glob))

The server never sees the masked (most attack-prone) parameters in
plaintext; weights alpha_i are plaintext by default (paper §2.3) costing the
single multiplicative depth.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dp, packing, selection
from repro.core.ckks import cipher, encoding, transcipher
from repro.core.ckks.cipher import Ciphertext
from repro.core.ckks.params import CkksContext
from repro.core.packing import FlatSpec, MaskPartition


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ProtectedUpdate:
    """One client's outgoing update: encrypted chunks + plaintext rest."""

    ct: Ciphertext          # data u32[n_chunks, L, 2, N]
    plain: Any              # f32[n_plain]


@dataclasses.dataclass(frozen=True)
class AggregatorConfig:
    p_ratio: float = 0.1
    strategy: str = "top_p"      # top_p | random | per_layer | recipe | all | none
    dp_b: float = 0.0            # Laplace scale on plaintext part (0 = off)
    seed: int = 0


class SelectiveHEAggregator:
    """Stateful glue object owning (ctx, partition, flat spec)."""

    def __init__(self, ctx: CkksContext, spec: FlatSpec,
                 part: MaskPartition, cfg: AggregatorConfig):
        self.ctx = ctx
        self.spec = spec
        self.part = part
        self.cfg = cfg

    # -- construction -------------------------------------------------------

    @staticmethod
    def build(ctx: CkksContext, params, sens_vec: np.ndarray,
              cfg: AggregatorConfig) -> "SelectiveHEAggregator":
        spec = packing.make_flat_spec(params)
        mask = selection.build_mask(sens_vec, cfg.strategy, cfg.p_ratio,
                                    offsets=spec.offsets, sizes=spec.sizes,
                                    seed=cfg.seed)
        part = packing.make_partition(mask, ctx.slots)
        return SelectiveHEAggregator(ctx, spec, part, cfg)

    # -- client side ---------------------------------------------------------

    def client_protect(self, params, pk: dict, key,
                       sharded=None) -> ProtectedUpdate:
        vec, _ = packing.flatten_params(params)
        return self.client_protect_vec(vec, pk, key, sharded=sharded)

    def client_protect_vec(self, vec, pk: dict, key,
                           sharded=None) -> ProtectedUpdate:
        """Protect one flat update vector.

        With `sharded` (a core.ckks.sharded.ShardedHe), the encode FFT +
        encrypt run as one sharded dispatch over its mesh — ciphertext
        chunks along `data`, limbs along `model` — bit-identical to the
        single-device path (per-chunk key derivation, DESIGN.md §9).
        """
        enc_vals, plain = packing.split_by_mask(vec, self.part)
        k_enc, k_dp = jax.random.split(key)
        # encode FFT + encrypt run as ONE jitted dispatch (weights ->
        # ciphertext without leaving the graph)
        if sharded is not None:
            ct = sharded.encrypt_values(pk, enc_vals, k_enc)
        else:
            ct = cipher.encrypt_values(self.ctx, pk, enc_vals, k_enc)
        if self.cfg.dp_b > 0:
            plain = dp.laplace_noise_vec(plain, k_dp, self.cfg.dp_b)
        return ProtectedUpdate(ct=ct, plain=plain)

    def client_protect_seeded(self, params, sk: dict, key, a_seed: int,
                              sharded=None,
                              derive: int = cipher.DERIVE_FOLD_CHUNK
                              ) -> ProtectedUpdate:
        """client_protect via the seeded secret-key encrypt path: c1 is
        PRG(a_seed), so the wire layer (repro.wire) can ship (seed, c0) and
        halve uplink ciphertext bytes.  `a_seed` must be unique per
        (client, round); `derive` picks the per-chunk seed-derivation id
        (cipher.DERIVES, DESIGN.md §9.2) the wire will advertise.

        With `sharded`, the whole weights -> seeded-ciphertext graph is one
        multi-chip dispatch (ShardedHe.encrypt_values_seeded) producing the
        same bits as the single-device path — the uplink counterpart of the
        server's sharded aggregation."""
        vec, _ = packing.flatten_params(params)
        enc_vals, plain = packing.split_by_mask(vec, self.part)
        k_enc, k_dp = jax.random.split(key)
        if sharded is not None:
            ct = sharded.encrypt_values_seeded(sk, enc_vals, k_enc, a_seed,
                                               derive=derive)
        else:
            ct = cipher.encrypt_values_seeded(self.ctx, sk, enc_vals, k_enc,
                                              a_seed, derive=derive)
        if self.cfg.dp_b > 0:
            plain = dp.laplace_noise_vec(plain, k_dp, self.cfg.dp_b)
        return ProtectedUpdate(ct=ct, plain=plain)

    def client_protect_transcipher(self, params,
                                   cm: transcipher.ClientMaterials,
                                   key) -> tuple[np.ndarray, Any]:
        """Thin-client protect: mask the encrypted partition with the
        provisioned keystream — no NTT, no RNS arithmetic on the client
        (core/ckks/transcipher.py, DESIGN.md §15).

        Returns (masked u32[n_chunks, N], plain); the wire layer frames
        them (stream.pack_masked_update_frames) together with the escrow
        seed ciphertext from `cm`.  `key` is split exactly like
        client_protect_seeded's so an enabled dp_b adds the SAME plaintext
        noise as the seeded path under the same key — the transcipher
        round stays bit-comparable end to end."""
        vec, _ = packing.flatten_params(params)
        enc_vals, plain = packing.split_by_mask(vec, self.part)
        _, k_dp = jax.random.split(key)
        masked = transcipher.mask_values(self.ctx, cm,
                                         np.asarray(enc_vals))
        if self.cfg.dp_b > 0:
            plain = dp.laplace_noise_vec(plain, k_dp, self.cfg.dp_b)
        return masked, plain

    def client_recover(self, agg: ProtectedUpdate, sk: dict):
        """Decrypt + merge -> flat global vector."""
        if agg.ct.n_limbs == 2:
            enc = cipher.decrypt_values(self.ctx, sk, agg.ct)
        else:
            # limb-dropped downlink (repro.wire.compress.limb_drop): the jnp
            # decode path is 2-limb only, fall back to the any-count host path
            enc = jnp.asarray(cipher.decrypt_values_np(self.ctx, sk, agg.ct))
        return packing.merge_by_mask(enc, agg.plain, self.part)

    def client_recover_params(self, agg: ProtectedUpdate, sk: dict):
        return packing.unflatten_params(self.client_recover(agg, sk), self.spec)

    # -- server side ---------------------------------------------------------

    def server_aggregate(self, updates: Sequence[ProtectedUpdate],
                         weights: Sequence[float],
                         sharded=None) -> ProtectedUpdate:
        """sum_i alpha_i [[enc_i]]  +  sum_i alpha_i plain_i.

        Args:
            updates: one ProtectedUpdate per received client.
            weights: FedAvg weights alpha_i (python floats).
            sharded: optional core.ckks.sharded.ShardedHe; when given the
                HE aggregation runs sharded over its mesh (ciphertext
                chunks -> data axis, RNS limbs -> model axis) with
                bit-identical results to the single-device path.

        Returns:
            The aggregated ProtectedUpdate (ct scale = in_scale * delta).
        """
        cts = Ciphertext(
            data=jnp.stack([u.ct.data for u in updates]),
            scale=updates[0].ct.scale)
        if sharded is not None:
            ct_glob = sharded.weighted_sum(cts, list(weights))
        else:
            ct_glob = cipher.weighted_sum(self.ctx, cts, list(weights))
        w = jnp.asarray(np.asarray(weights, dtype=np.float32))
        plain_glob = jnp.einsum("c,cp->p",
                                w, jnp.stack([u.plain for u in updates]))
        return ProtectedUpdate(ct=ct_glob, plain=plain_glob)

    # -- reporting (paper's overhead tables) ---------------------------------

    def overhead_report(self) -> dict:
        part = self.part
        ct_bytes = self.ctx.encrypted_bytes(part.n_enc)
        pt_bytes = self.ctx.plaintext_bytes(part.n_plain)
        return {
            "n_total": part.n_total,
            "n_enc": part.n_enc,
            "ratio": part.ratio,
            "n_ciphertexts": part.n_chunks,
            "bytes_encrypted": ct_bytes,
            "bytes_plain": pt_bytes,
            "bytes_total": ct_bytes + pt_bytes,
            "bytes_all_plain": self.ctx.plaintext_bytes(part.n_total),
            "comm_ratio": (ct_bytes + pt_bytes)
                          / max(1, self.ctx.plaintext_bytes(part.n_total)),
        }


# ---------------------------------------------------------------------------
# encryption-mask agreement (paper §2.4 Step 2, Figure 4)
# ---------------------------------------------------------------------------


def agree_sensitivity(ctx: CkksContext, pk: dict, sk: dict,
                      local_sens_vecs: Sequence[np.ndarray],
                      weights: Sequence[float], key) -> np.ndarray:
    """HE-aggregate the clients' local sensitivity maps -> global map.

    Each client encrypts its map under pk; the server weighted-sums the
    ciphertexts (never seeing an individual map in the clear); the decrypted
    aggregate is the shared global sensitivity every client thresholds into
    the public mask (build_mask / agree_mask).
    """
    n = int(local_sens_vecs[0].size)
    slots = ctx.slots
    n_chunks = -(-n // slots)
    cts = []
    for i, s in enumerate(local_sens_vecs):
        buf = np.zeros(n_chunks * slots, dtype=np.float32)
        buf[:n] = np.asarray(s, dtype=np.float32).ravel()
        coeffs = jnp.asarray(encoding.encode_np(
            buf.reshape(n_chunks, slots), ctx))
        cts.append(cipher.encrypt_coeffs(ctx, pk, coeffs,
                                         jax.random.fold_in(key, i)))
    stacked = Ciphertext(data=jnp.stack([c.data for c in cts]),
                         scale=cts[0].scale)
    agg = cipher.weighted_sum(ctx, stacked, list(weights))
    return cipher.decrypt_values_np(ctx, sk, agg).ravel()[:n]


def agree_mask(ctx: CkksContext, pk: dict, sk: dict,
               local_sens_vecs: Sequence[np.ndarray],
               weights: Sequence[float], p: float, key, *,
               strategy: str = "top_p", offsets=None, sizes=None,
               seed: int = 0) -> np.ndarray:
    """Clients encrypt local sensitivity maps; server HE-aggregates them;
    clients decrypt the aggregate and derive the selection mask.

    `strategy` picks the selector applied to the decrypted aggregate
    (selection.build_mask): the global `top_p` default, `per_layer`, or
    the paper's `recipe` (top-p UNION first/last leaves) — the layer-aware
    strategies need `offsets`/`sizes` from the model's FlatSpec.

    (Algorithm 1 writes Select() over the ciphertext; comparisons are not
    CKKS-evaluable, so — as the paper's own implementation must — the
    decrypted aggregate is thresholded client-side and M becomes public FL
    configuration.  Documented in DESIGN.md §5 and §13.)
    """
    s_glob = agree_sensitivity(ctx, pk, sk, local_sens_vecs, weights, key)
    return selection.build_mask(s_glob, strategy, p, offsets=offsets,
                                sizes=sizes, seed=seed)
