"""Render a per-round phase/bytes/launches table from an obs trace file.

    PYTHONPATH=src python tools/round_report.py obs_trace.jsonl
    PYTHONPATH=src python tools/round_report.py trace.jsonl --json
    PYTHONPATH=src python tools/round_report.py trace.jsonl --min-coverage 0.9

Input is the Chrome-trace-event JSONL written by repro.obs (REPRO_OBS=1):
a leading "[" line plus one JSON event per line with a trailing comma —
the same file Perfetto loads.  The report reconstructs the span tree by
wall-time containment per (pid, tid) — the model the trace format itself
uses — then prints:

  * one row per "round" span: wall ms, per-phase breakdown
    (client / aggregate / broadcast / recover / checkpoint / other),
    measured bytes up/down, accumulate launches, and COVERAGE — the
    fraction of round wall time inside the round's direct child spans.
    `--min-coverage X` exits 1 if any round falls below X (CI uses 0.9:
    the tree must explain >=90% of where round time went).
  * one row per (op, backend token) over cat="kernel" events: launch
    count, total/mean ms.  Only TOP-LEVEL kernel events count — a
    kernel_launch wrapping a sharded dispatch that itself records a
    launch span would otherwise be double-counted.

Exit status: 0 on success, 1 on unparseable/empty trace or a coverage
violation.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict

#: round phases reported as dedicated columns, in display order ("client"
#: wraps train+encrypt in the orchestrator; quickstart parents "encrypt"
#: directly under the round)
PHASES = ("client", "encrypt", "aggregate", "broadcast", "recover",
          "checkpoint")


def parse_trace(path: str) -> list[dict]:
    """Trace file -> list of event dicts (tolerates the Chrome-array
    framing: leading '[', trailing commas, optional closing ']')."""
    events = []
    with open(path) as f:
        for line in f:
            line = line.strip().rstrip(",")
            if line in ("", "[", "]"):
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError:
                continue        # torn tail line from a crashed run
            if isinstance(ev, dict):
                events.append(ev)
    return events


def build_tree(events: list[dict]) -> list[dict]:
    """Complete ('X') events -> forest by wall-time containment per
    (pid, tid).  Each node gains 'children' and 'parent' keys; returns
    the roots in start order."""
    roots = []
    by_track = defaultdict(list)
    for ev in events:
        if ev.get("ph") == "X":
            by_track[(ev.get("pid"), ev.get("tid"))].append(ev)
    for track in by_track.values():
        # sort by start, longest first on ties so parents precede children
        track.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack = []
        for ev in track:
            ev["children"] = []
            ev["parent"] = None
            end = ev["ts"] + ev["dur"]
            while stack and ev["ts"] + 1e-9 >= stack[-1]["_end"]:
                stack.pop()
            if stack and end <= stack[-1]["_end"] + 1e-3:
                ev["parent"] = stack[-1]
                stack[-1]["children"].append(ev)
            else:
                roots.append(ev)
            ev["_end"] = end
            stack.append(ev)
    roots.sort(key=lambda e: e["ts"])
    return roots


def _walk(node: dict):
    yield node
    for c in node["children"]:
        yield from _walk(c)


def round_rows(roots: list[dict]) -> list[dict]:
    """One report row per 'round' span found anywhere in the forest."""
    rows = []
    for root in roots:
        for node in _walk(root):
            if node.get("name") != "round":
                continue
            args = node.get("args", {})
            dur_ms = node["dur"] / 1e3
            phase_ms = defaultdict(float)
            child_ms = 0.0
            for c in node["children"]:
                child_ms += c["dur"]
                key = c["name"] if c["name"] in PHASES else "other"
                phase_ms[key] += c["dur"] / 1e3
            launches = args.get("launches")
            if launches is None:
                launches = sum(1 for n in _walk(node)
                               if n.get("cat") == "kernel"
                               and "accum" in n.get("name", ""))
            rows.append({
                "round": args.get("round", -1),
                "wall_ms": dur_ms,
                **{p: phase_ms.get(p, 0.0) for p in PHASES},
                "other_ms": phase_ms.get("other", 0.0),
                "bytes_up": args.get("bytes_up", 0),
                "bytes_down": args.get("bytes_down", 0),
                "launches": launches,
                "coverage": min(1.0, child_ms / node["dur"])
                if node["dur"] > 0 else 0.0,
            })
    return rows


def kernel_rows(roots: list[dict]) -> list[dict]:
    """Per-(op, token) launch stats over TOP-LEVEL kernel events (a
    kernel event nested inside another kernel event is the same launch
    measured twice — e.g. the stream flush wrapping a sharded dispatch)."""
    acc = defaultdict(lambda: {"count": 0, "total_ms": 0.0})
    for root in roots:
        for node in _walk(root):
            if node.get("cat") != "kernel":
                continue
            p = node["parent"]
            nested = False
            while p is not None:
                if p.get("cat") == "kernel":
                    nested = True
                    break
                p = p["parent"]
            if nested:
                continue
            args = node.get("args", {})
            key = (args.get("op", node["name"]), args.get("token", "?"))
            acc[key]["count"] += 1
            acc[key]["total_ms"] += node["dur"] / 1e3
    rows = []
    for (op, token), a in sorted(acc.items()):
        rows.append({"op": op, "token": token, "count": a["count"],
                     "total_ms": a["total_ms"],
                     "mean_ms": a["total_ms"] / max(1, a["count"])})
    return rows


def render(rounds: list[dict], kernels: list[dict]) -> str:
    out = []
    out.append("per-round phases (ms):")
    hdr = (f"{'round':>5} {'wall':>9} "
           + " ".join(f"{p[:9]:>9}" for p in PHASES)
           + f" {'other':>9} {'up_B':>10} {'down_B':>10} "
             f"{'launch':>6} {'cover':>6}")
    out.append(hdr)
    for r in rounds:
        out.append(
            f"{r['round']:>5} {r['wall_ms']:>9.2f} "
            + " ".join(f"{r[p]:>9.2f}" for p in PHASES)
            + f" {r['other_ms']:>9.2f} {r['bytes_up']:>10,} "
              f"{r['bytes_down']:>10,} {r['launches']:>6} "
              f"{r['coverage']:>6.1%}")
    out.append("")
    out.append("kernel launches by (op, backend token):")
    out.append(f"{'op':<34} {'count':>6} {'total_ms':>9} {'mean_ms':>8} "
               f"token")
    for k in kernels:
        out.append(f"{k['op']:<34} {k['count']:>6} {k['total_ms']:>9.2f} "
                   f"{k['mean_ms']:>8.3f} {k['token']}")
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="per-round phase/bytes/launches report from an obs "
                    "trace (see repro/obs)")
    ap.add_argument("trace", help="Chrome-trace-event JSONL from REPRO_OBS=1")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as JSON instead of a table")
    ap.add_argument("--min-coverage", type=float, default=None,
                    help="exit 1 if any round's child-span coverage is "
                         "below this fraction")
    args = ap.parse_args(argv)

    try:
        events = parse_trace(args.trace)
    except OSError as e:
        print(f"round_report: cannot read {args.trace}: {e}",
              file=sys.stderr)
        return 1
    if not events:
        print(f"round_report: no events in {args.trace}", file=sys.stderr)
        return 1
    roots = build_tree(events)
    rounds = round_rows(roots)
    kernels = kernel_rows(roots)

    if args.json:
        print(json.dumps({"rounds": rounds, "kernels": kernels}, indent=2))
    else:
        print(render(rounds, kernels))

    if args.min_coverage is not None:
        if not rounds:
            print("round_report: --min-coverage given but no 'round' "
                  "spans in trace", file=sys.stderr)
            return 1
        bad = [r for r in rounds if r["coverage"] < args.min_coverage]
        if bad:
            print(f"round_report: {len(bad)} round(s) below coverage "
                  f"{args.min_coverage:.0%}: "
                  + ", ".join(f"round {r['round']}={r['coverage']:.1%}"
                              for r in bad), file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
