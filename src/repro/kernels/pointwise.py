"""Pallas TPU kernel: fused modular pointwise ops (one RNS limb).

`mul_add`:  out = x (*) y_mont + z  — the encrypt/decrypt workhorse:
    encrypt: c0 = pk0 (*) u + (e0 + m),  c1 = pk1 (*) u + e1
    decrypt: m~ = c1 (*) s + c0
Fusing the Montgomery multiply with the modular add keeps each operand to a
single HBM read (arithmetic intensity of HE pointwise ops is ~0.5 int-op/B,
firmly memory-bound — see EXPERIMENTS.md §Roofline-HE).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref as _ref


def _mul_add_body(x_ref, y_ref, z_ref, o_ref, *, q: int, qinv_neg: int):
    prod = _ref.mont_mul(x_ref[...], y_ref[...], q, qinv_neg)
    o_ref[...] = _ref.mod_add(prod, z_ref[...], q)


@functools.lru_cache(maxsize=128)
def _build(b: int, n: int, q: int, qinv_neg: int, block_b: int, interpret: bool):
    body = functools.partial(_mul_add_body, q=q, qinv_neg=qinv_neg)

    def call(x, y, z):
        grid = (pl.cdiv(b, block_b),)
        spec = pl.BlockSpec((block_b, n), lambda i: (i, 0))
        return pl.pallas_call(
            body,
            grid=grid,
            in_specs=[spec, spec, spec],
            out_specs=spec,
            out_shape=jax.ShapeDtypeStruct((b, n), jnp.uint32),
            interpret=interpret,
        )(x, y, z)

    return call


def mul_add(x, y_mont, z, q: int, qinv_neg: int, *, block_b: int = 8,
            interpret: bool = True):
    """out = x (*) y_mont + z mod q.  All u32[B, N]."""
    b, n = x.shape
    call = _build(b, n, int(q), int(qinv_neg), min(block_b, b), interpret)
    return call(x, y_mont, z)
