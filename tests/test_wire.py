"""repro.wire: serialization round-trips, seed-expanded uplink compression,
quantized plain partition, streaming O(1) server ingest, bandwidth ledger,
SelectiveHEAggregator.overhead_report coverage, and decoder fuzzing (every
mutated/truncated input raises WireError — deterministic sweeps always run;
hypothesis widens the search when installed)."""
import functools

import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # property tests skip cleanly
    from _hyp import given, settings, st

from repro.core import packing
from repro.core.ckks import cipher, encoding
from repro.core.ckks import params as ckks_params
from repro.core.secure_agg import (AggregatorConfig, ProtectedUpdate,
                                   SelectiveHEAggregator)
from repro import wire
from repro.wire import budget as wb
from repro.wire import compress as wc
from repro.wire import format as wf
from repro.wire import stream as ws

CTX = ckks_params.make_test_context(n_poly=256, n_limbs=2, delta_bits=20)
SK, PK = cipher.keygen(CTX, jax.random.PRNGKey(0))


def small_model(seed=1):
    r = np.random.RandomState(seed)
    return {"w1": jnp.asarray(r.randn(40, 10), jnp.float32),
            "b1": jnp.asarray(r.randn(50), jnp.float32)}


def make_agg(p=0.4, seed=3):
    m = small_model()
    n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(m))
    sens = np.abs(np.random.RandomState(seed).randn(n))
    return SelectiveHEAggregator.build(CTX, m, sens,
                                       AggregatorConfig(p_ratio=p)), m


def fresh_ct(b=2, seed=0):
    v = np.random.RandomState(seed).randn(b, CTX.slots).astype(np.float32)
    return v, cipher.encrypt_values(CTX, PK, jnp.asarray(v),
                                    jax.random.PRNGKey(seed + 1))


# ---------------------------------------------------------------------------
# format: lossless round-trips
# ---------------------------------------------------------------------------


def test_ciphertext_roundtrip_bitexact():
    _, ct = fresh_ct()
    out, off = wf.deserialize(wf.serialize_ciphertext(ct))
    assert off == len(wf.serialize_ciphertext(ct))
    np.testing.assert_array_equal(np.asarray(ct.data, dtype=np.uint32),
                                  out.data)
    assert out.scale == ct.scale
    # decrypts identically to the in-memory path
    a = cipher.decrypt_values(CTX, SK, ct)
    b = cipher.decrypt_values(CTX, SK, wire.deserialize(
        wire.serialize_ciphertext(ct))[0])
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keyset_roundtrip_bitexact():
    for keys in (PK, SK):
        out, _ = wf.deserialize(wf.serialize_keyset(keys))
        assert sorted(out) == sorted(keys)
        for k in keys:
            np.testing.assert_array_equal(np.asarray(keys[k]), out[k])


def test_partition_roundtrip():
    agg, _ = make_agg()
    out, _ = wf.deserialize(wf.serialize_partition(agg.part))
    assert out.n_total == agg.part.n_total and out.slots == agg.part.slots
    np.testing.assert_array_equal(out.enc_idx, agg.part.enc_idx)
    np.testing.assert_array_equal(out.plain_idx, agg.part.plain_idx)


def test_protected_update_roundtrip_bitexact():
    agg, m = make_agg()
    upd = agg.client_protect(m, PK, jax.random.PRNGKey(5))
    out, _ = wf.deserialize(wf.serialize_update(upd), CTX)
    np.testing.assert_array_equal(np.asarray(upd.ct.data, np.uint32),
                                  out.ct.data)
    np.testing.assert_allclose(np.asarray(upd.plain), np.asarray(out.plain),
                               rtol=0, atol=0)
    # serialized -> deserialized -> decrypt equals the in-memory path
    a = agg.client_recover(upd, SK)
    b = agg.client_recover(out, SK)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bad_magic_and_truncation_rejected():
    blob = bytearray(wf.serialize_ciphertext(fresh_ct()[1]))
    with pytest.raises(wf.NeedMoreData):
        wf.parse_frame(blob[:-1], 0)
    blob[0] = 0
    with pytest.raises(wf.WireError):
        wf.parse_frame(bytes(blob), 0)


def test_frame_reader_incremental():
    _, ct = fresh_ct()
    blob = wf.serialize_ciphertext(ct) + wf.serialize_keyset(PK)
    r = wf.FrameReader()
    got = []
    for i in range(0, len(blob), 97):       # arbitrary slicing
        r.feed(blob[i:i + 97])
        got.extend(r)
    assert [t for t, _, _ in got] == [wf.T_CIPHERTEXT, wf.T_KEYSET]


# ---------------------------------------------------------------------------
# versioning: v1 frames decode forever, unknown versions reject loudly
# ---------------------------------------------------------------------------


def _seeded_ct(b=2, seed=1, a_seed=77):
    v = np.random.RandomState(seed).randn(b, CTX.slots).astype(np.float32)
    coeffs = encoding.encode_jnp(jnp.asarray(v), CTX)
    return cipher.encrypt_coeffs_seeded(CTX, SK, coeffs,
                                        jax.random.PRNGKey(seed), a_seed)


def _seeded_ct_derive(derive, b=2, seed=1, a_seed=77):
    v = np.random.RandomState(seed).randn(b, CTX.slots).astype(np.float32)
    coeffs = encoding.encode_jnp(jnp.asarray(v), CTX)
    return cipher.encrypt_coeffs_seeded(CTX, SK, coeffs,
                                        jax.random.PRNGKey(seed), a_seed,
                                        derive=derive)


def _provisioned(a_seed=19, n_chunks=1, seed=6):
    from repro.core.ckks import transcipher as tc
    return tc.provision(CTX, SK, jax.random.PRNGKey(seed), a_seed, n_chunks)


def _masked_chunk(cm, seed=6):
    from repro.core.ckks import transcipher as tc
    v = np.random.RandomState(seed).randn(cm.n_chunks,
                                          CTX.slots).astype(np.float32)
    return wc.MaskedChunk(masked=tc.mask_values(CTX, cm, v),
                          a_seed=cm.a_seed, scale=cm.scale,
                          derive=cm.derive)


def test_v1_frames_roundtrip_through_v2_decoder_bitexact():
    """Every artifact emitted in the legacy v1 layout decodes bit-exactly
    on the current (v2-default) decoder."""
    _, ct = fresh_ct()
    out, _ = wf.deserialize(wf.serialize_ciphertext(ct, version=1))
    np.testing.assert_array_equal(np.asarray(ct.data, np.uint32), out.data)
    assert out.scale == ct.scale

    sct = wc.seed_compress(_seeded_ct(), 77)
    blob = wf.serialize_seeded_ciphertext(sct, version=1)
    # v1 seeded payload really has NO derive byte: header + <dQI> + array
    assert len(blob) + 1 == len(wf.serialize_seeded_ciphertext(sct))
    out, _ = wf.deserialize(blob)
    assert out.derive == wc.DERIVE_FOLD_CHUNK      # implied by v1
    np.testing.assert_array_equal(np.asarray(sct.c0, np.uint32), out.c0)
    np.testing.assert_array_equal(np.asarray(out.expand(CTX).data),
                                  np.asarray(_seeded_ct().data))

    agg, m = make_agg()
    upd = agg.client_protect(m, PK, jax.random.PRNGKey(5))
    out, _ = wf.deserialize(wf.serialize_update(upd, version=1), CTX)
    np.testing.assert_array_equal(np.asarray(upd.ct.data, np.uint32),
                                  out.ct.data)


def test_v1_update_stream_ingests_bit_identical_to_v2():
    agg, m = make_agg()
    upd = agg.client_protect_seeded(m, SK, jax.random.PRNGKey(6), a_seed=21)
    sct = wc.seed_compress(upd.ct, 21)
    blob_v1 = ws.pack_update_frames(upd, cid=0, n_samples=1, seeded=sct,
                                    version=1)
    blob_v2 = ws.pack_update_frames(upd, cid=0, n_samples=1, seeded=sct,
                                    version=2)
    assert blob_v1 != blob_v2          # layouts differ on the wire...
    outs = []
    for blob in (blob_v1, blob_v2):
        ing = ws.StreamIngest(CTX)
        ing.ingest(blob, 1.0)
        outs.append(ing.finalize())
    # ...but the decoded aggregate is bit-identical
    np.testing.assert_array_equal(np.asarray(outs[0].ct.data),
                                  np.asarray(outs[1].ct.data))


def test_unknown_wire_version_rejected_actionably():
    """A v3 frame must raise WireError, and the message must tell the
    operator which knob to flip (README section / REPRO_WIRE_VERSION)."""
    blob = bytearray(wf.serialize_ciphertext(fresh_ct()[1]))
    blob[4] = 3                        # version byte in the envelope
    with pytest.raises(wf.WireError, match="REPRO_WIRE_VERSION"):
        wf.deserialize(bytes(blob))
    with pytest.raises(wf.WireError, match="README"):
        wf.parse_frame(bytes(blob), 0)
    # emission is pinned to the supported set too
    with pytest.raises(wf.WireError, match="cannot emit"):
        wf.frame(wf.T_UPDATE_END, b"", version=3)


def test_v2_seeded_frame_carries_and_validates_derive():
    import dataclasses

    sct = wc.seed_compress(_seeded_ct(), 77)
    out, _ = wf.deserialize(wf.serialize_seeded_ciphertext(sct, version=2))
    assert out.derive == wc.DERIVE_FOLD_CHUNK
    # an unknown derive id on the wire is rejected at parse time
    bad = dataclasses.replace(sct, derive=9)
    blob = wf.serialize_seeded_ciphertext(bad, version=2)
    with pytest.raises(wf.WireError, match="derivation"):
        wf.deserialize(blob)
    # ...and cannot be down-serialized to v1 (which cannot express it)
    with pytest.raises(wf.WireError, match="not expressible"):
        wf.serialize_seeded_ciphertext(bad, version=1)


def test_derive_registry_consistent_across_layers():
    """One registry (core/ckks/cipher.py), re-exported unchanged by the
    wire layers — the negotiation tables can never drift apart."""
    assert cipher.DERIVES == wc.DERIVES == wf.DERIVES == (1, 2)
    assert wc.DERIVE_FOLD_CHUNK == cipher.DERIVE_FOLD_CHUNK == 1
    assert wc.DERIVE_CTR == cipher.DERIVE_CTR == 2


def test_v2_seeded_frame_roundtrips_derive_ctr_bitexact():
    """DERIVE_CTR negotiation end to end at the frame level: the v2 frame
    carries the id, the receiver's expand regenerates the exact ciphertext,
    and the two derive families really produce different bits."""
    ct = _seeded_ct_derive(wc.DERIVE_CTR, b=2, seed=2, a_seed=55)
    sct = wc.seed_compress(ct, 55, derive=wc.DERIVE_CTR)
    out, _ = wf.deserialize(wf.serialize_seeded_ciphertext(sct))
    assert out.derive == wc.DERIVE_CTR
    np.testing.assert_array_equal(np.asarray(out.expand(CTX).data),
                                  np.asarray(ct.data))
    ct_fold = _seeded_ct_derive(wc.DERIVE_FOLD_CHUNK, b=2, seed=2, a_seed=55)
    assert not np.array_equal(np.asarray(ct.data), np.asarray(ct_fold.data))
    # a v1 peer cannot be sent this stream — refuse, don't reinterpret
    with pytest.raises(wf.WireError, match="not expressible"):
        wf.serialize_seeded_ciphertext(sct, version=1)


def test_derive_ctr_seeded_stream_recovers_fedavg():
    """The negotiation matrix end to end: clients protect with
    derive=DERIVE_CTR, the packed v2 stream round-trips through
    StreamIngest, and FedAvg recovers; packing the same update for a v1
    peer refuses."""
    agg, m = make_agg()
    n = 3
    clients = [jax.tree_util.tree_map(lambda x, i=i: x + 0.1 * i, m)
               for i in range(n)]
    ing = ws.StreamIngest(CTX)
    for i, c in enumerate(clients):
        upd = agg.client_protect_seeded(c, SK, jax.random.PRNGKey(70 + i),
                                        a_seed=900 + i,
                                        derive=wc.DERIVE_CTR)
        sct = wc.seed_compress(upd.ct, 900 + i, derive=wc.DERIVE_CTR)
        blob = ws.pack_update_frames(upd, cid=i, n_samples=4, rnd=0,
                                     seeded=sct)
        with pytest.raises(wf.WireError, match="not expressible"):
            ws.pack_update_frames(upd, cid=i, n_samples=4, rnd=0,
                                  seeded=sct, version=1)
        ing.ingest(blob, 1.0 / n)
    rec = agg.client_recover_params(ing.finalize(), SK)
    expect = jax.tree_util.tree_map(lambda *xs: sum(xs) / n, *clients)
    err = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree_util.tree_leaves(rec), jax.tree_util.tree_leaves(expect)))
    assert err < 1e-2


# ---------------------------------------------------------------------------
# compress: seeded uplink, limb drop, plain quantization
# ---------------------------------------------------------------------------


def test_seeded_encrypt_decrypts_and_expands_bitexact():
    v = np.random.RandomState(0).randn(2, CTX.slots).astype(np.float32)
    coeffs = encoding.encode_jnp(jnp.asarray(v), CTX)
    ct = cipher.encrypt_coeffs_seeded(CTX, SK, coeffs, jax.random.PRNGKey(1),
                                      a_seed=77)
    out = cipher.decrypt_values(CTX, SK, ct)
    assert float(np.abs(np.asarray(out) - v).max()) < 3e-3
    sct = wc.seed_compress(ct, 77)
    np.testing.assert_array_equal(np.asarray(sct.expand(CTX).data),
                                  np.asarray(ct.data))


def test_seeded_uplink_bytes_leq_055x():
    v = np.random.RandomState(0).randn(3, CTX.slots).astype(np.float32)
    coeffs = encoding.encode_jnp(jnp.asarray(v), CTX)
    ct = cipher.encrypt_coeffs_seeded(CTX, SK, coeffs, jax.random.PRNGKey(1),
                                      a_seed=9)
    full = wf.serialize_ciphertext(ct)
    seeded = wf.serialize_seeded_ciphertext(wc.seed_compress(ct, 9))
    assert len(seeded) <= 0.55 * len(full)
    # and round-trips through the generic parser
    out, _ = wf.deserialize(seeded)
    np.testing.assert_array_equal(np.asarray(out.expand(CTX).data),
                                  np.asarray(ct.data))


def test_seeded_mixes_with_pk_ciphertexts():
    v = np.random.RandomState(3).randn(1, CTX.slots).astype(np.float32)
    coeffs = encoding.encode_jnp(jnp.asarray(v), CTX)
    ct_pk = cipher.encrypt_coeffs(CTX, PK, coeffs, jax.random.PRNGKey(4))
    ct_sk = cipher.encrypt_coeffs_seeded(CTX, SK, coeffs,
                                         jax.random.PRNGKey(5), a_seed=11)
    both = cipher.Ciphertext(
        data=jnp.stack([ct_pk.data, ct_sk.data]), scale=ct_pk.scale)
    agg = cipher.weighted_sum(CTX, both, [0.5, 0.5])
    out = cipher.decrypt_values(CTX, SK, agg)
    assert float(np.abs(np.asarray(out) - v).max()) < 3e-3


def test_limb_drop_halves_bytes_coarse_decrypt():
    v, ct = fresh_ct(b=1, seed=7)
    w = cipher.mul_plain_scalar(CTX, ct, 1.0)     # scale delta**2, like agg
    dropped = wc.limb_drop(CTX, w, 1)
    assert dropped.n_limbs == 1
    blob_full = wf.serialize_ciphertext(w)
    blob_drop = wf.serialize_ciphertext(dropped)
    assert len(blob_drop) < 0.55 * len(blob_full)
    out = cipher.decrypt_values_np(CTX, SK, dropped)
    # scale after the drop is delta**2/q ~ 2**11: coarse but faithful
    assert float(np.abs(out - v).max()) < 0.3


@pytest.mark.parametrize("codec,atol", [("f32", 0.0), ("f16", 2e-3),
                                        ("i8", 5e-2)])
def test_plain_quantization_tolerance(codec, atol):
    x = np.random.RandomState(0).randn(500).astype(np.float32)
    arr, qscale = wc.quantize_plain(x, codec)
    out = wc.dequantize_plain(arr, codec, qscale)
    assert float(np.abs(out - x).max()) <= atol + 1e-9
    if codec != "f32":
        assert arr.nbytes < x.nbytes


@pytest.mark.parametrize("x", [
    np.zeros(0, dtype=np.float32),               # empty segment
    np.zeros(16, dtype=np.float32),              # all-zero segment
], ids=["empty", "all-zero"])
def test_i8_degenerate_segments_quantize_to_zeros_scale_one(x):
    """Regression: amax == 0 made scale = 0 and x/scale put NaN on the
    wire.  Degenerate segments must emit zeros with scale 1 instead."""
    arr, qscale = wc.quantize_plain(x, "i8")
    assert qscale == 1.0 and arr.dtype == np.int8 and not arr.any()
    out = wc.dequantize_plain(arr, "i8", qscale)
    assert np.isfinite(out).all() and not out.any()


def test_i8_single_nonzero_and_subnormal_amax_stay_finite():
    x = np.zeros(10, dtype=np.float32)
    x[3] = 0.5
    arr, qscale = wc.quantize_plain(x, "i8")
    out = wc.dequantize_plain(arr, "i8", qscale)
    assert np.isfinite(out).all()
    assert float(np.abs(out - x).max()) <= 0.5 / 127 + 1e-9
    # a subnormal amax must never produce NaN/inf on the wire, whichever
    # branch (guard or normal quantization) it takes
    tiny = np.full(8, 1e-42, dtype=np.float32)
    arr, qscale = wc.quantize_plain(tiny, "i8")
    assert np.isfinite(qscale) and qscale > 0.0
    assert np.isfinite(arr.astype(np.float64)).all()
    out = wc.dequantize_plain(arr, "i8", qscale)
    assert np.isfinite(out).all()
    assert float(np.abs(out - tiny).max()) <= 1e-42


def test_i8_all_zero_plain_survives_update_stream():
    """End to end: an all-zero plain partition under the i8 codec packs,
    ingests, and aggregates to exact zeros (it used to poison the fold
    with NaN)."""
    agg, m = make_agg()
    upd = agg.client_protect(m, PK, jax.random.PRNGKey(8))
    zeroed = ProtectedUpdate(ct=upd.ct, plain=jnp.zeros_like(upd.plain))
    blob = ws.pack_update_frames(zeroed, cid=0, n_samples=1,
                                 plain_codec="i8")
    ing = ws.StreamIngest(CTX)
    ing.ingest(blob, 1.0)
    out = ing.finalize()
    assert np.isfinite(np.asarray(out.plain)).all()
    assert not np.asarray(out.plain).any()


# ---------------------------------------------------------------------------
# stream: chunked ingest, O(1) buffers, bit parity with batch aggregation
# ---------------------------------------------------------------------------


def _clients_updates(agg, m, n=6):
    clients = [jax.tree_util.tree_map(lambda x, i=i: x + 0.05 * i, m)
               for i in range(n)]
    ups = [agg.client_protect(c, PK, jax.random.PRNGKey(40 + i))
           for i, c in enumerate(clients)]
    return clients, ups


def test_streaming_bitexact_vs_batch_and_o1_buffers():
    agg, m = make_agg()
    clients, ups = _clients_updates(agg, m, n=6)
    wts = [1.0 / 6] * 6
    batch = agg.server_aggregate(ups, wts)

    ing = ws.StreamIngest(CTX)
    for u, w in zip(ups, wts):
        ing.ingest_update(u, w)
    out = ing.finalize()
    np.testing.assert_array_equal(np.asarray(batch.ct.data, np.uint32),
                                  np.asarray(out.ct.data, np.uint32))
    assert out.ct.scale == batch.ct.scale
    np.testing.assert_allclose(np.asarray(batch.plain), np.asarray(out.plain),
                               atol=1e-5)
    # server-side update buffers stay O(1) in the client count: at most ONE
    # update's chunks are resident between flushes
    assert ing.peak_chunk_buffers == agg.part.n_chunks
    assert ing.clients_ingested == 6
    # one chunk-batched accumulate launch per flush, one flush per update
    assert ing.accum_launches == 6


def test_serialized_seeded_stream_recovers_fedavg():
    agg, m = make_agg()
    n = 5
    clients = [jax.tree_util.tree_map(lambda x, i=i: x + 0.1 * i, m)
               for i in range(n)]
    blobs = []
    for i, c in enumerate(clients):
        upd = agg.client_protect_seeded(c, SK, jax.random.PRNGKey(60 + i),
                                        a_seed=500 + i)
        sct = wc.seed_compress(upd.ct, 500 + i)
        blobs.append(ws.pack_update_frames(upd, cid=i, n_samples=4, rnd=0,
                                           seeded=sct))
    metas = [ws.peek_update_meta(b) for b in blobs]
    assert all(mt.seeded and mt.n_chunks == agg.part.n_chunks for mt in metas)
    ing = ws.StreamIngest(CTX)
    for b in blobs:
        ing.ingest(b, 1.0 / n)
    rec = agg.client_recover_params(ing.finalize(), SK)
    expect = jax.tree_util.tree_map(lambda *xs: sum(xs) / n, *clients)
    err = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree_util.tree_leaves(rec), jax.tree_util.tree_leaves(expect)))
    assert err < 1e-2
    # ready-chunk buffering: one update's chunks resident at the peak,
    # folded by ONE accumulate launch per client update (not per chunk)
    assert ing.peak_chunk_buffers == agg.part.n_chunks
    assert ing.accum_launches == n


def test_stream_rejects_truncated_update():
    agg, m = make_agg()
    upd = agg.client_protect(m, PK, jax.random.PRNGKey(1))
    blob = ws.pack_update_frames(upd, cid=0, n_samples=1)
    # chop off the END frame
    *frames, _ = list(wf.iter_frames(blob))
    truncated = blob[:len(blob) - wf.HEADER_BYTES]
    ing = ws.StreamIngest(CTX)
    with pytest.raises(wf.WireError):
        ing.ingest(truncated, 1.0)


def test_stream_rejected_update_contributes_nothing():
    """A rejected update must leave NO trace: not its chunks, not its
    plain segment, not the scale it tried to establish."""
    agg, m = make_agg()
    good = ws.pack_update_frames(agg.client_protect(
        m, PK, jax.random.PRNGKey(1)), cid=0, n_samples=1)
    bad_upd = agg.client_protect(m, PK, jax.random.PRNGKey(2))
    bad = ws.pack_update_frames(bad_upd, cid=1, n_samples=1)
    # chop off the END frame -> rejected, but its PLAIN_SEGMENT and chunks
    # were already parsed by then
    truncated = bad[:len(bad) - wf.HEADER_BYTES]

    ing_clean = ws.StreamIngest(CTX)
    ing_clean.ingest(good, 1.0)
    clean = ing_clean.finalize()

    ing = ws.StreamIngest(CTX)
    with pytest.raises(wf.WireError):
        ing.ingest(truncated, 1.0)
    assert ing._in_scale is None and not ing._pending
    # the rejected chunks must not have pinned accumulator dims either
    assert ing._acc_ct is None
    ing.ingest(good, 1.0)
    out = ing.finalize()
    np.testing.assert_array_equal(np.asarray(out.ct.data),
                                  np.asarray(clean.ct.data))
    np.testing.assert_array_equal(np.asarray(out.plain),
                                  np.asarray(clean.plain))


def test_stream_corrupt_chunk_payload_drops_buffered_chunks():
    """Parse failures below the frame envelope (e.g. a short chunk payload)
    must roll the rejected update's buffered chunks back AND surface as
    WireError — never a raw struct/numpy error."""
    agg, m = make_agg()
    upd = agg.client_protect(m, PK, jax.random.PRNGKey(1))
    blob = ws.pack_update_frames(upd, cid=0, n_samples=1)
    frames = []
    off = 0
    while off < len(blob):
        _, _, _, end = wf.parse_frame(blob, off)
        frames.append(blob[off:end])
        off = end
    # replace the SECOND chunk with a syntactically-valid frame whose
    # payload is too short to parse
    corrupt = wf.frame(wf.T_CT_CHUNK, b"\x01")
    mangled = b"".join(frames[:2] + [corrupt] + frames[3:])
    ing = ws.StreamIngest(CTX)
    with pytest.raises(wf.WireError):
        ing.ingest(mangled, 1.0)
    assert not ing._pending          # first chunk was rolled back
    assert ing.peak_chunk_buffers <= agg.part.n_chunks


# ---------------------------------------------------------------------------
# decoder fuzzing: any mutation/truncation -> WireError, never a crash,
# hang, or over-read.  The deterministic sweeps below run in every
# environment; the @given variants widen the same properties with
# hypothesis when it is installed (tests/_hyp.py guard otherwise).
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=1)
def _fuzz_corpus() -> tuple:
    """Valid frames of every type, covering BOTH wire versions and BOTH
    seed-derivation paths (v1's implicit derive byte and v2's explicit
    one)."""
    blobs = []
    _, ct = fresh_ct(b=1, seed=3)
    for v in (1, 2):
        blobs.append(wf.serialize_ciphertext(ct, version=v))
    sct = wc.seed_compress(_seeded_ct(b=1, seed=2, a_seed=5), 5)
    for v in (1, 2):
        blobs.append(wf.serialize_seeded_ciphertext(sct, version=v))
    blobs.append(wf.serialize_keyset(PK))
    agg, m = make_agg()
    blobs.append(wf.serialize_partition(agg.part))
    upd = agg.client_protect(m, PK, jax.random.PRNGKey(5))
    for v in (1, 2):
        blobs.append(wf.serialize_update(upd, version=v))
    upd_s = agg.client_protect_seeded(m, SK, jax.random.PRNGKey(6), a_seed=9)
    for v in (1, 2):
        blobs.append(wf.serialize_update(
            upd_s, seeded=wc.seed_compress(upd_s.ct, 9), version=v))
    # the v2-only paths: DERIVE_CTR seeded frames and the transcipher
    # (masked chunk + escrow seed) frames
    sct_ctr = wc.seed_compress(
        _seeded_ct_derive(wc.DERIVE_CTR, b=1, seed=4, a_seed=13), 13,
        derive=wc.DERIVE_CTR)
    blobs.append(wf.serialize_seeded_ciphertext(sct_ctr))
    cm, _ = _provisioned(a_seed=19, n_chunks=1, seed=6)
    blobs.append(wf.serialize_masked_chunk(_masked_chunk(cm, seed=6)))
    blobs.append(wf.serialize_transcipher_seed(
        wc.seed_compress(cm.seed_ct, cm.escrow_a_seed, cm.derive)))
    return tuple(bytes(b) for b in blobs)


def _decode_ok_or_wire_error(blob: bytes) -> None:
    """The fuzz property: decode either succeeds or raises WireError."""
    try:
        wf.deserialize(blob, CTX)
    except wf.WireError:
        pass           # includes NeedMoreData for truncations


def test_fuzz_corpus_is_valid():
    for blob in _fuzz_corpus():
        out, end = wf.deserialize(blob, CTX)
        assert end == len(blob) and out is not None


def test_fuzz_truncation_always_wire_error():
    """EVERY proper prefix of every valid frame must be rejected with
    WireError (NeedMoreData for envelope-level cuts)."""
    for blob in _fuzz_corpus():
        cuts = set(range(0, min(len(blob), 64))) | {
            len(blob) * k // 23 for k in range(23)} | {len(blob) - 1}
        for cut in sorted(cuts):
            if cut >= len(blob):
                continue
            with pytest.raises(wf.WireError):
                wf.deserialize(blob[:cut], CTX)


def test_fuzz_mutation_never_crashes():
    """Single-byte mutations anywhere in any frame: decode either succeeds
    (a data byte changed) or raises WireError — no other exception type,
    no hang, no over-read."""
    rng = np.random.RandomState(0)
    for blob in _fuzz_corpus():
        positions = np.concatenate([
            np.arange(min(len(blob), 48)),           # every header byte
            rng.randint(0, len(blob), size=64)])     # random payload bytes
        for pos in positions:
            b = bytearray(blob)
            b[pos] ^= 1 + rng.randint(0, 255)
            _decode_ok_or_wire_error(bytes(b))


def test_fuzz_garbage_and_resized_buffers():
    rng = np.random.RandomState(1)
    for n in (0, 1, wf.HEADER_BYTES - 1, wf.HEADER_BYTES, 64, 4096):
        _decode_ok_or_wire_error(rng.bytes(n))
    # valid header, absurd declared length
    for blob in _fuzz_corpus()[:2]:
        _decode_ok_or_wire_error(blob + rng.bytes(17))    # trailing junk
        grown = bytearray(blob)
        grown[8:16] = (2 ** 62).to_bytes(8, "little")     # payload_len
        _decode_ok_or_wire_error(bytes(grown))


def test_fuzz_stream_ingest_never_crashes():
    """The streaming server path under the same property: a mutated or
    truncated update blob raises WireError and leaves the ingest clean for
    the next client."""
    agg, m = make_agg()
    upd = agg.client_protect(m, PK, jax.random.PRNGKey(1))
    blob = ws.pack_update_frames(upd, cid=0, n_samples=1)
    rng = np.random.RandomState(2)
    ing = ws.StreamIngest(CTX)
    rejected = 0
    for _ in range(60):
        b = bytearray(blob)
        if rng.rand() < 0.5:
            b = b[:rng.randint(0, len(blob))]
        else:
            b[rng.randint(0, len(b))] ^= 1 + rng.randint(0, 255)
        try:
            ing.ingest(bytes(b), 0.5)
        except wf.WireError:
            rejected += 1
    assert rejected > 0
    # after arbitrary rejections the ingest still accepts a clean update
    ing.ingest(blob, 1.0)
    assert ing.finalize() is not None


def test_fuzz_transcipher_stream_ingest_never_crashes():
    """Same property for the masked (transcipher) update stream: mutations
    and truncations reject with WireError, leave no partial state, and the
    ingest still accepts the clean blob afterwards."""
    from repro.core.ckks import transcipher as tc
    cm, sm = _provisioned(a_seed=19, n_chunks=2, seed=6)
    v = np.random.RandomState(6).randn(2, CTX.slots).astype(np.float32)
    mc = wc.MaskedChunk(masked=tc.mask_values(CTX, cm, v), a_seed=cm.a_seed,
                        scale=cm.scale, derive=cm.derive)
    sct = wc.seed_compress(cm.seed_ct, cm.escrow_a_seed, cm.derive)
    blob = ws.pack_masked_update_frames(
        mc, sct, np.zeros(4, np.float32), cid=0, n_samples=1, rnd=0)
    rng = np.random.RandomState(3)
    ing = ws.StreamIngest(CTX, transcipher_materials={(0, 0): sm})
    rejected = 0
    for _ in range(60):
        b = bytearray(blob)
        if rng.rand() < 0.5:
            b = b[:rng.randint(0, len(blob))]
        else:
            b[rng.randint(0, len(b))] ^= 1 + rng.randint(0, 255)
        try:
            ing.ingest(bytes(b), 0.5)
        except wf.WireError:
            rejected += 1
            assert not ing._pending
    assert rejected > 0
    ing.ingest(blob, 1.0)
    assert ing.finalize() is not None


@given(st.data())
@settings(max_examples=80, deadline=None)
def test_hyp_mutation_rejected_or_decoded(data):
    blobs = _fuzz_corpus()
    blob = data.draw(st.sampled_from(blobs))
    pos = data.draw(st.integers(0, len(blob) - 1))
    val = data.draw(st.integers(0, 255))
    b = bytearray(blob)
    b[pos] = val
    _decode_ok_or_wire_error(bytes(b))


@given(st.data())
@settings(max_examples=60, deadline=None)
def test_hyp_truncation_rejected(data):
    blobs = _fuzz_corpus()
    blob = data.draw(st.sampled_from(blobs))
    cut = data.draw(st.integers(0, len(blob) - 1))
    with pytest.raises(wf.WireError):
        wf.deserialize(blob[:cut], CTX)


@given(st.data())
@settings(max_examples=40, deadline=None)
def test_hyp_valid_frames_roundtrip(data):
    """Arbitrary valid ciphertext/seeded frames round-trip bit-exactly on
    both wire versions and both derive paths."""
    b = data.draw(st.integers(1, 3))
    seed = data.draw(st.integers(0, 2 ** 16))
    version = data.draw(st.sampled_from([1, 2]))
    seeded = data.draw(st.booleans())
    if seeded:
        a_seed = data.draw(st.integers(0, 2 ** 31))
        sct = wc.seed_compress(_seeded_ct(b=b, seed=seed, a_seed=a_seed),
                               a_seed)
        out, end = wf.deserialize(
            wf.serialize_seeded_ciphertext(sct, version=version))
        np.testing.assert_array_equal(np.asarray(sct.c0, np.uint32), out.c0)
        assert out.seed == sct.seed and out.derive == wc.DERIVE_FOLD_CHUNK
    else:
        _, ct = fresh_ct(b=b, seed=seed)
        blob = wf.serialize_ciphertext(ct, version=version)
        out, end = wf.deserialize(blob)
        assert end == len(blob)
        np.testing.assert_array_equal(np.asarray(ct.data, np.uint32),
                                      out.data)
        assert out.scale == ct.scale


@given(st.data())
@settings(max_examples=60, deadline=None)
def test_hyp_stream_ingest_mutation(data):
    agg, m = make_agg()
    upd = agg.client_protect(m, PK, jax.random.PRNGKey(1))
    blob = ws.pack_update_frames(upd, cid=0, n_samples=1)
    pos = data.draw(st.integers(0, len(blob) - 1))
    val = data.draw(st.integers(0, 255))
    b = bytearray(blob)
    b[pos] = val
    ing = ws.StreamIngest(CTX)
    try:
        ing.ingest(bytes(b), 1.0)
    except wf.WireError:
        assert not ing._pending          # rejected updates leave no trace


def test_stream_mismatched_plain_segment_rejected_atomically():
    """A well-framed update whose plain segment length disagrees with the
    running aggregation must be rejected as WireError INSIDE the rollback
    scope: its buffered ciphertext chunks are dropped and the plain
    accumulator keeps its exact pre-ingest values."""
    agg, m = make_agg()
    good = agg.client_protect(m, PK, jax.random.PRNGKey(1))
    ing = ws.StreamIngest(CTX)
    ing.ingest(ws.pack_update_frames(good, cid=0, n_samples=1), 0.5)
    snap_plain = np.array(ing._acc_plain)
    snap_acc = {i: np.asarray(v) for i, v in ing._acc_ct.items()}
    bad = ProtectedUpdate(ct=good.ct, plain=good.plain[:-5])
    with pytest.raises(wf.WireError, match="plain segment"):
        ing.ingest(ws.pack_update_frames(bad, cid=1, n_samples=1), 0.5)
    assert not ing._pending              # rejected chunks dropped
    np.testing.assert_array_equal(np.asarray(ing._acc_plain), snap_plain)
    # a clean third client still folds, unaffected by the rejection
    ing.ingest(ws.pack_update_frames(good, cid=2, n_samples=1), 0.5)
    for i, v in snap_acc.items():
        assert not np.array_equal(np.asarray(ing._acc_ct[i]), v)


def test_stream_mismatched_chunk_shape_rejected_atomically():
    """Same contract for the ciphertext side: a chunk whose (L, N) dims
    disagree with the pinned aggregation dims raises WireError and leaves
    no queued chunks behind."""
    agg, m = make_agg()
    good = agg.client_protect(m, PK, jax.random.PRNGKey(1))
    ing = ws.StreamIngest(CTX)
    ing.ingest(ws.pack_update_frames(good, cid=0, n_samples=1), 0.5)
    n_chunks = good.ct.data.shape[0]
    bad_ct = cipher.Ciphertext(
        data=jnp.zeros((n_chunks, CTX.n_limbs, 2, CTX.n_poly // 2),
                       jnp.uint32),
        scale=good.ct.scale)
    bad = ProtectedUpdate(ct=bad_ct, plain=good.plain)
    with pytest.raises(wf.WireError, match="chunk shape"):
        ing.ingest(ws.pack_update_frames(bad, cid=1, n_samples=1), 0.5)
    assert not ing._pending
    ing.ingest(ws.pack_update_frames(good, cid=2, n_samples=1), 0.5)
    assert ing.finalize() is not None


def test_stream_rejects_missing_or_duplicate_chunk():
    agg, m = make_agg()
    upd = agg.client_protect(m, PK, jax.random.PRNGKey(1))
    assert agg.part.n_chunks >= 2
    blob = ws.pack_update_frames(upd, cid=0, n_samples=1)
    frames = []
    off = 0
    while off < len(blob):
        _, _, _, end = wf.parse_frame(blob, off)
        frames.append(blob[off:end])
        off = end
    # frames: BEGIN, CT_CHUNK * n, PLAIN, END — drop one chunk frame
    dropped = b"".join(frames[:1] + frames[2:])
    with pytest.raises(wf.WireError, match="chunks"):
        ws.StreamIngest(CTX).ingest(dropped, 1.0)
    # duplicate a chunk frame
    duped = b"".join(frames[:2] + [frames[1]] + frames[2:])
    with pytest.raises(wf.WireError, match="duplicate"):
        ws.StreamIngest(CTX).ingest(duped, 1.0)


# ---------------------------------------------------------------------------
# budget ledger
# ---------------------------------------------------------------------------


def test_ledger_record_blob_classes_and_totals():
    agg, m = make_agg()
    upd = agg.client_protect_seeded(m, SK, jax.random.PRNGKey(2), a_seed=3)
    sct = wc.seed_compress(upd.ct, 3)
    blob = ws.pack_update_frames(upd, cid=7, n_samples=2, rnd=1, seeded=sct,
                                 plain_codec="f16")
    led = wb.BandwidthLedger()
    total = led.record_blob(blob, rnd=1, cid=7, direction=wb.UPLINK)
    assert total == len(blob)
    assert led.total(wb.UPLINK, 1) == len(blob)
    s = led.round_summary(1)
    assert s["uplink_bytes"] == len(blob) and s["downlink_bytes"] == 0
    assert s["by_kind"]["up/seeded_ciphertext"] > 0
    assert s["by_kind"]["up/plain"] > 0
    comp = led.compression_summary(CTX, agg.part, 1)
    assert comp["compression_ratio"] > 1.0
    assert comp["measured_uplink_bytes"] == len(blob)


# ---------------------------------------------------------------------------
# overhead_report (satellite coverage)
# ---------------------------------------------------------------------------


def test_overhead_report_consistency():
    agg, _ = make_agg(p=0.4)
    rep = agg.overhead_report()
    part = agg.part
    assert rep["n_total"] == part.n_total
    assert rep["n_enc"] == part.n_enc
    assert rep["n_ciphertexts"] == part.n_chunks
    assert rep["ratio"] == pytest.approx(part.n_enc / part.n_total)
    assert rep["bytes_total"] == rep["bytes_encrypted"] + rep["bytes_plain"]
    assert rep["bytes_plain"] == 4 * part.n_plain
    assert rep["bytes_all_plain"] == 4 * part.n_total
    assert rep["comm_ratio"] == pytest.approx(
        rep["bytes_total"] / rep["bytes_all_plain"])


def test_overhead_report_monotone_in_p():
    reps = [make_agg(p=p)[0].overhead_report() for p in (0.1, 0.5, 1.0)]
    assert reps[0]["n_enc"] <= reps[1]["n_enc"] <= reps[2]["n_enc"]
    assert reps[0]["bytes_total"] <= reps[1]["bytes_total"]
    # all-encrypted blows up communication; selective shrinks it
    assert reps[2]["comm_ratio"] > reps[0]["comm_ratio"]


def test_overhead_report_vs_measured_wire():
    """The report's byte model matches the measured raw-u32 wire within
    framing overhead for the uncompressed path."""
    agg, m = make_agg(p=0.4)
    upd = agg.client_protect(m, PK, jax.random.PRNGKey(3))
    blob = wf.serialize_update(upd)
    est = CTX.encrypted_bytes(agg.part.n_enc, packed=False) \
        + CTX.plaintext_bytes(agg.part.n_plain)
    assert abs(len(blob) - est) < 256   # headers only
