"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks.
Source: arXiv:2411.15242 (unverified tier).
81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
    vocab=32000, ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    shared_attn_every=6, tie_embeddings=True,
    dtype="bfloat16", param_dtype="float32", remat=True,
)

SMOKE = ModelConfig(
    name="zamba2-7b-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=257, ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=8,
    shared_attn_every=2, tie_embeddings=True, attn_chunk=16,
)
