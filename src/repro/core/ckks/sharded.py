"""Multi-chip sharded HE engine: the limb-fused execution model mapped onto
a device mesh (DESIGN.md §8).

PR 2 made RNS limbs a grid/batch axis so every op is one kernel launch;
this module makes that grid axis a MESH axis.  A `(data, model)` mesh
shards

  * the limb axis L of every `u32[..., L, 2, N]` ciphertext tensor — and of
    the stacked constant tables (`CkksContext.tables`) — along ``model``;
  * the ciphertext chunk/batch axis along ``data``.

Every graph is a single `shard_map` dispatch whose body routes through the
backend registry (`kernels.ops.apply`), so each shard runs the same fused
jnp graph or per-shard Pallas launch as the single-device engine, just on
its local `(B/n_data, L/n_model)` block.  HE aggregation is pointwise per
(limb, coefficient): keygen / encrypt / weighted_sum / weighted_accum need
NO cross-chip communication; the only collective in the whole round is the
gather of limb shards at the final decrypt (CRT decode needs every limb).

Bit-identity contract (asserted in tests/test_sharded.py): every op here is
bit-for-bit equal to the single-device fused engine for any mesh shape.
For the samplers this relies on draw shapes being shard-invariant — see
cipher.py's sampler docstrings; keygen's uniform `a` (whose draw shape
includes L) is drawn in full on every model shard and sliced locally.

Sharding rules:
  * ``ctx.n_limbs`` (or the ciphertext's limb count) must be divisible by
    the ``model`` axis size — `launch.mesh.make_he_mesh` picks a legal
    factorization automatically.
  * batch axes are zero-padded up to a multiple of the ``data`` axis size
    inside the graph and sliced back after (zeros are inert under the
    modular ops and the padded rows are discarded).
  * every batched graph — including encrypt and seeded encrypt — shards
    BOTH axes.  Encrypt sampling stays shard-invariant because every draw
    is per chunk, keyed on fold_in(key, global_chunk_id) (DESIGN.md §9):
    a shard re-derives exactly its own rows' keys from its row offset.
    Only keygen replicates over ``data`` (its tensors have no batch axis).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import obs
from repro.core.ckks import encoding
from repro.core.ckks.cipher import (DERIVE_FOLD_CHUNK, Ciphertext,
                                    _gaussian_residues, _ternary_residues,
                                    _uniform_residues, derive_chunk_keys)
from repro.core.ckks.params import CkksContext, LimbTables
from repro.kernels import ops, ref as _ref

_TABLE_FIELDS = ("qs", "qinv_negs", "r2s", "one_monts", "n_inv_monts",
                 "psi_rev_mont", "psi_inv_rev_mont",
                 "ntt4_psi1_mont", "ntt4_psi1_inv_mont",
                 "ntt4_psi2_mont", "ntt4_psi2_inv_mont",
                 "ntt4_corr_mont", "ntt4_corr_inv_mont")


def table_arrays(t: LimbTables) -> tuple:
    """LimbTables -> flat tuple of jnp arrays, in _TABLE_FIELDS order —
    the positional form `shard_map` bodies receive tables in.  Public:
    launch/fl_step.py builds its own sharded graphs from these."""
    return tuple(jnp.asarray(getattr(t, f)) for f in _TABLE_FIELDS)


def table_specs(model: str) -> tuple:
    """PartitionSpecs matching table_arrays: u32[L] fields shard along
    `model`, u32[L, .] twiddle/correction tables shard the limb row axis
    (the six ntt4_* 4-step tables included — limb-sharding covers the
    4-step NTT backend with zero new collectives)."""
    v, m = P(model), P(model, None)
    return (v, v, v, v, v, m, m, m, m, m, m, m, m)


def local_tables(tabs) -> LimbTables:
    """Rebuild a LimbTables view from per-shard (traced) arrays — the ops
    registry consumes it exactly like the host-numpy constant tables."""
    return LimbTables(**dict(zip(_TABLE_FIELDS, tabs)))


def _col(v):
    return v[:, None]


@dataclasses.dataclass(frozen=True)
class ShardedHe:
    """Sharded counterpart of the cipher-level API, bound to (ctx, mesh).

    Hashable (frozen dataclass over a hashable ctx and Mesh), so it is the
    static jit key of every sharded graph: a new mesh or context retraces.

    Attributes:
        ctx: CkksContext whose tables are sharded along `model_axis`.
        mesh: jax Mesh with at least (`data_axis`, `model_axis`) axes.
        data_axis: mesh axis name for ciphertext chunk/batch sharding.
        model_axis: mesh axis name for RNS-limb sharding.
    """

    ctx: CkksContext
    mesh: Any
    data_axis: str = "data"
    model_axis: str = "model"

    @property
    def n_data(self) -> int:
        return int(self.mesh.shape[self.data_axis])

    @property
    def n_model(self) -> int:
        return int(self.mesh.shape[self.model_axis])

    def _check_limbs(self, l: int) -> None:
        if l % self.n_model:
            raise ValueError(
                f"limb count {l} is not divisible by model-axis size "
                f"{self.n_model}; build the mesh with "
                "launch.mesh.make_he_mesh(n_limbs, ...) so the limb grid "
                "axis maps onto whole shards")

    # -- placement helpers ---------------------------------------------------

    def ct_sharding(self, with_batch: bool = True) -> NamedSharding:
        """NamedSharding for u32[B, L, 2, N] ciphertext data (chunks ->
        data axis, limbs -> model axis)."""
        return NamedSharding(
            self.mesh,
            P(self.data_axis if with_batch else None, self.model_axis,
              None, None))

    def put_ciphertext(self, ct: Ciphertext,
                       with_batch: bool = True) -> Ciphertext:
        """Place ciphertext data onto the mesh (no-op if B or L do not
        divide; the graphs re-shard on entry anyway)."""
        b, l = ct.data.shape[0], ct.n_limbs
        if l % self.n_model or (with_batch and b % self.n_data):
            return ct
        return Ciphertext(
            data=jax.device_put(ct.data, self.ct_sharding(with_batch)),
            scale=ct.scale)

    # -- public sharded ops --------------------------------------------------

    def keygen(self, key) -> tuple[dict, dict]:
        """Sharded keygen; bit-identical keys to cipher.keygen(ctx, key).

        Returns (sk, pk) with every u32[L, N] component sharded along
        `model_axis`.  No collectives: the ternary/gaussian draws are
        shard-invariant and the uniform `a` is drawn in full per shard,
        sliced to local limbs.
        """
        self._check_limbs(self.ctx.n_limbs)
        token = ops.backend_token()
        with obs.kernel_launch("sharded.keygen", token) as kl:
            s_mont, pk0_mont, pk1_mont = kl.done(
                _keygen_graph(self, token, key))
        return ({"s_mont": s_mont},
                {"pk0_mont": pk0_mont, "pk1_mont": pk1_mont})

    def encrypt_values(self, pk: dict, values, key) -> Ciphertext:
        """f32[B, slots] -> fresh ciphertext, encode FFT + encrypt in ONE
        sharded dispatch with no collective.

        Limbs shard over `model_axis` AND the chunk/batch axis shards over
        `data_axis`: every (u, e0, e1) draw is per chunk, keyed on
        fold_in(key, global_chunk_id), so each shard re-derives exactly
        the rows it owns and the result is bit-identical to
        cipher.encrypt_values on one device for ANY mesh shape (the
        shard-invariance contract, DESIGN.md §9.1; asserted in
        tests/test_sharded.py).  Batches that do not divide the data axis
        are zero-padded in-graph and sliced back."""
        self._check_limbs(self.ctx.n_limbs)
        token = ops.backend_token()
        with obs.kernel_launch("sharded.encrypt_values", token,
                               rows=int(values.shape[0])) as kl:
            data = kl.done(_encrypt_values_graph(self, token,
                                                 pk["pk0_mont"],
                                                 pk["pk1_mont"], values,
                                                 key))
        return Ciphertext(data=data, scale=float(self.ctx.delta))

    def encrypt_coeffs(self, pk: dict, m_coeff, key,
                       scale: float | None = None) -> Ciphertext:
        """u32[B, L, N] encoded residues -> ciphertext; same sharding and
        bit-identity contract as encrypt_values (chunks -> `data_axis`,
        limbs -> `model_axis`, per-chunk key derivation)."""
        self._check_limbs(m_coeff.shape[-2])
        scale = float(scale if scale is not None else self.ctx.delta)
        token = ops.backend_token()
        with obs.kernel_launch("sharded.encrypt_coeffs", token,
                               rows=int(m_coeff.shape[0])) as kl:
            data = kl.done(_encrypt_coeffs_graph(self, token,
                                                 pk["pk0_mont"],
                                                 pk["pk1_mont"], m_coeff,
                                                 key))
        return Ciphertext(data=data, scale=scale)

    def encrypt_values_seeded(self, sk: dict, values, key, a_seed: int,
                              derive: int = DERIVE_FOLD_CHUNK
                              ) -> Ciphertext:
        """f32[B, slots] -> seeded secret-key ciphertext (uplink path) in
        ONE sharded dispatch with no collective.

        Same wire convention as cipher.encrypt_values_seeded: chunk b's
        c1 row is PRG-expanded per the wire-v2 `derive` algorithm
        (cipher.DERIVE_KEYFNS, DESIGN.md §9.2), so the wire layer ships
        (a_seed, c0) at ~0.5x fresh-ciphertext bytes and a streaming
        server regenerates each chunk independently.  Chunks shard over
        `data_axis`, limbs over `model_axis`; the result is bit-identical
        to the single-device path for any mesh shape — the noise stream is
        per chunk, and the public `a` stream (whose draw shape includes L)
        is drawn full-table per model shard and sliced, like keygen's `a`.
        `a_seed` must be unique per (client, round); reuse leaks m1 - m2.
        """
        self._check_limbs(self.ctx.n_limbs)
        a_base = jax.random.PRNGKey(int(a_seed))
        token = ops.backend_token()
        with obs.kernel_launch("sharded.encrypt_values_seeded", token,
                               rows=int(values.shape[0])) as kl:
            data = kl.done(_encrypt_seeded_values_graph(self, token,
                                                        sk["s_mont"],
                                                        values, key,
                                                        a_base,
                                                        int(derive)))
        return Ciphertext(data=data, scale=float(self.ctx.delta))

    def encrypt_coeffs_seeded(self, sk: dict, m_coeff, key, a_seed: int,
                              scale: float | None = None,
                              derive: int = DERIVE_FOLD_CHUNK
                              ) -> Ciphertext:
        """u32[B, L, N] encoded residues -> seeded ciphertext; sharding,
        derivation, and uniqueness contract as encrypt_values_seeded."""
        self._check_limbs(m_coeff.shape[-2])
        scale = float(scale if scale is not None else self.ctx.delta)
        a_base = jax.random.PRNGKey(int(a_seed))
        token = ops.backend_token()
        with obs.kernel_launch("sharded.encrypt_coeffs_seeded", token,
                               rows=int(m_coeff.shape[0])) as kl:
            data = kl.done(_encrypt_seeded_coeffs_graph(self, token,
                                                        sk["s_mont"],
                                                        m_coeff, key,
                                                        a_base,
                                                        int(derive)))
        return Ciphertext(data=data, scale=scale)

    def decrypt_to_coeffs(self, sk: dict, ct: Ciphertext):
        """Sharded decrypt -> u32[B, L, N] coefficient residues.

        mul_add + iNTT are limb-local; the gather of limb shards implied
        by reading the (replicated-spec) output is the ONLY collective of
        the whole aggregation round — CRT decode needs every limb.
        """
        self._check_limbs(ct.n_limbs)
        s = sk["s_mont"][: ct.n_limbs]
        token = ops.backend_token()
        with obs.kernel_launch("sharded.decrypt", token) as kl:
            return kl.done(_decrypt_graph(self, token, s, ct.data))

    def decrypt_values(self, sk: dict, ct: Ciphertext):
        """-> f32[B, slots] via the jnp decode path (2-limb)."""
        return encoding.decode_jnp(self.decrypt_to_coeffs(sk, ct),
                                   self.ctx, ct.scale)

    def weighted_sum(self, cts: Ciphertext, weights) -> Ciphertext:
        """Fused FedAvg aggregation, sharded: chunks over `data_axis`,
        limbs over `model_axis`, zero collectives.

        Args:
            cts: Ciphertext with data u32[C, B, L, 2, N] (clients leading).
            weights: python floats, len C.

        Returns:
            Ciphertext u32[B, L, 2, N], bit-identical to
            cipher.weighted_sum on one device.
        """
        self._check_limbs(cts.data.shape[-3])
        w_mont = jnp.asarray(encoding.encode_weights_mont(weights, self.ctx))
        token = ops.backend_token()
        with obs.kernel_launch("sharded.weighted_sum", token,
                               n_clients=int(cts.data.shape[0])) as kl:
            data = kl.done(_weighted_sum_graph(self, token, cts.data,
                                               w_mont))
        return Ciphertext(data=data, scale=cts.scale * self.ctx.delta)

    def weighted_accum(self, acc: Ciphertext, ct: Ciphertext,
                       weight: float) -> Ciphertext:
        """Streaming fold acc + w (*) ct, sharded like weighted_sum."""
        self._check_limbs(ct.n_limbs)
        w_mont = jnp.asarray(
            encoding.encode_scalar_residues(float(weight), self.ctx))
        token = ops.backend_token()
        with obs.kernel_launch("sharded.weighted_accum", token) as kl:
            data = kl.done(_weighted_accum_graph(self, token, acc.data,
                                                 ct.data, w_mont))
        return Ciphertext(data=data, scale=acc.scale)

    def weighted_accum_chunks(self, accs, cts, w_mont):
        """Batched flush on the ops-level layout: accs, cts u32[K, ..., L, N]
        (limbs at axis -2), w_mont u32[K, L].  Ready-chunk rows shard over
        `data_axis`, limbs over `model_axis`; used by wire.stream when a
        ShardedHe is attached."""
        self._check_limbs(cts.shape[-2])
        token = ops.backend_token()
        with obs.kernel_launch("sharded.weighted_accum_chunks", token,
                               rows=int(cts.shape[0])) as kl:
            return kl.done(_weighted_accum_chunks_graph(self, token, accs,
                                                        cts, w_mont))


# ---------------------------------------------------------------------------
# sharded graphs (module-level, cached by jit on the hashable engine)
# ---------------------------------------------------------------------------


def _pad_rows(x, mult: int, axis: int = 0):
    """Zero-pad `axis` up to a multiple of `mult` (static shapes)."""
    r = x.shape[axis]
    pad = (-r) % mult
    if not pad:
        return x, r
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), r


@functools.partial(jax.jit, static_argnames=("eng", "token"))
def _weighted_sum_graph(eng: ShardedHe, token, data, w_mont):
    ctx, da, ma = eng.ctx, eng.data_axis, eng.model_axis
    c, n = data.shape[0], data.shape[-1]
    l = data.shape[-3]
    t = ctx.tables.take(l)
    # [C, B..., L, 2, N] -> limbs at -2, flatten (B..., 2) into rows
    x = jnp.moveaxis(data, -3, -2)
    mid = x.shape[1:-2]
    x = x.reshape((c, -1, l, n))
    x, r = _pad_rows(x, eng.n_data, axis=1)

    def body(x, w, *tabs):
        return ops.apply("weighted_sum", local_tables(tabs), x, w)

    f = shard_map(body, mesh=eng.mesh,
                  in_specs=(P(None, da, ma, None), P(None, ma))
                  + table_specs(ma),
                  out_specs=P(da, ma, None), check_rep=False)
    out = f(x, w_mont[:, :l], *table_arrays(t))[:r]
    return jnp.moveaxis(out.reshape(mid + (l, n)), -2, -3)


@functools.partial(jax.jit, static_argnames=("eng", "token"))
def _weighted_accum_graph(eng: ShardedHe, token, acc, ct, w_mont):
    ctx, da, ma = eng.ctx, eng.data_axis, eng.model_axis
    n = ct.shape[-1]
    l = ct.shape[-3]
    t = ctx.tables.take(l)
    x = jnp.moveaxis(ct, -3, -2)
    a = jnp.moveaxis(jnp.broadcast_to(acc, ct.shape), -3, -2)
    mid = x.shape[:-2]
    x = x.reshape((-1, l, n))
    a = a.reshape((-1, l, n))
    x, r = _pad_rows(x, eng.n_data)
    a, _ = _pad_rows(a, eng.n_data)

    def body(a, x, w, *tabs):
        return ops.apply("weighted_accum", local_tables(tabs), a, x, w)

    f = shard_map(body, mesh=eng.mesh,
                  in_specs=(P(da, ma, None), P(da, ma, None), P(ma))
                  + table_specs(ma),
                  out_specs=P(da, ma, None), check_rep=False)
    out = f(a, x, w_mont[:l], *table_arrays(t))[:r]
    return jnp.moveaxis(out.reshape(mid + (l, n)), -2, -3)


@functools.partial(jax.jit, static_argnames=("eng", "token"))
def _weighted_accum_chunks_graph(eng: ShardedHe, token, accs, cts, w_mont):
    ctx, da, ma = eng.ctx, eng.data_axis, eng.model_axis
    k, n = cts.shape[0], cts.shape[-1]
    l = cts.shape[-2]
    t = ctx.tables.take(l)
    accs = jnp.broadcast_to(accs, cts.shape)
    mid = cts.shape[1:-2]
    x = cts.reshape((k, -1, l, n))
    a = accs.reshape((k, -1, l, n))
    x, r = _pad_rows(x, eng.n_data)
    a, _ = _pad_rows(a, eng.n_data)
    w, _ = _pad_rows(w_mont[:, :l], eng.n_data)

    def body(a, x, w, *tabs):
        return ops.apply("weighted_accum_chunks", local_tables(tabs), a, x,
                         w)

    f = shard_map(body, mesh=eng.mesh,
                  in_specs=(P(da, None, ma, None), P(da, None, ma, None),
                            P(da, ma)) + table_specs(ma),
                  out_specs=P(da, None, ma, None), check_rep=False)
    out = f(a, x, w, *table_arrays(t))[:r]
    return out.reshape((r,) + mid + (l, n))


@functools.partial(jax.jit, static_argnames=("eng", "token"))
def _keygen_graph(eng: ShardedHe, token, key):
    ctx, ma = eng.ctx, eng.model_axis
    n = ctx.n_poly
    l_loc = ctx.n_limbs // eng.n_model
    qs_full = np.asarray(ctx.tables.qs)
    sigma = float(ctx.error_sigma)

    def body(key, *tabs):
        t = local_tables(tabs)
        q, qi = _col(t.qs), _col(t.qinv_negs)
        k_s, k_a, k_e = jax.random.split(key, 3)
        s = ops.apply("ntt_fwd", t, _ternary_residues(k_s, (n,), t.qs))
        s_mont = _ref.mont_mul(s, jnp.broadcast_to(_col(t.r2s), s.shape),
                               q, qi)
        # the uniform draw's shape includes L: draw the FULL table on every
        # shard (replicated constant qs_full) and slice local limbs so the
        # stream matches the single-device graph bit-for-bit
        a_full = _uniform_residues(k_a, (n,), qs_full)
        li = jax.lax.axis_index(ma)
        a = jax.lax.dynamic_slice_in_dim(a_full, li * l_loc, l_loc, axis=0)
        e = ops.apply("ntt_fwd", t,
                      _gaussian_residues(k_e, (n,), t.qs, sigma))
        a_s = _ref.mont_mul(a, s_mont, q, qi)
        pk0 = _ref.mod_add(_ref.mod_neg(a_s, q), e, q)
        to_mont = lambda x: _ref.mont_mul(
            x, jnp.broadcast_to(_col(t.r2s), x.shape), q, qi)
        return s_mont, to_mont(pk0), to_mont(a)

    f = shard_map(body, mesh=eng.mesh,
                  in_specs=(P(None),) + table_specs(ma),
                  out_specs=(P(ma, None),) * 3, check_rep=False)
    return f(key, *table_arrays(ctx.tables))


def _local_chunk_keys(eng: ShardedHe, key, b_loc: int,
                      derive: int = DERIVE_FOLD_CHUNK):
    """Keys for this data-shard's chunk rows, derived from GLOBAL chunk ids.

    Shard d of the data axis owns the contiguous rows
    [d * b_loc, (d + 1) * b_loc); derive_chunk_keys(key, global_offset, ..)
    re-derives exactly the keys the single-device trace would use for those
    rows — the whole shard-count-invariance argument in one line
    (DESIGN.md §9.1).  Every registered derive algorithm keys on the global
    chunk index, so the invariance holds per id."""
    start = jax.lax.axis_index(eng.data_axis) * b_loc
    return derive_chunk_keys(key, start, b_loc, derive)


def _encrypt_body_sharded(eng: ShardedHe, pk0, pk1, m_coeff, key, tabs):
    """Per-shard encrypt body: same op sequence and per-chunk key
    derivation as cipher._encrypt_body, limb constants from the local table
    shard, chunk keys from the shard's global row offset."""
    ctx = eng.ctx
    b_loc, n = m_coeff.shape[0], ctx.n_poly
    sigma = float(ctx.error_sigma)
    t = local_tables(tabs)
    q = _col(t.qs)
    k3 = jax.vmap(lambda k: jax.random.split(k, 3))(
        _local_chunk_keys(eng, key, b_loc))
    m = ops.apply("ntt_fwd", t, m_coeff)
    u = ops.apply("ntt_fwd", t, jax.vmap(
        lambda k: _ternary_residues(k, (n,), t.qs))(k3[:, 0]))
    e0 = ops.apply("ntt_fwd", t, jax.vmap(
        lambda k: _gaussian_residues(k, (n,), t.qs, sigma))(k3[:, 1]))
    e1 = ops.apply("ntt_fwd", t, jax.vmap(
        lambda k: _gaussian_residues(k, (n,), t.qs, sigma))(k3[:, 2]))
    c0 = ops.apply("mul_add", t, u, pk0[None], _ref.mod_add(e0, m, q))
    c1 = ops.apply("mul_add", t, u, pk1[None], e1)
    return jnp.stack([c0, c1], axis=-2)


def _encrypt_shard_map(eng: ShardedHe):
    da, ma = eng.data_axis, eng.model_axis

    def body(pk0, pk1, m_coeff, key, *tabs):
        return _encrypt_body_sharded(eng, pk0, pk1, m_coeff, key, tabs)

    return shard_map(
        body, mesh=eng.mesh,
        in_specs=(P(ma, None), P(ma, None), P(da, ma, None), P(None))
        + table_specs(ma),
        out_specs=P(da, ma, None, None), check_rep=False)


@functools.partial(jax.jit, static_argnames=("eng", "token"))
def _encrypt_coeffs_graph(eng: ShardedHe, token, pk0, pk1, m_coeff, key):
    l = m_coeff.shape[-2]
    t = eng.ctx.tables.take(l)
    x, r = _pad_rows(m_coeff, eng.n_data)
    out = _encrypt_shard_map(eng)(pk0[:l], pk1[:l], x, key,
                                  *table_arrays(t))
    return out[:r]


@functools.partial(jax.jit, static_argnames=("eng", "token"))
def _encrypt_values_graph(eng: ShardedHe, token, pk0, pk1, values, key):
    m_coeff = encoding.encode_jnp(values, eng.ctx)
    t = eng.ctx.tables
    x, r = _pad_rows(m_coeff, eng.n_data)
    out = _encrypt_shard_map(eng)(pk0, pk1, x, key, *table_arrays(t))
    return out[:r]


def _encrypt_seeded_body_sharded(eng: ShardedHe, s_mont, m_coeff, key,
                                 a_base, tabs,
                                 derive: int = DERIVE_FOLD_CHUNK):
    """Per-shard seeded (secret-key) encrypt body.

    The public c1 = a stream must match the server-side expand_a_rows
    regeneration bit for bit — for the SAME wire-negotiated derive id —
    and its draw shape includes L: so, like keygen's uniform `a`, every
    model shard draws the FULL limb table per chunk and slices its local
    limbs.  The secret noise draw is (N,) per chunk and limb-free (always
    fold_in — never wire-negotiated), so it broadcasts against the local
    primes."""
    ctx = eng.ctx
    b_loc, n = m_coeff.shape[0], ctx.n_poly
    sigma = float(ctx.error_sigma)
    t = local_tables(tabs)
    q = _col(t.qs)
    l_loc = ctx.n_limbs // eng.n_model
    qs_full = np.asarray(ctx.tables.qs)
    m = ops.apply("ntt_fwd", t, m_coeff)
    a_full = jax.vmap(lambda k: _uniform_residues(k, (n,), qs_full))(
        _local_chunk_keys(eng, a_base, b_loc, derive))  # [b_loc, L_full, N]
    li = jax.lax.axis_index(eng.model_axis)
    a = jax.lax.dynamic_slice_in_dim(a_full, li * l_loc, l_loc, axis=1)
    e = ops.apply("ntt_fwd", t, jax.vmap(
        lambda k: _gaussian_residues(k, (n,), t.qs, sigma))(
            _local_chunk_keys(eng, key, b_loc)))
    a_s = _ref.mont_mul(a, s_mont[None], q, _col(t.qinv_negs))
    c0 = _ref.mod_add(_ref.mod_neg(a_s, q), _ref.mod_add(e, m, q), q)
    return jnp.stack([c0, a], axis=-2)


def _encrypt_seeded_shard_map(eng: ShardedHe,
                              derive: int = DERIVE_FOLD_CHUNK):
    da, ma = eng.data_axis, eng.model_axis

    def body(s_mont, m_coeff, key, a_base, *tabs):
        return _encrypt_seeded_body_sharded(eng, s_mont, m_coeff, key,
                                            a_base, tabs, derive)

    return shard_map(
        body, mesh=eng.mesh,
        in_specs=(P(ma, None), P(da, ma, None), P(None), P(None))
        + table_specs(ma),
        out_specs=P(da, ma, None, None), check_rep=False)


@functools.partial(jax.jit, static_argnames=("eng", "token", "derive"))
def _encrypt_seeded_coeffs_graph(eng: ShardedHe, token, s_mont, m_coeff,
                                 key, a_base,
                                 derive: int = DERIVE_FOLD_CHUNK):
    t = eng.ctx.tables
    x, r = _pad_rows(m_coeff, eng.n_data)
    out = _encrypt_seeded_shard_map(eng, derive)(s_mont, x, key, a_base,
                                                 *table_arrays(t))
    return out[:r]


@functools.partial(jax.jit, static_argnames=("eng", "token", "derive"))
def _encrypt_seeded_values_graph(eng: ShardedHe, token, s_mont, values, key,
                                 a_base, derive: int = DERIVE_FOLD_CHUNK):
    m_coeff = encoding.encode_jnp(values, eng.ctx)
    t = eng.ctx.tables
    x, r = _pad_rows(m_coeff, eng.n_data)
    out = _encrypt_seeded_shard_map(eng, derive)(s_mont, x, key, a_base,
                                                 *table_arrays(t))
    return out[:r]


@functools.partial(jax.jit, static_argnames=("eng", "token"))
def _decrypt_graph(eng: ShardedHe, token, s_mont, data):
    ctx, da, ma = eng.ctx, eng.data_axis, eng.model_axis
    l, n = data.shape[-3], data.shape[-1]
    t = ctx.tables.take(l)
    x, b = _pad_rows(data, eng.n_data)

    def body(s, x, *tabs):
        t = local_tables(tabs)
        c0 = x[..., 0, :]
        c1 = x[..., 1, :]
        phase = ops.apply("mul_add", t, c1, s[None], c0)
        return ops.apply("ntt_inv", t, phase)

    f = shard_map(body, mesh=eng.mesh,
                  in_specs=(P(ma, None), P(da, ma, None, None))
                  + table_specs(ma),
                  out_specs=P(da, ma, None), check_rep=False)
    return f(s_mont, x, *table_arrays(t))[:b]
