"""Transcipher (hybrid-HE) uplink: additive-masked updates, server-side
homomorphic unmask into the seeded-ciphertext accumulator path.

The thin-client problem (DESIGN.md §15): the seeded uplink still makes
every client run L forward NTTs and the full RNS sampling stack.  Hybrid
homomorphic encryption moves that work to the server: the client encrypts
its update with a cheap symmetric stream cipher and the server
*transciphers* the result into CKKS without ever seeing the plaintext.

This implementation is an additive-mask instance chosen so the server
output is BIT-IDENTICAL to the seeded-CKKS path (the acceptance
invariant, pinned by tests/test_transcipher.py):

  offline (provisioner = any sk holder, per client x round):
    seed    = FRESH SECRET keystream seed (64-bit), drawn from the
              provisioner's secret noise PRNG key — never from the
              wire-public a_seed (the pad must depend on secret material;
              it reaches the client only inside ClientMaterials, i.e. out
              of band, and auditors only via seed_ct)
    c0_zero = c0 of a seeded encryption of ZERO        (-a s + e, [B, L, N])
    K       = keystream pad = PRG(seed), uniform u32[B, N] in
              [2^30, 2^32 - 2^30)
    D       = c0_zero - NTT(lift(K))                   (server material)
    seed_ct = tiny seeded CKKS encryption of the keystream seed's four
              u16 digits (1 chunk) under escrow_a_seed — the
              "HE-encrypted symmetric key" of the HHE literature, shipped
              on the uplink for escrow/audit.

  online (client, NO NTT / NO modular arithmetic):
    c       = encode_centered(values)                  (FFT + rint, i64[B, N])
    masked  = (c + K) as u32                            -> the wire

  server (per arriving chunk, kernels/lift.py riding LimbTables):
    c0 = NTT(mod_lift(masked)) + D
    a  = expand_a_rows(a_seed, ...)     (the negotiated derive id)
    ct = stack([c0, a])  ->  existing StreamIngest accumulator

  why it is exact: the pad window keeps masked = c + K inside [1, 2^32-2]
  with NO u32 wrap (|c| < 2^30 is validated client-side), so
  NTT((c+K) mod q) - NTT(K mod q) = NTT(c mod q) per limb, and
  c0 = NTT(c mod q) + c0_zero — precisely the seeded path's c0 for the
  same noise key.  Uplink bytes: 4 B/coeff vs L x 4 B/coeff seeded c0
  (0.5x at L=2), measured by `benchmarks.run uplink-hybrid`.

Security note (prototype scope): a one-time additive pad over Z_2^32 —
seed/pad reuse across rounds leaks differences, exactly like a_seed reuse
in the seeded path; the provisioner role models the HHE setup phase
(Correia et al.; Nguyen et al.) where symmetric key material is
established out of band.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ckks import cipher, encoding
from repro.core.ckks.cipher import (DERIVE_CTR, DERIVE_FOLD_CHUNK,
                                    Ciphertext)
from repro.core.ckks.params import CkksContext
from repro.kernels import ops

# client-side centered coefficients must satisfy |c| < 2**BOUND_BITS; with
# the pad window below, masked = c + K then spans [1, 2**32 - 2] with no
# u32 wrap (the exactness anchor).  2**30 also matches the q < 2**30 prime
# bound, so any encodable plaintext already fits.
BOUND_BITS = 30
_PAD_LO = np.uint32(1 << BOUND_BITS)

# the escrow ciphertext's own (public) a_seed lives in a region disjoint
# from every caller-issued update a_seed, so no PUBLIC a stream is keyed
# twice (a_seed itself stays < 2**40 in every caller — fl/client.py
# derives it as rnd*1e6 + cid).  The keystream seed is NOT partitioned
# from a_seed: it is fresh secret material (see provision) — deriving it
# from any wire-public value would let a passive observer recompute the
# pad and strip the mask.
ESCROW_SEED_OFFSET = 1 << 40

# fold_in tag under which provision() draws the secret keystream seed
# from the noise key (disjoint from the per-chunk noise ids 0..B-1 and
# the escrow-noise tag 0x5EED).
_PAD_KEY_TAG = 0x5AD5EED


def _pad_base_key(keystream_seed: int):
    """The 64-bit keystream seed as raw threefry key words [hi, lo] —
    what PRNGKey(seed) builds, but accepting the full u64 range (PRNGKey
    overflows past 2^63, and secret seeds are uniform over 64 bits)."""
    s = int(keystream_seed)
    return jnp.array([(s >> 32) & 0xFFFFFFFF, s & 0xFFFFFFFF],
                     dtype=jnp.uint32)


def expand_pad_rows(n_poly: int, keystream_seed: int, start, count: int,
                    derive: int = DERIVE_CTR):
    """Keystream pad rows u32[count, N], uniform in [2^30, 2^32 - 2^30).

    `keystream_seed` is SECRET (provision() draws it from the
    provisioner's noise key): everything else here — the derive registry,
    the chunk indices — is public, so the seed is the only thing standing
    between a wire observer and the pad.  Per-chunk keys come from the
    SAME wire-negotiated derive registry as the a stream
    (cipher.derive_chunk_keys), so pads are re-derivable for any
    contiguous chunk slice — client and provisioner agree bit for bit,
    and streaming chunks need no global state.  The window is exactly
    [2^30, 3*2^30): lo + a uniform 31-bit draw."""
    base = _pad_base_key(keystream_seed)
    keys = cipher.derive_chunk_keys(base, start, count, derive)
    hi = jnp.uint32(1 << 31)      # u32 literal: 2**31 overflows int32 args
    return jax.vmap(
        lambda k: _PAD_LO + jax.random.randint(
            k, (n_poly,), jnp.uint32(0), hi, dtype=jnp.uint32))(keys)


def escrow_values(keystream_seed: int, ctx: CkksContext) -> np.ndarray:
    """The keystream seed's four u16 digits as a 1-chunk slot vector —
    what `seed_ct` encrypts (little-endian digit order, slots 0..3)."""
    vals = np.zeros((1, ctx.slots), dtype=np.float32)
    for i in range(4):
        vals[0, i] = float((int(keystream_seed) >> (16 * i)) & 0xFFFF)
    return vals


@dataclasses.dataclass
class ClientMaterials:
    """What a thin client holds for one (client, round): symmetric key
    material plus the pre-provisioned escrow ciphertext it forwards.
    Contains NO CKKS secret-key material and requires NO NTT to use.
    `keystream_seed` is the symmetric SECRET: it must reach the client
    over a confidential channel (the HHE setup phase), never the
    aggregation wire — only its escrow ciphertext is ever serialized."""

    keystream_seed: int
    a_seed: int
    chunk_offset: int
    n_chunks: int
    derive: int
    scale: float
    seed_ct: Ciphertext          # escrow encryption of the keystream seed
    escrow_a_seed: int           # its a_seed (wire layer seed-compresses)


@dataclasses.dataclass
class ServerMaterials:
    """What the aggregator holds: the unmask offset D = c0_zero - NTT(K)
    and the public-stream parameters.  D is a single ciphertext component
    — it hides K under an encryption of zero, so holding it reveals
    neither the pad nor any update."""

    d: Any                       # u32[B, L, N], NTT domain
    a_seed: int
    chunk_offset: int
    n_chunks: int
    derive: int
    scale: float


def provision(ctx: CkksContext, sk: dict, key, a_seed: int, n_chunks: int,
              *, chunk_offset: int = 0, derive: int = DERIVE_CTR,
              scale: float | None = None, keystream_seed: int | None = None
              ) -> tuple[ClientMaterials, ServerMaterials]:
    """Offline HHE setup for one (client, round): draw a fresh SECRET
    keystream seed, build the server's unmask material D, and
    escrow-encrypt the seed.  `key` is the noise PRNG key the SEEDED path
    would have used — same key, same a_seed => the unmasked server
    ciphertext is bit-identical to `encrypt_coeffs_seeded` (the tests'
    invariant).

    The keystream seed is the pad's only secret: by default it is drawn
    from `key` (which never crosses the wire), or the caller supplies one
    established out of band (`keystream_seed=`).  It must NEVER be derived
    from a_seed or any other wire-visible value — a_seed rides cleartext
    in every MASKED_CHUNK frame, so a pad re-derivable from it would hand
    the plaintext update to any passive observer.  It reaches the client
    only inside ClientMaterials and auditors only via the escrow
    ciphertext; ServerMaterials never contains it."""
    scale = float(scale if scale is not None else ctx.delta)
    if keystream_seed is None:
        # four u16 digits from the secret noise key -> uniform 64-bit seed
        # (the same digit decomposition escrow_values() encrypts)
        digits = jax.random.randint(jax.random.fold_in(key, _PAD_KEY_TAG),
                                    (4,), 0, 1 << 16)
        keystream_seed = sum(int(d) << (16 * i)
                             for i, d in enumerate(np.asarray(digits)))
    keystream_seed = int(keystream_seed)
    if not 0 <= keystream_seed < 1 << 64:
        raise ValueError(
            f"keystream_seed must fit the escrow encoding's 64 bits, got "
            f"{keystream_seed}")
    escrow_a_seed = int(a_seed) + ESCROW_SEED_OFFSET
    l = ctx.n_limbs
    zeros = jnp.zeros((n_chunks, l, ctx.n_poly), dtype=jnp.uint32)
    ct_zero = cipher.encrypt_coeffs_seeded(ctx, sk, zeros, key, a_seed,
                                           scale=scale, derive=derive)
    c0_zero = ct_zero.data[..., 0, :]                       # [B, L, N]
    pad = expand_pad_rows(ctx.n_poly, keystream_seed, chunk_offset,
                          n_chunks, derive)
    ntt_k = ops.ntt_fwd(ops.mod_lift(pad, l, ctx), ctx)
    d = ops.mod_sub(c0_zero, ntt_k, ctx)
    seed_ct = cipher.encrypt_values_seeded(
        ctx, sk, jnp.asarray(escrow_values(keystream_seed, ctx)),
        jax.random.fold_in(key, 0x5EED), escrow_a_seed, derive=derive)
    cm = ClientMaterials(keystream_seed=keystream_seed, a_seed=int(a_seed),
                         chunk_offset=int(chunk_offset),
                         n_chunks=int(n_chunks), derive=int(derive),
                         scale=scale, seed_ct=seed_ct,
                         escrow_a_seed=escrow_a_seed)
    sm = ServerMaterials(d=d, a_seed=int(a_seed),
                         chunk_offset=int(chunk_offset),
                         n_chunks=int(n_chunks), derive=int(derive),
                         scale=scale)
    return cm, sm


# ---------------------------------------------------------------------------
# client online path — numpy only, no NTT, no modular arithmetic
# ---------------------------------------------------------------------------


def mask_coeffs_centered(ctx: CkksContext, cm: ClientMaterials,
                         c_int: np.ndarray) -> np.ndarray:
    """Centered i64 coefficients [B, N] -> masked u32[B, N] for the wire.

    The one validation a thin client must run: |c| < 2**BOUND_BITS, so the
    integer sum c + K cannot wrap u32 (exactness would silently die
    otherwise)."""
    c_int = np.asarray(c_int, dtype=np.int64)
    if c_int.shape[0] != cm.n_chunks:
        raise ValueError(
            f"masked update has {c_int.shape[0]} chunks but the provisioned "
            f"materials cover {cm.n_chunks}; re-provision for this shape")
    amax = int(np.max(np.abs(c_int))) if c_int.size else 0
    if amax >= (1 << BOUND_BITS):
        raise ValueError(
            f"centered coefficient magnitude {amax} >= 2**{BOUND_BITS}; "
            f"the transcipher pad window cannot absorb it — lower the "
            f"encoding delta or the update norm (DESIGN.md §15)")
    pad = np.asarray(expand_pad_rows(
        ctx.n_poly, cm.keystream_seed, cm.chunk_offset, c_int.shape[0],
        cm.derive)).astype(np.int64)
    return (pad + c_int).astype(np.uint32)     # in [1, 2**32 - 2], exact


def mask_values(ctx: CkksContext, cm: ClientMaterials,
                values: np.ndarray) -> np.ndarray:
    """f32[B, slots] update -> masked u32[B, N]: the entire client-side
    encrypt is one real FFT, a rint, and an add."""
    c_int = encoding.encode_centered(
        np.asarray(values, dtype=np.float32), ctx, cm.scale)
    return mask_coeffs_centered(ctx, cm, c_int)


# ---------------------------------------------------------------------------
# server transcipher — lift + NTT + offset, then the normal seeded shape
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("ctx", "token", "derive"))
def _unmask_graph(ctx: CkksContext, token, d_rows, masked, a_base,
                  row_start, derive: int):
    l = d_rows.shape[-2]
    c0 = ops.mod_add(ops.ntt_fwd(ops.mod_lift(masked, l, ctx), ctx),
                     d_rows, ctx)
    keys = cipher.derive_chunk_keys(a_base, row_start, masked.shape[0],
                                    derive)
    a = jax.vmap(lambda k: cipher._uniform_residues(
        k, (ctx.n_poly,), ctx.tables.qs))(keys)
    return jnp.stack([c0, a], axis=-2)


def server_unmask(ctx: CkksContext, sm: ServerMaterials, masked_rows,
                  chunk_idx: int) -> Ciphertext:
    """Masked u32[B, N] rows starting at global `chunk_idx` -> the full
    seeded-equivalent ciphertext chunk u32[B, L, 2, N].

    One jitted graph: mod_lift (kernels/lift.py), forward NTT, the D
    offset, and the derive-registry a expansion.  Output bits equal the
    seeded path's for the provisioning noise key — so the result drops
    straight into the existing StreamIngest accumulator."""
    masked = jnp.asarray(masked_rows, dtype=jnp.uint32)
    b = int(masked.shape[0])
    r0 = int(chunk_idx) - sm.chunk_offset
    if r0 < 0 or r0 + b > sm.n_chunks:
        raise ValueError(
            f"chunk rows [{chunk_idx}, {chunk_idx + b}) fall outside the "
            f"provisioned range [{sm.chunk_offset}, "
            f"{sm.chunk_offset + sm.n_chunks})")
    data = _unmask_graph(ctx, ops.backend_token(), sm.d[r0:r0 + b], masked,
                         jax.random.PRNGKey(int(sm.a_seed)), chunk_idx,
                         int(sm.derive))
    return Ciphertext(data=data, scale=sm.scale)


# ---------------------------------------------------------------------------
# byte accounting (benchmarks/run.py uplink-hybrid)
# ---------------------------------------------------------------------------


def masked_uplink_bytes(n_chunks: int, n_poly: int) -> int:
    """Wire bytes of the masked payload: 4 B/coeff, limb-free."""
    return n_chunks * n_poly * 4


def seeded_uplink_bytes(n_chunks: int, n_limbs: int, n_poly: int) -> int:
    """Wire bytes of the seeded-CKKS c0 payload: L x 4 B/coeff."""
    return n_chunks * n_limbs * n_poly * 4
