"""Fault-tolerant pytree checkpointing (npz payload + json manifest).

Atomicity: payload is written to a temp dir then os.replace'd into place —
a crash mid-write never corrupts the latest checkpoint.  Rotation keeps the
last ``keep`` steps.  FL round boundaries are natural checkpoint points
(repro/fl/orchestrator.py) so a restarted job resumes at the last round.

Sharded arrays: leaves are gathered to host (np.asarray) before writing;
restore hands back numpy arrays to be re-sharded by the caller's pjit
in_shardings (device_put against the target sharding).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save_checkpoint(path: str, step: int, tree, extra: dict | None = None):
    """Atomic write of one checkpoint at `path/step_<N>/`."""
    names, leaves, _ = _flatten_with_names(tree)
    final = os.path.join(path, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=path, prefix=".tmp_ckpt_")
    try:
        arrays = {f"a{i}": np.asarray(l) for i, l in enumerate(leaves)}
        np.savez(os.path.join(tmp, "payload.npz"), **arrays)
        manifest = {"step": step, "names": names,
                    "extra": extra or {}}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    finally:
        if os.path.exists(tmp):
            shutil.rmtree(tmp, ignore_errors=True)
    return final


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore_checkpoint(path: str, tree_like, step: int | None = None):
    """Returns (tree, step, extra) or (None, None, None) when absent."""
    step = latest_step(path) if step is None else step
    if step is None:
        return None, None, None
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    payload = np.load(os.path.join(d, "payload.npz"))
    leaves = [payload[f"a{i}"] for i in range(len(manifest["names"]))]
    _, treedef = jax.tree_util.tree_flatten(tree_like)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, manifest["step"], manifest["extra"]


class CheckpointManager:
    """Rotation + resume policy around save/restore."""

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep

    def save(self, step: int, tree, extra: dict | None = None):
        out = save_checkpoint(self.path, step, tree, extra)
        self._rotate()
        return out

    def restore(self, tree_like, step: int | None = None):
        return restore_checkpoint(self.path, tree_like, step)

    def _rotate(self):
        if not os.path.isdir(self.path):
            return
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.path)
                       if d.startswith("step_"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"),
                          ignore_errors=True)
