"""Distributed HE secure-aggregation step (the paper's server hot loop,
mapped onto the production mesh).

Ciphertext chunks are embarrassingly parallel: the [n_chunks] axis is
sharded across every mesh axis; the fused weighted-sum kernel then runs
purely pointwise per device — zero collectives, memory-bound (DESIGN.md
§3).  The plaintext remainder aggregates the same way.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.ckks import encoding
from repro.core.ckks.params import CkksContext, make_context
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class HeAggSpec:
    """Static description of one aggregation round's tensors."""

    n_clients: int
    n_chunks: int            # ciphertexts per client (padded to mesh size)
    n_plain: int             # plaintext parameters (padded to mesh size)
    ctx: CkksContext

    @staticmethod
    def for_model(n_params: int, p_ratio: float, n_clients: int,
                  mesh_size: int, ctx: CkksContext | None = None):
        ctx = ctx or make_context()
        n_enc = int(round(n_params * p_ratio))
        chunks = max(1, -(-n_enc // ctx.slots))
        chunks = -(-chunks // mesh_size) * mesh_size
        n_plain = n_params - n_enc
        n_plain = -(-n_plain // mesh_size) * mesh_size
        return HeAggSpec(n_clients=n_clients, n_chunks=chunks,
                         n_plain=n_plain, ctx=ctx)

    def input_specs(self):
        sds = jax.ShapeDtypeStruct
        c, l, n = self.n_clients, self.ctx.n_limbs, self.ctx.n_poly
        return {
            "cts": sds((c, self.n_chunks, l, 2, n), jnp.uint32),
            "plain": sds((c, self.n_plain), jnp.float32),
        }

    def shardings(self, mesh):
        axes = tuple(mesh.axis_names)
        return {
            "cts": NamedSharding(mesh, P(None, axes, None, None, None)),
            "plain": NamedSharding(mesh, P(None, axes)),
        }

    def wire_bytes_per_client(self) -> int:
        return self.n_chunks * self.ctx.ciphertext_bytes(packed=False) \
            + 4 * self.n_plain


def make_he_agg_step(spec: HeAggSpec, weights: list[float]):
    """Server aggregation: sum_i w_i (*) ct_i (HE) + sum_i w_i plain_i."""
    ctx = spec.ctx
    w_mont = encoding.encode_weights_mont(weights, ctx)    # [C, L]
    w_plain = jnp.asarray(np.asarray(weights, np.float32))

    def step(cts, plain):
        # [C, chunks, L, 2, N] -> limbs at axis -2 for the fused kernel
        x = jnp.moveaxis(cts, -3, -2)
        enc = ops.weighted_sum(x, jnp.asarray(w_mont), ctx)
        enc = jnp.moveaxis(enc, -2, -3)
        pt = jnp.einsum("c,cp->p", w_plain, plain)
        return enc, pt

    return step


def jit_he_agg_step(spec: HeAggSpec, mesh, weights: list[float]):
    sh = spec.shardings(mesh)
    return jax.jit(
        make_he_agg_step(spec, weights),
        in_shardings=(sh["cts"], sh["plain"]),
        out_shardings=(None, None),
    )
