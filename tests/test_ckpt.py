"""ckpt/store.py: save/restore round trips, rotation, crash hygiene.

The aggregation service (repro/serve) trusts this store with mid-round
accumulator state, so the crash corners get their own suite: a writer
killed mid-checkpoint must leave latest_step/restore pointing at the last
COMPLETE checkpoint, and junk in the checkpoint root (orphaned temp dirs,
non-numeric step_* strays) must never wedge a restore.
"""
import json
import os

import numpy as np
import pytest

from repro.ckpt import store


def tree(seed=0):
    r = np.random.RandomState(seed)
    return {"acc": r.randint(0, 2**32 - 1, size=(3, 2, 8)).astype(np.uint32),
            "plain": r.randn(5).astype(np.float32),
            "nested": {"w": r.randn(2, 2).astype(np.float64)}}


def assert_tree_equal(a, b):
    assert sorted(a) == sorted(b)
    np.testing.assert_array_equal(a["acc"], b["acc"])
    np.testing.assert_array_equal(a["plain"], b["plain"])
    np.testing.assert_array_equal(a["nested"]["w"], b["nested"]["w"])


def test_save_restore_roundtrip_bitexact(tmp_path):
    t = tree()
    extra = {"round": 3, "weights": [0.25, 0.75]}
    store.save_checkpoint(str(tmp_path), 7, t, extra)
    out, step, x = store.restore_checkpoint(str(tmp_path), tree(1))
    assert step == 7 and x == extra
    assert_tree_equal(out, t)
    # dtypes survive (u32 residues must not round-trip through float)
    assert out["acc"].dtype == np.uint32
    assert out["plain"].dtype == np.float32


def test_restore_absent_returns_nones(tmp_path):
    assert store.restore_checkpoint(str(tmp_path), tree()) == (None,) * 3
    assert store.latest_step(str(tmp_path)) is None
    assert store.latest_step(str(tmp_path / "never_made")) is None
    assert store.read_manifest(str(tmp_path)) is None


def test_rotation_keeps_last_k(tmp_path):
    mgr = store.CheckpointManager(str(tmp_path), keep=3)
    for s in range(1, 8):
        mgr.save(s, tree(s), {"s": s})
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == [f"step_{s:08d}" for s in (5, 6, 7)]
    out, step, x = mgr.restore(tree())
    assert step == 7 and x == {"s": 7}
    assert_tree_equal(out, tree(7))


def test_partial_write_crash_leaves_latest_intact(tmp_path):
    """A writer killed mid-checkpoint leaves only a .tmp_ckpt_* dir; the
    next reader must see the previous complete checkpoint untouched."""
    store.save_checkpoint(str(tmp_path), 4, tree(4), {"ok": True})
    # simulate the torn write: temp dir with a partial payload, no rename
    torn = tmp_path / ".tmp_ckpt_torn"
    torn.mkdir()
    (torn / "payload.npz").write_bytes(b"\x00partial")
    assert store.latest_step(str(tmp_path)) == 4
    out, step, x = store.restore_checkpoint(str(tmp_path), tree())
    assert step == 4 and x == {"ok": True}
    assert_tree_equal(out, tree(4))
    # rotation must also shrug at the orphan
    mgr = store.CheckpointManager(str(tmp_path), keep=1)
    mgr.save(5, tree(5))
    assert store.latest_step(str(tmp_path)) == 5


@pytest.mark.parametrize("stray", ["step_final", "step_", "step_3b",
                                   "step_00000009_old"])
def test_latest_step_ignores_non_integer_step_dirs(tmp_path, stray):
    store.save_checkpoint(str(tmp_path), 2, tree())
    (tmp_path / stray).mkdir()
    assert store.latest_step(str(tmp_path)) == 2
    out, step, _ = store.restore_checkpoint(str(tmp_path), tree())
    assert step == 2
    assert_tree_equal(out, tree())


def test_latest_step_ignores_step_named_files(tmp_path):
    store.save_checkpoint(str(tmp_path), 1, tree())
    (tmp_path / "step_00000099").write_text("not a dir")
    assert store.latest_step(str(tmp_path)) == 1


def test_read_manifest_latest_and_explicit(tmp_path):
    store.save_checkpoint(str(tmp_path), 1, tree(), {"r": 1})
    store.save_checkpoint(str(tmp_path), 2, tree(), {"r": 2})
    assert store.read_manifest(str(tmp_path))["extra"] == {"r": 2}
    m1 = store.read_manifest(str(tmp_path), step=1)
    assert m1["extra"] == {"r": 1} and m1["step"] == 1
    assert store.read_manifest(str(tmp_path), step=9) is None


def test_save_overwrites_same_step_atomically(tmp_path):
    store.save_checkpoint(str(tmp_path), 3, tree(0), {"v": "old"})
    store.save_checkpoint(str(tmp_path), 3, tree(1), {"v": "new"})
    out, step, x = store.restore_checkpoint(str(tmp_path), tree())
    assert step == 3 and x == {"v": "new"}
    assert_tree_equal(out, tree(1))
    # exactly one complete step dir, no leftover temp dirs
    entries = os.listdir(tmp_path)
    assert entries == ["step_00000003"]


def test_manifest_is_plain_json(tmp_path):
    """The manifest must stay debuggable with nothing but a text editor."""
    store.save_checkpoint(str(tmp_path), 5, tree(), {"round": 0})
    with open(tmp_path / "step_00000005" / "manifest.json") as f:
        m = json.load(f)
    assert m["step"] == 5 and m["extra"] == {"round": 0}
    assert sorted(m["names"]) == ["acc", "nested/w", "plain"]
