#!/usr/bin/env python
"""Docs checker: the CI docs job and the README bench-table generator.

Checks (default mode — exit nonzero on any failure):
  1. every intra-repo markdown link in README.md / DESIGN.md / ROADMAP.md
     resolves to an existing file or directory;
  2. the benchmark tables in README.md match what the checked-in
     BENCH_he.json / BENCH_agg_sharded.json render to;
  3. the README quickstart snippet (first ```bash block after the
     "quickstart" heading) executes successfully (skipped with
     --no-exec for fast local runs).

`--write` regenerates the README tables in place between the
BENCH_TABLES_START/END markers instead of failing on drift.

Usage:
    python tools/check_docs.py            # full check (CI docs job)
    python tools/check_docs.py --no-exec  # links + tables only
    python tools/check_docs.py --write    # refresh README bench tables
"""
from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
import tempfile

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DOC_FILES = ("README.md", "DESIGN.md", "ROADMAP.md")
MARK_START = "<!-- BENCH_TABLES_START -->"
MARK_END = "<!-- BENCH_TABLES_END -->"

_LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)]*)?\)")


def check_links() -> list[str]:
    """Every relative markdown link must resolve inside the repo."""
    errors = []
    for doc in DOC_FILES:
        path = os.path.join(ROOT, doc)
        if not os.path.exists(path):
            errors.append(f"{doc}: file missing")
            continue
        text = open(path).read()
        for m in _LINK.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            resolved = os.path.normpath(os.path.join(ROOT, target))
            if not resolved.startswith(ROOT):
                errors.append(f"{doc}: link escapes repo: {target}")
            elif not os.path.exists(resolved):
                errors.append(f"{doc}: broken link: {target}")
    return errors


def render_bench_tables() -> str:
    """Markdown tables from the checked-in BENCH json artifacts."""
    out = []

    he_path = os.path.join(ROOT, "BENCH_he.json")
    he = json.load(open(he_path))
    out.append(
        f"**Limb-fused engine vs per-limb dispatch baseline** "
        f"(`benchmarks/run.py he`; N={he['n_poly']}, L={he['n_limbs']}, "
        f"{he['n_clients']} clients, backend `{he['backend']}`):\n")
    out.append("| op | per-limb ms | fused ms | speedup |")
    out.append("|----|------------:|---------:|--------:|")
    for op, r in he["ops"].items():
        per = r.get("per_limb_ms")
        per_s = f"{per:.2f}" if per is not None else "—"
        spd = r.get("speedup")
        spd_s = f"{spd:.0f}x" if spd is not None else "—"
        out.append(f"| {op} | {per_s} | {r['fused_ms']:.2f} | {spd_s} |")
    out.append("")

    ag_path = os.path.join(ROOT, "BENCH_agg_sharded.json")
    ag = json.load(open(ag_path))
    rows = [ag["per_devices"][k] for k in sorted(ag["per_devices"],
                                                key=lambda s: int(s))]
    r0 = rows[0]
    out.append(
        f"**Sharded vs single-device aggregation** "
        f"(`benchmarks/run.py agg-sharded`; N={r0['n_poly']}, "
        f"L={r0['n_limbs']}, {r0['n_clients']} clients x "
        f"{r0['n_chunks']} chunks, simulated host devices):\n")
    out.append("| devices | mesh (data x model) | weighted_sum single ms | "
               "weighted_sum sharded ms | stream ingest ms | "
               "launches/update | bit-parity |")
    out.append("|--------:|---------------------|----------------------:|"
               "------------------------:|-----------------:|"
               "----------------:|:----------:|")
    for r in rows:
        mesh = f"{r['mesh']['data']} x {r['mesh']['model']}"
        out.append(
            f"| {r['devices']} | {mesh} | "
            f"{r['weighted_sum_single_ms']:.2f} | "
            f"{r['weighted_sum_sharded_ms']:.2f} | "
            f"{r['stream_ingest_sharded_ms']:.0f} | "
            f"{r['launches_per_update']:.0f} | "
            f"{'yes' if r['sharded_parity'] else 'NO'} |")
    return "\n".join(out) + "\n"


def check_or_write_tables(write: bool) -> list[str]:
    path = os.path.join(ROOT, "README.md")
    text = open(path).read()
    if MARK_START not in text or MARK_END not in text:
        return [f"README.md: missing {MARK_START}/{MARK_END} markers"]
    head, rest = text.split(MARK_START, 1)
    _, tail = rest.split(MARK_END, 1)
    rendered = MARK_START + "\n" + render_bench_tables() + MARK_END
    new = head + rendered + tail
    if new == text:
        return []
    if write:
        open(path, "w").write(new)
        print("README.md bench tables refreshed")
        return []
    return ["README.md: bench tables out of date with BENCH json "
            "(run `python tools/check_docs.py --write`)"]


def run_quickstart() -> list[str]:
    """Extract and execute the first ```bash block after 'quickstart'."""
    text = open(os.path.join(ROOT, "README.md")).read()
    m = re.search(r"quickstart.*?```bash\n(.*?)```", text,
                  re.IGNORECASE | re.DOTALL)
    if not m:
        return ["README.md: no ```bash quickstart block found"]
    script = m.group(1)
    with tempfile.NamedTemporaryFile("w", suffix=".sh", delete=False) as f:
        f.write("set -euo pipefail\n" + script)
        name = f.name
    try:
        proc = subprocess.run(["bash", name], cwd=ROOT, capture_output=True,
                              text=True, timeout=900)
    finally:
        os.unlink(name)
    if proc.returncode != 0:
        return [f"README quickstart failed (exit {proc.returncode}):\n"
                f"{proc.stdout}\n{proc.stderr}"]
    print(f"README quickstart OK: {proc.stdout.strip().splitlines()[-1]}")
    return []


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--write", action="store_true",
                    help="refresh README bench tables instead of checking")
    ap.add_argument("--no-exec", action="store_true",
                    help="skip executing the README quickstart snippet")
    args = ap.parse_args()

    errors = check_links()
    errors += check_or_write_tables(write=args.write)
    if not args.no_exec and not args.write:
        errors += run_quickstart()
    for e in errors:
        print(f"DOCS ERROR: {e}", file=sys.stderr)
    if not errors:
        print("docs check passed")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
