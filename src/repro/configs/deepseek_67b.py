"""deepseek-67b [dense] — llama architecture.
Source: arXiv:2401.02954 (hf tier).
95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab=102400,
    dtype="bfloat16", param_dtype="float32", remat=True,
)

SMOKE = ModelConfig(
    name="deepseek-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=160,
    vocab=257, attn_chunk=16,
)
