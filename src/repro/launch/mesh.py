"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state; callers (dryrun.py, benchmarks) set XLA_FLAGS *before* the first
jax import.

Simulating devices on a host: jax locks the device count at first
initialization, so the flag must be in the environment before jax is
imported —

    XLA_FLAGS=--xla_force_host_platform_device_count=<n>

README.md ("Environment variables & flags") is the canonical list of the
knobs (REPRO_HE_BACKEND, host-device-count) shared by the benchmarks, CI
legs, and these helpers.
"""
from __future__ import annotations

import numpy as np


def _make_mesh(shape, axes, devices):
    """jax.make_mesh across jax versions: axis_types only where supported.

    Failures (usually a device count that cannot fill `shape`) re-raise
    with a pointer to the knob that fixes them — like every other error in
    this module, it names the README section so operators never have to
    read this source to recover."""
    import inspect

    import jax

    try:
        if "axis_types" in inspect.signature(jax.make_mesh).parameters:
            return jax.make_mesh(
                shape, axes, devices=devices,
                axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
        return jax.make_mesh(shape, axes, devices=devices)
    except ValueError as e:
        raise RuntimeError(
            f"could not build mesh {dict(zip(axes, shape))}: {e}. "
            "Host-simulated devices come from XLA_FLAGS="
            "--xla_force_host_platform_device_count=<n>, which must be set "
            "before the first jax import — see README.md 'Environment "
            "variables & flags'.") from e


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} exist. "
            "jax locks the device count at first init, so set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 in the "
            "environment BEFORE the first jax import (repro.launch.dryrun "
            "sets this automatically; see README.md 'Environment variables "
            "& flags').")
    return _make_mesh(shape, axes, devices[:n])


def make_host_mesh():
    """Trivial 1x1 mesh for CPU smoke runs."""
    import jax

    return _make_mesh((1, 1), ("data", "model"), jax.devices()[:1])


def make_he_mesh(n_limbs: int, n_devices: int | None = None, *,
                 devices=None):
    """("data", "model") mesh for the sharded HE engine (DESIGN.md §8).

    Picks the largest model-axis size that divides BOTH `n_limbs` (so whole
    limbs map to shards) and the device count (so the mesh is full); the
    remaining factor becomes the data axis for ciphertext-chunk sharding.

    Args:
        n_limbs: RNS limb count of the CkksContext the mesh will serve.
        n_devices: devices to use (default: all available).
        devices: explicit device list (default jax.devices()).

    Returns:
        A jax Mesh with axes ("data", "model"), data*model == n_devices.
    """
    import jax

    devs = list(devices if devices is not None else jax.devices())
    k = int(n_devices if n_devices is not None else len(devs))
    if k > len(devs):
        raise RuntimeError(
            f"make_he_mesh asked for {k} devices but only {len(devs)} "
            "exist; simulate more with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=<n> set "
            "before the first jax import (see README.md 'Environment "
            "variables & flags').")
    m = max(d for d in range(1, k + 1) if n_limbs % d == 0 and k % d == 0)
    return _make_mesh((k // m, m), ("data", "model"), devs[:k])
