"""Threshold CKKS key management (paper §2.2 / Appendix B).

Two variants:
  * additive n-of-n — each party i holds s_i with s = sum_i s_i; joint pk is
    generated interactively from a common random `a` (b_i = -(a s_i) + e_i);
    decryption combines per-party partial decryptions d_i = c1*s_i + e_smudge.
  * Shamir t-of-n — coefficients of s are secret-shared over each limb field;
    any t parties reconstruct via Lagrange coefficients folded into their
    partial decryptions.

Smudging noise (sigma_smudge >> sigma_err) statistically hides each party's
share in its partial decryption, matching the standard threshold-HE argument
(Asharov et al., 2012).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ckks import cipher
from repro.core.ckks.cipher import Ciphertext
from repro.core.ckks.params import CkksContext
from repro.kernels import ops, ref as _ref

DEFAULT_SMUDGE_SIGMA = 2.0 ** 12


# ---------------------------------------------------------------------------
# additive n-of-n
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ThresholdParty:
    index: int
    s_mont: object   # u32[L, N] NTT-domain Montgomery share


def threshold_keygen(ctx: CkksContext, key, n_parties: int
                     ) -> tuple[list[ThresholdParty], dict]:
    """Interactive additive keygen. Returns (parties, joint pk)."""
    n = ctx.n_poly
    k_a, k_rest = jax.random.split(key)
    a = cipher._uniform_residues(k_a, (n,), ctx.tables.qs)      # common reference poly
    a_mont = ops.to_mont(a, ctx)
    parties = []
    b_sum = None
    for i in range(n_parties):
        k_s, k_e = jax.random.split(jax.random.fold_in(k_rest, i))
        s_i = ops.ntt_fwd(cipher._ternary_residues(k_s, (n,), ctx.tables.qs), ctx)
        s_i_mont = ops.to_mont(s_i, ctx)
        e_i = ops.ntt_fwd(cipher._gaussian_residues(k_e, (n,), ctx.tables.qs, ctx.error_sigma), ctx)
        b_i = ops.mod_add(ops.mod_neg(ops.mont_mul(a, s_i_mont, ctx), ctx),
                          e_i, ctx)
        b_sum = b_i if b_sum is None else ops.mod_add(b_sum, b_i, ctx)
        parties.append(ThresholdParty(index=i, s_mont=s_i_mont))
    pk = {"pk0_mont": ops.to_mont(b_sum, ctx), "pk1_mont": a_mont}
    return parties, pk


def partial_decrypt(ctx: CkksContext, party: ThresholdParty, ct: Ciphertext,
                    key, smudge_sigma: float = DEFAULT_SMUDGE_SIGMA):
    """d_i = c1 (*) s_i + e_smudge  (NTT domain)."""
    b = ct.data.shape[0]
    e = ops.ntt_fwd(
        cipher._gaussian_residues(key, (b, ctx.n_poly), ctx.tables.qs, smudge_sigma),
        ctx)
    return ops.mul_add(ct.c1, party.s_mont[None], e, ctx)


def combine_partials(ctx: CkksContext, ct: Ciphertext, partials: list):
    """m~ = c0 + sum_i d_i -> coefficient-domain residues."""
    acc = ct.c0
    for d in partials:
        acc = ops.mod_add(acc, d, ctx)
    return ops.ntt_inv(acc, ctx)


# ---------------------------------------------------------------------------
# Shamir t-of-n
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShamirParty:
    index: int          # evaluation point x = index + 1
    share: object       # u32[L, N] NTT-domain share of s (normal form)


def shamir_share_secret(ctx: CkksContext, sk: dict, key, n_parties: int,
                        threshold: int) -> list[ShamirParty]:
    """Split sk into Shamir shares over each limb field."""
    s = ops.from_mont(sk["s_mont"], ctx)     # [L, N] normal form
    coeff_keys = jax.random.split(key, threshold - 1)
    coeffs = [cipher._uniform_residues(k, (ctx.n_poly,), ctx.tables.qs)
              for k in coeff_keys]           # each [L, N]
    parties = []
    for i in range(n_parties):
        x = i + 1
        acc = s
        x_pow_mont = [jnp.asarray(
            np.asarray([pow(x, k + 1, q) * (1 << 32) % q for q in ctx.primes],
                       dtype=np.uint32))[:, None] for k in range(threshold - 1)]
        for k, c in enumerate(coeffs):
            acc = ops.mod_add(acc, ops.mont_mul(c, x_pow_mont[k], ctx), ctx)
        parties.append(ShamirParty(index=i, share=acc))
    return parties


def _lagrange_at_zero(indices: list[int], q: int) -> list[int]:
    """lambda_j = prod_{m != j} x_m / (x_m - x_j) mod q (x = index+1)."""
    lams = []
    xs = [i + 1 for i in indices]
    for j, xj in enumerate(xs):
        num, den = 1, 1
        for m, xm in enumerate(xs):
            if m == j:
                continue
            num = num * xm % q
            den = den * ((xm - xj) % q) % q
        lams.append(num * pow(den, -1, q) % q)
    return lams


def shamir_partial_decrypt(ctx: CkksContext, party: ShamirParty,
                           active_indices: list[int], ct: Ciphertext, key,
                           smudge_sigma: float = DEFAULT_SMUDGE_SIGMA):
    """d_j = c1 (*) (lambda_j * share_j) + e_smudge for the active subset."""
    pos = active_indices.index(party.index)
    lam_mont = jnp.asarray(np.asarray(
        [_lagrange_at_zero(active_indices, q)[pos] * (1 << 32) % q
         for q in ctx.primes], dtype=np.uint32))[:, None]
    lam_share = ops.mont_mul(party.share, lam_mont, ctx)      # normal form
    lam_share_mont = ops.to_mont(lam_share, ctx)
    b = ct.data.shape[0]
    e = ops.ntt_fwd(
        cipher._gaussian_residues(key, (b, ctx.n_poly), ctx.tables.qs, smudge_sigma),
        ctx)
    return ops.mul_add(ct.c1, lam_share_mont[None], e, ctx)
