"""Telemetry layer (repro.obs): registry semantics, span nesting, the
trace-JSONL round trip through tools/round_report.py, legacy-counter
parity on the streaming ingest, and the REPRO_OBS=0 do-no-harm contract
(disabled obs leaves backend tokens, dispatch behaviour, and gold-KAT
outputs untouched)."""
import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import obs
from repro.core.ckks import cipher
from repro.core.ckks import params as ckks_params
from repro.core.secure_agg import AggregatorConfig, SelectiveHEAggregator
from repro.kernels import ops, ref
from repro.obs.metrics import MetricsRegistry
from repro.wire import compress as wc
from repro.wire import stream as ws

import gold

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(ROOT, "tools"))
import round_report  # noqa: E402  (tools/ has no package)

CTX = ckks_params.make_test_context(n_poly=256, n_limbs=2, delta_bits=20)
SK, PK = cipher.keygen(CTX, jax.random.PRNGKey(0))


@pytest.fixture
def obs_memory():
    """Enable obs with an in-memory tracer; restore disabled on exit."""
    obs.configure(enabled=True, trace_path=None, reset=True)
    yield obs.get_tracer()
    obs.configure(enabled=False, trace_path=None, reset=True)


def small_model(seed=1):
    r = np.random.RandomState(seed)
    return {"w1": jnp.asarray(r.randn(40, 10), jnp.float32),
            "b1": jnp.asarray(r.randn(50), jnp.float32)}


def make_agg(p=0.4, seed=3):
    m = small_model()
    n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(m))
    sens = np.abs(np.random.RandomState(seed).randn(n))
    return SelectiveHEAggregator.build(CTX, m, sens,
                                       AggregatorConfig(p_ratio=p)), m


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_counter_gauge_semantics():
    reg = MetricsRegistry()
    c = reg.counter("reqs", route="a")
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)
    # distinct label sets are distinct series; same labels share one
    assert reg.counter("reqs", route="b") is not c
    assert reg.counter("reqs", route="a") is c
    assert reg.total("reqs") == 5
    g = reg.gauge("resident")
    g.set(3)
    g.add(2)
    g.set_max(4)            # below current -> unchanged
    assert g.value == 5
    g.set_max(9)
    assert g.value == 9
    # one name cannot be two instrument types
    with pytest.raises(TypeError):
        reg.gauge("reqs", route="a")
    assert reg.get("nope") is None


def test_histogram_percentiles_exact():
    reg = MetricsRegistry()
    h = reg.histogram("lat")
    with pytest.raises(ValueError):
        h.percentile(50)
    for v in range(1, 101):
        h.observe(float(v))
    assert h.count == 100
    assert h.mean == pytest.approx(50.5)
    assert h.percentile(0) == 1.0
    assert h.percentile(100) == 100.0
    # linear interpolation over the sorted samples (numpy's definition)
    assert h.percentile(50) == pytest.approx(
        np.percentile(np.arange(1, 101), 50))
    assert h.percentile(99) == pytest.approx(
        np.percentile(np.arange(1, 101), 99))


def test_prometheus_text_and_snapshot():
    reg = MetricsRegistry()
    reg.counter("ops_total", op="ntt", backend="ref").inc(7)
    reg.histogram("secs", op="ntt").observe(0.5)
    text = reg.prometheus_text()
    assert "# TYPE ops_total counter" in text
    assert 'ops_total{backend="ref",op="ntt"} 7' in text
    assert 'secs{op="ntt",quantile="0.5"}' in text
    assert 'secs_count{op="ntt"} 1' in text
    snap = reg.snapshot()
    assert snap["ops_total"][0]["value"] == 7
    assert snap["secs"][0]["count"] == 1


# ---------------------------------------------------------------------------
# trace spans
# ---------------------------------------------------------------------------


def test_span_nesting_and_ordering(obs_memory):
    tr = obs_memory
    with obs.span("round", round=0) as r:
        with obs.span("client", cid=1):
            assert tr.depth() == 2
        with obs.span("aggregate"):
            pass
        r.set(bytes_up=7)
    assert tr.depth() == 0
    names = [e["name"] for e in tr.events]
    # spans are emitted as they CLOSE: children before the parent
    assert names == ["client", "aggregate", "round"]
    rd = tr.events[-1]
    assert rd["ph"] == "X" and rd["args"]["bytes_up"] == 7
    # wall-time containment — the tree structure Perfetto reconstructs
    for child in tr.events[:2]:
        assert rd["ts"] <= child["ts"]
        assert child["ts"] + child["dur"] <= rd["ts"] + rd["dur"] + 1e-3
    # the two children are disjoint and in order
    c0, c1 = tr.events[0], tr.events[1]
    assert c0["ts"] + c0["dur"] <= c1["ts"] + 1e-3


def test_span_records_exception(obs_memory):
    tr = obs_memory
    with pytest.raises(RuntimeError):
        with obs.span("boom"):
            raise RuntimeError("x")
    assert tr.events[-1]["args"]["error"] == "RuntimeError"
    assert tr.depth() == 0


def test_disabled_span_is_shared_noop():
    obs.configure(enabled=False, trace_path=None, reset=True)
    sp = obs.span("anything", k=1)
    assert sp is obs.NULL_SPAN
    with sp as s:
        s.set(ignored=True)      # must not raise
    obs.event("nothing")         # no tracer instantiation needed
    assert obs.trace_path() is None


# ---------------------------------------------------------------------------
# trace file -> round_report round trip
# ---------------------------------------------------------------------------


def _synthetic_round(tr):
    """Emit a deterministic round tree: 1000us round fully covered by
    client(400) + aggregate(600); one kernel launch inside aggregate that
    nests a second kernel event (the sharded-dispatch double-measure)."""
    tok = "('ntt_fwd','ref')"
    tr.emit_complete("local_train", 10, 380, cat="phase", args={"cid": 0})
    tr.emit_complete("client", 0, 400, cat="phase", args={"cid": 0})
    tr.emit_complete("he.weighted_accum_chunks", 460, 50, cat="kernel",
                     args={"op": "weighted_accum_chunks", "token": tok})
    tr.emit_complete("he.weighted_accum_chunks", 450, 100, cat="kernel",
                     args={"op": "weighted_accum_chunks", "token": tok})
    tr.emit_complete("aggregate", 400, 600, cat="phase", args={})
    tr.emit_complete("round", 0, 1000, cat="phase",
                     args={"round": 3, "bytes_up": 111, "bytes_down": 222,
                           "launches": 1})


def test_round_report_roundtrip(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    obs.configure(enabled=True, trace_path=path, reset=True)
    try:
        _synthetic_round(obs.get_tracer())
        obs.get_tracer().close()
    finally:
        obs.configure(enabled=False, trace_path=None, reset=True)
    # the file is line-parseable AND a valid Chrome trace array once the
    # optional ']' is appended
    with open(path) as f:
        raw = f.read()
    json.loads(raw.rstrip().rstrip(",") + "]")

    proc = subprocess.run(
        [sys.executable, os.path.join("tools", "round_report.py"), path,
         "--json", "--min-coverage", "0.9"],
        cwd=ROOT, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr
    rep = json.loads(proc.stdout)
    (rnd,) = rep["rounds"]
    assert rnd["round"] == 3
    assert rnd["wall_ms"] == pytest.approx(1.0)
    assert rnd["client"] == pytest.approx(0.4)
    assert rnd["aggregate"] == pytest.approx(0.6)
    assert rnd["bytes_up"] == 111 and rnd["bytes_down"] == 222
    assert rnd["launches"] == 1
    assert rnd["coverage"] == pytest.approx(1.0)
    # the nested kernel event is the same launch measured twice: only the
    # top-level one is counted
    (k,) = rep["kernels"]
    assert k["op"] == "weighted_accum_chunks" and k["count"] == 1
    assert k["total_ms"] == pytest.approx(0.1)


def test_round_report_rejects_low_coverage(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    obs.configure(enabled=True, trace_path=path, reset=True)
    try:
        tr = obs.get_tracer()
        tr.emit_complete("client", 0, 100, cat="phase", args={})
        tr.emit_complete("round", 0, 1000, cat="phase", args={"round": 0})
        tr.close()
    finally:
        obs.configure(enabled=False, trace_path=None, reset=True)
    proc = subprocess.run(
        [sys.executable, os.path.join("tools", "round_report.py"), path,
         "--min-coverage", "0.9"],
        cwd=ROOT, capture_output=True, text=True)
    assert proc.returncode == 1
    assert "below coverage" in proc.stderr


def test_round_report_empty_trace_fails(tmp_path):
    path = tmp_path / "empty.jsonl"
    path.write_text("[\n")
    proc = subprocess.run(
        [sys.executable, os.path.join("tools", "round_report.py"),
         str(path)],
        cwd=ROOT, capture_output=True, text=True)
    assert proc.returncode == 1


# ---------------------------------------------------------------------------
# legacy counters == registry series (streaming ingest)
# ---------------------------------------------------------------------------


def test_stream_counters_are_registry_backed():
    agg, m = make_agg()
    n = 4
    blobs = []
    for i in range(n):
        c = jax.tree_util.tree_map(lambda x, i=i: x + 0.05 * i, m)
        upd = agg.client_protect_seeded(c, SK, jax.random.PRNGKey(30 + i),
                                        a_seed=700 + i)
        sct = wc.seed_compress(upd.ct, 700 + i)
        blobs.append(ws.pack_update_frames(upd, cid=i, n_samples=2, rnd=0,
                                           seeded=sct))
    ing = ws.StreamIngest(CTX)
    for b in blobs:
        ing.ingest(b, 1.0 / n)
    ing.finalize()
    # legacy invariants still hold through the property layer
    assert ing.clients_ingested == n
    assert ing.accum_launches == n
    assert ing.peak_chunk_buffers == agg.part.n_chunks
    assert ing.bytes_ingested == sum(len(b) for b in blobs)
    # and each property IS the labeled registry series, not a shadow copy
    lab = {"ingest": ing.ingest_id}
    assert obs.REGISTRY.get("wire_ingest_accum_launches",
                            **lab).value == ing.accum_launches
    assert obs.REGISTRY.get("wire_ingest_clients",
                            **lab).value == ing.clients_ingested
    assert obs.REGISTRY.get("wire_ingest_bytes",
                            **lab).value == ing.bytes_ingested
    assert obs.REGISTRY.get("wire_ingest_peak_chunk_buffers",
                            **lab).value == ing.peak_chunk_buffers
    # properties are read-only: the legacy `ing.clients_ingested += 1`
    # write pattern is gone for good
    with pytest.raises(AttributeError):
        ing.clients_ingested = 0


# ---------------------------------------------------------------------------
# REPRO_OBS=0 do-no-harm; hooks record when enabled
# ---------------------------------------------------------------------------


def test_disabled_obs_leaves_dispatch_untouched():
    obs.configure(enabled=False, trace_path=None, reset=True)
    token_before = ops.backend_token()
    obs.configure(enabled=True, trace_path=None, reset=True)
    try:
        # the jit static key is independent of the obs switch: flipping
        # telemetry can never retrace or recompile an HE graph
        assert ops.backend_token() == token_before
    finally:
        obs.configure(enabled=False, trace_path=None, reset=True)
    assert ops.backend_token() == token_before

    # disabled dispatch records nothing and emits nothing
    before = {k for k in obs.REGISTRY.snapshot() if k.startswith("kernel")}
    x = jnp.asarray(ref.rand_limbed_np(np.random.RandomState(0), CTX, (1,)))
    ops.ntt_fwd(x, CTX)
    after = {k for k in obs.REGISTRY.snapshot() if k.startswith("kernel")}
    assert before == after
    assert not obs.get_tracer().events


def test_gold_kats_bitexact_with_obs_disabled():
    obs.configure(enabled=False, trace_path=None, reset=True)
    golden = gold.load_kats()
    got = gold.compute_kats()
    for name in sorted(golden):
        np.testing.assert_array_equal(got[name], golden[name],
                                      err_msg=f"obs-disabled drift: {name}")


def test_enabled_eager_dispatch_records(obs_memory):
    x = jnp.asarray(ref.rand_limbed_np(np.random.RandomState(0), CTX, (1,)))
    y_ref = np.asarray(ops.ntt_fwd(x, CTX))
    c = obs.REGISTRY.get("kernel_op_launches_total", op="ntt_fwd",
                         backend=ops.get_backend("ntt_fwd"))
    assert c is not None and c.value >= 1
    h = obs.REGISTRY.get("kernel_op_seconds", op="ntt_fwd",
                         backend=ops.get_backend("ntt_fwd"))
    assert h is not None and h.count >= 1
    evs = [e for e in obs_memory.events if e.get("cat") == "kernel"]
    assert any(e["args"].get("op") == "ntt_fwd" for e in evs)
    # and the instrumented result is the raw result
    obs.configure(enabled=False)
    np.testing.assert_array_equal(y_ref, np.asarray(ops.ntt_fwd(x, CTX)))


def test_kernel_launch_context_manager(obs_memory):
    with obs.kernel_launch("fake_op", ops.backend_token(), rows=3) as kl:
        out = kl.done(jnp.ones((2, 2)))
    assert float(out.sum()) == 4.0
    ev = [e for e in obs_memory.events if e.get("cat") == "kernel"][-1]
    assert ev["args"]["op"] == "fake_op" and ev["args"]["rows"] == 3
    assert "token" in ev["args"]
    h = obs.REGISTRY.get("kernel_launch_seconds", op="fake_op", backend="")
    assert h is not None and h.count >= 1


# ---------------------------------------------------------------------------
# orchestrator round span tree
# ---------------------------------------------------------------------------


def test_orchestrator_round_span_coverage(obs_memory):
    from test_fl import tiny_task
    task = tiny_task()
    task.run()
    roots = round_report.build_tree(list(obs_memory.events))
    rows = round_report.round_rows(roots)
    assert len(rows) == 2                       # one row per round
    for r in rows:
        # the span tree explains where round wall time went
        assert r["coverage"] >= 0.8, r
        assert r["client"] > 0 and r["aggregate"] >= 0
    names = {e["name"] for e in obs_memory.events}
    assert {"round", "client", "local_train", "aggregate",
            "recover"} <= names
