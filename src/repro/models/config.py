"""Model configuration shared by every architecture family."""
from __future__ import annotations

import dataclasses


def pad_to(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    vocab: int = 0
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0            # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # mlp
    d_ff: int = 0
    mlp_gated: bool = True       # SwiGLU (3 mats) vs GELU (2 mats)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4
    ssm_groups: int = 1
    # hybrid (zamba2): shared attention block cadence
    shared_attn_every: int = 6
    # modality stubs
    n_patches: int = 0           # vlm: CLIP patch count
    patch_dim: int = 0           # vlm: CLIP feature dim
    frame_dim: int = 0           # audio: frontend frame feature dim
    # misc
    tie_embeddings: bool = False
    vocab_pad_multiple: int = 128
    # numerics / lowering
    dtype: str = "float32"       # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = False          # checkpoint each layer (dry-run/training)
    attn_chunk: int = 2048       # blocked-causal attention query-chunk size

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.n_heads))

    @property
    def vocab_padded(self) -> int:
        return pad_to(self.vocab, self.vocab_pad_multiple) if self.vocab else 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_causal(self) -> bool:
        return self.family != "encoder"

    @property
    def has_decode(self) -> bool:
        return self.family != "encoder"

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND and the paper's tables)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_padded
        hd = self.hd
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "encoder", "vlm"):
            att = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d
            if self.qkv_bias:
                att += self.n_heads * hd + 2 * self.n_kv_heads * hd
            mlp = (3 if self.mlp_gated else 2) * d * ff
            per = att + mlp + 2 * d
            extra = 0
            if self.family == "vlm":
                extra = self.patch_dim * d
            if self.family == "encoder":
                extra = self.frame_dim * d
            return emb + self.n_layers * per + d + extra
        if self.family == "moe":
            att = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d
            moe = d * self.n_experts + self.n_experts * 3 * d * ff
            return emb + self.n_layers * (att + moe + 2 * d) + d
        if self.family == "ssm":
            per = self._mamba_block_params()
            return emb + self.n_layers * per + d
        if self.family == "hybrid":
            per = self._mamba_block_params()
            d2 = 2 * d
            shared = d2 + d2 * self.n_heads * hd + 2 * d2 * self.n_kv_heads * hd \
                + self.n_heads * hd * d + d + 3 * d * ff
            return emb + self.n_layers * per + shared + d
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """MoE: params touched per token (for 6*N_active*D)."""
        if self.family != "moe":
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        hd = self.hd
        att = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        moe_active = d * self.n_experts + self.top_k * 3 * d * ff
        emb = self.vocab_padded * d * (1 if self.tie_embeddings else 2)
        return emb + self.n_layers * (att + moe_active + 2 * d) + d

    def _mamba_block_params(self) -> int:
        d = self.d_model
        din = self.d_inner
        st = self.ssm_state
        nh = self.ssm_heads
        proj_in = d * (2 * din + 2 * self.ssm_groups * st + nh)
        conv = self.conv_width * (din + 2 * self.ssm_groups * st)
        return proj_in + conv + 3 * nh + din + din * d + d
