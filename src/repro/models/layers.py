"""Shared neural-net building blocks (pure JAX, no flax).

Conventions:
  * params are nested dicts of jnp arrays; per-layer weights are stacked on a
    leading L axis and indexed with static python ints (layers are unrolled —
    exact cost_analysis accounting, see DESIGN.md §6).
  * attention is blocked-causal: a static python loop over query chunks, each
    materializing one [B, H, qc, kv_len] logits tile (flash-style memory
    behaviour with exact FLOP accounting; no lax.scan whose body XLA would
    count once).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import sharding
from repro.models.config import ModelConfig


def trunc_normal(key, shape, std, dtype):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) \
        .astype(dtype)


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, hd, 2, dtype=np.float64) / hd))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: [..., S] or [S] int32."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), dtype=jnp.float32)
    ang = positions.astype(jnp.float32)[..., None] * freqs       # [..., S, hd/2]
    cos = jnp.cos(ang)[..., None, :]                             # [..., S, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def _gqa_logits(q, k, scale):
    """q: [B, Sq, KH, G, hd], k: [B, Sk, KH, hd] -> [B, KH, G, Sq, Sk]."""
    return jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                      preferred_element_type=jnp.float32) * scale


def _gqa_out(probs, v):
    """probs: [B, KH, G, Sq, Sk], v: [B, Sk, KH, hd] -> [B, Sq, KH, G, hd]."""
    return jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(probs.dtype))


def blocked_attention(q, k, v, cfg: ModelConfig, ax: sharding.AxisEnv,
                      causal: bool, q_start: int = 0):
    """Blocked (causal) attention.

    q: [B, Sq, H, hd]; k, v: [B, Sk, KH, hd].  Returns [B, Sq, H, hd].
    Static python loop over query chunks; for causal attention each chunk
    only reads k/v up to its last row (true ~S^2/2 FLOPs).
    """
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(b, sq, kh, g, hd)
    chunk = min(cfg.attn_chunk, sq)
    n_chunks = -(-sq // chunk)
    outs = []
    for ci in range(n_chunks):
        s0 = ci * chunk
        s1 = min(sq, s0 + chunk)
        qc = qg[:, s0:s1]
        kv_end = (q_start + s1) if causal else k.shape[1]
        kc, vc = k[:, :kv_end], v[:, :kv_end]
        logits = _gqa_logits(qc, kc, scale)        # [B, KH, G, qc, kv_end] f32
        if causal:
            q_pos = q_start + jnp.arange(s0, s1)
            k_pos = jnp.arange(kv_end)
            mask = q_pos[:, None] >= k_pos[None, :]
            logits = jnp.where(mask[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        oc = _gqa_out(probs, vc)                   # [B, qc, KH, G, hd]
        outs.append(oc.astype(q.dtype))
    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return out.reshape(b, sq, h, hd)


def decode_attention(q, k_cache, v_cache, pos):
    """Single-token attention against a (possibly seq-sharded) cache.

    q: [B, H, hd]; k_cache/v_cache: [B, S, KH, hd]; pos: scalar i32 (number
    of valid cache entries minus one, i.e. the new token's position).
    Masked full-cache read; the softmax reductions over the sharded S dim
    lower to small per-head collectives (flash-decode pattern under SPMD).
    """
    b, h, hd = q.shape
    kh = k_cache.shape[2]
    g = h // kh
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(b, kh, g, hd)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    s = k_cache.shape[1]
    mask = jnp.arange(s) <= pos
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v_cache.astype(probs.dtype))
    return out.reshape(b, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block params / apply
# ---------------------------------------------------------------------------


def init_attn(key, cfg: ModelConfig, n_layers: int, d_in: int | None = None):
    d = d_in or cfg.d_model
    hd = cfg.hd
    std = 0.02
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": trunc_normal(ks[0], (n_layers, d, cfg.n_heads * hd), std, dt),
        "wk": trunc_normal(ks[1], (n_layers, d, cfg.n_kv_heads * hd), std, dt),
        "wv": trunc_normal(ks[2], (n_layers, d, cfg.n_kv_heads * hd), std, dt),
        "wo": trunc_normal(ks[3], (n_layers, cfg.n_heads * hd, cfg.d_model),
                           std / math.sqrt(2 * cfg.n_layers), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((n_layers, cfg.n_heads * hd), dt)
        p["bk"] = jnp.zeros((n_layers, cfg.n_kv_heads * hd), dt)
        p["bv"] = jnp.zeros((n_layers, cfg.n_kv_heads * hd), dt)
    return p


def attn_qkv(p, i, x, cfg: ModelConfig, ax: sharding.AxisEnv, positions):
    """x: [B, S, d_in] -> q [B,S,H,hd], k/v [B,S,KH,hd] (RoPE applied)."""
    b, s, _ = x.shape
    hd = cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"][i].astype(x.dtype))
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"][i].astype(x.dtype))
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"][i].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"][i].astype(x.dtype)
        k = k + p["bk"][i].astype(x.dtype)
        v = v + p["bv"][i].astype(x.dtype)
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = sharding.constrain(q, *_qspec(ax, cfg.n_heads))
    k = sharding.constrain(k, *_kvspec(ax, cfg.n_kv_heads))
    v = sharding.constrain(v, *_kvspec(ax, cfg.n_kv_heads))
    return q, k, v


def _qspec(ax: sharding.AxisEnv, h):
    return (ax.dp, None, ax.mp(h), None)


def _kvspec(ax: sharding.AxisEnv, kh):
    return (ax.dp, None, ax.mp(kh), None)


def attn_out(p, i, o, x_dtype):
    """o: [B, S, H, hd] -> [B, S, d_model]."""
    b, s = o.shape[:2]
    o = o.reshape(b, s, -1)
    return jnp.einsum("bsh,hd->bsd", o, p["wo"][i].astype(x_dtype))


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, n_layers: int, d_in: int | None = None):
    d = d_in or cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    p = {
        "w_up": trunc_normal(ks[1], (n_layers, d, cfg.d_ff), 0.02, dt),
        "w_down": trunc_normal(ks[2], (n_layers, cfg.d_ff, cfg.d_model),
                               0.02 / math.sqrt(2 * cfg.n_layers), dt),
    }
    if cfg.mlp_gated:
        p["w_gate"] = trunc_normal(ks[0], (n_layers, d, cfg.d_ff), 0.02, dt)
    return p


def mlp(p, i, x):
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"][i].astype(x.dtype))
    if "w_gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"][i].astype(x.dtype))
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(u)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"][i].astype(x.dtype))


# ---------------------------------------------------------------------------
# embeddings / logits / loss
# ---------------------------------------------------------------------------


def init_embed(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    p = {"embed": trunc_normal(k1, (cfg.vocab_padded, cfg.d_model), 0.02, dt)}
    if not cfg.tie_embeddings:
        p["unembed"] = trunc_normal(k2, (cfg.d_model, cfg.vocab_padded), 0.02, dt)
    return p


def embed_tokens(p, tokens, cfg: ModelConfig, dtype):
    return p["embed"].astype(dtype)[tokens]


def unembed_weight(p, cfg: ModelConfig):
    return p["embed"].T if cfg.tie_embeddings else p["unembed"]


def logits_fn(p, x, cfg: ModelConfig):
    return jnp.einsum("bsd,dv->bsv", x,
                      unembed_weight(p, cfg).astype(x.dtype))


def _xent_sums(logits, labels, vocab_real: int):
    """(sum of masked NLL, count of valid positions) for one chunk."""
    logits = logits.astype(jnp.float32)
    v = logits.shape[-1]
    vocab_ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                         logits.ndim - 1)
    if vocab_real < v:
        logits = jnp.where(vocab_ids < vocab_real, logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    onehot = vocab_ids == labels[..., None]
    label_logit = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    valid = labels >= 0
    nll = (lse - label_logit) * valid
    return jnp.sum(nll), jnp.sum(valid)


def softmax_xent(logits, labels, vocab_real: int):
    """Mean next-token CE; positions with label < 0 are masked out.

    SPMD-safe: everything is a *reduction* over the (model-sharded) vocab
    axis — a take_along_axis gather there would force an all-gather of the
    full f32 logits (~40 GB/device at 150k vocab).  The padded vocab tail
    is masked out of the partition function with an iota compare.
    """
    nll, valid = _xent_sums(logits, labels, vocab_real)
    return nll / jnp.maximum(1, valid)


def chunked_softmax_xent(hidden, unembed_w, labels, vocab_real: int,
                         chunk: int = 512):
    """Cross entropy with the logits never fully materialized.

    hidden: [B, S, d]; unembed_w: [d, V].  The S axis is processed in static
    chunks so the live f32 logits chain is [B, chunk, V_shard] instead of
    [B, S, V_shard] — at 150k vocab the full chain is ~15 GB/device.
    """
    s = hidden.shape[1]
    chunk = min(chunk, s)
    nll = jnp.zeros((), jnp.float32)
    valid = jnp.zeros((), jnp.int32)
    for s0 in range(0, s, chunk):
        s1 = min(s, s0 + chunk)
        lg = jnp.einsum("bsd,dv->bsv", hidden[:, s0:s1], unembed_w)
        dn, dv = _xent_sums(lg, labels[:, s0:s1], vocab_real)
        nll = nll + dn
        valid = valid + dv
    return nll / jnp.maximum(1, valid)
