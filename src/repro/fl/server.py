"""FL aggregation server.

Holds only the public crypto context + the SelectiveHEAggregator (static
mask indices).  Never sees secret keys.  Handles:
  * synchronous weighted aggregation over whatever updates arrived
    (dropout-robust: weights renormalize over the received set — HE needs
    no mask-recovery round, unlike secure aggregation, paper Table 1);
  * async FedBuff-style buffered aggregation with staleness discounting.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.secure_agg import ProtectedUpdate, SelectiveHEAggregator


@dataclasses.dataclass
class ReceivedUpdate:
    cid: int
    update: ProtectedUpdate
    n_samples: int
    round_sent: int = 0          # for staleness in async mode


class FLServer:
    def __init__(self, aggregator: SelectiveHEAggregator,
                 buffer_size: int = 0, staleness_half_life: float = 4.0):
        self.agg = aggregator
        self.buffer_size = buffer_size            # 0 => synchronous
        self.staleness_half_life = staleness_half_life
        self._buffer: list[ReceivedUpdate] = []
        self.rounds_aggregated = 0

    # -- synchronous ---------------------------------------------------------

    def aggregate_sync(self, received: list[ReceivedUpdate]) -> ProtectedUpdate:
        if not received:
            raise ValueError("no client updates received this round")
        weights = np.asarray([r.n_samples for r in received], dtype=np.float64)
        weights = weights / weights.sum()
        out = self.agg.server_aggregate([r.update for r in received],
                                        [float(w) for w in weights])
        self.rounds_aggregated += 1
        return out

    # -- async (FedBuff) -----------------------------------------------------

    def submit_async(self, r: ReceivedUpdate,
                     current_round: int) -> ProtectedUpdate | None:
        """Buffer an update; aggregate + flush when the buffer fills.
        Staleness discount: w *= 0.5 ** (staleness / half_life)."""
        self._buffer.append(r)
        if len(self._buffer) < self.buffer_size:
            return None
        ws = []
        for u in self._buffer:
            stale = max(0, current_round - u.round_sent)
            ws.append(u.n_samples * 0.5 ** (stale / self.staleness_half_life))
        ws = np.asarray(ws, dtype=np.float64)
        ws = ws / ws.sum()
        out = self.agg.server_aggregate([u.update for u in self._buffer],
                                        [float(w) for w in ws])
        self._buffer.clear()
        self.rounds_aggregated += 1
        return out
