"""Assigned input shapes x skip rules, and ShapeDtypeStruct input specs.

Shapes (LM transformer family; seq_len x global_batch):
  train_4k     seq=4,096   gb=256   lowers train_step
  prefill_32k  seq=32,768  gb=32    lowers serve prefill
  decode_32k   seq=32,768  gb=128   lowers serve_step (1 new token, KV cache)
  long_500k    seq=524,288 gb=1     long-context decode

Skip rules (assignment):
  * long_500k needs sub-quadratic attention -> only ssm/hybrid run it.
  * encoder-only archs have no decode step -> decode_32k/long_500k skipped.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def runnable(cfg: ModelConfig, shape_name: str) -> bool:
    sp = SHAPES[shape_name]
    if cfg.family == "encoder" and sp.kind == "decode":
        return False     # encoder-only: no decode step
    if shape_name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False     # quadratic-attention archs skip 500k decode
    return True


def cells_for(cfg: ModelConfig) -> list[str]:
    return [s for s in SHAPES if runnable(cfg, s)]


def input_specs(cfg: ModelConfig, shape_name: str, model=None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the cell.

    For 'train': the loss_fn batch.  For 'prefill': the prompt batch.  For
    'decode': {tokens, cache} where cache comes from the family's
    abstract_cache.  No device allocation happens here.
    """
    sp = SHAPES[shape_name]
    b, s = sp.batch, sp.seq
    i32 = jnp.int32
    act = jnp.dtype(cfg.dtype)
    if sp.kind == "train":
        if cfg.family == "encoder":
            return {"frames": SDS((b, s, cfg.frame_dim), act),
                    "labels": SDS((b, s), i32)}
        if cfg.family == "vlm":
            s_txt = s - cfg.n_patches
            return {"tokens": SDS((b, s_txt), i32),
                    "patches": SDS((b, cfg.n_patches, cfg.patch_dim), act),
                    "labels": SDS((b, s_txt), i32)}
        return {"tokens": SDS((b, s), i32), "labels": SDS((b, s), i32)}
    if sp.kind == "prefill":
        if cfg.family == "encoder":
            return {"frames": SDS((b, s, cfg.frame_dim), act)}
        if cfg.family == "vlm":
            return {"tokens": SDS((b, s - cfg.n_patches), i32),
                    "patches": SDS((b, cfg.n_patches, cfg.patch_dim), act)}
        return {"tokens": SDS((b, s), i32)}
    # decode: one new token against a seq-long cache
    assert model is not None, "decode specs need the built model"
    cache = model.abstract_cache(b, s, cfg.dtype)
    return {"tokens": SDS((b,), i32), "cache": cache}
