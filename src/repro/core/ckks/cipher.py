"""RNS-CKKS cipher: keygen / encrypt / decrypt / homomorphic ops.

Built on the limb-fused execution engine (kernels/ops.py): every sampling
helper vectorizes the RNS limb axis via the stacked constant tables on
`CkksContext.tables`, and keygen / encrypt / decrypt / weighted_sum each run
as ONE jitted graph (static-keyed on (ctx, ops.backend_token()) so backend
registry changes retrace).  Ciphertexts are u32[..., L, 2, N] tensors in
bit-reversed NTT domain, wrapped with their scale.

Scale discipline (depth-1, the paper's setting):
  fresh ct: scale = delta
  ct (*) plain-scalar weight: scale = delta**2   (no rescale — lazy; decode
  divides by the ct scale, saving one iNTT+NTT per limb per round. `rescale`
  is still provided and tested.)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ckks import encoding
from repro.core.ckks.params import CkksContext
from repro.kernels import ops, ref as _ref


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Ciphertext:
    """data: u32[..., L, 2, N] NTT domain; scale: encoding scale."""

    data: Any
    scale: float = dataclasses.field(metadata=dict(static=True), default=1.0)

    @property
    def n_limbs(self):
        return self.data.shape[-3]

    @property
    def c0(self):
        return self.data[..., 0, :]

    @property
    def c1(self):
        return self.data[..., 1, :]


# ---------------------------------------------------------------------------
# sampling helpers (all jittable)
# ---------------------------------------------------------------------------
#
# Each sampler takes the stacked u32[L] prime table explicitly (not a ctx)
# so the sharded engine (core/ckks/sharded.py) can hand in a per-shard limb
# slice: the random draw's SHAPE never involves L for the ternary/gaussian
# samplers, so the PRNG stream — and therefore the ciphertext — is
# bit-identical however the limb axis is sharded.
#
# The encrypt bodies additionally derive one PRNG key PER CIPHERTEXT CHUNK
# via fold_in(key, chunk_id) (`_chunk_keys`) and draw each chunk's samples
# with shape (N,): no draw shape involves the batch size either, so the
# stream is invariant under sharding the chunk axis across devices — each
# shard re-derives its local chunks' keys from the global chunk ids.  This
# is the wire-v2 derivation contract (DESIGN.md §9).


def _ternary_residues(key, shape, qs):
    """Uniform ternary {-1,0,1} -> per-limb residues u32[..., L, N].

    One draw of ternary symbols over `shape`, broadcast against the u32[L]
    prime table `qs` — the limb axis is never looped (and never drawn)."""
    t = jax.random.randint(key, shape, 0, 3)[..., None, :]  # 0,1,2 ~ {-1,0,1}
    qm1 = (jnp.asarray(qs) - np.uint32(1))[:, None]         # [L, 1]
    r = jnp.where(t == 0, qm1,
                  jnp.where(t == 1, np.uint32(0), np.uint32(1)))
    return r.astype(jnp.uint32)  # [..., L, N]


def _gaussian_residues(key, shape, qs, sigma: float):
    """Discrete-gaussian residues u32[..., L, N]: one normal draw over
    `shape`, centered-reduced against each limb prime."""
    e = jnp.rint(float(sigma) * jax.random.normal(key, shape)) \
        .astype(jnp.int32)
    return _ref.mod_reduce_centered(e[..., None, :],
                                    jnp.asarray(qs)[:, None])  # [..., L, N]


# ---------------------------------------------------------------------------
# per-chunk seed-derivation registry (wire-v2 derive ids, DESIGN.md §9.2)
# ---------------------------------------------------------------------------
#
# A seeded ciphertext's public c1 = a stream is expanded per chunk from a
# base PRNG key; the DERIVE id carried by wire-v2 SEEDED_CIPHERTEXT frames
# names HOW chunk i's key is derived from (base, i).  Both sides — client
# encrypt (here and in sharded.py) and server expand_a_rows — dispatch
# through this registry, so adding an algorithm is one entry.  Only the
# public a stream is derive-governed; the secret noise stream always uses
# fold_in (it never crosses the wire).

DERIVE_FOLD_CHUNK = 1    # chunk i's key = fold_in(base, i)
DERIVE_CTR = 2           # chunk i's key = [h_hi, h_lo + i], h = one
                         # fold_in hash of the base key (counter mode)

# DERIVE_CTR domain-separation tag: every counter stream starts from
# fold_in(base, _CTR_TAG), so base keys that differ in ANY bit map to
# unrelated counter blocks (PRNGKey(s) and PRNGKey(s+1) differ only in
# the low word — without the hash their streams would be shifted copies).
_CTR_TAG = 0x435452      # "CTR"


def _fold_chunk_keys(base, start, count: int):
    """DERIVE_FOLD_CHUNK: key for chunk i is fold_in(base, i) with i the
    GLOBAL chunk index, so any contiguous slice of the chunk axis can
    re-derive exactly its own keys — the property that lets the sharded
    engine split the batch across the `data` mesh axis without changing a
    single sampled bit (DESIGN.md §9).  `start` may be a traced offset
    (the sharded client passes axis_index * b_loc)."""
    ids = jnp.asarray(start) + jnp.arange(count)
    return jax.vmap(lambda i: jax.random.fold_in(base, i))(ids)


def _ctr_keys(base, start, count: int):
    """DERIVE_CTR: ONE fold_in hash of the base key (domain separation,
    `_CTR_TAG`), then chunk i's key is the raw uint32[2] counter block
    [h_hi, h_lo + i] over the hashed words (wrap is mod 2^32, matching the
    u32 wire id space).  Still cheaper than a fold_in chain — one hash per
    STREAM, not per chunk — and equally shard-invariant: the counter is
    the GLOBAL chunk index.

    The up-front hash is load-bearing: callers key streams from SEQUENTIAL
    seeds (fl.client.uplink_a_seed packs rnd*1_000_003 + cid, so adjacent
    clients' base keys differ only in the low word).  Counting over the
    RAW words would make client cid's chunk i+1 key equal client cid+1's
    chunk i key — the same uniform `a` (and pad) row reused across
    different ciphertexts, the exact a_seed-reuse leak the seeded-path
    docstrings warn about.  Hashing first maps nearby seeds to unrelated
    counter blocks, so cross-stream collisions need a ~2^-64 birthday
    coincidence instead of mere adjacency."""
    h = jnp.asarray(jax.random.fold_in(base, _CTR_TAG), dtype=jnp.uint32)
    ctr = jnp.asarray(start, jnp.uint32) + jnp.arange(count,
                                                      dtype=jnp.uint32)
    hi = jnp.broadcast_to(h[0], ctr.shape)
    return jnp.stack([hi, h[1] + ctr], axis=-1)


DERIVE_KEYFNS = {DERIVE_FOLD_CHUNK: _fold_chunk_keys,
                 DERIVE_CTR: _ctr_keys}
DERIVES = tuple(sorted(DERIVE_KEYFNS))


def derive_chunk_keys(base, start, count: int,
                      derive: int = DERIVE_FOLD_CHUNK):
    """Per-chunk PRNG keys for ciphertext chunks [start, start+count),
    derived by the registered algorithm `derive`.  Unknown ids raise the
    actionable registry error (the wire layer re-raises it as WireError)."""
    fn = DERIVE_KEYFNS.get(derive)
    if fn is None:
        raise ValueError(
            f"unknown seed-derivation id {derive}; this build implements "
            f"{DERIVES} (DESIGN.md §9.2)")
    return fn(base, start, count)


def _chunk_keys(key, start, count: int):
    """Noise-stream chunk keys: always fold_in (never wire-negotiated)."""
    return _fold_chunk_keys(key, start, count)


def _uniform_residues(key, shape, qs):
    """Uniform residues u32[..., L, N]: ONE randint draw of the full
    [..., L, N] block with the per-limb prime table as broadcast maxval.

    Unlike the other samplers, the draw shape includes L, so the stream
    depends on the limb count: sharded keygen draws the FULL table on every
    shard and slices its local limbs (see sharded.py) to stay bit-identical.
    """
    qs = jnp.asarray(qs, dtype=jnp.uint32)
    full = shape[:-1] + (qs.shape[0], shape[-1])
    return jax.random.randint(key, full, jnp.uint32(0), qs[:, None],
                              dtype=jnp.uint32)


# ---------------------------------------------------------------------------
# key generation
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("ctx", "token"))
def _keygen_graph(ctx: CkksContext, token, key):
    k_s, k_a, k_e = jax.random.split(key, 3)
    n = ctx.n_poly
    qs = ctx.tables.qs
    s = ops.ntt_fwd(_ternary_residues(k_s, (n,), qs), ctx)        # [L, N]
    s_mont = ops.to_mont(s, ctx)
    a = _uniform_residues(k_a, (n,), qs)                          # NTT domain
    e = ops.ntt_fwd(_gaussian_residues(k_e, (n,), qs, ctx.error_sigma), ctx)
    a_s = ops.mont_mul(a, s_mont, ctx)
    pk0 = ops.mod_add(ops.mod_neg(a_s, ctx), e, ctx)
    return s_mont, ops.to_mont(pk0, ctx), ops.to_mont(a, ctx)


def keygen(ctx: CkksContext, key) -> tuple[dict, dict]:
    """Returns (sk, pk) — one jitted graph.

    sk = {"s_mont": u32[L, N]}           NTT-domain Montgomery secret
    pk = {"pk0_mont", "pk1_mont": u32[L, N]}  b = -(a s) + e, a
    """
    s_mont, pk0_mont, pk1_mont = _keygen_graph(ctx, ops.backend_token(), key)
    return {"s_mont": s_mont}, {"pk0_mont": pk0_mont, "pk1_mont": pk1_mont}


# ---------------------------------------------------------------------------
# encrypt / decrypt
# ---------------------------------------------------------------------------

def _encrypt_body(ctx: CkksContext, pk0_mont, pk1_mont, m_coeff, key,
                  chunk_start: int = 0):
    """Shared trace of the public-key encrypt graph (m_coeff already
    coefficient-domain residues).

    Chunk i's (u, e0, e1) draws come from split(fold_in(key, i), 3) — one
    (N,)-shaped draw per chunk, never a (B, N) batch draw — so the stream
    only depends on each chunk's global index, not on how many chunks this
    trace happens to hold.  `chunk_start` offsets the global ids; the
    sharded engine passes each shard's row offset and gets bit-identical
    ciphertexts (DESIGN.md §9)."""
    b = m_coeff.shape[0]
    n = ctx.n_poly
    qs = ctx.tables.qs
    sigma = ctx.error_sigma
    k3 = jax.vmap(lambda k: jax.random.split(k, 3))(
        _chunk_keys(key, chunk_start, b))                    # [B, 3] keys
    m = ops.ntt_fwd(m_coeff, ctx)
    u = ops.ntt_fwd(jax.vmap(
        lambda k: _ternary_residues(k, (n,), qs))(k3[:, 0]), ctx)
    e0 = ops.ntt_fwd(jax.vmap(
        lambda k: _gaussian_residues(k, (n,), qs, sigma))(k3[:, 1]), ctx)
    e1 = ops.ntt_fwd(jax.vmap(
        lambda k: _gaussian_residues(k, (n,), qs, sigma))(k3[:, 2]), ctx)
    c0 = ops.mul_add(u, pk0_mont[None], ops.mod_add(e0, m, ctx), ctx)
    c1 = ops.mul_add(u, pk1_mont[None], e1, ctx)
    return jnp.stack([c0, c1], axis=-2)


@functools.partial(jax.jit, static_argnames=("ctx", "token"))
def _encrypt_graph(ctx: CkksContext, token, pk0_mont, pk1_mont, m_coeff, key):
    return _encrypt_body(ctx, pk0_mont, pk1_mont, m_coeff, key)


@functools.partial(jax.jit, static_argnames=("ctx", "token"))
def _encrypt_values_graph(ctx: CkksContext, token, pk0_mont, pk1_mont,
                          values, key):
    """Encode (length-2N FFT) + encrypt as ONE jitted dispatch: a client
    update goes weights -> ciphertext without leaving the graph."""
    return _encrypt_body(ctx, pk0_mont, pk1_mont,
                         encoding.encode_jnp(values, ctx), key)


def encrypt_coeffs(ctx: CkksContext, pk: dict, m_coeff, key,
                   scale: float | None = None) -> Ciphertext:
    """Public-key encryption of pre-encoded residues.

    Args:
        ctx: CkksContext.
        pk: {"pk0_mont", "pk1_mont": u32[L, N]} public key (Montgomery,
            NTT domain).
        m_coeff: u32[B, L, N] coefficient-domain residues (from encode).
        key: jax PRNG key for the (u, e0, e1) draws.
        scale: encoding scale of m_coeff (default ctx.delta).

    Returns:
        Ciphertext with data u32[B, L, 2, N]; sampling, NTTs and the two
        mul_adds run as one jitted graph.
    """
    scale = float(scale if scale is not None else ctx.delta)
    data = _encrypt_graph(ctx, ops.backend_token(), pk["pk0_mont"],
                          pk["pk1_mont"], m_coeff, key)
    return Ciphertext(data=data, scale=scale)


def encrypt_values(ctx: CkksContext, pk: dict, values, key) -> Ciphertext:
    """values: f32[B, slots] -> fresh ciphertext.

    The canonical-embedding encode FFT is folded into the same jitted
    graph as the encrypt sampling/NTTs — one dispatch end to end.
    """
    data = _encrypt_values_graph(ctx, ops.backend_token(), pk["pk0_mont"],
                                 pk["pk1_mont"], values, key)
    return Ciphertext(data=data, scale=float(ctx.delta))


def expand_a_rows(ctx: CkksContext, a_seed: int, start: int, count: int,
                  derive: int = DERIVE_FOLD_CHUNK):
    """Deterministic uniform `a` rows [start, start+count) from a public seed.

    Row i is expanded from derive_chunk_keys(PRNGKey(a_seed), ...)[i] —
    the wire-negotiated derive algorithm — so a receiver can regenerate any
    single chunk independently (streaming ingest never needs the whole
    batch).  Returns u32[count, L, N] in NTT domain (uniform residues are
    uniform in either domain; both sides just agree on this convention,
    matching keygen's treatment of `a`).
    """
    base = jax.random.PRNGKey(int(a_seed))
    keys = derive_chunk_keys(base, start, count, derive)
    return jax.vmap(
        lambda k: _uniform_residues(k, (ctx.n_poly,), ctx.tables.qs))(keys)
    # [count, L, N]


def expand_a(ctx: CkksContext, a_seed: int, batch: int,
             derive: int = DERIVE_FOLD_CHUNK):
    """Full-batch `a` expansion (rows 0..batch-1)."""
    return expand_a_rows(ctx, a_seed, 0, batch, derive)


def encrypt_coeffs_seeded(ctx: CkksContext, sk: dict, m_coeff, key,
                          a_seed: int, scale: float | None = None,
                          derive: int = DERIVE_FOLD_CHUNK) -> Ciphertext:
    """Secret-key encryption with seed-expandable c1 (uplink compression).

    ct = (c0, c1) with c1 = a = PRG(a_seed) and c0 = -(a s) + e + m, so the
    wire only needs (a_seed, c0) — half the fresh-ciphertext bytes.  Chunk
    b's c1 row expands per the wire-v2 `derive` algorithm (the registry
    above; DESIGN.md §9.2), matched bit for bit by expand_a_rows and by the
    sharded client.  The decryption identity c0 + c1 s = m + e matches the
    public-key path, so seeded and pk ciphertexts mix freely under the
    homomorphic ops.  `a_seed` must be unique per (client, round); reuse
    leaks m1 - m2.
    """
    scale = float(scale if scale is not None else ctx.delta)
    # PRNGKey is built host-side: a_seed is 64-bit on the wire, and the key
    # must match the server-side expand_a_rows stream exactly
    a_base = jax.random.PRNGKey(int(a_seed))
    data = _encrypt_seeded_graph(ctx, ops.backend_token(), sk["s_mont"],
                                 m_coeff, key, a_base, int(derive))
    return Ciphertext(data=data, scale=scale)


@functools.partial(jax.jit, static_argnames=("ctx", "token", "derive"))
def _encrypt_seeded_graph(ctx: CkksContext, token, s_mont, m_coeff, key,
                          a_base, derive: int = DERIVE_FOLD_CHUNK):
    return _seeded_body_from_coeffs(ctx, s_mont, m_coeff, key, a_base,
                                    derive=derive)


def _seeded_body_from_coeffs(ctx, s_mont, m_coeff, key, a_base,
                             chunk_start: int = 0,
                             derive: int = DERIVE_FOLD_CHUNK):
    """Shared trace of the seeded secret-key encrypt graph.

    Both streams are per-chunk (wire-v2 derivation, DESIGN.md §9):
      c1 chunk i = uniform from derive_chunk_keys(a_base, ...)[i] — public,
          matches the server-side expand_a_rows regeneration for the SAME
          derive id;
      e  chunk i = gaussian from fold_in(key, i)    — secret noise, one
          (N,) draw per chunk so the stream is chunk-shard-invariant (the
          noise stream never crosses the wire, so it is not derive-
          negotiated).
    """
    b = m_coeff.shape[0]
    n = ctx.n_poly
    qs = ctx.tables.qs
    sigma = ctx.error_sigma
    m = ops.ntt_fwd(m_coeff, ctx)
    a = jax.vmap(lambda k: _uniform_residues(k, (n,), qs))(
        derive_chunk_keys(a_base, chunk_start, b, derive))   # [B, L, N]
    e = ops.ntt_fwd(jax.vmap(
        lambda k: _gaussian_residues(k, (n,), qs, sigma))(
            _chunk_keys(key, chunk_start, b)), ctx)
    a_s = ops.mont_mul(a, s_mont[None], ctx)
    c0 = ops.mod_add(ops.mod_neg(a_s, ctx), ops.mod_add(e, m, ctx), ctx)
    return jnp.stack([c0, a], axis=-2)


@functools.partial(jax.jit, static_argnames=("ctx", "token", "derive"))
def _encrypt_seeded_values_graph(ctx: CkksContext, token, s_mont, values,
                                 key, a_base,
                                 derive: int = DERIVE_FOLD_CHUNK):
    return _seeded_body_from_coeffs(ctx, s_mont,
                                    encoding.encode_jnp(values, ctx), key,
                                    a_base, derive=derive)


def encrypt_values_seeded(ctx: CkksContext, sk: dict, values, key,
                          a_seed: int,
                          derive: int = DERIVE_FOLD_CHUNK) -> Ciphertext:
    """f32[B, slots] -> seeded secret-key ciphertext in ONE dispatch.

    Same wire convention as encrypt_coeffs_seeded (c1 = PRG(a_seed),
    per-chunk expansion by the negotiated `derive` id); the encode FFT runs
    inside the jitted graph.  ShardedHe.encrypt_values_seeded is the
    multi-chip version and produces identical bits.
    """
    a_base = jax.random.PRNGKey(int(a_seed))
    data = _encrypt_seeded_values_graph(ctx, ops.backend_token(),
                                        sk["s_mont"], values, key, a_base,
                                        int(derive))
    return Ciphertext(data=data, scale=float(ctx.delta))


def drop_limbs(ctx: CkksContext, ct: Ciphertext, keep: int) -> Ciphertext:
    """Rescale away trailing RNS limbs until only `keep` remain.

    Lossy downlink compression: each dropped limb divides the scale by that
    limb's prime, trading ~log2(q) bits of plaintext precision for a
    (L-keep)/L cut in ciphertext bytes.  decode must go through the
    any-limb-count np path when keep < 2.
    """
    assert 1 <= keep <= ct.n_limbs
    while ct.n_limbs > keep:
        ct = rescale(ctx, ct)
    return ct


@functools.partial(jax.jit, static_argnames=("ctx", "token"))
def _decrypt_graph(ctx: CkksContext, token, s_mont, data):
    c0 = data[..., 0, :]
    c1 = data[..., 1, :]
    phase = ops.mul_add(c1, s_mont[None], c0, ctx)
    return ops.ntt_inv(phase, ctx)


def decrypt_to_coeffs(ctx: CkksContext, sk: dict, ct: Ciphertext):
    """-> u32[B, L, N] coefficient-domain residues of m + noise — one jitted
    graph.  Handles rescaled ciphertexts (fewer limbs than the context)."""
    s = sk["s_mont"][: ct.n_limbs]
    return _decrypt_graph(ctx, ops.backend_token(), s, ct.data)


def decrypt_values(ctx: CkksContext, sk: dict, ct: Ciphertext):
    """-> f32[B, slots] (jnp decode path, 2-limb)."""
    return encoding.decode_jnp(decrypt_to_coeffs(ctx, sk, ct), ctx, ct.scale)


def decrypt_values_np(ctx: CkksContext, sk: dict, ct: Ciphertext) -> np.ndarray:
    """High-precision host decode (any limb count)."""
    coeffs = np.asarray(decrypt_to_coeffs(ctx, sk, ct))
    return encoding.decode_np(coeffs, ctx, ct.scale)


# ---------------------------------------------------------------------------
# homomorphic ops
# ---------------------------------------------------------------------------

def _limbs_to_minus2(data):
    """[..., L, 2, N] -> [..., 2, L, N]: ops.* helpers broadcast per-limb
    constants over axis -2, so the limb axis must sit there."""
    return jnp.moveaxis(data, -3, -2)


def _limbs_to_minus3(data):
    return jnp.moveaxis(data, -2, -3)


def add(ctx: CkksContext, a: Ciphertext, b: Ciphertext) -> Ciphertext:
    assert abs(a.scale - b.scale) < 1e-6 * a.scale
    out = ops.mod_add(_limbs_to_minus2(a.data), _limbs_to_minus2(b.data), ctx)
    return Ciphertext(data=_limbs_to_minus3(out), scale=a.scale)


def mul_plain_scalar(ctx: CkksContext, ct: Ciphertext, w: float) -> Ciphertext:
    """ct x plaintext scalar (encoded at delta): one multiplicative depth."""
    w_mont = encoding.encode_scalar_residues(w, ctx)   # u32[L]
    wb = jnp.asarray(w_mont)[:, None]                  # [L, N->bcast]
    out = ops.mont_mul(_limbs_to_minus2(ct.data), wb, ctx)
    return Ciphertext(data=_limbs_to_minus3(out), scale=ct.scale * ctx.delta)


def mul_plain_vec(ctx: CkksContext, ct: Ciphertext, pt_mont) -> Ciphertext:
    """ct x plaintext vector; pt_mont: u32[L, N] NTT-domain Montgomery."""
    out = ops.mont_mul(_limbs_to_minus2(ct.data), pt_mont, ctx)
    return Ciphertext(data=_limbs_to_minus3(out), scale=ct.scale * ctx.delta)


@functools.partial(jax.jit, static_argnames=("ctx", "token"))
def _weighted_sum_graph(ctx: CkksContext, token, data, w_mont):
    # fold the (c0,c1) component axis into batch: [C, ..., L, 2, N] ->
    # [C, ..., 2, L, N] so the kernel sees limbs at axis -2.
    x = jnp.moveaxis(data, -3, -2)
    out = ops.weighted_sum(x, w_mont, ctx)
    return jnp.moveaxis(out, -2, -3)


def weighted_sum(ctx: CkksContext, cts: Ciphertext, weights) -> Ciphertext:
    """Fused FedAvg aggregation: sum_i w_i * ct_i over the leading axis.

    cts.data: u32[C, ..., L, 2, N]; weights: python floats len C.
    One jitted graph over the fused kernel (single pass over client
    ciphertexts, all limbs in one launch).
    """
    w_mont = encoding.encode_weights_mont(weights, ctx)          # [C, L]
    data = _weighted_sum_graph(ctx, ops.backend_token(), cts.data,
                               jnp.asarray(w_mont))
    return Ciphertext(data=data, scale=cts.scale * ctx.delta)


def rescale(ctx: CkksContext, ct: Ciphertext) -> Ciphertext:
    """Drop the last RNS limb: c'_j = (c_j - lift(c_last)) * q_last^{-1} mod q_j.

    Needs a domain switch for the last limb (iNTT under q_last, re-NTT under
    each remaining q_j) because NTT evaluation points differ per prime.  The
    remaining-limb axis is vectorized via the fused engine — the per-limb
    lift constants are u32[L-1] host tables broadcast into the graph.
    """
    l = ct.n_limbs
    assert l >= 2
    q_last = ctx.primes[l - 1]
    lc_last = ctx.limbs[l - 1]
    t = ctx.tables.take(l - 1)
    # last limb to coefficient domain (exact)
    c_last_ntt = ct.data[..., l - 1, :, :]
    flat = c_last_ntt.reshape((-1, ctx.n_poly))
    c_last = _ref.ntt_inv(flat, jnp.asarray(lc_last.psi_inv_rev_mont),
                          np.asarray(lc_last.n_inv_mont),
                          np.uint32(q_last), np.uint32(lc_last.qinv_neg))
    # centered lift of v in [0, q_last) into each Z_qj: primes are within 2x
    # of each other, so v mod qj needs at most one conditional subtract.
    qjs = t.qs[:, None]                                         # [L-1, 1]
    v = c_last[..., None, :]                                    # [B, 1, N]
    need_sub = (np.uint32(q_last) > t.qs)[:, None]              # [L-1, 1]
    v_mod = jnp.where(need_sub & (v >= qjs), v - qjs, v)
    half = np.uint32(q_last // 2)
    q_last_mod = (np.uint32(q_last) % t.qs)[:, None]            # [L-1, 1]
    lifted = jnp.where(jnp.broadcast_to(v > half, v_mod.shape),
                       ops.mod_sub(v_mod, q_last_mod, ctx), v_mod)
    lifted_ntt = ops.ntt_fwd(lifted, ctx)                       # [B, L-1, N]
    cj = jnp.moveaxis(ct.data[..., : l - 1, :, :], -3, -2)      # [..., 2, L-1, N]
    cj = cj.reshape((-1, l - 1, ctx.n_poly))
    diff = ops.mod_sub(cj, lifted_ntt, ctx)
    inv_mont = np.asarray([pow(q_last, -1, int(qj)) * (1 << 32) % int(qj)
                           for qj in t.qs], dtype=np.uint32)[:, None]
    out = ops.mont_mul(diff, inv_mont, ctx)
    data = jnp.moveaxis(
        out.reshape(ct.data.shape[:-3] + (2, l - 1, ctx.n_poly)), -2, -3)
    return Ciphertext(data=data, scale=ct.scale / q_last)
