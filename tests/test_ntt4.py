"""4-step transpose NTT (backend "pallas4"): exact bit-identity with the
ref oracle and the flat pallas kernel across the acceptance grid
N in {4096, 8192, 16384} x L in {1, 2, 3}, both directions, single-device
and 1/2/4-device limb-sharded meshes (interpret mode).

The sharded cases route through the same `ops.apply` + per-shard-table
shard_map plumbing the engine uses (core/ckks/sharded.py), so they cover
the new ntt4_* table fields riding the limb axis.  conftest.py forces 4
host devices, so every mesh case runs under plain tier-1.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.ckks import params as ckks_params
from repro.core.ckks import sharded as sh
from repro.kernels import ntt, ops, ref
from repro.launch.mesh import make_he_mesh

import gold

_NS = (4096, 8192, 16384)
_LS = (1, 2, 3)


@pytest.fixture(scope="module")
def ctxs():
    return {(n, l): ckks_params.make_context(n_poly=n, n_limbs=l,
                                             delta_bits=12 if l == 1 else 26)
            for n in _NS for l in _LS}


def _rand(ctx, batch, seed):
    rng = np.random.RandomState(seed)
    return jnp.asarray(ref.rand_limbed_np(rng, ctx, (batch,)))


def test_ntt4_split_shapes():
    assert ckks_params.ntt4_split(4096) == (64, 64)
    assert ckks_params.ntt4_split(8192) == (64, 128)
    assert ckks_params.ntt4_split(16384) == (128, 128)
    for n in (64, 256, 1024, 8192):
        n1, n2 = ckks_params.ntt4_split(n)
        assert n1 * n2 == n and n1 <= n2 <= 2 * n1


def test_ntt4_matches_quadratic_gold():
    """The 4-step output against the O(N^2) textbook model — independent of
    both the flat kernel and the jnp ref."""
    ctx = ckks_params.make_test_context(n_poly=64, n_limbs=2)
    t = ctx.tables
    lc = ctx.limbs[0]
    psi = ckks_params.root_of_unity(lc.q, 128)
    rng = np.random.RandomState(3)
    x = rng.randint(0, lc.q, size=(2, 64)).astype(np.uint32)
    xl = jnp.asarray(np.stack([x, x], axis=-2))          # [2, L=2, 64]
    ours = np.asarray(ntt.ntt4_fwd_fused(
        xl, t.ntt4_psi1_mont, t.ntt4_psi2_mont, t.ntt4_corr_mont, t.qs,
        t.qinv_negs, interpret=True))[:, 0, :]
    g = np.stack([gold.gold_ntt(x[i], lc.q, psi) for i in range(2)])
    np.testing.assert_array_equal(ours, g)


@pytest.mark.parametrize("n_limbs", _LS)
@pytest.mark.parametrize("n_poly", _NS)
def test_ntt4_bitexact_vs_ref_and_pallas(n_poly, n_limbs, ctxs):
    """Acceptance grid, single device: fwd and inv of the 4-step kernel
    equal the ref oracle AND the flat pallas kernel, exactly."""
    ctx = ctxs[(n_poly, n_limbs)]
    t = ctx.tables
    x = _rand(ctx, 2, seed=n_poly + n_limbs)
    want_fwd = ref.ntt_fwd_fused(x, t.psi_rev_mont, t.qs, t.qinv_negs)
    got_fwd = ntt.ntt4_fwd_fused(x, t.ntt4_psi1_mont, t.ntt4_psi2_mont,
                                 t.ntt4_corr_mont, t.qs, t.qinv_negs,
                                 interpret=True, block_b=2)
    np.testing.assert_array_equal(np.asarray(got_fwd), np.asarray(want_fwd))
    flat_fwd = ntt.ntt_fwd_fused(x, t.psi_rev_mont, t.qs, t.qinv_negs,
                                 interpret=True, block_b=2)
    np.testing.assert_array_equal(np.asarray(got_fwd), np.asarray(flat_fwd))

    want_inv = ref.ntt_inv_fused(want_fwd, t.psi_inv_rev_mont, t.n_inv_monts,
                                 t.qs, t.qinv_negs)
    got_inv = ntt.ntt4_inv_fused(got_fwd, t.ntt4_psi1_inv_mont,
                                 t.ntt4_psi2_inv_mont, t.ntt4_corr_inv_mont,
                                 t.n_inv_monts, t.qs, t.qinv_negs,
                                 interpret=True, block_b=2)
    np.testing.assert_array_equal(np.asarray(got_inv), np.asarray(want_inv))
    np.testing.assert_array_equal(np.asarray(got_inv), np.asarray(x))


def _sharded_ntt(ctx, mesh, x, op):
    """One shard_map dispatch of `op` with per-shard table slices — the
    engine's exact plumbing (limbs -> model axis, chunks -> data axis)."""
    def body(x, *tabs):
        return ops.apply(op, sh.local_tables(tabs), x)

    f = shard_map(body, mesh=mesh,
                  in_specs=(P("data", "model", None),)
                  + sh.table_specs("model"),
                  out_specs=P("data", "model", None), check_rep=False)
    return f(x, *sh.table_arrays(ctx.tables))


@pytest.mark.parametrize("n_dev", [1, 2, 4])
@pytest.mark.parametrize("n_limbs", _LS)
@pytest.mark.parametrize("n_poly", _NS)
def test_ntt4_bitexact_sharded_mesh(n_poly, n_limbs, n_dev, ctxs):
    """Acceptance grid, 1/2/4-device meshes: the pallas4 NTT ops dispatched
    inside shard_map (per-shard ntt4_* tables) are bit-identical to the
    single-device ref, both directions."""
    if jax.device_count() < n_dev:
        pytest.skip(f"needs {n_dev} host devices, have {jax.device_count()}")
    ctx = ctxs[(n_poly, n_limbs)]
    mesh = make_he_mesh(n_limbs, n_dev)
    t = ctx.tables
    x = _rand(ctx, 4, seed=7 * n_poly + n_limbs + n_dev)
    old = {op: ops.get_backend(op) for op in ops.OPS}
    try:
        ops.set_backend("pallas4")
        got_fwd = _sharded_ntt(ctx, mesh, x, "ntt_fwd")
        want_fwd = ref.ntt_fwd_fused(x, t.psi_rev_mont, t.qs, t.qinv_negs)
        np.testing.assert_array_equal(np.asarray(got_fwd),
                                      np.asarray(want_fwd))
        got_inv = _sharded_ntt(ctx, mesh, got_fwd, "ntt_inv")
        np.testing.assert_array_equal(np.asarray(got_inv), np.asarray(x))
    finally:
        for op, name in old.items():
            ops.set_backend(name, op=op)


def test_pallas4_registry_dispatch():
    """REPRO_HE_BACKEND=pallas4's runtime equivalent: set_backend('pallas4')
    flips the NTT family to the 4-step kernels, keeps every other op on the
    shared pallas implementation, and re-keys backend_token()."""
    ctx = ckks_params.make_test_context(n_poly=128, n_limbs=2)
    x = _rand(ctx, 3, seed=11)
    old = {op: ops.get_backend(op) for op in ops.OPS}
    try:
        ops.set_backend("ref")
        tok_ref = ops.backend_token()
        want = ops.ntt_fwd(x, ctx)
        ops.set_backend("pallas4")
        assert ops.get_backend() == "pallas4"
        assert ops.backend_token() != tok_ref
        got = ops.ntt_fwd(x, ctx)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        np.testing.assert_array_equal(
            np.asarray(ops.ntt_inv(got, ctx)), np.asarray(x))
        # per-op: only the NTTs have a distinct pallas4 implementation
        assert ops._IMPL["weighted_sum"]["pallas4"] \
            is ops._IMPL["weighted_sum"]["pallas"]
        assert ops._IMPL["ntt_fwd"]["pallas4"] \
            is not ops._IMPL["ntt_fwd"]["pallas"]
    finally:
        for op, name in old.items():
            ops.set_backend(name, op=op)


@pytest.mark.parametrize("n_limbs", [1, 2, 3])
def test_ntt4_limb_dropped_tables(n_limbs):
    """take(l) slices the ntt4_* tables consistently: a limb-dropped input
    through pallas4 matches ref on the same slice."""
    ctx = ckks_params.make_test_context(
        n_poly=256, n_limbs=3, delta_bits=12)
    t = ctx.tables.take(n_limbs)
    rng = np.random.RandomState(n_limbs)
    x = jnp.asarray(ref.rand_limbed_np(rng, ctx, (2,))[:, :n_limbs])
    got = ntt.ntt4_fwd_fused(x, t.ntt4_psi1_mont, t.ntt4_psi2_mont,
                             t.ntt4_corr_mont, t.qs, t.qinv_negs,
                             interpret=True)
    want = ref.ntt_fwd_fused(x, t.psi_rev_mont, t.qs, t.qinv_negs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
