"""Paper-scale selective encryption, end to end (ROADMAP tier-0 item).

Drives a real LM fine-tune through the FULL selective pipeline at each
selection ratio p:

  client local_train (AdamW) -> per-client sensitivity map
  (core/sensitivity.py jvp estimator) -> HE mask agreement
  (secure_agg.agree_sensitivity + selection.build_mask; both the global
  `top_p` selector and the paper's `recipe`) -> packing.MaskPartition ->
  seeded uplink ciphertext chunks + int8 plain partition as wire frames
  (wire/stream.py) -> sharded streaming aggregation (StreamIngest over a
  ShardedHe mesh) -> decrypt + merge_by_mask recovery

measuring per-client uplink bytes, ciphertext count, and
encrypt/aggregate/decrypt wall time, each normalized against the p=1.0
encrypt-everything row — the paper's overhead-reduction curve (Table 7 /
Figure 7, the ~10x ResNet-50 / ~40x BERT claim) as a checked-in benchmark,
with a param-count extrapolation to those scales.

  PYTHONPATH=src python -m benchmarks.run selective           # full sweep,
      writes BENCH_selective.json (repo root)
  PYTHONPATH=src python -m benchmarks.run selective --smoke   # one tiny
      model, p in {0.1, 1.0}, asserts pipeline invariants, no artifacts
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

# paper-headline model scales for the closed-form wire extrapolation
PAPER_SCALES = {"bert-base": 110_000_000, "resnet-50": 25_600_000}
P_SWEEP = (0.05, 0.1, 0.3, 0.5, 1.0)
P_SMOKE = (0.1, 1.0)
PLAIN_CODEC = "i8"


def model_cfgs(smoke: bool) -> list[tuple[str, object]]:
    """(label, ModelConfig) pairs: the smoke transformer plus — in full
    mode — the largest config that fits CI wall clock (~1.3M params)."""
    from repro import configs

    base = configs.get_config("qwen1.5-0.5b", smoke=True)
    small = ("qwen-smoke", dataclasses.replace(base, vocab=512))
    if smoke:
        return [small]
    big = ("qwen-1m", dataclasses.replace(
        base, d_model=128, n_layers=4, n_heads=4, n_kv_heads=4, d_ff=512,
        vocab=1024))
    return [small, big]


def make_clients(cfg, n_clients: int = 2, seed: int = 0):
    """Build the model + FL clients over synthetic non-IID LM streams."""
    from repro.data import make_client_streams
    from repro.fl import ClientConfig, FLClient
    from repro.models import build_model

    model = build_model(cfg)
    streams = make_client_streams(n_clients, cfg.vocab, seq_len=32,
                                  batch_size=2, seed=seed)
    clients = [FLClient(i, model, streams[i],
                        ClientConfig(local_steps=2, sensitivity_probes=2))
               for i in range(n_clients)]
    return model, clients


def fine_tune_and_sense(cfg, n_clients: int = 2, seed: int = 0):
    """One real local fine-tune step per client + jvp sensitivity maps.

    Returns a dict with the global init, per-client locally-trained
    parameter pytrees, per-client sensitivity maps, FedAvg weights, and
    mean local loss — the client-side half of the pipeline, shared by the
    bench and examples/selective_encryption_sweep.py.
    """
    import jax
    import numpy as np

    model, clients = make_clients(cfg, n_clients=n_clients, seed=seed)
    g0 = model.init(jax.random.PRNGKey(seed))
    local_params, losses = [], []
    for c in clients:
        p_i, loss = c.local_train(g0)
        local_params.append(p_i)
        losses.append(loss)
    sens_maps = [c.sensitivity_map(g0) for c in clients]
    w = np.asarray([max(1, c.n_samples) for c in clients], dtype=np.float64)
    return {
        "model": model, "clients": clients, "global_params": g0,
        "local_params": local_params, "sens_maps": sens_maps,
        "weights": (w / w.sum()).tolist(), "loss": float(np.mean(losses)),
    }


def _frame_bytes(blob: bytes) -> tuple[int, int, int]:
    """-> (ciphertext-chunk bytes, plain-segment bytes, total bytes) of one
    update blob, split by frame type (envelope included)."""
    from repro.wire import format as wf

    ct_b = plain_b = 0
    off = 0
    while off < len(blob):
        ftype, _, payload, off2 = wf.parse_frame(blob, off)
        nb = off2 - off
        if ftype == wf.T_CT_CHUNK:
            ct_b += nb
        elif ftype == wf.T_PLAIN_SEGMENT:
            plain_b += nb
        off = off2
    return ct_b, plain_b, len(blob)


def run_selective(smoke: bool = False) -> dict:
    """The sweep driver.  Returns (and in full mode writes) the
    BENCH_selective.json document."""
    import jax
    import numpy as np

    from benchmarks.run import _rows
    from repro import obs
    from repro.core import packing, secure_agg, selection
    from repro.core.ckks import cipher
    from repro.core.ckks import params as ckks_params
    from repro.core.ckks.sharded import ShardedHe
    from repro.core.secure_agg import AggregatorConfig, SelectiveHEAggregator
    from repro.launch.mesh import make_he_mesh
    from repro.wire import compress as wire_compress
    from repro.wire import stream as ws

    ps = P_SMOKE if smoke else P_SWEEP
    ctx = ckks_params.make_context(n_poly=512 if smoke else 2048, n_limbs=2,
                                   delta_bits=24)
    mesh = make_he_mesh(ctx.n_limbs, len(jax.devices()))
    sharded = ShardedHe(ctx, mesh)
    sk, pk = cipher.keygen(ctx, jax.random.PRNGKey(0))

    doc = {
        "bench": "selective",
        "provenance": obs.provenance(),
        "ctx": {"n_poly": ctx.n_poly, "n_limbs": ctx.n_limbs,
                "delta_bits": ctx.delta_bits, "slots": ctx.slots},
        "devices": len(jax.devices()),
        "mesh": {"data": int(mesh.shape["data"]),
                 "model": int(mesh.shape["model"])},
        "plain_codec": PLAIN_CODEC,
        "uplink": "seeded sk-encrypt ciphertext chunks (wire v2)",
        "models": [],
        "extrapolation": [],
    }

    for label, cfg in model_cfgs(smoke):
        task = fine_tune_and_sense(cfg)
        g0 = task["global_params"]
        weights = task["weights"]
        spec = packing.make_flat_spec(g0)
        n_params = spec.total

        # stage 2 — HE mask agreement: aggregate the local maps ONCE under
        # encryption; every (strategy, p) mask below derives from the same
        # decrypted global map (what agree_mask does per call)
        t0 = time.perf_counter()
        s_glob = secure_agg.agree_sensitivity(
            ctx, pk, sk, task["sens_maps"], weights, jax.random.PRNGKey(7))
        mask_agree_s = time.perf_counter() - t0

        vecs = [np.asarray(packing.flatten_params(p_i)[0])
                for p_i in task["local_params"]]
        expect = sum(w * v for w, v in zip(weights, vecs))

        cases = [("top_p", p) for p in ps]
        cases.append(("recipe", 0.1 if smoke else 0.3))  # paper's recipe pt
        rows = []
        for strategy, p in cases:
            mask = selection.build_mask(s_glob, strategy, p,
                                        offsets=spec.offsets,
                                        sizes=spec.sizes)
            part = packing.make_partition(mask, ctx.slots)
            agg = SelectiveHEAggregator(
                ctx, spec, part,
                AggregatorConfig(p_ratio=p, strategy=strategy))

            def protect(i: int):
                a_seed = 1_000_003 + i
                upd = agg.client_protect_seeded(
                    task["local_params"][i], sk,
                    jax.random.fold_in(jax.random.PRNGKey(3), i), a_seed,
                    sharded=sharded)
                jax.block_until_ready(upd.ct.data)
                return upd, wire_compress.seed_compress(upd.ct, a_seed)

            def aggregate():
                ing = ws.StreamIngest(ctx, sharded=sharded)
                for b, w in zip(blobs, weights):
                    ing.ingest(b, w)
                out = ing.finalize()
                jax.block_until_ready(out.ct.data)
                return out

            # warmup once (compile: chunk counts retrace per case), then one
            # timed call whose result feeds the next stage — the aggregate
            # pass at p=1.0 on the large config is too slow to repeat
            protect(0)
            t0 = time.perf_counter()
            protect(0)
            encrypt_s = time.perf_counter() - t0
            blobs = []
            for i in range(len(vecs)):
                upd, sct = protect(i)
                blobs.append(ws.pack_update_frames(
                    upd, cid=i, n_samples=max(1, task["clients"][i].n_samples),
                    rnd=0, seeded=sct, plain_codec=PLAIN_CODEC))

            aggregate()
            t0 = time.perf_counter()
            glob = aggregate()
            aggregate_s = time.perf_counter() - t0

            agg.client_recover(glob, sk)
            t0 = time.perf_counter()
            rec = jax.block_until_ready(agg.client_recover(glob, sk))
            decrypt_s = time.perf_counter() - t0
            rec = np.asarray(rec)
            err = float(np.max(np.abs(rec - expect)))
            ct_b, plain_b, total_b = _frame_bytes(blobs[0])
            rows.append({
                "strategy": strategy, "p": p,
                "n_enc": part.n_enc, "enc_ratio": part.ratio,
                "n_cts": part.n_chunks,
                "uplink_B_per_client": total_b,
                "ct_B": ct_b, "plain_B": plain_b,
                "encrypt_s": encrypt_s, "aggregate_s": aggregate_s,
                "decrypt_s": decrypt_s, "recover_err": err,
            })

        base = next(r for r in rows
                    if r["strategy"] == "top_p" and r["p"] == 1.0)
        base_time = base["encrypt_s"] + base["aggregate_s"]
        for r in rows:
            r["bytes_ratio_vs_p1"] = base["uplink_B_per_client"] \
                / max(1, r["uplink_B_per_client"])
            r["time_ratio_vs_p1"] = base_time \
                / max(1e-12, r["encrypt_s"] + r["aggregate_s"])

        doc["models"].append({
            "label": label, "family": cfg.family, "n_params": n_params,
            "n_clients": len(vecs), "local_loss": task["loss"],
            "mask_agree_s": mask_agree_s, "rows": rows,
        })
        _rows(f"selective encryption end to end: {label} "
              f"({n_params/1e3:.0f}k params, N={ctx.n_poly}, "
              f"codec {PLAIN_CODEC}, mesh {doc['mesh']['data']}x"
              f"{doc['mesh']['model']})",
              rows, keys=["strategy", "p", "n_cts", "uplink_B_per_client",
                          "encrypt_s", "aggregate_s", "decrypt_s",
                          "bytes_ratio_vs_p1", "time_ratio_vs_p1",
                          "recover_err"])

        # every row must recover the true weighted average up to the i8
        # plain-partition quantization error (the encrypted partition is
        # exact to CKKS noise, the plain one to the codec step)
        tol = 0.02 * float(np.max(np.abs(expect))) + 1e-3
        bad = [r for r in rows if r["recover_err"] > tol]
        assert not bad, f"selective recovery drifted: {bad}"

    # closed-form wire extrapolation to the paper's headline scales, using
    # the MEASURED per-chunk and per-plain-param frame costs of the last
    # (largest) model swept
    last = doc["models"][-1]["rows"]
    base = next(r for r in last if r["strategy"] == "top_p" and r["p"] == 1.0)
    chunk_B = base["ct_B"] / base["n_cts"]
    small_p = next((r for r in last if r["p"] < 1.0 and r["plain_B"] > 0),
                   None)
    plain_B_per = (small_p["plain_B"] / max(1, doc["models"][-1]["n_params"]
                                            - small_p["n_enc"])
                   if small_p else 1.0)
    ex_rows = []
    for scale, n_total in PAPER_SCALES.items():
        per_p = {}
        for p in (0.05, 0.1, 0.3, 1.0):
            n_enc = int(round(n_total * p))
            chunks = -(-n_enc // ctx.slots)
            per_p[p] = chunks * chunk_B + (n_total - n_enc) * plain_B_per
        for p, b in per_p.items():
            ex_rows.append({
                "scale": scale, "n_params": n_total, "p": p,
                "est_uplink_MB_per_client": b / 1e6,
                "bytes_ratio_vs_p1": per_p[1.0] / b,
            })
    doc["extrapolation"] = ex_rows
    _rows("wire extrapolation to paper scales (measured per-chunk / "
          "per-plain-param costs)", ex_rows)

    if smoke:
        r01 = next(r for r in doc["models"][0]["rows"]
                   if r["strategy"] == "top_p" and r["p"] == 0.1)
        assert r01["bytes_ratio_vs_p1"] > 2.0, r01
        print("[smoke OK — no artifacts written]")
        return doc

    # acceptance: >=5x reduction at p=0.1 vs p=1.0 on the larger config,
    # in both comm bytes and encrypt+aggregate wall time
    big = doc["models"][-1]["rows"]
    r01 = next(r for r in big if r["strategy"] == "top_p" and r["p"] == 0.1)
    assert r01["bytes_ratio_vs_p1"] >= 5.0, r01
    assert r01["time_ratio_vs_p1"] >= 5.0, r01

    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    out = os.path.join(root, "BENCH_selective.json")
    with open(out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"[BENCH_selective.json written: p=0.1 reduction "
          f"{r01['bytes_ratio_vs_p1']:.1f}x bytes, "
          f"{r01['time_ratio_vs_p1']:.1f}x encrypt+aggregate time]")
    return doc


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    run_selective(smoke=args.smoke)
