"""Worker for `benchmarks/run.py uplink-sharded`: one host-device count per
process.

jax locks the device count at first initialization, so each measurement
point runs in its own subprocess with

    XLA_FLAGS=--xla_force_host_platform_device_count=<n>

set by the parent (see README.md "Environment variables & flags").  The
worker times the CLIENT uplink hot path:

  * single-device vs sharded `encrypt_values_seeded` (weights -> seeded
    ciphertext, encode FFT + sampling + NTTs in one dispatch; chunks shard
    along ``data``, limbs along ``model``);
  * frame packing of the seeded update (seed, c0 chunks) vs the full
    ciphertext, recording measured bytes per update for both;

asserts bit-parity between the sharded and single-device ciphertexts, and
prints one JSON object on the last stdout line for the parent to collect
into BENCH_uplink_sharded.json.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, required=True,
                    help="host device count this worker was launched with")
    ap.add_argument("--n-poly", type=int, default=2048)
    ap.add_argument("--n-limbs", type=int, default=2)
    ap.add_argument("--n-chunks", type=int, default=8)
    ap.add_argument("--reps", type=int, default=10)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.ckks import cipher, params as ckks_params
    from repro.core.ckks.sharded import ShardedHe
    from repro.core.secure_agg import ProtectedUpdate
    from repro.kernels import ops
    from repro.launch.mesh import make_he_mesh
    from repro.wire import compress as wc
    from repro.wire import stream as ws

    assert jax.device_count() >= args.devices, (
        f"worker expected {args.devices} devices, found "
        f"{jax.device_count()}; the parent must set "
        "XLA_FLAGS=--xla_force_host_platform_device_count")

    ctx = ckks_params.make_context(n_poly=args.n_poly, n_limbs=args.n_limbs,
                                   delta_bits=26)
    mesh = make_he_mesh(args.n_limbs, args.devices)
    eng = ShardedHe(ctx, mesh)
    rng = np.random.RandomState(0)
    sk, pk = cipher.keygen(ctx, jax.random.PRNGKey(0))
    vals = jnp.asarray(
        rng.randn(args.n_chunks, ctx.slots).astype(np.float32)) * 0.1
    key = jax.random.PRNGKey(1)
    a_seed = 4242

    def timeit(fn, *a, reps=args.reps):
        out = fn(*a)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready()
            if hasattr(x, "block_until_ready") else x, out)
        t0 = time.time()
        for _ in range(reps):
            out = fn(*a)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready()
            if hasattr(x, "block_until_ready") else x, out)
        return (time.time() - t0) / reps

    # -- seeded encrypt: sharded vs single-device fused ---------------------
    single_s = timeit(
        lambda: cipher.encrypt_values_seeded(ctx, sk, vals, key, a_seed).data)
    sharded_s = timeit(
        lambda: eng.encrypt_values_seeded(sk, vals, key, a_seed).data)
    ct1 = cipher.encrypt_values_seeded(ctx, sk, vals, key, a_seed)
    ct2 = eng.encrypt_values_seeded(sk, vals, key, a_seed)
    parity = bool(np.array_equal(np.asarray(ct1.data), np.asarray(ct2.data)))

    # -- pk-path encrypt (also data-sharded now) ----------------------------
    pk_single_s = timeit(
        lambda: cipher.encrypt_values(ctx, pk, vals, key).data)
    pk_sharded_s = timeit(lambda: eng.encrypt_values(pk, vals, key).data)

    # -- wire: seeded vs full frame bytes for the same update ---------------
    upd = ProtectedUpdate(ct=ct2, plain=jnp.zeros((0,), jnp.float32))
    sct = wc.seed_compress(ct2, a_seed)
    blob_seeded = ws.pack_update_frames(upd, cid=0, n_samples=1, rnd=0,
                                        seeded=sct)
    blob_full = ws.pack_update_frames(upd, cid=0, n_samples=1, rnd=0)

    result = {
        "devices": args.devices,
        "mesh": dict(mesh.shape),
        "n_poly": args.n_poly,
        "n_limbs": args.n_limbs,
        "n_chunks": args.n_chunks,
        "backend": ops.get_backend(),
        "encrypt_seeded_single_ms": single_s * 1e3,
        "encrypt_seeded_sharded_ms": sharded_s * 1e3,
        "encrypt_pk_single_ms": pk_single_s * 1e3,
        "encrypt_pk_sharded_ms": pk_sharded_s * 1e3,
        "sharded_parity": parity,
        "seeded_bytes_per_update": len(blob_seeded),
        "full_bytes_per_update": len(blob_full),
        "uplink_ratio": len(blob_seeded) / len(blob_full),
    }
    print(json.dumps(result))


if __name__ == "__main__":
    sys.exit(main())
