"""FL aggregation server.

Holds only the public crypto context + the SelectiveHEAggregator (static
mask indices).  Never sees secret keys.  Handles:
  * synchronous weighted aggregation over whatever updates arrived
    (dropout-robust: weights renormalize over the received set — HE needs
    no mask-recovery round, unlike secure aggregation, paper Table 1);
  * streaming wire ingest (repro.wire.stream): serialized client updates
    fold chunk-by-chunk into the modular accumulator — O(1) server-side
    update buffers in the number of clients;
  * async FedBuff-style buffered aggregation with staleness discounting.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro import obs
from repro.core.secure_agg import ProtectedUpdate, SelectiveHEAggregator
from repro.wire import budget as wire_budget
from repro.wire import stream as wire_stream


@dataclasses.dataclass
class ReceivedUpdate:
    cid: int
    update: ProtectedUpdate
    n_samples: int
    round_sent: int = 0          # for staleness in async mode


class FLServer:
    def __init__(self, aggregator: SelectiveHEAggregator,
                 buffer_size: int = 0, staleness_half_life: float = 4.0,
                 ledger: wire_budget.BandwidthLedger | None = None,
                 sharded=None):
        """Args:
            aggregator: the SelectiveHEAggregator (public ctx + mask).
            buffer_size: 0 => synchronous; >0 => async FedBuff buffer.
            staleness_half_life: async staleness discount half-life.
            ledger: optional BandwidthLedger for measured uplink bytes.
            sharded: optional core.ckks.sharded.ShardedHe engine; batch and
                streaming HE aggregation then run sharded over its mesh
                (chunks -> data axis, limbs -> model axis), bit-identical
                to the single-device path.
        """
        self.agg = aggregator
        self.buffer_size = buffer_size            # 0 => synchronous
        self.staleness_half_life = staleness_half_life
        self.ledger = ledger
        self.sharded = sharded
        self._buffer: list[ReceivedUpdate] = []
        self.rounds_aggregated = 0
        self.last_ingest: wire_stream.StreamIngest | None = None

    # -- synchronous ---------------------------------------------------------

    def aggregate_sync(self, received: list[ReceivedUpdate]) -> ProtectedUpdate:
        if not received:
            raise ValueError("no client updates received this round")
        weights = np.asarray([r.n_samples for r in received], dtype=np.float64)
        weights = weights / weights.sum()
        out = self.agg.server_aggregate([r.update for r in received],
                                        [float(w) for w in weights],
                                        sharded=self.sharded)
        self.rounds_aggregated += 1
        return out

    # -- streaming wire ingest (repro.wire) ----------------------------------

    def aggregate_wire(self, blobs: list[bytes]) -> ProtectedUpdate:
        """Aggregate serialized client updates without materializing them.

        Pass 1 reads only the fixed-size UPDATE_BEGIN headers to normalize
        FedAvg weights; pass 2 streams each blob through the chunked modular
        accumulator (one in-flight ciphertext chunk at any time — the
        decoded-update memory footprint does not grow with len(blobs)).
        """
        if not blobs:
            raise ValueError("no client updates received this round")
        metas = [wire_stream.peek_update_meta(b) for b in blobs]
        weights = np.asarray([m.n_samples for m in metas], dtype=np.float64)
        weights = weights / weights.sum()
        ingest = wire_stream.StreamIngest(self.agg.ctx, sharded=self.sharded)
        for blob, meta, w in zip(blobs, metas, weights):
            ingest.ingest(blob, float(w))
            if self.ledger is not None:
                # uplink is accounted where it arrives (the server);
                # clients account the downlink they receive
                self.ledger.record_blob(blob, rnd=meta.round, cid=meta.cid,
                                        direction=wire_budget.UPLINK)
        self.last_ingest = ingest
        self.rounds_aggregated += 1
        with obs.span("wire.finalize", n_updates=len(blobs),
                      launches=ingest.accum_launches):
            return ingest.finalize()

    # -- async (FedBuff) -----------------------------------------------------

    def submit_async(self, r: ReceivedUpdate,
                     current_round: int) -> ProtectedUpdate | None:
        """Buffer an update; aggregate + flush when the buffer fills.
        Staleness discount: w *= 0.5 ** (staleness / half_life) — the
        shared weight law in repro.serve.quorum (the aggregation service
        uses the same expressions; tests pin both paths)."""
        from repro.serve import quorum as serve_quorum

        self._buffer.append(r)
        if len(self._buffer) < self.buffer_size:
            return None
        ws = serve_quorum.staleness_weights(
            [u.n_samples for u in self._buffer],
            [u.round_sent for u in self._buffer],
            current_round, self.staleness_half_life)
        out = self.agg.server_aggregate([u.update for u in self._buffer],
                                        ws, sharded=self.sharded)
        self._buffer.clear()
        self.rounds_aggregated += 1
        return out
