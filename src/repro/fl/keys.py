"""Key management (paper §2.2, Appendix B).

* KeyAuthority — the default trusted key-authority server: generates the
  CKKS key pair, hands (pk, sk) to authenticated clients and ONLY the
  public crypto context to the aggregation server (no collusion assumed).
* ThresholdKeyAuthority — additive n-of-n threshold variant: clients run the
  interactive keygen; decryption needs every share (plus smudging noise),
  so a corrupted server + (n-1) clients still cannot decrypt an honest
  client's update.
"""
from __future__ import annotations

import jax

from repro.core.ckks import cipher, threshold
from repro.core.ckks.params import CkksContext, make_context


class KeyAuthority:
    def __init__(self, ctx: CkksContext | None = None, seed: int = 0):
        self.ctx = ctx or make_context()
        self._sk, self._pk = cipher.keygen(self.ctx, jax.random.PRNGKey(seed))

    # clients get both keys; the aggregation server only ever calls
    # public_context().
    def client_keys(self) -> tuple[dict, dict]:
        return self._pk, self._sk

    def public_context(self) -> CkksContext:
        return self.ctx


class ThresholdKeyAuthority:
    """Coordination point for the interactive additive threshold keygen."""

    def __init__(self, n_parties: int, ctx: CkksContext | None = None,
                 seed: int = 0):
        self.ctx = ctx or make_context()
        self.n_parties = n_parties
        self.parties, self._pk = threshold.threshold_keygen(
            self.ctx, jax.random.PRNGKey(seed), n_parties)

    def public_key(self) -> dict:
        return self._pk

    def party(self, i: int) -> threshold.ThresholdParty:
        return self.parties[i]

    def partial_decrypt(self, i: int, ct, key):
        return threshold.partial_decrypt(self.ctx, self.parties[i], ct, key)

    def combine(self, ct, partials):
        return threshold.combine_partials(self.ctx, ct, partials)
