"""Limb-fused execution engine: bit-exact parity against the per-limb
reference across limb counts, backends, the streaming accumulate path, and
limb-dropped ciphertexts — plus the backend registry contract."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.ckks import cipher, encoding
from repro.core.ckks import params as ckks_params
from repro.kernels import ops, ref

# L=1 needs a small delta for depth-1 modulus headroom; 2/3 use the default.
_DELTA_BITS = {1: 12, 2: 20, 3: 20}


def _ctx(n_limbs, n_poly=64):
    return ckks_params.make_test_context(
        n_poly=n_poly, n_limbs=n_limbs, delta_bits=_DELTA_BITS[n_limbs])


def _rand_limbed(rng, ctx, shape):
    return jnp.asarray(ref.rand_limbed_np(rng, ctx, shape))


def _per_limb_ntt_fwd(x, ctx):
    """The seed engine's execution model: one single-limb op per limb."""
    return jnp.stack(
        [ref.ntt_fwd(x[..., i, :], jnp.asarray(lc.psi_rev_mont),
                     np.uint32(lc.q), np.uint32(lc.qinv_neg))
         for i, lc in enumerate(ctx.limbs)], axis=-2)


def _per_limb_ntt_inv(x, ctx):
    return jnp.stack(
        [ref.ntt_inv(x[..., i, :], jnp.asarray(lc.psi_inv_rev_mont),
                     np.asarray(lc.n_inv_mont), np.uint32(lc.q),
                     np.uint32(lc.qinv_neg))
         for i, lc in enumerate(ctx.limbs)], axis=-2)


def _per_limb_mul_add(x, y, z, ctx):
    return jnp.stack(
        [ref.mul_add(x[..., i, :], y[..., i, :], z[..., i, :],
                     np.uint32(lc.q), np.uint32(lc.qinv_neg))
         for i, lc in enumerate(ctx.limbs)], axis=-2)


def _per_limb_weighted_sum(cts, w, ctx):
    c = cts.shape[0]
    shape = (c,) + (1,) * (cts.ndim - 3)
    return jnp.stack(
        [ref.he_weighted_sum(cts[..., i, :], w[:, i].reshape(shape),
                             np.uint32(lc.q), np.uint32(lc.qinv_neg))
         for i, lc in enumerate(ctx.limbs)], axis=-2)


@pytest.fixture(params=["ref", "pallas", "pallas4"])
def backend(request):
    old = {op: ops.get_backend(op) for op in ops.OPS}
    ops.set_backend(request.param)
    yield request.param
    for op, name in old.items():
        ops.set_backend(name, op=op)


@pytest.mark.parametrize("n_limbs", [1, 2, 3])
def test_ntt_parity(n_limbs, backend):
    ctx = _ctx(n_limbs)
    rng = np.random.RandomState(10 + n_limbs)
    x = _rand_limbed(rng, ctx, (5,))
    fwd = ops.ntt_fwd(x, ctx)
    np.testing.assert_array_equal(np.asarray(fwd),
                                  np.asarray(_per_limb_ntt_fwd(x, ctx)))
    inv = ops.ntt_inv(fwd, ctx)
    np.testing.assert_array_equal(np.asarray(inv),
                                  np.asarray(_per_limb_ntt_inv(fwd, ctx)))
    np.testing.assert_array_equal(np.asarray(inv), np.asarray(x))


@pytest.mark.parametrize("n_limbs", [1, 2, 3])
def test_mul_add_parity(n_limbs, backend):
    ctx = _ctx(n_limbs)
    rng = np.random.RandomState(20 + n_limbs)
    x, y, z = (_rand_limbed(rng, ctx, (4,)) for _ in range(3))
    np.testing.assert_array_equal(
        np.asarray(ops.mul_add(x, y, z, ctx)),
        np.asarray(_per_limb_mul_add(x, y, z, ctx)))


@pytest.mark.parametrize("n_limbs", [1, 2, 3])
def test_weighted_sum_parity(n_limbs, backend):
    ctx = _ctx(n_limbs)
    rng = np.random.RandomState(30 + n_limbs)
    cts = _rand_limbed(rng, ctx, (4, 3))
    w = jnp.asarray(np.stack([rng.randint(0, int(q), size=(4,))
                              for q in ctx.primes], axis=1).astype(np.uint32))
    np.testing.assert_array_equal(
        np.asarray(ops.weighted_sum(cts, w, ctx)),
        np.asarray(_per_limb_weighted_sum(cts, w, ctx)))


@pytest.mark.parametrize("n_limbs", [1, 2, 3])
def test_weighted_accum_matches_weighted_sum(n_limbs, backend):
    """Streaming accumulate path == batch weighted_sum, bit-for-bit, for any
    limb count — the wire/stream ingest invariant."""
    ctx = _ctx(n_limbs)
    rng = np.random.RandomState(40 + n_limbs)
    cts = _rand_limbed(rng, ctx, (3, 2))
    w = jnp.asarray(np.stack([rng.randint(0, int(q), size=(3,))
                              for q in ctx.primes], axis=1).astype(np.uint32))
    batch = ops.weighted_sum(cts, w, ctx)
    acc = jnp.zeros_like(cts[0])
    for i in range(cts.shape[0]):
        acc = ops.weighted_accum(acc, cts[i], w[i], ctx)
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(batch))


def test_limb_dropped_ciphertext_ops(backend):
    """Ops on a ciphertext with fewer limbs than the context slice the
    constant tables to the leading limbs (rescale keeps limb order)."""
    ctx = _ctx(3)
    rng = np.random.RandomState(50)
    x = _rand_limbed(rng, ctx, (4,))
    for keep in (2, 1):
        xd = x[..., :keep, :]
        fwd = ops.ntt_fwd(xd, ctx)
        np.testing.assert_array_equal(
            np.asarray(fwd),
            np.asarray(_per_limb_ntt_fwd(x, ctx))[..., :keep, :])
        np.testing.assert_array_equal(
            np.asarray(ops.ntt_inv(fwd, ctx)), np.asarray(xd))


def test_encrypt_decrypt_roundtrip_both_backends(backend):
    ctx = _ctx(2, n_poly=128)
    sk, pk = cipher.keygen(ctx, jax.random.PRNGKey(0))
    vals = jnp.asarray(np.linspace(-1, 1, ctx.slots, dtype=np.float32))[None]
    ct = cipher.encrypt_values(ctx, pk, vals, jax.random.PRNGKey(1))
    out = cipher.decrypt_values(ctx, sk, ct)
    np.testing.assert_allclose(np.asarray(out), np.asarray(vals), atol=2e-3)


def test_seeded_encrypt_64bit_seed():
    """a_seed is 64-bit on the wire: the seeded-encrypt graph must use the
    same full-width PRNG stream as the server-side expand_a_rows."""
    ctx = _ctx(2, n_poly=128)
    sk, pk = cipher.keygen(ctx, jax.random.PRNGKey(6))
    vals = jnp.asarray(np.linspace(-0.5, 0.5, ctx.slots,
                                   dtype=np.float32))[None]
    coeffs = encoding.encode_jnp(vals, ctx)
    a_seed = (1 << 33) + 12345
    ct = cipher.encrypt_coeffs_seeded(ctx, sk, coeffs, jax.random.PRNGKey(7),
                                      a_seed)
    np.testing.assert_array_equal(
        np.asarray(ct.c1), np.asarray(cipher.expand_a(ctx, a_seed, 1)))
    out = cipher.decrypt_values(ctx, sk, ct)
    np.testing.assert_allclose(np.asarray(out), np.asarray(vals), atol=2e-3)


def test_backend_parity_end_to_end():
    """Same keys/inputs produce bit-identical ciphertexts on every backend
    (the PRNG streams and modular math are backend-independent)."""
    ctx = _ctx(2, n_poly=128)
    vals = jnp.asarray(np.linspace(-0.5, 0.5, ctx.slots,
                                   dtype=np.float32))[None]
    datas = {}
    old = ops.get_backend()
    try:
        for b in ops.BACKENDS:
            ops.set_backend(b)
            sk, pk = cipher.keygen(ctx, jax.random.PRNGKey(3))
            ct = cipher.encrypt_values(ctx, pk, vals, jax.random.PRNGKey(4))
            datas[b] = (np.asarray(ct.data),
                        np.asarray(cipher.decrypt_to_coeffs(ctx, sk, ct)))
    finally:
        ops.set_backend(old)
    for b in ops.BACKENDS[1:]:
        np.testing.assert_array_equal(datas["ref"][0], datas[b][0])
        np.testing.assert_array_equal(datas["ref"][1], datas[b][1])


def test_per_op_backend_selection():
    """The registry flips one op at a time and reports 'mixed'."""
    ctx = _ctx(2)
    rng = np.random.RandomState(60)
    x = _rand_limbed(rng, ctx, (2,))
    old = {op: ops.get_backend(op) for op in ops.OPS}
    try:
        ops.set_backend("ref")
        a = ops.ntt_fwd(x, ctx)
        ops.set_backend("pallas", op="ntt_fwd")
        assert ops.get_backend("ntt_fwd") == "pallas"
        assert ops.get_backend("mul_add") == "ref"
        assert ops.get_backend() == "mixed"
        b = ops.ntt_fwd(x, ctx)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # token changes with the assignment — jitted graphs retrace
        tok_mixed = ops.backend_token()
        ops.set_backend("ref")
        assert ops.backend_token() != tok_mixed
    finally:
        for op, name in old.items():
            ops.set_backend(name, op=op)


def test_streaming_ingest_parity_across_backends():
    """wire.stream accumulate path: fused engine keeps the bit-parity
    invariant with the batch weighted_sum on both backends."""
    from repro.core.secure_agg import ProtectedUpdate
    from repro.wire import stream as ws

    ctx = _ctx(2, n_poly=128)
    sk, pk = cipher.keygen(ctx, jax.random.PRNGKey(5))
    rng = np.random.RandomState(70)
    n_clients = 3
    upds = []
    for i in range(n_clients):
        vals = jnp.asarray(rng.randn(1, ctx.slots).astype(np.float32)) * 0.1
        ct = cipher.encrypt_values(ctx, pk, vals, jax.random.PRNGKey(80 + i))
        upds.append(ProtectedUpdate(
            ct=ct, plain=jnp.zeros((0,), jnp.float32)))
    w = [1.0 / n_clients] * n_clients
    stacked = cipher.Ciphertext(
        data=jnp.stack([u.ct.data for u in upds]), scale=upds[0].ct.scale)
    old = ops.get_backend()
    datas = {}
    try:
        for b in ("ref", "pallas"):
            ops.set_backend(b)
            batch = cipher.weighted_sum(ctx, stacked, w)
            ingest = ws.StreamIngest(ctx)
            for u, wi in zip(upds, w):
                ingest.ingest_update(u, wi)
            streamed = ingest.finalize()
            np.testing.assert_array_equal(np.asarray(streamed.ct.data),
                                          np.asarray(batch.data))
            datas[b] = np.asarray(batch.data)
    finally:
        ops.set_backend(old)
    np.testing.assert_array_equal(datas["ref"], datas["pallas"])
