"""Flatten/partition/pack model parameters for selective HE.

The FL/HE boundary works on a single flat f32 vector per model (the paper's
``flatten``/``reshape`` APIs, Table 3).  Selection masks are *static* per FL
task (the paper fixes M after round 1), so the mask partition is realized as
constant index arrays -> jit-friendly gathers/scatters with static shapes.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Shape bookkeeping for pytree <-> flat-vector roundtrips."""

    treedef: object
    shapes: tuple[tuple[int, ...], ...]
    dtypes: tuple[object, ...]
    sizes: tuple[int, ...]
    offsets: tuple[int, ...]   # start offset of each leaf in the flat vector

    @property
    def total(self) -> int:
        return self.offsets[-1] + self.sizes[-1] if self.sizes else 0


def make_flat_spec(params) -> FlatSpec:
    leaves, treedef = jax.tree_util.tree_flatten(params)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(l.dtype for l in leaves)
    sizes = tuple(int(np.prod(s)) if s else 1 for s in shapes)
    offsets = tuple(int(o) for o in np.concatenate([[0], np.cumsum(sizes)[:-1]]))
    return FlatSpec(treedef=treedef, shapes=shapes, dtypes=dtypes, sizes=sizes,
                    offsets=offsets)


def flatten_params(params):
    """pytree -> (f32[P], FlatSpec)."""
    spec = make_flat_spec(params)
    leaves = jax.tree_util.tree_leaves(params)
    vec = jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])
    return vec, spec


def unflatten_params(vec, spec: FlatSpec):
    """f32[P] -> pytree with spec's shapes/dtypes."""
    leaves = []
    for off, size, shape, dt in zip(spec.offsets, spec.sizes, spec.shapes,
                                    spec.dtypes):
        leaves.append(jax.lax.dynamic_slice_in_dim(vec, off, size)
                      .reshape(shape).astype(dt))
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


# ---------------------------------------------------------------------------
# mask partition (static indices)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MaskPartition:
    """Static index arrays splitting a flat vector by a boolean mask.

    ``enc_idx``/``plain_idx`` are host numpy int32 arrays (constants baked
    into the jitted round step).  ``n_enc_padded`` pads the encrypted segment
    to a whole number of CKKS slot blocks.
    """

    n_total: int
    enc_idx: np.ndarray
    plain_idx: np.ndarray
    slots: int

    @property
    def n_enc(self) -> int:
        return int(self.enc_idx.size)

    @property
    def n_plain(self) -> int:
        return int(self.plain_idx.size)

    @property
    def n_chunks(self) -> int:
        return max(1, -(-self.n_enc // self.slots))

    @property
    def n_enc_padded(self) -> int:
        return self.n_chunks * self.slots

    @property
    def ratio(self) -> float:
        return self.n_enc / max(1, self.n_total)


def make_partition(mask: np.ndarray, slots: int) -> MaskPartition:
    mask = np.asarray(mask, dtype=bool)
    return MaskPartition(
        n_total=int(mask.size),
        enc_idx=np.where(mask)[0].astype(np.int32),
        plain_idx=np.where(~mask)[0].astype(np.int32),
        slots=int(slots),
    )


def split_by_mask(vec, part: MaskPartition):
    """f32[P] -> (enc f32[n_chunks, slots] zero-padded, plain f32[n_plain])."""
    enc = vec[jnp.asarray(part.enc_idx)]
    pad = part.n_enc_padded - part.n_enc
    enc = jnp.pad(enc, (0, pad)).reshape(part.n_chunks, part.slots)
    plain = vec[jnp.asarray(part.plain_idx)]
    return enc, plain


def merge_by_mask(enc_chunks, plain, part: MaskPartition):
    """Inverse of split_by_mask -> f32[P]."""
    out = jnp.zeros((part.n_total,), dtype=jnp.float32)
    enc_flat = enc_chunks.reshape(-1)[: part.n_enc]
    out = out.at[jnp.asarray(part.enc_idx)].set(enc_flat)
    out = out.at[jnp.asarray(part.plain_idx)].set(plain)
    return out
