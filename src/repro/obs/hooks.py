"""Kernel-launch timing hooks for the `kernels/ops.py` backend registry.

Two measurement points, both opt-in via REPRO_OBS=1 (DESIGN.md §11.3):

  * `timed_kernel` — wraps every registry dispatch.  Called EAGERLY
    (tests, benchmarks, ad-hoc use) it times the op wall-to-wall with
    `jax.block_until_ready` under a `jax.profiler.TraceAnnotation`, so
    host traces and device profiles both carry the op name.  Called under
    a jit/shard_map TRACE (the normal production path — cipher graphs,
    the streaming flush, sharded bodies) real timing is impossible, so it
    wraps the op in `jax.named_scope` instead: the compiled HLO carries
    `he.<op>.<backend>` metadata for device profilers, and a
    `kernel_op_traces_total` counter records the retrace.
  * `kernel_launch` — a span for the CALL SITE of a jitted HE graph
    (stream flush, ShardedHe dispatches): wall time of one launch,
    blocked on completion, keyed by op name and the full
    `ops.backend_token()` so flat/pallas/pallas4 runs are distinguishable
    in one trace.

With REPRO_OBS=0 every hook short-circuits to the raw implementation:
no block, no named_scope, no counter — jitted graph keys and dispatch
counts are bit-for-bit those of a build without this module
(tests/test_obs.py asserts it).
"""
from __future__ import annotations

import time

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace


def kernel_hooks_enabled() -> bool:
    """Gate for the registry dispatch hook (same switch as spans)."""
    return _trace.enabled()


def _any_tracer(args) -> bool:
    """True when any leaf is a jax Tracer — i.e. we are inside a jit /
    shard_map trace and wall-timing would measure tracing, not compute."""
    import jax

    return any(isinstance(x, jax.core.Tracer)
               for x in jax.tree_util.tree_leaves(args))


def timed_kernel(op: str, backend: str, token, impl, *args, config=None):
    """Dispatch one registry op with timing (see module docstring).

    `config` is the resolved tune.KernelConfig of an `auto` dispatch (None
    for explicit backends); it is stamped into the span args so a trace
    shows the launch geometry that actually ran (DESIGN.md §12.5)."""
    import jax

    if _any_tracer(args):
        _metrics.REGISTRY.counter("kernel_op_traces_total", op=op,
                                  backend=backend).inc()
        with jax.named_scope(f"he.{op}.{backend}"):
            return impl(*args)
    tracer = _trace.get_tracer()
    ts0 = tracer.now_us()
    t0 = time.perf_counter()
    with jax.profiler.TraceAnnotation(f"he.{op}"):
        out = jax.block_until_ready(impl(*args))
    dt = time.perf_counter() - t0
    _metrics.REGISTRY.counter("kernel_op_launches_total", op=op,
                              backend=backend).inc()
    _metrics.REGISTRY.histogram("kernel_op_seconds", op=op,
                                backend=backend).observe(dt)
    span_args = {"op": op, "backend": backend, "token": str(token),
                 "eager": True}
    if config is not None:
        span_args["config"] = config.to_json()
    tracer.emit_complete(f"he.{op}", ts0, dt * 1e6, cat="kernel",
                         args=span_args)
    return out


class _KernelLaunch:
    """Span + histogram around one jitted-graph launch (blocks on exit)."""

    __slots__ = ("op", "token", "args", "_ts0", "_t0", "_out")

    def __init__(self, op: str, token, args: dict):
        self.op = op
        self.token = token
        self.args = args
        self._out = None

    def done(self, out):
        """Hand the launch its outputs so __exit__ can block on them."""
        self._out = out
        return out

    def __enter__(self) -> "_KernelLaunch":
        self._ts0 = _trace.get_tracer().now_us()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        import jax

        if self._out is not None and exc_type is None:
            jax.block_until_ready(self._out)
        dt = time.perf_counter() - self._t0
        backend = self.args.get("backend", "")
        _metrics.REGISTRY.counter("kernel_launches_total", op=self.op,
                                  backend=backend).inc()
        _metrics.REGISTRY.histogram("kernel_launch_seconds", op=self.op,
                                    backend=backend).observe(dt)
        _trace.get_tracer().emit_complete(
            f"he.{self.op}", self._ts0, dt * 1e6, cat="kernel",
            args={"op": self.op, "token": str(self.token), **self.args})


class _NullLaunch:
    __slots__ = ()

    def done(self, out):
        return out

    def __enter__(self) -> "_NullLaunch":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        pass


_NULL_LAUNCH = _NullLaunch()


def kernel_launch(op: str, token, **args):
    """Context manager timing one jitted HE-graph launch.

    Usage::

        with obs.kernel_launch("weighted_accum_chunks", token, rows=k) as kl:
            out = kl.done(jitted_graph(...))

    `kl.done(out)` registers the outputs; exit blocks on them and records
    wall time into the `kernel_launch_seconds` histogram and a cat="kernel"
    trace event keyed by the backend token.  No-op when obs is disabled.
    """
    if not _trace.enabled():
        return _NULL_LAUNCH
    return _KernelLaunch(op, token, dict(args))


def maybe_block(x):
    """block_until_ready(x) when obs is enabled and x is concrete — makes
    span durations mean 'work finished', not 'dispatch returned'."""
    if not _trace.enabled():
        return x
    import jax

    if _any_tracer(x):
        return x
    return jax.block_until_ready(x)
