"""Bandwidth optimizations for the FL wire (repro.wire).

Three independent knobs, composable via WirePolicy:

  * seed-expanded fresh encryptions (uplink) — a fresh secret-key RLWE
    ciphertext's c1 component is uniform; sampling it from a public PRNG
    seed lets the client transmit (seed, c0) instead of (c1, c0), halving
    uplink ciphertext bytes.  Standard RLWE trick (NewHope/Kyber public
    matrices, SEAL's seeded ciphertexts); requires the seeded encrypt path
    in core/ckks/cipher.py and is only available to sk-holding clients
    (i.e. not in threshold mode, where no party holds the full secret).

  * RNS limb dropping (downlink) — rescale away trailing limbs of the
    aggregated ciphertext before broadcast: (L-keep)/L fewer bytes at the
    cost of log2(q_dropped) bits of plaintext precision.

  * plaintext-partition quantization (uplink) — the non-encrypted remainder
    of a selective-encryption update tolerates fp16 or int8 on the wire
    (it is averaged, not accumulated over rounds).

See DESIGN.md §6 for the byte-level layout and when each knob is sound.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core.ckks import cipher
from repro.core.ckks.cipher import Ciphertext
from repro.core.ckks.params import CkksContext

PLAIN_CODECS = ("f32", "f16", "i8")


@dataclasses.dataclass(frozen=True)
class WirePolicy:
    """Per-deployment compression configuration for the FL wire."""

    seed_ciphertexts: bool = True     # uplink: ship (seed, c0), not (c0, c1)
    downlink_keep_limbs: int = 0      # 0 = keep all limbs (lossless)
    plain_codec: str = "f32"          # f32 | f16 | i8

    def __post_init__(self):
        assert self.plain_codec in PLAIN_CODECS, self.plain_codec
        assert self.downlink_keep_limbs >= 0


LOSSLESS = WirePolicy(seed_ciphertexts=True, downlink_keep_limbs=0,
                      plain_codec="f32")
COMPACT = WirePolicy(seed_ciphertexts=True, downlink_keep_limbs=0,
                     plain_codec="f16")


# ---------------------------------------------------------------------------
# seed-expanded ciphertexts
# ---------------------------------------------------------------------------

# Per-chunk seed-derivation algorithm ids (wire v2 SEEDED_CIPHERTEXT frames
# carry one; v1 frames imply DERIVE_FOLD_CHUNK).  The registry itself lives
# in core/ckks/cipher.py (both encrypt and expansion dispatch through it);
# re-exported here — and from here by wire/format.py — as the wire-facing
# names, preserving the import layering (format.py imports SeededCiphertext
# from this module).
#
# DERIVE_FOLD_CHUNK: chunk b's c1 row is the uniform-residue expansion of
# fold_in(PRNGKey(seed), chunk_offset + b).  DERIVE_CTR: chunk b's key is
# the raw counter block [seed_hi, seed_lo + chunk_offset + b].  Normative
# registry table: DESIGN.md §9.2.
DERIVE_FOLD_CHUNK = cipher.DERIVE_FOLD_CHUNK
DERIVE_CTR = cipher.DERIVE_CTR
DERIVES = cipher.DERIVES


@dataclasses.dataclass
class SeededCiphertext:
    """Wire form of a fresh seeded encryption: c0 plus the c1 PRNG seed.

    c0: u32[B, L, N] (NTT domain); expand() regenerates c1 = PRG(seed) and
    returns the full in-memory Ciphertext.  `derive` names the per-chunk
    seed-derivation algorithm from the cipher.DERIVE_KEYFNS registry
    (DESIGN.md §9.2), so a streaming receiver expands each arriving chunk
    independently (chunk_offset tracks the index of c0's first row within
    the original update).  The field rides in wire-v2 frames; v1 frames
    imply DERIVE_FOLD_CHUNK.
    """

    c0: Any
    seed: int
    scale: float
    chunk_offset: int = 0
    derive: int = DERIVE_FOLD_CHUNK

    @property
    def n_chunks(self) -> int:
        return int(self.c0.shape[0])

    def expand(self, ctx: CkksContext) -> Ciphertext:
        # dispatches through cipher.DERIVE_KEYFNS; an unknown id raises the
        # registry's actionable error (DESIGN.md §9.2) before any expansion
        a = cipher.expand_a_rows(ctx, self.seed, self.chunk_offset,
                                 self.n_chunks, derive=self.derive)
        data = jnp.stack([jnp.asarray(self.c0), a], axis=-2)  # [B, L, 2, N]
        return Ciphertext(data=data, scale=self.scale)


@dataclasses.dataclass
class MaskedChunk:
    """Wire form of a transcipher (hybrid-HE) uplink chunk: stream-cipher-
    masked centered coefficients, NO ciphertext limbs (DESIGN.md §15).

    masked: u32[B, N] — encode_centered(values) + keystream pad, exact by
    the pad-window construction (core/ckks/transcipher.py).  `a_seed` and
    `derive` name the public a stream the server expands for the unmasked
    ciphertext (the same registry as seeded frames); `chunk_offset` is the
    global index of the first masked row.  Only expressible in wire v2+
    frames — there is no v1 layout to imply anything.
    """

    masked: Any
    a_seed: int
    scale: float
    chunk_offset: int = 0
    derive: int = DERIVE_CTR

    @property
    def n_chunks(self) -> int:
        return int(self.masked.shape[0])


def seed_compress(ct: Ciphertext, seed: int,
                  derive: int = DERIVE_FOLD_CHUNK) -> SeededCiphertext:
    """Strip the deterministic c1 from a seeded encryption for the wire.

    `ct` must have come from cipher.encrypt_coeffs_seeded /
    ShardedHe.encrypt_*_seeded with this seed and derivation algorithm;
    caller-enforced (a mismatch decrypts to noise, caught by tests).
    """
    return SeededCiphertext(c0=ct.data[..., 0, :], seed=int(seed),
                            scale=ct.scale, derive=int(derive))


# ---------------------------------------------------------------------------
# RNS limb dropping (downlink)
# ---------------------------------------------------------------------------


def limb_drop(ctx: CkksContext, ct: Ciphertext, keep: int) -> Ciphertext:
    """Rescale the aggregated ciphertext down to `keep` limbs (lossy)."""
    return cipher.drop_limbs(ctx, ct, keep)


# ---------------------------------------------------------------------------
# plaintext-partition quantization
# ---------------------------------------------------------------------------


def quantize_plain(x, codec: str) -> tuple[np.ndarray, float]:
    """f32[P] -> (wire array, scale).  i8 is symmetric per-tensor."""
    x = np.asarray(x, dtype=np.float32)
    if codec == "f32":
        return x, 1.0
    if codec == "f16":
        return x.astype(np.float16), 1.0
    if codec == "i8":
        amax = float(np.max(np.abs(x))) if x.size else 0.0
        scale = amax / 127.0
        # guard the COMPUTED scale, not amax: a subnormal amax underflows
        # amax/127 to 0.0 and x/scale would put NaN/inf on the wire.  An
        # empty/all-zero/underflowing segment quantizes to zeros, scale 1.
        if not np.isfinite(scale) or scale <= 0.0:
            return np.zeros(x.shape, dtype=np.int8), 1.0
        return np.clip(np.rint(x / scale), -127, 127).astype(np.int8), scale
    raise ValueError(codec)


def dequantize_plain(arr: np.ndarray, codec: str, scale: float) -> np.ndarray:
    if codec == "f32":
        return np.asarray(arr, dtype=np.float32)
    if codec == "f16":
        return np.asarray(arr, dtype=np.float32)
    if codec == "i8":
        return np.asarray(arr, dtype=np.float32) * np.float32(scale)
    raise ValueError(codec)
