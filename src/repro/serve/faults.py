"""Deterministic fault injector: the aggregation service's adversary.

Two fault families, both driven by a seeded RNG so every failure a test
observes is replayable from its seed:

  * **wire faults** (`corrupt_blob`) — byte-level surgery on one client's
    serialized update stream: drop / duplicate a CT_CHUNK frame, truncate
    the blob, overwrite a frame header with garbage, or reorder the chunk
    frames.  ``delay`` is a timing fault (the blob is untouched; the
    driver submits it after the round deadline).  Every mode except
    ``reorder`` and ``delay`` must be REJECTED by the service with the
    aggregate untouched (StreamIngest's atomic per-update rollback);
    ``reorder`` must be accepted bit-identically (chunk index order is
    not part of the wire contract) and ``delay`` is rejected at submit.

  * **crash points** (`FaultInjector.crash_point`) — named points between
    service transitions where a `SimulatedCrash` is raised AFTER the
    state was checkpointed, simulating `kill -9`.  The test restarts via
    `AggregationService.resume` and asserts a bit-exact round.

Scope note (DESIGN.md §14.4): garbage targets frame STRUCTURE (magic /
length fields), not ciphertext payload bytes — a flipped bit inside the
u32 residue body is indistinguishable from a valid residue vector, so
payload integrity is the transport's job (TLS/QUIC), while the service
owns structural validation and atomicity.
"""
from __future__ import annotations

import numpy as np

from repro.wire import format as wf

FAULT_MODES = ("drop", "duplicate", "truncate", "garbage", "delay",
               "reorder")

# the service transitions a crash can fire after (service.py calls these)
CRASH_POINTS = ("after_open", "after_accept", "after_seal",
                "after_fold_step", "after_finalize")


class SimulatedCrash(RuntimeError):
    """Raised at an armed crash point: the in-process stand-in for
    `kill -9`.  State written before the raise is exactly what a real
    crash would leave on disk (ckpt/store.py writes are atomic)."""

    def __init__(self, point: str):
        super().__init__(f"simulated crash at service transition "
                         f"'{point}'")
        self.point = point


def split_frames(blob: bytes) -> list[bytes]:
    """Split a frame stream into whole re-concatenable frames."""
    out, off = [], 0
    while off < len(blob):
        _, _, _, end = wf.parse_frame(blob, off)
        out.append(blob[off:end])
        off = end
    return out


def _chunk_positions(frames: list[bytes]) -> list[int]:
    idx = []
    for i, fr in enumerate(frames):
        ftype, _, _, _ = wf.parse_frame(fr, 0)
        if ftype == wf.T_CT_CHUNK:
            idx.append(i)
    return idx


def corrupt_blob(blob: bytes, mode: str,
                 rng: np.random.RandomState) -> bytes:
    """Apply one wire fault to a client's update stream.

    Args:
        blob: the clean serialized frame stream (pack_update_frames).
        mode: one of FAULT_MODES.
        rng: seeded RandomState — all choices (which chunk, where to cut,
            which permutation) are drawn from it.

    Returns:
        The faulty bytes.  ``delay`` returns the blob unchanged (the
        fault is WHEN it is submitted, not what).
    """
    if mode not in FAULT_MODES:
        raise ValueError(f"unknown fault mode {mode!r}; choose from "
                         f"{FAULT_MODES}")
    if mode == "delay":
        return blob
    if mode == "truncate":
        # cut inside the stream: anywhere from mid-first-frame to one byte
        # short of complete
        cut = int(rng.randint(1, len(blob)))
        return blob[:cut]
    frames = split_frames(blob)
    chunks = _chunk_positions(frames)
    if mode in ("drop", "duplicate", "reorder") and not chunks:
        raise ValueError(f"fault mode {mode!r} needs at least one CT_CHUNK "
                         "frame in the blob")
    if mode == "drop":
        del frames[chunks[int(rng.randint(len(chunks)))]]
    elif mode == "duplicate":
        i = chunks[int(rng.randint(len(chunks)))]
        frames.insert(i, frames[i])
    elif mode == "garbage":
        # overwrite a frame header's magic with non-MAGIC bytes: the frame
        # chain breaks there and the decoder must reject, never over-read
        i = int(rng.randint(len(frames)))
        bad = bytearray(frames[i])
        junk = bytes(int(b) for b in rng.randint(0, 256, size=4))
        if junk == wf.MAGIC:                    # one-in-2^32, still seal it
            junk = bytes([junk[0] ^ 0xFF]) + junk[1:]
        bad[:4] = junk
        frames[i] = bytes(bad)
    elif mode == "reorder":
        # permute the CT_CHUNK frames among themselves (envelope frames
        # stay put); chunk order is explicitly NOT part of the contract
        perm = rng.permutation(len(chunks))
        if len(chunks) > 1:
            while all(int(p) == i for i, p in enumerate(perm)):
                perm = rng.permutation(len(chunks))
        reordered = [frames[chunks[int(p)]] for p in perm]
        for slot, fr in zip(chunks, reordered):
            frames[slot] = fr
    return b"".join(frames)


class FaultInjector:
    """Deterministic fault schedule for one service run.

    Args:
        seed: RNG seed for every byte-level choice.
        crash_at: iterable of CRASH_POINTS names; each armed point fires
            `SimulatedCrash` ONCE (then disarms, so the resumed service
            sails past it).
        blob_faults: optional {cid: mode} map; `corrupt(cid, blob)`
            applies the scheduled mode to that client's bytes and leaves
            every other client untouched.
    """

    def __init__(self, seed: int = 0, crash_at=(),
                 blob_faults: dict[int, str] | None = None):
        self.rng = np.random.RandomState(seed)
        unknown = set(crash_at) - set(CRASH_POINTS)
        if unknown:
            raise ValueError(f"unknown crash point(s) {sorted(unknown)}; "
                             f"choose from {CRASH_POINTS}")
        self.armed = set(crash_at)
        self.fired: list[str] = []
        self.blob_faults = dict(blob_faults or {})

    def corrupt(self, cid: int, blob: bytes) -> bytes:
        """Apply this client's scheduled wire fault (if any)."""
        mode = self.blob_faults.get(cid)
        return blob if mode is None else corrupt_blob(blob, mode, self.rng)

    def crash_point(self, name: str) -> None:
        """Crash here iff `name` is armed (fires once, then disarms)."""
        if name in self.armed:
            self.armed.discard(name)
            self.fired.append(name)
            raise SimulatedCrash(name)
