"""Three-term roofline from compiled dry-run artifacts (DESIGN.md §6).

Hardware model (TPU v5e-class target):
    peak bf16 compute   197 TFLOP/s per chip
    HBM bandwidth       819 GB/s per chip
    ICI link bandwidth  ~50 GB/s per link

Terms (seconds, per device — cost_analysis is per-device post-SPMD):
    compute    = HLO flops / PEAK_FLOPS
    memory     = HLO bytes accessed / HBM_BW
    collective = sum over collective ops of wire-bytes / ICI_BW
      ring formulas on per-device shapes from the partitioned module:
        all-gather      (g-1)/g * result_bytes
        reduce-scatter  (g-1)   * result_bytes   (= (g-1)/g * input)
        all-reduce      2 (g-1)/g * result_bytes
        all-to-all      (g-1)/g * result_bytes
        collective-permute  result_bytes
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(", )

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of possibly-tuple HLO shape text; tuples take the LAST
    element (the destination buffer of -start ops)."""
    matches = _SHAPE_RE.findall(shape_str)
    if not matches:
        return 0
    dt, dims = matches[-1]
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))          # [n_groups, group_size]<=[...]
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    wire_bytes: float          # per device
    by_op: dict                # op -> wire bytes


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict = {}
    by_op: dict = {}
    total = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        op = op.replace("-start", "")
        g = _group_size(line)
        if g <= 1:
            continue
        rb = _shape_bytes(shape_str)
        if op == "all-gather":
            wire = rb * (g - 1) / g
        elif op == "reduce-scatter":
            wire = rb * (g - 1)
        elif op == "all-reduce":
            wire = 2 * rb * (g - 1) / g
        elif op == "all-to-all":
            wire = rb * (g - 1) / g
        else:  # collective-permute
            wire = rb
        counts[op] = counts.get(op, 0) + 1
        by_op[op] = by_op.get(op, 0.0) + wire
        total += wire
    return CollectiveStats(counts=counts, wire_bytes=total, by_op=by_op)


# ---------------------------------------------------------------------------
# fusion-aware HBM-traffic estimate
# ---------------------------------------------------------------------------

# ops that materialize buffers on TPU too (fusion boundaries); everything
# else (standalone elementwise, plus the copies/transposes/pads/iotas the
# CPU backend inserts for layout but a TPU pipeline folds into neighbours)
# is assumed fused away — the CPU backend's sparse fusion makes raw
# `bytes accessed` an op-level overcount.
_MATERIALIZING = (
    "dot", "convolution", "fusion", "reduce", "reduce-window", "gather",
    "scatter", "dynamic-slice", "dynamic-update-slice", "sort", "rng",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s+=\s+((?:\([^=]*?\)|[\w\[\],{}\/ ]+?))\s+"
    r"([\w\-]+)\((.*)$")

_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def parse_memory_traffic(hlo_text: str) -> float:
    """Estimate per-device HBM bytes under TPU-like fusion: sum operand +
    result bytes over materializing ops only (dots, reduces, gathers,
    collectives, existing fusions...), skipping standalone elementwise ops
    that a TPU pipeline would fuse into neighbours."""
    shapes: dict = {}
    entries = []
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape_str, op, rest = m.groups()
        shapes[name] = _shape_bytes(shape_str)
        entries.append((name, op, rest))
    total = 0.0
    for name, op, rest in entries:
        base = op.replace("-start", "").replace("-done", "")
        if base not in _MATERIALIZING:
            continue
        if op.endswith("-done"):
            continue
        total += shapes.get(name, 0)
        # operand list terminates at "), " metadata; good enough to scan
        # the full tail for %refs that have known shapes.
        for ref in _OPERAND_RE.findall(rest.split("metadata=")[0]):
            total += shapes.get(ref, 0)
    return total


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float             # fusion-aware estimate (the scored term)
    collective_s: float
    memory_upper_s: float       # raw op-level bytes / bw (upper bound)
    flops: float
    bytes_accessed: float       # raw op-level (CPU-backend fusion)
    fused_bytes: float          # materializing-ops-only estimate
    wire_bytes: float
    model_flops: float          # analytic useful flops per device
    flops_ratio: float          # model_flops / hlo flops

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step-time estimate = max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the roofline-limited step: how close
        the step is to spending all its time on model flops at peak."""
        ideal = self.model_flops / PEAK_FLOPS
        return ideal / self.step_s if self.step_s > 0 else 0.0

    def to_dict(self) -> dict:
        return {**dataclasses.asdict(self),
                "dominant": self.dominant,
                "step_s": self.step_s,
                "roofline_fraction": self.roofline_fraction}


def model_flops_per_device(cfg, shape_kind: str, tokens: int,
                           n_devices: int) -> float:
    """Analytic 'useful' flops: 6ND train / 2ND per generated-or-prefilled
    token (MoE: active params)."""
    n = cfg.active_param_count()
    mult = 6.0 if shape_kind == "train" else 2.0
    return mult * n * tokens / n_devices


def build_roofline(cfg, shape_kind: str, tokens: int, n_devices: int,
                   flops: float, bytes_accessed: float,
                   colls: CollectiveStats, fused_bytes: float) -> Roofline:
    mf = model_flops_per_device(cfg, shape_kind, tokens, n_devices)
    if bytes_accessed:
        fused_bytes = min(fused_bytes, bytes_accessed)
    return Roofline(
        compute_s=flops / PEAK_FLOPS,
        memory_s=fused_bytes / HBM_BW,
        collective_s=colls.wire_bytes / ICI_BW,
        memory_upper_s=bytes_accessed / HBM_BW,
        flops=flops,
        bytes_accessed=bytes_accessed,
        fused_bytes=fused_bytes,
        wire_bytes=colls.wire_bytes,
        model_flops=mf,
        flops_ratio=mf / flops if flops else 0.0,
    )
