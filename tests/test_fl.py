"""FL orchestration integration tests: encrypted rounds, dropout,
stragglers, threshold decryption, checkpoint-resume, elasticity, FedProx,
async buffering."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.core.ckks import params as ckks_params
from repro.core.secure_agg import AggregatorConfig
from repro.data import make_client_streams
from repro.fl import (ClientConfig, FLClient, FLRunConfig, FLServer, FLTask)
from repro.fl.server import ReceivedUpdate
from repro.models import build_model

CTX = ckks_params.make_test_context(n_poly=256, n_limbs=2, delta_bits=20)


def tiny_task(n_clients=3, tmp=None, **run_kw):
    cfg = configs.get_config("qwen1.5-0.5b", smoke=True)
    import dataclasses
    cfg = dataclasses.replace(cfg, n_layers=1, d_model=32, n_heads=2,
                              n_kv_heads=2, d_ff=64, vocab=61)
    model = build_model(cfg)
    streams = make_client_streams(n_clients, cfg.vocab, seq_len=8,
                                  batch_size=2, seed=0)
    clients = [FLClient(i, model, streams[i],
                        ClientConfig(local_steps=1, sensitivity_probes=1))
               for i in range(n_clients)]
    run = FLRunConfig(n_rounds=2, seed=0, **run_kw)
    return FLTask(model, clients,
                  AggregatorConfig(p_ratio=0.2, strategy="top_p"),
                  run, ctx=CTX)


def test_encrypted_round_reduces_loss():
    task = tiny_task()
    logs = task.run()
    assert len(logs) == 2
    assert all(np.isfinite(l.loss) for l in logs)
    assert all(l.n_participating == 3 for l in logs)


def test_dropout_renormalizes():
    task = tiny_task(n_clients=4, dropout_prob=0.45)
    logs = task.run()
    dropped = sum(l.n_dropped for l in logs)
    assert dropped > 0                       # some clients failed
    assert all(np.isfinite(l.loss) for l in logs if l.n_participating)


def test_straggler_deadline_cuts():
    task = tiny_task(n_clients=4, straggler_prob=0.5, deadline_s=2.0)
    logs = task.run()
    assert sum(l.n_dropped for l in logs) > 0


def test_total_dropout_keeps_global_model():
    task = tiny_task(n_clients=2, dropout_prob=1.0)
    task.agree_encryption_mask()
    before = jax.tree_util.tree_leaves(task.global_params)
    log = task.run_round(0)
    after = jax.tree_util.tree_leaves(task.global_params)
    assert log.n_participating == 0
    for a, b in zip(before, after):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_threshold_mode_roundtrip():
    task = tiny_task(n_clients=3, threshold_mode=True)
    logs = task.run()
    assert all(np.isfinite(l.loss) for l in logs)


def test_checkpoint_resume(tmp_path):
    d = str(tmp_path / "ck")
    t1 = tiny_task(ckpt_dir=d)
    t1.run()
    # fresh task resumes from round 2 and runs nothing new at n_rounds=2
    t2 = tiny_task(ckpt_dir=d)
    t2.agree_encryption_mask()
    t2.maybe_resume()
    assert t2._start_round == 2
    for a, b in zip(jax.tree_util.tree_leaves(t1.global_params),
                    jax.tree_util.tree_leaves(t2.global_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_elastic_add_remove_client():
    task = tiny_task(n_clients=2)
    task.agree_encryption_mask()
    task.run_round(0)
    cfg = task.model.cfg
    from repro.data import SyntheticLM, dirichlet_partition
    prior = dirichlet_partition(1, cfg.vocab, seed=9)[0]
    newc = FLClient(99, task.model,
                    SyntheticLM(vocab=cfg.vocab, seq_len=8, batch_size=2,
                                client_prior=prior, seed=9),
                    ClientConfig(local_steps=1))
    task.add_client(newc)
    log = task.run_round(1)
    assert log.n_participating == 3
    task.remove_client(99)
    log = task.run_round(2)
    assert log.n_participating == 2


def test_fedprox_client_stays_closer():
    cfg = configs.get_config("qwen1.5-0.5b", smoke=True)
    import dataclasses
    cfg = dataclasses.replace(cfg, n_layers=1, d_model=32, n_heads=2,
                              n_kv_heads=2, d_ff=64, vocab=61)
    model = build_model(cfg)
    streams = make_client_streams(1, cfg.vocab, seq_len=8, batch_size=2)
    params = model.init(jax.random.PRNGKey(0))

    def drift(mu):
        c = FLClient(0, model, streams[0],
                     ClientConfig(local_steps=4, lr=5e-2, prox_mu=mu))
        local, _ = c.local_train(params)
        return sum(float(jnp.sum((a - b) ** 2)) for a, b in zip(
            jax.tree_util.tree_leaves(local),
            jax.tree_util.tree_leaves(params)))

    assert drift(mu=1.0) < drift(mu=0.0)


def test_wire_transport_measured_bytes():
    from repro.fl import WirePolicy
    task = tiny_task(wire_policy=WirePolicy(seed_ciphertexts=True,
                                            plain_codec="f16"))
    logs = task.run()
    assert all(np.isfinite(l.loss) for l in logs)
    # bytes are measured-on-wire, both directions, every round
    assert all(l.comm_measured for l in logs)
    assert all(l.comm_up_bytes > 0 and l.comm_down_bytes > 0 for l in logs)
    assert all(l.comm_bytes == l.comm_up_bytes + l.comm_down_bytes
               for l in logs)
    # streaming ingest kept server update buffers O(1) in clients (at most
    # one update's ready chunks resident) with one accumulate launch per
    # client update, not per chunk
    ing = task.server.last_ingest
    assert ing.peak_chunk_buffers == task.aggregator.part.n_chunks
    assert ing.accum_launches == ing.clients_ingested
    # ledger breakdown exists per artifact class
    s = task.ledger.round_summary(0)
    assert s["by_kind"]["up/seeded_ciphertext"] > 0
    assert s["by_kind"]["up/plain"] > 0


def test_async_fedbuff_buffer():
    task = tiny_task(n_clients=3)
    agg = task.agree_encryption_mask()
    server = FLServer(agg, buffer_size=2)
    ups = []
    for i, c in enumerate(task.clients):
        local, _ = c.local_train(task.global_params)
        ups.append(ReceivedUpdate(
            cid=i, n_samples=4, round_sent=i,
            update=agg.client_protect(local, task.pk,
                                      jax.random.PRNGKey(i))))
    assert server.submit_async(ups[0], current_round=2) is None
    out = server.submit_async(ups[1], current_round=2)   # buffer full
    assert out is not None
    rec = agg.client_recover_params(out, task.sk)
    assert all(bool(jnp.isfinite(l).all())
               for l in jax.tree_util.tree_leaves(rec))
