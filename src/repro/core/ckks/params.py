"""CKKS (RNS) parameter generation for the TPU-native u32 backend.

All ring arithmetic downstream is u32-only Montgomery (R = 2**32): primes are
NTT-friendly (q == 1 mod 2N) and < 2**30 so every Montgomery bound holds with
16-bit limb decomposition (see repro/kernels/ref.py).

Everything here is host-side Python/numpy executed once per context; the
resulting tables are plain numpy arrays handed to jitted code.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import numpy as np

# ---------------------------------------------------------------------------
# number theory (host-side, python ints)
# ---------------------------------------------------------------------------

_SMALL_PRIMES = [2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37]


def is_prime(n: int) -> bool:
    """Deterministic Miller-Rabin for n < 3.3e24 (we only use n < 2**31)."""
    if n < 2:
        return False
    for p in _SMALL_PRIMES:
        if n % p == 0:
            return n == p
    d, r = n - 1, 0
    while d % 2 == 0:
        d //= 2
        r += 1
    for a in (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37):
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(r - 1):
            x = x * x % n
            if x == n - 1:
                break
        else:
            return False
    return True


def find_ntt_primes(n_poly: int, count: int, max_bits: int = 30) -> list[int]:
    """Largest `count` primes q < 2**max_bits with q == 1 (mod 2*n_poly)."""
    step = 2 * n_poly
    q = ((1 << max_bits) - 1) // step * step + 1
    primes: list[int] = []
    while len(primes) < count and q > (1 << 20):
        if is_prime(q):
            primes.append(q)
        q -= step
    if len(primes) < count:
        raise ValueError(f"could not find {count} NTT primes for N={n_poly}")
    return primes


def _primitive_root(q: int) -> int:
    """Smallest primitive root modulo prime q."""
    phi = q - 1
    factors = set()
    m = phi
    d = 2
    while d * d <= m:
        while m % d == 0:
            factors.add(d)
            m //= d
        d += 1
    if m > 1:
        factors.add(m)
    for g in range(2, q):
        if all(pow(g, phi // f, q) != 1 for f in factors):
            return g
    raise ValueError("no primitive root")


def root_of_unity(q: int, order: int) -> int:
    """A primitive `order`-th root of unity mod q (order | q-1)."""
    assert (q - 1) % order == 0
    g = _primitive_root(q)
    w = pow(g, (q - 1) // order, q)
    assert pow(w, order, q) == 1 and pow(w, order // 2, q) != 1
    return w


def bit_reverse(x: int, bits: int) -> int:
    out = 0
    for _ in range(bits):
        out = (out << 1) | (x & 1)
        x >>= 1
    return out


def ntt4_split(n_poly: int) -> tuple[int, int]:
    """Default factorization N = n1 * n2 for the 4-step transpose NTT
    (DESIGN.md §10).

    n1 <= n2, both powers of two, as close to sqrt(N) as possible — for
    N=8192 this is 64 x 128, so the second sub-transform's vectorized
    spectator axis spans a full 128-lane TPU register.  This is the
    heuristic the autotuner (kernels/tune.py, DESIGN.md §12) falls back to;
    `ntt4_split_candidates` enumerates the splits it sweeps instead.
    """
    logn = n_poly.bit_length() - 1
    k = logn // 2
    return 1 << k, n_poly >> k


def ntt4_split_candidates(n_poly: int) -> tuple[tuple[int, int], ...]:
    """Power-of-two splits around sqrt(N) the autotuner sweeps — the sqrt
    heuristic plus its two neighbours (32x256 / 64x128 / 128x64 at N=8192).
    Every candidate keeps both sub-transform lengths >= 2 so the LN
    butterfly recurrences stay non-degenerate."""
    logn = n_poly.bit_length() - 1
    mid = logn // 2
    out = []
    for k in (mid - 1, mid, mid + 1):
        if 1 <= k <= logn - 1:
            pair = (1 << k, n_poly >> k)
            if pair not in out:
                out.append(pair)
    return tuple(out)


# ---------------------------------------------------------------------------
# per-prime (limb) Montgomery + NTT tables
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LimbContext:
    """All constants for one RNS limb prime q (< 2**30)."""

    q: int
    # Montgomery constants, R = 2**32
    qinv_neg: int        # -q^{-1} mod 2**32
    r2: int              # R^2 mod q  (to_mont multiplicand)
    one_mont: int        # R mod q
    # negacyclic NTT tables (Longa-Naehrig layout), in Montgomery form
    psi_rev_mont: np.ndarray      # [N] u32, psi^bitrev(i) * R mod q
    psi_inv_rev_mont: np.ndarray  # [N] u32
    n_inv_mont: np.ndarray        # scalar u32 array, N^{-1} * R mod q
    # 4-step transpose NTT tables (DESIGN.md §10), N = n1 * n2
    # sub-transform 1: LN table of mu = psi^n2 (a primitive 2*n1-th root)
    ntt4_psi1_mont: np.ndarray      # [n1] u32
    ntt4_psi1_inv_mont: np.ndarray  # [n1] u32
    # sub-transform 2: LN table of chi = psi^n1 (a primitive 2*n2-th root)
    ntt4_psi2_mont: np.ndarray      # [n2] u32
    ntt4_psi2_inv_mont: np.ndarray  # [n2] u32
    # inter-step correction, [bitrev(k1)][j2] = psi^(j2*(2*k1+1-n1)), flat [N]
    ntt4_corr_mont: np.ndarray      # [N] u32
    ntt4_corr_inv_mont: np.ndarray  # [N] u32

    def to_mont_scalar(self, x: int) -> int:
        """x -> x*R mod q (host-side)."""
        return (x % self.q) * (1 << 32) % self.q


@functools.lru_cache(maxsize=64)
def make_limb_context(q: int, n_poly: int) -> LimbContext:
    assert q < (1 << 30), "Montgomery u32 bounds require q < 2**30"
    assert (q - 1) % (2 * n_poly) == 0
    logn = n_poly.bit_length() - 1
    r = 1 << 32
    qinv = pow(q, -1, r)
    qinv_neg = (-qinv) % r
    r2 = r * r % q
    psi = root_of_unity(q, 2 * n_poly)   # primitive 2N-th root (negacyclic)
    psi_inv = pow(psi, -1, q)

    def mont(x: int) -> int:
        return x * r % q

    psi_rev = np.zeros(n_poly, dtype=np.uint32)
    psi_inv_rev = np.zeros(n_poly, dtype=np.uint32)
    for i in range(n_poly):
        j = bit_reverse(i, logn)
        psi_rev[i] = mont(pow(psi, j, q))
        psi_inv_rev[i] = mont(pow(psi_inv, j, q))
    n_inv = pow(n_poly, -1, q)

    # 4-step transpose NTT tables (DESIGN.md §10) at the default sqrt split;
    # kernels/tune.py builds variant-split tables through ntt4_limb_tables.
    n1, n2 = ntt4_split(n_poly)
    psi1, psi1_inv, psi2, psi2_inv, corr, corr_inv = \
        _ntt4_limb_tables(q, n_poly, n1, n2)

    return LimbContext(
        q=q,
        qinv_neg=qinv_neg,
        r2=r2,
        one_mont=r % q,
        psi_rev_mont=psi_rev,
        psi_inv_rev_mont=psi_inv_rev,
        n_inv_mont=np.asarray(mont(n_inv), dtype=np.uint32),
        ntt4_psi1_mont=psi1,
        ntt4_psi1_inv_mont=psi1_inv,
        ntt4_psi2_mont=psi2,
        ntt4_psi2_inv_mont=psi2_inv,
        ntt4_corr_mont=corr,
        ntt4_corr_inv_mont=corr_inv,
    )


@functools.lru_cache(maxsize=256)
def _ntt4_limb_tables(q: int, n_poly: int, n1: int, n2: int) -> tuple:
    """4-step NTT tables for one limb at an ARBITRARY split N = n1 * n2.

    With x[j] = x[j2 + n2*j1], the full negacyclic NTT factors into a
    length-n1 negacyclic LN NTT over j1 with mu = psi^n2 (mu^2 = omega^n2,
    pre-twist mu^j1 folded in), an elementwise correction
    psi^(j2*(2*k1+1-n1)) (which folds the psi^j2 pre-twist, the
    omega^(j2*k1) cross twiddle, and the chi^(-j2) un-twist of
    sub-transform 2), a transpose, and a length-n2 negacyclic LN NTT over
    j2 with chi = psi^n1.  All sub-tables are LN bit-reversed Montgomery,
    like psi_rev_mont.  The derivation never assumes n1 <= n2, so the
    autotuner's "wide" splits (e.g. 128x64 at N=8192) reuse this verbatim.

    Returns (psi1, psi1_inv, psi2, psi2_inv, corr_flat, corr_inv_flat).
    """
    assert n1 * n2 == n_poly and n1 >= 2 and n2 >= 2, (n1, n2, n_poly)
    r = 1 << 32
    psi = root_of_unity(q, 2 * n_poly)
    k_bits, r_bits = n1.bit_length() - 1, n2.bit_length() - 1

    def mont(x: int) -> int:
        return x * r % q

    mu, chi = pow(psi, n2, q), pow(psi, n1, q)
    mu_inv, chi_inv = pow(mu, -1, q), pow(chi, -1, q)
    psi1 = np.zeros(n1, dtype=np.uint32)
    psi1_inv = np.zeros(n1, dtype=np.uint32)
    for i in range(n1):
        j = bit_reverse(i, k_bits)
        psi1[i] = mont(pow(mu, j, q))
        psi1_inv[i] = mont(pow(mu_inv, j, q))
    psi2 = np.zeros(n2, dtype=np.uint32)
    psi2_inv = np.zeros(n2, dtype=np.uint32)
    for i in range(n2):
        j = bit_reverse(i, r_bits)
        psi2[i] = mont(pow(chi, j, q))
        psi2_inv[i] = mont(pow(chi_inv, j, q))
    corr = np.zeros((n1, n2), dtype=np.uint32)
    corr_inv = np.zeros((n1, n2), dtype=np.uint32)
    for k1 in range(n1):
        w = pow(psi, (2 * k1 + 1 - n1) % (2 * n_poly), q)
        w_inv = pow(w, -1, q)
        row = bit_reverse(k1, k_bits)
        c = ci = 1
        for j2 in range(n2):
            corr[row, j2] = mont(c)
            corr_inv[row, j2] = mont(ci)
            c = c * w % q
            ci = ci * w_inv % q
    return (psi1, psi1_inv, psi2, psi2_inv, corr.reshape(-1),
            corr_inv.reshape(-1))


# ---------------------------------------------------------------------------
# stacked limb tables (the limb-fused execution engine's constant layout)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LimbTables:
    """Per-limb constants stacked along a leading limb axis.

    This is the layout the limb-fused kernels consume: RNS limbs are a
    grid/batch dimension, so every constant a kernel needs is a u32[L] (or
    u32[L, N] for twiddles) table indexed by the limb coordinate instead of a
    Python-level loop over `CkksContext.limbs`.  All arrays are host numpy;
    jitted code embeds the (sliced) tables as constants.
    """

    qs: np.ndarray                # u32[L] limb primes
    qinv_negs: np.ndarray         # u32[L] -q^{-1} mod 2**32
    r2s: np.ndarray               # u32[L] R^2 mod q
    one_monts: np.ndarray         # u32[L] R mod q
    n_inv_monts: np.ndarray       # u32[L] N^{-1} * R mod q
    psi_rev_mont: np.ndarray      # u32[L, N] forward twiddles (Montgomery)
    psi_inv_rev_mont: np.ndarray  # u32[L, N] inverse twiddles (Montgomery)
    # 4-step transpose NTT tables (DESIGN.md §10), N = n1 * n2: still
    # stacked u32[L, .] with the limb axis leading, so the sharded engine's
    # limb-axis table sharding covers them with no new plumbing.
    ntt4_psi1_mont: np.ndarray      # u32[L, n1] sub-NTT-1 fwd twiddles
    ntt4_psi1_inv_mont: np.ndarray  # u32[L, n1]
    ntt4_psi2_mont: np.ndarray      # u32[L, n2] sub-NTT-2 fwd twiddles
    ntt4_psi2_inv_mont: np.ndarray  # u32[L, n2]
    ntt4_corr_mont: np.ndarray      # u32[L, N] inter-step correction
    ntt4_corr_inv_mont: np.ndarray  # u32[L, N]

    @property
    def n_limbs(self) -> int:
        return int(self.qs.shape[0])

    def take(self, l: int) -> "LimbTables":
        """First-l-limb slice (limb-dropped ciphertexts keep leading limbs)."""
        if l == self.n_limbs:
            return self
        assert 1 <= l <= self.n_limbs, (l, self.n_limbs)
        return LimbTables(
            qs=self.qs[:l], qinv_negs=self.qinv_negs[:l], r2s=self.r2s[:l],
            one_monts=self.one_monts[:l], n_inv_monts=self.n_inv_monts[:l],
            psi_rev_mont=self.psi_rev_mont[:l],
            psi_inv_rev_mont=self.psi_inv_rev_mont[:l],
            ntt4_psi1_mont=self.ntt4_psi1_mont[:l],
            ntt4_psi1_inv_mont=self.ntt4_psi1_inv_mont[:l],
            ntt4_psi2_mont=self.ntt4_psi2_mont[:l],
            ntt4_psi2_inv_mont=self.ntt4_psi2_inv_mont[:l],
            ntt4_corr_mont=self.ntt4_corr_mont[:l],
            ntt4_corr_inv_mont=self.ntt4_corr_inv_mont[:l],
        )


def _stack_limb_tables(limbs: "tuple[LimbContext, ...]") -> LimbTables:
    return LimbTables(
        qs=np.asarray([lc.q for lc in limbs], dtype=np.uint32),
        qinv_negs=np.asarray([lc.qinv_neg for lc in limbs], dtype=np.uint32),
        r2s=np.asarray([lc.r2 for lc in limbs], dtype=np.uint32),
        one_monts=np.asarray([lc.one_mont for lc in limbs], dtype=np.uint32),
        n_inv_monts=np.asarray([lc.n_inv_mont for lc in limbs],
                               dtype=np.uint32),
        psi_rev_mont=np.stack([lc.psi_rev_mont for lc in limbs], axis=0),
        psi_inv_rev_mont=np.stack([lc.psi_inv_rev_mont for lc in limbs],
                                  axis=0),
        ntt4_psi1_mont=np.stack([lc.ntt4_psi1_mont for lc in limbs], axis=0),
        ntt4_psi1_inv_mont=np.stack([lc.ntt4_psi1_inv_mont for lc in limbs],
                                    axis=0),
        ntt4_psi2_mont=np.stack([lc.ntt4_psi2_mont for lc in limbs], axis=0),
        ntt4_psi2_inv_mont=np.stack([lc.ntt4_psi2_inv_mont for lc in limbs],
                                    axis=0),
        ntt4_corr_mont=np.stack([lc.ntt4_corr_mont for lc in limbs], axis=0),
        ntt4_corr_inv_mont=np.stack([lc.ntt4_corr_inv_mont for lc in limbs],
                                    axis=0),
    )


@functools.lru_cache(maxsize=64)
def ntt4_variant_tables(primes: tuple, n_poly: int, n1: int,
                        n2: int) -> dict:
    """Stacked u32[L, .] 4-step tables for a NON-default split n1 x n2.

    The autotuner's split sweep (kernels/tune.py) needs the six ntt4_*
    tables at every candidate factorization; the per-limb math is shared
    with `make_limb_context` via `_ntt4_limb_tables`.  Returns a dict of
    LimbTables field name -> stacked array, ready for
    `retable_ntt4` / dataclasses.replace.
    """
    per_limb = [_ntt4_limb_tables(int(q), n_poly, n1, n2) for q in primes]
    names = ("ntt4_psi1_mont", "ntt4_psi1_inv_mont", "ntt4_psi2_mont",
             "ntt4_psi2_inv_mont", "ntt4_corr_mont", "ntt4_corr_inv_mont")
    return {name: np.stack([t[i] for t in per_limb], axis=0)
            for i, name in enumerate(names)}


def retable_ntt4(tables: LimbTables, n1: int, n2: int) -> LimbTables:
    """`tables` with its six ntt4_* fields swapped for the n1 x n2 split.

    Host-side only: the limb primes are read back off the numpy `qs` row
    (exact — they are the primes themselves), so this cannot be used on
    traced/sharded table slices; the registry falls back to the default
    split there (kernels/ops.py)."""
    n_poly = int(tables.psi_rev_mont.shape[-1])
    if (n1, n2) == ntt4_split(n_poly):
        return tables
    primes = tuple(int(q) for q in np.asarray(tables.qs))
    return dataclasses.replace(
        tables, **ntt4_variant_tables(primes, n_poly, n1, n2))


# ---------------------------------------------------------------------------
# full CKKS context
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CkksContext:
    """RNS-CKKS context, depth-1 chain (the paper's setting).

    Shape conventions (shared by every module downstream):
      * ciphertext tensors: u32[..., L, 2, N] in bit-reversed NTT domain
        (L = n_limbs RNS limbs, 2 polynomial components, ring degree N);
      * kernel-level ops see limbs at axis -2: u32[..., L, N];
      * per-limb constants: stacked u32[L] / u32[L, N] tables (`tables`).

    `delta` is the encoding scale; after the one ct x plain weighting the
    scale is delta**2 and we *lazily* skip rescale (divide at decode) —
    see DESIGN.md §3.  Frozen and hashable: a context is the static jit
    key of every cached crypto graph, and the sharded engine
    (core/ckks/sharded.py) shards `tables` along its mesh's model axis.
    """

    n_poly: int                 # ring degree N (slots = N/2)
    primes: tuple[int, ...]     # RNS limb primes, big -> small
    delta_bits: int             # encoding scale = 2**delta_bits
    security_lambda: int = 128  # nominal; N>=8192 & logQ<=60 clears 128-bit
    error_sigma: float = 3.2    # RLWE noise stddev
    hamming_weight: int = 0     # 0 => uniform ternary secret

    @property
    def n_limbs(self) -> int:
        return len(self.primes)

    @property
    def slots(self) -> int:
        return self.n_poly // 2

    @property
    def delta(self) -> float:
        return float(2 ** self.delta_bits)

    @property
    def big_q(self) -> int:
        out = 1
        for q in self.primes:
            out *= q
        return out

    @property
    def log_q(self) -> float:
        return math.log2(self.big_q)

    @functools.cached_property
    def limbs(self) -> tuple[LimbContext, ...]:
        return tuple(make_limb_context(q, self.n_poly) for q in self.primes)

    @functools.cached_property
    def tables(self) -> LimbTables:
        """Stacked u32[L]/u32[L, N] constant tables for the fused engine."""
        return _stack_limb_tables(self.limbs)

    # -- serialized-size model (for the paper's communication tables) -------
    def ciphertext_bytes(self, packed: bool = True) -> int:
        """Bytes to ship one ciphertext.

        packed=True models entropy-optimal serialization (ceil(log2 q) bits
        per coefficient, what PALISADE approximates); packed=False is the raw
        u32 wire format this implementation would DMA.
        """
        if packed:
            bits = sum(q.bit_length() for q in self.primes) * 2 * self.n_poly
            return (bits + 7) // 8
        return self.n_limbs * 2 * self.n_poly * 4

    def plaintext_bytes(self, n_values: int) -> int:
        return 4 * n_values  # f32 wire format

    def num_ciphertexts(self, n_values: int) -> int:
        return max(0, -(-n_values // self.slots))

    def encrypted_bytes(self, n_values: int, packed: bool = True) -> int:
        return self.num_ciphertexts(n_values) * self.ciphertext_bytes(packed)


def make_context(
    n_poly: int = 8192,
    n_limbs: int = 2,
    delta_bits: int = 26,
    max_prime_bits: int = 30,
) -> CkksContext:
    """Build a context. Defaults mirror the paper: packing batch 4096 slots
    (N=8192), multiplicative depth 1, 128-bit security."""
    assert n_poly & (n_poly - 1) == 0, "N must be a power of two"
    primes = tuple(find_ntt_primes(n_poly, n_limbs, max_prime_bits))
    # depth-1 headroom: values*delta**2 must stay below Q/2 at decode
    headroom_bits = sum(q.bit_length() for q in primes) - 2 * delta_bits - 1
    if headroom_bits < 4:
        raise ValueError(
            f"insufficient modulus headroom: logQ~{sum(q.bit_length() for q in primes)}"
            f" vs 2*delta_bits={2 * delta_bits}; add limbs or shrink delta"
        )
    return CkksContext(n_poly=n_poly, primes=primes, delta_bits=delta_bits)


# Small context for tests/examples on CPU.
def make_test_context(n_poly: int = 256, n_limbs: int = 2, delta_bits: int = 20):
    return make_context(n_poly=n_poly, n_limbs=n_limbs, delta_bits=delta_bits)
