"""The paper's technique: packing, selection, DP accounting, sensitivity,
and Algorithm 1 end-to-end (+ hypothesis properties)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pragma: no cover
    from _hyp import given, settings, st

from repro.core import dp, packing, secure_agg, selection, sensitivity
from repro.core.ckks import cipher
from repro.core.ckks import params as ckks_params
from repro.core.secure_agg import AggregatorConfig, SelectiveHEAggregator

CTX = ckks_params.make_test_context(n_poly=256, n_limbs=2, delta_bits=20)
SK, PK = cipher.keygen(CTX, jax.random.PRNGKey(0))


def small_model(seed=1):
    r = np.random.RandomState(seed)
    return {"w1": jnp.asarray(r.randn(40, 30), jnp.float32),
            "b1": jnp.asarray(r.randn(30), jnp.float32),
            "w2": jnp.asarray(r.randn(30, 5), jnp.float32)}


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------


def test_flatten_roundtrip():
    m = small_model()
    vec, spec = packing.flatten_params(m)
    assert vec.shape == (40 * 30 + 30 + 150,)
    m2 = packing.unflatten_params(vec, spec)
    for a, b in zip(jax.tree_util.tree_leaves(m),
                    jax.tree_util.tree_leaves(m2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


@given(p=st.floats(0.0, 1.0), n=st.integers(10, 500))
@settings(max_examples=25, deadline=None)
def test_split_merge_roundtrip(p, n):
    rng = np.random.RandomState(0)
    vec = jnp.asarray(rng.randn(n), jnp.float32)
    mask = selection.random_mask(p, n, seed=3)
    part = packing.make_partition(mask, slots=32)
    enc, plain = packing.split_by_mask(vec, part)
    out = packing.merge_by_mask(enc, plain, part)
    np.testing.assert_allclose(np.asarray(out), np.asarray(vec))


# ---------------------------------------------------------------------------
# selection
# ---------------------------------------------------------------------------


@given(p1=st.floats(0.0, 1.0), p2=st.floats(0.0, 1.0))
@settings(max_examples=25, deadline=None)
def test_mask_monotonicity(p1, p2):
    """p1 <= p2  =>  mask(p1) subset mask(p2) (for top_p and random)."""
    lo, hi = min(p1, p2), max(p1, p2)
    s = np.random.RandomState(1).randn(400)
    m_lo, m_hi = selection.top_p_mask(s, lo), selection.top_p_mask(s, hi)
    assert (m_lo <= m_hi).all()
    r_lo = selection.random_mask(lo, 400, seed=5)
    r_hi = selection.random_mask(hi, 400, seed=5)
    assert (r_lo <= r_hi).all()


def test_top_p_selects_largest():
    s = np.asarray([0.1, 5.0, -7.0, 0.01, 2.0])
    m = selection.top_p_mask(s, 0.4)
    np.testing.assert_array_equal(m, [False, True, True, False, False])


def test_recipe_includes_first_last_layers():
    sens = np.zeros(100)
    sens[50] = 1.0
    m = selection.recipe_mask(sens, 0.01, offsets=(0, 10, 90),
                              sizes=(10, 80, 10))
    assert m[:10].all() and m[90:].all() and m[50]


def test_per_layer_top_p():
    s = np.concatenate([np.full(10, 10.0), np.full(10, 0.1)])
    m = selection.per_layer_top_p_mask(s, 0.5, offsets=(0, 10), sizes=(10, 10))
    assert m[:5].sum() == 5 and m[10:15].sum() == 5


# ---------------------------------------------------------------------------
# DP accounting (paper §3)
# ---------------------------------------------------------------------------


def test_epsilon_ordering_selective_beats_random():
    """Remarks 3.12-3.14: eps_selective < eps_random < eps_none."""
    s = np.random.RandomState(2).rand(10_000)      # Delta f ~ U(0,1)
    out = dp.selection_advantage(s, p=0.3, b=1.0)
    assert out["eps_selective"] < out["eps_random"] < out["eps_none"]


@pytest.mark.parametrize("p", [0.1, 0.3, 0.5, 0.9])
def test_epsilon_closed_forms_under_uniform(p):
    """Empirical eps matches (1-p)J random and (1-p)^2 J selective under
    Delta f ~ U(0,1)."""
    s = np.random.RandomState(3).rand(200_000)
    j = dp.epsilon_all_plaintext(s, 1.0)
    out = dp.selection_advantage(s, p=p, b=1.0)
    np.testing.assert_allclose(out["eps_random"],
                               dp.epsilon_uniform_random(j, p), rtol=0.02)
    np.testing.assert_allclose(out["eps_selective"],
                               dp.epsilon_uniform_selective(j, p), rtol=0.02)


@given(b=st.floats(0.1, 10.0))
@settings(max_examples=10, deadline=None)
def test_epsilon_composition_additivity(b):
    s = np.random.RandomState(4).rand(1000)
    m1 = np.zeros(1000, bool)
    m1[:500] = True
    eps_half = dp.epsilon_total(s, m1, b)
    eps_all = dp.epsilon_total(s, np.zeros(1000, bool), b)
    np.testing.assert_allclose(eps_half + dp.epsilon_total(s, ~m1, b),
                               eps_all, rtol=1e-9)


def test_laplace_noise_scale():
    key = jax.random.PRNGKey(0)
    v = jnp.zeros((200_000,))
    noised = dp.laplace_noise_vec(v, key, b=2.0)
    # Var of Laplace(b) = 2 b^2
    assert abs(float(jnp.var(noised)) - 8.0) < 0.3


# ---------------------------------------------------------------------------
# sensitivity
# ---------------------------------------------------------------------------


def _mlp_loss(params, x, y_soft):
    h = jnp.tanh(x @ params["w1"] + params["b1"])
    logp = jax.nn.log_softmax(h @ params["w2"])
    return -jnp.mean(jnp.sum(y_soft * logp, axis=-1))


def test_sensitivity_exact_vs_jvp_ranking():
    p0 = jax.tree_util.tree_map(lambda x: x * 0.1, small_model(7))
    rng = np.random.RandomState(8)
    x = jnp.asarray(rng.randn(16, 40), jnp.float32)
    y = jax.nn.one_hot(jnp.asarray(rng.randint(0, 5, 16)), 5)
    se = sensitivity.sensitivity_exact(_mlp_loss, p0, x, y)
    sj = sensitivity.sensitivity_jvp(_mlp_loss, p0, x, y,
                                     jax.random.PRNGKey(9), n_probes=32)
    ve, _ = packing.flatten_params(se)
    vj, _ = packing.flatten_params(sj)
    ve, vj = np.asarray(ve), np.asarray(vj)
    ra = np.argsort(np.argsort(ve))
    rb = np.argsort(np.argsort(vj))
    rho = np.corrcoef(ra, rb)[0, 1]
    assert rho > 0.8, rho
    # top-20% masks overlap well
    me = selection.top_p_mask(ve, 0.2)
    mj = selection.top_p_mask(vj, 0.2)
    assert (me & mj).sum() / me.sum() > 0.5


def test_sensitivity_nonnegative():
    p0 = small_model(10)
    rng = np.random.RandomState(11)
    x = jnp.asarray(rng.randn(4, 40), jnp.float32)
    y = jax.nn.one_hot(jnp.asarray(rng.randint(0, 5, 4)), 5)
    s = sensitivity.sensitivity_jvp(_mlp_loss, p0, x, y,
                                    jax.random.PRNGKey(1), n_probes=2)
    assert all(bool((l >= 0).all()) for l in jax.tree_util.tree_leaves(s))


# ---------------------------------------------------------------------------
# Algorithm 1 end-to-end
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy,p", [("top_p", 0.3), ("random", 0.5),
                                        ("all", 1.0), ("none", 0.0),
                                        ("recipe", 0.2), ("per_layer", 0.25)])
def test_algorithm1_aggregation_exact(strategy, p):
    model = small_model(12)
    sens = np.abs(np.random.RandomState(13).randn(1380))
    agg = SelectiveHEAggregator.build(
        CTX, model, sens, AggregatorConfig(p_ratio=p, strategy=strategy))
    models, ups = [], []
    for i in range(3):
        m = jax.tree_util.tree_map(lambda x: x + 0.05 * (i + 1), model)
        models.append(m)
        ups.append(agg.client_protect(m, PK, jax.random.PRNGKey(100 + i)))
    ws = [0.5, 0.3, 0.2]
    glob = agg.server_aggregate(ups, ws)
    rec = agg.client_recover_params(glob, SK)
    expect = jax.tree_util.tree_map(
        lambda *xs: sum(w * x for w, x in zip(ws, xs)), *models)
    for a, b in zip(jax.tree_util.tree_leaves(rec),
                    jax.tree_util.tree_leaves(expect)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-2)


def test_fedavg_equal_clients_equals_single():
    """FedAvg of identical models == the model (homomorphism sanity)."""
    model = small_model(14)
    sens = np.abs(np.random.RandomState(15).randn(1380))
    agg = SelectiveHEAggregator.build(
        CTX, model, sens, AggregatorConfig(p_ratio=0.4))
    ups = [agg.client_protect(model, PK, jax.random.PRNGKey(200 + i))
           for i in range(4)]
    glob = agg.server_aggregate(ups, [0.25] * 4)
    rec = agg.client_recover_params(glob, SK)
    for a, b in zip(jax.tree_util.tree_leaves(rec),
                    jax.tree_util.tree_leaves(model)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-2)


def test_overhead_report_scales_with_p():
    model = small_model(16)
    sens = np.abs(np.random.RandomState(17).randn(1380))
    reps = [SelectiveHEAggregator.build(
        CTX, model, sens, AggregatorConfig(p_ratio=p)).overhead_report()
        for p in (0.1, 0.5, 1.0)]
    assert reps[0]["bytes_encrypted"] < reps[1]["bytes_encrypted"] \
        <= reps[2]["bytes_encrypted"]
    assert reps[0]["comm_ratio"] < reps[2]["comm_ratio"]


def test_mask_agreement_mechanism():
    sens = np.abs(np.random.RandomState(18).randn(500))
    locals_ = [sens + 0.01 * np.random.RandomState(i).randn(500)
               for i in range(3)]
    mask = secure_agg.agree_mask(CTX, PK, SK, locals_, [1 / 3] * 3, 0.2,
                                 jax.random.PRNGKey(19))
    ref = selection.top_p_mask(sens, 0.2)
    assert (mask & ref).sum() / ref.sum() > 0.9
    assert abs(int(mask.sum()) - int(ref.sum())) <= 2


def test_dp_noise_on_plaintext_part():
    model = small_model(20)
    sens = np.abs(np.random.RandomState(21).randn(1380))
    agg = SelectiveHEAggregator.build(
        CTX, model, sens, AggregatorConfig(p_ratio=0.3, dp_b=0.5))
    up = agg.client_protect(model, PK, jax.random.PRNGKey(22))
    vec, _ = packing.flatten_params(model)
    plain_clean = np.asarray(vec)[agg.part.plain_idx]
    diff = np.abs(np.asarray(up.plain) - plain_clean)
    assert diff.mean() > 0.1          # noise present
    eps = dp.epsilon_total(sens, ~np.isin(np.arange(1380),
                                          agg.part.plain_idx), 0.5)
    assert np.isfinite(eps)
