"""Quickstart: encrypt a model update, aggregate under CKKS, decrypt.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import packing, selection
from repro.core.ckks import cipher, params as ckks_params
from repro.core.secure_agg import AggregatorConfig, SelectiveHEAggregator


def main():
    # 1. crypto context (paper defaults scaled down for a quick run:
    #    packing batch 512 slots, depth-1, two ~29-bit RNS limbs)
    ctx = ckks_params.make_context(n_poly=1024, n_limbs=2, delta_bits=24)
    sk, pk = cipher.keygen(ctx, jax.random.PRNGKey(0))
    print(f"CKKS: N={ctx.n_poly} slots={ctx.slots} logQ~{ctx.log_q:.0f} "
          f"delta=2^{ctx.delta_bits}")

    # 2. a 'model' + per-parameter sensitivity (here synthetic; see
    #    examples/encrypted_finetune.py for real sensitivity maps)
    rng = np.random.RandomState(0)
    model = {"w1": jnp.asarray(rng.randn(256, 64), jnp.float32),
             "w2": jnp.asarray(rng.randn(64, 10), jnp.float32)}
    n_params = 256 * 64 + 64 * 10
    sens = np.abs(rng.randn(n_params))

    # 3. Selective Parameter Encryption at p=0.1
    agg = SelectiveHEAggregator.build(
        ctx, model, sens, AggregatorConfig(p_ratio=0.1, strategy="top_p"))
    rep = agg.overhead_report()
    print(f"encrypting {rep['n_enc']}/{rep['n_total']} params "
          f"({rep['ratio']:.0%}) in {rep['n_ciphertexts']} ciphertexts; "
          f"comm ratio vs plaintext {rep['comm_ratio']:.2f}x")

    # 4. three clients -> encrypted FedAvg -> decrypt
    clients = [jax.tree_util.tree_map(lambda x: x + 0.1 * i, model)
               for i in range(3)]
    updates = [agg.client_protect(m, pk, jax.random.PRNGKey(10 + i))
               for i, m in enumerate(clients)]
    glob = agg.server_aggregate(updates, [1 / 3] * 3)
    recovered = agg.client_recover_params(glob, sk)

    expect = jax.tree_util.tree_map(lambda *xs: sum(xs) / 3, *clients)
    err = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree_util.tree_leaves(recovered),
        jax.tree_util.tree_leaves(expect)))
    print(f"aggregation max error vs plaintext FedAvg: {err:.2e}")
    assert err < 1e-2
    print("OK")


if __name__ == "__main__":
    main()
