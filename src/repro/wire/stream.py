"""Streaming uplink ingest: fold each arriving ciphertext chunk into the
running modular accumulator, never materializing all n_clients updates.

Client side — pack_update_frames() emits, per update:

    UPDATE_BEGIN   (cid, n_samples, round, n_chunks, ct_kind)
    CT_CHUNK * n   (chunk_idx + one-chunk ciphertext/seeded-ciphertext frame)
    PLAIN_SEGMENT  (quantized plaintext partition)
    UPDATE_END

Server side — StreamIngest parses frames incrementally (any byte slicing)
and performs  acc[chunk] = acc[chunk] + w (*) ct_chunk  the moment a chunk
arrives, via the limb-fused accumulate kernel (he_agg.he_weighted_accum_fused
through ops.weighted_accum — one launch covers every RNS limb) wrapped in a
single jitted graph keyed on (ctx, backend registry).  Server-side update
buffers are O(1) in the number of clients: one accumulator plus at most one
in-flight chunk (peak_chunk_buffers instruments this; tests assert it).

The modular arithmetic is identical to the batch weighted_sum applied in
arrival order, so the streamed aggregate is bit-for-bit equal to the
in-memory path.
"""
from __future__ import annotations

import dataclasses
import functools
import struct

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ckks import encoding
from repro.core.ckks.cipher import Ciphertext
from repro.core.ckks.params import CkksContext
from repro.core.secure_agg import ProtectedUpdate
from repro.kernels import ops
from repro.wire import compress as _c
from repro.wire import format as wf

_BEGIN = struct.Struct("<IIIIB")

CT_FULL = 0
CT_SEEDED = 1


@dataclasses.dataclass(frozen=True)
class UpdateMeta:
    cid: int
    n_samples: int
    round: int
    n_chunks: int
    seeded: bool


# ---------------------------------------------------------------------------
# client side: update -> frames
# ---------------------------------------------------------------------------


def pack_update_frames(upd: ProtectedUpdate, *, cid: int, n_samples: int,
                       rnd: int = 0,
                       seeded: _c.SeededCiphertext | None = None,
                       plain_codec: str = "f32") -> bytes:
    """One client's ProtectedUpdate -> concatenated wire frames.

    If `seeded` is given (from compress.seed_compress on a seeded encryption)
    each CT_CHUNK carries (seed, c0-chunk) instead of the full chunk.
    """
    n_chunks = int(upd.ct.data.shape[0])
    kind = CT_SEEDED if seeded is not None else CT_FULL
    out = [wf.frame(wf.T_UPDATE_BEGIN,
                    _BEGIN.pack(cid, n_samples, rnd, n_chunks, kind))]
    ct_host = np.asarray(seeded.c0 if seeded is not None else upd.ct.data)
    for b in range(n_chunks):
        if seeded is not None:
            chunk = _c.SeededCiphertext(c0=ct_host[b:b + 1],
                                        seed=seeded.seed, scale=seeded.scale,
                                        chunk_offset=b)
            inner = wf.serialize_seeded_ciphertext(chunk)
        else:
            inner = wf.serialize_ciphertext(Ciphertext(
                data=ct_host[b:b + 1], scale=upd.ct.scale))
        out.append(wf.frame(wf.T_CT_CHUNK, struct.pack("<I", b) + inner))
    arr, qscale = _c.quantize_plain(np.asarray(upd.plain), plain_codec)
    out.append(wf.serialize_plain_segment(arr, plain_codec, qscale))
    out.append(wf.frame(wf.T_UPDATE_END, b""))
    return b"".join(out)


def peek_update_meta(blob: bytes) -> UpdateMeta:
    """Read only the UPDATE_BEGIN header (e.g. to compute FedAvg weights
    before a second ingest pass)."""
    ftype, _, payload, _ = wf.parse_frame(blob, 0)
    if ftype != wf.T_UPDATE_BEGIN:
        raise wf.WireError(f"expected UPDATE_BEGIN, got {ftype:#x}")
    cid, n_samples, rnd, n_chunks, kind = _BEGIN.unpack_from(payload, 0)
    return UpdateMeta(cid=cid, n_samples=n_samples, round=rnd,
                      n_chunks=n_chunks, seeded=kind == CT_SEEDED)


# ---------------------------------------------------------------------------
# server side: streaming modular accumulator
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("ctx", "token"))
def _accum_graph(ctx: CkksContext, token, acc, ct, w_mont):
    """One fused fold: acc + w (*) ct over all limbs in a single launch."""
    return ops.weighted_accum(acc, ct, w_mont, ctx)


class StreamIngest:
    """Accumulates arriving client updates chunk-by-chunk.

    Usage:
        ingest = StreamIngest(ctx)
        for blob, w in arriving:   # any interleaving of byte slices works
            ingest.ingest(blob, weight=w)
        agg = ingest.finalize()    # ProtectedUpdate, scale = in_scale*delta
    """

    def __init__(self, ctx: CkksContext):
        self.ctx = ctx
        self._acc_ct = None            # u32[n_chunks, L, 2, N]
        self._acc_plain = None         # f32[n_plain]
        self._in_scale = None
        self.clients_ingested = 0
        self.bytes_ingested = 0
        # O(1)-memory instrumentation: decoded ciphertext chunk buffers
        # resident beyond the accumulator.  Incremented where a chunk is
        # decoded, decremented once it has been folded — so a regression
        # that decodes a whole update (or several) before folding shows up
        # as peak > 1 on the serialized path.
        self._resident_chunks = 0
        self.peak_chunk_buffers = 0

    # -- internals ----------------------------------------------------------

    def _w_mont(self, weight: float):
        return jnp.asarray(encoding.encode_scalar_residues(float(weight),
                                                           self.ctx))

    def _note_decoded(self, n: int) -> None:
        self._resident_chunks += n
        self.peak_chunk_buffers = max(self.peak_chunk_buffers,
                                      self._resident_chunks)

    def _fold_chunk(self, chunk_idx: int, data, scale: float, w_mont) -> None:
        """data: u32[1, L, 2, N] one decoded chunk; folds and discards."""
        if self._in_scale is None:
            self._in_scale = float(scale)
        elif abs(self._in_scale - scale) > 1e-6 * self._in_scale:
            raise wf.WireError("mixed ciphertext scales in one aggregation")
        x = jnp.moveaxis(jnp.asarray(data), -3, -2)       # [1, 2, L, N]
        if self._acc_ct is None:
            n_limbs, n = data.shape[-3], data.shape[-1]
            self._n_limbs, self._n = n_limbs, n
            self._acc_ct = {}
        acc = self._acc_ct.get(chunk_idx)
        if acc is None:
            acc = jnp.zeros((2, self._n_limbs, self._n), dtype=jnp.uint32)
        out = _accum_graph(self.ctx, ops.backend_token(), acc, x[0], w_mont)
        self._acc_ct[chunk_idx] = out

    def _fold_plain(self, arr, codec: str, qscale: float,
                    weight: float) -> None:
        plain = _c.dequantize_plain(arr, codec, qscale)
        if self._acc_plain is None:
            self._acc_plain = np.zeros(plain.shape, dtype=np.float32)
        self._acc_plain += np.float32(weight) * plain

    # -- public API ---------------------------------------------------------

    def ingest(self, blob: bytes, weight: float) -> UpdateMeta:
        """Parse one client's frames and fold them into the accumulator.

        Validates the stream against its own UPDATE_BEGIN header: the set
        of received chunk indices must be exactly {0..n_chunks-1} — a
        dropped or duplicated CT_CHUNK frame is an error, never a silent
        partial contribution to the aggregate.
        """
        meta = None
        w_mont = self._w_mont(weight)
        saw_end = False
        chunks_seen: set[int] = set()
        for ftype, _, payload in wf.iter_frames(blob):
            if ftype == wf.T_UPDATE_BEGIN:
                cid, n_samples, rnd, n_chunks, kind = _BEGIN.unpack_from(
                    payload, 0)
                meta = UpdateMeta(cid, n_samples, rnd, n_chunks,
                                  kind == CT_SEEDED)
            elif ftype == wf.T_CT_CHUNK:
                if meta is None:
                    raise wf.WireError("CT_CHUNK before UPDATE_BEGIN")
                (chunk_idx,) = struct.unpack_from("<I", payload, 0)
                if chunk_idx >= meta.n_chunks:
                    raise wf.WireError(
                        f"chunk index {chunk_idx} >= declared "
                        f"n_chunks {meta.n_chunks}")
                if chunk_idx in chunks_seen:
                    raise wf.WireError(f"duplicate chunk {chunk_idx}")
                chunks_seen.add(chunk_idx)
                inner, _ = wf.deserialize(payload, self.ctx, off=4)
                if isinstance(inner, _c.SeededCiphertext):
                    inner = inner.expand(self.ctx)
                self._note_decoded(+1)
                self._fold_chunk(chunk_idx, inner.data, inner.scale, w_mont)
                self._note_decoded(-1)
            elif ftype == wf.T_PLAIN_SEGMENT:
                arr, codec, qscale = wf._parse_plain_segment(payload)
                self._fold_plain(arr, codec, qscale, weight)
            elif ftype == wf.T_UPDATE_END:
                saw_end = True
            else:
                raise wf.WireError(f"unexpected frame type {ftype:#x} "
                                   "in update stream")
        if meta is None or not saw_end:
            raise wf.WireError("truncated update stream")
        if len(chunks_seen) != meta.n_chunks:
            raise wf.WireError(
                f"update declared {meta.n_chunks} chunks, "
                f"received {len(chunks_seen)}")
        self.clients_ingested += 1
        self.bytes_ingested += len(blob)
        return meta

    def ingest_update(self, upd: ProtectedUpdate, weight: float) -> None:
        """In-memory streaming (no serialization): the caller already holds
        the whole decoded update, so one update's worth of chunk buffers is
        resident for the duration — still O(1) in the client count."""
        w_mont = self._w_mont(weight)
        data = np.asarray(upd.ct.data)
        n_chunks = data.shape[0]
        self._note_decoded(+n_chunks)
        for b in range(n_chunks):
            self._fold_chunk(b, data[b:b + 1], upd.ct.scale, w_mont)
        self._note_decoded(-n_chunks)
        self._fold_plain(np.asarray(upd.plain), "f32", 1.0, weight)
        self.clients_ingested += 1

    def finalize(self) -> ProtectedUpdate:
        if self.clients_ingested == 0 or self._acc_ct is None:
            raise wf.WireError("no updates ingested")
        n_chunks = max(self._acc_ct) + 1
        if sorted(self._acc_ct) != list(range(n_chunks)):
            raise wf.WireError("missing ciphertext chunks at finalize")
        data = jnp.stack([jnp.moveaxis(self._acc_ct[b], -3, -2)
                          for b in range(n_chunks)], axis=0)
        ct = Ciphertext(data=data, scale=self._in_scale * self.ctx.delta)
        plain = jnp.asarray(self._acc_plain if self._acc_plain is not None
                            else np.zeros((0,), np.float32))
        return ProtectedUpdate(ct=ct, plain=plain)
