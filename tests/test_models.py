"""Per-arch smoke tests (reduced same-family configs, one fwd/train step on
CPU: output shapes + finite) and decode-equivalence properties."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import build_model
from repro.models.config import ModelConfig

RNG = np.random.RandomState(0)


def make_batch(cfg: ModelConfig, b=2, s=16):
    if cfg.family == "encoder":
        return {"frames": jnp.asarray(RNG.randn(b, s, cfg.frame_dim),
                                      jnp.float32),
                "labels": jnp.asarray(RNG.randint(0, cfg.vocab, (b, s)))}
    if cfg.family == "vlm":
        st = s - cfg.n_patches
        return {"tokens": jnp.asarray(RNG.randint(0, cfg.vocab, (b, st))),
                "patches": jnp.asarray(RNG.randn(b, cfg.n_patches,
                                                 cfg.patch_dim), jnp.float32),
                "labels": jnp.asarray(RNG.randint(0, cfg.vocab, (b, st)))}
    return {"tokens": jnp.asarray(RNG.randint(0, cfg.vocab, (b, s))),
            "labels": jnp.asarray(RNG.randint(0, cfg.vocab, (b, s)))}


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_smoke_train_step(arch):
    """One forward + one gradient step on the reduced config."""
    cfg = configs.get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_actual = sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(params))
    assert n_actual == cfg.param_count(), (n_actual, cfg.param_count())
    batch = make_batch(cfg)
    loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss))
    assert 0 < float(loss) < 3 * np.log(cfg.vocab)
    for g in jax.tree_util.tree_leaves(grads):
        assert bool(jnp.isfinite(g).all()), "non-finite grad"
    # one SGD step changes the loss
    params2 = jax.tree_util.tree_map(lambda p, g: p - 0.1 * g, params, grads)
    loss2 = model.loss_fn(params2, batch)
    assert float(loss2) < float(loss)


@pytest.mark.parametrize("arch", [a for a in configs.ARCHS
                                  if configs.get_config(a).has_decode])
def test_arch_prefill_decode_consistency(arch):
    """decode-from-prefix logits == prefill-of-full-sequence logits."""
    cfg = configs.get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    b, s, n_pre = 2, 12, 7
    toks = jnp.asarray(RNG.randint(0, cfg.vocab, (b, s)))
    extra = cfg.n_patches if cfg.family == "vlm" else 0
    kw = {} if cfg.family == "ssm" else {"cache_len": s + extra + 2}
    if cfg.family == "vlm":
        patches = jnp.asarray(RNG.randn(b, cfg.n_patches, cfg.patch_dim),
                              jnp.float32)
        pre = {"tokens": toks[:, :n_pre], "patches": patches}
        full = {"tokens": toks, "patches": patches}
    else:
        pre = {"tokens": toks[:, :n_pre]}
        full = {"tokens": toks}
    logits, cache = model.prefill(params, pre, **kw)
    for t in range(n_pre, s):
        logits, cache = model.decode_step(params, cache,
                                          {"tokens": toks[:, t]})
    ref_logits, _ = model.prefill(params, full, **kw)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               atol=2e-3)


def test_encoder_has_no_decode():
    cfg = configs.get_config("hubert-xlarge", smoke=True)
    model = build_model(cfg)
    assert model.decode_step is None          # encoder-only: no decode
    # but inference forward (prefill_32k cell) exists
    b = make_batch(cfg)
    logits, cache = model.prefill(params := model.init(jax.random.PRNGKey(0)),
                                  {"frames": b["frames"]})
    assert cache is None
    assert logits.shape[:2] == b["frames"].shape[:2]


def test_moe_capacity_drops_tokens_gracefully():
    """Tiny capacity factor must not produce NaNs (dropped tokens pass
    through the residual)."""
    cfg = configs.get_config("phi3.5-moe-42b-a6.6b", smoke=True)
    import dataclasses
    cfg = dataclasses.replace(cfg, capacity_factor=0.25)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    loss = model.loss_fn(params, make_batch(cfg))
    assert np.isfinite(float(loss))


def test_ssd_chunked_matches_recurrence():
    """Chunked SSD == step-by-step recurrent decode on the same weights."""
    from repro.models import mamba2
    cfg = configs.get_config("mamba2-370m", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    b, s = 2, 16
    toks = jnp.asarray(RNG.randint(0, cfg.vocab, (b, s)))
    # full forward logits at final position
    logits_full, _ = mamba2.forward_logits(params, {"tokens": toks}, cfg,
                                           model.ax)
    # recurrent path
    _, cache = model.prefill(params, {"tokens": toks[:, :1]})
    lg = None
    for t in range(1, s):
        lg, cache = model.decode_step(params, cache, {"tokens": toks[:, t]})
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(logits_full[:, -1]), atol=2e-3)


def test_ssd_chunk_size_invariance():
    """SSD output must not depend on the chunk size."""
    import dataclasses
    cfg = configs.get_config("mamba2-370m", smoke=True)
    toks = jnp.asarray(RNG.randint(0, cfg.vocab, (2, 24)))
    outs = []
    for chunk in (4, 8, 24):
        c = dataclasses.replace(cfg, ssm_chunk=chunk)
        m = build_model(c)
        params = m.init(jax.random.PRNGKey(4))
        from repro.models import mamba2
        lg, _ = mamba2.forward_logits(params, {"tokens": toks}, c, m.ax)
        outs.append(np.asarray(lg))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-3)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-3)


def test_zamba_shared_block_weight_sharing():
    """The hybrid's attention weights exist once, not per invocation."""
    cfg = configs.get_config("zamba2-7b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(5))
    assert params["shared"]["wq"].ndim == 2          # single copy
    from repro.models import zamba2
    assert zamba2.n_shared_invocations(cfg) == cfg.n_layers // \
        cfg.shared_attn_every


def test_blocked_attention_matches_reference():
    """Blocked causal attention == naive full attention."""
    from repro.models import layers as L
    from repro.models.sharding import CPU_ENV
    import dataclasses
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=11,
                      attn_chunk=5)
    rng = np.random.RandomState(6)
    b, s, h, kh, hd = 2, 17, 4, 2, 8
    q = jnp.asarray(rng.randn(b, s, h, hd), jnp.float32)
    k = jnp.asarray(rng.randn(b, s, kh, hd), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, kh, hd), jnp.float32)
    out = L.blocked_attention(q, k, v, cfg, CPU_ENV, causal=True)
    # naive reference
    import math
    qg = np.asarray(q).reshape(b, s, kh, 2, hd)
    logits = np.einsum("bqkgd,bskd->bkgqs", qg, np.asarray(k)) / math.sqrt(hd)
    mask = np.tril(np.ones((s, s), bool))
    logits = np.where(mask[None, None, None], logits, -1e30)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    ref = np.einsum("bkgqs,bskd->bqkgd", probs, np.asarray(v)) \
        .reshape(b, s, h, hd)
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-4)


def test_rope_position_shift_property():
    """RoPE: attention depends only on relative positions."""
    from repro.models import layers as L
    rng = np.random.RandomState(7)
    x = jnp.asarray(rng.randn(1, 8, 2, 16), jnp.float32)
    a = L.apply_rope(x, jnp.arange(8), 10_000.0)
    b = L.apply_rope(x, jnp.arange(8) + 5, 10_000.0)
    # inner products between positions i,j must match for equal i-j
    ip_a = np.einsum("bshd,bthd->st", np.asarray(a), np.asarray(a))
    ip_b = np.einsum("bshd,bthd->st", np.asarray(b), np.asarray(b))
    np.testing.assert_allclose(ip_a, ip_b, rtol=1e-3, atol=1e-3)
