"""Quickstart: encrypt a model update, aggregate under CKKS, decrypt —
then ship the same round over the repro.wire serialized transport and
print the measured per-round bandwidth ledger.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro import obs
from repro.core import packing, selection
from repro.core.ckks import cipher, params as ckks_params
from repro.core.secure_agg import AggregatorConfig, SelectiveHEAggregator
from repro import wire
from repro.wire import budget as wb
from repro.wire import stream as ws


def main():
    # 1. crypto context (paper defaults scaled down for a quick run:
    #    packing batch 512 slots, depth-1, two ~29-bit RNS limbs)
    ctx = ckks_params.make_context(n_poly=1024, n_limbs=2, delta_bits=24)
    sk, pk = cipher.keygen(ctx, jax.random.PRNGKey(0))
    print(f"CKKS: N={ctx.n_poly} slots={ctx.slots} logQ~{ctx.log_q:.0f} "
          f"delta=2^{ctx.delta_bits}")

    # 2. a 'model' + per-parameter sensitivity (here synthetic; see
    #    examples/encrypted_finetune.py for real sensitivity maps)
    rng = np.random.RandomState(0)
    model = {"w1": jnp.asarray(rng.randn(256, 64), jnp.float32),
             "w2": jnp.asarray(rng.randn(64, 10), jnp.float32)}
    n_params = 256 * 64 + 64 * 10
    sens = np.abs(rng.randn(n_params))

    # 3. Selective Parameter Encryption at p=0.1
    agg = SelectiveHEAggregator.build(
        ctx, model, sens, AggregatorConfig(p_ratio=0.1, strategy="top_p"))
    rep = agg.overhead_report()
    print(f"encrypting {rep['n_enc']}/{rep['n_total']} params "
          f"({rep['ratio']:.0%}) in {rep['n_ciphertexts']} ciphertexts; "
          f"comm ratio vs plaintext {rep['comm_ratio']:.2f}x")

    # 4. three clients -> encrypted FedAvg -> decrypt
    clients = [jax.tree_util.tree_map(lambda x: x + 0.1 * i, model)
               for i in range(3)]
    updates = [agg.client_protect(m, pk, jax.random.PRNGKey(10 + i))
               for i, m in enumerate(clients)]
    glob = agg.server_aggregate(updates, [1 / 3] * 3)
    recovered = agg.client_recover_params(glob, sk)

    expect = jax.tree_util.tree_map(lambda *xs: sum(xs) / 3, *clients)
    err = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree_util.tree_leaves(recovered),
        jax.tree_util.tree_leaves(expect)))
    print(f"aggregation max error vs plaintext FedAvg: {err:.2e}")
    assert err < 1e-2

    # 5. same round over the wire: seed-expanded uplink ciphertexts, fp16
    #    plaintext partition, streaming server ingest, measured bytes —
    #    traced as one "round" span tree when REPRO_OBS=1
    ledger = wb.BandwidthLedger()
    with obs.span("round", round=0) as rsp:
        blobs = []
        for i, m in enumerate(clients):
            with obs.span("encrypt", cid=i) as esp:
                upd = agg.client_protect_seeded(
                    m, sk, jax.random.PRNGKey(20 + i), a_seed=100 + i)
                sct = wire.seed_compress(upd.ct, 100 + i)
                blob = ws.pack_update_frames(upd, cid=i, n_samples=4,
                                             rnd=0, seeded=sct,
                                             plain_codec="f16")
                esp.set(nbytes=len(blob))
            ledger.record_blob(blob, rnd=0, cid=i, direction=wb.UPLINK)
            blobs.append(blob)
        with obs.span("aggregate", n_updates=len(blobs)):
            ingest = ws.StreamIngest(ctx)
            for blob in blobs:
                ingest.ingest(blob, 1 / 3)
            glob_wire = ingest.finalize()
        with obs.span("broadcast", n_clients=len(clients)):
            blob_down = wire.serialize_update(glob_wire)
            for i in range(len(clients)):
                ledger.record_blob(blob_down, rnd=0, cid=i,
                                   direction=wb.DOWNLINK)
        with obs.span("recover"):
            rec_wire = obs.maybe_block(
                agg.client_recover_params(glob_wire, sk))
        rsp.set(bytes_up=ledger.total(wb.UPLINK, 0),
                bytes_down=ledger.total(wb.DOWNLINK, 0),
                launches=ingest.accum_launches)
    err_w = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree_util.tree_leaves(rec_wire),
        jax.tree_util.tree_leaves(expect)))
    assert err_w < 1e-2, err_w
    # O(1)-in-clients server memory: at most one update's ready chunks
    # resident, folded by ONE accumulate launch per client update
    assert ingest.peak_chunk_buffers == agg.part.n_chunks
    assert ingest.accum_launches == ingest.clients_ingested

    s = ledger.round_summary(0)
    comp = ledger.compression_summary(ctx, agg.part, 0)
    print("\nper-round bandwidth ledger (measured bytes on the wire):")
    print(f"  uplink   {s['uplink_bytes']:>9,} B total "
          f"({comp['uplink_bytes_per_client']:,} B/client)")
    print(f"  downlink {s['downlink_bytes']:>9,} B total")
    for kind, nbytes in sorted(s["by_kind"].items()):
        print(f"    {kind:<24} {nbytes:>9,} B")
    print(f"  compression vs naive all-encrypted uplink: "
          f"{comp['compression_ratio']:.1f}x "
          f"({comp['naive_all_encrypted_bytes']:,} B -> "
          f"{comp['measured_uplink_bytes']:,} B)")
    if obs.enabled():
        obs.flush()
        print(f"\ntrace written to {obs.trace_path()} "
              f"(open in Perfetto, or: "
              f"python tools/round_report.py {obs.trace_path()})")
    print("OK")


if __name__ == "__main__":
    main()
