from repro.core.ckks.params import CkksContext, make_context, make_test_context
from repro.core.ckks.cipher import (
    Ciphertext, keygen, encrypt_values, encrypt_coeffs, decrypt_values,
    decrypt_values_np, decrypt_to_coeffs, add, mul_plain_scalar,
    mul_plain_vec, weighted_sum, rescale,
)
from repro.core.ckks import encoding, threshold
