"""Fleet simulation: many thousands of wire-distinct clients, cheaply.

Encrypting 10k genuinely independent updates would make the BENCHMARK the
bottleneck, not the service.  Instead the simulator encrypts a handful of
TEMPLATE updates once and mints each simulated client by rewriting the
UPDATE_BEGIN header (cid / n_samples / round) of a rotating template —
pure byte surgery, no HE.  The service cannot tell the difference: every
submission is a fully valid, parseable, foldable wire stream with a
unique client id, and the server-side work (frame parsing, chunk decode,
weighted accumulate launch) is exactly what real traffic would cost.

The header layout being patched (wire/format.py, wire/stream.py):

    [16B frame header][u32 cid][u32 n_samples][u32 round][u32 n_chunks][u8]

`benchmarks/serve.py` uses this for the 10k-client sustained-throughput
measurement; `tests/test_serve.py` uses it (at small N) wherever client
identity matters more than ciphertext content.
"""
from __future__ import annotations

import struct

import numpy as np

from repro.wire import format as wf
from repro.wire import stream as wire_stream

_U32 = struct.Struct("<I")


def rewrite_begin(blob: bytes, *, cid: int | None = None,
                  n_samples: int | None = None,
                  rnd: int | None = None) -> bytes:
    """Return `blob` with its UPDATE_BEGIN header fields rewritten.

    The first frame must be UPDATE_BEGIN (raises WireError otherwise);
    only the requested fields change, every other byte is shared with the
    input (slices of the same template bytes).
    """
    ftype, _, payload, _ = wf.parse_frame(blob, 0)
    if ftype != wf.T_UPDATE_BEGIN:
        raise wf.WireError(f"expected UPDATE_BEGIN, got {ftype:#x}")
    if len(payload) < 12:
        raise wf.WireError("short UPDATE_BEGIN payload")
    base = wf.HEADER_BYTES          # payload offset of the first frame
    out = bytearray(blob)
    if cid is not None:
        out[base:base + 4] = _U32.pack(int(cid))
    if n_samples is not None:
        out[base + 4:base + 8] = _U32.pack(int(n_samples))
    if rnd is not None:
        out[base + 8:base + 12] = _U32.pack(int(rnd))
    return bytes(out)


class Fleet:
    """A population of `n_clients` simulated clients over template blobs.

    Args:
        templates: clean serialized update streams (pack_update_frames
            output) to rotate through; each minted client is template
            `cid % len(templates)` with a rewritten header.
        n_clients: fleet size (client ids are 0..n_clients-1).
        seed: RNG seed for the per-client n_samples draw.
        n_samples_range: inclusive (lo, hi) for the local sample counts —
            distinct weights keep the FedAvg normalization honest.
    """

    def __init__(self, templates: list[bytes], n_clients: int,
                 seed: int = 0, n_samples_range: tuple[int, int] = (8, 64)):
        if not templates:
            raise ValueError("need at least one template blob")
        self.templates = [bytes(t) for t in templates]
        self.n_clients = int(n_clients)
        lo, hi = n_samples_range
        rng = np.random.RandomState(seed)
        self.n_samples = rng.randint(lo, hi + 1,
                                     size=self.n_clients).astype(int)

    def blob(self, cid: int, rnd: int) -> bytes:
        """Mint client `cid`'s update stream for round `rnd`."""
        return rewrite_begin(self.templates[cid % len(self.templates)],
                             cid=cid, n_samples=int(self.n_samples[cid]),
                             rnd=rnd)

    def blobs(self, rnd: int, cids=None):
        """Yield (cid, blob) for the whole fleet (or the given cids)."""
        for cid in (range(self.n_clients) if cids is None else cids):
            yield cid, self.blob(cid, rnd)


def reference_aggregate(ctx, blobs: list[bytes], *, sharded=None):
    """The clean synchronous aggregate the service must match bit-for-bit:
    one StreamIngest over `blobs` in order, FedAvg weights normalized over
    exactly this set (the same float64 math as quorum.normalized_weights
    and fl.server.FLServer.aggregate_wire)."""
    metas = [wire_stream.peek_update_meta(b) for b in blobs]
    weights = np.asarray([m.n_samples for m in metas], dtype=np.float64)
    weights = weights / weights.sum()
    ingest = wire_stream.StreamIngest(ctx, sharded=sharded)
    for b, w in zip(blobs, weights):
        ingest.ingest(b, float(w))
    return ingest.finalize()
