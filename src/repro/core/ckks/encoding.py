"""CKKS canonical-embedding encode/decode.

Slots: z in C^{N/2} (we use real payloads) are the evaluations of the message
polynomial m(X) at the 2N-th roots zeta^{idx_j}, idx_j = 5^j mod 2N. Using all
2N roots lets both directions run as a single length-2N FFT:

  encode:  c_k = (2/N) * Re( FFT(scatter(z, idx))[k] ),   k < N
  decode:  z_j = (2N * IFFT(pad(c, 2N)))[idx_j]

(rows of the embedding are orthogonal: E E^H = N I, see DESIGN.md).

Two paths:
  * numpy/f64 host path — exact-enough for any delta (used by the FL client
    runtime and all tight tests);
  * jnp/complex64 jittable path — used inside the fully-jitted encrypted FL
    round; relative precision ~2**-24, which sits below the CKKS noise floor
    for delta <= 2**26 (validated in tests/test_ckks.py).
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from repro.core.ckks.params import CkksContext
from repro.kernels import ref as _ref


@functools.lru_cache(maxsize=32)
def _root_indices(n_poly: int) -> np.ndarray:
    """idx_j = 5^j mod 2N for j = 0..N/2-1."""
    idx = np.empty(n_poly // 2, dtype=np.int64)
    cur = 1
    for j in range(n_poly // 2):
        idx[j] = cur
        cur = cur * 5 % (2 * n_poly)
    return idx


# ---------------------------------------------------------------------------
# numpy / float64 host path
# ---------------------------------------------------------------------------

def encode_centered(values: np.ndarray, ctx: CkksContext,
                    delta: float | None = None) -> np.ndarray:
    """Real values [B, slots] -> CENTERED integer coefficients i64[B, N].

    The pre-RNS half of encode_np — FFT interpolation and delta scaling
    with no modular reduction.  This is everything a transcipher thin
    client computes (core/ckks/transcipher.py): no NTT, no per-limb
    arithmetic.  `encode_np(v) == encode_centered(v) % qs` bit-exactly
    (numpy's int64 `%` returns non-negative residues), which is the
    transcipher bit-identity anchor.
    """
    if values.ndim == 1:
        values = values[None]
    b = values.shape[0]
    n = ctx.n_poly
    assert values.shape[1] == ctx.slots, (values.shape, ctx.slots)
    delta = float(delta if delta is not None else ctx.delta)
    idx = _root_indices(n)
    buf = np.zeros((b, 2 * n), dtype=np.complex128)
    buf[:, idx] = values.astype(np.float64)
    c = (2.0 / n) * np.real(np.fft.fft(buf, axis=-1))[:, :n]
    return np.rint(c * delta).astype(np.int64)  # [B, N]


def encode_np(values: np.ndarray, ctx: CkksContext, delta: float | None = None
              ) -> np.ndarray:
    """Real values [B, slots] -> coefficient-domain residues u32[B, L, N]."""
    c_int = encode_centered(values, ctx, delta)
    qs = np.asarray(ctx.primes, dtype=np.int64)[None, :, None]
    return (c_int[:, None, :] % qs).astype(np.uint32)  # [B, L, N]


def decode_np(residues: np.ndarray, ctx: CkksContext, scale: float) -> np.ndarray:
    """Coefficient-domain residues u32[B, L, N] -> real values [B, slots].

    Garner CRT reconstruction (exact per-step u64), centered, then f64 FFT.
    """
    b, n_limbs, n = residues.shape
    assert n == ctx.n_poly
    primes = ctx.primes[:n_limbs]
    x = residues.astype(np.uint64)
    # Garner: value = t0 + q0*t1 + q0*q1*t2 + ...
    ts = [x[:, 0, :]]
    prods: list[int] = [1]
    for i in range(1, n_limbs):
        qi = primes[i]
        acc = ts[0] % qi
        mod_prod = 1
        for k in range(1, i):
            mod_prod = mod_prod * primes[k - 1] % qi
            acc = (acc + ts[k] % qi * (mod_prod % qi)) % qi
        # full product q0..q_{i-1} mod qi
        full = 1
        for k in range(i):
            full = full * primes[k] % qi
        inv = pow(full, -1, qi)
        ti = (x[:, i, :] + qi - acc) % qi * inv % qi
        ts.append(ti)
        prods.append(prods[-1] * primes[i - 1])
    # exact big-int accumulation: f64 would round above 2**53 (3+ limbs),
    # turning ~2**88 mod-Q representatives into O(2**35) coefficient error.
    value = np.zeros((b, n), dtype=object)
    prod = 1
    for i, t in enumerate(ts):
        value += t.astype(object) * prod
        prod *= int(primes[i])
    big_q = 1
    for p in primes:
        big_q *= int(p)
    value = np.where(value > big_q // 2, value - big_q, value)
    c = (value / float(scale)).astype(np.float64)
    z = 2 * n * np.fft.ifft(np.pad(c, ((0, 0), (0, n))), axis=-1)
    return np.real(z[:, _root_indices(n)])


# ---------------------------------------------------------------------------
# jnp / complex64 jittable path
# ---------------------------------------------------------------------------

def encode_jnp(values, ctx: CkksContext, delta: float | None = None):
    """Real values f32[B, slots] -> coefficient residues u32[B, L, N]."""
    n = ctx.n_poly
    delta = float(delta if delta is not None else ctx.delta)
    idx = jnp.asarray(_root_indices(n))
    b = values.shape[0]
    buf = jnp.zeros((b, 2 * n), dtype=jnp.complex64)
    buf = buf.at[:, idx].set(values.astype(jnp.complex64))
    c = (2.0 / n) * jnp.real(jnp.fft.fft(buf, axis=-1))[:, :n]
    c_int = jnp.rint(c * delta).astype(jnp.int32)
    # limb axis broadcast against the stacked prime table — no per-limb loop
    return _ref.mod_reduce_centered(c_int[:, None, :],
                                    ctx.tables.qs[:, None])  # [B, L, N]


def decode_jnp(residues, ctx: CkksContext, scale: float):
    """u32[B, 2, N] coefficient residues -> f32[B, slots].

    Two-limb Garner with exact u32 steps; the 64-bit combine x0 + q0*t1 and
    the mod-Q centering run in (hi, lo) u32 pairs (mod-Q representatives are
    ~2**58 — f32 would quantize at 2**34, far above the CKKS noise floor).
    Only the small centered magnitude is converted to float.
    """
    assert residues.shape[1] == 2, "jnp decode path supports 2 limbs"
    n = ctx.n_poly
    q0, q1 = ctx.primes[0], ctx.primes[1]
    lc1 = ctx.limbs[1]
    x0 = residues[:, 0, :]
    x1 = residues[:, 1, :]
    # t1 = (x1 - x0) * q0^{-1} mod q1   (exact u32 Montgomery)
    x0_mod_q1 = jnp.where(x0 >= np.uint32(q1), x0 - np.uint32(q1), x0)
    diff = _ref.mod_sub(x1, x0_mod_q1, np.uint32(q1))
    inv_q0_mont = np.uint32(pow(q0, -1, q1) * (1 << 32) % q1)
    t1 = _ref.mont_mul(diff, jnp.broadcast_to(inv_q0_mont, diff.shape),
                       np.uint32(q1), np.uint32(lc1.qinv_neg))
    # v = x0 + q0 * t1  (exact 64-bit in u32 pairs), then center mod Q
    hi, lo = _ref.mul_wide(t1, np.uint32(q0))
    hi, lo = _ref.add_wide(hi, lo, jnp.zeros_like(x0), x0)
    big_q = int(q0) * int(q1)
    q_hi, q_lo = np.uint32(big_q >> 32), np.uint32(big_q & 0xFFFFFFFF)
    h_hi, h_lo = np.uint32((big_q // 2) >> 32), np.uint32((big_q // 2) & 0xFFFFFFFF)
    neg = _ref.gt_wide(hi, lo, h_hi, h_lo)
    mag_hi, mag_lo = _ref.sub_wide(q_hi, q_lo, hi, lo)
    mag = jnp.where(neg, _ref.wide_to_f32(mag_hi, mag_lo),
                    _ref.wide_to_f32(hi, lo))
    value = jnp.where(neg, -mag, mag)
    c = value / jnp.float32(scale)
    z = 2 * n * jnp.fft.ifft(jnp.pad(c, ((0, 0), (0, n))).astype(jnp.complex64),
                             axis=-1)
    return jnp.real(z[:, jnp.asarray(_root_indices(n))]).astype(jnp.float32)


def encode_scalar_residues(w: float, ctx: CkksContext, delta: float | None = None,
                           mont: bool = True) -> np.ndarray:
    """Scalar plaintext (constant poly) per-limb residues, optionally in
    Montgomery form — the FedAvg weight encoding. Returns u32[L]."""
    return encode_weights_mont([w], ctx, delta=delta, mont=mont)[0]


def encode_weights_mont(weights, ctx: CkksContext, delta: float | None = None,
                        mont: bool = True) -> np.ndarray:
    """Batch of scalar weights -> stacked per-limb residues u32[C, L].

    Vectorized over both axes (exact: w*delta < 2**31 and q < 2**30, so the
    int64 intermediates r * 2**32 < 2**62 never overflow); this is the weight
    table handed to the fused weighted_sum/weighted_accum kernels.
    """
    delta = float(delta if delta is not None else ctx.delta)
    w_int = np.asarray([int(round(float(w) * delta)) for w in weights],
                       dtype=np.int64)[:, None]                  # [C, 1]
    qs = np.asarray(ctx.primes, dtype=np.int64)[None, :]         # [1, L]
    r = w_int % qs
    if mont:
        r = (r << 32) % qs
    return r.astype(np.uint32)
