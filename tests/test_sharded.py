"""Multi-chip sharded HE engine: bit-exact parity against the single-device
fused engine for L in {1, 2, 3} across 1/2/4-device meshes and all three
kernel backends (ref / pallas / pallas4), plus the streaming flush
contract (one chunk-batched accumulate launch per update).

tests/conftest.py forces 4 simulated host devices before the first jax
import, so every mesh case RUNS under plain tier-1 (CI asserts 0 skips
for these families); the skip guard below only fires under
REPRO_TEST_REAL_DEVICES=1 on smaller machines."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.ckks import cipher, encoding
from repro.core.ckks import params as ckks_params
from repro.core.ckks.sharded import ShardedHe
from repro.core.secure_agg import ProtectedUpdate
from repro.kernels import ops, ref
from repro.launch.mesh import make_he_mesh
from repro.wire import stream as ws

_DELTA_BITS = {1: 12, 2: 20, 3: 20}


def _ctx(n_limbs, n_poly=64):
    return ckks_params.make_test_context(
        n_poly=n_poly, n_limbs=n_limbs, delta_bits=_DELTA_BITS[n_limbs])


def _engine(ctx, n_dev):
    if jax.device_count() < n_dev:
        pytest.skip(f"needs {n_dev} host devices, have "
                    f"{jax.device_count()} (set XLA_FLAGS="
                    "--xla_force_host_platform_device_count=4)")
    return ShardedHe(ctx, make_he_mesh(ctx.n_limbs, n_dev))


def _ct_stack(rng, ctx, c, b):
    """Cipher-layout stack u32[C, B, L, 2, N]."""
    raw = ref.rand_limbed_np(rng, ctx, (c, b, 2))      # [C, B, 2, L, N]
    return jnp.asarray(np.moveaxis(raw, -2, -3))


@pytest.fixture(params=["ref", "pallas", "pallas4"])
def backend(request):
    old = {op: ops.get_backend(op) for op in ops.OPS}
    ops.set_backend(request.param)
    yield request.param
    for op, name in old.items():
        ops.set_backend(name, op=op)


@pytest.mark.parametrize("n_dev", [1, 2, 4])
@pytest.mark.parametrize("n_limbs", [1, 2, 3])
def test_sharded_weighted_sum_bitexact(n_limbs, n_dev, backend):
    ctx = _ctx(n_limbs)
    eng = _engine(ctx, n_dev)
    rng = np.random.RandomState(100 * n_limbs + n_dev)
    data = _ct_stack(rng, ctx, 4, 3)
    w = [0.1, 0.2, 0.3, 0.4]
    cts = cipher.Ciphertext(data=data, scale=float(ctx.delta))
    single = cipher.weighted_sum(ctx, cts, w)
    shard = eng.weighted_sum(cts, w)
    np.testing.assert_array_equal(np.asarray(single.data),
                                  np.asarray(shard.data))
    assert single.scale == shard.scale


@pytest.mark.parametrize("n_dev", [1, 2, 4])
@pytest.mark.parametrize("n_limbs", [1, 2, 3])
def test_sharded_weighted_accum_bitexact(n_limbs, n_dev, backend):
    ctx = _ctx(n_limbs)
    eng = _engine(ctx, n_dev)
    rng = np.random.RandomState(200 * n_limbs + n_dev)
    data = _ct_stack(rng, ctx, 2, 3)
    acc = cipher.Ciphertext(data=data[0], scale=float(ctx.delta))
    ct = cipher.Ciphertext(data=data[1], scale=float(ctx.delta))
    w = 0.25
    w_mont = jnp.asarray(encoding.encode_scalar_residues(w, ctx))
    single = ops.weighted_accum(jnp.moveaxis(acc.data, -3, -2),
                                jnp.moveaxis(ct.data, -3, -2), w_mont, ctx)
    shard = eng.weighted_accum(acc, ct, w)
    np.testing.assert_array_equal(
        np.asarray(jnp.moveaxis(single, -2, -3)), np.asarray(shard.data))


@pytest.mark.parametrize("n_dev", [1, 2, 4])
@pytest.mark.parametrize("n_limbs", [1, 2, 3])
def test_sharded_accum_chunks_bitexact(n_limbs, n_dev, backend):
    """The flush kernel: rows with per-row weights, sharded == per-row
    single-device weighted_accum."""
    ctx = _ctx(n_limbs)
    eng = _engine(ctx, n_dev)
    rng = np.random.RandomState(300 * n_limbs + n_dev)
    k = 5
    accs = jnp.asarray(ref.rand_limbed_np(rng, ctx, (k, 2)))
    cts = jnp.asarray(ref.rand_limbed_np(rng, ctx, (k, 2)))
    w = jnp.asarray(np.stack(
        [rng.randint(0, int(q), size=(k,)) for q in ctx.primes],
        axis=1).astype(np.uint32))
    single = ops.weighted_accum_chunks(accs, cts, w, ctx)
    rows = jnp.stack([ops.weighted_accum(accs[i], cts[i], w[i], ctx)
                      for i in range(k)])
    np.testing.assert_array_equal(np.asarray(single), np.asarray(rows))
    shard = eng.weighted_accum_chunks(accs, cts, w)
    np.testing.assert_array_equal(np.asarray(single), np.asarray(shard))


@pytest.mark.parametrize("n_dev", [1, 2, 4])
def test_sharded_keygen_encrypt_decrypt_bitexact(n_dev):
    """The full client path is bit-identical however the limb axis is
    sharded: same keys, same ciphertext, same decrypted residues."""
    ctx = _ctx(2, n_poly=128)
    eng = _engine(ctx, n_dev)
    rng = np.random.RandomState(7)
    sk1, pk1 = cipher.keygen(ctx, jax.random.PRNGKey(0))
    sk2, pk2 = eng.keygen(jax.random.PRNGKey(0))
    for k in sk1:
        np.testing.assert_array_equal(np.asarray(sk1[k]), np.asarray(sk2[k]))
    for k in pk1:
        np.testing.assert_array_equal(np.asarray(pk1[k]), np.asarray(pk2[k]))
    vals = jnp.asarray(rng.randn(2, ctx.slots).astype(np.float32)) * 0.1
    ct1 = cipher.encrypt_values(ctx, pk1, vals, jax.random.PRNGKey(1))
    ct2 = eng.encrypt_values(pk2, vals, jax.random.PRNGKey(1))
    np.testing.assert_array_equal(np.asarray(ct1.data), np.asarray(ct2.data))
    d1 = cipher.decrypt_to_coeffs(ctx, sk1, ct1)
    d2 = eng.decrypt_to_coeffs(sk2, ct2)
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
    out = eng.decrypt_values(sk2, ct2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(vals), atol=2e-3)


@pytest.mark.parametrize("n_dev", [1, 2, 4])
@pytest.mark.parametrize("n_limbs", [1, 2, 3])
def test_sharded_encrypt_values_data_sharded_bitexact(n_limbs, n_dev,
                                                      backend):
    """Data-axis-sharded pk encrypt: per-chunk key derivation makes the
    sampled streams shard-count-invariant, so the ciphertext is
    bit-identical to cipher.encrypt_values on any mesh."""
    ctx = _ctx(n_limbs)
    eng = _engine(ctx, n_dev)
    rng = np.random.RandomState(400 * n_limbs + n_dev)
    sk, pk = cipher.keygen(ctx, jax.random.PRNGKey(1))
    vals = jnp.asarray(rng.randn(4, ctx.slots).astype(np.float32)) * 0.1
    ct1 = cipher.encrypt_values(ctx, pk, vals, jax.random.PRNGKey(2))
    ct2 = eng.encrypt_values(pk, vals, jax.random.PRNGKey(2))
    np.testing.assert_array_equal(np.asarray(ct1.data), np.asarray(ct2.data))
    # batch that does NOT divide the data axis (padding path)
    ct1 = cipher.encrypt_values(ctx, pk, vals[:3], jax.random.PRNGKey(3))
    ct2 = eng.encrypt_values(pk, vals[:3], jax.random.PRNGKey(3))
    np.testing.assert_array_equal(np.asarray(ct1.data), np.asarray(ct2.data))


@pytest.mark.parametrize("n_dev", [1, 2, 4])
@pytest.mark.parametrize("n_limbs", [1, 2, 3])
def test_sharded_encrypt_values_seeded_bitexact(n_limbs, n_dev, backend):
    """Sharded seeded (uplink) encrypt: bit-identical ciphertext AND a c1
    that still matches the server-side expand_a_rows regeneration (the
    wire-v2 derive=1 contract)."""
    from repro.wire import compress as wc

    ctx = _ctx(n_limbs)
    eng = _engine(ctx, n_dev)
    rng = np.random.RandomState(500 * n_limbs + n_dev)
    sk, _ = cipher.keygen(ctx, jax.random.PRNGKey(4))
    vals = jnp.asarray(rng.randn(4, ctx.slots).astype(np.float32)) * 0.1
    a_seed = 9000 + n_limbs
    ct1 = cipher.encrypt_values_seeded(ctx, sk, vals, jax.random.PRNGKey(5),
                                       a_seed)
    ct2 = eng.encrypt_values_seeded(sk, vals, jax.random.PRNGKey(5), a_seed)
    np.testing.assert_array_equal(np.asarray(ct1.data), np.asarray(ct2.data))
    np.testing.assert_array_equal(
        np.asarray(ct2.c1), np.asarray(cipher.expand_a(ctx, a_seed, 4)))
    # seed_compress/expand round-trips the sharded ciphertext bit-exact
    sct = wc.seed_compress(ct2, a_seed)
    np.testing.assert_array_equal(np.asarray(sct.expand(ctx).data),
                                  np.asarray(ct2.data))


@pytest.mark.parametrize("n_dev", [2, 4])
def test_sharded_encrypt_graph_has_no_collectives(n_dev, backend):
    """The acceptance contract: encrypt (pk and seeded) compiles to a
    graph with NO cross-device communication — sampling, encode FFT, NTTs
    and mul_adds are all chunk- and limb-local (DESIGN.md §9.1).  Runs on
    every backend: the pallas4 tables (ntt4_*) ride the same per-shard
    limb slicing, so the 4-step NTT must add zero collectives too."""
    import re as _re

    from repro.core.ckks import sharded as sh
    from repro.kernels import ops

    ctx = _ctx(2, n_poly=64)
    eng = _engine(ctx, n_dev)
    sk, pk = cipher.keygen(ctx, jax.random.PRNGKey(0))
    vals = jnp.zeros((4, ctx.slots), jnp.float32)
    key = jax.random.PRNGKey(1)
    collective = _re.compile(
        r"all-reduce|all-gather|all-to-all|collective-permute|"
        r"reduce-scatter|collective-broadcast")
    lowered = sh._encrypt_values_graph.lower(
        eng, ops.backend_token(), pk["pk0_mont"], pk["pk1_mont"], vals, key)
    assert not collective.search(lowered.compile().as_text())
    lowered = sh._encrypt_seeded_values_graph.lower(
        eng, ops.backend_token(), sk["s_mont"], vals, key,
        jax.random.PRNGKey(7))
    assert not collective.search(lowered.compile().as_text())


@pytest.mark.parametrize("n_dev", [1, 2, 4])
def test_sharded_client_uplink_frames_byte_identical(n_dev):
    """The whole uplink: a sharded client's packed wire frames are
    byte-identical to a single-device client's, and the streamed aggregate
    recovers FedAvg."""
    from repro.core.secure_agg import AggregatorConfig, SelectiveHEAggregator
    from repro.wire import compress as wc

    ctx = _ctx(2, n_poly=128)
    eng = _engine(ctx, n_dev)
    sk, pk = cipher.keygen(ctx, jax.random.PRNGKey(8))
    rng = np.random.RandomState(90)
    model = {"w": jnp.asarray(rng.randn(6, ctx.slots), jnp.float32)}
    n = 6 * ctx.slots
    agg = SelectiveHEAggregator.build(
        ctx, model, np.abs(rng.randn(n)), AggregatorConfig(p_ratio=0.5))
    n_clients = 2
    blobs, blobs_ref = [], []
    clients = [jax.tree_util.tree_map(lambda x, i=i: x + 0.02 * i, model)
               for i in range(n_clients)]
    for i, m in enumerate(clients):
        key = jax.random.PRNGKey(30 + i)
        a_seed = 600 + i
        upd = agg.client_protect_seeded(m, sk, key, a_seed, sharded=eng)
        ref = agg.client_protect_seeded(m, sk, key, a_seed)
        kw = dict(cid=i, n_samples=3, rnd=1)
        blobs.append(ws.pack_update_frames(
            upd, seeded=wc.seed_compress(upd.ct, a_seed), **kw))
        blobs_ref.append(ws.pack_update_frames(
            ref, seeded=wc.seed_compress(ref.ct, a_seed), **kw))
    assert blobs == blobs_ref          # byte-identical uplink
    ing = ws.StreamIngest(ctx, sharded=eng)
    for b in blobs:
        ing.ingest(b, 1.0 / n_clients)
    rec = agg.client_recover_params(ing.finalize(), sk)
    expect = jax.tree_util.tree_map(lambda *xs: sum(xs) / n_clients,
                                    *clients)
    err = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree_util.tree_leaves(rec), jax.tree_util.tree_leaves(expect)))
    assert err < 1e-2


def test_sharded_rejects_indivisible_limbs():
    """A 3-limb context on a model-axis-2 mesh must fail loudly, pointing
    at make_he_mesh."""
    if jax.device_count() < 2:
        pytest.skip("needs 2 host devices")
    ctx = _ctx(3)
    mesh2 = make_he_mesh(2, 2)          # model axis size 2 does not divide 3
    with pytest.raises(ValueError, match="not divisible"):
        ShardedHe(ctx, mesh2).weighted_sum(
            cipher.Ciphertext(
                data=jnp.zeros((1, 1, 3, 2, ctx.n_poly), jnp.uint32),
                scale=1.0), [1.0])


@pytest.mark.parametrize("n_dev", [1, 2, 4])
def test_streaming_flush_sharded_matches_batch(n_dev, backend):
    """StreamIngest with a sharded engine: bit-identical to the batch
    weighted_sum AND one accumulate launch per update."""
    ctx = _ctx(2, n_poly=128)
    eng = _engine(ctx, n_dev)
    sk, pk = cipher.keygen(ctx, jax.random.PRNGKey(5))
    rng = np.random.RandomState(70)
    n_clients = 3
    upds = []
    for i in range(n_clients):
        vals = jnp.asarray(rng.randn(2, ctx.slots).astype(np.float32)) * 0.1
        ct = cipher.encrypt_values(ctx, pk, vals, jax.random.PRNGKey(80 + i))
        upds.append(ProtectedUpdate(ct=ct,
                                    plain=jnp.zeros((0,), jnp.float32)))
    w = [1.0 / n_clients] * n_clients
    stacked = cipher.Ciphertext(
        data=jnp.stack([u.ct.data for u in upds]), scale=upds[0].ct.scale)
    batch = cipher.weighted_sum(ctx, stacked, w)
    ing = ws.StreamIngest(ctx, sharded=eng)
    for u, wi in zip(upds, w):
        ing.ingest_update(u, wi)
    streamed = ing.finalize()
    np.testing.assert_array_equal(np.asarray(streamed.ct.data),
                                  np.asarray(batch.data))
    # one chunk-batched launch per client update — not one per chunk
    assert ing.accum_launches == n_clients
    assert ing.peak_chunk_buffers == int(upds[0].ct.data.shape[0])


def test_stream_flush_one_launch_per_update_serialized():
    """Serialized path: n_chunks >= 2 chunks per update still cost exactly
    one accumulate launch per ingested blob."""
    from repro.wire import compress as wc

    ctx = _ctx(2, n_poly=64)
    sk, pk = cipher.keygen(ctx, jax.random.PRNGKey(9))
    rng = np.random.RandomState(11)
    n_clients, n_chunks = 3, 4
    blobs = []
    for i in range(n_clients):
        vals = jnp.asarray(
            rng.randn(n_chunks, ctx.slots).astype(np.float32)) * 0.1
        ct = cipher.encrypt_values(ctx, pk, vals, jax.random.PRNGKey(20 + i))
        upd = ProtectedUpdate(ct=ct, plain=jnp.zeros((0,), jnp.float32))
        blobs.append(ws.pack_update_frames(upd, cid=i, n_samples=1))
    ing = ws.StreamIngest(ctx)
    for b in blobs:
        ing.ingest(b, 1.0 / n_clients)
    out = ing.finalize()
    assert out.ct.data.shape[0] == n_chunks
    assert ing.accum_launches == n_clients          # one per update
    assert ing.peak_chunk_buffers == n_chunks       # one update resident
    # bit parity with the in-memory ingest path over the same updates
    ing2 = ws.StreamIngest(ctx)
    for b in blobs:
        assert ws.peek_update_meta(b).n_chunks == n_chunks
        ing2.ingest(b, 1.0 / n_clients)
    np.testing.assert_array_equal(np.asarray(out.ct.data),
                                  np.asarray(ing2.finalize().ct.data))
