"""DoubleSqueeze gradient compression with error feedback (Tang et al., 2019).

The paper (Figure 8, Table 5) stacks DoubleSqueeze top-k compression in
front of HE to shrink the encrypted volume: only the top-k update entries
are shipped (and encrypted); the compression error is fed back into the
next round on both worker and server sides.

Jit-friendly: k is static, selection by jax.lax.top_k on |value|.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DoubleSqueezeState:
    error: Any               # f32[P] residual carried between rounds


def double_squeeze_init(n_params: int) -> DoubleSqueezeState:
    return DoubleSqueezeState(error=jnp.zeros((n_params,), jnp.float32))


def topk_sparsify(vec, k: int):
    """Keep the k largest-|.| entries. Returns (values f32[k], idx i32[k],
    dense_compressed f32[P])."""
    mag = jnp.abs(vec)
    _, idx = jax.lax.top_k(mag, k)
    vals = vec[idx]
    dense = jnp.zeros_like(vec).at[idx].set(vals)
    return vals, idx, dense


def double_squeeze_compress(vec, state: DoubleSqueezeState, k: int):
    """One error-compensated compression pass.

    corrected = vec + error;  compressed = top_k(corrected);
    new_error = corrected - compressed.
    Returns (compressed_dense f32[P], (values, idx), new_state).
    """
    corrected = vec + state.error
    vals, idx, dense = topk_sparsify(corrected, k)
    return dense, (vals, idx), DoubleSqueezeState(error=corrected - dense)
