"""Fallback for the optional `hypothesis` test dependency.

Test modules do

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hyp import given, settings, st

so environments without hypothesis still collect and run the whole suite:
property tests are skipped (not errored), everything else runs normally.
"""
import pytest


def given(*_args, **_kwargs):
    def deco(fn):
        return pytest.mark.skip(
            reason="hypothesis not installed (property test)")(fn)
    return deco


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn
    return deco


class _Strategies:
    """Accepts any strategy constructor (floats, integers, lists, ...) and
    returns a placeholder; @given skips the test before these are drawn."""

    def __getattr__(self, _name):
        def make(*args, **kwargs):
            return None
        return make


st = _Strategies()
