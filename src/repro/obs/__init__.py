"""repro.obs — unified telemetry: metrics registry, trace spans, kernel
timing hooks, exporters.

The measurement substrate under every performance claim this repo makes
(DESIGN.md §11).  Three layers, one switch:

  * **metrics** (`repro/obs/metrics.py`) — process-wide registry of
    counters / gauges / histograms with labels.  Always on: the legacy
    one-off counters (`StreamIngest.accum_launches`,
    `peak_chunk_buffers`, the `wire/budget.py` byte ledger) now resolve
    here behind compatible properties.
  * **trace spans** (`repro/obs/trace.py`) — nestable `span()` context
    managers emitting Chrome-trace-event JSONL loadable in Perfetto,
    wired through the FL round loop, the wire ingest/flush path, and the
    sharded HE dispatches.  Gated on REPRO_OBS=1.
  * **kernel hooks** (`repro/obs/hooks.py`) — per-op wall time +
    `jax.profiler.TraceAnnotation` / `jax.named_scope` in the
    `kernels/ops.py` registry, and `kernel_launch` timing for jitted HE
    graphs, keyed by `ops.backend_token()`.  Gated on REPRO_OBS=1.

Exporters: the trace JSONL sink itself, `prometheus_text()` /
`dump_metrics()`, and `tools/round_report.py` (per-round
phase/bytes/launches table from a trace file).  `provenance()` stamps
BENCH_*.json entries with backend token / device kind / obs version.

Environment (canonical table: README.md):
  REPRO_OBS=1           enable spans + kernel hooks (default off).
  REPRO_OBS_TRACE=path  trace sink (default ./obs_trace.jsonl).
"""
from __future__ import annotations

from repro.obs.metrics import (REGISTRY, Counter, Gauge, Histogram,
                               MetricsRegistry)
from repro.obs.trace import (NULL_SPAN, OBS_VERSION, Span, Tracer, configure,
                             enabled, event, flush, get_tracer, span,
                             trace_path)
from repro.obs.hooks import (kernel_hooks_enabled, kernel_launch,
                             maybe_block, timed_kernel)

__all__ = [
    "REGISTRY", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "NULL_SPAN", "OBS_VERSION", "Span", "Tracer",
    "configure", "enabled", "event", "flush", "get_tracer", "span",
    "trace_path",
    "kernel_hooks_enabled", "kernel_launch", "maybe_block", "timed_kernel",
    "counter", "gauge", "histogram", "prometheus_text", "dump_metrics",
    "provenance",
]


def counter(name: str, **labels) -> Counter:
    """Get-or-create a counter in the process registry."""
    return REGISTRY.counter(name, **labels)


def gauge(name: str, **labels) -> Gauge:
    """Get-or-create a gauge in the process registry."""
    return REGISTRY.gauge(name, **labels)


def histogram(name: str, **labels) -> Histogram:
    """Get-or-create a histogram in the process registry."""
    return REGISTRY.histogram(name, **labels)


def prometheus_text() -> str:
    """Prometheus-style text dump of the process registry."""
    return REGISTRY.prometheus_text()


def dump_metrics(path: str) -> None:
    """Write the Prometheus-style registry dump to `path`."""
    with open(path, "w") as f:
        f.write(REGISTRY.prometheus_text())


def provenance() -> dict:
    """Measurement provenance stamped into BENCH_*.json entries: obs
    schema version, backend registry snapshot, and device identity —
    enough to know what a checked-in number was measured on."""
    import jax

    from repro.kernels import ops

    devs = jax.devices()
    out = {
        "obs_version": OBS_VERSION,
        "backend": ops.get_backend(),
        "backend_token": str(ops.backend_token()),
        "platform": jax.default_backend(),
        "device_kind": devs[0].device_kind if devs else "unknown",
        "device_count": len(devs),
    }
    if "auto" in {ops.get_backend(op) for op in ops.OPS}:
        from repro.kernels import tune

        out["tune"] = tune.provenance()
    return out
