"""Encryption-mask selection (paper §2.4 Step 2).

All selectors return a flat boolean numpy mask over the flattened parameter
vector (host-side: masks are FL *configuration*, computed once per task and
baked into the jitted round step as static indices — see packing.py).

Monotonicity: ``top_p_mask(s, p1) subset top_p_mask(s, p2)`` for p1 <= p2 is
guaranteed by selecting along a fixed argsort order (deterministic
tie-break by index).
"""
from __future__ import annotations

import numpy as np


def _n_select(n_total: int, p: float) -> int:
    p = float(min(max(p, 0.0), 1.0))
    return int(round(n_total * p))


def top_p_mask(sens_vec: np.ndarray, p: float) -> np.ndarray:
    """Global top-p by sensitivity magnitude. Returns bool[P]."""
    s = np.asarray(sens_vec, dtype=np.float64).ravel()
    k = _n_select(s.size, p)
    mask = np.zeros(s.size, dtype=bool)
    if k > 0:
        # stable order: sort by (-|s|, index) so masks nest across p
        order = np.lexsort((np.arange(s.size), -np.abs(s)))
        mask[order[:k]] = True
    return mask


def random_mask(p: float, n_total: int, seed: int = 0) -> np.ndarray:
    """Random-p baseline (FLARE's 'partial encryption'); nested across p for
    a fixed seed (same permutation prefix)."""
    rng = np.random.RandomState(seed)
    order = rng.permutation(n_total)
    mask = np.zeros(n_total, dtype=bool)
    mask[order[: _n_select(n_total, p)]] = True
    return mask


def per_layer_top_p_mask(sens_vec: np.ndarray, p: float,
                         offsets, sizes) -> np.ndarray:
    """Top-p within each leaf (layer) instead of globally."""
    s = np.asarray(sens_vec, dtype=np.float64).ravel()
    mask = np.zeros(s.size, dtype=bool)
    for off, size in zip(offsets, sizes):
        seg = s[off: off + size]
        k = _n_select(size, p)
        if k > 0:
            order = np.lexsort((np.arange(size), -np.abs(seg)))
            mask[off + order[:k]] = True
    return mask


def recipe_mask(sens_vec: np.ndarray, p: float, offsets, sizes,
                first_last: bool = True) -> np.ndarray:
    """The paper's empirical recipe: global top-p UNION first & last leaves
    ('encrypting top-30% ... as well as the first and last model layers')."""
    mask = top_p_mask(sens_vec, p)
    if first_last and len(sizes) > 0:
        mask[offsets[0]: offsets[0] + sizes[0]] = True
        mask[offsets[-1]: offsets[-1] + sizes[-1]] = True
    return mask


STRATEGIES = ("top_p", "random", "per_layer", "recipe", "all", "none")


def build_mask(sens_vec: np.ndarray, strategy: str, p: float, *,
               offsets=None, sizes=None, seed: int = 0) -> np.ndarray:
    """Single dispatch point from (strategy, p) to a boolean mask.

    Used by both `SelectiveHEAggregator.build` and the HE mask-agreement
    path (`secure_agg.agree_mask`), so every strategy — including the
    paper's `recipe` — is reachable from an HE-aggregated sensitivity map.
    `offsets`/`sizes` (the FlatSpec leaf layout) are required for the
    layer-aware strategies (`per_layer`, `recipe`).
    """
    s = np.asarray(sens_vec).ravel()
    n = s.size
    if strategy == "top_p":
        return top_p_mask(s, p)
    if strategy == "random":
        return random_mask(p, n, seed=seed)
    if strategy in ("per_layer", "recipe"):
        if offsets is None or sizes is None:
            raise ValueError(
                f"strategy {strategy!r} needs the leaf layout "
                "(offsets/sizes from packing.FlatSpec)")
        if strategy == "per_layer":
            return per_layer_top_p_mask(s, p, offsets, sizes)
        return recipe_mask(s, p, offsets, sizes)
    if strategy == "all":
        return np.ones(n, dtype=bool)
    if strategy == "none":
        return np.zeros(n, dtype=bool)
    raise ValueError(f"unknown selection strategy {strategy!r}; "
                     f"choose from {STRATEGIES}")


def mask_stats(mask: np.ndarray) -> dict:
    mask = np.asarray(mask, dtype=bool)
    return {"n_total": int(mask.size), "n_enc": int(mask.sum()),
            "ratio": float(mask.mean())}
