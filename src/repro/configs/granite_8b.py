"""granite-8b [dense] — llama architecture, code model.
Source: arXiv:2405.04324 (hf tier).
36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="granite-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=49152,
    dtype="bfloat16", param_dtype="float32", remat=True,
)

SMOKE = ModelConfig(
    name="granite-8b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
    vocab=257, attn_chunk=16,
)
