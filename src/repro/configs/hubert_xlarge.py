"""hubert-xlarge [audio/encoder] — encoder-only, w2v2 architecture.
Source: arXiv:2106.07447 (unverified tier).
48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504.  The conv waveform
frontend is a STUB: input_specs() provides precomputed frame embeddings
(frame_dim=512, the frontend's output width)."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="hubert-xlarge", family="encoder",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, d_ff=5120,
    vocab=504, frame_dim=512,
    mlp_gated=False,
    dtype="bfloat16", param_dtype="float32", remat=True,
)

SMOKE = ModelConfig(
    name="hubert-smoke", family="encoder",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=61, frame_dim=24, attn_chunk=16,
)
