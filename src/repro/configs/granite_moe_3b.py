"""granite-moe-3b-a800m [moe] — 40 experts top-8.
Source: hf:ibm-granite (hf tier).  Assignment inline spec: 32L d_model=1536
24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40e top-8.  (The bracketed hf id
granite-3.0-1b-a400m and the '32 experts' prose disagree with the inline
numbers; the inline spec wins — see DESIGN.md §5.)"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
    vocab=49155, n_experts=40, top_k=8, capacity_factor=1.25,
    dtype="bfloat16", param_dtype="float32", remat=True,
)

SMOKE = ModelConfig(
    name="granite-moe-smoke", family="moe",
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, d_ff=32,
    vocab=257, n_experts=8, top_k=4, capacity_factor=2.0, attn_chunk=16,
)
