"""Pallas TPU kernel: negacyclic NTT / iNTT, limb-fused over all RNS limbs.

Target: TPU VPU (u32 lanes). The grid is (L, ceil(B / block_b)): the RNS limb
is a *grid coordinate*, not a Python loop, so one `pallas_call` covers the
whole u32[B, L, N] tensor and kernel count no longer scales with limb depth.
Each invocation holds a (block_b, N) tile of one limb plus that limb's
N-entry twiddle row and scalar constants (q, -q^{-1}, N^{-1}R) in VMEM
(block_b=8, N=8192 -> 288 KiB of VMEM, well under budget) and runs all
log2(N) butterfly stages in-register.  The DIF/DIT pairing keeps both
directions permutation-free (bit-reversed NTT domain).

Constants arrive as stacked u32[L] / u32[L, N] tables (params.LimbTables);
the BlockSpec index map selects the limb's row, so the kernel body is
identical for every limb.  This is exactly what lets the sharded engine
(core/ckks/sharded.py, DESIGN.md §8) turn the limb grid axis into the
`model` MESH axis: inside `shard_map` each shard passes its local table
slice and launches this same kernel over its local limbs — the NTT runs
within one limb's N coefficients, so limb sharding needs no collectives.

Stages are unrolled in Python: every reshape has a static shape. On real TPU
the final stages (t < 128 lanes) relayout across sublanes; a 4-step
transpose-based NTT is the known fix and is listed in EXPERIMENTS.md §Perf.

Validated in interpret mode against repro/kernels/ref.py with exact integer
equality (tests/test_kernels.py, tests/test_fused_engine.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref as _ref


def _ntt_fwd_body(x_ref, psi_ref, q_ref, qinv_ref, o_ref, *, n: int):
    x = x_ref[:, 0, :]
    psi = psi_ref[0]
    q = q_ref[0]
    qinv_neg = qinv_ref[0]
    m, t = 1, n
    while m < n:
        t //= 2
        xs = x.reshape((-1, m, 2, t))
        u = xs[:, :, 0, :]
        s = psi[m:2 * m][None, :, None]
        v = _ref.mont_mul(xs[:, :, 1, :], jnp.broadcast_to(s, u.shape), q,
                          qinv_neg)
        x = jnp.stack(
            [_ref.mod_add(u, v, q), _ref.mod_sub(u, v, q)], axis=2
        ).reshape((-1, n))
        m *= 2
    o_ref[:, 0, :] = x


def _ntt_inv_body(x_ref, psi_inv_ref, q_ref, qinv_ref, ninv_ref, o_ref, *,
                  n: int):
    x = x_ref[:, 0, :]
    psi_inv = psi_inv_ref[0]
    q = q_ref[0]
    qinv_neg = qinv_ref[0]
    t, m = 1, n
    while m > 1:
        h = m // 2
        xs = x.reshape((-1, h, 2, t))
        u = xs[:, :, 0, :]
        v = xs[:, :, 1, :]
        s = psi_inv[h:2 * h][None, :, None]
        lo = _ref.mod_add(u, v, q)
        hi = _ref.mont_mul(_ref.mod_sub(u, v, q),
                           jnp.broadcast_to(s, u.shape), q, qinv_neg)
        x = jnp.stack([lo, hi], axis=2).reshape((-1, n))
        t *= 2
        m = h
    x = _ref.mont_mul(x, jnp.broadcast_to(ninv_ref[0], x.shape), q, qinv_neg)
    o_ref[:, 0, :] = x


@functools.lru_cache(maxsize=128)
def _build(direction: str, l: int, n: int, block_b: int, interpret: bool):
    tile = pl.BlockSpec((block_b, 1, n), lambda li, bi: (bi, li, 0))
    row = pl.BlockSpec((1, n), lambda li, bi: (li, 0))
    scalar = pl.BlockSpec((1,), lambda li, bi: (li,))
    if direction == "fwd":
        body = functools.partial(_ntt_fwd_body, n=n)
        in_specs = [tile, row, scalar, scalar]
    else:
        body = functools.partial(_ntt_inv_body, n=n)
        in_specs = [tile, row, scalar, scalar, scalar]

    def call(x, *tables):
        b = x.shape[0]
        return pl.pallas_call(
            body,
            grid=(l, pl.cdiv(b, block_b)),
            in_specs=in_specs,
            out_specs=tile,
            out_shape=jax.ShapeDtypeStruct((b, l, n), jnp.uint32),
            interpret=interpret,
        )(x, *tables)

    return call


def _flatten(x):
    l, n = x.shape[-2], x.shape[-1]
    return x.reshape((-1, l, n)), x.shape[:-2]


def ntt_fwd_fused(x, psi_rev_mont, qs, qinv_negs, *, block_b: int = 8,
                  interpret: bool = True):
    """x: u32[..., L, N] natural -> bit-reversed NTT domain, all limbs in one
    pallas_call.  psi_rev_mont: u32[L, N]; qs, qinv_negs: u32[L]."""
    x2, batch = _flatten(x)
    b, l, n = x2.shape
    call = _build("fwd", l, n, min(block_b, b), interpret)
    return call(x2, psi_rev_mont, qs, qinv_negs).reshape(batch + (l, n))


def ntt_inv_fused(x, psi_inv_rev_mont, n_inv_monts, qs, qinv_negs, *,
                  block_b: int = 8, interpret: bool = True):
    """x: u32[..., L, N] bit-reversed NTT domain -> natural order."""
    x2, batch = _flatten(x)
    b, l, n = x2.shape
    call = _build("inv", l, n, min(block_b, b), interpret)
    return call(x2, psi_inv_rev_mont, qs, qinv_negs,
                n_inv_monts).reshape(batch + (l, n))
