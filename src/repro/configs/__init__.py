"""Architecture registry: ``--arch <id>`` -> ModelConfig.

Each assigned architecture lives in its own module with the exact public
config (FULL) and a reduced same-family smoke config (SMOKE).
"""
from __future__ import annotations

from repro.configs import (
    deepseek_67b,
    granite_34b,
    granite_8b,
    granite_moe_3b,
    hubert_xlarge,
    mamba2_370m,
    phi3_5_moe,
    phi3_vision,
    qwen1_5_0_5b,
    zamba2_7b,
)
from repro.configs.shapes import SHAPES, cells_for, input_specs, runnable

_MODULES = {
    "zamba2-7b": zamba2_7b,
    "phi3.5-moe-42b-a6.6b": phi3_5_moe,
    "granite-moe-3b-a800m": granite_moe_3b,
    "hubert-xlarge": hubert_xlarge,
    "deepseek-67b": deepseek_67b,
    "granite-8b": granite_8b,
    "qwen1.5-0.5b": qwen1_5_0_5b,
    "granite-34b": granite_34b,
    "mamba2-370m": mamba2_370m,
    "phi-3-vision-4.2b": phi3_vision,
}

ARCHS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {list(_MODULES)}")
    mod = _MODULES[arch]
    return mod.SMOKE if smoke else mod.FULL


def all_cells() -> list[tuple[str, str]]:
    """Every runnable (arch, shape) pair."""
    out = []
    for a in ARCHS:
        cfg = get_config(a)
        out.extend((a, s) for s in cells_for(cfg))
    return out
