"""Numpy-uint64 gold model for the u32 Montgomery construction.

Validates that the 16-bit-limb u32 arithmetic in repro/kernels/ref.py
computes the same ring operations as straightforward 64-bit modular
arithmetic (which the TPU does not have — hence the construction).
"""
from __future__ import annotations

import numpy as np


def gold_mulmod(a: np.ndarray, b: np.ndarray, q: int) -> np.ndarray:
    return (a.astype(np.uint64) * b.astype(np.uint64) % np.uint64(q)) \
        .astype(np.uint32)


def gold_mont_mul(a, b, q: int) -> np.ndarray:
    """Montgomery product a*b*R^{-1} mod q via uint64/object math."""
    r_inv = pow(1 << 32, -1, q)
    wide = a.astype(object) * b.astype(object) * r_inv % q
    return np.asarray(wide, dtype=np.uint64).astype(np.uint32)


def gold_ntt(x: np.ndarray, q: int, psi: int) -> np.ndarray:
    """O(N^2) negacyclic NTT in bit-reversed output order."""
    n = x.shape[-1]
    logn = n.bit_length() - 1
    # X_k = sum_j x_j psi^(2jk + j) ; output bit-reversed
    ks = np.arange(n)
    out = np.zeros_like(x, dtype=np.uint64)
    xs = x.astype(np.uint64)
    for k in range(n):
        acc = 0
        for j in range(n):
            w = pow(psi, (2 * j * k + j) % (2 * n), q)
            acc = (acc + int(xs[..., j]) * w) % q
        out[..., _bitrev(k, logn)] = acc
    return out.astype(np.uint32)


def _bitrev(x: int, bits: int) -> int:
    out = 0
    for _ in range(bits):
        out = (out << 1) | (x & 1)
        x >>= 1
    return out
