from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from repro.optim.schedule import cosine_lr
from repro.optim.compression import (DoubleSqueezeState, double_squeeze_init,
                                     double_squeeze_compress, topk_sparsify)
