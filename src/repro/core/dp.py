"""Laplace-mechanism DP accounting for partially-encrypted FL (paper §3).

The paper's analysis: encrypting parameter i spends 0 privacy budget
(Theorem 3.9); leaving it plaintext with Laplace(b) noise spends
eps_i = Delta f_i / b (Lemma 3.8); budgets add by sequential composition
(Lemma 3.10), so a partial encryption scheme spends

    eps_total = sum_{i not in S} Delta f_i / b          (Theorem 3.11)

Under Delta f ~ U(0,1): all-plaintext J, random-p (1-p) J, and sensitivity-
ordered top-p selection (1-p)^2 J (Remarks 3.12-3.14).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def laplace_noise_tree(tree, key, b: float):
    """Add Laplace(0, b) to every leaf (the optional DP step in Alg. 1)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    noised = [l + b * jax.random.laplace(k, l.shape, dtype=l.dtype)
              for l, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, noised)


def laplace_noise_vec(vec, key, b: float):
    return vec + b * jax.random.laplace(key, vec.shape, dtype=vec.dtype)


# ---------------------------------------------------------------------------
# epsilon accounting
# ---------------------------------------------------------------------------


def epsilon_total(sens_vec: np.ndarray, mask: np.ndarray, b: float) -> float:
    """Theorem 3.11: sum of Delta f_i / b over UNENCRYPTED parameters."""
    s = np.abs(np.asarray(sens_vec, dtype=np.float64).ravel())
    m = np.asarray(mask, dtype=bool).ravel()
    return float(s[~m].sum() / b)


def epsilon_all_plaintext(sens_vec: np.ndarray, b: float) -> float:
    """Remark 3.12: J = sum_i Delta f_i / b."""
    return float(np.abs(np.asarray(sens_vec, dtype=np.float64)).sum() / b)


def epsilon_uniform_random(j_total: float, p: float) -> float:
    """Remark 3.13 closed form (Delta f ~ U(0,1)): (1-p) J."""
    return (1.0 - p) * j_total


def epsilon_uniform_selective(j_total: float, p: float) -> float:
    """Remark 3.14 closed form (Delta f ~ U(0,1)): (1-p)^2 J.

    Top-p selection removes the largest mass: residual = integral of the
    lowest (1-p) quantile of U(0,1) = (1-p)^2 / 2, vs total mass 1/2.
    """
    return (1.0 - p) ** 2 * j_total


def selection_advantage(sens_vec: np.ndarray, p: float, b: float,
                        seed: int = 0) -> dict:
    """Empirical eps for {selective, random, none} at ratio p (paper's key
    observation, used by benchmarks and tests)."""
    from repro.core import selection

    s = np.asarray(sens_vec, dtype=np.float64).ravel()
    sel = selection.top_p_mask(s, p)
    rnd = selection.random_mask(p, s.size, seed=seed)
    return {
        "eps_none": epsilon_all_plaintext(s, b),
        "eps_random": epsilon_total(s, rnd, b),
        "eps_selective": epsilon_total(s, sel, b),
    }
