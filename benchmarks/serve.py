"""Aggregation-service throughput: sustained updates/sec at fleet scale.

Drives `repro.serve.AggregationService` with a 10k-client simulated fleet
(`repro.serve.sim.Fleet` — template ciphertexts, per-client rewritten
UPDATE_BEGIN headers, so the fleet costs bytes, not HE) under a partial
quorum: every round seals at `target_clients`, the stragglers behind the
seal are dropped, and the service's background worker folds round r while
the driver is already submitting round r+1 — the async overlap is ON for
the measured window.

Reported rates:
  * submit_rate  — accepted updates/sec through `submit()` per round
    (parse header, dedup, spool-free accept) while the worker folds.
  * sustained_updates_per_s — folded updates / total wall across all
    rounds including the final drain: the end-to-end service number the
    README table quotes.

Full mode writes BENCH_serve.json (repo root); --smoke shrinks the ring
(N=64, 1 chunk) but keeps the fleet at 10k clients so the partial-quorum
path is exercised at scale, and touches no repo artifacts.
"""
from __future__ import annotations

import json
import os
import time


def run_serve(smoke: bool = False) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import obs, serve
    from repro.core.ckks import cipher
    from repro.core.ckks import params as ckks_params
    from repro.core.secure_agg import ProtectedUpdate
    from repro.kernels import ops
    from repro.serve import sim as ssim
    from repro.wire import stream as ws

    if smoke:
        n_poly, n_chunks, rounds = 64, 1, 2
    else:
        n_poly, n_chunks, rounds = 256, 2, 3
    n_clients, target, min_clients = 10_000, 8_000, 1_000
    ctx = ckks_params.make_test_context(n_poly=n_poly, n_limbs=2,
                                        delta_bits=20)
    sk, pk = cipher.keygen(ctx, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)

    def template(seed: int) -> bytes:
        v = rng.randn(n_chunks, ctx.slots).astype(np.float32)
        ct = cipher.encrypt_values(ctx, pk, jnp.asarray(v),
                                   jax.random.PRNGKey(seed))
        upd = ProtectedUpdate(ct=ct, plain=jnp.asarray(
            rng.randn(32).astype(np.float32)))
        return ws.pack_update_frames(upd, cid=0, n_samples=1, rnd=0)

    fleet = ssim.Fleet([template(s) for s in range(4)], n_clients, seed=7)
    pol = serve.QuorumPolicy(min_clients=min_clients, target_clients=target)
    svc = serve.AggregationService(ctx, pol, fold_batch=256)

    rows = []
    svc.start()
    try:
        t_all = time.perf_counter()
        for _ in range(rounds):
            rnd = svc.open_round()
            accepted = stragglers = 0
            t0 = time.perf_counter()
            for cid, blob in fleet.blobs(rnd):
                res = svc.submit(blob)
                if res.accepted:
                    accepted += 1
                else:
                    # the round sealed at target mid-fleet: everyone behind
                    # the seal is a straggler the quorum already covered
                    stragglers += 1
            submit_s = time.perf_counter() - t0
            rows.append({"round": rnd, "accepted": accepted,
                         "stragglers_dropped": stragglers,
                         "submit_s": submit_s,
                         "submit_rate": accepted / submit_s})
        # drain the tail: the last round is still folding in the worker
        # (bail if the worker died — its error is re-raised below)
        while svc.unfinished() and svc.worker_error is None:
            time.sleep(0.01)
        wall = time.perf_counter() - t_all
    finally:
        svc.stop()
    if svc.worker_error is not None:
        raise svc.worker_error

    folded = 0
    for row in rows:
        info = svc.round_info(row["round"])
        assert info["status"] == serve.ST_DONE, info
        assert info["sealed_reason"] == "target", info
        row["folded"] = info["folded"]
        folded += info["folded"]

    results = {
        "bench": "serve",
        "backend": ops.get_backend(),
        "provenance": obs.provenance(),
        "config": {
            "n_poly": n_poly, "n_limbs": 2, "n_chunks": n_chunks,
            "n_clients": n_clients, "target_clients": target,
            "min_clients": min_clients, "rounds": rounds,
            "blob_bytes": len(fleet.templates[0]), "fold_batch": 256,
        },
        "rows": rows,
        "wall_s": wall,
        "sustained_updates_per_s": folded / wall,
    }

    if not smoke:
        root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
        with open(os.path.join(root, "BENCH_serve.json"), "w") as f:
            json.dump(results, f, indent=2)
            f.write("\n")

    from benchmarks.run import _rows
    _rows(f"Aggregation service: {n_clients} simulated clients, quorum "
          f"target {target}, async overlap on (N={n_poly}, "
          f"chunks={n_chunks}"
          + (" [smoke — no artifacts]" if smoke
             else "; BENCH_serve.json written") + ")",
          rows, keys=["round", "accepted", "stragglers_dropped", "folded",
                      "submit_s", "submit_rate"])
    print(f"sustained: {results['sustained_updates_per_s']:.0f} "
          f"updates/s over {rounds} rounds ({wall:.1f}s wall)")
    return results
