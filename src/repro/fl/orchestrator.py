"""FL task orchestration: the full paper pipeline (Figure 3).

  stage 1  key agreement        (KeyAuthority | ThresholdKeyAuthority)
  stage 2  encryption-mask calc (clients' sensitivity maps, HE-aggregated)
  stage 3  encrypted rounds     (Algorithm 1) with:
             - client sampling per round
             - dropout simulation (clients fail mid-round; weights
               renormalize over survivors — no protocol restart)
             - straggler deadlines (simulated wall-clock per client)
             - elastic client pool (join/leave between rounds)
             - round-boundary checkpointing + resume
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.ckpt import CheckpointManager
from repro.core import packing, secure_agg
from repro.core.secure_agg import AggregatorConfig, SelectiveHEAggregator
from repro.fl.client import ClientConfig, FLClient
from repro.fl.keys import KeyAuthority, ThresholdKeyAuthority
from repro.fl.server import FLServer, ReceivedUpdate
from repro.models import Model
from repro.wire import budget as wire_budget
from repro.wire import compress as wire_compress
from repro.wire import format as wire_format
from repro.wire.compress import WirePolicy


@dataclasses.dataclass
class FLRunConfig:
    n_rounds: int = 5
    clients_per_round: int = 0          # 0 = all
    dropout_prob: float = 0.0           # per-client, per-round
    straggler_prob: float = 0.0         # client exceeds the deadline
    deadline_s: float = float("inf")    # simulated round deadline
    threshold_mode: bool = False        # threshold HE decryption
    threshold_t: int = 0                # parties needed (0 = all)
    ckpt_dir: str | None = None
    ckpt_every: int = 1
    seed: int = 0
    # repro.wire transport: None keeps the legacy in-memory hand-off (comm
    # bytes estimated); a WirePolicy serializes every update, streams it
    # through the O(1)-memory server ingest, and logs measured bytes.
    wire_policy: WirePolicy | None = None


@dataclasses.dataclass
class RoundLog:
    round: int
    loss: float
    n_participating: int
    n_dropped: int
    comm_bytes: int
    wall_s: float
    comm_up_bytes: int = 0      # measured uplink (wire mode only)
    comm_down_bytes: int = 0    # measured downlink (wire mode only)
    comm_measured: bool = False  # True = bytes-on-wire, False = estimate


class FLTask:
    """Owns (model, clients, server, keys) and runs the 3-stage pipeline."""

    def __init__(self, model: Model, clients: list[FLClient],
                 agg_cfg: AggregatorConfig, run_cfg: FLRunConfig,
                 ctx=None):
        self.model = model
        self.clients = clients
        self.agg_cfg = agg_cfg
        self.run_cfg = run_cfg
        self.rng = np.random.RandomState(run_cfg.seed)

        # stage 1 — key agreement
        if run_cfg.threshold_mode:
            self.authority = ThresholdKeyAuthority(
                n_parties=len(clients), ctx=ctx, seed=run_cfg.seed)
            self.pk = self.authority.public_key()
            self.sk = None
        else:
            self.authority = KeyAuthority(ctx=ctx, seed=run_cfg.seed)
            self.pk, self.sk = self.authority.client_keys()
        self.ctx = self.authority.ctx

        self.global_params = model.init(jax.random.PRNGKey(run_cfg.seed))
        # base for per-(round, client) encryption keys, distinct from the
        # model-init stream
        self._round_key_base = jax.random.fold_in(
            jax.random.PRNGKey(run_cfg.seed), 0x5EC)
        self.server: FLServer | None = None
        self.aggregator: SelectiveHEAggregator | None = None
        # the task owns round accounting: always (re)attach its ledger, so
        # clients reused from a previous FLTask record into THIS task's
        # ledger rather than the old one
        self.ledger = wire_budget.BandwidthLedger()
        for c in clients:
            c.ledger = self.ledger
        self.logs: list[RoundLog] = []
        self._ckpt = (CheckpointManager(run_cfg.ckpt_dir)
                      if run_cfg.ckpt_dir else None)
        self._start_round = 0

    # -- stage 2: encryption-mask agreement -----------------------------------

    def agree_encryption_mask(self):
        spec = packing.make_flat_spec(self.global_params)
        if self.agg_cfg.strategy in ("all", "none", "random"):
            # sensitivity-free strategies: no map exchange needed
            sens = np.zeros(spec.total)
            self.aggregator = SelectiveHEAggregator.build(
                self.ctx, self.global_params, sens, self.agg_cfg)
        else:
            # sensitivity-driven strategies (top_p / per_layer / recipe):
            # HE-aggregate the clients' local maps, then apply the
            # configured selector to the decrypted aggregate
            sens_maps = [c.sensitivity_map(self.global_params)
                         for c in self.clients]
            weights = [1.0 / len(sens_maps)] * len(sens_maps)
            if self.run_cfg.threshold_mode:
                # threshold path: aggregate in the clear between clients
                # (maps are lower-sensitivity than weights; microbenchmarked
                # HE path is exercised in single-key mode)
                from repro.core import selection
                glob = sum(w * s for w, s in zip(weights, sens_maps))
                mask = selection.build_mask(
                    glob, self.agg_cfg.strategy, self.agg_cfg.p_ratio,
                    offsets=spec.offsets, sizes=spec.sizes,
                    seed=self.agg_cfg.seed)
            else:
                mask = secure_agg.agree_mask(
                    self.ctx, self.pk, self.sk, sens_maps, weights,
                    self.agg_cfg.p_ratio, jax.random.PRNGKey(7),
                    strategy=self.agg_cfg.strategy, offsets=spec.offsets,
                    sizes=spec.sizes, seed=self.agg_cfg.seed)
            part = packing.make_partition(mask, self.ctx.slots)
            self.aggregator = SelectiveHEAggregator(
                self.ctx, spec, part, self.agg_cfg)
        self.server = FLServer(self.aggregator, ledger=self.ledger)
        return self.aggregator

    # -- resume ----------------------------------------------------------------

    def maybe_resume(self):
        if self._ckpt is None:
            return
        tree, step, _ = self._ckpt.restore(self.global_params)
        if tree is not None:
            self.global_params = jax.tree_util.tree_map(jnp.asarray, tree)
            self._start_round = step + 1

    # -- stage 3: encrypted federated rounds ------------------------------------

    def run_round(self, rnd: int) -> RoundLog:
        with obs.span("round", round=rnd) as sp:
            log = self._run_round(rnd, sp)
            sp.set(loss=log.loss, n_participating=log.n_participating,
                   n_dropped=log.n_dropped, bytes_up=log.comm_up_bytes,
                   bytes_down=log.comm_down_bytes, wall_s=log.wall_s)
        return log

    def _run_round(self, rnd: int, sp) -> RoundLog:
        # perf_counter: monotonic, immune to wall-clock steps; RoundLog
        # wall_s is a duration, not a timestamp
        t0 = time.perf_counter()
        cfg = self.run_cfg
        n = len(self.clients)
        k = cfg.clients_per_round or n
        chosen = self.rng.choice(n, size=min(k, n), replace=False)

        use_wire = cfg.wire_policy is not None
        received, dropped = [], 0
        wire_blobs, wire_clients = [], []
        losses = []
        for ci in chosen:
            client = self.clients[ci]
            if self.rng.rand() < cfg.dropout_prob:
                dropped += 1
                continue                      # client crashed mid-round
            with obs.span("client", cid=int(ci)):
                local_params, loss = client.local_train(self.global_params)
                simulated_s = self.rng.exponential(1.0)
                if self.rng.rand() < cfg.straggler_prob:
                    simulated_s += cfg.deadline_s   # guaranteed late
                if simulated_s > cfg.deadline_s:
                    dropped += 1
                    continue                  # straggler cut at the deadline
                losses.append(loss)
                # collision-free per-(round, client) stream: fold_in is
                # injective per step, unlike the old PRNGKey(rnd * 1000 + ci)
                # arithmetic which collides once client indices reach the
                # round stride
                key = jax.random.fold_in(
                    jax.random.fold_in(self._round_key_base, rnd), int(ci))
                if use_wire:
                    blob = client.protect_and_pack(
                        self.aggregator, local_params, rnd=rnd,
                        policy=cfg.wire_policy, pk=self.pk,
                        sk=None if cfg.threshold_mode else self.sk, key=key)
                    wire_blobs.append(blob)
                    wire_clients.append(client)
                else:
                    upd = self.aggregator.client_protect(local_params,
                                                         self.pk, key)
                    received.append(ReceivedUpdate(
                        cid=int(ci), update=upd,
                        n_samples=max(1, client.n_samples), round_sent=rnd))
        if not received and not wire_blobs:
            # total dropout: keep the old global model, log and move on
            return RoundLog(rnd, float("nan"), 0, dropped, 0,
                            time.perf_counter() - t0)
        if use_wire:
            agg, n_recv = self._wire_round(rnd, wire_blobs, wire_clients)
            with obs.span("recover"):
                self.global_params = obs.maybe_block(self._recover(agg))
            up = self.ledger.total(wire_budget.UPLINK, rnd)
            down = self.ledger.total(wire_budget.DOWNLINK, rnd)
            log = RoundLog(rnd, float(np.mean(losses)), n_recv, dropped,
                           up + down, time.perf_counter() - t0,
                           comm_up_bytes=up, comm_down_bytes=down,
                           comm_measured=True)
        else:
            with obs.span("aggregate", n_updates=len(received)):
                agg = self.server.aggregate_sync(received)
            with obs.span("recover"):
                self.global_params = obs.maybe_block(self._recover(agg))
            rep = self.aggregator.overhead_report()
            comm = (rep["bytes_total"]) * len(received)
            log = RoundLog(rnd, float(np.mean(losses)), len(received),
                           dropped, comm, time.perf_counter() - t0)
        self.logs.append(log)
        if self._ckpt is not None and (rnd + 1) % cfg.ckpt_every == 0:
            with obs.span("checkpoint", round=rnd):
                self._ckpt.save(rnd, self.global_params,
                                extra={"loss": log.loss})
        return log

    def _wire_round(self, rnd, wire_blobs, wire_clients):
        """Serialized transport: stream blobs through the O(1) server
        ingest, apply the downlink policy, broadcast, deserialize."""
        policy = self.run_cfg.wire_policy
        with obs.span("aggregate", n_updates=len(wire_blobs)):
            agg = self.server.aggregate_wire(wire_blobs)
        with obs.span("broadcast", n_clients=len(wire_clients)):
            keep = policy.downlink_keep_limbs
            if (keep and keep < agg.ct.n_limbs
                    and not self.run_cfg.threshold_mode):
                agg = secure_agg.ProtectedUpdate(
                    ct=wire_compress.limb_drop(self.ctx, agg.ct, keep),
                    plain=agg.plain)
            blob_down = wire_format.serialize_update(agg)
            out = None
            for client in wire_clients:
                out = client.receive_global(blob_down, self.ctx, rnd=rnd)
        return out, len(wire_clients)

    def _recover(self, agg):
        if self.run_cfg.threshold_mode:
            t = self.run_cfg.threshold_t or len(self.clients)
            partials = [self.authority.partial_decrypt(
                i, agg.ct, jax.random.PRNGKey(900 + i)) for i in range(t)]
            coeffs = self.authority.combine(agg.ct, partials)
            from repro.core.ckks import encoding
            enc = jnp.asarray(encoding.decode_np(
                np.asarray(coeffs), self.ctx, agg.ct.scale))
            vec = packing.merge_by_mask(enc, agg.plain, self.aggregator.part)
            return packing.unflatten_params(vec, self.aggregator.spec)
        return self.aggregator.client_recover_params(agg, self.sk)

    def run(self) -> list[RoundLog]:
        if self.aggregator is None:
            self.agree_encryption_mask()
        self.maybe_resume()
        for rnd in range(self._start_round, self.run_cfg.n_rounds):
            self.run_round(rnd)
        return self.logs

    # -- elasticity -------------------------------------------------------------

    def add_client(self, client: FLClient):
        """Elastic scale-up: new clients only need (pk, sk) + the public
        mask — no re-keying, no mask re-agreement."""
        client.ledger = self.ledger
        self.clients.append(client)

    def remove_client(self, cid: int):
        self.clients = [c for c in self.clients if c.cid != cid]


def run_federated_training(model: Model, clients: list[FLClient],
                           agg_cfg: AggregatorConfig,
                           run_cfg: FLRunConfig, ctx=None) -> FLTask:
    task = FLTask(model, clients, agg_cfg, run_cfg, ctx=ctx)
    task.run()
    return task
