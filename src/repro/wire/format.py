"""repro.wire binary format: versioned, length-prefixed frames.

Every FL artifact travels as one or more frames:

    [4s magic "RPWR"][u8 version][u8 type][u16 flags][u64 payload_len][payload]

Length-prefixing makes the stream self-delimiting: a receiver can split a
byte stream into frames without understanding the payloads, and frames nest
(a PROTECTED_UPDATE payload contains a ciphertext frame and a plain-segment
frame).  Arrays inside payloads are encoded as

    [u8 dtype_code][u8 ndim][u32 dims...][raw little-endian bytes]

All integers are little-endian.  See DESIGN.md §6 for the full layout and
the compression flags; DESIGN.md §9.2 is the normative v2 appendix.

Versioning (DESIGN.md §9.2)
---------------------------
The header's version byte is per FRAME, so frames of different versions mix
freely in one stream (a v2 update may nest a v1 ciphertext frame and vice
versa).  This build speaks versions 1 and 2:

  * v1 — the PR-1 layout.  Decoded forever; never removed.
  * v2 — identical to v1 for every frame type EXCEPT SEEDED_CIPHERTEXT,
    which gains a trailing `u8 derive` field in the fixed header naming the
    per-chunk seed-derivation algorithm (DERIVE_* below).  v1 seeded frames
    decode with the implicit v1 algorithm, DERIVE_FOLD_CHUNK.

Emission defaults to `VERSION` (= 2); set REPRO_WIRE_VERSION=1 to pin a
sender to the legacy layout during rollout (canonical knob list: README.md
"Environment variables & flags").  Unknown versions raise WireError — the
protocol never guesses at layouts it postdates.
"""
from __future__ import annotations

import os
import struct
from typing import Iterator

import numpy as np

from repro.core.ckks.cipher import Ciphertext
from repro.core.packing import MaskPartition
from repro.wire.compress import (DERIVE_CTR, DERIVE_FOLD_CHUNK,
                                 DERIVES, MaskedChunk, SeededCiphertext)

MAGIC = b"RPWR"
VERSION = 2                      # default emit version
SUPPORTED_VERSIONS = (1, 2)      # what parse_frame accepts

_HEADER = struct.Struct("<4sBBHQ")
HEADER_BYTES = _HEADER.size

# frame types (payload layouts: DESIGN.md §8.5 for v1, §9.2 for the v2 diff)
T_CIPHERTEXT = 0x01          # f64 scale + u32[B, L, 2, N] array (all versions)
T_SEEDED_CIPHERTEXT = 0x02   # v1: f64 scale, u64 seed, u32 chunk_offset +
                             #     u32[B, L, N] c0
                             # v2: + u8 derive between chunk_offset and c0
T_PROTECTED_UPDATE = 0x03    # nested (SEEDED_)CIPHERTEXT + PLAIN_SEGMENT
T_KEYSET = 0x04              # named-array bundle: pk / eval keys / sk shares
T_MASK_PARTITION = 0x05      # u64 n_total, u32 slots + enc/plain idx arrays
# streaming uplink protocol (repro.wire.stream); layouts version-invariant
T_UPDATE_BEGIN = 0x06        # u32 cid, n_samples, round, n_chunks; u8 ct_kind
T_CT_CHUNK = 0x07            # u32 chunk_idx + one nested one-chunk ct frame
T_PLAIN_SEGMENT = 0x08       # u8 codec, f64 qscale + quantized array
T_UPDATE_END = 0x09          # empty payload
# transcipher (hybrid-HE) uplink frames (DESIGN.md §15); v2+ only — these
# frame types postdate v1 and have no legacy layout to imply
T_MASKED_CHUNK = 0x0A        # f64 scale, u64 a_seed, u32 chunk_offset,
                             #     u8 derive + u32[B, N] masked coefficients
T_TRANSCIPHER_SEED = 0x0B    # one nested SEEDED_CIPHERTEXT frame: the
                             #     escrow encryption of the keystream seed

# seed-derivation algorithm ids carried by v2 SEEDED_CIPHERTEXT frames
# (DESIGN.md §9.2).  The registry lives in core/ckks/cipher.py and is
# re-exported through compress.py (import layering: this module imports
# SeededCiphertext from there); DERIVES is the sorted tuple of known ids —
# currently (DERIVE_FOLD_CHUNK, DERIVE_CTR) = (1, 2).

_DTYPE_CODES = {
    np.dtype(np.uint32): 0, np.dtype(np.float32): 1, np.dtype(np.float16): 2,
    np.dtype(np.int8): 3, np.dtype(np.float64): 4, np.dtype(np.int32): 5,
    np.dtype(np.uint8): 6, np.dtype(np.int64): 7,
}
_CODE_DTYPES = {v: k for k, v in _DTYPE_CODES.items()}

_PLAIN_CODEC_IDS = {"f32": 0, "f16": 2, "i8": 3}
_PLAIN_CODEC_NAMES = {v: k for k, v in _PLAIN_CODEC_IDS.items()}


class WireError(ValueError):
    pass


class NeedMoreData(WireError):
    """Raised when a buffer ends mid-frame (incremental readers catch it)."""


def _emit_version_from_env() -> int:
    """Sender-side pin for staged rollouts: REPRO_WIRE_VERSION=1 makes
    every frame() call emit the legacy layout (README.md "Environment
    variables & flags").  Read once at import, like REPRO_HE_BACKEND;
    bad values fail HERE, loudly, not at the first emit."""
    raw = os.environ.get("REPRO_WIRE_VERSION")
    if raw is None:
        return VERSION
    try:
        v = int(raw)
    except ValueError:
        v = None
    if v not in SUPPORTED_VERSIONS:
        raise WireError(
            f"REPRO_WIRE_VERSION={raw!r} is not a supported wire version; "
            f"this build speaks {SUPPORTED_VERSIONS} (README.md "
            "'Environment variables & flags')")
    return v


EMIT_VERSION = _emit_version_from_env()


# ---------------------------------------------------------------------------
# frame envelope
# ---------------------------------------------------------------------------


def frame(ftype: int, payload: bytes, flags: int = 0,
          version: int | None = None) -> bytes:
    """Wrap `payload` in a frame envelope.

    `version` defaults to EMIT_VERSION (the REPRO_WIRE_VERSION override,
    else VERSION); pass it explicitly to emit a specific legacy layout —
    the caller is responsible for the payload matching that version."""
    version = EMIT_VERSION if version is None else version
    if version not in SUPPORTED_VERSIONS:
        raise WireError(
            f"cannot emit wire version {version}; this build speaks "
            f"{SUPPORTED_VERSIONS} (README.md 'Environment variables & "
            "flags', REPRO_WIRE_VERSION)")
    return _HEADER.pack(MAGIC, version, ftype, flags, len(payload)) + payload


def parse_frame_v(buf, off: int = 0) -> tuple[int, int, int, memoryview, int]:
    """-> (ftype, flags, version, payload, next_off).

    Raises NeedMoreData on a truncated buffer; WireError on bad magic or a
    version this build does not speak (the error names the README section
    and the REPRO_WIRE_VERSION sender pin so operators know which side to
    flip)."""
    view = memoryview(buf)
    if len(view) - off < HEADER_BYTES:
        raise NeedMoreData("incomplete frame header")
    magic, version, ftype, flags, plen = _HEADER.unpack_from(view, off)
    if magic != MAGIC:
        raise WireError(f"bad magic {magic!r} at offset {off}")
    if version not in SUPPORTED_VERSIONS:
        raise WireError(
            f"unsupported wire version {version}: this build speaks "
            f"versions {SUPPORTED_VERSIONS}. Upgrade this receiver, or pin "
            "the sender to a legacy layout with REPRO_WIRE_VERSION=1 — see "
            "README.md 'Environment variables & flags' and the version "
            "rules in DESIGN.md §9.2")
    end = off + HEADER_BYTES + plen
    if len(view) < end:
        raise NeedMoreData("incomplete frame payload")
    return ftype, flags, version, view[off + HEADER_BYTES:end], end


def parse_frame(buf, off: int = 0) -> tuple[int, int, memoryview, int]:
    """-> (ftype, flags, payload, next_off); parse_frame_v without the
    version (kept for callers that only split frames)."""
    ftype, flags, _, payload, end = parse_frame_v(buf, off)
    return ftype, flags, payload, end


def iter_frames(buf) -> Iterator[tuple[int, int, memoryview]]:
    off = 0
    n = len(buf)
    while off < n:
        ftype, flags, payload, off = parse_frame(buf, off)
        yield ftype, flags, payload


class FrameReader:
    """Incremental frame splitter: feed() arbitrary byte slices, pop()
    complete frames.  Holds at most one partial frame of buffered bytes."""

    def __init__(self):
        self._buf = bytearray()

    def feed(self, data: bytes) -> None:
        self._buf.extend(data)

    def pop(self):
        """-> (ftype, flags, payload bytes) or None if no complete frame."""
        try:
            ftype, flags, payload, end = parse_frame(self._buf, 0)
        except NeedMoreData:
            return None
        out = (ftype, flags, bytes(payload))
        payload.release()          # else the bytearray can't be resized
        del self._buf[:end]
        return out

    def __iter__(self):
        while True:
            item = self.pop()
            if item is None:
                return
            yield item


# ---------------------------------------------------------------------------
# array primitive
# ---------------------------------------------------------------------------


def pack_array(a) -> bytes:
    a = np.ascontiguousarray(np.asarray(a))
    code = _DTYPE_CODES.get(a.dtype)
    if code is None:
        raise WireError(f"unsupported wire dtype {a.dtype}")
    head = struct.pack("<BB", code, a.ndim)
    dims = struct.pack(f"<{a.ndim}I", *a.shape) if a.ndim else b""
    return head + dims + a.tobytes()


def unpack_array(payload, off: int = 0) -> tuple[np.ndarray, int]:
    view = memoryview(payload)
    code, ndim = struct.unpack_from("<BB", view, off)
    off += 2
    shape = struct.unpack_from(f"<{ndim}I", view, off) if ndim else ()
    off += 4 * ndim
    dtype = _CODE_DTYPES.get(code)
    if dtype is None:
        raise WireError(f"unknown dtype code {code}")
    # python-int size math (u32 dims from a corrupt frame can overflow
    # fixed-width accumulators), bounds-checked BEFORE touching the buffer:
    # the decoder must never over-read, however the dims were mutated.
    count = 1
    for d in shape:
        count *= int(d)
    nbytes = count * dtype.itemsize
    if nbytes > len(view) - off:
        raise WireError(
            f"array of {count} x {dtype} ({nbytes} B) exceeds the "
            f"{len(view) - off} payload bytes remaining")
    arr = np.frombuffer(view, dtype=dtype, count=count, offset=off)
    return arr.reshape(shape).copy(), off + nbytes


# ---------------------------------------------------------------------------
# ciphertexts
# ---------------------------------------------------------------------------


def serialize_ciphertext(ct: Ciphertext, version: int | None = None) -> bytes:
    """Full ciphertext -> one frame (payload layout version-invariant)."""
    payload = struct.pack("<d", float(ct.scale)) + pack_array(
        np.asarray(ct.data, dtype=np.uint32))
    return frame(T_CIPHERTEXT, payload, version=version)


def _parse_ciphertext(payload) -> Ciphertext:
    (scale,) = struct.unpack_from("<d", payload, 0)
    data, _ = unpack_array(payload, 8)
    return Ciphertext(data=data, scale=scale)


def serialize_seeded_ciphertext(sct: SeededCiphertext,
                                version: int | None = None) -> bytes:
    """Seeded ciphertext -> one frame.

    v2 (default) carries sct.derive as the per-chunk seed-derivation id;
    v1 has no derive field and can only express DERIVE_FOLD_CHUNK (the
    implicit v1 algorithm) — any other derive id refuses to down-serialize
    rather than silently changing meaning."""
    version = EMIT_VERSION if version is None else version
    arr = pack_array(np.asarray(sct.c0, dtype=np.uint32))
    head = struct.pack("<dQI", float(sct.scale), int(sct.seed),
                       int(sct.chunk_offset))
    if version == 1:
        if sct.derive != DERIVE_FOLD_CHUNK:
            raise WireError(
                f"seed-derivation id {sct.derive} is not expressible in "
                "wire v1 frames (v1 implies derive="
                f"{DERIVE_FOLD_CHUNK}); emit v2 (DESIGN.md §9.2)")
        return frame(T_SEEDED_CIPHERTEXT, head + arr, version=1)
    return frame(T_SEEDED_CIPHERTEXT,
                 head + struct.pack("<B", int(sct.derive)) + arr,
                 version=version)


def _parse_seeded_ciphertext(payload, version: int = 1) -> SeededCiphertext:
    scale, seed, chunk_offset = struct.unpack_from("<dQI", payload, 0)
    off = struct.calcsize("<dQI")
    derive = DERIVE_FOLD_CHUNK
    if version >= 2:
        (derive,) = struct.unpack_from("<B", payload, off)
        off += 1
        if derive not in DERIVES:
            raise WireError(
                f"unknown seed-derivation id {derive} in v{version} seeded "
                f"ciphertext; this build knows {DERIVES} (DESIGN.md §9.2)")
    c0, _ = unpack_array(payload, off)
    return SeededCiphertext(c0=c0, seed=seed, scale=scale,
                            chunk_offset=chunk_offset, derive=derive)


# ---------------------------------------------------------------------------
# transcipher uplink (DESIGN.md §15)
# ---------------------------------------------------------------------------


def serialize_masked_chunk(mc: MaskedChunk,
                           version: int | None = None) -> bytes:
    """Masked transcipher chunk -> one frame.  v2+ only: the type postdates
    v1, so down-serialization refuses rather than inventing a layout."""
    version = EMIT_VERSION if version is None else version
    if version < 2:
        raise WireError(
            "transcipher masked chunks are not expressible in wire v1 "
            "frames; emit v2 (DESIGN.md §15)")
    head = struct.pack("<dQI", float(mc.scale), int(mc.a_seed),
                       int(mc.chunk_offset))
    payload = head + struct.pack("<B", int(mc.derive)) \
        + pack_array(np.asarray(mc.masked, dtype=np.uint32))
    return frame(T_MASKED_CHUNK, payload, version=version)


def _parse_masked_chunk(payload, version: int) -> MaskedChunk:
    if version < 2:
        raise WireError(
            "masked transcipher chunk in a v1 frame; transcipher requires "
            "wire v2 (DESIGN.md §15)")
    scale, a_seed, chunk_offset = struct.unpack_from("<dQI", payload, 0)
    off = struct.calcsize("<dQI")
    (derive,) = struct.unpack_from("<B", payload, off)
    off += 1
    if derive not in DERIVES:
        raise WireError(
            f"unknown seed-derivation id {derive} in v{version} masked "
            f"chunk; this build knows {DERIVES} (DESIGN.md §9.2)")
    masked, _ = unpack_array(payload, off)
    if masked.dtype != np.uint32 or masked.ndim != 2:
        raise WireError(
            f"masked chunk array must be u32[B, N], got "
            f"{masked.dtype}[{masked.ndim}d]")
    return MaskedChunk(masked=masked, a_seed=a_seed, scale=scale,
                       chunk_offset=chunk_offset, derive=derive)


def serialize_transcipher_seed(sct: SeededCiphertext,
                               version: int | None = None) -> bytes:
    """The escrow keystream-seed ciphertext -> one wrapper frame (nests a
    normal seeded-ciphertext frame; v2+ only like every transcipher
    frame)."""
    version = EMIT_VERSION if version is None else version
    if version < 2:
        raise WireError(
            "transcipher seed frames are not expressible in wire v1 "
            "frames; emit v2 (DESIGN.md §15)")
    return frame(T_TRANSCIPHER_SEED,
                 serialize_seeded_ciphertext(sct, version=version),
                 version=version)


# ---------------------------------------------------------------------------
# plain segment (quantized plaintext partition)
# ---------------------------------------------------------------------------


def serialize_plain_segment(arr: np.ndarray, codec: str, qscale: float,
                            version: int | None = None) -> bytes:
    payload = struct.pack("<Bd", _PLAIN_CODEC_IDS[codec], float(qscale)) \
        + pack_array(arr)
    return frame(T_PLAIN_SEGMENT, payload, version=version)


def _parse_plain_segment(payload) -> tuple[np.ndarray, str, float]:
    codec_id, qscale = struct.unpack_from("<Bd", payload, 0)
    arr, _ = unpack_array(payload, struct.calcsize("<Bd"))
    return arr, _PLAIN_CODEC_NAMES[codec_id], qscale


# ---------------------------------------------------------------------------
# protected update (one-shot, non-streaming)
# ---------------------------------------------------------------------------


def serialize_update(upd, *, seeded: SeededCiphertext | None = None,
                     plain_codec: str = "f32",
                     version: int | None = None) -> bytes:
    """ProtectedUpdate -> one nested frame.

    If `seeded` is given it replaces upd.ct on the wire (the caller got it
    from compress.seed_compress on a seeded encryption of the same values).
    `version` pins every frame in the nest (default: the emit default).
    """
    from repro.wire import compress as _c
    ct_frame = (serialize_seeded_ciphertext(seeded, version=version)
                if seeded is not None
                else serialize_ciphertext(upd.ct, version=version))
    arr, qscale = _c.quantize_plain(np.asarray(upd.plain), plain_codec)
    return frame(T_PROTECTED_UPDATE,
                 ct_frame + serialize_plain_segment(arr, plain_codec, qscale,
                                                    version=version),
                 version=version)


def _parse_update(payload, ctx):
    from repro.core.secure_agg import ProtectedUpdate
    from repro.wire import compress as _c
    ftype, _, ct_version, ct_payload, off = parse_frame_v(payload, 0)
    if ftype == T_CIPHERTEXT:
        ct = _parse_ciphertext(ct_payload)
    elif ftype == T_SEEDED_CIPHERTEXT:
        if ctx is None:
            raise WireError("seeded ciphertext needs a ctx to expand")
        ct = _parse_seeded_ciphertext(ct_payload, ct_version).expand(ctx)
    else:
        raise WireError(f"unexpected inner frame type {ftype}")
    ftype, _, pl_payload, _ = parse_frame(payload, off)
    if ftype != T_PLAIN_SEGMENT:
        raise WireError(f"expected plain segment, got type {ftype}")
    arr, codec, qscale = _parse_plain_segment(pl_payload)
    return ProtectedUpdate(ct=ct, plain=_c.dequantize_plain(arr, codec, qscale))


# ---------------------------------------------------------------------------
# key bundles + mask partition
# ---------------------------------------------------------------------------


def serialize_keyset(keys: dict) -> bytes:
    """dict[str, array] -> frame (covers pk, eval keys, threshold shares)."""
    parts = [struct.pack("<I", len(keys))]
    for name, arr in sorted(keys.items()):
        nb = name.encode("utf-8")
        parts.append(struct.pack("<H", len(nb)) + nb)
        parts.append(pack_array(np.asarray(arr, dtype=np.uint32)))
    return frame(T_KEYSET, b"".join(parts))


def _parse_keyset(payload) -> dict:
    (n,) = struct.unpack_from("<I", payload, 0)
    if n > (len(payload) - 4) // 4:
        # every entry needs >= 4 bytes (name length + array head): a bound
        # that keeps a corrupt count from driving a multi-billion-iteration
        # parse loop
        raise WireError(f"keyset declares {n} entries but only "
                        f"{len(payload) - 4} payload bytes follow")
    off = 4
    out = {}
    for _ in range(n):
        (nlen,) = struct.unpack_from("<H", payload, off)
        off += 2
        name = bytes(memoryview(payload)[off:off + nlen]).decode("utf-8")
        off += nlen
        arr, off = unpack_array(payload, off)
        out[name] = arr
    return out


def serialize_partition(part: MaskPartition) -> bytes:
    payload = struct.pack("<QI", part.n_total, part.slots) \
        + pack_array(part.enc_idx) + pack_array(part.plain_idx)
    return frame(T_MASK_PARTITION, payload)


def _parse_partition(payload) -> MaskPartition:
    n_total, slots = struct.unpack_from("<QI", payload, 0)
    off = struct.calcsize("<QI")
    enc_idx, off = unpack_array(payload, off)
    plain_idx, _ = unpack_array(payload, off)
    return MaskPartition(n_total=int(n_total),
                         enc_idx=enc_idx.astype(np.int32),
                         plain_idx=plain_idx.astype(np.int32),
                         slots=int(slots))


# ---------------------------------------------------------------------------
# generic entry point
# ---------------------------------------------------------------------------

_PARSERS = {
    T_CIPHERTEXT: lambda p, ctx, v: _parse_ciphertext(p),
    T_SEEDED_CIPHERTEXT: lambda p, ctx, v: _parse_seeded_ciphertext(p, v),
    T_PROTECTED_UPDATE: lambda p, ctx, v: _parse_update(p, ctx),
    T_KEYSET: lambda p, ctx, v: _parse_keyset(p),
    T_MASK_PARTITION: lambda p, ctx, v: _parse_partition(p),
    T_MASKED_CHUNK: lambda p, ctx, v: _parse_masked_chunk(p, v),
    # unwrap to the nested escrow seeded-ciphertext artifact
    T_TRANSCIPHER_SEED: lambda p, ctx, v: deserialize(p, ctx, 0)[0],
}


def deserialize(buf, ctx=None, off: int = 0):
    """One frame -> (artifact, next_off).  `ctx` is needed to expand seeded
    ciphertexts nested in protected updates.

    Version handling is per frame (header byte): v1 and v2 frames decode
    transparently — the only layout difference is the seeded-ciphertext
    derive field (DESIGN.md §9.2) — and unsupported versions raise
    WireError before any payload is touched.

    Robustness contract (fuzzed in tests/test_wire.py): ANY mutated or
    truncated input raises WireError (NeedMoreData for a short buffer) —
    the decoder never surfaces a raw struct/numpy error, never loops on a
    corrupt count, and never reads past the frame payload."""
    ftype, _, version, payload, end = parse_frame_v(buf, off)
    parser = _PARSERS.get(ftype)
    if parser is None:
        raise WireError(f"no parser for frame type {ftype:#x}")
    try:
        return parser(payload, ctx, version), end
    except WireError:
        raise
    except Exception as e:
        # struct.error / KeyError / reshape ValueError etc. from a payload
        # whose bytes were mutated after the envelope survived
        raise WireError(
            f"malformed frame type {ftype:#x} payload: {e!r}") from e
