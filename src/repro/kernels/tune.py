"""Shape-keyed kernel autotuner: swept launch configs, cached winners,
`auto` backend resolution (DESIGN.md §12).

Every hot HE op bottoms out in a handful of Pallas launch parameters that
used to be frozen at guesses: a per-kernel `block_b`, the sqrt heuristic
for `params.ntt4_split`, and a process-wide env var for the flat-vs-4-step
NTT choice.  This module makes all of them a *measured, per-shape*
decision:

  * **config** — `KernelConfig(block_b, ntt4_split, radix)` is the full
    launch geometry of one kernel invocation.  `DEFAULT_BLOCK` is the one
    table every kernel default routes through (kernels/{ntt,pointwise,
    he_agg}.py take `block_b=None` and ask here), so block sizes live in
    exactly one place.
  * **sweep** — `sweep_op()` measures every candidate
    (backend x block_b x ntt4_split x radix) for one `(op, N, L, B)`
    point with `block_until_ready` wall time, pruning candidates whose
    roofline-model estimate (memory traffic / HBM bandwidth + per-grid-
    step launch overhead, constants from benchmarks/roofline.py) is
    hopeless before ever running them.  The default config is always a
    candidate, so the winner is never slower than the default at
    measurement time.
  * **cache** — winners persist as JSON keyed by
    `op|N<n>|L<l>|B<b>|<platform>` with a meta block recording
    `ops.backend_token()`, platform, and device count at tune time.
    `REPRO_HE_TUNE_CACHE` names the file (README env table); entries for
    a different platform, unknown ops, or malformed configs are stale and
    ignored.
  * **auto** — `REPRO_HE_BACKEND=auto` (kernels/ops.py) resolves every
    dispatch through `resolve()`: cache hit -> the measured winner
    (backend + config), miss -> `DEFAULT_BLOCK` on the platform fallback
    backend.  `generation()` is folded into `ops.backend_token()` so
    jitted graphs retrace whenever the cache (re)loads and the resolved
    config may have changed.

Correctness invariant: a config only changes LAUNCH GEOMETRY — block
sizes, sub-NTT factorization, butterfly radix — never the modular
arithmetic, so every candidate reproduces the gold KATs bit-exactly
(tests/test_tune.py sweeps the full grid against tests/golden/).

Module-level imports stay stdlib-only: the kernel modules import this one
for their defaults, so jax/kernels are imported lazily inside functions.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
import warnings

# ---------------------------------------------------------------------------
# launch-config defaults: the ONE table kernel block sizes route through
# ---------------------------------------------------------------------------

# Per-op default tile height (batch rows per grid step).  weighted_sum and
# weighted_accum_chunks hold n_clients / block_k ciphertext tiles resident
# at once, so their default tile is half the pointwise ops' (the VMEM
# budget note in kernels/he_agg.py) — previously an uncommented magic "4"
# in one signature and "8" in the rest.
DEFAULT_BLOCK = {
    "ntt_fwd": 8,
    "ntt_inv": 8,
    "mul_add": 8,
    "mod_lift": 8,
    "weighted_sum": 4,
    "weighted_accum": 8,
    "weighted_accum_chunks": 4,
}

BLOCK_CANDIDATES = (1, 2, 4, 8, 16)
RADIX_CANDIDATES = (2, 4)
NTT_OPS = ("ntt_fwd", "ntt_inv")

CACHE_VERSION = 1
CACHE_ENV = "REPRO_HE_TUNE_CACHE"

# roofline pruning rule (DESIGN.md §12.3): a candidate whose modelled time
# exceeds PRUNE_RATIO x the best modelled candidate is skipped unmeasured.
PRUNE_RATIO = 3.0
# per-grid-step dispatch overhead for the launch term of the model; the
# exact value only shifts where the memory and launch terms cross, and the
# rule is a >=3x filter, so order of magnitude is enough.
LAUNCH_OVERHEAD_S = 2e-6


def _roofline_constants() -> tuple[float, float]:
    """(HBM bytes/s, peak flop/s) from benchmarks/roofline.py when the
    repo root is importable, else that file's TPU v5e-class constants."""
    try:
        from benchmarks.roofline import HBM_BW, PEAK_FLOPS
        return HBM_BW, PEAK_FLOPS
    except ImportError:
        return 819e9, 197e12


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """Launch geometry of one kernel invocation — never arithmetic.

    block_b: batch rows per grid step (block_k for the chunk kernel).
    ntt4_split: (n1, n2) sub-NTT factorization, None = params.ntt4_split's
        sqrt heuristic (4-step NTT ops only).
    radix: butterfly radix inside the 4-step sub-NTTs (2 or 4; radix 4
        fuses two butterfly stages per pass, halving stage count for the
        length-64/128 sub-transforms).
    """

    block_b: int
    ntt4_split: tuple[int, int] | None = None
    radix: int = 2

    def to_json(self) -> dict:
        return {"block_b": self.block_b,
                "ntt4_split": list(self.ntt4_split)
                if self.ntt4_split else None,
                "radix": self.radix}

    @classmethod
    def from_json(cls, doc: dict) -> "KernelConfig":
        split = doc.get("ntt4_split")
        return cls(block_b=int(doc["block_b"]),
                   ntt4_split=tuple(int(x) for x in split) if split
                   else None,
                   radix=int(doc.get("radix", 2)))


def default_config(op: str) -> KernelConfig:
    """The config a dispatch uses with no cache entry: the DEFAULT_BLOCK
    tile, sqrt split, radix-2 — exactly the pre-autotuner behaviour."""
    return KernelConfig(block_b=DEFAULT_BLOCK[op])


def default_block(op: str) -> int:
    """Kernel-signature fallback: kernels/{ntt,pointwise,he_agg}.py call
    this when their `block_b`/`block_k` kwarg is left None."""
    return DEFAULT_BLOCK[op]


# ---------------------------------------------------------------------------
# tuning cache
# ---------------------------------------------------------------------------


def shape_key(op: str, n: int, l: int, b: int, platform: str) -> str:
    """Cache key for one tuned point.  Shape-exact: a different batch or
    limb count is a different entry (no interpolation)."""
    return f"{op}|N{n}|L{l}|B{b}|{platform}"


@dataclasses.dataclass
class _CacheEntry:
    backend: str
    config: KernelConfig
    tuned_ms: float = float("nan")
    default_ms: float = float("nan")


_ENTRIES: dict[str, _CacheEntry] = {}
_GENERATION = 0          # bumped on every load/clear/put -> backend_token
_LOADED_PATH: str | None = None
_LOAD_ATTEMPTED = False


def cache_path() -> str | None:
    """The JSON tuning-cache path (REPRO_HE_TUNE_CACHE), None if unset."""
    return os.environ.get(CACHE_ENV) or None


def generation() -> int:
    """Monotonic cache state counter, folded into `ops.backend_token()`
    when any op is assigned `auto`: a (re)load or edit retraces every
    jitted graph that embedded a resolved config."""
    return _GENERATION


def clear_cache() -> None:
    """Drop every in-memory entry (resolution falls back to defaults)."""
    global _GENERATION, _LOADED_PATH, _LOAD_ATTEMPTED
    _ENTRIES.clear()
    _LOADED_PATH = None
    _LOAD_ATTEMPTED = True      # an explicit clear pins "empty", no reload
    _GENERATION += 1


def put(op: str, n: int, l: int, b: int, platform: str, backend: str,
        config: KernelConfig, tuned_ms: float = float("nan"),
        default_ms: float = float("nan")) -> None:
    """Insert/overwrite one resolved winner (sweep_op and tests)."""
    global _GENERATION
    _ENTRIES[shape_key(op, n, l, b, platform)] = _CacheEntry(
        backend=backend, config=config, tuned_ms=tuned_ms,
        default_ms=default_ms)
    _GENERATION += 1


def load_cache(path: str | None = None) -> int:
    """Load a JSON tuning cache, REPLACING the in-memory entries.

    Returns the number of entries accepted.  Stale entries — unknown op
    names, malformed configs, keys whose platform tag differs from the
    running platform — are skipped one by one, so a cache tuned on TPU
    degrades to defaults on CPU instead of mis-steering it; a missing or
    unreadable file loads as empty.  Always bumps `generation()`.
    """
    global _GENERATION, _LOADED_PATH, _LOAD_ATTEMPTED
    import jax

    platform = jax.default_backend()
    path = path if path is not None else cache_path()
    _ENTRIES.clear()
    _LOAD_ATTEMPTED = True
    _LOADED_PATH = path
    _GENERATION += 1
    if not path:
        return 0
    try:
        with open(path) as f:
            doc = json.load(f)
        raw = doc.get("entries", {})
    except FileNotFoundError:
        # a named-but-not-yet-written cache is the normal first-run state
        return 0
    except (OSError, json.JSONDecodeError, AttributeError) as e:
        # A cache that EXISTS but cannot be read (permissions, truncation,
        # corruption, non-dict JSON) silently disabling tuning is the bug
        # this guards: surface it once, visibly, and count it.
        from repro import obs
        obs.counter("tune_cache_load_errors_total").inc()
        warnings.warn(
            f"tuning cache {path!r} (from {CACHE_ENV}) could not be loaded"
            f" ({e!r}); autotuned configs are disabled and every `auto`"
            f" dispatch falls back to defaults — fix or delete the file",
            RuntimeWarning, stacklevel=2)
        return 0
    accepted = 0
    for key, e in raw.items():
        try:
            op, _, _, _, key_platform = key.split("|")
            if op not in DEFAULT_BLOCK or key_platform != platform:
                continue
            backend = e["backend"]
            if backend not in ("ref", "pallas", "pallas4"):
                continue
            _ENTRIES[key] = _CacheEntry(
                backend=backend,
                config=KernelConfig.from_json(e["config"]),
                tuned_ms=float(e.get("tuned_ms", float("nan"))),
                default_ms=float(e.get("default_ms", float("nan"))))
            accepted += 1
        except (KeyError, ValueError, TypeError):
            continue
    return accepted


def save_cache(path: str) -> None:
    """Persist the in-memory entries (plus tune-time provenance meta)."""
    import jax

    from repro.kernels import ops as _ops

    doc = {
        "version": CACHE_VERSION,
        "meta": {
            "platform": jax.default_backend(),
            "device_count": len(jax.devices()),
            "backend_token": str(_ops.backend_token()),
        },
        "entries": {
            key: {"backend": e.backend, "config": e.config.to_json(),
                  "tuned_ms": e.tuned_ms, "default_ms": e.default_ms}
            for key, e in sorted(_ENTRIES.items())
        },
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")


def _ensure_loaded() -> None:
    if not _LOAD_ATTEMPTED:
        load_cache()


def n_entries() -> int:
    _ensure_loaded()
    return len(_ENTRIES)


def loaded_path() -> str | None:
    _ensure_loaded()
    return _LOADED_PATH


def fallback_backend(interpret: bool) -> str:
    """Concrete backend for an `auto` dispatch with no cache entry: the
    jnp oracle where Pallas would run in interpret mode (CPU), the Pallas
    kernels where they compile natively."""
    return "ref" if interpret else "pallas"


def resolve(op: str, n: int, l: int, b: int,
            interpret: bool) -> tuple[str, KernelConfig]:
    """(backend, config) for one `auto` dispatch.  Cache hit -> the
    measured winner; miss -> defaults.  Never returns "auto"."""
    _ensure_loaded()
    import jax

    e = _ENTRIES.get(shape_key(op, n, l, b, jax.default_backend()))
    if e is not None:
        return e.backend, e.config
    return fallback_backend(interpret), default_config(op)


def provenance() -> dict:
    """Tuner state stamped into `obs.provenance()` / BENCH artifacts."""
    return {"generation": generation(), "cache_path": loaded_path(),
            "entries": n_entries()}


# ---------------------------------------------------------------------------
# candidate enumeration + roofline pruning
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Candidate:
    backend: str            # "ref" | "pallas" | "pallas4"
    config: KernelConfig

    @property
    def is_default(self) -> bool:
        return self.config.ntt4_split is None and self.config.radix == 2


def candidates(op: str, n: int, l: int, b: int,
               interpret: bool) -> list[Candidate]:
    """The full swept space for one point:

      * every op: the jnp-oracle `ref` (one candidate — block_b is
        meaningless there) and the `pallas` kernel at each
        BLOCK_CANDIDATES tile <= B;
      * NTT ops additionally: `pallas4` at every
        `params.ntt4_split_candidates(N)` x RADIX_CANDIDATES x block.

    The default config (DEFAULT_BLOCK, sqrt split, radix 2) on the
    platform fallback backend is always present, so a sweep can only ever
    match or beat it.
    """
    from repro.core.ckks import params as ckks_params

    blocks = [blk for blk in BLOCK_CANDIDATES if blk <= max(b, 1)]
    if not blocks:
        blocks = [1]
    out = [Candidate("ref", default_config(op))]
    for blk in blocks:
        out.append(Candidate("pallas", KernelConfig(block_b=blk)))
    if op in NTT_OPS:
        for n1, n2 in ckks_params.ntt4_split_candidates(n):
            for radix in RADIX_CANDIDATES:
                for blk in blocks:
                    out.append(Candidate("pallas4", KernelConfig(
                        block_b=blk, ntt4_split=(n1, n2), radix=radix)))
    fb = fallback_backend(interpret)
    dflt = Candidate(fb, default_config(op))
    if dflt not in out:
        out.insert(0, dflt)
    return out


def _model_time_s(op: str, n: int, l: int, b: int, cand: Candidate,
                  interpret: bool) -> float:
    """Roofline estimate for one candidate: HBM traffic / bandwidth plus
    per-grid-step launch overhead (DESIGN.md §12.3).

    Memory term: each kernel reads/writes its u32[B, L, N] operands once
    (the fused kernels' whole point), so traffic is a config-independent
    ~3 x B x L x N x 4 bytes; NTT stage count scales the in-VMEM work:
    log2 reshuffles for the flat kernel, (stages(n1)+stages(n2))/radix-
    scaled for the 4-step.  Launch term: grid steps x LAUNCH_OVERHEAD_S —
    what small block_b actually costs.  The model only needs to be
    *ordinally* right: anything >= PRUNE_RATIO x the best estimate is
    skipped unmeasured.
    """
    hbm_bw, _ = _roofline_constants()
    import math

    bytes_main = 3 * b * l * n * 4
    mem_s = bytes_main / hbm_bw
    if op in NTT_OPS:
        if cand.backend == "pallas4":
            n1, n2 = cand.config.ntt4_split or (0, 0)
            if not n1:
                from repro.core.ckks import params as ckks_params
                n1, n2 = ckks_params.ntt4_split(n)
            stages = math.log2(n1) + math.log2(n2)
            if cand.config.radix == 4:
                stages = (math.ceil(math.log2(n1) / 2)
                          + math.ceil(math.log2(n2) / 2))
            # one extra full-tensor pass for correction + transpose
            mem_s *= (1.0 + stages / 8.0 + 0.25)
        else:
            mem_s *= (1.0 + math.log2(n) / 8.0)
    if cand.backend == "ref":
        # whole-tensor jnp graph: no grid, one fused dispatch
        return mem_s + LAUNCH_OVERHEAD_S
    grid_steps = l * -(-b // cand.config.block_b)
    return mem_s + grid_steps * LAUNCH_OVERHEAD_S


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------


def _timeit(fn, *args, reps: int = 3) -> float:
    """Mean wall seconds after one warmup, blocked on every output leaf
    (the same discipline as benchmarks/run.py and obs.timed_kernel — async
    dispatch cannot fake a win)."""
    import jax

    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _make_inputs(op: str, ctx, b: int, seed: int = 0):
    """Deterministic op inputs at the sweep point's shapes."""
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ref

    rng = np.random.RandomState(seed)
    l = ctx.n_limbs

    def rand(shape):
        return jnp.asarray(ref.rand_limbed_np(rng, ctx, shape))

    w_row = jnp.asarray(
        rng.randint(1, np.asarray(ctx.tables.qs).min(),
                    size=(b, l)).astype(np.uint32))
    if op in ("ntt_fwd", "ntt_inv"):
        return (rand((b,)),)
    if op == "mod_lift":
        return (jnp.asarray(rng.randint(
            0, 1 << 32, size=(b, ctx.n_poly)).astype(np.uint32)),)
    if op == "mul_add":
        return (rand((b,)), rand((b,)), rand((b,)))
    if op == "weighted_sum":
        return (rand((4, b)), w_row[:4])
    if op == "weighted_accum":
        return (rand((b,)), rand((b,)), w_row[0])
    if op == "weighted_accum_chunks":
        return (rand((b,)), rand((b,)), w_row)
    raise ValueError(op)


def _candidate_fn(op: str, cand: Candidate, ctx, interpret: bool):
    """A jitted callable running `op` under one candidate's exact launch
    geometry, bypassing the registry (the sweep must not mutate global
    backend state)."""
    import jax

    from repro.kernels import ops as _ops

    tables = ctx.tables.take(ctx.n_limbs)
    if cand.backend == "pallas4" and op in NTT_OPS \
            and cand.config.ntt4_split is not None:
        from repro.core.ckks import params as ckks_params
        tables = ckks_params.retable_ntt4(tables, *cand.config.ntt4_split)

    def fn(*args):
        return _ops.run_config(op, cand.backend, cand.config, tables,
                               *args)

    return jax.jit(fn)


@dataclasses.dataclass
class SweepResult:
    op: str
    n: int
    l: int
    b: int
    platform: str
    winner: Candidate
    tuned_ms: float
    default_ms: float
    n_candidates: int
    n_pruned: int

    @property
    def speedup(self) -> float:
        return self.default_ms / self.tuned_ms if self.tuned_ms else 1.0

    def to_row(self) -> dict:
        return {"op": self.op, "n": self.n, "l": self.l, "b": self.b,
                "platform": self.platform,
                "backend": self.winner.backend,
                "config": self.winner.config.to_json(),
                "default_ms": self.default_ms, "tuned_ms": self.tuned_ms,
                "speedup": self.speedup,
                "candidates": self.n_candidates, "pruned": self.n_pruned}


def sweep_op(op: str, ctx, b: int, reps: int = 3,
             use_roofline: bool = True) -> SweepResult:
    """Measure every (unpruned) candidate for one point and record the
    winner in the in-memory cache.

    Winner selection includes the default config, so `tuned_ms <=
    default_ms` by construction — a tuned cache can only match or beat
    the hardcoded defaults it replaces.
    """
    import jax

    from repro import obs
    from repro.kernels import ops as _ops

    interpret = _ops._interpret()
    platform = jax.default_backend()
    n, l = ctx.n_poly, ctx.n_limbs
    args = _make_inputs(op, ctx, b)
    cands = candidates(op, n, l, b, interpret)
    est = {c: _model_time_s(op, n, l, b, c, interpret) for c in cands}
    floor = min(est.values())
    default = Candidate(fallback_backend(interpret), default_config(op))
    measured: dict[Candidate, float] = {}
    pruned = 0
    for cand in cands:
        if use_roofline and cand != default \
                and est[cand] > PRUNE_RATIO * floor:
            pruned += 1
            continue
        fn = _candidate_fn(op, cand, ctx, interpret)
        dt = _timeit(fn, *args, reps=reps)
        measured[cand] = dt
        obs.histogram("tune_candidate_seconds", op=op,
                      backend=cand.backend).observe(dt)
    default_s = measured[default]
    winner = min(measured, key=measured.get)
    tuned_s = measured[winner]
    put(op, n, l, b, platform, winner.backend, winner.config,
        tuned_ms=tuned_s * 1e3, default_ms=default_s * 1e3)
    obs.counter("tune_sweeps_total", op=op).inc()
    return SweepResult(op=op, n=n, l=l, b=b, platform=platform,
                       winner=winner, tuned_ms=tuned_s * 1e3,
                       default_ms=default_s * 1e3,
                       n_candidates=len(cands), n_pruned=pruned)
