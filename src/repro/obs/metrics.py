"""Process-wide metrics registry: counters, gauges, histograms with labels.

The registry is the single home for every numeric fact the system wants to
report — the one-off counters that used to live on `StreamIngest`
(`accum_launches`, `peak_chunk_buffers`) and in `wire/budget.py` now
resolve to registry instruments, read back through compatible properties.
Instruments are get-or-create keyed on (name, sorted label items), so two
call sites asking for the same series share one value.

Always on: recording is a dict lookup + integer add with no jax imports,
cheap enough to leave unconditional (the opt-in REPRO_OBS=1 gate only
covers the *expensive* telemetry — trace emission and kernel-launch
blocking, repro/obs/trace.py and repro/obs/hooks.py).

Export: `snapshot()` for structured consumers, `prometheus_text()` for a
Prometheus-exposition-style text dump (histograms rendered as summaries
with fixed quantiles).  DESIGN.md §11.
"""
from __future__ import annotations

import threading

# summary quantiles rendered by prometheus_text()
_QUANTILES = (0.5, 0.9, 0.99)
# raw-sample cap per histogram: percentile queries stay exact until a
# series sees this many observations, then new samples keep count/sum
# exact but stop extending the reservoir (documented overhead bound)
HIST_MAX_SAMPLES = 65536


class Counter:
    """Monotonically increasing integer/float series."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: tuple):
        self.name = name
        self.labels = labels
        self.value = 0

    def inc(self, n=1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (n={n})")
        self.value += n


class Gauge:
    """Point-in-time value; `set_max` supports peak/high-watermark use."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: tuple):
        self.name = name
        self.labels = labels
        self.value = 0

    def set(self, v) -> None:
        self.value = v

    def add(self, d) -> None:
        self.value += d

    def set_max(self, v) -> None:
        """Raise the gauge to v if v exceeds the current value (peaks)."""
        if v > self.value:
            self.value = v


class Histogram:
    """Distribution of observations with exact percentiles.

    Keeps the raw samples (capped at HIST_MAX_SAMPLES) so `percentile`
    answers from the data instead of fixed buckets — right for the
    per-op kernel timings this registry exists to make trustworthy.
    """

    __slots__ = ("name", "labels", "count", "sum", "_samples")

    def __init__(self, name: str, labels: tuple):
        self.name = name
        self.labels = labels
        self.count = 0
        self.sum = 0.0
        self._samples: list[float] = []

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        if len(self._samples) < HIST_MAX_SAMPLES:
            self._samples.append(v)

    def percentile(self, p: float) -> float:
        """Exact percentile (linear interpolation) over recorded samples.
        p in [0, 100].  Raises ValueError on an empty series."""
        if not self._samples:
            raise ValueError(f"histogram {self.name} has no observations")
        xs = sorted(self._samples)
        if len(xs) == 1:
            return xs[0]
        rank = (p / 100.0) * (len(xs) - 1)
        lo = int(rank)
        hi = min(lo + 1, len(xs) - 1)
        frac = rank - lo
        return xs[lo] * (1.0 - frac) + xs[hi] * frac

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


class MetricsRegistry:
    """Get-or-create home for every (name, labels) instrument."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[tuple, object] = {}

    def _get(self, cls, name: str, labels: dict):
        key = (name, tuple(sorted(labels.items())))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, key[1])
                self._metrics[key] = m
            elif type(m) is not cls:
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def get(self, name: str, **labels):
        """Existing instrument or None — read-only query, never creates."""
        return self._metrics.get((name, tuple(sorted(labels.items()))))

    def series(self, name: str) -> list:
        """Every instrument registered under `name`, across label sets."""
        return [m for (n, _), m in sorted(self._metrics.items())
                if n == name]

    def total(self, name: str):
        """Sum of values across every label set of a counter/gauge name."""
        return sum(m.value for m in self.series(name))

    def snapshot(self) -> dict:
        """{name: [{"labels": {...}, ...values...}]} for every instrument —
        the structured export (trace metadata events, BENCH provenance)."""
        out: dict[str, list] = {}
        for (name, labels), m in sorted(self._metrics.items()):
            row: dict = {"labels": dict(labels)}
            if isinstance(m, Histogram):
                row.update(count=m.count, sum=m.sum, mean=m.mean)
                if m.count:
                    row.update({f"p{int(q * 100)}": m.percentile(q * 100)
                                for q in _QUANTILES})
            else:
                row["value"] = m.value
            out.setdefault(name, []).append(row)
        return out

    def prometheus_text(self) -> str:
        """Prometheus-exposition-style text dump of every instrument."""
        lines = []
        seen_type: set[str] = set()
        for (name, labels), m in sorted(self._metrics.items()):
            kind = {"Counter": "counter", "Gauge": "gauge",
                    "Histogram": "summary"}[type(m).__name__]
            if name not in seen_type:
                lines.append(f"# TYPE {name} {kind}")
                seen_type.add(name)
            lab = _fmt_labels(dict(labels))
            if isinstance(m, Histogram):
                for q in _QUANTILES:
                    ql = _fmt_labels(dict(labels) | {"quantile": str(q)})
                    v = m.percentile(q * 100) if m.count else 0.0
                    lines.append(f"{name}{ql} {v:.9g}")
                lines.append(f"{name}_sum{lab} {m.sum:.9g}")
                lines.append(f"{name}_count{lab} {m.count}")
            else:
                lines.append(f"{name}{lab} {_fmt_val(m.value)}")
        return "\n".join(lines) + "\n"

    def reset(self) -> None:
        """Drop every instrument (tests only — production counters are
        append-only for the life of the process)."""
        with self._lock:
            self._metrics.clear()


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _fmt_val(v) -> str:
    return f"{v:.9g}" if isinstance(v, float) else str(v)


#: the process-wide registry every repro subsystem records into
REGISTRY = MetricsRegistry()
