"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend stub.
Source: hf:microsoft/Phi-3-vision-128k-instruct (hf tier).
32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064.  The CLIP tower is a
STUB: input_specs() provides precomputed patch embeddings
(n_patches=576, patch_dim=1024)."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32064, n_patches=576, patch_dim=1024,
    dtype="bfloat16", param_dtype="float32", remat=True,
)

SMOKE = ModelConfig(
    name="phi3v-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=257, n_patches=4, patch_dim=16, attn_chunk=16,
)
