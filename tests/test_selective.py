"""Selective-aggregation conformance: the full selective pipeline (mask ->
partition -> seeded wire frames -> streaming aggregation -> recover) is
bit-identical between the single-device engine and 1/2/4-device ShardedHe
meshes on every kernel backend, the plaintext partition rides the wire
unencrypted-but-quantized exactly as specced, and HE mask agreement
reproduces the clear-text mask for both `top_p` and the paper's `recipe`.

tests/conftest.py forces 4 simulated host devices, so every mesh case runs
under plain tier-1.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import packing, secure_agg, selection
from repro.core.ckks import cipher
from repro.core.ckks import params as ckks_params
from repro.core.ckks.sharded import ShardedHe
from repro.core.secure_agg import AggregatorConfig, SelectiveHEAggregator
from repro.kernels import ops
from repro.launch.mesh import make_he_mesh
from repro.wire import compress as wire_compress
from repro.wire import format as wf
from repro.wire import stream as ws

WEIGHTS = [0.25, 0.75]


def _ctx():
    return ckks_params.make_test_context(n_poly=64, n_limbs=2, delta_bits=20)


def _params(rng):
    """302 params over 4 leaves -> ragged chunking at slots=32."""
    return {
        "emb": rng.randn(12, 8).astype(np.float32),
        "w1": rng.randn(9, 11).astype(np.float32),
        "b1": rng.randn(37).astype(np.float32),
        "head": rng.randn(10, 7).astype(np.float32),
    }


def _engine(ctx, n_dev):
    if jax.device_count() < n_dev:
        pytest.skip(f"needs {n_dev} host devices, have "
                    f"{jax.device_count()} (set XLA_FLAGS="
                    "--xla_force_host_platform_device_count=4)")
    return ShardedHe(ctx, make_he_mesh(ctx.n_limbs, n_dev))


@pytest.fixture(params=["ref", "pallas", "pallas4"])
def backend(request):
    old = {op: ops.get_backend(op) for op in ops.OPS}
    ops.set_backend(request.param)
    yield request.param
    for op, name in old.items():
        ops.set_backend(name, op=op)


def _setup(ctx, p=0.3, strategy="top_p"):
    rng = np.random.RandomState(7)
    g0 = _params(rng)
    spec = packing.make_flat_spec(g0)
    sens = rng.rand(spec.total)
    mask = selection.build_mask(sens, strategy, p, offsets=spec.offsets,
                                sizes=spec.sizes)
    part = packing.make_partition(mask, ctx.slots)
    agg = SelectiveHEAggregator(ctx, spec, part,
                                AggregatorConfig(p_ratio=p, strategy=strategy))
    sk, pk = cipher.keygen(ctx, jax.random.PRNGKey(0))
    vecs = [rng.randn(spec.total).astype(np.float32) for _ in range(2)]
    return spec, part, agg, sk, pk, vecs


def _blobs(ctx, agg, sk, vecs, sharded=None, plain_codec="i8"):
    """Selective round, client half: seeded protect -> wire frames."""
    out = []
    for i, v in enumerate(vecs):
        a_seed = 7_000 + i
        tree = packing.unflatten_params(jnp.asarray(v), agg.spec)
        upd = agg.client_protect_seeded(
            tree, sk, jax.random.fold_in(jax.random.PRNGKey(3), i), a_seed,
            sharded=sharded)
        sct = wire_compress.seed_compress(upd.ct, a_seed)
        out.append(ws.pack_update_frames(upd, cid=i, n_samples=i + 1, rnd=0,
                                         seeded=sct, plain_codec=plain_codec))
    return out


def _aggregate_recover(ctx, agg, sk, blobs, sharded=None):
    ing = ws.StreamIngest(ctx, sharded=sharded)
    for b, w in zip(blobs, WEIGHTS):
        ing.ingest(b, w)
    glob = ing.finalize()
    return np.asarray(agg.client_recover(glob, sk))


# ---------------------------------------------------------------------------
# end-to-end bit parity: single-device vs sharded meshes, all backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_dev", [1, 2, 4])
def test_selective_round_bitexact_across_meshes(n_dev, backend):
    ctx = _ctx()
    _, part, agg, sk, _, vecs = _setup(ctx)
    assert 1 < part.n_enc < part.n_total          # genuinely selective
    assert part.n_enc % ctx.slots != 0            # ragged last chunk

    blobs_ref = _blobs(ctx, agg, sk, vecs, sharded=None)
    rec_ref = _aggregate_recover(ctx, agg, sk, blobs_ref, sharded=None)

    eng = _engine(ctx, n_dev)
    blobs_sh = _blobs(ctx, agg, sk, vecs, sharded=eng)
    # the sharded encrypt path emits byte-identical wire frames ...
    assert blobs_sh == blobs_ref
    # ... and the sharded streaming aggregation recovers the bit-identical
    # merged model vector
    rec_sh = _aggregate_recover(ctx, agg, sk, blobs_sh, sharded=eng)
    np.testing.assert_array_equal(rec_sh, rec_ref)


def test_selective_round_recovers_weighted_average(backend):
    ctx = _ctx()
    _, _, agg, sk, _, vecs = _setup(ctx)
    rec = _aggregate_recover(ctx, agg, sk, _blobs(ctx, agg, sk, vecs))
    expect = sum(w * v for w, v in zip(WEIGHTS, vecs))
    # exact to CKKS noise on the encrypted partition, to the i8 step on the
    # plain one
    tol = 0.02 * float(np.max(np.abs(expect))) + 1e-3
    assert float(np.max(np.abs(rec - expect))) < tol


# ---------------------------------------------------------------------------
# plain partition on the wire: unencrypted but quantized as specced
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("codec,dtype", [("i8", np.int8), ("f16", np.float16),
                                         ("f32", np.float32)])
def test_plain_partition_bytes_quantized_not_encrypted(codec, dtype):
    ctx = _ctx()
    _, part, agg, sk, _, vecs = _setup(ctx)
    blob = _blobs(ctx, agg, sk, vecs, plain_codec=codec)[0]

    segs = [payload for ftype, _, payload in wf.iter_frames(blob)
            if ftype == wf.T_PLAIN_SEGMENT]
    assert len(segs) == 1
    arr, got_codec, qscale = wf._parse_plain_segment(segs[0])
    assert got_codec == codec and arr.dtype == dtype

    # the segment is exactly quantize_plain of the plain partition — no key
    # material involved; anyone on the wire reads it back
    plain_expect = np.asarray(vecs[0])[part.plain_idx]
    q_expect, s_expect = wire_compress.quantize_plain(plain_expect, codec)
    assert qscale == s_expect
    np.testing.assert_array_equal(np.asarray(arr), q_expect)
    deq = wire_compress.dequantize_plain(arr, codec, qscale)
    step = (np.max(np.abs(plain_expect)) / 127.0) if codec == "i8" else 1e-2
    np.testing.assert_allclose(deq, plain_expect, atol=step + 1e-7)


# ---------------------------------------------------------------------------
# HE mask agreement reproduces the clear mask (top_p AND recipe)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", ["top_p", "recipe", "per_layer"])
def test_agree_mask_matches_clear_selection(strategy):
    ctx = _ctx()
    rng = np.random.RandomState(11)
    spec = packing.make_flat_spec(_params(rng))
    sk, pk = cipher.keygen(ctx, jax.random.PRNGKey(0))
    # well-separated sensitivities (integer gaps >> CKKS noise) so the HE
    # aggregate cannot flip the selection order
    base = rng.permutation(spec.total).astype(np.float64)
    sens = [base + 0.125, base - 0.125]            # clients agree on average
    m_he = secure_agg.agree_mask(
        ctx, pk, sk, sens, [0.5, 0.5], 0.3, jax.random.PRNGKey(5),
        strategy=strategy, offsets=spec.offsets, sizes=spec.sizes)
    m_clear = selection.build_mask(base, strategy, 0.3, offsets=spec.offsets,
                                   sizes=spec.sizes)
    np.testing.assert_array_equal(m_he, m_clear)
    if strategy == "recipe":
        # paper's recipe: first and last leaves always fully covered
        assert m_he[spec.offsets[0]: spec.offsets[0] + spec.sizes[0]].all()
        assert m_he[spec.offsets[-1]:
                    spec.offsets[-1] + spec.sizes[-1]].all()


def test_orchestrator_routes_recipe_strategy():
    """FLTask.agree_encryption_mask with strategy='recipe' builds a
    partition that fully covers the first and last model leaves."""
    from test_fl import tiny_task

    task = tiny_task(n_clients=2)
    task.agg_cfg = AggregatorConfig(p_ratio=0.1, strategy="recipe")
    agg = task.agree_encryption_mask()
    spec = agg.spec
    mask = np.zeros(spec.total, dtype=bool)
    mask[agg.part.enc_idx] = True
    assert mask[spec.offsets[0]: spec.offsets[0] + spec.sizes[0]].all()
    assert mask[spec.offsets[-1]: spec.offsets[-1] + spec.sizes[-1]].all()
    assert 0 < agg.part.n_enc < spec.total
