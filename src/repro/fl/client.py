"""FL client: local training + selective encryption of the outgoing model.

Supports FedAvg (plain local SGD/AdamW) and FedProx (proximal term against
the incoming global model).  Local training is a jitted step closed over
the model's loss_fn.
"""
from __future__ import annotations

import dataclasses
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import packing, sensitivity
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.wire import budget as wire_budget
from repro.wire import compress as wire_compress
from repro.wire import format as wire_format
from repro.wire import stream as wire_stream


UPLINK_MODES = ("auto", "full", "seeded", "transcipher")
UPLINK_MODE_ENV = "REPRO_UPLINK_MODE"


def uplink_a_seed(rnd: int, cid: int) -> int:
    """The per-(client, round) public seed every uplink path keys its a
    stream (and, via transcipher.provision's escrow offset, the escrow
    frame's a stream) from.  One shared definition so the client and the
    server-side provisioner (serve/service.py) agree without negotiation.
    PUBLIC by design — the transcipher keystream seed is deliberately NOT
    derived from it (transcipher.provision draws it from secret
    material)."""
    return rnd * 1_000_003 + cid


@dataclasses.dataclass(frozen=True)
class ClientConfig:
    local_steps: int = 4
    lr: float = 1e-3
    prox_mu: float = 0.0           # FedProx coefficient (0 = FedAvg)
    optimizer: str = "adamw"       # adamw | sgd
    sensitivity_probes: int = 4


class FLClient:
    def __init__(self, cid: int, model: Model, stream,
                 cfg: ClientConfig = ClientConfig(),
                 ledger: wire_budget.BandwidthLedger | None = None):
        self.cid = cid
        self.model = model
        self.stream = stream
        self.cfg = cfg
        self.ledger = ledger           # shared wire-bandwidth ledger (opt.)
        self._step = jax.jit(self._make_step())
        self.n_samples = 0

    # -- local training -------------------------------------------------------

    def _make_step(self):
        loss_fn = self.model.loss_fn
        mu = self.cfg.prox_mu
        opt_cfg = AdamWConfig(lr=self.cfg.lr, weight_decay=0.0)

        def objective(params, batch, global_params):
            loss = loss_fn(params, batch)
            if mu > 0.0:
                prox = sum(jnp.sum((p.astype(jnp.float32)
                                    - g.astype(jnp.float32)) ** 2)
                           for p, g in zip(jax.tree_util.tree_leaves(params),
                                           jax.tree_util.tree_leaves(global_params)))
                loss = loss + 0.5 * mu * prox
            return loss

        if self.cfg.optimizer == "sgd":
            def step(params, opt_state, batch, global_params):
                loss, grads = jax.value_and_grad(objective)(
                    params, batch, global_params)
                params = jax.tree_util.tree_map(
                    lambda p, g: p - self.cfg.lr * g.astype(p.dtype),
                    params, grads)
                return params, opt_state, loss
            return step

        def step(params, opt_state, batch, global_params):
            loss, grads = jax.value_and_grad(objective)(
                params, batch, global_params)
            params, opt_state, _ = adamw_update(grads, opt_state, params,
                                                opt_cfg)
            return params, opt_state, loss
        return step

    def local_train(self, global_params) -> tuple[dict, float]:
        """E local steps from the incoming global model. Returns
        (local params, mean loss)."""
        with obs.span("local_train", cid=self.cid,
                      steps=self.cfg.local_steps) as sp:
            params = global_params
            opt_state = adamw_init(params)
            losses = []
            for _ in range(self.cfg.local_steps):
                batch = {k: jnp.asarray(v) for k, v in
                         self.stream.next_batch().items()}
                params, opt_state, loss = self._step(params, opt_state, batch,
                                                     global_params)
                losses.append(float(loss))
                self.n_samples += int(batch["tokens"].shape[0]) \
                    if "tokens" in batch \
                    else int(next(iter(batch.values())).shape[0])
            params = obs.maybe_block(params)
            sp.set(loss=float(np.mean(losses)))
        return params, float(np.mean(losses))

    # -- wire: serialized uplink/downlink (repro.wire) -------------------------

    def protect_and_pack(self, aggregator, local_params, *, rnd: int,
                         policy: wire_compress.WirePolicy,
                         pk: dict | None = None, sk: dict | None = None,
                         key=None, sharded=None, mode: str | None = None,
                         derive: int | None = None,
                         transcipher_materials=None) -> bytes:
        """Protect the local update and serialize it for the uplink.

        `mode` picks the uplink path (default: the REPRO_UPLINK_MODE env
        var, else "auto"):

          * "auto"        — seeded when policy.seed_ciphertexts and sk is
                            available, else full public-key ciphertexts.
          * "full"        — public-key ciphertexts (requires pk).
          * "seeded"      — secret-key seeded path; the wire carries
                            (seed, c0), roughly half the ciphertext bytes.
                            `derive` picks the per-chunk derivation id the
                            frames advertise (DESIGN.md §9.2).
          * "transcipher" — thin-client hybrid path (DESIGN.md §15): the
                            wire carries keystream-masked coefficients (no
                            client NTT, 1/L of the seeded ciphertext
                            bytes) plus the escrow seed ciphertext from
                            the pre-provisioned `transcipher_materials`
                            (a transcipher.ClientMaterials for
                            (cid, rnd); its a_seed must be
                            uplink_a_seed(rnd, cid)).

        With `sharded` (a core.ckks.sharded.ShardedHe), the weights ->
        ciphertext graph runs as one sharded dispatch over its mesh and —
        because the per-chunk key derivation is shard-invariant (DESIGN.md
        §9) — the emitted frames are byte-identical to the single-device
        client's.  Bytes are accounted at the receiving end: the server
        ledgers this uplink blob when it ingests it
        (FLServer.aggregate_wire); this client ledgers the downlink it
        receives (receive_global).
        """
        mode = mode if mode is not None \
            else os.environ.get(UPLINK_MODE_ENV, "auto")
        if mode not in UPLINK_MODES:
            raise ValueError(f"unknown uplink mode {mode!r} "
                             f"(from {UPLINK_MODE_ENV}?); "
                             f"expected one of {UPLINK_MODES}")
        if mode == "auto":
            mode = "seeded" if policy.seed_ciphertexts and sk is not None \
                else "full"
        key = key if key is not None else jax.random.PRNGKey(
            rnd * 100_003 + self.cid)
        a_seed = uplink_a_seed(rnd, self.cid)
        with obs.span("encrypt", cid=self.cid, round=rnd, mode=mode,
                      seeded=mode == "seeded") as sp:
            if mode == "transcipher":
                cm = transcipher_materials
                if cm is None:
                    raise ValueError(
                        "mode='transcipher' needs transcipher_materials (a "
                        "core.ckks.transcipher.ClientMaterials provisioned "
                        "for this (cid, round) — DESIGN.md §15)")
                if int(cm.a_seed) != a_seed:
                    raise ValueError(
                        f"transcipher materials a_seed {cm.a_seed} != "
                        f"uplink_a_seed({rnd}, {self.cid}) = {a_seed}; "
                        f"provision per (client, round)")
                masked, plain = aggregator.client_protect_transcipher(
                    local_params, cm, key)
                mc = wire_compress.MaskedChunk(
                    masked=masked, a_seed=cm.a_seed, scale=cm.scale,
                    chunk_offset=cm.chunk_offset, derive=cm.derive)
                blob = wire_stream.pack_masked_update_frames(
                    mc, wire_compress.seed_compress(cm.seed_ct,
                                                    cm.escrow_a_seed,
                                                    cm.derive),
                    plain, cid=self.cid, n_samples=max(1, self.n_samples),
                    rnd=rnd, plain_codec=policy.plain_codec)
                sp.set(nbytes=len(blob))
                return blob
            seeded = None
            if mode == "seeded":
                if sk is None:
                    raise ValueError("mode='seeded' needs sk")
                drv = derive if derive is not None \
                    else wire_compress.DERIVE_FOLD_CHUNK
                upd = aggregator.client_protect_seeded(local_params, sk, key,
                                                       a_seed,
                                                       sharded=sharded,
                                                       derive=drv)
                seeded = wire_compress.seed_compress(upd.ct, a_seed,
                                                     derive=drv)
            else:
                upd = aggregator.client_protect(local_params, pk, key,
                                                sharded=sharded)
            blob = wire_stream.pack_update_frames(
                upd, cid=self.cid, n_samples=max(1, self.n_samples), rnd=rnd,
                seeded=seeded, plain_codec=policy.plain_codec)
            sp.set(nbytes=len(blob))
        return blob

    def receive_global(self, blob: bytes, ctx, *, rnd: int):
        """Deserialize the broadcast global update, recording downlink
        bytes against this client."""
        with obs.span("recv_global", cid=self.cid, round=rnd,
                      nbytes=len(blob)):
            if self.ledger is not None:
                self.ledger.record_blob(blob, rnd=rnd, cid=self.cid,
                                        direction=wire_budget.DOWNLINK)
            upd, _ = wire_format.deserialize(blob, ctx)
        return upd

    # -- privacy sensitivity (paper §2.4 Step 1) ------------------------------

    def sensitivity_map(self, params, key=None) -> np.ndarray:
        """Flat |d(grad)/dy| estimate on one local batch (soft labels)."""
        key = key if key is not None else jax.random.PRNGKey(self.cid)
        batch = {k: jnp.asarray(v) for k, v in self.stream.next_batch().items()}
        vocab = self.model.cfg.vocab

        label_key = "labels" if "labels" in batch else "targets"
        y_soft = jax.nn.one_hot(batch[label_key], vocab, dtype=jnp.float32)
        feats = {k: v for k, v in batch.items() if k != label_key}

        from repro.models import mamba2, transformer, zamba2
        cfg = self.model.cfg
        ax = self.model.ax
        fwd = {"dense": transformer, "moe": transformer, "vlm": transformer,
               "encoder": transformer, "ssm": mamba2,
               "hybrid": zamba2}[cfg.family].forward_logits

        def loss_of_y(p, feats_, y):
            logits, _ = fwd(p, dict(feats_), cfg, ax)
            logits = logits[..., :vocab]
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            return -jnp.mean(jnp.sum(y * logp, axis=-1))

        smap = sensitivity.sensitivity_jvp(
            loss_of_y, params, feats, y_soft, key,
            n_probes=self.cfg.sensitivity_probes)
        vec, _ = packing.flatten_params(smap)
        return np.asarray(vec)
