"""Pallas TPU kernel: negacyclic NTT / iNTT over RNS limbs.

Target: TPU VPU (u32 lanes). Grid tiles the polynomial-batch axis; each kernel
invocation holds a (block_b, N) tile plus the N-entry twiddle table in VMEM
(block_b=8, N=8192 -> 288 KiB of VMEM, well under budget) and runs all
log2(N) butterfly stages in-register.  The DIF/DIT pairing keeps both
directions permutation-free (bit-reversed NTT domain).

Stages are unrolled in Python: every reshape has a static shape. On real TPU
the final stages (t < 128 lanes) relayout across sublanes; a 4-step
transpose-based NTT is the known fix and is listed in EXPERIMENTS.md §Perf.

Validated in interpret mode against repro/kernels/ref.py with exact integer
equality (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import ref as _ref


def _ntt_fwd_body(x_ref, psi_ref, o_ref, *, q: int, qinv_neg: int, n: int):
    x = x_ref[...]
    psi = psi_ref[...]
    m, t = 1, n
    while m < n:
        t //= 2
        xs = x.reshape((-1, m, 2, t))
        u = xs[:, :, 0, :]
        s = jax.lax.dynamic_slice_in_dim(psi, m, m)[None, :, None]
        v = _ref.mont_mul(xs[:, :, 1, :], jnp.broadcast_to(s, u.shape), q, qinv_neg)
        x = jnp.stack(
            [_ref.mod_add(u, v, q), _ref.mod_sub(u, v, q)], axis=2
        ).reshape((-1, n))
        m *= 2
    o_ref[...] = x


def _ntt_inv_body(x_ref, psi_inv_ref, o_ref, *, q, qinv_neg, n_inv_mont, n):
    x = x_ref[...]
    psi_inv = psi_inv_ref[...]
    t, m = 1, n
    while m > 1:
        h = m // 2
        xs = x.reshape((-1, h, 2, t))
        u = xs[:, :, 0, :]
        v = xs[:, :, 1, :]
        s = jax.lax.dynamic_slice_in_dim(psi_inv, h, h)[None, :, None]
        lo = _ref.mod_add(u, v, q)
        hi = _ref.mont_mul(_ref.mod_sub(u, v, q), jnp.broadcast_to(s, u.shape), q, qinv_neg)
        x = jnp.stack([lo, hi], axis=2).reshape((-1, n))
        t *= 2
        m = h
    x = _ref.mont_mul(x, jnp.full_like(x, np.uint32(n_inv_mont)), q, qinv_neg)
    o_ref[...] = x


@functools.lru_cache(maxsize=128)
def _build(direction: str, n: int, q: int, qinv_neg: int, n_inv_mont: int,
           block_b: int, interpret: bool):
    if direction == "fwd":
        body = functools.partial(_ntt_fwd_body, q=q, qinv_neg=qinv_neg, n=n)
    else:
        body = functools.partial(
            _ntt_inv_body, q=q, qinv_neg=qinv_neg, n_inv_mont=n_inv_mont, n=n
        )

    def call(x, twiddles):
        b = x.shape[0]
        grid = (pl.cdiv(b, block_b),)
        return pl.pallas_call(
            body,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_b, n), lambda i: (i, 0)),
                pl.BlockSpec((n,), lambda i: (0,)),
            ],
            out_specs=pl.BlockSpec((block_b, n), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((b, n), jnp.uint32),
            interpret=interpret,
        )(x, twiddles)

    return call


def ntt_fwd(x, psi_rev_mont, q: int, qinv_neg: int, *, block_b: int = 8,
            interpret: bool = True):
    """x: u32[B, N] natural -> bit-reversed NTT domain."""
    b = x.shape[0]
    call = _build("fwd", x.shape[-1], int(q), int(qinv_neg), 0,
                  min(block_b, b), interpret)
    return call(x, psi_rev_mont)


def ntt_inv(x, psi_inv_rev_mont, n_inv_mont, q: int, qinv_neg: int, *,
            block_b: int = 8, interpret: bool = True):
    """x: u32[B, N] bit-reversed NTT domain -> natural order."""
    b = x.shape[0]
    call = _build("inv", x.shape[-1], int(q), int(qinv_neg), int(n_inv_mont),
                  min(block_b, b), interpret)
    return call(x, psi_inv_rev_mont)
