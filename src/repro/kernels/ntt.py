"""Pallas TPU kernel: negacyclic NTT / iNTT, limb-fused over all RNS limbs.

Target: TPU VPU (u32 lanes). The grid is (L, ceil(B / block_b)): the RNS limb
is a *grid coordinate*, not a Python loop, so one `pallas_call` covers the
whole u32[B, L, N] tensor and kernel count no longer scales with limb depth.
Each invocation holds a (block_b, N) tile of one limb plus that limb's
N-entry twiddle row and scalar constants (q, -q^{-1}, N^{-1}R) in VMEM
(block_b=8, N=8192 -> 288 KiB of VMEM, well under budget) and runs all
log2(N) butterfly stages in-register.  The DIF/DIT pairing keeps both
directions permutation-free (bit-reversed NTT domain).

Constants arrive as stacked u32[L] / u32[L, N] tables (params.LimbTables);
the BlockSpec index map selects the limb's row, so the kernel body is
identical for every limb.  This is exactly what lets the sharded engine
(core/ckks/sharded.py, DESIGN.md §8) turn the limb grid axis into the
`model` MESH axis: inside `shard_map` each shard passes its local table
slice and launches this same kernel over its local limbs — the NTT runs
within one limb's N coefficients, so limb sharding needs no collectives.

Stages are unrolled in Python: every reshape has a static shape. On real TPU
the flat kernel's final stages (t < 128 lanes) relayout across sublanes; the
4-step transpose NTT below (`ntt4_fwd_fused` / `ntt4_inv_fused`, backend
name "pallas4") is the fix: it decomposes the length-N transform into
n1 x n2 sub-NTTs (64 x 128 for N=8192) so every butterfly stage pairs
whole lane-contiguous rows, with one transpose between the two sub-NTT
phases instead of log2(N) sublane shuffles.  DESIGN.md §10 documents the
decomposition, the table layout (params.ntt4_* on LimbTables), and when
each NTT implementation wins.

Validated in interpret mode against repro/kernels/ref.py with exact integer
equality (tests/test_kernels.py, tests/test_fused_engine.py,
tests/test_ntt4.py, tests/test_gold.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref as _ref
from repro.kernels import tune as _tune


def _ntt_fwd_body(x_ref, psi_ref, q_ref, qinv_ref, o_ref, *, n: int):
    x = x_ref[:, 0, :]
    psi = psi_ref[0]
    q = q_ref[0]
    qinv_neg = qinv_ref[0]
    m, t = 1, n
    while m < n:
        t //= 2
        xs = x.reshape((-1, m, 2, t))
        u = xs[:, :, 0, :]
        s = psi[m:2 * m][None, :, None]
        v = _ref.mont_mul(xs[:, :, 1, :], jnp.broadcast_to(s, u.shape), q,
                          qinv_neg)
        x = jnp.stack(
            [_ref.mod_add(u, v, q), _ref.mod_sub(u, v, q)], axis=2
        ).reshape((-1, n))
        m *= 2
    o_ref[:, 0, :] = x


def _ntt_inv_body(x_ref, psi_inv_ref, q_ref, qinv_ref, ninv_ref, o_ref, *,
                  n: int):
    x = x_ref[:, 0, :]
    psi_inv = psi_inv_ref[0]
    q = q_ref[0]
    qinv_neg = qinv_ref[0]
    t, m = 1, n
    while m > 1:
        h = m // 2
        xs = x.reshape((-1, h, 2, t))
        u = xs[:, :, 0, :]
        v = xs[:, :, 1, :]
        s = psi_inv[h:2 * h][None, :, None]
        lo = _ref.mod_add(u, v, q)
        hi = _ref.mont_mul(_ref.mod_sub(u, v, q),
                           jnp.broadcast_to(s, u.shape), q, qinv_neg)
        x = jnp.stack([lo, hi], axis=2).reshape((-1, n))
        t *= 2
        m = h
    x = _ref.mont_mul(x, jnp.broadcast_to(ninv_ref[0], x.shape), q, qinv_neg)
    o_ref[:, 0, :] = x


# ---------------------------------------------------------------------------
# 4-step transpose NTT (backend "pallas4", DESIGN.md §10)
# ---------------------------------------------------------------------------
#
# N = n1 * n2 (params.ntt4_split).  Writing j = j2 + n2*j1 and
# k = k1 + n1*k2, the negacyclic NTT X[k] = sum_j x[j] psi^(j*(2k+1))
# factors into
#
#   1. length-n1 negacyclic LN NTT over j1, root mu = psi^n2 — butterflies
#      pair whole rows of the [n1, n2] matrix, the n2 columns ride along as
#      the vectorized lane axis;
#   2. elementwise correction by psi^(j2*(2*k1+1-n1)) (the pre-twist, the
#      omega^(j2*k1) cross term, and the chi^(-j2) un-twist of step 4,
#      folded into ONE precomputed Montgomery table);
#   3. transpose [n1, n2] -> [n2, n1] — the single data relayout that
#      replaces the flat kernel's per-stage sublane shuffles;
#   4. length-n2 negacyclic LN NTT over j2, root chi = psi^n1.
#
# Both sub-NTTs keep the LN bit-reversed convention, and
# bitrev(k1 + n1*k2, logN) = bitrev(k1)*n2 + bitrev(k2), so transposing the
# [bitrev(k2)][bitrev(k1)] result back and flattening lands every output in
# exactly the flat kernel's bit-reversed slot: all three backends are
# bit-identical (tests/test_ntt4.py, tests/test_gold.py).


def _ln_fwd_axis1(x, psi, q, qinv_neg, radix: int = 2):
    """LN forward butterflies along axis 1 of x[b, len, spec]; psi: [len].

    Identical recurrence to _ntt_fwd_body, but the transform axis is a
    middle axis: the trailing spectator axis stays lane-contiguous through
    every stage.

    radix=4 fuses each PAIR of consecutive radix-2 stages into one pass
    (a trailing radix-2 stage remains when log2(len) is odd), halving the
    reshape/stack round trips for the short sub-transforms.  The fused
    pass performs the exact same modular multiplies/adds on the exact
    same elements as the two stages it replaces, so the output is
    bit-identical — radix is launch geometry, not arithmetic
    (DESIGN.md §12)."""
    b, ln, spec = x.shape
    m, t = 1, ln
    while m < ln:
        if radix == 4 and m * 4 <= ln:
            t //= 4
            xs = x.reshape((b, m, 2, 2, t, spec))
            s1 = psi[m:2 * m][None, :, None, None, None]
            u = xs[:, :, 0]                     # [b, m, 2(c), t, spec]
            v = _ref.mont_mul(xs[:, :, 1], jnp.broadcast_to(s1, u.shape),
                              q, qinv_neg)
            y0 = _ref.mod_add(u, v, q)          # stage-1 outputs, p = 0/1
            y1 = _ref.mod_sub(u, v, q)
            s20 = psi[2 * m:4 * m:2][None, :, None, None]
            s21 = psi[2 * m + 1:4 * m:2][None, :, None, None]
            v0 = _ref.mont_mul(y0[:, :, 1],
                               jnp.broadcast_to(s20, y0[:, :, 1].shape),
                               q, qinv_neg)
            v1 = _ref.mont_mul(y1[:, :, 1],
                               jnp.broadcast_to(s21, y1[:, :, 1].shape),
                               q, qinv_neg)
            x = jnp.stack([_ref.mod_add(y0[:, :, 0], v0, q),
                           _ref.mod_sub(y0[:, :, 0], v0, q),
                           _ref.mod_add(y1[:, :, 0], v1, q),
                           _ref.mod_sub(y1[:, :, 0], v1, q)],
                          axis=2).reshape((b, ln, spec))
            m *= 4
            continue
        t //= 2
        xs = x.reshape((b, m, 2, t, spec))
        u = xs[:, :, 0]
        s = psi[m:2 * m][None, :, None, None]
        v = _ref.mont_mul(xs[:, :, 1], jnp.broadcast_to(s, u.shape), q,
                          qinv_neg)
        x = jnp.stack([_ref.mod_add(u, v, q), _ref.mod_sub(u, v, q)],
                      axis=2).reshape((b, ln, spec))
        m *= 2
    return x


def _ln_inv_axis1(x, psi_inv, q, qinv_neg, radix: int = 2):
    """GS inverse butterflies along axis 1 (no final 1/len scaling — the
    caller applies one combined N^{-1} multiply after both phases).

    radix=4 fuses stage pairs like _ln_fwd_axis1, same bit-identity
    argument."""
    b, ln, spec = x.shape
    t, m = 1, ln
    while m > 1:
        if radix == 4 and m % 4 == 0:
            h2 = m // 4
            xs = x.reshape((b, h2, 2, 2, t, spec))   # [g2, a, dA, i]
            u = xs[:, :, :, 0]                       # [b, h2, 2(a), t, spec]
            v = xs[:, :, :, 1]
            s1 = psi_inv[m // 2:m].reshape((h2, 2))[None, :, :, None, None]
            lo = _ref.mod_add(u, v, q)               # stage-A outputs
            hi = _ref.mont_mul(_ref.mod_sub(u, v, q),
                               jnp.broadcast_to(s1, u.shape), q, qinv_neg)
            s2 = psi_inv[h2:2 * h2][None, :, None, None]
            d1_lo = _ref.mod_sub(lo[:, :, 0], lo[:, :, 1], q)
            d1_hi = _ref.mod_sub(hi[:, :, 0], hi[:, :, 1], q)
            x = jnp.stack(
                [_ref.mod_add(lo[:, :, 0], lo[:, :, 1], q),
                 _ref.mod_add(hi[:, :, 0], hi[:, :, 1], q),
                 _ref.mont_mul(d1_lo, jnp.broadcast_to(s2, d1_lo.shape),
                               q, qinv_neg),
                 _ref.mont_mul(d1_hi, jnp.broadcast_to(s2, d1_hi.shape),
                               q, qinv_neg)],
                axis=2).reshape((b, ln, spec))
            t *= 4
            m = h2
            continue
        h = m // 2
        xs = x.reshape((b, h, 2, t, spec))
        u = xs[:, :, 0]
        v = xs[:, :, 1]
        s = psi_inv[h:2 * h][None, :, None, None]
        lo = _ref.mod_add(u, v, q)
        hi = _ref.mont_mul(_ref.mod_sub(u, v, q),
                           jnp.broadcast_to(s, u.shape), q, qinv_neg)
        x = jnp.stack([lo, hi], axis=2).reshape((b, ln, spec))
        t *= 2
        m = h
    return x


def _ntt4_fwd_body(x_ref, psi1_ref, psi2_ref, corr_ref, q_ref, qinv_ref,
                   o_ref, *, n: int, n1: int, n2: int, radix: int = 2):
    x = x_ref[:, 0, :]
    b = x.shape[0]
    q = q_ref[0]
    qi = qinv_ref[0]
    x = x.reshape((b, n1, n2))                       # [j1][j2]
    x = _ln_fwd_axis1(x, psi1_ref[0], q, qi, radix)  # [br k1][j2]
    corr = corr_ref[0].reshape((n1, n2))
    x = _ref.mont_mul(x, jnp.broadcast_to(corr[None], x.shape), q, qi)
    x = jnp.swapaxes(x, 1, 2)                        # [j2][br k1]
    x = _ln_fwd_axis1(x, psi2_ref[0], q, qi, radix)  # [br k2][br k1]
    o_ref[:, 0, :] = jnp.swapaxes(x, 1, 2).reshape((b, n))


def _ntt4_inv_body(x_ref, psi1_inv_ref, psi2_inv_ref, corr_inv_ref, q_ref,
                   qinv_ref, ninv_ref, o_ref, *, n: int, n1: int, n2: int,
                   radix: int = 2):
    x = x_ref[:, 0, :]
    b = x.shape[0]
    q = q_ref[0]
    qi = qinv_ref[0]
    x = x.reshape((b, n1, n2))                          # [br k1][br k2]
    x = jnp.swapaxes(x, 1, 2)                           # [br k2][br k1]
    x = _ln_inv_axis1(x, psi2_inv_ref[0], q, qi, radix)  # [j2][br k1]
    x = jnp.swapaxes(x, 1, 2)                           # [br k1][j2]
    corr_inv = corr_inv_ref[0].reshape((n1, n2))
    x = _ref.mont_mul(x, jnp.broadcast_to(corr_inv[None], x.shape), q, qi)
    x = _ln_inv_axis1(x, psi1_inv_ref[0], q, qi, radix)  # [j1][j2]
    x = x.reshape((b, n))
    x = _ref.mont_mul(x, jnp.broadcast_to(ninv_ref[0], x.shape), q, qi)
    o_ref[:, 0, :] = x


@functools.lru_cache(maxsize=128)
def _build(direction: str, l: int, n: int, block_b: int, interpret: bool):
    tile = pl.BlockSpec((block_b, 1, n), lambda li, bi: (bi, li, 0))
    row = pl.BlockSpec((1, n), lambda li, bi: (li, 0))
    scalar = pl.BlockSpec((1,), lambda li, bi: (li,))
    if direction == "fwd":
        body = functools.partial(_ntt_fwd_body, n=n)
        in_specs = [tile, row, scalar, scalar]
    else:
        body = functools.partial(_ntt_inv_body, n=n)
        in_specs = [tile, row, scalar, scalar, scalar]

    def call(x, *tables):
        b = x.shape[0]
        return pl.pallas_call(
            body,
            grid=(l, pl.cdiv(b, block_b)),
            in_specs=in_specs,
            out_specs=tile,
            out_shape=jax.ShapeDtypeStruct((b, l, n), jnp.uint32),
            interpret=interpret,
        )(x, *tables)

    return call


def _flatten(x):
    l, n = x.shape[-2], x.shape[-1]
    return x.reshape((-1, l, n)), x.shape[:-2]


def ntt_fwd_fused(x, psi_rev_mont, qs, qinv_negs, *, block_b: int | None = None,
                  interpret: bool = True):
    """x: u32[..., L, N] natural -> bit-reversed NTT domain, all limbs in one
    pallas_call.  psi_rev_mont: u32[L, N]; qs, qinv_negs: u32[L].

    block_b=None takes the shared default from tune.DEFAULT_BLOCK — the
    registry (kernels/ops.py) threads tuned values here instead."""
    if block_b is None:
        block_b = _tune.default_block("ntt_fwd")
    x2, batch = _flatten(x)
    b, l, n = x2.shape
    call = _build("fwd", l, n, min(block_b, b), interpret)
    return call(x2, psi_rev_mont, qs, qinv_negs).reshape(batch + (l, n))


def ntt_inv_fused(x, psi_inv_rev_mont, n_inv_monts, qs, qinv_negs, *,
                  block_b: int | None = None, interpret: bool = True):
    """x: u32[..., L, N] bit-reversed NTT domain -> natural order."""
    if block_b is None:
        block_b = _tune.default_block("ntt_inv")
    x2, batch = _flatten(x)
    b, l, n = x2.shape
    call = _build("inv", l, n, min(block_b, b), interpret)
    return call(x2, psi_inv_rev_mont, qs, qinv_negs,
                n_inv_monts).reshape(batch + (l, n))


@functools.lru_cache(maxsize=128)
def _build4(direction: str, l: int, n: int, n1: int, n2: int, block_b: int,
            radix: int, interpret: bool):
    tile = pl.BlockSpec((block_b, 1, n), lambda li, bi: (bi, li, 0))
    row1 = pl.BlockSpec((1, n1), lambda li, bi: (li, 0))
    row2 = pl.BlockSpec((1, n2), lambda li, bi: (li, 0))
    rown = pl.BlockSpec((1, n), lambda li, bi: (li, 0))
    scalar = pl.BlockSpec((1,), lambda li, bi: (li,))
    if direction == "fwd":
        body = functools.partial(_ntt4_fwd_body, n=n, n1=n1, n2=n2,
                                 radix=radix)
        in_specs = [tile, row1, row2, rown, scalar, scalar]
    else:
        body = functools.partial(_ntt4_inv_body, n=n, n1=n1, n2=n2,
                                 radix=radix)
        in_specs = [tile, row1, row2, rown, scalar, scalar, scalar]

    def call(x, *tables):
        b = x.shape[0]
        return pl.pallas_call(
            body,
            grid=(l, pl.cdiv(b, block_b)),
            in_specs=in_specs,
            out_specs=tile,
            out_shape=jax.ShapeDtypeStruct((b, l, n), jnp.uint32),
            interpret=interpret,
        )(x, *tables)

    return call


def ntt4_fwd_fused(x, psi1_mont, psi2_mont, corr_mont, qs, qinv_negs, *,
                   block_b: int | None = None, radix: int = 2,
                   interpret: bool = True):
    """4-step forward negacyclic NTT, bit-identical to ntt_fwd_fused.

    x: u32[..., L, N] natural -> bit-reversed NTT domain.  Tables come from
    params.LimbTables: psi1_mont u32[L, n1], psi2_mont u32[L, n2],
    corr_mont u32[L, N] (N = n1*n2; the split is read off the table shapes,
    so retabled variants from params.retable_ntt4 change it here).  radix
    picks the sub-NTT butterfly grouping (2 or 4) — launch geometry only,
    never bits."""
    if block_b is None:
        block_b = _tune.default_block("ntt_fwd")
    x2, batch = _flatten(x)
    b, l, n = x2.shape
    n1, n2 = psi1_mont.shape[-1], psi2_mont.shape[-1]
    assert n1 * n2 == n, (n1, n2, n)
    call = _build4("fwd", l, n, n1, n2, min(block_b, b), radix, interpret)
    return call(x2, psi1_mont, psi2_mont, corr_mont, qs,
                qinv_negs).reshape(batch + (l, n))


def ntt4_inv_fused(x, psi1_inv_mont, psi2_inv_mont, corr_inv_mont,
                   n_inv_monts, qs, qinv_negs, *, block_b: int | None = None,
                   radix: int = 2, interpret: bool = True):
    """4-step inverse negacyclic NTT, bit-identical to ntt_inv_fused.

    x: u32[..., L, N] bit-reversed NTT domain -> natural order."""
    if block_b is None:
        block_b = _tune.default_block("ntt_inv")
    x2, batch = _flatten(x)
    b, l, n = x2.shape
    n1, n2 = psi1_inv_mont.shape[-1], psi2_inv_mont.shape[-1]
    assert n1 * n2 == n, (n1, n2, n)
    call = _build4("inv", l, n, n1, n2, min(block_b, b), radix, interpret)
    return call(x2, psi1_inv_mont, psi2_inv_mont, corr_inv_mont, qs,
                qinv_negs, n_inv_monts).reshape(batch + (l, n))
