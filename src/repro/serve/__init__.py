"""repro.serve — the async encrypted aggregation service (DESIGN.md §14).

The serving layer the ROADMAP's "async production aggregation service"
item describes: a round state machine (`service.AggregationService`)
that drives `wire.stream.StreamIngest` asynchronously — accepting
round r+1's updates while round r finalizes — with partial-quorum
finalization (`quorum.QuorumPolicy`), atomic rejection of faulty or
late updates, and accumulator + budget-ledger + round-state
checkpointing through `ckpt/store.py` so a `kill -9` mid-round resumes
bit-exactly.  `faults.py` is the service's adversary: a deterministic
injector for wire faults (drop / duplicate / truncate / garbage /
delay / reorder) and crash points between service transitions, used by
tests/test_serve.py and benchmarks/serve.py.
"""
from repro.serve.faults import (FAULT_MODES, CRASH_POINTS, FaultInjector,
                                SimulatedCrash, corrupt_blob)
from repro.serve.quorum import (QuorumPolicy, normalized_weights,
                                staleness_weights)
from repro.serve.service import (AggregationService, RoundState, SubmitResult,
                                 ST_DONE, ST_FAILED, ST_FOLDING, ST_OPEN,
                                 ST_SEALED)

__all__ = [
    "AggregationService", "RoundState", "SubmitResult",
    "ST_OPEN", "ST_SEALED", "ST_FOLDING", "ST_DONE", "ST_FAILED",
    "QuorumPolicy", "normalized_weights", "staleness_weights",
    "FAULT_MODES", "CRASH_POINTS", "FaultInjector", "SimulatedCrash",
    "corrupt_blob",
]
