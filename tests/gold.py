"""Gold models + known-answer-test (KAT) layer.

Two independent safety nets against silent drift:

  1. a numpy-uint64/object gold model for the u32 Montgomery construction
     (gold_mulmod / gold_mont_mul / gold_ntt below) — validates the 16-bit
     limb arithmetic in repro/kernels/ref.py against straightforward wide
     modular arithmetic;
  2. checked-in known-answer vectors (tests/golden/ckks_kats.json):
     NTT fwd/inv, pk + seeded encrypt, weighted_sum, and the selective
     partitioned-update path (fixed-mask wire bytes, streamed aggregation,
     merged recovery) for FIXED
     keys/params, which every backend ("ref", "pallas", "pallas4") must
     reproduce bit-exactly (tests/test_gold.py).  A jax PRNG change, a
     kernel regression, or a cross-version numeric drift all fail loudly
     instead of silently changing ciphertexts on the wire.

`compute_kats()` is the single source of the KAT inputs (fixed seeds) and
op sequence; `tools/gen_gold.py` serializes its output to the golden file
and the test recomputes it per backend and compares.
"""
from __future__ import annotations

import base64
import hashlib
import json
import os

import numpy as np

KAT_PATH = os.path.join(os.path.dirname(__file__), "golden",
                        "ckks_kats.json")

# fixed KAT parameter points: one small 2-limb context, one 3-limb context
# with a different N so the limb-dropped table paths and both ntt4_split
# shapes get pinned
KAT_CONTEXTS = {
    "n64_l2": dict(n_poly=64, n_limbs=2, delta_bits=20),
    "n256_l3": dict(n_poly=256, n_limbs=3, delta_bits=12),
}


def compute_kats() -> dict:
    """name -> u32 ndarray of every known-answer output, computed through
    the CURRENT backend registry assignment (kernels.ops).

    All inputs are derived from fixed seeds here, so the golden file only
    needs to pin outputs.  Deterministic by construction: numpy RandomState
    streams for data, jax threefry keys for the crypto sampling.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import packing, selection
    from repro.core.ckks import cipher
    from repro.core.ckks import params as ckks_params
    from repro.core.secure_agg import ProtectedUpdate
    from repro.kernels import ops, ref
    from repro.wire import compress as wire_compress
    from repro.wire import stream as ws

    out = {}
    for name, spec in KAT_CONTEXTS.items():
        ctx = ckks_params.make_context(**spec)
        rng = np.random.RandomState(12345)
        x = jnp.asarray(ref.rand_limbed_np(rng, ctx, (2,)))
        out[f"{name}/ntt_fwd"] = np.asarray(ops.ntt_fwd(x, ctx))
        out[f"{name}/ntt_inv"] = np.asarray(ops.ntt_inv(x, ctx))
        sk, pk = cipher.keygen(ctx, jax.random.PRNGKey(0))
        out[f"{name}/keygen_sk"] = np.asarray(sk["s_mont"])
        coeffs = jnp.asarray(ref.rand_limbed_np(rng, ctx, (2,)))
        ct_seeded = cipher.encrypt_coeffs_seeded(
            ctx, sk, coeffs, jax.random.PRNGKey(1), a_seed=77)
        out[f"{name}/encrypt_seeded"] = np.asarray(ct_seeded.data)
        ct_pk = cipher.encrypt_coeffs(ctx, pk, coeffs, jax.random.PRNGKey(2))
        out[f"{name}/encrypt_pk"] = np.asarray(ct_pk.data)
        both = cipher.Ciphertext(
            data=jnp.stack([ct_seeded.data, ct_pk.data]),
            scale=float(ctx.delta))
        out[f"{name}/weighted_sum"] = np.asarray(
            cipher.weighted_sum(ctx, both, [0.25, 0.75]).data)

        # -- selective path: fixed-mask partitioned update on the wire ------
        # pins the exact uplink bytes (seeded ct chunks + i8 plain segment,
        # wire v2) and the streamed aggregation / merged recovery of a
        # ragged selective partition
        n_total = 5 * ctx.slots // 2
        mask = selection.top_p_mask(rng.rand(n_total), 0.45)
        part = packing.make_partition(mask, ctx.slots)
        assert 0 < part.n_enc % ctx.slots          # ragged last chunk
        blobs = []
        for i in range(2):
            vec = jnp.asarray(rng.randn(n_total).astype(np.float32))
            enc_vals, plain = packing.split_by_mask(vec, part)
            sct_full = cipher.encrypt_values_seeded(
                ctx, sk, enc_vals, jax.random.PRNGKey(10 + i),
                a_seed=1234 + i)
            sct = wire_compress.seed_compress(sct_full, 1234 + i)
            blobs.append(ws.pack_update_frames(
                ProtectedUpdate(ct=sct_full, plain=plain), cid=i,
                n_samples=i + 1, rnd=0, seeded=sct, plain_codec="i8",
                version=2))
        out[f"{name}/selective_wire"] = \
            np.frombuffer(blobs[0], dtype=np.uint8).astype(np.uint32)
        ing = ws.StreamIngest(ctx)
        for blob, w in zip(blobs, [0.25, 0.75]):
            ing.ingest(blob, w)
        glob = ing.finalize()
        out[f"{name}/selective_agg"] = np.asarray(glob.ct.data)
        if ctx.n_limbs == 2:
            enc = cipher.decrypt_values(ctx, sk, glob.ct)
        else:
            enc = jnp.asarray(cipher.decrypt_values_np(ctx, sk, glob.ct))
        merged = np.asarray(packing.merge_by_mask(enc, glob.plain, part),
                            dtype=np.float32)
        # f32 bit pattern, not value conversion: encode_kats casts to u32
        out[f"{name}/selective_merged"] = merged.view(np.uint32)
    return out


def encode_kats(kats: dict) -> dict:
    """Serializable golden-file payload: shape + sha256 + b64 le-bytes."""
    entries = {}
    for name, arr in sorted(kats.items()):
        a = np.ascontiguousarray(np.asarray(arr, dtype=np.uint32))
        raw = a.tobytes()
        entries[name] = {
            "shape": list(a.shape),
            "sha256": hashlib.sha256(raw).hexdigest(),
            "data_b64": base64.b64encode(raw).decode("ascii"),
        }
    return {
        "comment": "Generated by tools/gen_gold.py — do not edit. "
                   "Known-answer vectors for fixed keys/params; every "
                   "backend must reproduce these bit-exactly "
                   "(tests/test_gold.py).",
        "contexts": KAT_CONTEXTS,
        "kats": entries,
    }


def load_kats(path: str = KAT_PATH) -> dict:
    """Golden file -> name -> u32 ndarray (sha256-verified)."""
    with open(path) as f:
        doc = json.load(f)
    out = {}
    for name, e in doc["kats"].items():
        raw = base64.b64decode(e["data_b64"])
        digest = hashlib.sha256(raw).hexdigest()
        if digest != e["sha256"]:
            raise ValueError(
                f"golden KAT {name!r} is corrupt: sha256 {digest} != "
                f"recorded {e['sha256']} (regenerate with "
                "tools/gen_gold.py)")
        out[name] = np.frombuffer(raw, dtype=np.uint32).reshape(e["shape"])
    return out


def gold_mulmod(a: np.ndarray, b: np.ndarray, q: int) -> np.ndarray:
    return (a.astype(np.uint64) * b.astype(np.uint64) % np.uint64(q)) \
        .astype(np.uint32)


def gold_mont_mul(a, b, q: int) -> np.ndarray:
    """Montgomery product a*b*R^{-1} mod q via uint64/object math."""
    r_inv = pow(1 << 32, -1, q)
    wide = a.astype(object) * b.astype(object) * r_inv % q
    return np.asarray(wide, dtype=np.uint64).astype(np.uint32)


def gold_ntt(x: np.ndarray, q: int, psi: int) -> np.ndarray:
    """O(N^2) negacyclic NTT in bit-reversed output order."""
    n = x.shape[-1]
    logn = n.bit_length() - 1
    # X_k = sum_j x_j psi^(2jk + j) ; output bit-reversed
    ks = np.arange(n)
    out = np.zeros_like(x, dtype=np.uint64)
    xs = x.astype(np.uint64)
    for k in range(n):
        acc = 0
        for j in range(n):
            w = pow(psi, (2 * j * k + j) % (2 * n), q)
            acc = (acc + int(xs[..., j]) * w) % q
        out[..., _bitrev(k, logn)] = acc
    return out.astype(np.uint32)


def _bitrev(x: int, bits: int) -> int:
    out = 0
    for _ in range(bits):
        out = (out << 1) | (x & 1)
        x >>= 1
    return out
