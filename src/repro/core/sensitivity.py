"""Per-parameter privacy sensitivity maps (paper §2.4 Step 1).

For model W and K samples (X, y) the paper defines, per parameter w_m,

    S_m = (1/K) sum_k | d/dy_k ( dl(X, y, W) / dw_m ) |

i.e. how strongly each parameter's gradient reacts to perturbing the true
output — a cheap proxy for gradient-inversion attackability (Novak et al.,
2018; Mo et al., 2020).

Losses here take *soft* targets (one-hot / distribution y) so d/dy exists.

Two evaluators:
  * ``sensitivity_exact``   — full Jacobian d(grad_w)/dy via jacrev over the
    y->grad map.  O(P * K * n_out) memory; for tests and LeNet-scale models.
  * ``sensitivity_jvp``     — Hutchinson-style estimator: for probe vectors
    v ~ N(0, I) in y-space, jvp(y -> grad_w, v) gives J v in one
    forward-over-reverse pass; E_v |J v| ~ sqrt(2/pi) ||J_m||_2 per row.
    Cost per probe = one grad evaluation; memory O(P).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def sensitivity_exact(loss_fn, params, x, y_soft):
    """loss_fn(params, x, y_soft) -> scalar. Returns pytree like params.

    S = mean_k |d(grad_w)/dy_k| where k ranges over every element of y_soft.
    """
    grad_of_y = lambda y: jax.grad(loss_fn)(params, x, y)
    jac = jax.jacrev(grad_of_y)(y_soft)          # pytree of [*w_shape, *y_shape]
    ndim_y = jnp.ndim(y_soft)

    def reduce_leaf(j):
        axes = tuple(range(j.ndim - ndim_y, j.ndim))
        return jnp.mean(jnp.abs(j), axis=axes)

    return jax.tree_util.tree_map(reduce_leaf, jac)


def sensitivity_jvp(loss_fn, params, x, y_soft, key, n_probes: int = 8):
    """Hutchinson estimator of the exact map above (same pytree output).

    E_{v~N(0,I)} |(J v)_m| = sqrt(2/pi) * ||J_m||_2 ; we return the raw
    expectation estimate — selection only needs the *ranking*, which matches
    the exact map's ranking as rows are reduced with the same norm family.
    """
    grad_of_y = lambda y: jax.grad(loss_fn)(params, x, y)

    def one_probe(k):
        v = jax.random.normal(k, jnp.shape(y_soft), dtype=jnp.result_type(y_soft))
        _, jv = jax.jvp(grad_of_y, (y_soft,), (v,))
        return jax.tree_util.tree_map(jnp.abs, jv)

    keys = jax.random.split(key, n_probes)
    acc = one_probe(keys[0])
    for k in keys[1:]:
        acc = jax.tree_util.tree_map(jnp.add, acc, one_probe(k))
    scale = 1.0 / (n_probes * math.sqrt(2.0 / math.pi))
    return jax.tree_util.tree_map(lambda a: a * scale, acc)


def sensitivity_magnitude_proxy(grads):
    """|grad| fallback proxy (used when y is not differentiable, e.g. pure
    token-id pipelines); documented deviation — ranking quality is lower but
    the selection/encryption machinery is identical."""
    return jax.tree_util.tree_map(jnp.abs, grads)
