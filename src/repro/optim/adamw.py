"""AdamW with global-norm clipping (pure JAX, pytree-native).

Optimizer state is a pytree shaped like params (m, v in f32) and shards
with the same PartitionSpecs as the parameters (ZeRO-style).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(grads, opt_state, params, cfg: AdamWConfig, lr=None):
    """Returns (new_params, new_opt_state, grad_norm)."""
    lr = cfg.lr if lr is None else lr
    grads, norm = clip_by_global_norm(grads, cfg.clip_norm)
    step = opt_state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        mh = m / bc1
        vh = v / bc2
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * p32)
        return p32.astype(p.dtype), m, v

    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt_state["m"])
    flat_v = jax.tree_util.tree_leaves(opt_state["v"])
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, norm
