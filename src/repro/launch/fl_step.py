"""Distributed HE secure-aggregation step (the paper's server hot loop,
mapped onto the production mesh).

Two sharding regimes (DESIGN.md §8):

  * limb-sharded — when the mesh's ``model`` axis size divides the RNS
    limb count, the step routes through the sharded engine layout: limbs
    shard along ``model``, ciphertext chunks along every other axis, and
    the fused weighted-sum runs as one `shard_map` dispatch with zero
    collectives (HE aggregation is pointwise per (limb, coefficient)).
  * chunk-only (fallback) — otherwise the [n_chunks] axis shards across
    every mesh axis (production meshes have model=16 > L); still zero
    collectives, memory-bound.

The plaintext remainder aggregates the same way in both regimes.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.ckks import encoding
from repro.core.ckks.params import CkksContext, make_context
from repro.core.ckks.sharded import local_tables, table_arrays, table_specs
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class HeAggSpec:
    """Static description of one aggregation round's tensors."""

    n_clients: int
    n_chunks: int            # ciphertexts per client (padded to mesh size)
    n_plain: int             # plaintext parameters (padded to mesh size)
    ctx: CkksContext

    @staticmethod
    def for_model(n_params: int, p_ratio: float, n_clients: int,
                  mesh_size: int, ctx: CkksContext | None = None):
        ctx = ctx or make_context()
        n_enc = int(round(n_params * p_ratio))
        chunks = max(1, -(-n_enc // ctx.slots))
        chunks = -(-chunks // mesh_size) * mesh_size
        n_plain = n_params - n_enc
        n_plain = -(-n_plain // mesh_size) * mesh_size
        return HeAggSpec(n_clients=n_clients, n_chunks=chunks,
                         n_plain=n_plain, ctx=ctx)

    def input_specs(self):
        sds = jax.ShapeDtypeStruct
        c, l, n = self.n_clients, self.ctx.n_limbs, self.ctx.n_poly
        return {
            "cts": sds((c, self.n_chunks, l, 2, n), jnp.uint32),
            "plain": sds((c, self.n_plain), jnp.float32),
        }

    def limb_sharded(self, mesh) -> bool:
        """True when the mesh's model axis can host whole limb shards."""
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        m = sizes.get("model", 0)
        return m > 0 and self.ctx.n_limbs % m == 0 \
            and self.n_chunks % (mesh.size // m) == 0

    def shardings(self, mesh):
        """NamedShardings for the step inputs.

        Limb-sharded regime: cts [C, chunks, L, 2, N] put chunks on the
        non-model axes and limbs on ``model``.  Fallback: chunks across
        every axis, limbs replicated.
        """
        axes = tuple(mesh.axis_names)
        if self.limb_sharded(mesh):
            data_axes = tuple(a for a in axes if a != "model")
            return {
                "cts": NamedSharding(
                    mesh, P(None, data_axes, "model", None, None)),
                "plain": NamedSharding(mesh, P(None, data_axes)),
            }
        return {
            "cts": NamedSharding(mesh, P(None, axes, None, None, None)),
            "plain": NamedSharding(mesh, P(None, axes)),
        }

    def wire_bytes_per_client(self) -> int:
        return self.n_chunks * self.ctx.ciphertext_bytes(packed=False) \
            + 4 * self.n_plain


def make_he_agg_step(spec: HeAggSpec, weights: list[float], mesh=None):
    """Server aggregation: sum_i w_i (*) ct_i (HE) + sum_i w_i plain_i.

    With a mesh whose model axis divides the limb count, the HE part is an
    explicit `shard_map` over (chunks -> data axes, limbs -> model); the
    body dispatches through the backend registry per shard.  Without a
    mesh (or when limbs don't divide) the single-device fused op is used
    and any sharding comes from jit's in_shardings alone.
    """
    ctx = spec.ctx
    w_mont = jnp.asarray(
        encoding.encode_weights_mont(weights, ctx))        # [C, L]
    w_plain = jnp.asarray(np.asarray(weights, np.float32))
    limb_sharded = mesh is not None and spec.limb_sharded(mesh)

    if limb_sharded:
        data_axes = tuple(a for a in mesh.axis_names if a != "model")
        tabs = table_arrays(ctx.tables)

        def he_body(x, w, *tabs):
            return ops.apply("weighted_sum", local_tables(tabs), x, w)

        he = shard_map(
            he_body, mesh=mesh,
            in_specs=(P(None, data_axes, None, "model", None),
                      P(None, "model")) + table_specs("model"),
            out_specs=P(data_axes, None, "model", None), check_rep=False)

        def step(cts, plain):
            # [C, chunks, L, 2, N] -> limbs at axis -2 for the kernels
            x = jnp.moveaxis(cts, -3, -2)
            enc = jnp.moveaxis(he(x, w_mont, *tabs), -2, -3)
            pt = jnp.einsum("c,cp->p", w_plain, plain)
            return enc, pt

        return step

    def step(cts, plain):
        x = jnp.moveaxis(cts, -3, -2)
        enc = ops.weighted_sum(x, w_mont, ctx)
        enc = jnp.moveaxis(enc, -2, -3)
        pt = jnp.einsum("c,cp->p", w_plain, plain)
        return enc, pt

    return step


def jit_he_agg_step(spec: HeAggSpec, mesh, weights: list[float]):
    sh = spec.shardings(mesh)
    return jax.jit(
        make_he_agg_step(spec, weights, mesh=mesh),
        in_shardings=(sh["cts"], sh["plain"]),
        out_shardings=(None, None),
    )
