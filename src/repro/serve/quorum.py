"""Quorum policy: when may a round seal, and with what client weights.

A round of the aggregation service accepts updates while OPEN and seals —
freezing the accepted set — when the policy says so.  Sealing is the
partial-quorum contract of every HE-FL serving system (paper §4; flwr's
failure-handling contract minus its decrypt-at-server hole): the server
never waits for the full fleet, it waits for `min_clients` and a reason
to stop (the optional `target_clients` high-water mark, or the round
deadline).  Below `min_clients` a round can NEVER finalize — tests
assert both directions as a hypothesis property.

Weight math lives here so every aggregation path (the service,
`FLServer.aggregate_wire`, the async FedBuff buffer) computes FedAvg
weights through the same float64 expressions and stays bit-identical.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

# reasons should_seal can return (None = keep accepting)
SEAL_TARGET = "target"        # target_clients accepted
SEAL_DEADLINE = "deadline"    # deadline passed with quorum met
FAIL_DEADLINE = "deadline_below_quorum"   # deadline passed, quorum NOT met


@dataclasses.dataclass(frozen=True)
class QuorumPolicy:
    """Partial-quorum finalization policy for one service round.

    Attributes:
        min_clients: quorum floor — a round below this NEVER finalizes
            (it fails at the deadline instead).
        target_clients: optional high-water mark; the round seals as soon
            as this many updates were accepted (stragglers past it are
            late).  None = seal only at the deadline.
        deadline_s: optional round deadline in seconds since open; updates
            arriving later are rejected as ``late`` and the round seals
            (quorum met) or fails (quorum unmet) at the next poll.
            None = no deadline (the driver must seal explicitly).
    """

    min_clients: int = 2
    target_clients: int | None = None
    deadline_s: float | None = None

    def __post_init__(self):
        if self.min_clients < 1:
            raise ValueError(f"min_clients must be >= 1, got "
                             f"{self.min_clients}")
        if self.target_clients is not None \
                and self.target_clients < self.min_clients:
            raise ValueError(
                f"target_clients ({self.target_clients}) must be >= "
                f"min_clients ({self.min_clients})")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got "
                             f"{self.deadline_s}")

    def met(self, n_accepted: int) -> bool:
        """True iff `n_accepted` updates satisfy the quorum floor."""
        return n_accepted >= self.min_clients

    def late(self, elapsed_s: float) -> bool:
        """True iff an update arriving `elapsed_s` after round open missed
        the deadline."""
        return self.deadline_s is not None and elapsed_s > self.deadline_s

    def should_seal(self, n_accepted: int, elapsed_s: float) -> str | None:
        """-> SEAL_TARGET | SEAL_DEADLINE | FAIL_DEADLINE | None.

        None means the round stays open.  FAIL_DEADLINE means the round
        can no longer reach quorum in time and must fail."""
        if self.target_clients is not None \
                and n_accepted >= self.target_clients:
            return SEAL_TARGET
        if self.deadline_s is not None and elapsed_s > self.deadline_s:
            return SEAL_DEADLINE if self.met(n_accepted) else FAIL_DEADLINE
        return None


def normalized_weights(n_samples: Sequence[int]) -> list[float]:
    """FedAvg weights over the accepted set: n_i / sum(n).

    The same float64 expression `FLServer.aggregate_wire` uses, extracted
    so the service's partial-quorum renormalization is bit-identical to
    the synchronous reference path.
    """
    w = np.asarray(list(n_samples), dtype=np.float64)
    if w.size == 0 or w.sum() <= 0:
        raise ValueError("cannot normalize weights over an empty or "
                         "zero-sample accepted set")
    w = w / w.sum()
    return [float(x) for x in w]


def staleness_weights(n_samples: Sequence[int],
                      rounds_sent: Sequence[int],
                      current_round: int,
                      half_life: float) -> list[float]:
    """FedBuff staleness-discounted FedAvg weights.

    w_i ∝ n_i * 0.5 ** (staleness_i / half_life), normalized to sum to 1 —
    the exact float64 math `FLServer.submit_async` applied inline before it
    was folded into the service layer (tests/test_serve.py pins both the
    discount law and the FLServer round trip).
    """
    ws = []
    for n, sent in zip(n_samples, rounds_sent):
        stale = max(0, current_round - sent)
        ws.append(n * 0.5 ** (stale / half_life))
    ws = np.asarray(ws, dtype=np.float64)
    if ws.size == 0 or ws.sum() <= 0:
        raise ValueError("cannot normalize staleness weights over an empty "
                         "or zero-sample buffer")
    ws = ws / ws.sum()
    return [float(w) for w in ws]
