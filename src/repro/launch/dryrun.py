"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
placeholder devices, prove memory/sharding coherence, and dump the roofline
raw material (cost_analysis, memory_analysis, collective schedule) to
benchmarks/artifacts/<arch>_<shape>_<mesh>[__tag].json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --he-agg --mesh single
"""
# The first two executable lines: jax locks the device count on first init,
# so the placeholder-device flag must be set before ANY other import.
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_EXTRA", ""))

import argparse
import json
import sys
import time
import traceback

import jax
import numpy as np

from repro import configs
from repro.configs.shapes import SHAPES, input_specs
from repro.launch import fl_step, steps
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.models.sharding import axis_env_from_mesh
from repro.optim import AdamWConfig

sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                "..", "..", ".."))  # benchmarks/
from benchmarks import roofline as rf  # noqa: E402

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "benchmarks", "artifacts")


def _mesh_for(name: str):
    return make_production_mesh(multi_pod=(name == "multi"))


def _abstract_opt(params_abs):
    sds = jax.ShapeDtypeStruct
    f32 = jax.numpy.float32
    zeros = lambda p: sds(p.shape, f32)
    return {"m": jax.tree_util.tree_map(zeros, params_abs),
            "v": jax.tree_util.tree_map(zeros, params_abs),
            "step": sds((), jax.numpy.int32)}


def lower_cell(arch: str, shape: str, mesh_name: str, tag: str = "",
               param_mode: str = "train", cfg_overrides: dict | None = None):
    """Lower+compile one cell; returns the artifact dict."""
    mesh = _mesh_for(mesh_name)
    n_dev = mesh.size
    cfg = configs.get_config(arch)
    if cfg_overrides:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, **cfg_overrides)
    sp = SHAPES[shape]
    with jax.sharding.set_mesh(mesh):
        ax = axis_env_from_mesh(mesh)
        model = build_model(cfg, ax)
        params_abs = model.init_abstract()

        t0 = time.perf_counter()
        if sp.kind == "train":
            batch = input_specs(cfg, shape)
            step = steps.jit_train_step(model, mesh, AdamWConfig(), batch)
            lowered = step.lower(params_abs, _abstract_opt(params_abs), batch)
            tokens = sp.batch * sp.seq
        elif sp.kind == "prefill":
            batch = input_specs(cfg, shape)
            step = steps.jit_prefill_step(model, mesh, batch)
            lowered = step.lower(params_abs, batch)
            tokens = sp.batch * sp.seq
        else:  # decode
            full = input_specs(cfg, shape, model=model)
            batch = {"tokens": full["tokens"]}
            cache = full["cache"]
            step = steps.jit_decode_step(model, mesh, cache, batch, sp.batch,
                                         param_mode=param_mode)
            lowered = step.lower(params_abs, cache, batch)
            tokens = sp.batch
        t_lower = time.perf_counter() - t0

        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    return _analyze(compiled, cfg, sp.kind, tokens, n_dev, arch, shape,
                    mesh_name, t_lower, t_compile, tag)


def lower_he_agg(mesh_name: str, arch: str = "qwen1.5-0.5b",
                 p_ratio: float = 0.1, n_clients: int = 8, tag: str = ""):
    """The paper-technique cell: distributed CKKS FedAvg aggregation."""
    mesh = _mesh_for(mesh_name)
    cfg = configs.get_config(arch)
    spec = fl_step.HeAggSpec.for_model(
        cfg.param_count(), p_ratio, n_clients, mesh.size)
    ins = spec.input_specs()
    with jax.sharding.set_mesh(mesh):
        t0 = time.perf_counter()
        step = fl_step.jit_he_agg_step(spec, mesh,
                                       [1.0 / n_clients] * n_clients)
        lowered = step.lower(ins["cts"], ins["plain"])
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    class _HECfg:
        name = f"he-agg[{arch}, p={p_ratio}]"

        @staticmethod
        def active_param_count():
            return 0

    art = _analyze(compiled, _HECfg, "he_agg", 0, mesh.size,
                   arch, "he_agg", mesh_name, t_lower, t_compile, tag)
    art["he"] = {
        "n_clients": n_clients, "p_ratio": p_ratio,
        "n_chunks": spec.n_chunks, "n_plain": spec.n_plain,
        "wire_bytes_per_client": spec.wire_bytes_per_client(),
    }
    _write(art)
    return art


def _analyze(compiled, cfg, kind, tokens, n_dev, arch, shape, mesh_name,
             t_lower, t_compile, tag=""):
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    txt = compiled.as_text()
    colls = rf.parse_collectives(txt)
    flops = float(ca.get("flops", 0.0))
    bytes_acc = float(ca.get("bytes accessed", 0.0))
    fused = rf.parse_memory_traffic(txt)
    roof = rf.build_roofline(cfg, kind, tokens, n_dev, flops, bytes_acc,
                             colls, fused) if kind != "he_agg" else rf.Roofline(
        compute_s=flops / rf.PEAK_FLOPS, memory_s=fused / rf.HBM_BW,
        collective_s=colls.wire_bytes / rf.ICI_BW,
        memory_upper_s=bytes_acc / rf.HBM_BW, flops=flops,
        bytes_accessed=bytes_acc, fused_bytes=fused,
        wire_bytes=colls.wire_bytes, model_flops=0.0, flops_ratio=0.0)
    import gzip
    os.makedirs(ARTIFACTS, exist_ok=True)
    tagsuf = f"__{tag}" if tag else ""
    hlo_fn = os.path.join(ARTIFACTS,
                          f"{arch}_{shape}_{mesh_name}{tagsuf}.hlo.gz")
    with gzip.open(hlo_fn, "wt") as f:
        f.write(txt)
    art = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "tag": tag,
        "n_devices": n_dev, "tokens": tokens, "kind": kind,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "hlo_lines": len(txt.splitlines()),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_hbm_bytes": ma.argument_size_in_bytes
                              + ma.output_size_in_bytes
                              + ma.temp_size_in_bytes
                              - ma.alias_size_in_bytes,
        },
        "collectives": {"counts": colls.counts,
                        "by_op_bytes": colls.by_op},
        "roofline": roof.to_dict(),
    }
    return art


def _write(art: dict):
    os.makedirs(ARTIFACTS, exist_ok=True)
    tag = f"__{art['tag']}" if art.get("tag") else ""
    fn = f"{art['arch']}_{art['shape']}_{art['mesh']}{tag}.json"
    with open(os.path.join(ARTIFACTS, fn), "w") as f:
        json.dump(art, f, indent=1)
    return fn


def run_cell(arch, shape, mesh_name, force=False, tag="",
             param_mode="train", cfg_overrides=None):
    tagsuf = f"__{tag}" if tag else ""
    fn = os.path.join(ARTIFACTS, f"{arch}_{shape}_{mesh_name}{tagsuf}.json")
    if os.path.exists(fn) and not force:
        print(f"SKIP (cached) {arch} {shape} {mesh_name}")
        return json.load(open(fn))
    t0 = time.perf_counter()
    try:
        art = lower_cell(arch, shape, mesh_name, tag, param_mode=param_mode,
                         cfg_overrides=cfg_overrides)
    except Exception as e:
        print(f"FAIL {arch} {shape} {mesh_name}: {e}")
        traceback.print_exc()
        return None
    _write(art)
    r = art["roofline"]
    peak = art["memory"]["peak_hbm_bytes"] / 1e9
    print(f"OK {arch} {shape} {mesh_name} "
          f"compile={art['compile_s']}s "
          f"comp={r['compute_s']*1e3:.1f}ms mem={r['memory_s']*1e3:.1f}ms "
          f"coll={r['collective_s']*1e3:.1f}ms dom={r['dominant']} "
          f"frac={r['roofline_fraction']:.2f} peakHBM={peak:.1f}GB "
          f"({time.perf_counter()-t0:.0f}s)")
    return art


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--he-agg", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--param-mode", default="train",
                    choices=["train", "serve_tp"])
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.he_agg:
        for m in meshes:
            t0 = time.perf_counter()
            art = lower_he_agg(m, tag=args.tag)
            r = art["roofline"]
            print(f"OK he_agg {m} comp={r['compute_s']*1e3:.2f}ms "
                  f"mem={r['memory_s']*1e3:.2f}ms coll={r['collective_s']*1e3:.2f}ms "
                  f"dom={r['dominant']} ({time.perf_counter()-t0:.0f}s)")
        return
    if args.all:
        cells = configs.all_cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    ok = fail = 0
    for arch, shape in cells:
        for m in meshes:
            art = run_cell(arch, shape, m, force=args.force, tag=args.tag,
                           param_mode=args.param_mode)
            ok += art is not None
            fail += art is None
    print(f"done: {ok} ok, {fail} failed")
    if fail:
        sys.exit(1)


if __name__ == "__main__":
    main()
