"""Benchmark harness: one entry per paper table/figure + kernel
microbenchmarks + the roofline summary table from dry-run artifacts.

  PYTHONPATH=src python -m benchmarks.run               # everything
  PYTHONPATH=src python -m benchmarks.run table4 fig8   # subset
  PYTHONPATH=src python -m benchmarks.run --help        # modes + env vars

Environment (full list in README.md "Environment variables & flags"):
  REPRO_HE_BACKEND=ref|pallas|pallas4   backend for every HE op (default
      ref; pallas4 = 4-step transpose NTT kernels)
  XLA_FLAGS=--xla_force_host_platform_device_count=<n>
      simulate <n> devices on one host; must be set before the first jax
      import.  `agg-sharded` and `uplink-sharded` spawn their own
      subprocess per device count, so they need no flags from the caller.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time


def _rows(title, rows, keys=None):
    print(f"\n== {title} ==")
    if not rows:
        print("(no rows)")
        return
    keys = keys or list(rows[0].keys())
    print(",".join(keys))
    for r in rows:
        print(",".join(_fmt(r.get(k)) for k in keys))


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def _timeit(fn, *args, reps: int = 5):
    """Mean wall time of fn(*args) after one warmup call; blocks on every
    output leaf so async dispatch cannot fake speedups."""
    import jax

    def _block(x):
        return x.block_until_ready() if hasattr(x, "block_until_ready") \
            else x

    out = fn(*args)
    jax.tree_util.tree_map(_block, out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.tree_util.tree_map(_block, out)
    return (time.time() - t0) / reps


def bench_table4():
    """Paper Table 4: fully-encrypted aggregation vs plaintext."""
    from benchmarks import paper_tables
    _rows("Table 4: fully-encrypted aggregation vs plaintext",
          paper_tables.table4())


def bench_table6():
    """Paper Table 6: crypto parameter sweep."""
    from benchmarks import paper_tables
    _rows("Table 6: crypto parameter sweep", paper_tables.table6())


def bench_table7():
    """Paper Table 7: selective-encryption ratio sweep (ViT-sized)."""
    from benchmarks import paper_tables
    _rows("Table 7: selective-encryption ratio sweep (ViT-sized)",
          paper_tables.table7())


def bench_fig7():
    """Paper Figure 7: overhead vs selection ratio."""
    from benchmarks import paper_tables
    _rows("Figure 7: overhead vs selection ratio", paper_tables.fig7())


def bench_fig8():
    """Paper Figure 8: training-cycle decomposition (SAR bandwidth)."""
    from benchmarks import paper_tables
    _rows("Figure 8: training-cycle decomposition (SAR bandwidth)",
          paper_tables.fig8())


def bench_fig14a():
    """Paper Figure 14a: aggregation cost vs clients."""
    from benchmarks import paper_tables
    _rows("Figure 14a: aggregation cost vs clients", paper_tables.fig14a())


def bench_dp():
    """Remarks 3.12-3.14: privacy-budget laws."""
    from benchmarks import paper_tables
    _rows("Remarks 3.12-3.14: privacy-budget laws",
          paper_tables.dp_advantage())


def bench_kernels():
    """Microbenchmark the HE kernels (ref backend on CPU; Pallas interpret
    parity is asserted in tests)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.ckks import params as ckks_params
    from repro.kernels import ref

    rows = []
    for n_poly in (2048, 8192):
        ctx = ckks_params.make_context(n_poly=n_poly, n_limbs=2,
                                       delta_bits=26)
        lc = ctx.limbs[0]
        rng = np.random.RandomState(0)
        x = jnp.asarray(rng.randint(0, lc.q, size=(64, n_poly))
                        .astype(np.uint32))
        tw = jnp.asarray(lc.psi_rev_mont)
        f = jax.jit(lambda x: ref.ntt_fwd(x, tw, jnp.uint32(lc.q),
                                          jnp.uint32(lc.qinv_neg)))
        f(x).block_until_ready()
        t0 = time.time()
        for _ in range(5):
            out = f(x)
        out.block_until_ready()
        dt = (time.time() - t0) / 5
        rows.append({"kernel": "ntt_fwd", "N": n_poly, "batch": 64,
                     "us_per_poly": dt / 64 * 1e6})
    _rows("Kernel microbenchmarks (ref backend, CPU)", rows)


def bench_he():
    """Limb-fused HE engine vs the per-limb dispatch baseline.

    The baseline reproduces the seed engine's execution model — an eager
    Python loop dispatching one single-limb kernel per RNS limb — against
    the fused engine's one-jitted-graph-per-op over u32[..., L, N].
    Emits BENCH_he.json (repo root) for the bench trajectory.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.ckks import cipher, encoding
    from repro.core.ckks import params as ckks_params
    from repro.kernels import ops, ref

    n_poly, n_limbs, n_clients, batch = 8192, 2, 8, 8
    ctx = ckks_params.make_context(n_poly=n_poly, n_limbs=n_limbs,
                                   delta_bits=26)
    t = ctx.tables
    rng = np.random.RandomState(0)

    def rand_limbed(shape):
        return jnp.asarray(ref.rand_limbed_np(rng, ctx, shape))

    timeit = _timeit

    # -- per-limb baselines: eager loop, one single-limb ref op per limb ----
    def per_limb_ntt_fwd(x):
        return jnp.stack(
            [ref.ntt_fwd(x[..., i, :], jnp.asarray(lc.psi_rev_mont),
                         np.uint32(lc.q), np.uint32(lc.qinv_neg))
             for i, lc in enumerate(ctx.limbs)], axis=-2)

    def per_limb_ntt_inv(x):
        return jnp.stack(
            [ref.ntt_inv(x[..., i, :], jnp.asarray(lc.psi_inv_rev_mont),
                         np.asarray(lc.n_inv_mont), np.uint32(lc.q),
                         np.uint32(lc.qinv_neg))
             for i, lc in enumerate(ctx.limbs)], axis=-2)

    def per_limb_weighted_sum(cts, w):
        return jnp.stack(
            [ref.he_weighted_sum(cts[..., i, :],
                                 w[:, i].reshape((n_clients, 1, 1)),
                                 np.uint32(lc.q), np.uint32(lc.qinv_neg))
             for i, lc in enumerate(ctx.limbs)], axis=-2)

    # -- fused engine: one jitted graph per op ------------------------------
    token = ops.backend_token()
    fused_ntt_fwd = jax.jit(lambda x: ops.ntt_fwd(x, ctx))
    fused_ntt_inv = jax.jit(lambda x: ops.ntt_inv(x, ctx))
    fused_weighted_sum = jax.jit(lambda c, w: ops.weighted_sum(c, w, ctx))

    x = rand_limbed((batch,))
    cts = rand_limbed((n_clients, batch))
    w_mont = jnp.asarray(encoding.encode_weights_mont(
        [1.0 / n_clients] * n_clients, ctx))

    from repro import obs
    rows, results = [], {"n_poly": n_poly, "n_limbs": n_limbs,
                         "n_clients": n_clients, "batch": batch,
                         "backend": ops.get_backend(), "token": str(token),
                         "provenance": obs.provenance(), "ops": {}}
    cases = [
        ("ntt_fwd", lambda: timeit(per_limb_ntt_fwd, x),
         lambda: timeit(fused_ntt_fwd, x)),
        ("ntt_inv", lambda: timeit(per_limb_ntt_inv, x),
         lambda: timeit(fused_ntt_inv, x)),
        ("weighted_sum", lambda: timeit(per_limb_weighted_sum, cts, w_mont),
         lambda: timeit(fused_weighted_sum, cts, w_mont)),
    ]
    for name, base_fn, fused_fn in cases:
        base_s, fused_s = base_fn(), fused_fn()
        rows.append({"op": name, "per_limb_ms": base_s * 1e3,
                     "fused_ms": fused_s * 1e3,
                     "speedup": base_s / fused_s})
        results["ops"][name] = {"per_limb_ms": base_s * 1e3,
                                "fused_ms": fused_s * 1e3,
                                "speedup": base_s / fused_s}

    # -- end-to-end encrypt/decrypt (fused jitted graphs) -------------------
    sk, pk = cipher.keygen(ctx, jax.random.PRNGKey(0))
    vals = jnp.asarray(rng.randn(2, ctx.slots).astype(np.float32))
    coeffs = encoding.encode_jnp(vals, ctx)
    key = jax.random.PRNGKey(1)
    enc_s = timeit(lambda: cipher.encrypt_coeffs(ctx, pk, coeffs, key).data)
    ct = cipher.encrypt_coeffs(ctx, pk, coeffs, key)
    dec_s = timeit(lambda: cipher.decrypt_to_coeffs(ctx, sk, ct))
    for name, s in (("encrypt", enc_s), ("decrypt", dec_s)):
        rows.append({"op": name, "per_limb_ms": float("nan"),
                     "fused_ms": s * 1e3, "speedup": float("nan")})
        results["ops"][name] = {"fused_ms": s * 1e3}

    _merge_bench_he(results)
    _rows(f"HE engine: per-limb baseline vs limb-fused "
          f"(N={n_poly}, L={n_limbs}, C={n_clients}, backend="
          f"{ops.get_backend()}; BENCH_he.json written)", rows)


def _merge_bench_he(update: dict) -> None:
    """Merge keys into BENCH_he.json so `he` and `ntt` can each refresh
    their own section without clobbering the other's rows."""
    path = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                        "BENCH_he.json"))
    try:
        with open(path) as f:
            doc = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        doc = {}
    doc.update(update)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)


def bench_ntt():
    """Flat limb-grid NTT kernel vs the 4-step transpose NTT ("pallas4")
    at N in {4096, 8192, 16384} x L in {1, 2, 3}, both directions.

    Both kernels run through their Pallas path (interpret mode on CPU, so
    the numbers track kernel structure/dispatch, not real TPU lane
    behaviour — DESIGN.md §10 explains where the 4-step layout wins on
    hardware).  Appends an "ntt4" section to BENCH_he.json.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.ckks import params as ckks_params
    from repro.kernels import ntt, ref

    batch, reps = 4, 3

    def timeit(fn, *args):
        return _timeit(fn, *args, reps=reps)

    interpret = jax.default_backend() == "cpu"
    rows = []
    for n_poly in (4096, 8192, 16384):
        for n_limbs in (1, 2, 3):
            ctx = ckks_params.make_context(
                n_poly=n_poly, n_limbs=n_limbs,
                delta_bits=12 if n_limbs == 1 else 26)
            t = ctx.tables
            rng = np.random.RandomState(0)
            x = jnp.asarray(ref.rand_limbed_np(rng, ctx, (batch,)))
            flat_fwd = jax.jit(lambda x, t=t: ntt.ntt_fwd_fused(
                x, t.psi_rev_mont, t.qs, t.qinv_negs, interpret=interpret))
            four_fwd = jax.jit(lambda x, t=t: ntt.ntt4_fwd_fused(
                x, t.ntt4_psi1_mont, t.ntt4_psi2_mont, t.ntt4_corr_mont,
                t.qs, t.qinv_negs, interpret=interpret))
            flat_inv = jax.jit(lambda x, t=t: ntt.ntt_inv_fused(
                x, t.psi_inv_rev_mont, t.n_inv_monts, t.qs, t.qinv_negs,
                interpret=interpret))
            four_inv = jax.jit(lambda x, t=t: ntt.ntt4_inv_fused(
                x, t.ntt4_psi1_inv_mont, t.ntt4_psi2_inv_mont,
                t.ntt4_corr_inv_mont, t.n_inv_monts, t.qs, t.qinv_negs,
                interpret=interpret))
            y = flat_fwd(x)
            parity = bool(
                np.array_equal(np.asarray(y), np.asarray(four_fwd(x)))
                and np.array_equal(np.asarray(flat_inv(y)),
                                   np.asarray(four_inv(y))))
            n1, n2 = ckks_params.ntt4_split(n_poly)
            rows.append({
                "n_poly": n_poly, "n_limbs": n_limbs, "split": f"{n1}x{n2}",
                "fwd_fused_ms": timeit(flat_fwd, x) * 1e3,
                "fwd_4step_ms": timeit(four_fwd, x) * 1e3,
                "inv_fused_ms": timeit(flat_inv, y) * 1e3,
                "inv_4step_ms": timeit(four_inv, y) * 1e3,
                "bit_parity": parity,
            })
    from repro import obs
    _merge_bench_he({"ntt4": {"batch": batch, "interpret": interpret,
                              "provenance": obs.provenance(),
                              "rows": rows}})
    _rows("NTT: flat limb-grid kernel vs 4-step transpose kernel "
          f"(batch={batch}, interpret={interpret}; BENCH_he.json "
          "'ntt4' section written)", rows)


def bench_wire():
    """Measured bytes-on-wire (repro.wire): serialized uplink per policy,
    streaming-ingest stats, and recovery error — real payloads, not the
    byte model."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import wire
    from repro.core.ckks import cipher
    from repro.core.ckks import params as ckks_params
    from repro.core.secure_agg import AggregatorConfig, SelectiveHEAggregator
    from repro.wire import stream as ws

    ctx = ckks_params.make_context(n_poly=1024, n_limbs=2, delta_bits=24)
    sk, pk = cipher.keygen(ctx, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    model = {"w": jnp.asarray(rng.randn(4096, 4), jnp.float32)}
    sens = np.abs(rng.randn(4096 * 4))
    agg = SelectiveHEAggregator.build(
        ctx, model, sens, AggregatorConfig(p_ratio=0.1, strategy="top_p"))
    n_clients = 4
    clients = [jax.tree_util.tree_map(lambda x, i=i: x + 0.02 * i, model)
               for i in range(n_clients)]
    expect = jax.tree_util.tree_map(lambda *xs: sum(xs) / n_clients, *clients)
    naive = ctx.encrypted_bytes(agg.part.n_total, packed=False)
    est = agg.overhead_report()["bytes_total"]

    policies = [
        ("full_f32", False, "f32"),
        ("seeded_f32", True, "f32"),
        ("seeded_f16", True, "f16"),
        ("seeded_i8", True, "i8"),
    ]
    rows = []
    for name, seed_cts, codec in policies:
        blobs = []
        for i, m in enumerate(clients):
            key = jax.random.PRNGKey(100 + i)
            if seed_cts:
                upd = agg.client_protect_seeded(m, sk, key, a_seed=7000 + i)
                sct = wire.seed_compress(upd.ct, 7000 + i)
            else:
                upd, sct = agg.client_protect(m, pk, key), None
            blobs.append(ws.pack_update_frames(
                upd, cid=i, n_samples=4, rnd=0, seeded=sct,
                plain_codec=codec))
        ingest = ws.StreamIngest(ctx)
        for b in blobs:
            ingest.ingest(b, 1.0 / n_clients)
        rec = agg.client_recover_params(ingest.finalize(), sk)
        err = max(float(jnp.abs(a - b).max()) for a, b in zip(
            jax.tree_util.tree_leaves(rec),
            jax.tree_util.tree_leaves(expect)))
        per_client = len(blobs[0])
        rows.append({
            "policy": name,
            "measured_B_per_client": per_client,
            "estimated_B_per_client": est,
            "vs_naive_all_enc": naive / per_client,
            "peak_chunk_buffers": ingest.peak_chunk_buffers,
            "recover_err": err,
        })
    _rows("Wire: measured bytes-on-wire per client "
          f"(N={ctx.n_poly}, {n_clients} clients, p=0.1, "
          f"naive all-encrypted = {naive} B)", rows)


def _run_sharded_workers(module: str, bench: str, artifact: str,
                         ndevs=(1, 2, 8)) -> dict:
    """Shared scaffold for the subprocess-per-device-count benchmarks.

    jax locks the device count at first init, so each point runs `module`
    in its own subprocess with
    XLA_FLAGS=--xla_force_host_platform_device_count=<n> and collects the
    worker's last stdout line as JSON.  Writes {bench, per_devices} to
    `artifact` (repo root) only if EVERY point succeeded — a partial
    artifact would silently shrink the README table.  Returns per_devices.
    """
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    per_dev = {}
    for ndev in ndevs:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
        env["PYTHONPATH"] = os.path.join(root, "src") + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        proc = subprocess.run(
            [sys.executable, "-m", module, "--devices", str(ndev)],
            cwd=root, env=env, capture_output=True, text=True)
        out_lines = proc.stdout.strip().splitlines()
        if proc.returncode != 0 or not out_lines:
            raise RuntimeError(
                f"{bench} worker ndev={ndev} failed "
                f"({artifact} left untouched):\n{proc.stdout}\n{proc.stderr}")
        per_dev[str(ndev)] = json.loads(out_lines[-1])
    from repro import obs
    with open(os.path.join(root, artifact), "w") as f:
        json.dump({"bench": bench, "provenance": obs.provenance(),
                   "per_devices": per_dev}, f, indent=2)
    return per_dev


def bench_agg_sharded():
    """Multi-chip sharded HE aggregation vs the single-device fused engine.

    Subprocess per device count (see _run_sharded_workers).  Records
    sharded vs single-device weighted_sum, the streaming-ingest flush (one
    chunk-batched accumulate launch per update), and bit-parity flags.
    Emits BENCH_agg_sharded.json (repo root).
    """
    per_dev = _run_sharded_workers("benchmarks.agg_sharded", "agg_sharded",
                                   "BENCH_agg_sharded.json")
    rows = []
    for ndev in sorted(per_dev, key=int):
        r = per_dev[ndev]
        rows.append({
            "devices": int(ndev), "mesh": str(r["mesh"]),
            "ws_single_ms": r["weighted_sum_single_ms"],
            "ws_sharded_ms": r["weighted_sum_sharded_ms"],
            "parity": r["sharded_parity"],
            "ingest_ms": r["stream_ingest_single_ms"],
            "ingest_sharded_ms": r["stream_ingest_sharded_ms"],
            "launches_per_update": r["launches_per_update"],
        })
    _rows("Sharded HE aggregation: 1/2/8 host devices vs single-device "
          "fused baseline (BENCH_agg_sharded.json written)", rows)


def bench_uplink_sharded():
    """Sharded client uplink (seeded encrypt) vs the single-device path.

    Times `ShardedHe.encrypt_values_seeded` (weights -> seeded ciphertext,
    chunks sharded over `data`, limbs over `model`) against
    `cipher.encrypt_values_seeded`, plus the pk encrypt pair and the
    measured seeded-vs-full frame bytes.  Subprocess per device count (see
    _run_sharded_workers).  Emits BENCH_uplink_sharded.json (repo root).
    """
    per_dev = _run_sharded_workers("benchmarks.uplink_sharded",
                                   "uplink_sharded",
                                   "BENCH_uplink_sharded.json")
    rows = []
    for ndev in sorted(per_dev, key=int):
        r = per_dev[ndev]
        rows.append({
            "devices": int(ndev), "mesh": str(r["mesh"]),
            "seeded_single_ms": r["encrypt_seeded_single_ms"],
            "seeded_sharded_ms": r["encrypt_seeded_sharded_ms"],
            "pk_single_ms": r["encrypt_pk_single_ms"],
            "pk_sharded_ms": r["encrypt_pk_sharded_ms"],
            "parity": r["sharded_parity"],
            "uplink_ratio": r["uplink_ratio"],
        })
    _rows("Sharded client uplink: seeded encrypt at 1/2/8 host devices vs "
          "single-device (BENCH_uplink_sharded.json written)", rows)


def bench_tune(smoke: bool = False):
    """Autotuner sweep (kernels/tune.py): measure every launch-config
    candidate per (op, N, L, B) point, record the winners.

    Full mode writes BENCH_tune.json (repo root) — the default-vs-tuned
    table the README renders — and saves the tuning cache to
    REPRO_HE_TUNE_CACHE (falling back to tuning/<platform>.json) for
    `REPRO_HE_BACKEND=auto` runs.  `--smoke` sweeps one tiny point per op
    with reps=1 and touches no repo artifacts (the cache still goes to
    REPRO_HE_TUNE_CACHE if set) — the CI docs job uses it to exercise the
    sweep -> save -> load path end to end.
    """
    import jax
    from repro import obs
    from repro.core.ckks import params as ckks_params
    from repro.kernels import ops, tune

    if smoke:
        points = [(64, 2, 4)]
        op_names = ("ntt_fwd", "mul_add")
        reps = 1
    else:
        points = [(2048, 2, 8), (8192, 2, 8)]
        op_names = ops.OPS
        reps = 2

    tune.clear_cache()
    rows = []
    for n_poly, n_limbs, b in points:
        ctx = ckks_params.make_context(
            n_poly=n_poly, n_limbs=n_limbs,
            delta_bits=20 if n_poly <= 256 else 26)
        for op in op_names:
            res = tune.sweep_op(op, ctx, b=b, reps=reps)
            rows.append(res.to_row())

    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    cache_out = tune.cache_path()
    if cache_out is None and not smoke:
        cache_out = os.path.join(root, "tuning",
                                 f"{jax.default_backend()}.json")
        os.makedirs(os.path.dirname(cache_out), exist_ok=True)
    if cache_out:
        tune.save_cache(cache_out)
        # prove the round trip: what we just wrote must resolve identically
        n_loaded = tune.load_cache(cache_out)
        assert n_loaded == len(rows), (n_loaded, len(rows))

    if not smoke:
        with open(os.path.join(root, "BENCH_tune.json"), "w") as f:
            json.dump({"provenance": obs.provenance(),
                       "interpret": jax.default_backend() == "cpu",
                       "cache": cache_out, "rows": rows}, f, indent=2)
            f.write("\n")

    regressions = [r for r in rows if r["tuned_ms"] > r["default_ms"]]
    assert not regressions, regressions  # winner includes the default
    _rows("Autotuner sweep: default vs tuned per (op, N, L, B) "
          + ("[smoke — no artifacts]" if smoke
             else "(BENCH_tune.json + tuning cache written)"),
          rows, keys=["op", "n", "l", "b", "backend", "default_ms",
                      "tuned_ms", "speedup", "candidates", "pruned"])


def bench_uplink_hybrid(smoke: bool = False):
    """Transcipher (hybrid-HE) thin-client uplink vs the seeded-CKKS
    client: measured client-side encrypt wall-time, modeled client FLOPs,
    and measured frame bytes, plus the bit-parity of the two aggregates
    through StreamIngest (DESIGN.md §15).  Full mode writes
    BENCH_uplink_hybrid.json (repo root); --smoke shrinks the shapes and
    touches no repo artifacts.
    """
    import math

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import obs
    from repro.core.ckks import cipher, encoding
    from repro.core.ckks import params as ckks_params
    from repro.core.ckks import transcipher as tc
    from repro.wire import compress as wc
    from repro.wire import stream as ws

    if smoke:
        n_poly, n_limbs, delta_bits, n_chunks, reps = 256, 2, 20, 2, 1
    else:
        n_poly, n_limbs, delta_bits, n_chunks, reps = 2048, 2, 24, 32, 5
    ctx = ckks_params.make_context(n_poly=n_poly, n_limbs=n_limbs,
                                   delta_bits=delta_bits)
    sk, _pk = cipher.keygen(ctx, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    values = rng.randn(n_chunks, ctx.slots).astype(np.float32) * 0.05
    plain = rng.randn(64).astype(np.float32)
    vals_j = jnp.asarray(values)
    key = jax.random.PRNGKey(7)
    a_seed, cid, rnd = 9001, 0, 0

    # modeled client arithmetic (documented, not measured): both paths pay
    # the length-2N encode FFT (~5 n log2 n real flops); the seeded client
    # additionally runs L forward NTTs per chunk (N/2 log2 N butterflies,
    # ~8 int-ops each: one Montgomery modmul + two modadds) plus the RNS
    # noise/rounding stack the model ignores — so the ratio is a floor.
    fft_flops = 5.0 * (2 * n_poly) * math.log2(2 * n_poly)
    ntt_flops = n_limbs * (n_poly / 2) * math.log2(n_poly) * 8
    flops_seeded = n_chunks * (fft_flops + ntt_flops)
    flops_masked = n_chunks * fft_flops

    rows, per_derive = [], {}
    for dname, derive in (("fold_chunk", wc.DERIVE_FOLD_CHUNK),
                          ("ctr", wc.DERIVE_CTR)):
        cm, sm = tc.provision(ctx, sk, key, a_seed, n_chunks, derive=derive)

        def seeded_client():
            return cipher.encrypt_values_seeded(ctx, sk, vals_j, key, a_seed,
                                                derive=derive).data

        def masked_client():
            return tc.mask_values(ctx, cm, values)

        t_seeded = _timeit(seeded_client, reps=reps)
        t_masked = _timeit(masked_client, reps=reps)

        # measured wire frames, both directions of the acceptance invariant
        coeffs = jnp.asarray(encoding.encode_np(values, ctx))
        ct_ref = cipher.encrypt_coeffs_seeded(ctx, sk, coeffs, key, a_seed,
                                              derive=derive)
        from repro.core.secure_agg import ProtectedUpdate
        blob_seeded = ws.pack_update_frames(
            ProtectedUpdate(ct=ct_ref, plain=jnp.asarray(plain)),
            cid=cid, n_samples=1, rnd=rnd,
            seeded=wc.seed_compress(ct_ref, a_seed, derive))
        mc = wc.MaskedChunk(masked=masked_client(), a_seed=a_seed,
                            scale=cm.scale, derive=derive)
        blob_masked = ws.pack_masked_update_frames(
            mc, wc.seed_compress(cm.seed_ct, cm.escrow_a_seed, derive),
            plain, cid=cid, n_samples=1, rnd=rnd)

        ing_a = ws.StreamIngest(ctx)
        ing_a.ingest(blob_seeded, 1.0)
        ing_b = ws.StreamIngest(ctx,
                                transcipher_materials={(cid, rnd): sm})
        ing_b.ingest(blob_masked, 1.0)
        parity = bool(np.array_equal(
            np.asarray(ing_a.finalize().ct.data),
            np.asarray(ing_b.finalize().ct.data)))

        r = {
            "derive": dname,
            "seeded_encrypt_ms": t_seeded * 1e3,
            "masked_encrypt_ms": t_masked * 1e3,
            "encrypt_speedup": t_seeded / t_masked,
            "client_mflops_seeded": flops_seeded / 1e6,
            "client_mflops_masked": flops_masked / 1e6,
            "seeded_B": len(blob_seeded),
            "masked_B": len(blob_masked),
            "uplink_ratio": len(blob_masked) / len(blob_seeded),
            "model_ct_B": tc.seeded_uplink_bytes(n_chunks, n_limbs, n_poly),
            "model_masked_B": tc.masked_uplink_bytes(n_chunks, n_poly),
            "bit_parity": parity,
        }
        assert parity, f"transcipher/seeded aggregate bits differ ({dname})"
        rows.append(r)
        per_derive[dname] = r

    if not smoke:
        root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
        with open(os.path.join(root, "BENCH_uplink_hybrid.json"), "w") as f:
            json.dump({"bench": "uplink_hybrid",
                       "provenance": obs.provenance(),
                       "n_poly": n_poly, "n_limbs": n_limbs,
                       "n_chunks": n_chunks, "delta_bits": delta_bits,
                       "reps": reps, "per_derive": per_derive}, f, indent=2)
            f.write("\n")

    _rows("Hybrid (transcipher) uplink vs seeded CKKS client "
          f"(N={n_poly}, L={n_limbs}, chunks={n_chunks}"
          + (" [smoke — no artifacts]" if smoke
             else "; BENCH_uplink_hybrid.json written") + ")",
          rows)


def bench_roofline():
    """Summarize dry-run artifacts (run repro.launch.dryrun first)."""
    art_dir = os.path.join(os.path.dirname(__file__), "artifacts")
    rows = []
    if os.path.isdir(art_dir):
        for fn in sorted(os.listdir(art_dir)):
            if not fn.endswith(".json"):
                continue
            a = json.load(open(os.path.join(art_dir, fn)))
            r = a["roofline"]
            rows.append({
                "arch": a["arch"], "shape": a["shape"], "mesh": a["mesh"],
                "tag": a.get("tag", ""),
                "compute_ms": r["compute_s"] * 1e3,
                "memory_ms": r["memory_s"] * 1e3,
                "collective_ms": r["collective_s"] * 1e3,
                "dominant": r["dominant"],
                "flops_ratio": r["flops_ratio"],
                "roofline_frac": r["roofline_fraction"],
            })
    _rows("Roofline terms from dry-run artifacts", rows)


def bench_selective(smoke: bool = False):
    """Paper-scale selective encryption end to end (benchmarks/selective.py):
    fine-tune -> sensitivity -> HE mask agreement -> partitioned seeded wire
    -> sharded streaming aggregation -> recover, swept over p; full mode
    writes BENCH_selective.json."""
    from benchmarks.selective import run_selective

    run_selective(smoke=smoke)


def bench_serve(smoke: bool = False):
    """Aggregation-service sustained updates/sec (benchmarks/serve.py):
    10k simulated clients per round, partial quorum (seal at target,
    stragglers dropped), background worker folding round r while round
    r+1 submits; full mode writes BENCH_serve.json."""
    from benchmarks.serve import run_serve

    run_serve(smoke=smoke)


ALL = {
    "table4": bench_table4,
    "table6": bench_table6,
    "table7": bench_table7,
    "fig7": bench_fig7,
    "fig8": bench_fig8,
    "fig14a": bench_fig14a,
    "dp": bench_dp,
    "kernels": bench_kernels,
    "he": bench_he,
    "ntt": bench_ntt,
    "wire": bench_wire,
    "agg-sharded": bench_agg_sharded,
    "uplink-sharded": bench_uplink_sharded,
    "uplink-hybrid": bench_uplink_hybrid,
    "tune": bench_tune,
    "roofline": bench_roofline,
    "selective": bench_selective,
    "serve": bench_serve,
}


def main() -> None:
    ap = argparse.ArgumentParser(
        prog="benchmarks.run",
        formatter_class=argparse.RawDescriptionHelpFormatter,
        description="FedML-HE reproduction benchmark harness.",
        epilog="modes:\n" + "\n".join(
            f"  {name:<12} "
            + ((fn.__doc__ or "").strip().splitlines() or [""])[0]
            for name, fn in ALL.items())
        + "\n\nenvironment (canonical list: README.md 'Environment "
          "variables & flags'):\n"
          "  REPRO_HE_BACKEND=ref|pallas|pallas4|auto\n"
          "      backend for every HE op (default ref; pallas runs the\n"
          "      kernels in interpret mode on CPU; pallas4 swaps the NTT\n"
          "      family for the 4-step transpose kernels, DESIGN.md §10;\n"
          "      auto resolves per op/shape from the tuning cache,\n"
          "      DESIGN.md §12)\n"
          "  REPRO_HE_TUNE_CACHE=path\n"
          "      JSON tuning cache for the 'tune' mode and auto backend\n"
          "  XLA_FLAGS=--xla_force_host_platform_device_count=<n>\n"
          "      simulate <n> host devices; must be set before the first\n"
          "      jax import ('agg-sharded' / 'uplink-sharded' manage this\n"
          "      themselves via subprocess workers)\n"
          "  REPRO_WIRE_VERSION=1|2\n"
          "      pin the wire emit version (default 2; 1 = legacy layout\n"
          "      for staged rollouts)\n"
          "  REPRO_UPLINK_MODE=auto|full|seeded|transcipher\n"
          "      default uplink path for FLClient.protect_and_pack\n"
          "      (transcipher = thin-client hybrid-HE, DESIGN.md §15)")
    ap.add_argument("modes", nargs="*", metavar="mode",
                    help="benchmark modes to run (default: all)")
    ap.add_argument("--smoke", action="store_true",
                    help="tune/selective/serve modes: tiny sweep, no repo "
                         "artifacts (CI exercises the full code path)")
    args = ap.parse_args()
    names = args.modes or list(ALL)
    unknown = [n for n in names if n not in ALL]
    if unknown:
        ap.error(f"unknown mode(s) {unknown}; choose from {list(ALL)}")
    for n in names:
        t0 = time.time()
        if n in ("tune", "selective", "serve", "uplink-hybrid"):
            ALL[n](smoke=args.smoke)
        else:
            ALL[n]()
        print(f"[{n} done in {time.time()-t0:.1f}s]")


if __name__ == "__main__":
    main()
